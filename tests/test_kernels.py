"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True
executes the kernel body on CPU; TPU is the lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.forest import RandomForest
from repro.kernels import ops, ref


# ----------------------------------------------------------------------
# quantize
# ----------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(256, 256), (512, 256), (256, 512),
                                   (512, 512)])
@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_matches_ref(shape, bits, dtype):
    x = (jax.random.normal(jax.random.key(0), shape, jnp.float32) * 3
         ).astype(dtype)
    q, s = ops.quantize(x, bits=bits)
    qr, sr = ref.quantize_ref(x, bits)
    dq = np.abs(np.asarray(q, np.int32) - np.asarray(qr, np.int32))
    if dtype == jnp.float32:
        assert (dq == 0).all()
    else:
        # bf16 inputs: ulp-level division-order differences flip round()
        # ties on a tiny fraction of elements — off-by-one only
        assert dq.max() <= 1 and (dq > 0).mean() < 0.01
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


@pytest.mark.parametrize("bits", [8, 4])
def test_quantize_roundtrip_error_bound(bits):
    x = jax.random.normal(jax.random.key(1), (512, 512), jnp.float32)
    q, s = ops.quantize(x, bits=bits)
    xd = ops.dequantize(q, s)
    # error bounded by half a quantization step per tile
    step = np.asarray(s)
    err = np.abs(np.asarray(xd) - np.asarray(x))
    tile_err = err.reshape(2, 256, 2, 256).max(axis=(1, 3))
    assert (tile_err <= step * 0.5001 + 1e-7).all()


def test_dequantize_matches_ref():
    x = jax.random.normal(jax.random.key(2), (512, 256), jnp.float32)
    q, s = ops.quantize(x, bits=8)
    d1 = ops.dequantize(q, s)
    d2 = ref.dequantize_ref(np.asarray(q), np.asarray(s))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)


# ----------------------------------------------------------------------
# rf_predict
# ----------------------------------------------------------------------
@pytest.mark.parametrize("depth,n_trees,n", [(4, 5, 32), (6, 20, 100),
                                             (8, 40, 257)])
def test_rf_predict_matches_ref(depth, n_trees, n):
    rng = np.random.default_rng(depth)
    X = rng.normal(size=(300, 6)).astype(np.float32)
    y = (X[:, 0] * 2 + np.sin(X[:, 1]) + X[:, 2] * X[:, 3]).astype(np.float32)
    rf = RandomForest(n_trees=n_trees, depth=depth).fit(X, y)
    Xt = rng.normal(size=(n, 6)).astype(np.float32)
    f, t, l = [jnp.asarray(a) for a in rf.packed()]
    pk = ops.rf_predict(f, t, l, jnp.asarray(Xt), depth=depth)
    pr = ref.rf_predict_ref(f, t, l, jnp.asarray(Xt), depth=depth)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), atol=1e-5)


# ----------------------------------------------------------------------
# ssd_scan
# ----------------------------------------------------------------------
@pytest.mark.parametrize("Q,H,P,N", [(16, 8, 8, 16), (32, 16, 16, 24),
                                     (64, 8, 32, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk_matches_ref(Q, H, P, N, dtype):
    B, nC = 2, 2
    k = jax.random.key(Q + H)
    ks = jax.random.split(k, 4)
    xq = (jax.random.normal(ks[0], (B, nC, Q, H, P)) * 0.1).astype(dtype)
    Bq = (jax.random.normal(ks[1], (B, nC, Q, N)) * 0.3).astype(dtype)
    Cq = (jax.random.normal(ks[2], (B, nC, Q, N)) * 0.3).astype(dtype)
    da = -jnp.abs(jax.random.normal(ks[3], (B, nC, H, Q))) * 0.1
    y, st = ops.ssd_chunk(xq, Bq, Cq, da)
    for b in range(B):
        for c in range(nC):
            yr, sr = ref.ssd_chunk_ref(xq[b, c], Bq[b, c], Cq[b, c],
                                       da[b, c])
            tol = 1e-4 if dtype == jnp.float32 else 3e-2
            np.testing.assert_allclose(np.asarray(y[b, c]), np.asarray(yr),
                                       atol=tol, rtol=tol)
            np.testing.assert_allclose(np.asarray(st[b, c]), np.asarray(sr),
                                       atol=tol, rtol=tol)


def test_ssd_kernel_vs_model_path():
    """Kernel output must agree with the model's ssd_chunked (which also
    handles the cross-chunk recurrence)."""
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N, Q = 1, 64, 4, 8, 16, 16
    k = jax.random.key(0)
    ks = jax.random.split(k, 4)
    xh = jax.random.normal(ks[0], (B, S, H, P)) * 0.1
    Bc = jax.random.normal(ks[1], (B, S, N)) * 0.3
    Cc = jax.random.normal(ks[2], (B, S, N)) * 0.3
    da = -jnp.abs(jax.random.normal(ks[3], (B, S, H))) * 0.1
    y_model, _ = ssd_chunked(xh, Bc, Cc, da, Q)
    # kernel computes the DIAGONAL part only; compare against a
    # single-chunk call where diag == full
    y_model1, _ = ssd_chunked(xh[:, :Q], Bc[:, :Q], Cc[:, :Q], da[:, :Q], Q)
    xq = (xh[:, :Q] * 1.0).reshape(B, 1, Q, H, P)
    yk, _ = ops.ssd_chunk(xq, Bc[:, :Q].reshape(B, 1, Q, N),
                          Cc[:, :Q].reshape(B, 1, Q, N),
                          da[:, :Q].transpose(0, 2, 1).reshape(B, 1, H, Q))
    np.testing.assert_allclose(np.asarray(yk[0, 0]),
                               np.asarray(y_model1[0]), atol=1e-4)
