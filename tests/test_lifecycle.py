"""Online predictor lifecycle (drift -> probe -> refresh) test tier.

Four layers of pinning:

  * gating — ``REPRO_LIFECYCLE=off`` (the default) builds NO manager
    and every historical trace golden replays byte-identical
    (parametrized per pin);
  * unit + hypothesis properties for the EWMA drift detector, the
    windowed percentile estimator, the sliding window, and the
    deterministic refresh;
  * backend parity — a refreshed forest predicts the same matrix on
    numpy / jnp / pallas within the repo's standard tolerance;
  * the headline recovery pin — after a provider shift under noisy
    snapshots, the lifecycle run detects, refits and holds residual
    accuracy while the frozen predictor degrades, at lower Eq. 1
    monitoring spend than the periodic-full-probe baseline.
"""
import dataclasses
import hashlib
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.core.forest import RandomForest
from repro.lifecycle import (DriftConfig, EwmaDriftDetector,
                             LifecycleConfig, LifecycleManager,
                             ProbeConfig, ProbeScheduler, RefreshConfig,
                             ResidualStats, SlidingWindow,
                             WindowedPercentileEstimator,
                             baseline_probe_spend, decay_seed_data,
                             lifecycle_mode, pretrain_predictor,
                             refresh_forest, run_lifecycle_comparison)
from repro.wan.monitor import probe_cost_usd

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover
    HAVE_HYPOTHESIS = False

HERE = os.path.dirname(__file__)


# ----------------------------------------------------------------------
# gating: off = no manager, on = manager wired through the stack
# ----------------------------------------------------------------------
def test_lifecycle_mode_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_LIFECYCLE", raising=False)
    assert lifecycle_mode() == "off"
    assert lifecycle_mode("on") == "on"
    monkeypatch.setenv("REPRO_LIFECYCLE", "on")
    assert lifecycle_mode() == "on"
    assert lifecycle_mode("off") == "off"     # explicit arg beats env
    with pytest.raises(ValueError):
        lifecycle_mode("sometimes")
    monkeypatch.setenv("REPRO_LIFECYCLE", "adaptive")
    with pytest.raises(ValueError):
        lifecycle_mode()


def test_engine_default_builds_no_manager(monkeypatch):
    from repro.scenarios import ScenarioEngine, get_scenario
    monkeypatch.delenv("REPRO_LIFECYCLE", raising=False)
    spec = dataclasses.replace(get_scenario("provider_shift"), steps=2)
    eng = ScenarioEngine(spec, seed=0)
    assert eng.lifecycle is None
    assert eng.controller.lifecycle is None


def test_engine_env_on_builds_manager(monkeypatch):
    from repro.scenarios import ScenarioEngine, get_scenario
    monkeypatch.setenv("REPRO_LIFECYCLE", "on")
    spec = dataclasses.replace(get_scenario("provider_shift"), steps=3)
    eng = ScenarioEngine(spec, seed=0)
    assert isinstance(eng.lifecycle, LifecycleManager)
    assert eng.controller.lifecycle is eng.lifecycle
    eng.run()
    assert len(eng.lifecycle.records) == 3
    assert [r.step for r in eng.lifecycle.records] == [0, 1, 2]


def test_engine_accepts_prebuilt_manager():
    from repro.core.predictor import SnapshotPredictor
    from repro.scenarios import ScenarioEngine, get_scenario
    spec = dataclasses.replace(get_scenario("provider_shift"), steps=2)
    pred = SnapshotPredictor()
    mgr = LifecycleManager(pred, 8, active=False)
    eng = ScenarioEngine(spec, seed=0, predictor=pred, lifecycle=mgr)
    assert eng.lifecycle is mgr
    eng.run()
    assert len(mgr.records) == 2


# ----------------------------------------------------------------------
# satellite 1: every historical golden replays byte-identical with
# REPRO_LIFECYCLE=off — parametrized per pin
# ----------------------------------------------------------------------
def _golden_hashes():
    with open(os.path.join(HERE, "data", "trace_golden.json")) as f:
        return json.load(f)["hashes"]


GOLDEN = _golden_hashes()


@pytest.fixture(scope="module")
def collected_hashes():
    """Run the golden collector ONCE with the lifecycle explicitly
    gated off; each parametrized pin then compares its own key."""
    path = os.path.join(HERE, os.pardir, "tools", "gen_trace_goldens.py")
    spec = importlib.util.spec_from_file_location("gen_trace_goldens", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    old = os.environ.get("REPRO_LIFECYCLE")
    os.environ["REPRO_LIFECYCLE"] = "off"
    try:
        return mod.collect()
    finally:
        if old is None:
            os.environ.pop("REPRO_LIFECYCLE", None)
        else:                                       # pragma: no cover
            os.environ["REPRO_LIFECYCLE"] = old


@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_golden_pin_lifecycle_off(key, collected_hashes):
    """With the lifecycle off, trace `key` is byte-identical to the
    sha256 pinned before this subsystem existed."""
    assert key in collected_hashes, f"collector no longer produces {key}"
    assert collected_hashes[key] == GOLDEN[key]


def test_golden_set_spans_all_suites():
    keys = GOLDEN.keys()
    for prefix, minimum in (("scenario/", 9), ("fleet/", 4),
                            ("placement/", 3)):
        assert sum(k.startswith(prefix) for k in keys) >= minimum


# ----------------------------------------------------------------------
# drift detector: units
# ----------------------------------------------------------------------
def _feed(det, seq):
    """Feed a scalar sequence; return the list of tick indices (0-based
    position in `seq`) on which a DriftSignal fired."""
    alarms = []
    for i, r in enumerate(seq):
        if det.update(np.asarray(r)) is not None:
            alarms.append(i)
    return alarms


def test_detector_zero_stream_never_trips():
    det = EwmaDriftDetector((), DriftConfig())
    assert _feed(det, [0.0] * 200) == []
    assert not det.suspicious()


def test_detector_signals_within_k_of_step():
    cfg = DriftConfig(threshold=4.0, k_consecutive=3, warmup=10)
    det = EwmaDriftDetector((), cfg)
    onset = 30
    seq = [0.0] * onset + [1.0] * 10
    alarms = _feed(det, seq)
    # z jumps over threshold at `onset`; streak reaches K at onset+K-1
    # and the signal repeats every tick until reset
    assert alarms[0] == onset + cfg.k_consecutive - 1
    assert det.suspicious()


def test_detector_signal_structure_and_pairs():
    cfg = DriftConfig(k_consecutive=2, warmup=5)
    det = EwmaDriftDetector((3, 3), cfg)
    r = np.zeros((3, 3))
    for _ in range(20):
        assert det.update(r) is None
    r2 = r.copy()
    r2[0, 2] = 2.0
    r2[1, 0] = -2.0
    assert det.update(r2) is None                   # streak = 1
    sig = det.update(r2)                            # streak = 2 = K
    assert sig is not None
    assert set(sig.pairs) == {(0, 2), (1, 0)}
    assert sig.z_max > cfg.threshold
    assert sig.consec_max == 2


def test_detector_baseline_frozen_under_suspicion():
    """A suspicious pair must not talk its drift into the baseline."""
    det = EwmaDriftDetector((), DriftConfig(warmup=5))
    for _ in range(20):
        det.update(np.asarray(0.0))
    mean_before = float(det.mean)
    for _ in range(6):
        det.update(np.asarray(3.0))                 # sustained drift
    assert float(det.mean) == pytest.approx(mean_before)
    assert det.suspicious()


def test_detector_streak_resets_on_calm_tick():
    cfg = DriftConfig(k_consecutive=3, warmup=5)
    det = EwmaDriftDetector((), cfg)
    for _ in range(20):
        det.update(np.asarray(0.0))
    det.update(np.asarray(2.0))
    det.update(np.asarray(2.0))
    assert int(det.consec) == 2
    det.update(np.asarray(0.0))                     # calm tick
    assert int(det.consec) == 0
    assert not det.suspicious()


def test_detector_reset_forgets_everything():
    det = EwmaDriftDetector((), DriftConfig(warmup=5))
    _feed(det, [0.0] * 15 + [5.0] * 5)
    assert det.suspicious()
    det.reset()
    assert not det.suspicious()
    assert det.ticks == 0
    assert _feed(det, [0.0] * 50) == []


def test_detector_nan_residual_skipped_and_counted():
    """A poisoned residual (NaN/inf — a lost probe, a dead link's 0/0)
    must never touch the EWMA baselines: skip-and-count, no alarm, no
    permanent mean/var corruption."""
    det = EwmaDriftDetector((), DriftConfig(warmup=5))
    for _ in range(20):
        det.update(np.asarray(0.1))
    mean_before, var_before = float(det.mean), float(det.var)
    assert det.update(np.asarray(np.nan)) is None
    assert det.update(np.asarray(np.inf)) is None
    assert det.nan_skipped == 2
    assert float(det.mean) == mean_before           # baseline untouched
    assert float(det.var) == var_before
    assert np.isfinite(det.mean).all() and np.isfinite(det.var).all()
    assert not det.suspicious()                     # poisoned != drift
    # detection still works after the poisoned ticks
    alarms = _feed(det, [3.0] * 10)
    assert alarms and alarms[0] == det.cfg.k_consecutive - 1


def test_detector_nan_during_warmup_and_matrix_partial():
    """NaN in the very first / warmup samples must not seed a NaN
    baseline; in a matrix, only the poisoned entries are skipped."""
    det = EwmaDriftDetector((2, 2), DriftConfig(warmup=3))
    r0 = np.array([[0.0, np.nan], [0.2, 0.0]])
    det.update(r0)                                  # seeding sample
    assert np.isfinite(det.mean).all()
    assert det.nan_skipped == 1
    for _ in range(30):
        sig = det.update(np.array([[0.0, 0.1], [0.2, np.inf]]))
        assert sig is None
    assert np.isfinite(det.mean).all() and np.isfinite(det.var).all()
    assert det.nan_skipped == 31


def test_residual_stats_excludes_nonfinite():
    """The accuracy EWMA averages only finite entries; an all-poisoned
    tick repeats the previous value (history still appended)."""
    stats = ResidualStats(alpha=0.5)
    stats.update(np.array([0.2, 0.4]))
    assert stats.value == pytest.approx(0.3)
    stats.update(np.array([np.nan, 0.1]))           # finite-only mean
    assert stats.value == pytest.approx(0.5 * 0.3 + 0.5 * 0.1)
    held = stats.value
    stats.update(np.array([np.nan, np.inf]))        # all poisoned
    assert stats.value == pytest.approx(held)
    assert len(stats.history) == 3


# ----------------------------------------------------------------------
# satellite 2a: drift-detector hypothesis properties
# ----------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    @given(st.floats(-10.0, 10.0, allow_nan=False),
           st.integers(20, 120))
    @settings(max_examples=30, deadline=None)
    def test_property_constant_stream_no_false_positive(value, n):
        """Any constant residual stream standardizes to z = 0 forever
        — no false positive regardless of the constant's size."""
        det = EwmaDriftDetector((), DriftConfig())
        assert _feed(det, [value] * n) == []

    @given(st.floats(0.2, 8.0), st.integers(10, 40))
    @settings(max_examples=30, deadline=None)
    def test_property_sustained_step_detected_within_k(delta, onset):
        """A sustained step whose standardized magnitude clears the
        threshold (delta >= thr * sqrt(var_floor) here) is signalled
        within k_consecutive ticks of onset."""
        cfg = DriftConfig()
        det = EwmaDriftDetector((), cfg)
        seq = [0.0] * max(onset, cfg.warmup) + [delta] * (
            cfg.k_consecutive + 2)
        alarms = _feed(det, seq)
        assert alarms, "sustained step never signalled"
        assert alarms[0] - max(onset, cfg.warmup) <= cfg.k_consecutive - 1

    @given(st.lists(st.floats(-5.0, 5.0, allow_nan=False),
                    min_size=20, max_size=80))
    @settings(max_examples=30, deadline=None)
    def test_property_sign_convention_invariance(seq):
        """Feeding -r trips at exactly the same ticks as r: detection
        must not care which way achieved/predicted is oriented."""
        a = EwmaDriftDetector((), DriftConfig())
        b = EwmaDriftDetector((), DriftConfig())
        assert _feed(a, seq) == _feed(b, [-x for x in seq])


# ----------------------------------------------------------------------
# windowed percentile estimator
# ----------------------------------------------------------------------
def test_estimator_validates_args():
    with pytest.raises(ValueError):
        WindowedPercentileEstimator((2, 2), window=0)
    with pytest.raises(ValueError):
        WindowedPercentileEstimator((2, 2), q=120.0)


def test_estimator_empty_passthrough_and_none_capacity():
    est = WindowedPercentileEstimator((3, 3))
    assert est.capacity() is None
    pred = np.full((3, 3), 777.0)
    out = est.clamp_matrix(pred)
    assert np.array_equal(out, pred)
    assert out is not pred                          # always a copy


def test_estimator_clamp_off_diagonal_only():
    est = WindowedPercentileEstimator((3, 3), window=4, q=95.0)
    est.push(np.full((3, 3), 100.0))
    pred = np.full((3, 3), 500.0)
    np.fill_diagonal(pred, 9999.0)
    out = est.clamp_matrix(pred, headroom=1.5)
    off = ~np.eye(3, dtype=bool)
    assert np.allclose(out[off], 150.0)             # 1.5 x capacity
    assert np.allclose(np.diag(out), 9999.0)        # diag untouched


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 10),
           st.floats(0.0, 100.0))
    @settings(max_examples=30, deadline=None)
    def test_property_capacity_within_data_range(seed, n_push, q):
        rng = np.random.default_rng(seed)
        est = WindowedPercentileEstimator((4, 4), window=6, q=q)
        samples = rng.uniform(1.0, 1000.0, (n_push, 4, 4))
        for s in samples:
            est.push(s)
        tail = samples[-min(n_push, 6):]
        cap = est.capacity()
        assert np.all(cap >= tail.min(axis=0) - 1e-9)
        assert np.all(cap <= tail.max(axis=0) + 1e-9)

    @given(st.integers(0, 2 ** 31 - 1), st.floats(0.0, 100.0),
           st.floats(0.0, 100.0))
    @settings(max_examples=30, deadline=None)
    def test_property_capacity_monotone_in_quantile(seed, q1, q2):
        rng = np.random.default_rng(seed)
        est = WindowedPercentileEstimator((3, 3), window=8)
        for _ in range(5):
            est.push(rng.uniform(1.0, 1000.0, (3, 3)))
        lo, hi = sorted((q1, q2))
        assert np.all(est.capacity(lo) <= est.capacity(hi) + 1e-9)

    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 8))
    @settings(max_examples=20, deadline=None)
    def test_property_window_roll_stability(seed, extra):
        """Pushing window+extra samples is equivalent to a fresh
        estimator fed only the last `window` of them."""
        rng = np.random.default_rng(seed)
        window = 5
        samples = rng.uniform(1.0, 1000.0, (window + extra, 2, 2))
        rolled = WindowedPercentileEstimator((2, 2), window=window)
        for s in samples:
            rolled.push(s)
        fresh = WindowedPercentileEstimator((2, 2), window=window)
        for s in samples[-window:]:
            fresh.push(s)
        assert rolled.n_samples == fresh.n_samples == window
        assert np.array_equal(rolled.capacity(), fresh.capacity())


# ----------------------------------------------------------------------
# sliding harvest window
# ----------------------------------------------------------------------
def test_sliding_window_trims_with_partial_chunk_split():
    w = SlidingWindow(capacity=5)
    X1 = np.arange(18, dtype=np.float32).reshape(3, 6)
    y1 = np.array([10.0, 11.0, 12.0], np.float32)
    X2 = X1 + 100
    y2 = y1 + 100
    w.push(X1, y1)
    w.push(X2, y2)                  # 6 rows -> oldest row must fall off
    assert w.n_rows == 5
    X, y = w.rows()
    assert np.array_equal(y, np.array([11, 12, 110, 111, 112],
                                      np.float32))
    assert np.array_equal(X[0], X1[1])              # chunk split kept tail


def test_sliding_window_clear_and_empty_rows():
    w = SlidingWindow(capacity=8)
    X, y = w.rows()
    assert X.shape == (0, 6) and y.shape == (0,)
    w.push(np.zeros((4, 6), np.float32), np.ones(4, np.float32))
    assert w.n_rows == 4
    w.clear()
    assert w.n_rows == 0
    assert w.rows()[1].shape == (0,)


def test_sliding_window_validates():
    with pytest.raises(ValueError):
        SlidingWindow(0)
    w = SlidingWindow(4)
    with pytest.raises(ValueError):
        w.push(np.zeros((3, 6)), np.zeros(2))


# ----------------------------------------------------------------------
# satellite 3: refresh determinism + backend parity
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def refresh_inputs():
    rng = np.random.default_rng(42)
    seed_X = rng.uniform(0, 500, (300, 6)).astype(np.float32)
    seed_y = rng.uniform(1, 400, 300).astype(np.float32)
    win_X = rng.uniform(0, 500, (250, 6)).astype(np.float32)
    win_y = rng.uniform(1, 200, 250).astype(np.float32)
    template = RandomForest(n_trees=12, depth=6, min_leaf=4,
                            seed=0).fit(seed_X, seed_y)
    return template, seed_X, seed_y, win_X, win_y


def test_refresh_is_bit_deterministic(refresh_inputs):
    """Same (template, window, seed data, cfg) => bit-identical packed
    (feat, thr, leaf) tensors, twice over."""
    template, sX, sy, wX, wy = refresh_inputs
    cfg = RefreshConfig(seed=7)
    a = refresh_forest(template, wX, wy, sX, sy, cfg)
    b = refresh_forest(template, wX, wy, sX, sy, cfg)
    for ta, tb in zip(a.packed(), b.packed()):
        assert np.array_equal(ta, tb)


def test_refresh_never_mutates_template(refresh_inputs):
    template, sX, sy, wX, wy = refresh_inputs
    before = [t.copy() for t in template.packed()]
    out = refresh_forest(template, wX, wy, sX, sy, RefreshConfig())
    assert out is not template
    for t0, t1 in zip(before, template.packed()):
        assert np.array_equal(t0, t1)


def test_refresh_requires_training_rows(refresh_inputs):
    template = refresh_inputs[0]
    empty_X = np.zeros((0, 6), np.float32)
    empty_y = np.zeros(0, np.float32)
    with pytest.raises(ValueError):
        refresh_forest(template, empty_X, empty_y, None, None)
    # window-only (no seed set) is fine
    wX, wy = refresh_inputs[3], refresh_inputs[4]
    assert refresh_forest(template, wX, wy).packed()[0].shape[0] == 12


def test_decay_seed_data_deterministic_subset():
    rng = np.random.default_rng(1)
    X = rng.uniform(0, 1, (100, 6)).astype(np.float32)
    y = np.arange(100, dtype=np.float32)
    Xa, ya = decay_seed_data(X, y, 0.25, seed=3)
    Xb, yb = decay_seed_data(X, y, 0.25, seed=3)
    assert len(ya) == 25
    assert np.array_equal(ya, yb) and np.array_equal(Xa, Xb)
    assert set(ya.tolist()) <= set(y.tolist())      # a true subset
    assert np.all(np.diff(ya) > 0)                  # sorted row order
    assert decay_seed_data(X, y, 0.0, seed=3)[1].shape == (0,)


def test_refreshed_predictor_backend_parity(refresh_inputs):
    """numpy / jnp / pallas predictions of a REFRESHED forest agree
    within the repo's standard parity tolerance."""
    from repro.core.predictor import BwPredictor
    template, sX, sy, wX, wy = refresh_inputs
    pred = BwPredictor(refresh_forest(template, wX, wy, sX, sy,
                                      RefreshConfig()))
    n = 6
    rng = np.random.default_rng(5)
    snap = rng.uniform(10, 400, (n, n))
    mem = rng.uniform(0, 1, n)
    cpu = rng.uniform(0, 1, n)
    retr = np.rint(rng.uniform(0, 20, (n, n)))
    dist = rng.uniform(100, 9000, (n, n))
    base = pred.predict_matrix(n, snap, mem, cpu, retr, dist,
                               backend="numpy")
    for backend in ("jnp", "pallas"):
        other = np.asarray(pred.predict_matrix(
            n, snap, mem, cpu, retr, dist, backend=backend))
        np.testing.assert_allclose(other, base, rtol=1e-4, atol=0.05)


# ----------------------------------------------------------------------
# cost-aware probe scheduling
# ----------------------------------------------------------------------
def test_scheduler_quiet_ticks_never_probe():
    s = ProbeScheduler(8)
    assert not any(s.want_full(k, suspicious=False) for k in range(50))
    assert s.spend_usd == 0.0 and s.full_probes == 0


def test_scheduler_cooldown_gates_full_probes():
    s = ProbeScheduler(8, ProbeConfig(cooldown_ticks=3))
    assert s.want_full(10, True)
    s.charge_full(10)
    assert not s.want_full(11, True)
    assert not s.want_full(12, True)
    assert s.want_full(13, True)                    # cooldown elapsed


def test_scheduler_spend_arithmetic():
    cfg = ProbeConfig()
    s = ProbeScheduler(8, cfg)
    s.charge_full(0)
    s.charge_full(5)
    s.charge_snapshot(7)
    want = (2 * probe_cost_usd(cfg.probe_seconds, 8)
            + 7 * probe_cost_usd(cfg.snapshot_seconds, 8))
    assert s.spend_usd == pytest.approx(want)
    assert s.full_probes == 2 and s.snapshots == 7


def test_baseline_probe_spend_matches_cadence():
    """40 steps x 10 simulated min at a 30-min cadence = 13 probes."""
    cfg = ProbeConfig()
    want = 13 * probe_cost_usd(cfg.probe_seconds, 8)
    assert baseline_probe_spend(40, 8) == pytest.approx(want)
    assert baseline_probe_spend(0, 8) == 0.0


# ----------------------------------------------------------------------
# manager behavior outside the headline scenario
# ----------------------------------------------------------------------
def test_shadow_manager_never_clamps():
    from repro.core.predictor import SnapshotPredictor
    mgr = LifecycleManager(SnapshotPredictor(), 3, active=False)
    mgr.estimator.push(np.full((3, 3), 10.0))
    pred = np.full((3, 3), 1e6)
    assert np.array_equal(mgr.adjust_prediction(pred), pred)


def test_active_manager_clamps_against_capacity():
    from repro.core.predictor import SnapshotPredictor
    mgr = LifecycleManager(SnapshotPredictor(), 3)
    mgr.estimator.push(np.full((3, 3), 10.0))
    out = mgr.adjust_prediction(np.full((3, 3), 1e6))
    off = ~np.eye(3, dtype=bool)
    assert np.allclose(out[off], 15.0)              # headroom 1.5 x 10


def test_snapshot_predictor_cannot_refresh():
    from repro.core.predictor import SnapshotPredictor
    assert not LifecycleManager(SnapshotPredictor(), 8).can_refresh()


def test_quiet_scenario_on_mode_stays_silent():
    """With the default snapshot-ablation predictor in a QUIET-ish
    scenario the residual stream carries no drift: lifecycle=on must
    spend ZERO full-probe dollars and never signal or refit."""
    from repro.scenarios import ScenarioEngine, get_scenario
    spec = dataclasses.replace(get_scenario("skew_ramp"), steps=12)
    eng = ScenarioEngine(spec, seed=3, lifecycle="on")
    assert eng.lifecycle is not None
    eng.run()
    mgr = eng.lifecycle
    assert mgr.signals == []
    assert mgr.refreshes == 0
    assert mgr.scheduler.full_probes == 0
    assert len(mgr.records) == 12


# ----------------------------------------------------------------------
# the headline recovery pin (provider_shift_drift, seed 3)
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def comparison():
    return run_lifecycle_comparison(scenario="provider_shift_drift",
                                    seed=3, pre_steps=15)


def test_recovery_preshift_series_identical(comparison):
    """Before the shift the shadow and active runs are the SAME
    deterministic replay — residuals match to the last bit."""
    fr = comparison["modes"]["frozen"]["resid"]
    lc = comparison["modes"]["lifecycle"]["resid"]
    assert fr[:15] == lc[:15]


def test_recovery_drift_detected_promptly(comparison):
    lc = comparison["modes"]["lifecycle"]
    assert lc["signal_steps"], "no drift signal after the shift"
    assert 15 <= lc["signal_steps"][0] <= 20
    assert lc["refresh_steps"], "drift never produced a refit"
    assert 15 <= lc["refresh_steps"][0] <= 22
    assert lc["refreshes"] >= 1


def test_recovery_refreshed_beats_frozen_accuracy(comparison):
    """Post-recovery (steps 25+) the refreshed predictor holds residual
    accuracy while the frozen one keeps degrading."""
    fr = comparison["modes"]["frozen"]["resid"]
    lc = comparison["modes"]["lifecycle"]["resid"]
    fr_post = float(np.mean(fr[25:]))
    lc_post = float(np.mean(lc[25:]))
    assert lc_post < 0.3 < fr_post
    assert lc_post < fr_post


def test_recovery_costs_less_than_periodic_probing(comparison):
    """The drift-gated probe schedule undercuts the frozen baseline's
    Tetrium-cadence full probing in Eq. 1 dollars."""
    fr = comparison["modes"]["frozen"]
    lc = comparison["modes"]["lifecycle"]
    assert fr["full_probes"] == 0                   # shadow never probes
    assert lc["full_probes"] >= 1                   # but spent SOME
    assert lc["monitor_usd"] < 0.75 * fr["monitor_usd"]


def test_recovery_frozen_mode_is_pure_shadow(comparison):
    """The frozen run's trace is byte-identical to a plain engine run
    with the same pretrained predictor and NO manager at all — the
    shadow observes without perturbing."""
    from repro.scenarios import ScenarioEngine, get_scenario
    spec = get_scenario("provider_shift_drift")
    predictor, _, _ = pretrain_predictor(spec, seed=3, pre_steps=15)
    res = ScenarioEngine(spec, seed=3, predictor=predictor).run()
    sha = hashlib.sha256(res.trace.to_json().encode()).hexdigest()
    assert comparison["modes"]["frozen"]["trace_sha"] == sha


def test_recovery_lifecycle_config_defaults():
    """The headline pins ride on these defaults — changing them is a
    reviewed decision, not an accident."""
    cfg = LifecycleConfig()
    assert cfg.drift.k_consecutive == 3
    assert cfg.drift.threshold == 4.0
    assert cfg.refresh.min_rows == 224
    assert cfg.probes.cooldown_ticks == 3
    assert cfg.clamp_headroom == 1.5
