"""Fleet integration tier: N concurrent jobs arbitrated over one WAN.

Covers the acceptance criteria of the fleet subsystem: byte-identical
replay of a >=3-job scenario, per-job min-link BW inside the priority-
weighted fair-share envelope, exactly one batched RF kernel launch per
fleet tick (counted at both the predictor and the kernel wrapper), and
the arbitration invariants (per-host budget never oversubscribed,
caps proportional to priority on fully shared links).
"""
import numpy as np
import pytest

from repro.core.global_opt import split_budget
from repro.fleet import (BatchedRfPredictor, FleetController, JobSpec,
                         TenantView, default_fleet_forest,
                         get_fleet_scenario, run_fleet_scenario)
from repro.wan.simulator import WanSimulator

QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0)


@pytest.fixture(scope="module")
def forest():
    """One small deterministic forest shared by every fleet test."""
    return default_fleet_forest(n_samples=40, n_trees=6, depth=4, seed=7)


@pytest.fixture(scope="module")
def steady(forest):
    """One deterministic run of the 3-job steady scenario."""
    return run_fleet_scenario(get_fleet_scenario("fleet_steady"),
                              seed=0, forest=forest)


# ----------------------------------------------------------------------
# Acceptance: determinism, fairness envelope, one kernel launch per tick
# ----------------------------------------------------------------------
def test_fleet_replay_byte_identical(forest):
    """>=3 concurrent jobs replay to byte-identical canonical JSON."""
    spec = get_fleet_scenario("fleet_steady")
    assert len(spec.jobs) >= 3
    a = run_fleet_scenario(spec, seed=3, forest=forest).trace.to_json()
    b = run_fleet_scenario(get_fleet_scenario("fleet_steady"),
                           seed=3, forest=forest).trace.to_json()
    assert a.encode() == b.encode()


def test_fleet_seeds_diverge(forest):
    a = run_fleet_scenario(get_fleet_scenario("fleet_steady"),
                           seed=0, forest=forest).trace.to_json()
    b = run_fleet_scenario(get_fleet_scenario("fleet_steady"),
                           seed=1, forest=forest).trace.to_json()
    assert a != b


def test_one_rf_kernel_launch_per_tick(forest, monkeypatch):
    """The whole fleet's inference is ONE kernel launch per tick,
    counted both at the batched predictor and at the kernel wrapper
    actually launching Pallas."""
    from repro.kernels import ops
    launches = {"n": 0}
    real = ops.rf_predict

    def counting(*args, **kw):
        launches["n"] += 1
        return real(*args, **kw)

    monkeypatch.setattr(ops, "rf_predict", counting)
    res = run_fleet_scenario(get_fleet_scenario("fleet_steady"),
                             seed=0, forest=forest)
    ticks = len(res.trace.steps)
    assert res.trace.steps[-1].kernel_calls == ticks
    assert launches["n"] == ticks
    # the per-tick counter in the trace is cumulative and monotone
    assert [s.kernel_calls for s in res.trace.steps] == \
        list(range(1, ticks + 1))


def test_one_launch_per_tick_through_churn(forest):
    """Arrivals bootstrap from the snapshot ablation (no RF launch), so
    churn never breaks the one-launch-per-tick invariant."""
    res = run_fleet_scenario(get_fleet_scenario("fleet_churn"),
                             seed=0, forest=forest)
    ticks = len(res.trace.steps)
    assert res.trace.steps[-1].kernel_calls == ticks
    assert [s.n_jobs for s in res.trace.steps] == \
        [2, 2, 2, 2, 3, 3, 3, 3, 3, 2, 2, 2, 2, 2]


def test_min_bw_within_fair_share_envelope(steady):
    """Each job's credited min-link BW stays within its arbitrated
    envelope (TC shaping: achieved <= cap on every contended link)."""
    for s in steady.trace.steps:
        for row in s.jobs:
            assert row["achieved_min"] <= row["cap_min"] + 1e-9


def test_priority_orders_budget_cap_and_bw(forest):
    """On a fully shared slice, the higher-priority job gets the larger
    connection budget, the larger capacity share (proportional to its
    weight), and at least the lower-priority job's min-link BW."""
    res = run_fleet_scenario(get_fleet_scenario("fleet_priority_shift"),
                             seed=0, forest=forest)
    pre = res.trace.steps[4]          # before the shift: serving 4, batch 1
    rows = {r["name"]: r for r in pre.jobs}
    assert rows["serving"]["budget"] > rows["batch"]["budget"]
    assert rows["serving"]["cap_min"] == pytest.approx(
        4.0 * rows["batch"]["cap_min"], rel=1e-6)
    assert rows["serving"]["achieved_min"] >= rows["batch"]["achieved_min"]
    post = res.trace.steps[-1]        # after: batch 6, serving 4
    rows = {r["name"]: r for r in post.jobs}
    assert rows["batch"]["budget"] > rows["serving"]["budget"]
    assert rows["batch"]["cap_min"] > rows["serving"]["cap_min"]


def test_per_host_budget_never_oversubscribed(forest):
    """Arbitration invariant: at every DC, the admitted jobs' budgets
    sum to at most the fleet-wide per-host M."""
    sim = WanSimulator(seed=0, **QUIET)
    fleet = FleetController(
        sim, BatchedRfPredictor(forest), m_total=8,
        jobs=(JobSpec("a", (0, 1, 2, 3), priority=5.0),
              JobSpec("b", (0, 1, 4, 5), priority=2.0),
              JobSpec("c", (0, 2, 4, 6), priority=1.0)))
    fleet.tick()
    per_dc = np.zeros(sim.N)
    for job in fleet.jobs.values():
        m = job.controller.envelope.max_conns
        for d in job.spec.dcs:
            per_dc[d] += m
    assert (per_dc <= 8).all()
    # and every job keeps at least one connection of budget
    assert all(j.controller.envelope.max_conns >= 1
               for j in fleet.jobs.values())


def test_depart_frees_share_for_survivors(forest):
    """After the low-priority job departs, the survivor's envelope
    grows back toward the full per-host budget."""
    res = run_fleet_scenario(get_fleet_scenario("fleet_churn"),
                             seed=0, forest=forest)
    t = res.trace
    before = {r["name"]: r for r in t.steps[8].jobs}   # 3 jobs
    after = {r["name"]: r for r in t.steps[9].jobs}    # batch departed
    assert after["serving"]["budget"] >= before["serving"]["budget"]
    assert "batch" not in after


# ----------------------------------------------------------------------
# Tenant crediting + the sliced view
# ----------------------------------------------------------------------
def test_tenant_crediting_sums_to_aggregate_fill():
    """Per-tenant credited BW from one fleet-wide fill equals the
    aggregate fill split by connection count (flows on a pair share
    the pair's per-connection rate)."""
    sim = WanSimulator(seed=0, **QUIET)
    c1 = np.zeros((8, 8))
    c1[0, 1] = 6
    c2 = np.zeros((8, 8))
    c2[0, 1] = 2
    per = sim.waterfill_tenants({"a": c1, "b": c2})
    agg = sim.waterfill(c1 + c2)
    assert per["a"][0, 1] + per["b"][0, 1] == pytest.approx(agg[0, 1])
    assert per["a"][0, 1] == pytest.approx(3.0 * per["b"][0, 1])


def test_registered_rival_contends_in_measurement():
    """A tenant measuring its own flows sees rival tenants as real
    contention — but never its own registration twice."""
    sim = WanSimulator(seed=0, **QUIET)
    c = np.zeros((8, 8))
    c[0, 1] = 4
    solo = sim.waterfill(c, tenant="a")
    sim.set_tenant_conns("a", c)
    again = sim.waterfill(c, tenant="a")
    np.testing.assert_allclose(again, solo)        # no double-count
    rival = np.zeros((8, 8))
    rival[0, 1] = 4
    sim.set_tenant_conns("b", rival)
    contended = sim.waterfill(c, tenant="a")
    assert contended[0, 1] < solo[0, 1]
    sim.clear_tenant("b")
    np.testing.assert_allclose(sim.waterfill(c, tenant="a"), solo)


def test_tenant_view_slices_the_shared_mesh():
    """TenantView embeds slice conns into the mesh, measures tenant-
    aware, and slices back; with no rivals it matches the plain fill."""
    sim = WanSimulator(seed=0, **QUIET)
    view = TenantView(sim, "job", dcs=(2, 5, 6, 7))
    assert view.N == 4
    assert view.regions == [sim.regions[i] for i in (2, 5, 6, 7)]
    c = np.ones((4, 4)) * 3
    got = view.waterfill(c)
    full = np.zeros((8, 8))
    full[np.ix_([2, 5, 6, 7], [2, 5, 6, 7])] = c
    want = sim.waterfill(full)[np.ix_([2, 5, 6, 7], [2, 5, 6, 7])]
    np.testing.assert_allclose(got, want)


def test_tenant_view_rejects_bad_slices():
    sim = WanSimulator(seed=0)
    with pytest.raises(ValueError):
        TenantView(sim, "x", dcs=(0, 0, 1))
    with pytest.raises(ValueError):
        TenantView(sim, "x", dcs=(0, 99))


def test_duplicate_job_name_rejected(forest):
    sim = WanSimulator(seed=0, **QUIET)
    fleet = FleetController(sim, BatchedRfPredictor(forest),
                            jobs=(JobSpec("a", (0, 1)),))
    with pytest.raises(ValueError):
        fleet.add_job(JobSpec("a", (2, 3)))


def test_single_dc_job_rejected_at_admission(forest):
    """A one-DC job has no WAN pairs to plan; it must be rejected at
    add_job instead of crashing the whole fleet's next tick."""
    sim = WanSimulator(seed=0, **QUIET)
    fleet = FleetController(sim, BatchedRfPredictor(forest),
                            jobs=(JobSpec("a", (0, 1)),))
    with pytest.raises(ValueError, match="WAN pairs"):
        fleet.add_job(JobSpec("solo", (3,)))
    fleet.tick()                                  # fleet still healthy
    assert list(fleet.jobs) == ["a"]


def test_fleet_timeline_rejects_single_job_events(forest):
    """Workload events (Straggler, Rescale, ...) and notify=True would
    silently no-op or crash mid-run on the fleet engine; the spec is
    rejected up front instead."""
    from repro.fleet import FleetEngine, FleetScenarioSpec
    from repro.scenarios import LinkDegrade, Straggler, at
    jobs = (JobSpec("a", (0, 1, 2)), JobSpec("b", (0, 1, 3)))
    bad = FleetScenarioSpec(
        name="bad", steps=4, jobs=jobs,
        events=(at(1, Straggler(slowdown=4.0)),), sim_kwargs=dict(QUIET))
    with pytest.raises(ValueError, match="single-job-engine"):
        FleetEngine(bad, seed=0, forest=forest)
    noisy = FleetScenarioSpec(
        name="bad2", steps=4, jobs=jobs,
        events=(at(1, LinkDegrade(("us-east", "us-west"), 0.1,
                                  notify=True)),),
        sim_kwargs=dict(QUIET))
    with pytest.raises(ValueError, match="notify"):
        FleetEngine(noisy, seed=0, forest=forest)


def test_mesh_scale_envelope_rejected_by_controller():
    """A mesh-scale link_cap handed straight to a controller planning a
    non-prefix slice would cap the wrong links; the controller demands
    pod-scale caps instead of silently prefix-slicing."""
    from repro.control import BudgetEnvelope, WanifyController
    from repro.core.predictor import SnapshotPredictor
    sim = WanSimulator(seed=0, **QUIET)
    ctl = WanifyController(sim=sim, predictor=SnapshotPredictor(),
                           n_pods=4)
    ctl.set_envelope(BudgetEnvelope(max_conns=4,
                                    link_cap=np.full((8, 8), 500.0)))
    with pytest.raises(ValueError, match="pod scale"):
        ctl.replan()


# ----------------------------------------------------------------------
# Budget splitting (the arbiter's core primitive)
# ----------------------------------------------------------------------
def test_split_budget_invariants():
    for M in (2, 3, 8, 16):
        for w in ([1.0], [1, 1], [3, 1], [5, 2, 1], [1] * 6):
            s = split_budget(M, np.asarray(w, float))
            assert (s >= 1).all()
            if M >= len(w):
                assert s.sum() <= M
            # monotone in weight (equal weights may differ by the
            # 1-connection largest-remainder slack)
            for i in range(len(w)):
                for j in range(len(w)):
                    if w[i] < w[j]:
                        assert s[i] <= s[j]


def test_split_budget_proportions():
    np.testing.assert_array_equal(split_budget(8, np.array([3.0, 1.0])),
                                  [6, 2])
    np.testing.assert_array_equal(split_budget(8, np.array([1.0, 1.0])),
                                  [4, 4])
    # more tenants than budget: everyone keeps the floor of one
    np.testing.assert_array_equal(split_budget(3, np.array([9., 1., 1., 1.])),
                                  [1, 1, 1, 1])
