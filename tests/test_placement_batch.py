"""Batched placement-search engine tests: bit-exactness of
`estimate_cost_batch` against the scalar reference, byte-identical
search decisions vs the historical one-eval-per-move goldens and across
numpy/jax backends, lock-step `search_many` fusion, and the jax launch
bucketing."""
import json
import os

import numpy as np
import pytest

from repro.control import WanifyController
from repro.core.predictor import SnapshotPredictor
from repro.placement import (PLACEMENT_BACKENDS, SearchTask,
                             achievable_bw, estimate_cost,
                             estimate_cost_batch, exhaustive_place,
                             get_workload, greedy_place,
                             placement_backend, search_many,
                             workload_names)
from repro.placement.query import QuerySpec, Stage, skewed_partitions
from repro.wan.monitor import egress_price_vector
from repro.wan.simulator import WanSimulator

QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0)
GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "placement_golden.json")
FIELDS = ("makespan_s", "net_s", "compute_s", "egress_gb", "egress_usd",
          "instance_usd")


def plan_bw(n, seed=0):
    """Achievable BW + per-region egress prices at a quiet steady state."""
    sim = WanSimulator(seed=seed, **QUIET)
    ctl = WanifyController(sim, SnapshotPredictor(), n_pods=n)
    return achievable_bw(ctl.plan), egress_price_vector(sim.regions[:n])


# ----------------------------------------------------------------------
# estimate_cost_batch == [estimate_cost(p) for p in batch], bit-for-bit
# ----------------------------------------------------------------------
def assert_batch_matches_scalar(query, placements, bw, price):
    batch = estimate_cost_batch(query, placements, bw,
                                egress_usd_per_gb=price)
    for m, p in enumerate(placements):
        ref = estimate_cost(query, p, bw, egress_usd_per_gb=price)
        for f in FIELDS:
            assert getattr(batch, f)[m] == getattr(ref, f), \
                f"{f}[{m}] diverged from the scalar reference"


def test_batch_matches_scalar_named_workloads():
    rng = np.random.default_rng(0)
    for name in workload_names():
        for n in (3, 4, 8):
            bw, price = plan_bw(n)
            q = get_workload(name, n)
            P = rng.dirichlet(np.ones(n), size=(32, q.n_shuffles()))
            assert_batch_matches_scalar(q, P, bw, price)


def test_batch_property_random_queries():
    hypothesis = pytest.importorskip("hypothesis")     # noqa: F841
    from hypothesis import given, settings, strategies as st

    @given(st.integers(2, 12), st.integers(1, 3), st.floats(1.0, 8.0),
           st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=30, deadline=None)
    def run(n, n_shuffles, skew, waves, seed):
        rng = np.random.default_rng(seed)
        stages = [Stage("map", out_ratio=float(rng.uniform(0.2, 1.5)),
                        compute_s_per_gb=float(rng.uniform(0.5, 3.0)))]
        for k in range(n_shuffles):
            stages.append(Stage(
                f"s{k}", out_ratio=float(rng.uniform(0.05, 1.5)),
                compute_s_per_gb=float(rng.uniform(0.5, 3.0)),
                waves=waves if k == n_shuffles - 1 else 1))
        q = QuerySpec("rand", input_gb=skewed_partitions(n, 80.0, skew),
                      stages=tuple(stages),
                      compute_speed=tuple(rng.uniform(0.25, 2.0, n)))
        P = rng.dirichlet(np.ones(n), size=(int(rng.integers(1, 24)),
                                            n_shuffles))
        bw = rng.uniform(5.0, 3000.0, (n, n))
        price = rng.uniform(0.01, 0.2, n)
        assert_batch_matches_scalar(q, P, bw, price)

    run()


def test_batch_validation_and_backend_resolution():
    q = get_workload("scan_agg", 4)
    bw = np.full((4, 4), 300.0)
    with pytest.raises(ValueError):
        estimate_cost_batch(q, np.ones((2, 1, 3)) / 3, bw)
    with pytest.raises(ValueError):     # fractions must sum to 1
        estimate_cost_batch(q, np.full((2, 1, 4), 0.3), bw)
    with pytest.raises(ValueError):
        placement_backend("cuda")
    assert placement_backend() in PLACEMENT_BACKENDS
    old = os.environ.get("REPRO_PLACEMENT_BACKEND")
    try:
        os.environ["REPRO_PLACEMENT_BACKEND"] = "scalar"
        assert placement_backend() == "scalar"
    finally:
        if old is None:
            del os.environ["REPRO_PLACEMENT_BACKEND"]
        else:
            os.environ["REPRO_PLACEMENT_BACKEND"] = old


def test_scalar_backend_is_the_reference():
    q = get_workload("iterative", 4)
    bw, price = plan_bw(4)
    P = np.stack([np.full((1, 4), 0.25), np.array([[0.5, 0.5, 0.0, 0.0]])])
    a = estimate_cost_batch(q, P, bw, egress_usd_per_gb=price,
                            backend="numpy")
    b = estimate_cost_batch(q, P, bw, egress_usd_per_gb=price,
                            backend="scalar")
    for f in FIELDS:
        assert (getattr(a, f) == getattr(b, f)).all()


# ----------------------------------------------------------------------
# search decisions: pinned to the historical scalar search, and equal
# across backends
# ----------------------------------------------------------------------
def decision_key(d):
    return {"placement": [[repr(v) for v in row] for row in d.placement],
            "makespan_s": repr(d.cost.makespan_s),
            "egress_usd": repr(d.cost.egress_usd),
            "evals": d.evals}


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_search_decisions_match_scalar_goldens(backend):
    """The acceptance pin: greedy and exhaustive decisions (placement,
    cost, even the eval count) are byte-identical to the pre-batching
    one-`estimate_cost`-per-move search, recorded in
    tests/data/placement_golden.json, on every named workload at
    N in {3, 4, 8} — on both array backends."""
    with open(GOLDEN) as f:
        golden = json.load(f)
    for name in workload_names():
        for n in (3, 4, 8):
            bw, price = plan_bw(n)
            q = get_workload(name, n)
            g = greedy_place(q, bw, egress_usd_per_gb=price,
                             backend=backend)
            assert decision_key(g) == golden[f"greedy/{name}/{n}"], \
                (backend, name, n)
            if n <= 4:
                e = exhaustive_place(q, bw, egress_usd_per_gb=price,
                                     levels=4, backend=backend)
                assert decision_key(e) == \
                    golden[f"exhaustive/{name}/{n}"], (backend, name, n)


def test_search_disabled_and_coarse_only_still_match_scalar():
    q = get_workload("scan_agg", 4)
    bw, price = plan_bw(4)
    for kw in (dict(coarse=0, fine=0), dict(coarse=0.1, fine=0),
               dict(coarse=0, fine=0.05)):
        a = greedy_place(q, bw, egress_usd_per_gb=price,
                         backend="scalar", **kw)
        b = greedy_place(q, bw, egress_usd_per_gb=price,
                         backend="numpy", **kw)
        assert a.placement == b.placement and a.evals == b.evals, kw


# ----------------------------------------------------------------------
# search_many: lock-step fusion never changes a decision
# ----------------------------------------------------------------------
def test_search_many_matches_independent_searches():
    rng = np.random.default_rng(2)
    tasks = []
    for i, name in enumerate(("scan_agg", "scan_agg", "two_stage_join",
                              "iterative")):
        n = 4 if i < 3 else 3           # mixed shapes force 2 groups
        tasks.append(SearchTask(query=get_workload(name, n),
                                bw=rng.uniform(40.0, 900.0, (n, n)),
                                egress_usd_per_gb=rng.uniform(0.02, 0.1,
                                                              n)))
    fused = search_many(tasks)
    for t, d in zip(tasks, fused):
        solo = greedy_place(t.query, t.bw,
                            egress_usd_per_gb=t.egress_usd_per_gb)
        assert d.placement == solo.placement
        assert d.evals == solo.evals
        assert d.cost == solo.cost


def test_jax_launches_are_bucketed():
    from repro.kernels import placement_cost as kpc
    q = get_workload("scan_agg", 4)
    bw, price = plan_bw(4)
    rng = np.random.default_rng(3)
    P = rng.dirichlet(np.ones(4), size=(40, 1))
    estimate_cost_batch(q, P[:37], bw, egress_usd_per_gb=price,
                        backend="jax")
    before = kpc.compile_count()
    # any batch size inside the same power-of-two bucket reuses the trace
    for m in (33, 40, 64):
        estimate_cost_batch(q, P[:m], bw, egress_usd_per_gb=price,
                            backend="jax")
    assert kpc.compile_count() == before
    assert kpc.bucket(1) == 64 and kpc.bucket(64) == 64
    assert kpc.bucket(65) == 128
