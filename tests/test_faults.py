"""Fault plane: gating, injection determinism, the degradation ladder,
divergence recovery, fleet quarantine, and the chaos harness pins."""
import dataclasses
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.control import ControllerConfig, WanifyController
from repro.core.predictor import SnapshotPredictor
from repro.faults import (FaultConfig, FaultPlane, ProbeTimeout,
                          ProbeTimeoutError, chaos_schedule, faults_mode)
from repro.faults.harness import chaos_report, run_chaos
from repro.faults.scenarios import CHAOS_SCENARIOS, get_chaos_scenario
from repro.fleet import (BatchedRfPredictor, FleetController, JobSpec,
                         default_fleet_forest)
from repro.fleet.arbiter import arbitrate
from repro.fleet.scenario import FleetEngine, run_fleet_scenario
from repro.scenarios import ScenarioEngine, get_scenario
from repro.scenarios.events import at
from repro.wan.monitor import SnapshotMonitor
from repro.wan.simulator import WanSimulator, WaterfillDivergence

HERE = os.path.dirname(__file__)


# ----------------------------------------------------------------------
# gating
# ----------------------------------------------------------------------
def test_faults_mode_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    assert faults_mode() == "off"
    assert faults_mode("on") == "on"
    monkeypatch.setenv("REPRO_FAULTS", "on")
    assert faults_mode() == "on"
    assert faults_mode("off") == "off"       # explicit argument wins
    with pytest.raises(ValueError, match="unknown faults mode"):
        faults_mode("chaos")


def test_engine_off_without_fault_events_builds_no_plane(monkeypatch):
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    spec = dataclasses.replace(get_scenario("steady"), steps=2)
    eng = ScenarioEngine(spec, seed=0)
    assert eng.faults is None
    assert eng.controller.faults is None


def test_scripted_fault_events_build_the_naive_plane(monkeypatch):
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    eng = ScenarioEngine(get_chaos_scenario("solver_flake").spec, seed=0)
    assert eng.faults is not None and not eng.faults.graceful


def test_faults_on_builds_the_graceful_plane():
    spec = dataclasses.replace(get_scenario("steady"), steps=2)
    eng = ScenarioEngine(spec, seed=0, faults="on")
    assert eng.faults is not None and eng.faults.graceful
    assert eng.controller.faults is eng.faults
    eng.run()                                # clean timeline still runs


# ----------------------------------------------------------------------
# tentpole acceptance: every historical golden replays byte-identical
# with REPRO_FAULTS=off — parametrized per pin
# ----------------------------------------------------------------------
def _golden_hashes():
    with open(os.path.join(HERE, "data", "trace_golden.json")) as f:
        return json.load(f)["hashes"]


GOLDEN = _golden_hashes()


def _goldens_module():
    path = os.path.join(HERE, os.pardir, "tools", "gen_trace_goldens.py")
    spec = importlib.util.spec_from_file_location("gen_trace_goldens", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def collected_hashes():
    """Run the golden collector ONCE with faults explicitly gated off;
    each parametrized pin then compares its own key."""
    mod = _goldens_module()
    old = os.environ.get("REPRO_FAULTS")
    os.environ["REPRO_FAULTS"] = "off"
    try:
        return mod.collect()
    finally:
        if old is None:
            os.environ.pop("REPRO_FAULTS", None)
        else:                                       # pragma: no cover
            os.environ["REPRO_FAULTS"] = old


@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_golden_pin_faults_off(key, collected_hashes):
    """With faults off, trace `key` is byte-identical to the sha256
    pinned before this subsystem existed."""
    assert key in collected_hashes, f"collector no longer produces {key}"
    assert collected_hashes[key] == GOLDEN[key]


def test_gen_goldens_only_filter():
    """--only regenerates a matching subset and errors on no match."""
    mod = _goldens_module()
    sub = mod.collect(only="fleet_steady")
    assert set(sub) == {"fleet/fleet_steady/seed3"}
    assert sub["fleet/fleet_steady/seed3"] == \
        GOLDEN["fleet/fleet_steady/seed3"]
    with pytest.raises(SystemExit, match="matches no pin key"):
        mod.collect(only="no_such_scenario")


# ----------------------------------------------------------------------
# reachability surface
# ----------------------------------------------------------------------
def test_set_reachable_zeroes_dead_pairs():
    sim = WanSimulator(seed=0, fluct_sigma=0.0)
    base = sim.link_bw_now().copy()
    mask = np.ones((sim.N, sim.N), bool)
    mask[2, :] = mask[:, 2] = False
    sim.set_reachable(mask)
    bw = sim.link_bw_now()
    assert bw[2, 3] == 0.0 and bw[0, 2] == 0.0
    assert bw[0, 1] == base[0, 1]            # live pairs untouched
    sim.set_reachable(None)
    assert np.array_equal(sim.link_bw_now(), base)
    with pytest.raises(ValueError, match="reachability mask"):
        sim.set_reachable(np.ones((2, 2), bool))


def test_plane_reachability_composition():
    p = FaultPlane(6, graceful=True)
    assert p.reachable_mask() is None        # clean = the no-mask path
    p.blackout(1)
    p.set_partition([[0, 2], [3, 4]])
    m = p.reachable_mask()
    assert not m[1, 0] and not m[0, 1]       # blackout kills DC 1
    assert not m[0, 3] and not m[2, 4]       # cross-group partitioned
    assert m[0, 2] and m[3, 4] and m[5, 0]   # in-group / unnamed live
    p.heal_partition()
    m2 = p.reachable_mask()
    assert m2[0, 3] and not m2[1, 0]         # blackout survives heal
    p.restore(1)
    assert p.reachable_mask() is None


# ----------------------------------------------------------------------
# the degradation ladder
# ----------------------------------------------------------------------
def _quiet_monitor(seed=0):
    sim = WanSimulator(seed=seed, fluct_sigma=0.0, snapshot_sigma=0.0,
                       runtime_sigma=0.0)
    return sim, SnapshotMonitor(sim)


def test_probe_timeout_naive_raises_graceful_degrades():
    sim, mon = _quiet_monitor()
    conns = np.ones((sim.N, sim.N))
    naive = FaultPlane(sim.N, graceful=False)
    naive.probe_fault("timeout", 5)
    with pytest.raises(ProbeTimeoutError, match="timed out at step 0"):
        naive.captured(mon, conns)

    plane = FaultPlane(sim.N, graceful=True)
    raw0, ov0 = plane.captured(mon, conns)   # clean: remembered
    assert ov0 is None
    plane.step = 3
    plane.probe_fault("timeout", 5)
    raw, ov = plane.captured(mon, conns)
    assert ov is None                        # within bounded staleness
    age = 3
    disc = plane.cfg.stale_discount ** age
    assert np.allclose(raw["snapshot_bw"], raw0["snapshot_bw"] * disc)
    assert plane.metrics.counters()["probe_retries"] == \
        plane.cfg.probe_retries
    assert plane.retry_usd > 0.0             # Eq. 1-priced backoff


def test_staleness_bottoms_out_at_the_snapshot_rung():
    sim, mon = _quiet_monitor()
    conns = np.ones((sim.N, sim.N))
    plane = FaultPlane(sim.N, graceful=True,
                       cfg=FaultConfig(max_stale_steps=2))
    plane.captured(mon, conns)
    plane.step = 5                           # age 5 > max_stale_steps 2
    plane.monitor_outage(10)
    raw, override = plane.captured(mon, conns)
    assert override is not None              # RF bypassed entirely
    off = ~np.eye(sim.N, dtype=bool)
    assert np.all(override[off] >= 1.0)      # snapshot clamp floor
    assert np.allclose(override[off],
                       np.maximum(raw["snapshot_bw"], 1.0)[off])
    assert plane.metrics.counters()["snapshot_fallbacks"] == 1


def test_probe_loss_naive_holes_graceful_backfills():
    sim, mon = _quiet_monitor()
    conns = np.ones((sim.N, sim.N))
    naive = FaultPlane(sim.N, graceful=False)
    naive.probe_fault("loss", 5, frac=0.5)
    raw, _ = naive.captured(mon, conns)
    assert np.isnan(raw["snapshot_bw"]).any()    # holes flow downstream

    plane = FaultPlane(sim.N, graceful=True)
    plane.captured(mon, conns)
    plane.step = 1
    plane.probe_fault("loss", 5, frac=0.9)
    raw2, _ = plane.captured(mon, conns)
    assert np.isfinite(raw2["snapshot_bw"]).all()


def test_monitor_outage_freezes_measurement_and_flags_it():
    sim, mon = _quiet_monitor()
    conns = np.ones((sim.N, sim.N))
    plane = FaultPlane(sim.N, graceful=True)
    m0, ok0 = plane.measured(mon, conns)
    assert ok0
    plane.step = 1
    plane.monitor_outage(4)
    sim.advance()
    m1, ok1 = plane.measured(mon, conns)
    assert not ok1 and np.array_equal(m1, m0)    # frozen fossil
    assert plane.metrics.counters()["outage_ticks"] == 1


def test_predictor_fault_injects_and_ladder_sanitizes():
    sim, _ = _quiet_monitor()
    snap = sim.measure_snapshot(np.ones((sim.N, sim.N)))
    pred = snap * 1.1
    naive = FaultPlane(sim.N, graceful=False)
    naive.predictor_fault(3, kind="nan", rows=2)
    out = naive.predicted(pred, snap)
    assert np.isnan(out).any()                   # raw injection

    plane = FaultPlane(sim.N, graceful=True)
    plane.predictor_fault(3, kind="nan", rows=2)
    out2 = plane.predicted(pred, snap)
    assert np.isfinite(out2).all()
    assert plane.metrics.counters()["rows_quarantined"] >= 1


def test_sanitize_matrix_quarantines_nan_negative_outlier():
    plane = FaultPlane(4, graceful=True,
                       cfg=FaultConfig(outlier_factor=4.0))
    snap = np.full((4, 4), 100.0)
    pred = snap.copy()
    pred[0, 1] = np.nan
    pred[1, 2] = -5.0
    pred[2, 3] = 1e6                             # > 4x reference
    out = plane.sanitize_matrix(pred, snap)
    assert out[0, 1] == 100.0 and out[1, 2] == 100.0 and out[2, 3] == 100.0
    assert out[3, 0] == pred[3, 0]               # healthy entries kept


def test_chaos_schedule_is_deterministic_per_seed():
    a = chaos_schedule(7, 40, regions=["ap-se2"])
    b = chaos_schedule(7, 40, regions=["ap-se2"])
    assert [(t.step, t.event) for t in a] == [(t.step, t.event) for t in b]
    c = chaos_schedule(8, 40, regions=["ap-se2"])
    assert [(t.step, t.event) for t in a] != [(t.step, t.event) for t in c]
    assert all(t.step < 40 for t in a)


# ----------------------------------------------------------------------
# controller rollback (ladder rung 5)
# ----------------------------------------------------------------------
def test_rollback_restores_last_known_good_plan():
    sim = WanSimulator(seed=3, fluct_sigma=0.1)
    ctl = WanifyController(sim, SnapshotPredictor(), n_pods=4,
                           cfg=ControllerConfig(advance_sim=False))
    assert ctl.rollback_plan() is None           # nothing to restore yet
    first = ctl.plan
    for _ in range(6):                           # drift until a new sig
        sim.advance()
        ctl.replan(reason="explicit")
        if ctl.plan.signature() != first.signature():
            break
    prev = ctl._prev_plan
    assert prev is not None
    restored = ctl.rollback_plan(step=9)
    assert restored is prev and ctl.plan is prev
    conns = ctl.current_conns()
    for i in range(4):
        assert tuple(int(v) for v in conns[i, :4]) == prev.conns[i]
    assert ctl.record[-1]["reason"] == "rollback"
    assert ctl.record[-1]["step"] == 9


# ----------------------------------------------------------------------
# satellite: WaterfillDivergence surfaces with step/tick context
# ----------------------------------------------------------------------
def test_engine_divergence_carries_scenario_and_step(monkeypatch):
    spec = dataclasses.replace(get_scenario("steady"), steps=3)
    eng = ScenarioEngine(spec, seed=0)

    def boom(*a, **k):
        raise WaterfillDivergence("synthetic non-convergence")
    monkeypatch.setattr(eng.sim, "waterfill", boom)
    with pytest.raises(WaterfillDivergence,
                       match=r"scenario 'steady', step 0"):
        eng.run()


def test_fleet_tick_divergence_carries_tick_context(monkeypatch):
    sim = WanSimulator(seed=0, fluct_sigma=0.0)
    fleet = FleetController(
        sim, BatchedRfPredictor(default_fleet_forest()),
        jobs=(JobSpec("a", dcs=(0, 1)), JobSpec("b", dcs=(2, 3))))

    def boom(*a, **k):
        raise WaterfillDivergence("synthetic non-convergence")
    monkeypatch.setattr(sim, "waterfill_tenants", boom)
    with pytest.raises(WaterfillDivergence, match=r"fleet tick 1"):
        fleet.tick()


def test_fused_divergence_names_the_offending_tick():
    sim = WanSimulator(seed=0, fluct_sigma=0.0, snapshot_sigma=0.0,
                       host_sigma=0.0)
    fleet = FleetController(
        sim, BatchedRfPredictor(default_fleet_forest()),
        jobs=(JobSpec("a", dcs=(0, 1, 2, 3)),
              JobSpec("b", dcs=(4, 5, 6, 7))))
    ff = fleet.fused()
    fake = {"converged": np.array([True, False, True])}
    ff._scan_fn = lambda detail: (
        lambda carry, s, b: ((carry[0], carry[1]), fake))
    with pytest.raises(WaterfillDivergence, match=r"tick 2 of 3"):
        ff.run(3)


def test_solver_fault_recovers_via_rollback():
    res = run_chaos("solver_flake", graceful=True)
    assert not res["crashed"]
    assert res["rollbacks"] >= 1
    assert res["degraded_min_bw"] > 0.0


# ----------------------------------------------------------------------
# fleet quarantine
# ----------------------------------------------------------------------
def test_arbitrate_quarantines_dead_dc():
    cap = np.full((4, 4), 100.0)
    jobs = [("a", (0, 1, 2), 1.0), ("b", (0, 1, 3), 1.0)]
    base = arbitrate(jobs, 4, 8, cap)
    mask = np.ones((4, 4), bool)
    mask[3, :] = mask[:, 3] = False              # DC 3 dead
    np.fill_diagonal(mask, True)
    quar = arbitrate(jobs, 4, 8, cap, reachable=mask)
    # job b spans the dead DC: its dead pairs are capped to ZERO,
    # including sole-tenant pairs link_shares leaves uncapped
    assert quar["b"].link_cap[1, 3] == 0.0
    assert quar["b"].link_cap[3, 0] == 0.0
    # live contended pairs keep their fair-share caps
    assert quar["b"].link_cap[0, 1] == base["b"].link_cap[0, 1]
    # job a never touched DC 3: fully unchanged
    assert quar["a"].max_conns == base["a"].max_conns
    assert np.array_equal(quar["a"].link_cap, base["a"].link_cap)


def test_fleet_blackout_untouched_job_keeps_integer_series():
    """The fleet_blackout chaos run vs the same spec with no faults:
    the batch job (disjoint from the dead DC) keeps its budget and
    connection-count series tick for tick."""
    chaos = get_chaos_scenario("fleet_blackout")
    faulted = run_fleet_scenario(chaos.spec, seed=3, faults="on")
    clean_spec = dataclasses.replace(chaos.spec, events=())
    clean = run_fleet_scenario(clean_spec, seed=3)

    def series(res, job, key):
        return [next(r[key] for r in s.jobs if r["name"] == job)
                for s in res.trace.steps]
    assert series(faulted, "batch", "budget") == \
        series(clean, "batch", "budget")
    assert series(faulted, "batch", "conns_total") == \
        series(clean, "batch", "conns_total")
    # while the touched job's envelope visibly shrank during blackout
    dead = [series(faulted, "serving", "cap_min")[t]
            for t in chaos.dead_steps]
    assert min(dead) == 0.0


def test_fleet_rejects_control_plane_fault_events():
    chaos = get_chaos_scenario("fleet_blackout")
    bad = dataclasses.replace(
        chaos.spec, events=chaos.spec.events + (at(2, ProbeTimeout(3)),))
    with pytest.raises(ValueError, match="single-job-engine"):
        FleetEngine(bad, seed=0)


# ----------------------------------------------------------------------
# lifecycle integration: outage ticks are skipped, not learned
# ----------------------------------------------------------------------
def test_monitor_outage_skips_lifecycle_ticks():
    from repro.lifecycle.manager import LifecycleManager
    spec = get_chaos_scenario("monitor_freeze").spec
    pred = SnapshotPredictor()
    mgr = LifecycleManager(pred, 8, active=False)
    eng = ScenarioEngine(spec, seed=3, predictor=pred, lifecycle=mgr,
                         faults="on")
    eng.run()
    skipped = [r.step for r in mgr.records if r.skipped]
    assert skipped                               # the outage window
    assert all(8 <= s < 20 for s in skipped)
    # skipped ticks never advanced the drift detector
    live = [r for r in mgr.records if not r.skipped]
    assert mgr.detector.ticks == len(live)


# ----------------------------------------------------------------------
# the chaos harness: headline pins (the BENCH_faults CI contract)
# ----------------------------------------------------------------------
def test_every_chaos_scenario_survives_the_ladder():
    for name in CHAOS_SCENARIOS:
        res = run_chaos(name, graceful=True)
        assert not res["crashed"], f"{name}: {res['error']}"
        assert res["steps_completed"] == res["steps_total"]
        assert res["degraded_min_bw"] > 0.0


def test_naive_ablation_crashes_where_scripted():
    for name, build in CHAOS_SCENARIOS.items():
        chaos = build()
        res = run_chaos(name, graceful=False)
        if chaos.naive_crashes:
            assert res["crashed"], f"{name} should die naively"
            assert res["steps_completed"] < res["steps_total"]
            assert res["degraded_min_bw"] == 0.0


def test_chaos_report_summary_beats_the_ablation():
    rep = chaos_report(names=["solver_flake", "dc_blackout"], seed=3)
    s = rep["summary"]
    assert s["ladder_crashes"] == 0
    assert s["naive_crashes"] == 2
    assert s["ladder_mean_mttr"] < s["naive_mean_mttr"]
    assert s["ladder_min_floor"] > 0.0 and s["naive_min_floor"] == 0.0
