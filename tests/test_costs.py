"""Monitoring cost model (Eq. 1 / Table 2): ~96% savings claim."""
from repro.core.plan import monitoring_cost
from repro.wan.monitor import annual_costs


def test_eq1_form():
    # O x N x (x*y + z)
    assert monitoring_cost(10, 4, 0.5, 2.0, 3.0) == 10 * 4 * (0.5 * 2 + 3)


def test_savings_fraction():
    """Table 2: prediction saves ~96% of runtime-monitoring cost."""
    for n in (4, 6, 8):
        c = annual_costs(n)
        assert 0.90 <= c["savings_frac"] <= 0.99, c


def test_costs_scale_with_cluster():
    c4, c8 = annual_costs(4), annual_costs(8)
    assert c8["runtime_monitoring"] > c4["runtime_monitoring"]
