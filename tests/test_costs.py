"""Monitoring cost model (Eq. 1 / Table 2): ~96% savings claim."""
import pytest

from repro.core.plan import monitoring_cost, prediction_cost
from repro.wan.monitor import (MONITOR_EVERY_MIN, MONITOR_SECONDS,
                               SNAPSHOT_SECONDS, T3_NANO_PER_SEC,
                               annual_costs, measurement_net_cost)


def test_eq1_form():
    # O x N x (x*y + z)
    assert monitoring_cost(10, 4, 0.5, 2.0, 3.0) == 10 * 4 * (0.5 * 2 + 3)


def test_savings_fraction():
    """Table 2: prediction saves ~96% of runtime-monitoring cost."""
    for n in (4, 6, 8):
        c = annual_costs(n)
        assert 0.90 <= c["savings_frac"] <= 0.99, c


def test_costs_scale_with_cluster():
    c4, c8 = annual_costs(4), annual_costs(8)
    assert c8["runtime_monitoring"] > c4["runtime_monitoring"]


@pytest.mark.parametrize("n_dcs", [4, 8])
def test_annual_costs_table2(n_dcs):
    """One Table-2 row end-to-end: prediction is strictly cheaper than
    30-minute-cadence runtime monitoring, both costs are the Eq. 1 form
    evaluated at the published constants, and the savings fraction sits
    in the paper's band."""
    c = annual_costs(n_dcs)
    assert 0.0 < c["prediction"] < c["runtime_monitoring"]
    assert 0.90 <= c["savings_frac"] <= 0.99
    # reconstruct both sides from Eq. 1 directly
    O = 365 * 24 * 60 / MONITOR_EVERY_MIN
    z_full = measurement_net_cost(MONITOR_SECONDS, n_dcs - 1)
    z_snap = measurement_net_cost(SNAPSHOT_SECONDS, n_dcs - 1)
    assert c["runtime_monitoring"] == pytest.approx(
        monitoring_cost(O, n_dcs, T3_NANO_PER_SEC, MONITOR_SECONDS, z_full))
    assert c["prediction"] == pytest.approx(
        prediction_cost(O, n_dcs, T3_NANO_PER_SEC, z_snap))
    # the 20s-vs-1s measurement window dominates the gap: the network
    # portion alone already saves ~95%
    assert z_snap == pytest.approx(z_full / MONITOR_SECONDS)


def test_annual_costs_magnitudes():
    """Table 2 sanity: an 8-DC cluster's runtime monitoring runs in the
    tens of thousands of $/yr (full-mesh 20 s iPerf every 30 min is
    dominated by egress), prediction two orders below."""
    c = annual_costs(8)
    assert 1e4 < c["runtime_monitoring"] < 1e5
    assert 1e2 < c["prediction"] < 1e4
    assert c["prediction"] == pytest.approx(
        c["runtime_monitoring"] / 20.0, rel=1e-6)
