"""Per-architecture smoke tests (REQUIRED by the assignment): a reduced
same-family config runs one forward/train step on CPU; output shapes and
no-NaN asserted. Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced
from repro.models import registry
from repro.models.layers import ShardCtx
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

CTX = ShardCtx(remat="none")


def _batch(cfg, B=2, S=32):
    b = {"tokens": jnp.ones((B, S), jnp.int32),
         "targets": jnp.ones((B, S), jnp.int32)}
    if cfg.is_encdec:
        b["enc_frames"] = jnp.ones(
            (B, cfg.encoder.source_len, cfg.encoder.d_model), jnp.bfloat16)
    if cfg.is_vlm:
        b["patch_embeds"] = jnp.ones(
            (B, cfg.encoder.source_len, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    params = registry.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    loss_fn = registry.loss_fn(cfg, CTX)

    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(p, batch), has_aux=True))(params)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        a = np.asarray(g, np.float32)
        assert np.isfinite(a).all(), f"{arch}: NaN grad at {path}"

    # one optimizer step moves the loss
    opt = init_opt_state(params)
    new_params, _, om = adamw_update(
        AdamWConfig(lr=1e-3, warmup_steps=1), params, grads, opt)
    loss2 = jax.jit(lambda p: loss_fn(p, batch)[0])(new_params)
    assert np.isfinite(float(loss2))
    assert float(om["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_logits_shape(arch):
    cfg = reduced(get_config(arch))
    params = registry.init_params(cfg, jax.random.key(0))
    B, S, S_max = 2, 16, 32
    batch = _batch(cfg, B, S)
    batch.pop("targets")
    logits, cache = jax.jit(registry.prefill_fn(cfg, CTX, S_max, tp=1))(
        params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
