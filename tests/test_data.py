"""Data pipeline: shapes, determinism, skew weights."""
import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced
from repro.data.pipeline import (DataConfig, batches, pod_skew_weights,
                                 prefetch)


def test_shapes_and_range():
    cfg = reduced(get_config("llama3-8b"))
    c = DataConfig(batch=8, seq=16, vocab=cfg.vocab, n_pods=2)
    b = next(batches(cfg, c))
    assert b["tokens"].shape == (8, 16)
    assert b["targets"].shape == (8, 16)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab


def test_deterministic():
    cfg = reduced(get_config("llama3-8b"))
    c = DataConfig(batch=4, seq=8, vocab=cfg.vocab, seed=5)
    a = next(batches(cfg, c))
    b = next(batches(cfg, c))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_modality_stubs():
    for arch in ("whisper-medium", "internvl2-2b"):
        cfg = reduced(get_config(arch))
        c = DataConfig(batch=2, seq=8, vocab=cfg.vocab)
        b = next(batches(cfg, c))
        if cfg.is_encdec:
            assert b["enc_frames"].shape == (2, cfg.encoder.source_len,
                                             cfg.encoder.d_model)
        if cfg.is_vlm:
            assert b["patch_embeds"].shape == (2, cfg.encoder.source_len,
                                               cfg.d_model)


def test_skew_weights_detect_skew():
    cfg = reduced(get_config("llama3-8b"))
    skewed = DataConfig(batch=8, seq=64, vocab=cfg.vocab, n_pods=2, skew=0.9)
    b = next(batches(cfg, skewed))
    w = pod_skew_weights(b["tokens"], 2, cfg.vocab)
    assert w.shape == (2,)
    assert abs(w.mean() - 1.0) < 1e-6


def test_prefetch_passthrough():
    cfg = reduced(get_config("llama3-8b"))
    c = DataConfig(batch=2, seq=8, vocab=cfg.vocab)
    it = prefetch(batches(cfg, c), depth=2)
    b = next(it)
    assert b["tokens"].shape == (2, 8)
