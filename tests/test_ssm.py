"""Mamba-2 SSD: chunked scan == naive recurrence; decode == prefill."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.ssm import ssd_chunked


def naive_ssd(xh, Bc, Cc, da):
    """Reference O(S*N*P) sequential recurrence."""
    B, S, H, P = xh.shape
    N = Bc.shape[-1]
    st = np.zeros((B, H, P, N), np.float64)
    ys = np.zeros((B, S, H, P), np.float64)
    xh = np.asarray(xh, np.float64)
    Bc_ = np.asarray(Bc, np.float64)
    Cc_ = np.asarray(Cc, np.float64)
    da_ = np.asarray(da, np.float64)
    for s in range(S):
        dec = np.exp(da_[:, s])                       # [B,H]
        st = st * dec[..., None, None] + \
            np.einsum("bhp,bn->bhpn", xh[:, s], Bc_[:, s])
        ys[:, s] = np.einsum("bn,bhpn->bhp", Cc_[:, s], st)
    return ys, st


def test_chunked_equals_naive():
    B, S, H, P, N, Q = 2, 48, 4, 8, 12, 16
    k = jax.random.split(jax.random.key(0), 4)
    xh = jax.random.normal(k[0], (B, S, H, P)) * 0.2
    Bc = jax.random.normal(k[1], (B, S, N)) * 0.3
    Cc = jax.random.normal(k[2], (B, S, N)) * 0.3
    da = -jnp.abs(jax.random.normal(k[3], (B, S, H))) * 0.2
    y, fin = ssd_chunked(xh, Bc, Cc, da, Q)
    yn, fn = naive_ssd(xh, Bc, Cc, da)
    np.testing.assert_allclose(np.asarray(y), yn, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(fin), fn, atol=1e-4, rtol=1e-3)


def test_chunk_size_invariance():
    B, S, H, P, N = 1, 64, 2, 4, 8
    k = jax.random.split(jax.random.key(1), 4)
    xh = jax.random.normal(k[0], (B, S, H, P)) * 0.2
    Bc = jax.random.normal(k[1], (B, S, N)) * 0.3
    Cc = jax.random.normal(k[2], (B, S, N)) * 0.3
    da = -jnp.abs(jax.random.normal(k[3], (B, S, H))) * 0.2
    y16, f16 = ssd_chunked(xh, Bc, Cc, da, 16)
    y64, f64 = ssd_chunked(xh, Bc, Cc, da, 64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(f16), np.asarray(f64),
                               atol=1e-4, rtol=1e-3)


def test_init_state_threading():
    """Splitting a sequence in two with state carry == one pass."""
    B, S, H, P, N, Q = 1, 32, 2, 4, 8, 16
    k = jax.random.split(jax.random.key(2), 4)
    xh = jax.random.normal(k[0], (B, S, H, P)) * 0.2
    Bc = jax.random.normal(k[1], (B, S, N)) * 0.3
    Cc = jax.random.normal(k[2], (B, S, N)) * 0.3
    da = -jnp.abs(jax.random.normal(k[3], (B, S, H))) * 0.2
    y_full, f_full = ssd_chunked(xh, Bc, Cc, da, Q)
    h = S // 2
    y1, f1 = ssd_chunked(xh[:, :h], Bc[:, :h], Cc[:, :h], da[:, :h], Q)
    y2, f2 = ssd_chunked(xh[:, h:], Bc[:, h:], Cc[:, h:], da[:, h:], Q,
                         init_state=f1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, h:]),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f_full),
                               atol=1e-4, rtol=1e-3)
