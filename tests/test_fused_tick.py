"""The fused fleet tick (repro.fleet.fused): each jax-port stage pinned
against its numpy reference, and the whole scanned program pinned
against the sequential `FleetController.tick` loop."""
import numpy as np
import pytest

import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core.global_opt import _pair_weights, global_optimize, \
    split_budget
from repro.core.local_opt import AimdAgent
from repro.core.relations import infer_dc_relations
from repro.fleet import (BatchedRfPredictor, FleetController, FusedFleet,
                         JobSpec, default_fleet_forest, make_schedule)
from repro.fleet import arbiter
from repro.fleet.fused import (aimd_step_jnp, connection_budgets_jnp,
                               global_ranges_jnp, link_shares_jnp,
                               relations_jnp, split_budget_jnp)
from repro.fleet.scenario import FleetEngine, FleetScenarioSpec
from repro.scenarios.events import (CrossTraffic, DiurnalCycle, JobArrive,
                                    LinkDegrade, LinkRestore, at)
from repro.wan.simulator import WanSimulator

QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0,
             host_sigma=0.0)
JOBS = (JobSpec("serving", dcs=(0, 1, 2, 3), priority=4.0),
        JobSpec("training", dcs=(0, 1, 4, 5), priority=2.0),
        JobSpec("batch", dcs=(2, 3, 6, 7), priority=1.0))


def _forest():
    return default_fleet_forest()


def build_fleet(seed=3, jobs=JOBS, m_total=8, **sim_kw):
    kw = dict(QUIET)
    kw.update(sim_kw)
    sim = WanSimulator(seed=seed, **kw)
    return FleetController(sim, BatchedRfPredictor(_forest()),
                           m_total=m_total, jobs=jobs)


def random_bw(rng, n):
    bw = rng.uniform(60.0, 2200.0, (n, n))
    bw = (bw + bw.T) / 2
    np.fill_diagonal(bw, 10000.0)
    return bw


# ----------------------------------------------------------------------
# stage-by-stage parity
# ----------------------------------------------------------------------
def test_relations_port_exact():
    rng = np.random.default_rng(0)
    with enable_x64():
        for trial in range(40):
            n = int(rng.integers(2, 9))
            bw = random_bw(rng, n)
            if trial % 3 == 0:                  # force near-duplicates
                bw[0, 1] = bw[1, 0] = bw[1 % n, 0] + rng.uniform(0, 150)
            D = float(rng.uniform(10, 300))
            ref = infer_dc_relations(bw, D)
            got = np.asarray(relations_jnp(jnp.asarray(bw), D))
            np.testing.assert_array_equal(got, ref)


def test_global_ranges_port_exact():
    """Eq. 2-3 + throttle + link-cap clamp: integer ranges match the
    numpy optimizer exactly, continuous outputs to roundoff."""
    rng = np.random.default_rng(1)
    with enable_x64():
        for trial in range(25):
            n = int(rng.integers(2, 7))
            bw = random_bw(rng, n)
            M = int(rng.integers(2, 16))
            skew = rng.uniform(0.5, 3.0, n) if trial % 2 else None
            ws = _pair_weights(n, skew)
            link_cap = np.where(rng.random((n, n)) < 0.4,
                                rng.uniform(100, 3000, (n, n)), np.inf)
            ref = global_optimize(bw, M=M, w_s=skew, link_cap=link_cap)
            got = global_ranges_jnp(jnp.asarray(bw), jnp.asarray(float(M)),
                                    jnp.asarray(ws), jnp.asarray(link_cap))
            np.testing.assert_array_equal(np.asarray(got["min_cons"]),
                                          ref.min_cons)
            np.testing.assert_array_equal(np.asarray(got["max_cons"]),
                                          ref.max_cons)
            np.testing.assert_allclose(np.asarray(got["min_bw"]),
                                       ref.min_bw, rtol=1e-12)
            np.testing.assert_allclose(np.asarray(got["max_bw"]),
                                       ref.max_bw, rtol=1e-12)
            np.testing.assert_allclose(np.asarray(got["throttle"]),
                                       ref.throttle, rtol=1e-9)


def test_split_budget_port_exact():
    rng = np.random.default_rng(2)
    with enable_x64():
        for _ in range(40):
            J = int(rng.integers(1, 9))
            m = int(rng.integers(1, 33))
            w = rng.choice([1.0, 2.0, 4.0, 8.0], J)
            present = rng.random(J) < 0.7
            ref = np.full(J, float(m))
            if present.any():
                ref[present] = split_budget(m, w[present])
            got = np.asarray(split_budget_jnp(m, jnp.asarray(w),
                                              jnp.asarray(present)))
            np.testing.assert_array_equal(got, ref)


def test_arbiter_ports_exact():
    rng = np.random.default_rng(3)
    with enable_x64():
        for _ in range(15):
            J, n = int(rng.integers(1, 7)), 8
            presence = rng.random((J, n)) < 0.5
            presence[:, 0] = True                # nobody floats free
            w = rng.choice([1.0, 2.0, 4.0], J)
            cap = rng.uniform(100, 5000, (n, n))
            ref_b = arbiter.connection_budgets(presence, w, 8)
            got_b = np.asarray(connection_budgets_jnp(
                jnp.asarray(presence), jnp.asarray(w), 8))
            np.testing.assert_array_equal(got_b, ref_b)
            ref_c = arbiter.link_shares(presence, w, cap)
            got_c = np.asarray(link_shares_jnp(
                jnp.asarray(presence), jnp.asarray(w), jnp.asarray(cap)))
            np.testing.assert_allclose(got_c, ref_c, rtol=1e-12)


def test_aimd_port_exact():
    """Every source row stepped at once == per-agent Python AIMD."""
    rng = np.random.default_rng(4)
    with enable_x64():
        for _ in range(10):
            n = int(rng.integers(2, 7))
            plan = global_optimize(random_bw(rng, n), M=8)
            agents = [AimdAgent.from_plan(plan, i) for i in range(n)]
            cons = np.stack([ag.cons for ag in agents])
            target = np.stack([ag.target_bw for ag in agents])
            ranges = {
                "min_cons": jnp.asarray(plan.min_cons, jnp.int32),
                "max_cons": jnp.asarray(plan.max_cons, jnp.int32),
                "min_bw": jnp.asarray(plan.min_bw),
                "max_bw": jnp.asarray(plan.max_bw),
                "unit_bw": jnp.asarray(plan.pred_bw),
                "throttle": jnp.asarray(plan.throttle),
            }
            for _step in range(4):
                mon = rng.uniform(0, 3000, (n, n))
                new_c, new_t = aimd_step_jnp(
                    jnp.asarray(cons, jnp.int32), jnp.asarray(target),
                    ranges, jnp.asarray(mon))
                for i, ag in enumerate(agents):
                    ag.step(mon[i])
                cons = np.stack([ag.cons for ag in agents])
                target = np.stack([ag.target_bw for ag in agents])
                np.testing.assert_array_equal(np.asarray(new_c), cons)
                np.testing.assert_allclose(np.asarray(new_t), target,
                                           rtol=1e-12, atol=1e-12)


# ----------------------------------------------------------------------
# whole-loop equivalence
# ----------------------------------------------------------------------
def _rows_match(seq_row, fus_row, tol=1e-6):
    assert seq_row["name"] == fus_row["name"]
    assert seq_row["budget"] == fus_row["budget"]
    assert seq_row["conns_total"] == fus_row["conns_total"]
    for k in ("cap_min", "achieved_min", "achieved_mean"):
        a, b = seq_row[k], fus_row[k]
        assert a == b or np.isclose(a, b, rtol=tol, atol=tol), \
            (k, a, b)


def test_fused_matches_sequential_ticks():
    """`run_fused(T)` reproduces T sequential ticks: identical integer
    budgets/connection totals per tick, achieved BW to roundoff, and
    the SAME final controller state (sequential ticks continue
    byte-compatibly afterwards)."""
    seq = build_fleet()
    seq_rows = [seq.tick() for _ in range(4)]
    fus = build_fleet()
    fus_rows = fus.run_fused(4)
    assert fus.tick_count == seq.tick_count == 4
    for a, b in zip(seq_rows, fus_rows):
        assert a["tick"] == b["tick"] and a["n_jobs"] == b["n_jobs"]
        for ra, rb in zip(a["jobs"], b["jobs"]):
            _rows_match(ra, rb)
    for name in seq.jobs:
        ca = seq.jobs[name].controller.current_conns()
        cb = fus.jobs[name].controller.current_conns()
        np.testing.assert_array_equal(ca, cb)
        ta = np.stack([ag.target_bw
                       for ag in seq.jobs[name].controller._agents])
        tb = np.stack([ag.target_bw
                       for ag in fus.jobs[name].controller._agents])
        np.testing.assert_allclose(ta, tb, rtol=1e-6, atol=1e-6)
    # the loop keeps running sequentially from the synced state
    a, b = seq.tick(), fus.tick()
    for ra, rb in zip(a["jobs"], b["jobs"]):
        _rows_match(ra, rb)


def test_fused_matches_engine_under_events():
    """WAN events (degrade / cross-traffic / diurnal / restore) replay
    through the precomputed schedule exactly as the FleetEngine applies
    them tick by tick."""
    events = (at(1, LinkDegrade(("us-east", "us-west"), 0.3)),
              at(2, CrossTraffic(("us-east", "eu-west"), conns=32)),
              at(3, DiurnalCycle(amplitude=0.2, period=6)),
              at(4, LinkRestore(("us-east", "us-west"))))
    spec = FleetScenarioSpec(name="x", steps=6, jobs=JOBS, events=events,
                             sim_kwargs=dict(QUIET))
    res = FleetEngine(spec, seed=3, forest=_forest()).run()
    fus = build_fleet()
    fus_rows = fus.run_fused(6, events=events)
    for a, b in zip(res.trace.steps, fus_rows):
        for ra, rb in zip(a.jobs, b["jobs"]):
            _rows_match(ra, rb)


def test_fused_with_skew_and_fluctuation():
    """Skewed jobs + live AR(1) fluctuation (consumed while the
    schedule is precomputed) still match the sequential loop."""
    jobs = (JobSpec("a", dcs=(0, 1, 2, 3), priority=2.0,
                    skew_w=(2.0, 1.0, 1.0, 0.5)),
            JobSpec("b", dcs=(2, 3, 4, 5), priority=1.0))
    kw = dict(fluct_sigma=0.1)
    seq = build_fleet(jobs=jobs, **kw)
    seq_rows = [seq.tick() for _ in range(3)]
    fus = build_fleet(jobs=jobs, **kw)
    fus_rows = fus.run_fused(3)
    for a, b in zip(seq_rows, fus_rows):
        for ra, rb in zip(a["jobs"], b["jobs"]):
            _rows_match(ra, rb)


def test_sweep_matches_individual_runs():
    """One vmapped [B,T] launch == B independent fused runs."""
    T, variants = 4, (0.25, 0.6)
    singles, bgs = [], []
    for f in variants:
        sim = WanSimulator(seed=3, **QUIET)
        s, g = make_schedule(sim, T,
                             (at(1, LinkDegrade(("us-east", "us-west"),
                                                f)),))
        singles.append(s)
        bgs.append(g)
    ff = build_fleet().fused()
    outs = ff.sweep(np.stack(singles), np.stack(bgs))
    assert outs["achieved_min"].shape == (2, T, len(JOBS))
    assert bool(outs["converged"].all())
    for b, f in enumerate(variants):
        fleet = build_fleet()
        rows = fleet.run_fused(
            T, (at(1, LinkDegrade(("us-east", "us-west"), f)),))
        for t, row in enumerate(rows):
            for j, jr in enumerate(row["jobs"]):
                assert np.isclose(jr["achieved_min"],
                                  outs["achieved_min"][b, t, j])
                assert jr["conns_total"] == int(outs["conns_total"][b, t, j])


def test_fused_contract_validation():
    """Noisy sims, mixed slice sizes, attached planners, and job-churn
    events are rejected loudly (the contract, not silent divergence)."""
    with pytest.raises(ValueError, match="snapshot_sigma"):
        build_fleet(snapshot_sigma=0.05).fused()
    with pytest.raises(ValueError, match="host_sigma|snapshot_sigma"):
        build_fleet(host_sigma=0.02).fused()
    with pytest.raises(ValueError, match="slice sizes"):
        build_fleet(jobs=(JobSpec("a", dcs=(0, 1, 2)),
                          JobSpec("b", dcs=(3, 4, 5, 6)))).fused()
    fleet = build_fleet()
    with pytest.raises(ValueError, match="replayable"):
        fleet.run_fused(2, (at(0, JobArrive(JobSpec("x", dcs=(0, 1)))),))
    from repro.placement import scan_agg
    fleet.job_planner("serving", scan_agg(4))
    with pytest.raises(ValueError, match="planners"):
        fleet.fused()


def test_fused_memoized_on_controller():
    """`FleetController.fused()` reuses the compiled program until the
    job set / priorities change."""
    fleet = build_fleet()
    f1 = fleet.fused()
    assert fleet.fused() is f1
    fleet.set_priority("batch", 6.0)
    f2 = fleet.fused()
    assert f2 is not f1
    assert isinstance(f2, FusedFleet)
