"""Scenario integration tier: scripted WAN dynamics driven end-to-end
through the closed loop (simulator -> monitor -> predictor -> global
opt -> AIMD -> plan), with deterministic replay.

Every test runs a named scenario from repro.scenarios.library and
asserts controller behavior — not unit state, but what the control
plane actually did under the scripted dynamics.
"""
import dataclasses

import numpy as np
import pytest

from repro.scenarios import (ScenarioEngine, at, flap, get_scenario,
                             run_scenario, scenario_names)
from repro.scenarios.events import LinkDegrade, LinkRestore, Straggler


@pytest.fixture(scope="module")
def results():
    """One deterministic run per (scenario, seed), shared module-wide."""
    cache = {}

    def get(name, seed=0):
        if (name, seed) not in cache:
            cache[(name, seed)] = run_scenario(get_scenario(name),
                                               seed=seed)
        return cache[(name, seed)]
    return get


# ----------------------------------------------------------------------
# Determinism contract
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", ["congestion", "runtime_fluctuation"])
def test_replay_byte_identical(name):
    """Two fresh runs with the same seed produce byte-identical traces —
    including the noisy scenario, because all draws come from the
    simulator's named RNG streams."""
    a = run_scenario(get_scenario(name), seed=3).trace.to_json()
    b = run_scenario(get_scenario(name), seed=3).trace.to_json()
    assert a.encode() == b.encode()


def test_different_seeds_diverge(results):
    a = results("runtime_fluctuation", seed=0).trace
    b = results("runtime_fluctuation", seed=1).trace
    assert a.to_json() != b.to_json()


def test_step_hook_sees_every_step():
    """The per-step tap (ride-along harnesses, e.g. repro.placement)
    fires once per step with the engine and the just-appended row."""
    eng = ScenarioEngine(get_scenario("steady"), seed=0)
    seen = []
    eng.step_hook = lambda engine, row: seen.append(
        (row.step, engine.controller.n_pods))
    res = eng.run()
    assert [s for s, _ in seen] == [r.step for r in res.trace.steps]


def test_measurement_interleaving_does_not_change_replay():
    """The RNG-stream split in action: an extra host_metrics draw does
    not shift subsequent observation noise, so a consumer polling extra
    metrics cannot perturb the replay."""
    from repro.wan.simulator import WanSimulator
    c = np.ones((8, 8))
    s1 = WanSimulator(seed=5)
    s2 = WanSimulator(seed=5)
    s2.host_metrics(c)                   # extra draw on the host stream
    np.testing.assert_array_equal(s1.measure_snapshot(c),
                                  s2.measure_snapshot(c))


# ----------------------------------------------------------------------
# Named scenarios: controller behavior under dynamics
# ----------------------------------------------------------------------
def test_steady_replans_are_periodic_only(results):
    t = results("steady").trace
    assert set(t.replan_reasons()) <= {"periodic"}
    assert len(t.replan_steps()) >= 2
    # in a quiet scenario the per-step monitor sample equals the
    # achieved ground truth exactly — replan steps included
    assert all(abs(s.monitored_mean - s.achieved_mean) < 1e-9
               for s in t.steps)


def test_congestion_exactly_one_straggler_replan(results):
    """Cross-traffic burst squeezes a ring hop: the step time spikes,
    the straggler trigger fires once (the cooldown outlasts the burst),
    and nothing else replans."""
    t = results("congestion").trace
    reasons = t.replan_reasons()
    assert reasons.count("straggler") == 1
    assert set(reasons) == {"straggler"}
    trigger = t.replan_steps("straggler")[0]
    assert 10 <= trigger < 15                 # inside the burst window
    # the burst visibly squeezed the achieved BW on the ground truth
    before = t.steps[9].achieved_min
    during = min(s.achieved_min for s in t.steps[10:15])
    assert during < 0.5 * before


def test_congestion_aimd_backoff(results):
    """The straggler replan carries an AIMD multiplicative decrease:
    the in-force connection total drops at the trigger step."""
    t = results("congestion").trace
    k = t.replan_steps("straggler")[0]
    assert t.steps[k].conns_total < t.steps[k - 1].conns_total


def test_flap_recovery_hits_plan_cache(results):
    """Degrade-then-restore oscillates the plan back to its pre-flap
    signature: the third replan reuses the compiled artifact instead of
    re-lowering (builds stay at 2, hits keep growing)."""
    t = results("link_flap").trace
    pre, down, post = t.steps[9], t.steps[15], t.steps[25]
    assert down.plan_sig != pre.plan_sig      # flap changed the plan
    assert post.plan_sig == pre.plan_sig      # recovery restored it
    assert t.replan_reasons().count("topology") == 2
    assert t.steps[-1].cache_builds == 2      # init + degraded, no 3rd
    assert t.steps[-1].cache_hits > t.steps[19].cache_hits


def test_straggler_injection_forces_aimd_decrease(results):
    """An injected slow host (network untouched) trips the straggler
    trigger; the AIMD multiplicative decrease shrinks the connection
    matrix before the replan rebuilds the bounds."""
    t = results("straggler_host").trace
    assert t.replan_reasons().count("straggler") >= 1
    k = t.replan_steps("straggler")[0]
    assert k == 15                            # the injection step
    assert t.steps[15].conns_total < t.steps[14].conns_total


def test_elastic_rescale_join_and_leave(results):
    t = results("elastic").trace
    reasons = t.replan_reasons()
    assert "rescale:6" in reasons and "rescale:4" in reasons
    assert t.steps[11].n_pods == 4
    assert t.steps[12].n_pods == 6            # join applied at step 12
    assert t.steps[28].n_pods == 4            # leave applied at step 28
    # plans stay internally consistent across the rescale
    assert all(s.conns_total >= s.n_pods * (s.n_pods - 1)
               for s in t.steps)


def test_provider_shift_triggers_topology_replan(results):
    t = results("provider_shift").trace
    assert "topology" in t.replan_reasons()
    assert t.replan_steps("topology") == [15]
    # half the mesh lost capacity: the controller's own prediction sees
    # a weaker network after the shift
    assert t.steps[16].predicted_mean < 0.9 * t.steps[14].predicted_mean


def test_skew_ramp_shifts_connection_budget():
    """§3.3.1: as DC 0's skew weight ramps to 4x, the global optimizer
    hands its pairs a larger share of the per-host connection budget
    (the AIMD agents then oscillate inside those skewed bounds)."""
    eng = ScenarioEngine(get_scenario("skew_ramp"), seed=0)
    eng.run()
    agents = eng.controller._agents
    row0_budget = int(agents[0].max_cons.sum())
    other_budget = int(agents[1].max_cons.sum())
    assert row0_budget > other_budget
    # before the ramp the budget was symmetric across DCs
    first = eng.controller.record[0]["signature"][1]
    rows = [sum(row) for row in first]
    assert len(set(rows)) == 1


def test_skew_ramp_composes_with_rescale():
    """Scripted skew weights survive an elastic rescale in either
    direction: the engine refits the skew vector to the new pod count
    (new pods carry neutral weight) instead of handing the optimizer a
    wrong-length w_s."""
    from repro.scenarios import Rescale, ScenarioSpec, SkewRamp
    spec = ScenarioSpec(
        name="skew_then_rescale", steps=24,
        events=(at(5, SkewRamp(weights=(4.0, 1.0, 1.0, 1.0), over=3)),
                at(12, Rescale(n_pods=6)),
                # a second ramp at the new width must reseed from the
                # old 4-wide weights without a shape mismatch
                at(14, SkewRamp(weights=(1.0, 1.0, 2.0, 2.0, 1.0, 1.0),
                                over=2)),
                at(18, Rescale(n_pods=3))),
        sim_kwargs=dict(fluct_sigma=0.0, snapshot_sigma=0.0,
                        runtime_sigma=0.0),
        cfg_kwargs=dict(replan_every=4))
    t = run_scenario(spec, seed=0).trace
    assert t.steps[12].n_pods == 6 and t.steps[18].n_pods == 3
    assert "rescale:6" in t.replan_reasons()


def test_cable_cut_discovered_by_periodic_trigger(results):
    """Silent degradation (no notify): the periodic trigger's snapshot
    sees the collapse and the plan changes without any explicit event."""
    t = results("cable_cut").trace
    assert t.steps[20].predicted_min < 0.5 * t.steps[10].predicted_min
    assert t.steps[25].plan_sig != t.steps[10].plan_sig


def test_cable_cut_reroute_overlay_recovers_min_bw(results):
    """The staged far-link cut: with the overlay on the engine executes
    the routed lowering and the settled post-cut min achievable BW
    strictly beats the direct-only run every step (the full acceptance
    pin — relays, both-hop charging, placement makespan — lives in
    tests/test_overlay.py)."""
    off = {s.step: s.achieved_min
           for s in results("cable_cut_reroute", seed=3).trace.steps}
    on = {s.step: s.achieved_min
          for s in run_scenario(get_scenario("cable_cut_reroute"),
                                seed=3, overlay="on").trace.steps}
    assert all(on[k] > off[k] for k in range(14, len(on)))
    assert all(on[k] == off[k] for k in range(0, 12))   # pre-cut: none


def test_diurnal_achieved_bw_tracks_cycle(results):
    """The ground-truth achieved BW follows the scripted sinusoid:
    trough steps deliver less than peak steps."""
    t = results("diurnal").trace
    peak = np.mean([s.achieved_mean for s in t.steps[5:10]])
    trough = np.mean([s.achieved_mean for s in t.steps[20:25]])
    assert trough < 0.8 * peak


# ----------------------------------------------------------------------
# DSL, trace schema, summaries
# ----------------------------------------------------------------------
def test_event_dsl_construction():
    e = at(7, LinkDegrade(("us-east", "ap-se"), 0.1))
    assert e.step == 7 and e.event.factor == 0.1
    pair = flap(10, ("us-east", "us-west"), 0.05, down_steps=5)
    assert [t.step for t in pair] == [10, 15]
    assert isinstance(pair[0].event, LinkDegrade)
    assert isinstance(pair[1].event, LinkRestore)
    # describe() strings are stable (they are part of the trace bytes)
    assert Straggler(4.0, 2).describe() == \
        "Straggler(slowdown=4.0, duration=2)"


def test_fleet_events_target_engine_surface():
    """The fleet events (JobArrive/JobDepart/PriorityShift) drive the
    engine's churn surface and keep stable describe() strings — the
    real fleet engine is exercised in tests/test_fleet.py; here a stub
    pins the DSL contract without importing the fleet package."""
    from repro.scenarios import JobArrive, JobDepart, PriorityShift

    class StubEngine:
        calls = []

        def add_job(self, spec):
            self.calls.append(("add", spec))

        def remove_job(self, name):
            self.calls.append(("remove", name))

        def set_priority(self, name, priority):
            self.calls.append(("prio", name, priority))

    eng = StubEngine()
    JobArrive(job="spec-sentinel").apply(eng)
    JobDepart(name="batch").apply(eng)
    PriorityShift(name="serving", priority=6.0).apply(eng)
    assert eng.calls == [("add", "spec-sentinel"), ("remove", "batch"),
                         ("prio", "serving", 6.0)]
    assert JobDepart(name="batch").describe() == "JobDepart(name=batch)"
    assert PriorityShift("a", 2.0).describe() == \
        "PriorityShift(name=a, priority=2.0)"


def test_unknown_scenario_rejected():
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")


def test_trace_schema_and_summary(results):
    res = results("steady")
    row = dataclasses.asdict(res.trace.steps[0])
    for key in ("step", "events", "dt", "achieved_min", "achieved_mean",
                "monitored_min", "monitored_mean", "predicted_min",
                "predicted_mean", "plan_sig", "n_pods", "conns_total",
                "replans", "cache_builds", "cache_hits"):
        assert key in row
    s = res.summary()
    assert s["steps"] == len(res.trace.steps)
    assert s["throughput_mbps"] > 0
    assert s["cache_builds"] + s["cache_hits"] > 0


def test_all_library_scenarios_build():
    for name in scenario_names():
        spec = get_scenario(name)
        assert spec.steps > 0 and spec.name == name
