"""Observability-plane tests (PR 9).

  * registry primitives: counters/gauges/histograms/series semantics,
    labeled names, kind conflicts, pure reads (incl. a hypothesis
    property that interleaved reads never perturb later values);
  * back-compat: the migrated ad-hoc counters (`cache_builds`,
    `fill_calls`, `kernel_calls`, lifecycle tallies) read identically
    through the legacy attributes and the registry;
  * passivity: every historical trace golden replays byte-identical
    with REPRO_OBS=on — parametrized per pin;
  * spans: nesting, counter deltas, rollups, bounded capacity, the
    off-gate null tracer;
  * SLE rollups: Jain index, accuracy band, capacity, responsiveness
    (with censoring), the Eq. 1 monitoring meter, scenario/fleet
    blocks;
  * export/CLI: canonical run documents, check/diff, and the obsctl
    subcommands end to end.
"""
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.obs import (NULL_TRACER, SLE_BAND, MetricsRegistry, SpanTracer,
                       accuracy_sle, capacity_sle, check_run, diff_runs,
                       export_run, export_scenario, fleet_sle, flatten,
                       jain_index, obs_mode, responsiveness_steps,
                       scenario_sle, summarize, to_json)
from repro.obs.registry import Counter, Gauge, Histogram, Series
from repro.scenarios import ScenarioEngine, get_scenario

HERE = os.path.dirname(__file__)


# ----------------------------------------------------------------------
# registry primitives
# ----------------------------------------------------------------------
def test_counter_monotone():
    c = Counter("x")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    c.reset(0)
    assert c.value == 0


def test_gauge_last_write_wins():
    g = Gauge("x")
    g.set(5)
    g.set(2)
    assert g.value == 2


def test_histogram_fixed_buckets():
    h = Histogram("x", buckets=(1, 10, 100))
    for v in (0.5, 1.0, 5, 50, 500):
        h.observe(v)
    # bisect_left: values equal to an upper bound land in its bucket
    assert h.counts == [2, 1, 1, 1]
    assert h.count == 5
    assert h.sum == pytest.approx(556.5)
    assert h.mean == pytest.approx(556.5 / 5)
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(3, 2, 1))
    with pytest.raises(ValueError):
        Histogram("bad", buckets=())


def test_series_bounded():
    s = Series("x", cap=3)
    for i in range(5):
        s.record(float(i), label="a" if i % 2 else "b")
    assert len(s) == 3
    assert s.dropped == 2
    # keeps the LAST cap points: i = 2 (b), 3 (a), 4 (b)
    assert s.by_label() == {"a": 1, "b": 2}


def test_registry_get_or_create_and_kind_conflict():
    reg = MetricsRegistry("t")
    c1 = reg.counter("hits")
    assert reg.counter("hits") is c1
    with pytest.raises(TypeError):
        reg.gauge("hits")
    lab = reg.counter("replans", labels={"reason": "periodic"})
    assert lab.name == "replans{reason=periodic}"
    assert "replans{reason=periodic}" in reg.names()
    assert reg.get("hits") is c1


def test_registry_snapshot_sorted_and_counters_view():
    reg = MetricsRegistry("t")
    reg.counter("b").inc(2)
    reg.gauge("a").set(7)
    reg.histogram("h", buckets=(1,)).observe(0.5)
    reg.series("s").record(1.0, label="x")
    snap = reg.snapshot()
    assert list(snap) == sorted(snap)
    assert snap["b"] == {"kind": "counter", "value": 2}
    # counters() covers counters AND gauges only (the span-delta view)
    assert reg.counters() == {"b": 2, "a": 7}


def test_registry_reads_are_pure_hypothesis():
    """Interleaving snapshot()/counters()/names() reads between writes
    never changes what later reads observe (two registries, identical
    write sequences, one read-hammered)."""
    hyp = pytest.importorskip("hypothesis")            # noqa: F841
    from hypothesis import given, settings, strategies as st

    op = st.tuples(st.sampled_from(["counter", "gauge", "hist", "series"]),
                   st.integers(0, 2),
                   st.floats(0, 100, allow_nan=False, width=32))

    @settings(max_examples=50, deadline=None)
    @given(st.lists(op, max_size=40))
    def run(ops):
        quiet, noisy = MetricsRegistry("q"), MetricsRegistry("n")
        for reg, read in ((quiet, False), (noisy, True)):
            for kind, idx, val in ops:
                if kind == "counter":
                    reg.counter(f"c{idx}").inc(val)
                elif kind == "gauge":
                    reg.gauge(f"g{idx}").set(val)
                elif kind == "hist":
                    reg.histogram(f"h{idx}", buckets=(10, 50)).observe(val)
                else:
                    reg.series(f"s{idx}", cap=8).record(val, label="l")
                if read:
                    reg.snapshot()
                    reg.counters()
                    reg.names()
        assert quiet.snapshot() == noisy.snapshot()

    run()


def test_registry_reads_are_pure_seeded():
    """Same property as the hypothesis test, but with a seeded PRNG so
    it still runs when hypothesis is absent from the environment."""
    import random
    rng = random.Random(0)
    ops = [(rng.choice(["counter", "gauge", "hist", "series"]),
            rng.randrange(3), rng.uniform(0, 100)) for _ in range(200)]
    quiet, noisy = MetricsRegistry("q"), MetricsRegistry("n")
    for reg, read in ((quiet, False), (noisy, True)):
        for kind, idx, val in ops:
            if kind == "counter":
                reg.counter(f"c{idx}").inc(val)
            elif kind == "gauge":
                reg.gauge(f"g{idx}").set(val)
            elif kind == "hist":
                reg.histogram(f"h{idx}", buckets=(10, 50)).observe(val)
            else:
                reg.series(f"s{idx}", cap=8).record(val, label="l")
            if read:
                reg.snapshot()
                reg.counters()
                reg.names()
    assert quiet.snapshot() == noisy.snapshot()


# ----------------------------------------------------------------------
# back-compat: legacy attributes == registry metrics
# ----------------------------------------------------------------------
def test_backcompat_counters_agree_after_scenario():
    eng = ScenarioEngine(get_scenario("steady"), seed=0)
    eng.run()
    ctl, sim = eng.controller, eng.sim
    assert ctl.cache_builds == ctl.metrics.counter("cache_builds").value
    assert ctl.cache_hits == ctl.metrics.counter("cache_hits").value
    assert ctl.cache_builds > 0 and ctl.cache_hits > 0
    assert sim.fill_calls == sim.metrics.counter("fill_calls").value
    assert sim.last_fill_iters == \
        sim.metrics.gauge("last_fill_iters").value
    assert sim.fill_calls > 0
    # the derived convergence metrics stay consistent
    h = sim.metrics.get("fill_iters")
    assert h.count == sim.fill_calls
    assert h.sum == sim.metrics.counter("fill_iters_total").value
    # replans_total matches the controller's structured record
    assert ctl.metrics.counter("replans_total").value == len(ctl.record)


def test_backcompat_setters_route_to_registry():
    eng = ScenarioEngine(get_scenario("steady"), seed=0)
    eng.controller.cache_builds = 0
    eng.controller.cache_hits = 0
    assert eng.controller.metrics.counter("cache_builds").value == 0
    eng.sim.fill_calls = 0
    eng.sim.last_fill_iters = 0
    assert eng.sim.metrics.counter("fill_calls").value == 0


def test_backcompat_probe_scheduler():
    from repro.lifecycle.probes import ProbeScheduler
    s = ProbeScheduler(n_dcs=8)
    s.charge_full(0)
    s.charge_snapshot(3)
    assert s.full_probes == 1 == s.metrics.counter("full_probes").value
    assert s.snapshots == 3 == s.metrics.counter("snapshots").value
    assert s.spend_usd == pytest.approx(
        s.metrics.counter("spend_usd").value)
    assert s.spend_usd > 0


def test_backcompat_kernel_calls():
    pytest.importorskip("jax")
    from repro.fleet import BatchedRfPredictor, default_fleet_forest
    p = BatchedRfPredictor(default_fleet_forest())
    p.predict_rows(np.zeros((4, 6), np.float32))
    assert p.kernel_calls == 1 == p.metrics.counter("kernel_calls").value
    assert p.metrics.counter("rows_total").value == 4


# ----------------------------------------------------------------------
# passivity: every golden replays byte-identical with REPRO_OBS=on
# ----------------------------------------------------------------------
def _golden_hashes():
    with open(os.path.join(HERE, "data", "trace_golden.json")) as f:
        return json.load(f)["hashes"]


GOLDEN = _golden_hashes()


@pytest.fixture(scope="module")
def collected_obs_on():
    """Run the golden collector ONCE with span tracing forced on;
    each parametrized pin then compares its own key."""
    path = os.path.join(HERE, os.pardir, "tools", "gen_trace_goldens.py")
    spec = importlib.util.spec_from_file_location("gen_trace_goldens", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    old = os.environ.get("REPRO_OBS")
    os.environ["REPRO_OBS"] = "on"
    try:
        return mod.collect()
    finally:
        if old is None:
            os.environ.pop("REPRO_OBS", None)
        else:                                       # pragma: no cover
            os.environ["REPRO_OBS"] = old


@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_golden_pin_obs_on(key, collected_obs_on):
    """With obs ON, trace `key` is byte-identical to the sha256 pinned
    before the obs plane existed — spans observe, never steer."""
    assert key in collected_obs_on, f"collector no longer produces {key}"
    assert collected_obs_on[key] == GOLDEN[key]


# ----------------------------------------------------------------------
# spans
# ----------------------------------------------------------------------
def _fake_clock():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]
    return clock


def test_obs_mode_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_OBS", raising=False)
    assert obs_mode() == "off"
    monkeypatch.setenv("REPRO_OBS", "on")
    assert obs_mode() == "on"
    assert obs_mode("off") == "off"          # explicit argument wins
    with pytest.raises(ValueError):
        obs_mode("loud")


def test_null_tracer_is_inert():
    with NULL_TRACER.span("anything", delta=True, step=3):
        pass
    assert NULL_TRACER.spans == []
    assert NULL_TRACER.enabled is False
    NULL_TRACER.watch(MetricsRegistry("x"))  # no-op


def test_span_nesting_and_attrs():
    tr = SpanTracer(clock=_fake_clock())
    with tr.span("outer", step=1):
        with tr.span("inner"):
            pass
    inner, outer = tr.spans          # completion order: inner first
    assert inner["name"] == "inner" and outer["name"] == "outer"
    assert inner["parent"] == outer["sid"]
    assert inner["depth"] == 1 and outer["depth"] == 0
    assert outer["attrs"] == {"step": 1}
    assert outer["dur_s"] > inner["dur_s"] > 0


def test_span_delta_captures_watched_counters():
    tr = SpanTracer(clock=_fake_clock())
    reg = MetricsRegistry("sim")
    reg.counter("fills").inc(5)
    tr.watch(reg)
    with tr.span("work", delta=True):
        reg.counter("fills").inc(2)
        reg.counter("born_inside").inc(4)    # created mid-span: delta 0->4
        reg.gauge("level").set(9.0)
    with tr.span("idle", delta=True):
        pass
    work, idle = tr.spans
    assert work["delta"] == {"sim.fills": 2, "sim.born_inside": 4,
                             "sim.level": 9.0}
    assert "delta" not in idle               # nothing moved, key omitted
    roll = tr.by_stage()
    assert roll["work"]["count"] == 1
    assert roll["work"]["delta"]["sim.fills"] == 2
    assert "delta" not in roll["idle"]


def test_span_capacity_bounded_and_reset():
    tr = SpanTracer(max_spans=2, clock=_fake_clock())
    for i in range(4):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.spans) == 2 and tr.dropped == 2
    tr.reset()
    assert tr.spans == [] and tr.dropped == 0
    with tr.span("again"):
        pass
    assert tr.spans[0]["sid"] == 0


def test_engine_obs_on_records_stage_spans():
    eng = ScenarioEngine(get_scenario("steady"), seed=0, obs="on")
    eng.run()
    stages = eng.tracer.by_stage()
    for stage in ("events", "waterfill", "control", "lower", "measure"):
        assert stages[stage]["count"] == eng.spec.steps
    # replan internals nest under the control span on replan steps
    assert stages["optimize"]["count"] >= 1
    assert stages["waterfill"]["delta"]["sim.fill_calls"] == eng.spec.steps


def test_fleet_obs_on_records_tick_spans():
    pytest.importorskip("jax")
    from repro.fleet.scenario import FleetEngine, get_fleet_scenario
    spec = get_fleet_scenario("fleet_steady")
    spec.steps = min(spec.steps, 3)
    eng = FleetEngine(spec, seed=0, obs="on")
    res = eng.run()
    assert len(res.trace.steps) == spec.steps
    stages = eng.tracer.by_stage()
    assert stages["tick"]["count"] == spec.steps
    # per-tick internals nest under the tick span
    for stage in ("arbitrate", "waterfill"):
        assert stages[stage]["count"] == spec.steps
    # the per-job delta keys carry the job namespace, not "controller"
    deltas = [s.get("delta", {}) for s in eng.tracer.spans]
    keys = {k for d in deltas for k in d}
    assert any(k.startswith("job.") for k in keys)


# ----------------------------------------------------------------------
# SLE rollups
# ----------------------------------------------------------------------
def test_jain_index():
    assert jain_index([]) == 1.0
    assert jain_index([0.0, 0.0]) == 1.0
    assert jain_index([5, 5, 5]) == pytest.approx(1.0)
    assert jain_index([1, 0, 0, 0]) == pytest.approx(0.25)


def test_capacity_sle():
    assert capacity_sle([]) == 1.0
    assert capacity_sle([100.0] * 10) == pytest.approx(1.0)
    # one sagging step out of ten drags the mean down
    assert capacity_sle([100.0] * 9 + [50.0]) < 1.0


def test_responsiveness_steps():
    floor = [100, 100, 100, 10, 20, 95, 100, 100]
    assert responsiveness_steps([3], floor) == pytest.approx(2.0)
    # never recovers: censored at run end (a lower bound)
    assert responsiveness_steps([3], [100, 100, 100, 10, 10, 10]) \
        == pytest.approx(3.0)
    assert responsiveness_steps([], floor) is None


def test_scenario_sle_block():
    eng = ScenarioEngine(get_scenario("cable_cut"), seed=3)
    res = eng.run()
    sle = scenario_sle(res.trace, n_dcs=eng.sim.N)
    assert set(sle) == {"band", "accuracy", "capacity", "fairness",
                        "responsiveness_steps", "monitoring_usd"}
    assert sle["band"] == SLE_BAND
    assert 0.0 <= sle["accuracy"] <= 1.0
    assert 0.0 < sle["capacity"] <= 1.0
    assert 0.0 < sle["fairness"] <= 1.0
    assert sle["monitoring_usd"] > 0
    # cable_cut scripts events, so responsiveness is measurable
    assert sle["responsiveness_steps"] is not None
    assert accuracy_sle(res.trace, band=10.0) == 1.0  # huge band: all in


def test_fleet_sle_block():
    pytest.importorskip("jax")
    from repro.fleet import run_fleet_scenario
    from repro.fleet.scenario import get_fleet_scenario
    spec = get_fleet_scenario("fleet_steady")
    spec.steps = min(spec.steps, 3)
    res = run_fleet_scenario(spec, seed=3)
    sle = fleet_sle(res.trace, n_dcs=8)
    assert sle["accuracy"] is None       # no predicted columns, honestly
    assert 0.0 < sle["capacity"] <= 1.0
    assert 0.0 < sle["fairness"] <= 1.0
    assert sle["monitoring_usd"] > 0


# ----------------------------------------------------------------------
# export / check / diff / CLI
# ----------------------------------------------------------------------
def _run_doc(obs="on", name="steady", seed=0):
    eng = ScenarioEngine(get_scenario(name), seed=seed, obs=obs)
    return export_scenario(eng.run(), eng), eng


def test_export_scenario_document_passes_check():
    doc, eng = _run_doc()
    assert check_run(doc) == []
    assert doc["metrics"]["sim"]["fill_calls"]["value"] == \
        eng.sim.fill_calls
    assert doc["spans"]["count"] == len(eng.tracer.spans)
    # canonical serialization round-trips
    assert json.loads(to_json(doc)) == doc
    # obs off: same document minus the spans block
    doc_off, _ = _run_doc(obs="off")
    assert "spans" not in doc_off
    assert check_run(doc_off) == []


def test_check_run_rejects_bad_documents():
    doc, _ = _run_doc(obs="off")
    assert check_run({"kind": "nope"})          # wrong schema + kind
    bad = dict(doc)
    bad.pop("sle")
    assert any("sle" in p for p in check_run(bad))
    assert check_run(doc, min_accuracy=1.01)    # floor above any ratio
    assert check_run(doc, max_usd=0.0)          # ceiling below any spend
    assert check_run(doc, min_accuracy=0.0) == []


def test_flatten_and_diff_runs():
    a = {"x": {"y": 1, "z": [1, 2]}, "s": "str", "b": True}
    assert flatten(a) == {"x.y": 1.0, "x.z[0]": 1.0, "x.z[1]": 2.0}
    d = diff_runs({"v": 1, "only_a": 3}, {"v": 2})
    assert d["v"] == {"a": 1.0, "b": 2.0, "rel": 1.0}
    assert d["only_a"] == {"a": 3.0, "b": None}
    assert diff_runs(a, a) == {}


def test_summarize_handles_all_document_kinds():
    doc, _ = _run_doc()
    text = summarize(doc)
    assert "steady" in text and "sle:" in text and "waterfill" in text
    bench = {"bench": "tick", "schema": 1,
             "rows": [{"kind": "obs", "overhead_frac": 0.01,
                       "sle": {"capacity": 0.9}}]}
    btext = summarize(bench)
    assert "bench: tick" in btext and "overhead_frac=0.01" in btext
    # unknown documents fall back to JSON, never crash
    assert summarize({"weird": 1}) == json.dumps({"weird": 1}, indent=2,
                                                 sort_keys=True)


def test_export_run_namespace_collisions_survive():
    a, b = MetricsRegistry("dup"), MetricsRegistry("dup")
    a.counter("x").inc()
    b.counter("x").inc(2)
    doc = export_run("r", registries=[a, b])
    vals = sorted(m["x"]["value"] for m in doc["metrics"].values())
    assert vals == [1, 2]


def test_obsctl_cli_end_to_end(tmp_path):
    path = os.path.join(HERE, os.pardir, "tools", "obsctl.py")
    spec = importlib.util.spec_from_file_location("obsctl", path)
    obsctl = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(obsctl)
    out = str(tmp_path / "run.json")
    spans = str(tmp_path / "spans.jsonl")
    assert obsctl.main(["run", "steady", "--seed", "3",
                        "-o", out, "--spans", spans]) == 0
    assert obsctl.main(["summarize", out]) == 0
    assert obsctl.main(["check", out, "--min-capacity", "0.1"]) == 0
    assert obsctl.main(["check", out, "--min-accuracy", "1.01"]) == 1
    with open(spans) as f:
        rows = [json.loads(line) for line in f]
    assert rows and {"sid", "name", "dur_s"} <= set(rows[0])
    # diff a run against itself: clean; against another seed: not
    out2 = str(tmp_path / "run2.json")
    assert obsctl.main(["run", "steady", "--seed", "4",
                        "-o", out2]) == 0
    assert obsctl.main(["diff", out, out]) == 0
    assert obsctl.main(["diff", out, out2, "--fail-on-diff"]) == 1
