"""Random-Forest prediction model (§3.1, §5.8) — accuracy, warm start,
cluster-size generalization (Fig. 11)."""
import numpy as np
import pytest

from repro.core.forest import RandomForest
from repro.core.predictor import BwPredictor
from repro.wan.dataset import generate_dataset
from repro.wan.monitor import SnapshotMonitor
from repro.wan.simulator import WanSimulator


@pytest.fixture(scope="module")
def data():
    return generate_dataset(n_samples=250, seed=7)


@pytest.fixture(scope="module")
def forest(data):
    X, y = data
    cut = int(len(y) * 0.85)
    rf = RandomForest(n_trees=100, seed=0).fit(X[:cut], y[:cut])
    return rf, X, y, cut


def test_training_accuracy(forest):
    """Paper: 98.51% training accuracy (within-10% on train set)."""
    rf, X, y, cut = forest
    acc = rf.training_accuracy(X[:cut], y[:cut])
    assert acc > 0.85, f"train acc {acc}"


def test_holdout_r2(forest):
    rf, X, y, cut = forest
    r2 = rf.score(X[cut:], y[cut:])
    assert r2 > 0.9, f"holdout R2 {r2}"


def test_prediction_beats_static_measurement(forest):
    """Fig. 11: predicted BW has fewer significant (>100 Mbps) errors vs
    actual runtime BW than statically-measured BW, across cluster sizes."""
    rf = forest[0]
    pred_wins = 0
    for n, seed in [(4, 11), (6, 12), (8, 13)]:
        sim = WanSimulator(regions=WanSimulator().regions[:n], seed=seed)
        si = sim.measure_static_independent()
        sim.advance(10)
        mon = SnapshotMonitor(sim)
        _, raw = mon.capture()
        pred = BwPredictor(rf).predict_matrix(
            n, raw["snapshot_bw"], raw["mem_util"], raw["cpu_load"],
            raw["retrans"], raw["dist"])
        truth = sim.measure_runtime()
        off = ~np.eye(n, dtype=bool)
        sig_static = (np.abs(si - truth)[off] > 100).sum()
        sig_pred = (np.abs(pred - truth)[off] > 100).sum()
        pred_wins += int(sig_pred <= sig_static)
    assert pred_wins >= 2, "prediction should beat static in >=2/3 sizes"


def test_warm_start_adds_trees(forest):
    rf = forest[0]
    n0 = rf.feat.shape[0]
    X, y = generate_dataset(n_samples=30, seed=99)
    rf.fit(X, y, warm=True, n_new=10)
    assert rf.feat.shape[0] == n0 + 10


def test_backends_agree(forest):
    rf, X, y, cut = forest
    import jax.numpy as jnp
    from repro.core.predictor import forest_predict_jnp
    from repro.kernels import ops
    f, t, l = rf.packed()
    Xs = X[cut:cut + 64]
    p_np = rf.predict(Xs)
    p_j = np.asarray(forest_predict_jnp(jnp.asarray(f), jnp.asarray(t),
                                        jnp.asarray(l), jnp.asarray(Xs),
                                        rf.depth))
    p_k = np.asarray(ops.rf_predict(jnp.asarray(f), jnp.asarray(t),
                                    jnp.asarray(l), jnp.asarray(Xs),
                                    depth=rf.depth))
    np.testing.assert_allclose(p_j, p_np, rtol=1e-4, atol=0.05)
    np.testing.assert_allclose(p_k, p_j, rtol=1e-4, atol=0.05)


# ----------------------------------------------------------------------
# Vectorized feature assembly — bit-identical to the loop oracles
# ----------------------------------------------------------------------
@pytest.mark.parametrize("n", [3, 8, 16])
def test_assemble_features_matches_loop_oracle(n):
    """The one-shot [N,N,6] assembly must reproduce the historical
    double loop BIT-identically — it sits on the golden capture path."""
    from repro.core.predictor import assemble_features, \
        assemble_features_loop
    rng = np.random.default_rng(n)
    snap = rng.uniform(1.0, 900.0, (n, n))
    mem = rng.uniform(0.05, 0.98, n)
    cpu = rng.uniform(0.02, 0.98, n)
    retr = np.rint(rng.uniform(0.0, 40.0, (n, n)))
    dist = rng.uniform(10.0, 9000.0, (n, n))
    fast = assemble_features(n, snap, mem, cpu, retr, dist)
    slow = assemble_features_loop(n, snap, mem, cpu, retr, dist)
    assert fast.dtype == slow.dtype == np.float32
    assert np.array_equal(fast, slow)


@pytest.mark.parametrize("n", [3, 8, 16])
def test_matrix_from_pairs_matches_loop_oracle(n):
    from repro.core.predictor import matrix_from_pairs, \
        matrix_from_pairs_loop
    rng = np.random.default_rng(100 + n)
    vals = rng.uniform(1.0, 500.0, n * (n - 1))
    fast = matrix_from_pairs(vals, n, diag=123.5)
    slow = matrix_from_pairs_loop(vals, n, diag=123.5)
    assert fast.dtype == slow.dtype
    assert np.array_equal(fast, slow)


def test_matrix_from_pairs_roundtrips_assembly_order():
    """matrix_from_pairs must invert assemble_features' row order."""
    from repro.core.predictor import assemble_features, matrix_from_pairs
    n = 5
    rng = np.random.default_rng(0)
    snap = rng.uniform(1.0, 900.0, (n, n))
    X = assemble_features(n, snap, np.zeros(n), np.zeros(n),
                          np.zeros((n, n)), np.zeros((n, n)))
    back = matrix_from_pairs(X[:, 1], n, diag=0.0)
    off = ~np.eye(n, dtype=bool)
    np.testing.assert_allclose(back[off],
                               snap.astype(np.float32)[off].astype(float))
