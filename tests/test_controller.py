"""WANify control plane (`repro.control`): plan cache, AIMD feedback at
the in-force connection matrix, elastic rescale, serve-side replanning,
and the trigger surface shared by training and serving."""
import numpy as np
import pytest

from repro.control import (ControllerConfig, WanifyController,
                           offset_schedule, pick_bits, wire_decode,
                           wire_encode)
from repro.core.plan import WanPlan
from repro.core.predictor import SnapshotPredictor
from repro.wan.simulator import WanSimulator

VALID_BITS = (8, 16, 32)


def quiet_sim(seed=3, **kw):
    """Deterministic network: no fluctuation / observation noise."""
    return WanSimulator(seed=seed, fluct_sigma=0.0, snapshot_sigma=0.0,
                        runtime_sigma=0.0, **kw)


def make_controller(n_pods=4, seed=3, sim=None, **cfg):
    return WanifyController(sim=sim or quiet_sim(seed),
                            predictor=SnapshotPredictor(), n_pods=n_pods,
                            cfg=ControllerConfig(**cfg))


# ----------------------------------------------------------------------
# config validation: bad knobs fail at construction, not ticks later
# ----------------------------------------------------------------------
@pytest.mark.parametrize("kw,msg", [
    (dict(max_conns=0), "max_conns"),
    (dict(max_conns=-3), "max_conns"),
    (dict(replan_every=0), "replan_every"),
    (dict(straggler_factor=0.0), "straggler_factor"),
    (dict(straggler_factor=-1.0), "straggler_factor"),
    (dict(straggler_cooldown=-1), "straggler_cooldown"),
    (dict(ewma_alpha=0.0), "ewma_alpha"),
    (dict(ewma_alpha=1.5), "ewma_alpha"),
])
def test_config_rejects_bad_knobs(kw, msg):
    with pytest.raises(ValueError, match=msg):
        ControllerConfig(**kw)


def test_config_accepts_boundary_values():
    cfg = ControllerConfig(max_conns=1, replan_every=1,
                           straggler_cooldown=0, ewma_alpha=1.0)
    assert cfg.max_conns == 1 and cfg.ewma_alpha == 1.0


# ----------------------------------------------------------------------
# (a) plan cache: identical signature => no new jit entry
# ----------------------------------------------------------------------
def test_plan_cache_no_rebuild_on_identical_signature():
    ctl = make_controller()
    builds = []

    def build(plan):
        builds.append(plan.signature())
        return ("compiled", plan.signature())

    first = ctl.compiled(("train", True), build)
    assert len(builds) == 1 and len(ctl.plan_cache) == 1

    # a replan that oscillates back to a structurally-equal plan: new
    # WanPlan object, same signature -> the cache must hit
    ctl.plan = WanPlan(n_pods=ctl.plan.n_pods, conns=ctl.plan.conns,
                       pred_bw=ctl.plan.pred_bw,
                       compress_bits=ctl.plan.compress_bits)
    again = ctl.compiled(("train", True), build)
    assert again is first
    assert len(builds) == 1 and len(ctl.plan_cache) == 1

    # across real replans the cache grows exactly one entry per distinct
    # signature, never re-lowering a seen plan
    for _ in range(4):
        ctl.replan()
        ctl.compiled(("train", True), build)
    assert len(builds) == len(set(builds))
    assert len(ctl.plan_cache) == len(set(builds))


def test_plan_cache_distinguishes_extra_key():
    ctl = make_controller()
    a = ctl.compiled(("compress",), lambda p: object())
    b = ctl.compiled(("no-compress",), lambda p: object())
    assert a is not b and len(ctl.plan_cache) == 2


# ----------------------------------------------------------------------
# (b) AIMD feedback measured at the CURRENT connection matrix
# ----------------------------------------------------------------------
def test_aimd_feedback_uses_current_conns():
    ctl = make_controller(n_pods=4)
    seen = []
    orig = ctl.sim.measure_snapshot

    def spy(conns=None):
        seen.append(None if conns is None else np.asarray(conns).copy())
        return orig(conns)

    ctl.sim.measure_snapshot = spy
    in_force = ctl.current_conns()          # agents' post-init matrix
    assert (in_force[:4, :4] != np.ones((4, 4))).any(), \
        "agents should have adapted away from all-ones"
    ctl.replan()
    # every measurement of this replan happened at the in-force matrix,
    # never at the idle all-ones default (the snapshot capture doubles
    # as the AIMD monitored-BW feed — one draw, same matrix)
    assert len(seen) >= 1
    for conns in seen:
        assert conns is not None
        np.testing.assert_array_equal(conns, in_force)


def test_agents_adapt_within_global_bounds():
    ctl = make_controller(n_pods=4)
    for _ in range(5):
        ctl.sim.advance()
        ctl.replan()
    for ag in ctl._agents:
        assert (ag.cons >= ag.min_cons).all()
        assert (ag.cons <= ag.max_cons).all()


# ----------------------------------------------------------------------
# (c) elastic rescale (§3.3.2)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("new_pods", [2, 5, 8])
def test_rescale_produces_valid_plan(new_pods):
    ctl = make_controller(n_pods=3)
    plan = ctl.rescale(new_pods)
    assert plan.n_pods == new_pods
    assert len(plan.conns) == new_pods
    assert all(len(row) == new_pods for row in plan.conns)
    assert all(v >= 1 for row in plan.conns for v in row)
    assert all(b in VALID_BITS for b in plan.compress_bits)
    assert ctl.plan is plan
    sched = offset_schedule(plan)
    assert [s["offset"] for s in sched] == list(range(1, new_pods))


def test_rescale_beyond_monitored_cluster_rejected():
    ctl = make_controller(n_pods=2)
    with pytest.raises(ValueError):
        ctl.rescale(ctl.sim.N + 1)


# ----------------------------------------------------------------------
# (d) serve-side replanning: a degraded link changes the migration plan
# ----------------------------------------------------------------------
def test_engine_replan_adapts_migration_schedule():
    import jax
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import registry
    from repro.serve.engine import Engine, ServeConfig

    sim = quiet_sim(seed=3)
    ctl = make_controller(n_pods=4, sim=sim)
    cfg = reduced(get_config("qwen3-4b"))
    params = registry.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(batch=2, s_max=32),
                 controller=ctl)
    before = eng.migration_schedule()

    # a trans-pacific cable cut: the strongest pod link collapses 50x
    off = ~np.eye(4, dtype=bool)
    i, j = divmod(int(np.argmax(np.where(off, sim.base[:4, :4], 0.0))), 4)
    sim.base[i, j] *= 0.02
    sim.base[j, i] *= 0.02
    plan = eng.replan()

    assert plan is eng.plan and plan is ctl.plan
    after = eng.migration_schedule()
    assert after != before, (before, after)
    # the degraded pair's offset class carries the adaptation: fewer
    # wire bits for the collapsed link (chunks may move either way —
    # AIMD multiplicative decrease can cut connections under congestion)
    o = (j - i) % 4
    cls_b = next(s for s in before if s["offset"] == o)
    cls_a = next(s for s in after if s["offset"] == o)
    assert cls_a["bits"] <= cls_b["bits"]
    assert cls_a != cls_b


def test_engine_without_controller_cannot_replan():
    # replan() must not silently no-op when no control plane is attached
    from repro.serve.engine import Engine
    eng = Engine.__new__(Engine)
    eng.controller, eng.plan = None, None
    with pytest.raises(RuntimeError):
        Engine.replan(eng)
    with pytest.raises(RuntimeError):
        Engine.migration_schedule(eng)


# ----------------------------------------------------------------------
# Triggers and event log
# ----------------------------------------------------------------------
def test_straggler_trigger_decreases_and_replans():
    ctl = make_controller(n_pods=4, straggler_factor=2.0)
    assert ctl.observe_step_time(1.0, step=0) is None     # seeds the EWMA
    plan = ctl.observe_step_time(10.0, step=1)            # 10x slower
    assert plan is not None and plan is ctl.plan
    assert any("straggler at step 1" in e for e in ctl.events)
    # multiplicative decrease ran before the replan rebuilt the bounds
    assert len(ctl.record) >= 2
    assert ctl.record[-1]["reason"] == "straggler"


def test_periodic_trigger_cadence_and_signature_gate():
    ctl = make_controller(n_pods=4, replan_every=5)
    assert not ctl.replan_due(0)
    assert ctl.replan_due(4)
    assert ctl.maybe_replan(0) is None                    # not due
    n_replans = len(ctl.record)
    out = ctl.maybe_replan(4)                             # due
    assert len(ctl.record) == n_replans + 1
    if out is not None:                                   # signature moved
        assert any("replanned at step 4" in e for e in ctl.events)


def test_topology_change_resets_adaptation():
    ctl = make_controller(n_pods=4)
    ctl.replan()
    old_agents = ctl._agents
    ctl.topology_changed()
    assert ctl._agents is not old_agents
    assert ctl.record[-1]["reason"] == "topology"


def test_event_log_shared_with_consumer():
    events = []
    ctl = WanifyController(sim=quiet_sim(), predictor=SnapshotPredictor(),
                           n_pods=4, events=events)
    ctl.observe_step_time(1.0, step=0)
    ctl.observe_step_time(50.0, step=1)
    assert ctl.events is events and len(events) > 0


# ----------------------------------------------------------------------
# schedule.py public API
# ----------------------------------------------------------------------
def test_wire_codec_roundtrip_scalar_and_sliced():
    import jax.numpy as jnp
    x = jnp.asarray(np.random.default_rng(0).normal(0, 3, (4, 64)),
                    jnp.float32)
    for bits in VALID_BITS:
        enc, scale = wire_encode(x, bits)
        dec = wire_decode(enc, scale, x.dtype, bits)
        assert dec.shape == x.shape
        tol = {32: 0.0, 16: 0.05, 8: 0.1}[bits]
        assert float(jnp.max(jnp.abs(dec - x))) <= tol * 3 + 1e-6
        # per-pod-slice scales: one scale per leading-dim slice
        enc_s, scale_s = wire_encode(x, bits, axes=(1,))
        if bits == 8:
            assert scale_s.shape == (4, 1)
        dec_s = wire_decode(enc_s, scale_s, x.dtype, bits)
        assert float(jnp.max(jnp.abs(dec_s - x))) <= tol * 3 + 1e-6


def test_pick_bits_reexported():
    assert pick_bits(100.0) == 8
    assert pick_bits(400.0) == 16
    assert pick_bits(5000.0) == 32


def test_pick_bits_policy_without_inf_sentinel():
    """A policy with no ``inf`` threshold falls back to full 32-bit
    for BW above every threshold instead of raising or mis-binning."""
    pol = {200.0: 8}
    assert pick_bits(100.0, pol) == 8
    assert pick_bits(200.0, pol) == 8       # inclusive threshold
    assert pick_bits(5000.0, pol) == 32


def test_offset_bits_follows_custom_policy():
    """`from_global(bits_policy=...)` used to pick per-hop bits with
    the custom policy but per-OFFSET bits with the default — two bit
    sets from two policies inside one signature. The policy is frozen
    on the plan and both pickers now use it."""
    from repro.core.global_opt import global_optimize
    from repro.core.plan import freeze_bits_policy
    pred = np.full((4, 4), 400.0)
    np.fill_diagonal(pred, 10000.0)
    gp = global_optimize(pred, M=8)
    custom = {500.0: 8, float("inf"): 16}
    plan = WanPlan.from_global(gp, bits_policy=custom)
    default = WanPlan.from_global(gp)
    assert plan.compress_bits == (8, 8, 8, 8)      # 400 <= 500 -> 8
    assert default.compress_bits == (16, 16, 16, 16)
    assert plan.offset_bits() == (8, 8, 8)         # SAME policy now
    assert default.offset_bits() == (16, 16, 16)
    assert plan.bits_policy == freeze_bits_policy(custom)
    assert default.bits_policy == freeze_bits_policy(None)
    assert plan.signature() != default.signature()
    # a hand-built plan (no policy argument) defaults identically, so
    # historical signatures are unchanged
    bare = WanPlan(n_pods=default.n_pods, conns=default.conns,
                   pred_bw=default.pred_bw,
                   compress_bits=default.compress_bits)
    assert bare.signature() == default.signature()
