"""The jax water-fill kernel vs the numpy reference loop, the
convergence-accounting contract, backend dispatch, and the golden
trace pins that prove the default path is byte-identical."""
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.kernels import waterfill as wfk
from repro.wan.simulator import WanSimulator, WaterfillDivergence

QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0)
R8 = WanSimulator().regions


def random_sim(rng, n, seed):
    """A fluctuated simulator over an n-DC mesh (n=16 doubles the 8-DC
    testbed — duplicate regions give zero-distance pairs, the most
    heterogeneous RTT weights the fill can see)."""
    regions = (R8 * 2)[:n]
    sim = WanSimulator(regions=regions, seed=seed)
    sim.advance(int(rng.integers(0, 4)))
    if rng.random() < 0.5:                       # uncredited cross-traffic
        bg = rng.integers(0, 4, (n, n)).astype(float)
        for i in range(n):
            for j in range(n):
                if bg[i, j]:
                    sim.set_background(i, j, bg[i, j])
    if rng.random() < 0.5:                       # rival registered tenants
        for t in range(int(rng.integers(1, 3))):
            tc = rng.integers(0, 3, (n, n)).astype(float)
            sim.set_tenant_conns(f"rival{t}", tc)
    return sim


def random_case(rng, n, seed):
    """(sim, aggregate conns, optional §3.2.2 cap) for one parity check."""
    sim = random_sim(rng, n, seed)
    c = rng.integers(0, 7, (n, n)).astype(float)
    np.fill_diagonal(c, 0.0)
    cap = None
    if rng.random() < 0.4:
        cap = rng.uniform(50.0, 2000.0, (n, n))
    return sim, c, cap


@pytest.mark.parametrize("n", [3, 8, 16])
def test_jax_matches_numpy_randomized(n):
    """The batched while_loop kernel reproduces `_fill_rates` to
    roundoff — same rates AND the same iteration count — across
    fluctuation states, cross-traffic, rival tenants, and throttle
    caps."""
    rng = np.random.default_rng(100 + n)
    for trial in range(12):
        sim, c, cap = random_case(rng, n, seed=1000 * n + trial)
        ref = sim._fill_rates(c, cap)
        ref_iters = sim.last_fill_iters
        # the kernel consumes the same loop-invariant inputs the
        # simulator computes once per fill
        single, egress, ingress, w, path_cap = sim.fill_inputs(cap)
        rate, iters, ok = wfk.fill_rates(c, single, egress, ingress, w,
                                         path_cap)
        assert bool(ok)
        assert int(iters) == ref_iters
        np.testing.assert_allclose(rate, ref, rtol=1e-9, atol=1e-9)


def test_jax_batched_fill_matches_per_matrix():
    """One [B,N,N] launch equals B independent fills."""
    rng = np.random.default_rng(7)
    sim = WanSimulator(seed=7)
    n = sim.N
    cs = rng.integers(0, 6, (5, n, n)).astype(float)
    for c in cs:
        np.fill_diagonal(c, 0.0)
    single, egress, ingress, w, path_cap = sim.fill_inputs()
    rate_b, iters_b, ok_b = wfk.fill_rates(
        cs, np.broadcast_to(single, cs.shape),
        np.broadcast_to(egress, (5, n)), np.broadcast_to(ingress, (5, n)),
        w, np.broadcast_to(path_cap, cs.shape))
    assert ok_b.all()
    for k, c in enumerate(cs):
        ref = sim._fill_rates(c)
        np.testing.assert_allclose(rate_b[k], ref, rtol=1e-9, atol=1e-9)
        assert int(iters_b[k]) == sim.last_fill_iters


def test_iteration_counter_surfaced():
    """The historical silent 8*N*N cap is now an explicit budget: the
    actual count is surfaced and sits far below the bound."""
    sim = WanSimulator(seed=3)
    assert sim.fill_calls == 0
    conns = np.full((sim.N, sim.N), 4.0)
    np.fill_diagonal(conns, 0.0)
    sim.waterfill(conns)
    assert sim.fill_calls == 1
    assert 0 < sim.last_fill_iters < sim.fill_iter_cap
    assert sim.fill_iter_cap == 8 * sim.N * sim.N
    assert wfk.max_fill_iters(sim.N) == sim.fill_iter_cap


def test_numpy_divergence_raises(monkeypatch):
    """A fill that exhausts its iteration budget fails loudly instead
    of returning partial rates."""
    monkeypatch.setattr(WanSimulator, "fill_iter_cap",
                        property(lambda self: 1))
    sim = WanSimulator(seed=0, **QUIET)
    conns = np.full((sim.N, sim.N), 4.0)
    np.fill_diagonal(conns, 0.0)
    with pytest.raises(WaterfillDivergence):
        sim.waterfill(conns)


def test_jax_divergence_raises(monkeypatch):
    """The jax dispatch honors the kernel's converged flag."""
    def fake_fill(c, *a):
        return np.zeros_like(c), np.asarray(999), np.asarray(False)
    monkeypatch.setattr(wfk, "fill_rates", fake_fill)
    sim = WanSimulator(seed=0, waterfill_backend="jax", **QUIET)
    conns = np.full((sim.N, sim.N), 2.0)
    with pytest.raises(WaterfillDivergence):
        sim.waterfill(conns)


def test_backend_dispatch():
    """Instance field wins, then $REPRO_WATERFILL_BACKEND, then numpy;
    unknown names fail fast; the jax backend agrees with numpy."""
    sim = WanSimulator(seed=5, **QUIET)
    assert sim._fill_backend() == "numpy"
    sim.waterfill_backend = "jax"
    assert sim._fill_backend() == "jax"
    sim.waterfill_backend = "tpu"
    with pytest.raises(ValueError, match="tpu"):
        sim._fill_backend()

    conns = np.full((sim.N, sim.N), 3.0)
    np.fill_diagonal(conns, 0.0)
    a = WanSimulator(seed=5, **QUIET).waterfill(conns)
    jx = WanSimulator(seed=5, waterfill_backend="jax", **QUIET)
    b = jx.waterfill(conns)
    np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-9)
    assert jx.fill_calls == 1 and jx.last_fill_iters > 0


def test_backend_env_var(monkeypatch):
    """$REPRO_WATERFILL_BACKEND selects the kernel when the instance
    leaves the backend unset."""
    monkeypatch.setenv("REPRO_WATERFILL_BACKEND", "jax")
    sim = WanSimulator(seed=5, **QUIET)
    assert sim._fill_backend() == "jax"
    monkeypatch.setenv("REPRO_WATERFILL_BACKEND", "quantum")
    with pytest.raises(ValueError):
        sim._fill_backend()


# ----------------------------------------------------------------------
# golden pins: the default numpy path is byte-identical pre-vs-post
# ----------------------------------------------------------------------
def _golden():
    here = os.path.dirname(__file__)
    with open(os.path.join(here, "data", "trace_golden.json")) as f:
        return json.load(f)["hashes"]


def _collector():
    here = os.path.dirname(__file__)
    path = os.path.join(here, os.pardir, "tools", "gen_trace_goldens.py")
    spec = importlib.util.spec_from_file_location("gen_trace_goldens", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_trace_goldens_unchanged():
    """Every named scenario / fleet / placement trace replays to the
    sha256 pinned BEFORE the water-fill/optimizer refactor — the
    byte-identity proof the fused-tick PR rides on."""
    want = _golden()
    got = _collector().collect()
    assert got == want


def test_trace_goldens_cover_all_suites():
    """The pin set spans all three trace families (a regenerated file
    that silently dropped a suite would weaken the contract)."""
    keys = _golden().keys()
    for prefix, minimum in (("scenario/", 8), ("fleet/", 4),
                            ("placement/", 3)):
        assert sum(k.startswith(prefix) for k in keys) >= minimum


# ----------------------------------------------------------------------
# hypothesis property (skipped when hypothesis is unavailable; the
# seeded randomized parity above always runs)
# ----------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([3, 8, 16]))
    @settings(max_examples=20, deadline=None)
    def test_property_jax_equals_numpy(seed, n):
        """For any contended matrix (caps / background / tenants), the
        jax kernel's rates match `_fill_rates` within tight tolerance."""
        rng = np.random.default_rng(seed)
        sim, c, cap = random_case(rng, n, seed=seed)
        ref = sim._fill_rates(c, cap)
        single, egress, ingress, w, path_cap = sim.fill_inputs(cap)
        rate, iters, ok = wfk.fill_rates(c, single, egress, ingress, w,
                                         path_cap)
        assert bool(ok) and int(iters) < wfk.max_fill_iters(n)
        np.testing.assert_allclose(rate, ref, rtol=1e-9, atol=1e-9)
