"""Global optimization (Eq. 2-3) — paper worked example + invariants."""
import numpy as np

from repro.core.global_opt import global_optimize, split_budget

PAPER_BW = np.array([[1000, 400, 120],
                     [380, 1000, 130],
                     [110, 120, 1000]], float)


def test_paper_worked_example():
    plan = global_optimize(PAPER_BW, M=8, D=30)
    # minCons all ones (paper)
    np.testing.assert_array_equal(plan.min_cons, np.ones((3, 3), int))
    # maxCons formula values {3,6,8;6,3,8;8,8,3}; Eq. 3 overrides the
    # diagonal to 1 (single connection inside a DC)
    expected_off = np.array([[3, 6, 8],
                             [6, 3, 8],
                             [8, 8, 3]])
    off = ~np.eye(3, dtype=bool)
    np.testing.assert_array_equal(plan.max_cons[off], expected_off[off])
    assert (np.diag(plan.max_cons) == 1).all()


def test_weak_links_get_more_connections():
    plan = global_optimize(PAPER_BW, M=8, D=30)
    off = ~np.eye(3, dtype=bool)
    bw = PAPER_BW[off]
    cons = plan.max_cons[off].astype(float)
    order = np.argsort(bw)
    assert (np.diff(cons[order]) <= 0).all(), \
        "weaker links must get >= connections"


def test_achievable_bw_linear_in_connections():
    plan = global_optimize(PAPER_BW, M=8, D=30)
    np.testing.assert_allclose(plan.max_bw, PAPER_BW * plan.max_cons)
    np.testing.assert_allclose(plan.min_bw, PAPER_BW * plan.min_cons)


def test_min_bw_improves_vs_single_connection():
    """The heterogeneous approach must raise the cluster's weakest
    achievable off-diagonal BW (Fig. 2's 2.1x claim direction)."""
    plan = global_optimize(PAPER_BW, M=8, D=30)
    off = ~np.eye(3, dtype=bool)
    assert plan.max_bw[off].min() >= 2 * PAPER_BW[off].min()


def test_skew_weights_shift_budget():
    w = np.array([1.0, 1.0, 3.0])          # DC2 holds skewed data
    base = global_optimize(PAPER_BW, M=8, D=30)
    skew = global_optimize(PAPER_BW, M=8, D=30, w_s=w)
    # pairs touching DC2 should not lose connections; others may
    assert skew.max_cons[0, 2] >= base.max_cons[0, 2]
    assert skew.max_cons[1, 2] >= base.max_cons[1, 2]


def test_refactor_vector_scales_bw():
    r = np.array([1.0, 1.0, 4.0])
    plan = global_optimize(PAPER_BW, M=8, D=30, r_vec=r)
    base = global_optimize(PAPER_BW, M=8, D=30)
    np.testing.assert_allclose(plan.max_bw[0, 2], base.max_bw[0, 2] * 2.0)


def test_throttle_caps_rich_links():
    plan = global_optimize(PAPER_BW, M=8, D=30)
    off = ~np.eye(3, dtype=bool)
    for i in range(3):
        capped = plan.throttle[i][off[i]]
        finite = np.isfinite(capped)
        if finite.any():
            T = plan.max_bw[i][off[i]].mean()
            np.testing.assert_allclose(capped[finite], T)


# ----------------------------------------------------------------------
# §3.2.2 throttling, tested directly: which links get capped, at what
# value, and how the cap propagates through the fill and the agents
# ----------------------------------------------------------------------
def test_throttle_rich_set_is_exactly_above_row_mean():
    """Per row, the capped destinations are EXACTLY those whose
    achievable max BW exceeds the row mean T; everything else
    (including the diagonal) stays uncapped."""
    plan = global_optimize(PAPER_BW, M=8, D=30)
    off = ~np.eye(3, dtype=bool)
    for i in range(3):
        T = plan.max_bw[i][off[i]].mean()
        for j in range(3):
            if i == j:
                assert np.isinf(plan.throttle[i, j])
            elif plan.max_bw[i, j] > T:
                assert plan.throttle[i, j] == T
            else:
                assert np.isinf(plan.throttle[i, j])


def test_throttle_disabled_leaves_all_links_uncapped():
    plan = global_optimize(PAPER_BW, M=8, D=30, throttle_enabled=False)
    assert np.isinf(plan.throttle).all()


def test_throttle_cap_enforced_by_waterfill():
    """The simulator's `cap` argument is the TC analogue: achieved BW
    on a throttled pair never exceeds the row-mean cap."""
    from repro.wan.simulator import WanSimulator
    sim = WanSimulator(seed=0, fluct_sigma=0.0, snapshot_sigma=0.0,
                       runtime_sigma=0.0)
    conns = np.ones((8, 8)) * 4
    free = sim.waterfill(conns)
    plan = global_optimize(free, M=8)
    capped = sim.waterfill(conns, cap=plan.throttle)
    off = ~np.eye(8, dtype=bool)
    finite = np.isfinite(plan.throttle) & off
    assert finite.any()
    assert (capped[finite] <= plan.throttle[finite] + 1e-6).all()
    # throttling a rich pair can only help the row's weakest pair
    for i in range(8):
        assert capped[i][off[i]].min() >= free[i][off[i]].min() - 1e-6


def test_aimd_target_never_exceeds_throttle():
    """The local agents' additive increase is clipped at the throttle:
    even under perfectly-on-target monitoring the target BW of a
    capped destination converges to the cap, not to max_bw."""
    from repro.core.local_opt import AimdAgent
    plan = global_optimize(PAPER_BW, M=8, D=30)
    src = 0
    ag = AimdAgent.from_plan(plan, src)
    for _ in range(50):
        ag.step(ag.target_bw.copy())      # monitored == target
    for j in range(3):
        if j != src and np.isfinite(plan.throttle[src, j]):
            assert ag.target_bw[j] <= plan.throttle[src, j] + 1e-9


def test_external_link_cap_joins_throttle_and_clamps_conns():
    """A fleet-arbitrated link cap tightens the plan: the throttle is
    min(row-mean cap, link cap) and max_cons never buys BW past the
    cap (budget spent beyond ceil(cap/unit_bw) is wasted)."""
    lc = np.full((3, 3), np.inf)
    lc[0, 1] = 500.0                      # 400 Mbps/conn link capped
    base = global_optimize(PAPER_BW, M=8, D=30)
    plan = global_optimize(PAPER_BW, M=8, D=30, link_cap=lc)
    assert plan.throttle[0, 1] == 500.0
    assert plan.max_cons[0, 1] == 2       # ceil(500/400)
    assert plan.max_cons[0, 1] < base.max_cons[0, 1]
    # uncapped entries are untouched
    assert plan.max_cons[0, 2] == base.max_cons[0, 2]
    np.testing.assert_array_equal(plan.min_cons <= plan.max_cons,
                                  np.ones((3, 3), bool))


def test_throttle_vectorization_bit_identical_to_row_loop():
    """The vectorized §3.2.2 throttle equals the historical per-row
    Python loop BIT-FOR-BIT (np.float64 ==, not allclose): the row
    means are taken over the same contiguous off-diagonal slices, so
    summation order is unchanged."""
    rng = np.random.default_rng(11)
    for _ in range(50):
        n = int(rng.integers(2, 12))
        bw = rng.uniform(30.0, 2500.0, (n, n))
        np.fill_diagonal(bw, 10000.0)
        plan = global_optimize(bw, M=int(rng.integers(2, 12)))
        ref = np.full((n, n), np.inf)
        for i in range(n):                 # the pre-vectorization loop
            row = np.delete(plan.max_bw[i], i)
            T = row.mean()
            for j in range(n):
                if j != i and plan.max_bw[i, j] > T:
                    ref[i, j] = T
        np.testing.assert_array_equal(plan.throttle, ref)


def test_split_budget_floor_when_budget_equals_tenants():
    """M == J: the one-connection floor consumes the whole budget —
    every tenant gets exactly 1 no matter the skew."""
    s = split_budget(3, np.array([5.0, 1.0, 1.0]))
    assert (s == 1).all()
    assert int(s.sum()) == 3


def test_split_budget_extreme_skew_repays_floor_bumps():
    """Near-zero weights floor up to 1 each; the repayment loop must
    claw the overdraft back from the richest tenant, terminate, and
    keep every invariant."""
    w = np.array([1.0, 1e-12, 1e-12, 1e-12])
    s = split_budget(5, w)
    assert s.tolist() == [2, 1, 1, 1]       # 5 - 3 floors leaves 2
    for M in (6, 17, 64):
        s = split_budget(M, w)
        assert (s >= 1).all()
        assert int(s.sum()) <= M
        assert s[0] == s.max()              # monotone in weight
