"""Global optimization (Eq. 2-3) — paper worked example + invariants."""
import numpy as np

from repro.core.global_opt import global_optimize

PAPER_BW = np.array([[1000, 400, 120],
                     [380, 1000, 130],
                     [110, 120, 1000]], float)


def test_paper_worked_example():
    plan = global_optimize(PAPER_BW, M=8, D=30)
    # minCons all ones (paper)
    np.testing.assert_array_equal(plan.min_cons, np.ones((3, 3), int))
    # maxCons formula values {3,6,8;6,3,8;8,8,3}; Eq. 3 overrides the
    # diagonal to 1 (single connection inside a DC)
    expected_off = np.array([[3, 6, 8],
                             [6, 3, 8],
                             [8, 8, 3]])
    off = ~np.eye(3, dtype=bool)
    np.testing.assert_array_equal(plan.max_cons[off], expected_off[off])
    assert (np.diag(plan.max_cons) == 1).all()


def test_weak_links_get_more_connections():
    plan = global_optimize(PAPER_BW, M=8, D=30)
    off = ~np.eye(3, dtype=bool)
    bw = PAPER_BW[off]
    cons = plan.max_cons[off].astype(float)
    order = np.argsort(bw)
    assert (np.diff(cons[order]) <= 0).all(), \
        "weaker links must get >= connections"


def test_achievable_bw_linear_in_connections():
    plan = global_optimize(PAPER_BW, M=8, D=30)
    np.testing.assert_allclose(plan.max_bw, PAPER_BW * plan.max_cons)
    np.testing.assert_allclose(plan.min_bw, PAPER_BW * plan.min_cons)


def test_min_bw_improves_vs_single_connection():
    """The heterogeneous approach must raise the cluster's weakest
    achievable off-diagonal BW (Fig. 2's 2.1x claim direction)."""
    plan = global_optimize(PAPER_BW, M=8, D=30)
    off = ~np.eye(3, dtype=bool)
    assert plan.max_bw[off].min() >= 2 * PAPER_BW[off].min()


def test_skew_weights_shift_budget():
    w = np.array([1.0, 1.0, 3.0])          # DC2 holds skewed data
    base = global_optimize(PAPER_BW, M=8, D=30)
    skew = global_optimize(PAPER_BW, M=8, D=30, w_s=w)
    # pairs touching DC2 should not lose connections; others may
    assert skew.max_cons[0, 2] >= base.max_cons[0, 2]
    assert skew.max_cons[1, 2] >= base.max_cons[1, 2]


def test_refactor_vector_scales_bw():
    r = np.array([1.0, 1.0, 4.0])
    plan = global_optimize(PAPER_BW, M=8, D=30, r_vec=r)
    base = global_optimize(PAPER_BW, M=8, D=30)
    np.testing.assert_allclose(plan.max_bw[0, 2], base.max_bw[0, 2] * 2.0)


def test_throttle_caps_rich_links():
    plan = global_optimize(PAPER_BW, M=8, D=30)
    off = ~np.eye(3, dtype=bool)
    for i in range(3):
        capped = plan.throttle[i][off[i]]
        finite = np.isfinite(capped)
        if finite.any():
            T = plan.max_bw[i][off[i]].mean()
            np.testing.assert_allclose(capped[finite], T)
