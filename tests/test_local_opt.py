"""Local AIMD optimization (§3.2.2) — paper worked example + dynamics."""
import numpy as np

from repro.core.local_opt import AimdAgent


def _paper_agent():
    """Paper example: min-max from DC0 = {1000,800,240}-{1000,1600,600}
    Mbps and {1,2,2}-{1,4,5} connections."""
    return AimdAgent(
        src=0,
        min_cons=np.array([1, 2, 2]),
        max_cons=np.array([1, 4, 5]),
        min_bw=np.array([1000.0, 800.0, 240.0]),
        max_bw=np.array([1000.0, 1600.0, 600.0]),
        unit_bw=np.array([1000.0, 400.0, 120.0]),
        throttle=np.array([np.inf, np.inf, np.inf]),
    )


def test_starts_at_maximum():
    ag = _paper_agent()
    np.testing.assert_array_equal(ag.cons, [1, 4, 5])
    np.testing.assert_allclose(ag.target_bw, [1000.0, 1600.0, 600.0])


def test_multiplicative_decrease_on_congestion():
    ag = _paper_agent()
    # paper: decrease mode when monitored < 1500 / 500 Mbps (target-100)
    ag.step(np.array([1000.0, 1300.0, 350.0]))
    assert ag.cons[1] == 2          # 4 -> 2 (half, >= min 2)
    assert ag.target_bw[1] == 800.0  # halved to 800 (>= min 800)
    assert ag.cons[2] == 2          # 5 -> 2 (half=2 >= min 2)
    assert ag.target_bw[2] == 300.0  # 600/2, >= min 240


def test_additive_increase_on_recovery():
    ag = _paper_agent()
    ag.step(np.array([1000.0, 1300.0, 350.0]))      # decrease
    cons_before = ag.cons.copy()
    ag.step(ag.target_bw.copy())                     # monitored == target
    assert ag.cons[1] == cons_before[1] + 1
    assert ag.cons[2] == cons_before[2] + 1


def test_bounds_always_respected():
    ag = _paper_agent()
    rng = np.random.default_rng(0)
    for _ in range(50):
        ag.step(rng.uniform(0, 2000, 3))
        assert (ag.cons >= ag.min_cons).all()
        assert (ag.cons <= ag.max_cons).all()
        assert (ag.target_bw >= ag.min_bw - 1e-9).all()
        assert (ag.target_bw <= ag.max_bw + 1e-9).all()


def test_small_transfer_skips_toggle():
    ag = _paper_agent()
    before = ag.cons.copy()
    ag.step(np.array([0.0, 0.0, 0.0]),
            transfer_bytes=np.array([0, 1000, 1000]))  # < 1 MB
    np.testing.assert_array_equal(ag.cons, before)


def test_throttle_caps_target():
    ag = _paper_agent()
    ag.throttle = np.array([np.inf, 900.0, np.inf])
    ag.step(ag.target_bw.copy())
    assert ag.target_bw[1] <= 900.0
