"""Hypothesis property tests over system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.global_opt import global_optimize
from repro.core.local_opt import AimdAgent
from repro.core.plan import WanPlan, pick_bits
from repro.core.relations import infer_dc_relations
from repro.control.schedule import offset_schedule
from repro.wan.simulator import WanSimulator

bw_matrix = st.integers(2, 6).flatmap(
    lambda n: st.lists(
        st.lists(st.floats(60, 2200), min_size=n, max_size=n),
        min_size=n, max_size=n))


def _sym(m):
    a = np.asarray(m)
    a = (a + a.T) / 2
    np.fill_diagonal(a, 10000.0)
    return a


@given(bw_matrix, st.floats(10, 300))
@settings(max_examples=40, deadline=None)
def test_relations_valid_range(m, D):
    bw = _sym(m)
    rel = infer_dc_relations(bw, D)
    assert rel.min() >= 1
    assert (np.diag(rel) == 1).all()


@given(bw_matrix, st.integers(2, 16))
@settings(max_examples=40, deadline=None)
def test_global_opt_invariants(m, M):
    bw = _sym(m)
    plan = global_optimize(bw, M=M)
    assert (plan.min_cons >= 1).all()
    assert (plan.max_cons >= plan.min_cons).all()
    assert (plan.max_cons <= 2 * M).all()
    assert (np.diag(plan.max_cons) == 1).all()
    assert (plan.max_bw >= plan.min_bw - 1e-9).all()


@given(bw_matrix)
@settings(max_examples=25, deadline=None)
def test_aimd_stays_in_bounds(m):
    bw = _sym(m)
    plan = global_optimize(bw, M=8)
    ag = AimdAgent.from_plan(plan, 0)
    rng = np.random.default_rng(0)
    for _ in range(20):
        ag.step(rng.uniform(0, 3000, plan.n))
        assert (ag.cons >= ag.min_cons).all()
        assert (ag.cons <= ag.max_cons).all()


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8))
@settings(max_examples=10, deadline=None)
def test_waterfill_never_exceeds_caps(seed, n):
    """Achieved BW never exceeds the per-connection ceiling, the
    path-knee cap, or the NIC egress/ingress caps."""
    sim = WanSimulator(regions=WanSimulator().regions[:n], seed=seed)
    sim.advance(seed % 5)                    # arbitrary fluctuation state
    rng = np.random.default_rng(seed)
    conns = rng.integers(0, 10, (n, n)).astype(float)
    np.fill_diagonal(conns, 0)
    bw = sim.waterfill(conns)
    off = ~np.eye(n, dtype=bool)
    single = sim.link_bw_now()
    assert (bw[off] <= np.maximum(conns, 1)[off] * single[off] * 1.01).all()
    assert (bw[off] <= single[off] * sim.knee * 1.01).all()
    assert (np.where(off, bw, 0).sum(1) <= sim.nic_cap * 1.01).all()
    assert (np.where(off, bw, 0).sum(0) <= sim.nic_cap * 1.01).all()
    assert (bw[off] >= -1e-9).all()


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8))
@settings(max_examples=10, deadline=None)
def test_waterfill_idle_pairs_get_exactly_zero(seed, n):
    sim = WanSimulator(regions=WanSimulator().regions[:n], seed=seed)
    rng = np.random.default_rng(seed)
    conns = rng.integers(0, 10, (n, n)).astype(float)
    np.fill_diagonal(conns, 0)
    bw = sim.waterfill(conns)
    off = ~np.eye(n, dtype=bool)
    assert (bw[off][conns[off] == 0] == 0.0).all()


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8),
       st.integers(0, 63), st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_waterfill_monotone_in_own_connections(seed, n, pick, extra):
    """Growing ONLY one pair's connection count never decreases that
    pair's achieved BW (more aggregate weight in the fair share)."""
    sim = WanSimulator(regions=WanSimulator().regions[:n], seed=seed)
    sim.advance(seed % 5)
    rng = np.random.default_rng(seed)
    conns = rng.integers(0, 10, (n, n)).astype(float)
    np.fill_diagonal(conns, 0)
    i, j = divmod(pick % (n * n), n)
    if i == j:
        j = (j + 1) % n
    before = sim.waterfill(conns)[i, j]
    grown = conns.copy()
    grown[i, j] += extra
    after = sim.waterfill(grown)[i, j]
    assert after >= before - max(1e-6 * before, 1e-6)


@given(st.floats(1, 5000))
@settings(max_examples=30, deadline=None)
def test_pick_bits_monotone(bw):
    b = pick_bits(bw)
    assert b in (8, 16, 32)
    assert pick_bits(bw * 10) >= b or pick_bits(bw * 10) == 32


@given(st.integers(2, 8))
@settings(max_examples=10, deadline=None)
def test_offset_schedule_covers_all_offsets(P):
    plan = WanPlan.uniform(P, conns=5)
    sched = offset_schedule(plan)
    assert [s["offset"] for s in sched] == list(range(1, P))
    for s in sched:
        c = s["chunks"]
        assert c & (c - 1) == 0          # power of two
        assert 1 <= c <= 16
