"""Overlay routing tier (repro.overlay): route selection, the routed
water-fill's two-hop contention physics, controller/record wiring, the
placement layer's routed pricing, and the pinned cable_cut_reroute
acceptance — routing around a far-link cut strictly beats direct-only
on post-cut min achievable BW and on placement makespan, while
``REPRO_OVERLAY=off`` (the default) runs no routed code path at all.
"""
import numpy as np
import pytest

from repro.control import ControllerConfig, WanifyController
from repro.core.global_opt import global_optimize, relay_candidates
from repro.core.plan import WanPlan
from repro.core.predictor import SnapshotPredictor
from repro.overlay import (DEFAULT_GAIN_MIN, OVERLAY_MODES, RoutedPlan,
                           overlay_mode, plan_routes)
from repro.placement.cost import achievable_bw
from repro.placement.scenario import run_placement_scenario
from repro.scenarios import get_scenario, run_scenario
from repro.scenarios.engine import ScenarioEngine
from repro.wan.simulator import WanSimulator

QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0)

# the staged cut lands at step 12; the first post-cut replan's routing
# is in force from step 14 on (step 13's achieved BW is measured before
# that step's replan chooses the relays)
SETTLED = 14


def quiet_sim(seed=3, **kw):
    return WanSimulator(seed=seed, **QUIET, **kw)


# ----------------------------------------------------------------------
# Gate resolution
# ----------------------------------------------------------------------
def test_overlay_mode_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_OVERLAY", raising=False)
    assert overlay_mode() == "off"
    monkeypatch.setenv("REPRO_OVERLAY", "on")
    assert overlay_mode() == "on"
    assert overlay_mode("off") == "off"      # explicit argument wins
    with pytest.raises(ValueError):
        overlay_mode("sideways")
    monkeypatch.setenv("REPRO_OVERLAY", "bogus")
    with pytest.raises(ValueError):
        overlay_mode()
    assert OVERLAY_MODES == ("off", "on")


def test_env_gate_reaches_controller(monkeypatch):
    monkeypatch.setenv("REPRO_OVERLAY", "on")
    ctl = WanifyController(sim=quiet_sim(), predictor=SnapshotPredictor(),
                           n_pods=4, cfg=ControllerConfig(advance_sim=False))
    assert ctl.overlay == "on"
    assert ctl.record[-1].get("overlay") == "on"
    monkeypatch.delenv("REPRO_OVERLAY")
    ctl = WanifyController(sim=quiet_sim(), predictor=SnapshotPredictor(),
                           n_pods=4, cfg=ControllerConfig(advance_sim=False))
    assert ctl.overlay == "off"


def test_fleet_jobs_pin_overlay_off(monkeypatch):
    """A global $REPRO_OVERLAY=on must not leak into fleet jobs: the
    arbiter's envelopes model direct per-pair flows only."""
    from repro.fleet import (BatchedRfPredictor, FleetController, JobSpec,
                             default_fleet_forest)
    monkeypatch.setenv("REPRO_OVERLAY", "on")
    forest = default_fleet_forest(n_samples=20, n_trees=4, depth=3, seed=7)
    fleet = FleetController(quiet_sim(), BatchedRfPredictor(forest),
                            m_total=8)
    job = fleet.add_job(JobSpec(name="j0", dcs=(0, 1, 2, 3)))
    assert job.controller.overlay == "off"
    assert job.controller.routed is None


# ----------------------------------------------------------------------
# RoutedPlan / plan_routes units
# ----------------------------------------------------------------------
def _toy_routed():
    direct = ((1, 2, 1), (2, 1, 3), (1, 3, 1))
    relays = ((0, 1, 2, 3),)
    pred = tuple(tuple(100.0 for _ in range(3)) for _ in range(3))
    return RoutedPlan(n_pods=3, direct=direct, relays=relays, pred_bw=pred)


def test_expanded_conns_folds_relay_onto_both_hops():
    rp = _toy_routed()
    exp = rp.expanded_conns()
    base = np.asarray(rp.direct, float)
    assert exp[0, 1] == base[0, 1] + 3       # hop i -> k
    assert exp[1, 2] == base[1, 2] + 3       # hop k -> j
    assert exp[0, 2] == base[0, 2]           # end-to-end pair untouched


def test_routed_plan_signature_covers_routing():
    rp = _toy_routed()
    sig = rp.signature()
    assert sig == (3, rp.direct, rp.relays)
    other = RoutedPlan(n_pods=3, direct=rp.direct, relays=(),
                       pred_bw=rp.pred_bw)
    assert other.signature() != sig          # relays are plan identity
    assert hash(sig) is not None             # cache-keyable


def test_plan_routes_no_relay_without_decisive_gain():
    """Healthy geometry: no candidate clears gain_min, every
    connection stays on its direct link."""
    pred = np.array([[1e4, 900.0, 150.0],
                     [900.0, 1e4, 160.0],
                     [150.0, 160.0, 1e4]])
    conns = np.full((3, 3), 4)
    rp = plan_routes(pred, conns)
    assert rp.relays == ()
    assert np.array_equal(np.asarray(rp.direct), conns)


def test_plan_routes_picks_best_relay_and_bounds_split():
    """A collapsed far link with one strong detour: the relay fires,
    picks the best min-hop candidate, keeps min_direct on the direct
    link, and never exceeds max_relay_conns."""
    pred = np.array([[1e4, 800.0, 700.0],
                     [800.0, 1e4, 5.0],     # (1,2) cut
                     [700.0, 5.0, 1e4]])
    conns = np.full((3, 3), 8)
    np.fill_diagonal(conns, 1)
    rp = plan_routes(pred, conns, gain_min=2.0, max_relay_conns=4)
    assert (1, 0, 2, 4) in rp.relays and (2, 0, 1, 4) in rp.relays
    d = np.asarray(rp.direct)
    assert d[1, 2] == 4 and d[2, 1] == 4     # total conserved
    assert d[1, 2] >= 1                      # monitor keeps observing
    # unbounded split would move nearly everything onto the detour
    rp2 = plan_routes(pred, conns, gain_min=2.0, max_relay_conns=99)
    cr2 = dict(((i, j), c) for i, k, j, c in rp2.relays)[(1, 2)]
    assert cr2 == 7                          # total - min_direct


def test_plan_routes_normalizes_by_capture_conns():
    """pred measured at heterogeneous conns: pair totals alone would
    fake a gain; per-connection units must kill it."""
    # per-conn truth is uniform 100 Mbps; both hops of the 0->1->2
    # detour were measured at 8 conns, the direct (0,2) at 1 — raw
    # pair totals fake an 8x relay gain that is pure operating point
    pred = np.array([[1e4, 800.0, 100.0],
                     [800.0, 1e4, 800.0],
                     [100.0, 800.0, 1e4]])
    cap = np.ones((3, 3))
    for a, b in ((0, 1), (1, 2)):
        cap[a, b] = cap[b, a] = 8.0
    conns = np.full((3, 3), 6)
    assert plan_routes(pred, conns, capture_conns=cap).relays == ()
    # without the normalization the phantom 8x edge fires a relay
    assert plan_routes(pred, conns).relays != ()


def test_relay_candidates_closeness_pruning():
    rel = np.array([[1, 2, 3, 3],
                    [2, 1, 3, 3],
                    [3, 3, 1, 2],
                    [3, 3, 2, 1]])
    # far pair (1,2): both remaining DCs qualify (hops no farther than
    # the direct class), nearest class-sum first, index tiebreak
    assert relay_candidates(rel, 1, 2) == [0, 3]
    # close pair (0,1): a relay would cross a farther class; pruned
    assert relay_candidates(rel, 0, 1) == []
    assert relay_candidates(rel, 1, 2, max_candidates=1) == [0]


# ----------------------------------------------------------------------
# waterfill_routed physics
# ----------------------------------------------------------------------
def test_relay_flows_charged_on_both_hops():
    """Relay connections contend on BOTH hop links: every pair sharing
    either hop loses credited BW when the relay shows up."""
    sim = quiet_sim()
    direct = np.ones((sim.N, sim.N))
    base = sim.waterfill(direct)
    relays = [(1, 0, 2, 4)]
    routed = sim.waterfill_routed(direct, relays)
    assert routed[1, 0] < base[1, 0]         # hop i -> k contended
    assert routed[0, 2] < base[0, 2]         # hop k -> j contended
    # ... and the relayed pair's credit is exactly the store-and-
    # forward bottleneck of the two hop rates on the expanded fill
    expanded = direct.copy()
    expanded[1, 0] += 4
    expanded[0, 2] += 4
    rate = sim._fill_rates(sim._contending_conns(expanded, None), None)
    want = direct[1, 2] * rate[1, 2] + 4 * min(rate[1, 0], rate[0, 2])
    assert routed[1, 2] == pytest.approx(float(want))


def test_relay_through_saturated_nic_buys_nothing():
    """A detour through a DC whose NIC is already saturated cannot beat
    the direct path — the min-of-hop-rates credit collapses."""
    sim = quiet_sim()
    # bury the via-DC (0) in background flows on every link
    for m in range(1, sim.N):
        sim.set_background(0, m, 10_000)
        sim.set_background(m, 0, 10_000)
    direct = np.ones((sim.N, sim.N)) * 2
    plain = sim.waterfill(direct)
    shifted = direct.copy()
    shifted[1, 2] = shifted[2, 1] = 1        # move a conn onto the relay
    routed = sim.waterfill_routed(shifted, [(1, 0, 2, 1), (2, 0, 1, 1)])
    assert routed[1, 2] <= plain[1, 2] * (1 + 1e-9)
    assert routed[2, 1] <= plain[2, 1] * (1 + 1e-9)


def test_waterfill_routed_no_relays_equals_waterfill():
    sim = quiet_sim()
    conns = np.ones((sim.N, sim.N)) * 3
    assert np.array_equal(sim.waterfill_routed(conns, []),
                          sim.waterfill(conns))


# ----------------------------------------------------------------------
# Controller wiring and plan identity
# ----------------------------------------------------------------------
def _on_controller():
    sim = quiet_sim()
    ctl = WanifyController(sim=sim, predictor=SnapshotPredictor(),
                           n_pods=4, cfg=ControllerConfig(advance_sim=False),
                           overlay="on")
    return sim, ctl


def test_record_gains_relay_fields_only_when_on():
    sim, ctl = _on_controller()
    rec = ctl.record[-1]
    assert rec["overlay"] == "on"
    assert rec["relays"] == ctl.routed.relays
    assert rec["routed_signature"] == ctl.routed.signature()
    off = WanifyController(sim=quiet_sim(), predictor=SnapshotPredictor(),
                           n_pods=4, cfg=ControllerConfig(advance_sim=False))
    assert off.routed is None
    assert "overlay" not in off.record[-1]   # off-path records unchanged
    assert "relays" not in off.record[-1]


def test_cut_link_gets_relayed_on_replan():
    sim, ctl = _on_controller()
    assert ctl.routed.relays == ()           # healthy: nothing to route
    assert ctl.current_routing() is None     # ... so direct execution
    i, j = sim.regions.index("us-west"), sim.regions.index("ap-south")
    sim.set_link_factor(i, j, 0.02)
    ctl.replan(reason="cut")
    vias = {(s, d): k for s, k, d, _ in ctl.routed.relays}
    assert (i, j) in vias and (j, i) in vias
    assert vias[(i, j)] not in (i, j)
    direct, relays = ctl.current_routing()
    assert relays == ctl.routed.relays
    P = ctl.n_pods
    assert np.array_equal(direct[:P, :P], np.asarray(ctl.routed.direct))
    # conservation: direct residue + relay conns == the plan's conns
    plan_c = np.asarray(ctl.plan.conns)
    for (s, d), k in vias.items():
        cr = dict(((a, b), c) for a, _, b, c in ctl.routed.relays)[(s, d)]
        assert ctl.routed.direct[s][d] + cr == plan_c[s, d]


# ----------------------------------------------------------------------
# Placement pricing on the routed surface
# ----------------------------------------------------------------------
def test_achievable_bw_prices_relay_credit():
    pred = ((1e4, 500.0, 10.0), (500.0, 1e4, 10.0), (10.0, 10.0, 1e4))
    plan = WanPlan(n_pods=3,
                   conns=((1, 4, 6), (4, 1, 6), (6, 6, 1)),
                   pred_bw=pred, compress_bits=(32, 32, 32))
    routing = RoutedPlan(
        n_pods=3, direct=((1, 4, 2), (4, 1, 6), (6, 6, 1)),
        relays=((0, 1, 2, 4),), pred_bw=pred)
    base = achievable_bw(plan, knee=None)
    routed = achievable_bw(plan, knee=None, routing=routing)
    # direct term re-priced at the residual conns, plus the relay's
    # conns x the weaker hop's per-connection prediction
    assert routed[0, 2] == pytest.approx(10.0 * 2 + 4 * min(500.0, 10.0))
    assert base[0, 2] == pytest.approx(10.0 * 6)
    # knee caps the relay's effective connection count too
    kneed = achievable_bw(plan, knee=3.0, routing=routing)
    assert kneed[0, 2] == pytest.approx(10.0 * 2 + 3.0 * 10.0)
    with pytest.raises(ValueError):
        achievable_bw(plan, routing=RoutedPlan(
            n_pods=2, direct=((1, 1), (1, 1)), relays=(),
            pred_bw=((1.0, 1.0), (1.0, 1.0))))


# ----------------------------------------------------------------------
# The pinned acceptance scenario
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def reroute_runs():
    """cable_cut_reroute at seed 3, direct-only vs routed, same
    weather; relays captured per step via the engine hook."""
    out = {}
    for mode in ("off", "on"):
        eng = ScenarioEngine(get_scenario("cable_cut_reroute"), seed=3,
                             overlay=mode)
        relays_by_step = {}

        def hook(engine, row, relays_by_step=relays_by_step,
                 ctl=eng.controller):
            relays_by_step[row.step] = (ctl.routed.relays
                                        if ctl.routed else ())
        eng.step_hook = hook
        out[mode] = (eng.run(), relays_by_step)
    return out


def test_reroute_strictly_beats_direct_min_bw(reroute_runs):
    """From the first settled post-cut step the routed run's min
    achievable BW is strictly higher EVERY step, and the detours go
    through the healthy DCs."""
    (off, _), (on, relays) = reroute_runs["off"], reroute_runs["on"]
    off_steps = {s.step: s for s in off.trace.steps}
    on_steps = {s.step: s for s in on.trace.steps}
    assert all(on_steps[k].achieved_min > off_steps[k].achieved_min
               for k in range(SETTLED, len(on_steps)))
    for k in range(SETTLED, len(on_steps)):
        assert relays[k] != ()
        assert all(via in (0, 3) for _, via, _, _ in relays[k])
    # pre-cut the healthy geometry routes nothing: identical traces
    assert all(on_steps[k].achieved_min == off_steps[k].achieved_min
               for k in range(0, 12))


def test_reroute_off_matches_default(reroute_runs):
    """overlay=None (the default gate) is byte-identical to an
    explicit off run — the gate introduces no routed code path."""
    (off, relays) = reroute_runs["off"]
    assert all(r == () for r in relays.values())
    default = run_scenario(get_scenario("cable_cut_reroute"), seed=3)
    assert default.trace.to_json().encode() == \
        off.trace.to_json().encode()


@pytest.fixture(scope="module")
def placement_runs():
    return {mode: run_placement_scenario("cable_cut_reroute", seed=3,
                                         overlay=mode)
            for mode in ("off", "on")}


def test_reroute_strictly_lowers_placement_makespan(placement_runs):
    off, on = placement_runs["off"], placement_runs["on"]
    off_total = sum(s.makespan_s for s in off.trace.steps)
    on_total = sum(s.makespan_s for s in on.trace.steps)
    assert on_total < off_total
    # and the executed (ground-truth) post-cut min BW is higher too
    off_min = min(s.achieved_min for s in off.trace.steps
                  if s.step >= SETTLED)
    on_min = min(s.achieved_min for s in on.trace.steps
                 if s.step >= SETTLED)
    assert on_min > off_min
