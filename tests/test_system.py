"""End-to-end system tests: training convergence, fault tolerance,
WANify end-to-end benefit, and multi-device wansync/dryrun (the latter
run in subprocesses so the main test session keeps 1 CPU device)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import get_config
from repro.configs.base import reduced
from repro.data.pipeline import DataConfig
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import AdamWConfig


def _mesh1():
    return compat.make_mesh((1,), ("data",))


def test_training_reduces_loss(tmp_path):
    cfg = reduced(get_config("llama3-8b"))
    dcfg = DataConfig(batch=4, seq=32, vocab=cfg.vocab)
    tr = Trainer(cfg, _mesh1(), dcfg,
                 LoopConfig(steps=8, sync="psum"),
                 opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=8))
    tr.run(jax.random.key(0))
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0], losses


def test_checkpoint_restart_resumes(tmp_path):
    cfg = reduced(get_config("qwen3-4b"))
    dcfg = DataConfig(batch=4, seq=32, vocab=cfg.vocab)
    lc = LoopConfig(steps=6, ckpt_dir=str(tmp_path), ckpt_every=3,
                    sync="psum")
    Trainer(cfg, _mesh1(), dcfg, lc).run(jax.random.key(0))
    tr2 = Trainer(cfg, _mesh1(), dcfg,
                  LoopConfig(steps=9, ckpt_dir=str(tmp_path), ckpt_every=3,
                             sync="psum"))
    tr2.run(jax.random.key(0))
    assert any("restored step 6" in e for e in tr2.events)
    assert len(tr2.history) == 3             # only steps 6..8 re-run


def test_failure_injection_recovers(tmp_path):
    cfg = reduced(get_config("llama3-8b"))
    dcfg = DataConfig(batch=4, seq=32, vocab=cfg.vocab)
    lc = LoopConfig(steps=7, ckpt_dir=str(tmp_path), ckpt_every=2,
                    sync="psum")
    tr = Trainer(cfg, _mesh1(), dcfg, lc)
    tr.run(jax.random.key(0), fail_at=5)
    assert any("simulated failure" in e for e in tr.events)
    assert any("restored" in e for e in tr.events)
    assert tr.history[-1]["step"] == 6       # completed all steps


_MULTIPOD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.core.wansync import wan_allreduce, psum_allreduce
    from repro.core.plan import WanPlan

    mesh = make_mesh((4, 2), ("pod", "data"))
    plan = WanPlan(
        n_pods=4,
        conns=tuple(tuple(6 if abs(i - j) % 4 > 1 else 2 for j in range(4))
                    for i in range(4)),
        pred_bw=tuple(tuple(150.0 if abs(i - j) % 4 > 1 else 900.0
                            for j in range(4)) for i in range(4)),
        compress_bits=(8, 8, 8, 8))
    tree = {"w": jnp.arange(48.0).reshape(12, 4) / 7.0,
            "s": jnp.float32(2.5)}

    def f(t):
        r = jax.lax.axis_index("pod").astype(jnp.float32)
        local = jax.tree.map(lambda x: x * (r + 1.0), t)
        return wan_allreduce(local, plan, compress=False, mean=True)

    # fully-manual axes: jax 0.4.x XLA-CPU cannot partition a partially-
    # manual mesh (PartitionId unimplemented); inputs are replicated so
    # making "data" manual too is value-identical here.
    sm = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=P(),
                   axis_names={"pod", "data"}, check_vma=False)
    out = jax.jit(sm)(tree)
    exp = np.mean([r + 1 for r in range(4)])
    for k in tree:
        assert np.allclose(np.asarray(out[k]), np.asarray(tree[k]) * exp,
                           rtol=1e-5), k
    txt = jax.jit(sm).lower(tree).compile().as_text()
    assert "collective-permute" in txt
    assert txt.count("all-reduce(") == 0      # fully our schedule
    print("MULTIPOD_OK")
""")


def test_wansync_multidevice_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _MULTIPOD_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=300)
    assert "MULTIPOD_OK" in r.stdout, r.stdout + r.stderr


_DRYRUN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.configs.base import reduced
    from repro.configs import get_config
    import repro.launch.dryrun as dr

    # shrink the production mesh to the 8 host devices: same axes/logic
    from repro.compat import make_mesh
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    import repro.configs as C
    cfg = get_config("llama3-8b")
    # patch a tiny config into the registry path used by run_cell
    import repro.configs
    small = reduced(cfg)
    repro.configs._SMALL = small
    orig = repro.configs.get_config
    repro.configs.get_config = lambda a: small
    dr.get_config = repro.configs.get_config
    import repro.configs.shapes as shp
    shp.SHAPES = {"train_4k": shp.ShapeSpec("train_4k", "train", 64, 8),
                  "decode_32k": shp.ShapeSpec("decode_32k", "decode", 64, 8)}
    dr.SHAPES = shp.SHAPES
    for shape in ("train_4k", "decode_32k"):
        cell = dr.run_cell("llama3-8b", shape, mesh, "multi")
        assert cell["status"] == "ok", cell
        assert cell["roofline"]["t_compute"] > 0
    print("DRYRUN_OK")
""")


def test_small_mesh_dryrun_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _DRYRUN_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=600)
    assert "DRYRUN_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


def test_wanify_improves_min_bw_end_to_end():
    """The paper's headline: WANify raises the cluster's minimum BW vs
    single-connection AND uniform-parallel baselines (on the calibrated
    simulator, full 8-DC mesh)."""
    from repro.core.global_opt import global_optimize
    from repro.wan.simulator import WanSimulator
    mins = {}
    sim = WanSimulator(seed=5)
    off = ~np.eye(8, dtype=bool)
    # noise-free runtime ground truth: the headline gain should not
    # hinge on one measurement-noise draw flipping a closeness class
    pred = sim.measure_simultaneous()
    plan = global_optimize(pred, M=8)
    mins["single"] = sim.measure_simultaneous(np.ones((8, 8)))[off].min()
    mins["uniform8"] = sim.measure_simultaneous(np.full((8, 8), 8.0))[off].min()
    mins["wanify"] = sim.measure_simultaneous(
        plan.max_cons.astype(float))[off].min()
    assert mins["wanify"] > mins["single"] * 1.25, mins
    assert mins["wanify"] > mins["uniform8"] * 1.1, mins
