"""Checkpointing: roundtrip, manifest contract, async, crash-atomicity."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    assert ckpt.latest_step(str(tmp_path)) == 3
    r = ckpt.restore(str(tmp_path), t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_step_picks_newest(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    ckpt.save(str(tmp_path), 5, t)
    ckpt.save(str(tmp_path), 3, t)
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_async_save(tmp_path):
    t = _tree()
    th = ckpt.save(str(tmp_path), 9, t, async_=True)
    th.join()
    assert ckpt.latest_step(str(tmp_path)) == 9


def test_incomplete_checkpoint_ignored(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 2, t)
    # simulate a crash mid-write: directory without manifest
    os.makedirs(tmp_path / "step_00000007")
    assert ckpt.latest_step(str(tmp_path)) == 2
    r = ckpt.restore(str(tmp_path), t)
    assert r is not None


def test_restore_casts_dtype(tmp_path):
    t = {"w": jnp.ones((4,), jnp.float32)}
    ckpt.save(str(tmp_path), 1, t)
    like = {"w": jnp.ones((4,), jnp.bfloat16)}
    r = ckpt.restore(str(tmp_path), like)
    assert r["w"].dtype == jnp.bfloat16
