"""Test config. NOTE: no XLA_FLAGS device-count override here — smoke
tests must see the real single CPU device. Multi-device tests (wansync,
small-mesh dryrun) spawn subprocesses with their own env."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
