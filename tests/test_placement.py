"""Placement subsystem tests: query model, cost estimator (validated
against the simulator water-fill), deterministic optimizer vs the
exhaustive reference, planner triggers + fleet envelope pricing, and
the §5 end-to-end comparison (WANify vs static-BW placement) with
byte-identical replay."""
import numpy as np
import pytest

from repro.control import BudgetEnvelope, WanifyController
from repro.core.predictor import SnapshotPredictor
from repro.placement import (PlacementPlanner, achievable_bw,
                             compare_backends, estimate_cost,
                             exhaustive_place, get_workload, greedy_place,
                             initial_placement, iterative,
                             run_placement_scenario, scan_agg,
                             skewed_partitions, two_stage_join,
                             workload_names)
from repro.placement.query import QuerySpec, Stage
from repro.scenarios import ScenarioSpec, at
from repro.scenarios.events import Rescale
from repro.wan.simulator import WanSimulator

QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0)


def quiet_controller(n_pods=4, seed=0, **cfg):
    sim = WanSimulator(seed=seed, **QUIET)
    from repro.control import ControllerConfig
    return WanifyController(sim, SnapshotPredictor(), n_pods=n_pods,
                            cfg=ControllerConfig(**cfg) if cfg else None)


# ----------------------------------------------------------------------
# query model
# ----------------------------------------------------------------------
def test_workload_library_shapes_and_totals():
    for name in workload_names():
        q = get_workload(name, 4)
        assert q.n == 4
        assert q.n_shuffles() >= 1
        assert q.inputs().sum() > 0


def test_skewed_partitions_deterministic_and_monotone():
    p = skewed_partitions(4, 60.0, skew=2.0)
    assert p == skewed_partitions(4, 60.0, skew=2.0)
    assert abs(sum(p) - 60.0) < 1e-9
    assert all(a > b for a, b in zip(p, p[1:]))   # DC0 heaviest
    flat = skewed_partitions(4, 60.0, skew=1.0)
    assert np.allclose(flat, 15.0)


def test_query_validation():
    with pytest.raises(ValueError):
        QuerySpec("bad", (10.0,), (Stage("s", 1.0, 1.0),))
    with pytest.raises(ValueError):
        QuerySpec("bad", (10.0, 10.0), ())
    with pytest.raises(ValueError):
        QuerySpec("bad", (10.0, 10.0), (Stage("s", 1.0, 1.0),),
                  compute_speed=(1.0,))
    with pytest.raises(KeyError):
        get_workload("nope", 4)


# ----------------------------------------------------------------------
# cost model
# ----------------------------------------------------------------------
def test_estimate_cost_hand_example():
    # 2 DCs, all data on DC0, everything placed on DC1: the whole
    # stage-0 output crosses the one link
    q = QuerySpec("hand", input_gb=(16.0, 0.0),
                  stages=(Stage("map", out_ratio=0.5,
                                compute_s_per_gb=1.0),
                          Stage("red", out_ratio=1.0,
                                compute_s_per_gb=2.0)))
    bw = np.array([[10000.0, 100.0], [100.0, 10000.0]])
    placement = np.array([[0.0, 1.0]])
    c = estimate_cost(q, placement, bw, egress_usd_per_gb=0.1)
    # stage 0 compute: 16 Gb * 1 s/Gb = 16 s; shuffle: 8 Gb over
    # 100 Mbps = 80 s; stage 1 compute: 8 Gb * 2 = 16 s
    assert c.compute_s == pytest.approx(32.0)
    assert c.net_s == pytest.approx(80.0)
    assert c.makespan_s == pytest.approx(112.0)
    assert c.egress_gb == pytest.approx(1.0)          # 8 Gb -> 1 GB
    assert c.egress_usd == pytest.approx(0.1)


def test_heterogeneous_compute_slows_makespan():
    q_fast = scan_agg(4)
    q_slow = scan_agg(4, speed=(1.0, 1.0, 1.0, 0.25))
    bw = np.full((4, 4), 500.0)
    p = initial_placement(q_fast)
    assert estimate_cost(q_slow, p, bw).makespan_s > \
        estimate_cost(q_fast, p, bw).makespan_s


def test_waves_amplify_network_term():
    q1 = iterative(4, waves=1)
    q5 = iterative(4, waves=5)
    bw = np.full((4, 4), 300.0)
    p = initial_placement(q1)
    c1, c5 = estimate_cost(q1, p, bw), estimate_cost(q5, p, bw)
    assert c5.net_s == pytest.approx(5 * c1.net_s)
    assert c5.egress_gb == pytest.approx(5 * c1.egress_gb)


def test_achievable_bw_scales_from_capture_point():
    ctl = quiet_controller()
    plan = ctl.plan
    # from-scratch capture (ones): plain predicted-BW x conns
    ones = np.ones((4, 4))
    bw = achievable_bw(plan, capture_conns=ones, knee=None)
    pred = np.asarray(plan.pred_bw)
    conns = np.asarray(plan.conns, float)
    off = ~np.eye(4, dtype=bool)
    assert np.allclose(bw[off], (pred * conns)[off])
    # captured at the plan's own matrix: the prediction IS the aggregate
    bw2 = achievable_bw(plan, capture_conns=conns, knee=None)
    assert np.allclose(bw2[off], pred[off])


def test_achievable_bw_envelope_cap_applies():
    ctl = quiet_controller()
    cap = np.full((4, 4), 50.0)
    bw = achievable_bw(ctl.plan, link_cap=cap)
    off = ~np.eye(4, dtype=bool)
    assert (bw[off] <= 50.0 + 1e-9).all()
    assert bw[0, 0] > 50.0                    # diagonal stays intra-DC


def test_priced_bw_tracks_waterfill_ground_truth():
    # the ISSUE contract: predicted-BW x conns pricing, validated
    # against the simulator's water-fill at the executed matrix
    sim = WanSimulator(seed=1, **QUIET)
    ctl = WanifyController(sim, SnapshotPredictor(), n_pods=4)
    for _ in range(3):                        # converge to steady state
        ctl.replan(reason="periodic")
    planner = PlacementPlanner(ctl, scan_agg(4))
    full = np.ones((sim.N, sim.N))
    full[:4, :4] = planner.exec_conns()
    achieved = sim.waterfill(full)[:4, :4]
    off = ~np.eye(4, dtype=bool)
    ratio = planner.priced_bw()[off] / achieved[off]
    assert (ratio > 0.7).all() and (ratio < 1.5).all()


# ----------------------------------------------------------------------
# optimizer
# ----------------------------------------------------------------------
def test_greedy_never_worse_than_initial():
    ctl = quiet_controller()
    bw = achievable_bw(ctl.plan)
    for name in workload_names():
        q = get_workload(name, 4)
        init = estimate_cost(q, initial_placement(q), bw)
        d = greedy_place(q, bw)
        assert d.cost.makespan_s <= init.makespan_s + 1e-9


def test_greedy_close_to_exhaustive_reference():
    ctl = quiet_controller()
    bw = achievable_bw(ctl.plan)
    q = scan_agg(4)                           # one shuffle: fine grid ok
    g = greedy_place(q, bw)
    e = exhaustive_place(q, bw, levels=10)
    assert g.cost.makespan_s <= e.cost.makespan_s * 1.05


def test_exhaustive_guard_and_small_n():
    bw = np.full((3, 3), 400.0)
    q3 = scan_agg(3)
    e = exhaustive_place(q3, bw, levels=4)
    assert abs(sum(e.placement[0]) - 1.0) < 1e-9
    with pytest.raises(ValueError):
        exhaustive_place(scan_agg(5), np.full((5, 5), 400.0))


def test_optimizer_deterministic():
    ctl = quiet_controller()
    bw = achievable_bw(ctl.plan)
    q = two_stage_join(4)
    assert greedy_place(q, bw).placement == greedy_place(q, bw).placement


def test_slow_dc_repels_tasks():
    # heterogeneous compute: making DC 2 4x slower must not increase
    # its assigned fraction
    ctl = quiet_controller()
    bw = achievable_bw(ctl.plan)
    base = greedy_place(scan_agg(4), bw).frac()
    slow = greedy_place(scan_agg(4, speed=(1.0, 1.0, 0.25, 1.0)),
                        bw).frac()
    assert slow[0, 2] <= base[0, 2] + 1e-9


# ----------------------------------------------------------------------
# planner
# ----------------------------------------------------------------------
def test_planner_replaces_on_controller_triggers():
    ctl = quiet_controller()
    planner = PlacementPlanner(ctl, scan_agg(4))
    assert [r.reason for r in planner.records] == ["init"]
    ctl.replan(reason="explicit")
    ctl.topology_changed()
    reasons = [r.reason for r in planner.records]
    assert reasons == ["init", "explicit", "topology"]


def test_static_backend_places_once_and_ignores_replans():
    ctl = quiet_controller()
    planner = PlacementPlanner(ctl, scan_agg(4), backend="static")
    ctl.replan(reason="explicit")
    ctl.topology_changed()
    assert len(planner.records) == 1
    assert np.allclose(planner.exec_conns(),
                       np.ones((4, 4)))       # the 1-conn ablation


def test_detached_planner_stops_replacing():
    ctl = quiet_controller()
    planner = PlacementPlanner(ctl, scan_agg(4))
    planner.detach()
    ctl.replan(reason="explicit")
    assert [r.reason for r in planner.records] == ["init"]
    # a fresh planner on the same controller still rides the triggers
    fresh = PlacementPlanner(ctl, scan_agg(4))
    ctl.replan(reason="explicit")
    assert len(fresh.records) == 2


def test_greedy_with_search_disabled_prices_baseline():
    ctl = quiet_controller()
    bw = achievable_bw(ctl.plan)
    q = scan_agg(4)
    d = greedy_place(q, bw, coarse=0, fine=0)
    assert np.allclose(d.frac(), initial_placement(q))
    init = estimate_cost(q, initial_placement(q), bw)
    assert d.cost.makespan_s == pytest.approx(init.makespan_s)


def test_planner_rejects_mismatched_query():
    ctl = quiet_controller()
    with pytest.raises(ValueError):
        PlacementPlanner(ctl, scan_agg(3))
    with pytest.raises(ValueError):
        PlacementPlanner(ctl, scan_agg(4), backend="nope")


def test_envelope_prices_fair_share():
    # the fleet tie-in: a capped tenant prices strictly less achievable
    # BW and a no-better makespan than the same job uncapped
    ctl = quiet_controller()
    q = scan_agg(4)
    free = PlacementPlanner(ctl, q)
    est_free = free.estimated()
    cap = np.full((4, 4), 40.0)
    ctl.set_envelope(BudgetEnvelope(max_conns=4, link_cap=cap))
    ctl.replan(reason="envelope")
    capped = PlacementPlanner(ctl, q)
    off = ~np.eye(4, dtype=bool)
    assert (capped.priced_bw()[off] <= 40.0 + 1e-9).all()
    assert capped.estimated().makespan_s > est_free.makespan_s


def test_fleet_job_planner_low_priority_prices_less():
    from repro.fleet import (BatchedRfPredictor, FleetController, JobSpec,
                             default_fleet_forest)
    sim = WanSimulator(seed=0, **QUIET)
    fleet = FleetController(
        sim, BatchedRfPredictor(default_fleet_forest()), m_total=8,
        jobs=(JobSpec("hi", dcs=(0, 1, 2, 3), priority=4.0),
              JobSpec("lo", dcs=(0, 1, 2, 3), priority=1.0)))
    fleet.tick()
    q = scan_agg(4)
    hi = fleet.job_planner("hi", q)
    lo = fleet.job_planner("lo", q)
    off = ~np.eye(4, dtype=bool)
    assert lo.priced_bw()[off].min() < hi.priced_bw()[off].min()
    assert lo.estimated().makespan_s > hi.estimated().makespan_s
    n_hi, n_lo = len(hi.records), len(lo.records)
    fleet.tick()                              # fleet replans re-place
    assert len(hi.records) == n_hi + 1
    assert len(lo.records) == n_lo + 1


# ----------------------------------------------------------------------
# scenario runs: the §5 end-to-end comparison + replay
# ----------------------------------------------------------------------
def test_e2e_wanify_beats_static_on_two_scenarios():
    # acceptance: on >= 2 named scenarios, WANify-predicted-BW
    # placement achieves strictly lower simulated makespan than the
    # static single-connection ablation, with egress cost no worse
    q = two_stage_join(4)
    for scen in ("skew_ramp", "cable_cut"):
        r = compare_backends(scen, query=q, seed=0)
        assert r["wanify"]["makespan_total_s"] < \
            r["static"]["makespan_total_s"], scen
        assert r["wanify"]["egress_usd_total"] <= \
            r["static"]["egress_usd_total"] + 1e-9, scen


def test_e2e_link_flap_latency_win():
    # under the flap, WANify re-places (2 topology replans) and still
    # wins latency outright; it pays a small egress premium (<3%) for
    # the spread that dodges the dead link — reported, not hidden
    r = compare_backends("link_flap", query=two_stage_join(4), seed=0)
    assert r["wanify"]["replacements"] >= 2
    assert r["latency_delta_pct"] > 10.0
    assert r["egress_delta_pct"] > -3.0


def test_placement_trace_replays_byte_identical():
    q = two_stage_join(4)
    for backend in ("wanify", "static"):
        a = run_placement_scenario("skew_ramp", query=q, seed=3,
                                   backend=backend)
        b = run_placement_scenario("skew_ramp", query=q, seed=3,
                                   backend=backend)
        assert a.trace.to_json() == b.trace.to_json()


def test_placement_trace_replays_byte_identical_noisy():
    a = run_placement_scenario("runtime_fluctuation", seed=5)
    b = run_placement_scenario("runtime_fluctuation", seed=5)
    assert a.trace.to_json() == b.trace.to_json()


def test_skew_ramp_replaces_and_traces():
    res = run_placement_scenario("skew_ramp", query=scan_agg(4), seed=0)
    s = res.summary()
    assert s["replacements"] >= 2              # periodic replans re-place
    assert res.trace.replaced_steps()
    # every step carries an executable placement
    for step in res.trace.steps:
        for row in step.placement:
            assert abs(sum(row) - 1.0) < 1e-6


def test_rescale_scenarios_rejected():
    spec = ScenarioSpec(name="bad", steps=5,
                        events=(at(2, Rescale(n_pods=6)),),
                        sim_kwargs=dict(QUIET))
    with pytest.raises(ValueError):
        run_placement_scenario(spec, query=scan_agg(4))
