"""Serving correctness: prefill+decode must reproduce the full-sequence
forward logits (KV-cache consistency), per family; engine batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import registry
from repro.models.layers import ShardCtx
from repro.serve.engine import Engine, Request, ServeConfig

CTX = ShardCtx(remat="none")

# one representative per attention/cache mechanism
FAMILIES = ["llama3-8b",        # GQA
            "qwen3-4b",         # GQA + qk_norm
            "minicpm3-4b",      # MLA (absorbed decode)
            "h2o-danube-1.8b",  # SWA ring cache
            "granite-moe-1b-a400m",  # MoE
            "mamba2-2.7b",      # SSM recurrent state
            "zamba2-2.7b",      # hybrid shared-attn cache
            "whisper-medium",   # enc-dec cross-attn
            "internvl2-2b"]     # VLM patch prefix


def _full_logits(cfg, params, batch, upto):
    """Logits at position `upto-1` from a full forward pass."""
    if cfg.is_encdec:
        from repro.models.encdec import encdec_loss  # noqa
        # run decoder forward via loss path machinery: easier to use
        # prefill at exactly `upto` tokens
        return None
    from repro.models.transformer import lm_forward
    logits, _, _ = lm_forward(params, batch["tokens"][:, :upto], cfg, CTX,
                              extra_embeds=batch.get("patch_embeds"))
    return logits[:, -1]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_full_forward(arch):
    cfg = reduced(get_config(arch))
    params = registry.init_params(cfg, jax.random.key(0))
    B, S0, n_dec, S_max = 2, 16, 4, 32
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab, (B, S0 + n_dec)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :S0])}
    extras = {}
    if cfg.is_encdec:
        extras["enc_frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.encoder.source_len,
                              cfg.encoder.d_model)).astype(np.float32))
    if cfg.is_vlm:
        extras["patch_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.encoder.source_len,
                                 cfg.d_model)).astype(np.float32))
    batch.update(extras)

    prefill = jax.jit(registry.prefill_fn(cfg, CTX, S_max, tp=1))
    decode = jax.jit(registry.decode_fn(cfg, CTX))
    logits_p, cache = prefill(params, batch)

    for t in range(n_dec):
        pos = S0 + t
        logits_d, cache = decode(params, cache,
                                 jnp.asarray(toks[:, pos:pos + 1]),
                                 jnp.int32(pos))
    # compare final decode logits against a full forward over the whole
    # prefix (positions 0..S0+n_dec-1)
    if cfg.is_encdec:
        full_batch = dict(extras, tokens=jnp.asarray(toks))
        logits_f, _ = jax.jit(
            registry.prefill_fn(cfg, CTX, S_max, tp=1))(params, full_batch)
    else:
        full_batch = dict(extras, tokens=jnp.asarray(toks))
        logits_f, _ = jax.jit(
            registry.prefill_fn(cfg, CTX, S_max, tp=1))(params, full_batch)
    a = np.asarray(logits_d, np.float32)
    b = np.asarray(logits_f, np.float32)
    top1 = (np.argmax(a, -1) == np.argmax(b, -1)).mean()
    if cfg.is_moe:
        # capacity-bounded MoE legitimately drops different tokens in the
        # 1-token decode group vs the batched prefill group: compare the
        # decisions, not the raw logits
        assert top1 >= 0.99, f"{arch}: top-1 agreement {top1}"
    else:
        # bf16 accumulation-order drift; random reduced weights give
        # near-tied logits, so compare values (argmax may flip on ties)
        np.testing.assert_allclose(a, b, atol=0.2, rtol=0.2)


def test_engine_batched_serving():
    cfg = reduced(get_config("qwen3-4b"))
    params = registry.init_params(cfg, jax.random.key(1))
    eng = Engine(cfg, params, ServeConfig(batch=2, s_max=64, tp=1))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab, 5 + i
                                               ).astype(np.int32), max_new=4)
            for i in range(5)]
    out = eng.serve(reqs)
    assert set(out) == {0, 1, 2, 3, 4}
    assert all(len(v) == 4 for v in out.values())
    assert all(0 <= t < cfg.vocab for v in out.values() for t in v)
