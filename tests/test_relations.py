"""Algorithm 1 (INFER_DC_RELATIONS) — paper-exact worked example."""
import numpy as np

from repro.core.relations import infer_dc_relations

PAPER_BW = np.array([[1000, 400, 120],
                     [380, 1000, 130],
                     [110, 120, 1000]], float)


def test_paper_example():
    rel = infer_dc_relations(PAPER_BW, D=30)
    # filtered unique BWs {110, 380, 1000}: 1000->1, {400,380}->2,
    # {120,130,110}->3 (paper Section 3.2.1)
    expected = np.array([[1, 2, 3],
                         [2, 1, 3],
                         [3, 3, 1]])
    np.testing.assert_array_equal(rel, expected)


def test_diagonal_always_closest():
    rng = np.random.default_rng(0)
    for _ in range(5):
        n = rng.integers(2, 8)
        bw = rng.uniform(100, 2000, (n, n))
        np.fill_diagonal(bw, 10000)
        rel = infer_dc_relations(bw, D=100)
        assert (np.diag(rel) == 1).all()


def test_filtering_merges_close_values():
    bw = np.array([[1000.0, 500, 505],
                   [500, 1000, 510],
                   [505, 510, 1000]])
    rel = infer_dc_relations(bw, D=30)
    off = rel[~np.eye(3, dtype=bool)]
    # all off-diagonal BWs are within D of each other -> one class
    assert len(set(off.tolist())) == 1


def test_huge_D_collapses_everything_to_one_class():
    # D larger than every BW gap: the reverse traversal filters the
    # unique list down to its smallest entry, so every pair (diagonal
    # included) lands in closeness class 1
    bw = np.array([[1000.0, 950, 920],
                   [950, 1000, 910],
                   [920, 910, 1000]])
    rel = infer_dc_relations(bw, D=1e6)
    np.testing.assert_array_equal(rel, np.ones((3, 3), np.int64))


def test_asymmetric_bw_yields_asymmetric_relations():
    # i->j and j->i are independent measurements (directional routing /
    # provider asymmetry); closeness follows each direction's own BW
    bw = np.array([[1000.0, 800, 120],
                   [300, 1000, 130],
                   [110, 600, 1000]])
    rel = infer_dc_relations(bw, D=50)
    assert rel[0, 1] != rel[1, 0]          # 800 vs 300
    assert rel[1, 0] > rel[0, 1]           # weaker direction = farther
    assert rel[2, 1] < rel[1, 2]           # 600 vs 130
    # every direction still monotone: weaker BW never gets a closer index
    off = ~np.eye(3, dtype=bool)
    flat_bw, flat_rel = bw[off], rel[off]
    order = np.argsort(flat_bw)
    assert (np.diff(flat_rel[order]) <= 0).all()


def test_all_equal_offdiagonal_is_single_class_behind_diagonal():
    # a perfectly homogeneous mesh: every WAN pair shares one class,
    # strictly behind the intra-DC diagonal
    bw = np.full((4, 4), 500.0)
    np.fill_diagonal(bw, 1000.0)
    rel = infer_dc_relations(bw, D=30)
    off = ~np.eye(4, dtype=bool)
    assert set(rel[off].tolist()) == {2}
    assert (np.diag(rel) == 1).all()


def test_monotone_weaker_link_larger_index():
    bw = np.array([[1000.0, 900, 300, 100],
                   [900, 1000, 350, 120],
                   [300, 350, 1000, 700],
                   [100, 120, 700, 1000]])
    rel = infer_dc_relations(bw, D=50)
    flat_bw = bw[~np.eye(4, dtype=bool)]
    flat_rel = rel[~np.eye(4, dtype=bool)]
    order = np.argsort(flat_bw)
    assert (np.diff(flat_rel[order]) <= 0).all()
