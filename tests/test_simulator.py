"""WAN simulator — calibration against the paper's published numbers +
max-min fairness invariants (Table 1 / §2)."""
import numpy as np
import pytest

from repro.wan.simulator import WanSimulator


@pytest.fixture(scope="module")
def sim():
    return WanSimulator(seed=1)


def test_fig1_calibration(sim):
    ue = sim.regions.index("us-east")
    uw = sim.regions.index("us-west")
    ap = sim.regions.index("ap-se")
    si = sim.measure_static_independent()
    assert abs(si[ue, uw] - 1700) < 50      # paper: 1700 Mbps
    assert abs(si[ue, ap] - 121) < 10       # paper: 121 Mbps


def test_parallel_connection_knee(sim):
    """~1 Gbps at 9 connections on the weakest link; no gain past ~8."""
    ue, ap = sim.regions.index("us-east"), sim.regions.index("ap-se")
    c = np.zeros((8, 8))
    c[ue, ap] = 9
    bw9 = sim.waterfill(c)[ue, ap]
    assert 850 <= bw9 <= 1150               # paper: "up to 1 Gbps"
    c[ue, ap] = 16
    bw16 = sim.waterfill(c)[ue, ap]
    assert bw16 <= bw9 * 1.15               # knee: no further gain


def test_table1_static_vs_runtime_gaps():
    sim = WanSimulator(seed=1)
    si = sim.measure_static_independent()
    sim.advance(10)                          # static data goes stale
    rt = sim.measure_runtime()
    off = ~np.eye(8, dtype=bool)
    gaps = np.abs(rt - si)[off]
    sig = int((gaps > 100).sum())
    assert 10 <= sig <= 30                   # paper: 18 significant pairs


def test_fairness_invariants(sim):
    rng = np.random.default_rng(0)
    conns = rng.integers(1, 9, (8, 8)).astype(float)
    np.fill_diagonal(conns, 0)
    bw = sim.waterfill(conns)
    off = ~np.eye(8, dtype=bool)
    single = sim.link_bw_now()
    # per-connection rate never exceeds the single-connection ceiling
    rate = bw / np.maximum(conns, 1e-9)
    assert (rate[off] <= single[off] * 1.001).all()
    # path cap: knee * single
    assert (bw[off] <= single[off] * sim.knee * 1.001).all()
    # NIC caps
    out_tot = np.where(off, bw, 0).sum(axis=1)
    in_tot = np.where(off, bw, 0).sum(axis=0)
    assert (out_tot <= sim.nic_cap * 1.001).all()
    assert (in_tot <= sim.nic_cap * 1.001).all()


def test_contention_reduces_bw(sim):
    """Runtime (all pairs) BW <= solo BW on every link."""
    solo = sim.measure_static_independent()
    allp = sim.waterfill(np.ones((8, 8)))
    off = ~np.eye(8, dtype=bool)
    assert (allp[off] <= solo[off] * 1.05).all()


def test_heterogeneous_beats_uniform_min_bw():
    """The Fig. 2 story on the simulator: WANify's heterogeneous
    connections lift the cluster's minimum BW vs uniform-8."""
    from repro.core.global_opt import global_optimize
    sim = WanSimulator(seed=2)
    pred = sim.measure_runtime()
    plan = global_optimize(pred, M=8)
    off = ~np.eye(8, dtype=bool)
    uni = sim.measure_simultaneous(np.full((8, 8), 8.0))
    het = sim.measure_simultaneous(plan.max_cons.astype(float))
    assert het[off].min() > uni[off].min()


def test_association_multiple_vms():
    """§3.3.3: more VMs per DC => proportionally more NIC capacity."""
    sim1 = WanSimulator(seed=3)
    sim2 = WanSimulator(seed=3, vms_per_dc=np.full(8, 2.0))
    c = np.full((8, 8), 8.0)
    b1 = sim1.waterfill(c)
    b2 = sim2.waterfill(c)
    off = ~np.eye(8, dtype=bool)
    assert b2[off].sum() > b1[off].sum() * 1.2


def test_provider_refactoring():
    """§3.3.3: provider factor scales link BW proportionally."""
    pf = np.ones(8)
    pf[:4] = 0.5
    sim = WanSimulator(seed=4, provider_factor=pf)
    base = WanSimulator(seed=4)
    assert sim.base[0, 1] < base.base[0, 1]
    # runtime provider migration rebuilds base and is reversible
    base.set_provider_factor(pf)
    np.testing.assert_allclose(base.base, sim.base)
    base.set_provider_factor(None)
    np.testing.assert_allclose(base.base, WanSimulator(seed=4).base)


# ----------------------------------------------------------------------
# Named RNG streams + observation-noise symmetry (determinism contract)
# ----------------------------------------------------------------------
def test_rng_streams_are_call_order_independent():
    """The same network state yields the same measurement regardless of
    which other draws happened in between: fluctuation, observation and
    host noise come from separate streams spawned from the seed."""
    c = np.full((8, 8), 4.0)
    np.fill_diagonal(c, 0)
    a = WanSimulator(seed=9)
    b = WanSimulator(seed=9)
    # a: snapshot then host metrics; b: host metrics then snapshot —
    # with the single shared rng these interleavings diverged
    snap_a = a.measure_snapshot(c)
    mem_a, cpu_a, retr_a = a.host_metrics(c)
    mem_b, cpu_b, retr_b = b.host_metrics(c)
    snap_b = b.measure_snapshot(c)
    np.testing.assert_array_equal(snap_a, snap_b)
    np.testing.assert_array_equal(mem_a, mem_b)
    np.testing.assert_array_equal(cpu_a, cpu_b)
    np.testing.assert_array_equal(retr_a, retr_b)


def test_advance_isolated_from_measurement_draws():
    """Fluctuation state depends only on advance() calls, not on how
    many measurements were taken in between."""
    a, b = WanSimulator(seed=11), WanSimulator(seed=11)
    b.measure_snapshot(np.ones((8, 8)))
    b.host_metrics(np.ones((8, 8)))
    a.advance(5)
    b.advance(5)
    np.testing.assert_array_equal(a.link_bw_now(), b.link_bw_now())


def test_symmetric_obs_noise_default():
    """Links are modelled symmetric in advance(); by default snapshot
    noise is symmetric too, so a snapshot of a symmetric network stays
    symmetric — the right input for the symmetric global optimizer."""
    sim = WanSimulator(seed=6)
    assert sim.symmetric_obs_noise is True
    snap = sim.measure_snapshot(np.ones((8, 8)))
    np.testing.assert_allclose(snap, snap.T, rtol=1e-9)

    indep = WanSimulator(seed=6, symmetric_obs_noise=False)
    snap_i = indep.measure_snapshot(np.ones((8, 8)))
    assert np.abs(snap_i - snap_i.T).max() > 1.0   # iPerf-style i/j noise


def test_symmetric_noise_preserves_marginal_scale():
    """The /sqrt(2) in the symmetrization keeps the per-link log-sd at
    snapshot_sigma, so the predictor's noise floor is flag-invariant."""
    devs = {}
    for flag in (True, False):
        sim = WanSimulator(seed=13, symmetric_obs_noise=flag,
                           snapshot_sigma=0.08)
        truth = sim.waterfill(np.ones((8, 8)))
        logs = []
        for _ in range(40):
            snap = sim.measure_snapshot(np.ones((8, 8)))
            off = ~np.eye(8, dtype=bool)
            logs.append(np.log(snap[off] / truth[off]))
        devs[flag] = np.std(np.concatenate(logs))
    assert abs(devs[True] - devs[False]) < 0.015
    assert abs(devs[True] - 0.08) < 0.015


# ----------------------------------------------------------------------
# Scripted-dynamics hooks (scenario engine targets)
# ----------------------------------------------------------------------
def test_link_factor_and_modulation():
    sim = WanSimulator(seed=2, fluct_sigma=0.0)
    nominal = sim.link_bw_now()[0, 1]
    sim.set_link_factor(0, 1, 0.1)
    assert abs(sim.link_bw_now()[0, 1] - 0.1 * nominal) < 1e-9
    assert abs(sim.link_bw_now()[1, 0] - 0.1 * nominal) < 1e-9  # symmetric
    sim.set_link_factor(0, 1, 1.0)
    sim.modulation = 0.5
    assert abs(sim.link_bw_now()[0, 1] - 0.5 * nominal) < 1e-9


def test_background_traffic_contends_but_is_not_credited():
    """Cross-traffic squeezes the workload's achieved BW but never
    shows up as workload throughput, and purely-background pairs report
    exactly zero."""
    sim = WanSimulator(seed=2, fluct_sigma=0.0)
    c = np.zeros((8, 8))
    c[0, 1] = 4.0
    quiet = sim.waterfill(c)[0, 1]
    sim.set_background(0, 1, 32.0)
    sim.set_background(2, 3, 8.0)           # background-only pair
    squeezed = sim.waterfill(c)
    assert squeezed[0, 1] < quiet
    assert squeezed[2, 3] == 0.0
    sim.set_background(0, 1, 0.0)
    sim.set_background(2, 3, 0.0)
    np.testing.assert_allclose(sim.waterfill(c)[0, 1], quiet)


# ----------------------------------------------------------------------
# Closed-form solo-pair measurement + fill-invariant caching
# ----------------------------------------------------------------------
def _static_independent_loop(sim, conns_per_pair=1):
    """The historical implementation: one full water-fill per pair."""
    from repro.wan.topology import INTRA_DC_BW
    N = sim.N
    out = np.full((N, N), INTRA_DC_BW)
    for i in range(N):
        for j in range(N):
            if i == j:
                continue
            c = np.zeros((N, N))
            c[i, j] = conns_per_pair
            out[i, j] = sim.waterfill(c)[i, j]
    return out


def test_static_independent_closed_form_equals_loop_exactly():
    """The closed-form solo-pair rate (min of per-conn cap, knee path
    cap, NIC caps in fill-level units) is BIT-identical to the
    N(N-1)-waterfill loop on the 8-DC mesh — fluctuated, degraded, and
    with heterogeneous VM counts."""
    sim = WanSimulator(seed=1)
    for conns in (1, 4, 16):
        assert (sim.measure_static_independent(conns) ==
                _static_independent_loop(sim, conns)).all()
    sim.advance(10)
    sim.set_link_factor(0, 7, 0.05)
    sim.vms_per_dc = np.array([1.0, 2.0, 1.0, 3.0, 1.0, 1.0, 2.0, 1.0])
    for conns in (1, 8):
        assert (sim.measure_static_independent(conns) ==
                _static_independent_loop(sim, conns)).all()


def test_static_independent_contended_falls_back_to_fills():
    """Cross-traffic (or a registered tenant) contends even with a solo
    measurement pair, so the closed form would overstate the rate;
    the fallback per-pair fills keep the semantics."""
    sim = WanSimulator(seed=2, fluct_sigma=0.0)
    clean = sim.measure_static_independent(4)
    sim.set_background(0, 1, 64.0)
    contended = sim.measure_static_independent(4)
    assert (contended == _static_independent_loop(sim, 4)).all()
    assert contended[0, 1] < clean[0, 1]    # the background squeezes it
    sim.set_background(0, 1, 0.0)
    sim.set_tenant_conns("rival", np.full((8, 8), 8.0))
    assert (sim.measure_static_independent(4) ==
            _static_independent_loop(sim, 4)).all()


def test_rtt_weight_cached_and_invalidated():
    sim = WanSimulator(seed=0)
    w1 = sim.rtt_weight()
    assert sim.rtt_weight() is w1           # cache hit, no rebuild
    with pytest.raises(ValueError):         # cached array is read-only
        w1[0, 1] = 9.9
    sim.rtt_beta = 3.0                      # knob change invalidates
    w2 = sim.rtt_weight()
    assert w2 is not w1
    assert not np.array_equal(w1, w2)
    off = ~np.eye(sim.N, dtype=bool)
    np.testing.assert_allclose(w2[off], w1[off] ** (3.0 / 2.0))


def test_static_independent_excludes_own_tenant():
    """A registered tenant measuring static-independent must not
    double-count its OWN flows as rival traffic (the `tenant=`
    self-exclusion every other measure_* mode already has)."""
    sim = WanSimulator(seed=2, fluct_sigma=0.0)
    clean = sim.measure_static_independent(4)
    sim.set_tenant_conns("me", np.full((8, 8), 8.0))
    named = sim.measure_static_independent(4, tenant="me")
    assert (named == clean).all()           # own registration excluded
    anon = sim.measure_static_independent(4)
    assert anon[0, 1] < clean[0, 1]         # anonymous: flows are rivals
    # with a real rival present the named call still sees the rival
    sim.set_tenant_conns("rival", np.full((8, 8), 16.0))
    both = sim.measure_static_independent(4, tenant="me")
    assert both[0, 1] < clean[0, 1]
    assert (both == _static_independent_loop_tenant(sim, 4, "me")).all()


def _static_independent_loop_tenant(sim, conns_per_pair, tenant):
    """Per-pair fills with the caller's registration excluded."""
    from repro.wan.topology import INTRA_DC_BW
    N = sim.N
    out = np.full((N, N), INTRA_DC_BW)
    for i in range(N):
        for j in range(N):
            if i == j:
                continue
            c = np.zeros((N, N))
            c[i, j] = conns_per_pair
            out[i, j] = sim.waterfill(c, tenant=tenant)[i, j]
    return out


def test_waterfill_tenants_passed_matrices_authoritative():
    """A tenant mid-replan passes a candidate matrix differing from its
    registration: the shared fill must contend AND credit at the PASSED
    matrix — the stale registration (fractional drift included) never
    enters the aggregate."""
    a_reg = np.zeros((8, 8)); a_reg[0, 1] = 6.0
    a_cand = np.zeros((8, 8)); a_cand[0, 1] = 2.3   # fractional candidate
    b = np.zeros((8, 8)); b[0, 1] = 4.0

    stale = WanSimulator(seed=0, fluct_sigma=0.0)
    stale.set_tenant_conns("a", a_reg)              # registration lags
    stale.set_tenant_conns("b", b)
    per = stale.waterfill_tenants({"a": a_cand, "b": b})

    fresh = WanSimulator(seed=0, fluct_sigma=0.0)
    fresh.set_tenant_conns("a", a_cand)             # registration matches
    fresh.set_tenant_conns("b", b)
    ref = fresh.waterfill_tenants({"a": a_cand, "b": b})
    for name in ("a", "b"):
        assert (per[name] == ref[name]).all()       # bit-identical
    # a registered tenant NOT passed still contends but is not credited
    stale.set_tenant_conns("c", np.full((8, 8), 8.0))
    squeezed = stale.waterfill_tenants({"a": a_cand, "b": b})
    assert squeezed["a"][0, 1] < per["a"][0, 1]
    assert set(squeezed) == {"a", "b"}
