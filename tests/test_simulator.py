"""WAN simulator — calibration against the paper's published numbers +
max-min fairness invariants (Table 1 / §2)."""
import numpy as np
import pytest

from repro.wan.simulator import WanSimulator
from repro.wan import topology as topo


@pytest.fixture(scope="module")
def sim():
    return WanSimulator(seed=1)


def test_fig1_calibration(sim):
    ue = sim.regions.index("us-east")
    uw = sim.regions.index("us-west")
    ap = sim.regions.index("ap-se")
    si = sim.measure_static_independent()
    assert abs(si[ue, uw] - 1700) < 50      # paper: 1700 Mbps
    assert abs(si[ue, ap] - 121) < 10       # paper: 121 Mbps


def test_parallel_connection_knee(sim):
    """~1 Gbps at 9 connections on the weakest link; no gain past ~8."""
    ue, ap = sim.regions.index("us-east"), sim.regions.index("ap-se")
    c = np.zeros((8, 8))
    c[ue, ap] = 9
    bw9 = sim.waterfill(c)[ue, ap]
    assert 850 <= bw9 <= 1150               # paper: "up to 1 Gbps"
    c[ue, ap] = 16
    bw16 = sim.waterfill(c)[ue, ap]
    assert bw16 <= bw9 * 1.15               # knee: no further gain


def test_table1_static_vs_runtime_gaps():
    sim = WanSimulator(seed=1)
    si = sim.measure_static_independent()
    sim.advance(10)                          # static data goes stale
    rt = sim.measure_runtime()
    off = ~np.eye(8, dtype=bool)
    gaps = np.abs(rt - si)[off]
    sig = int((gaps > 100).sum())
    assert 10 <= sig <= 30                   # paper: 18 significant pairs


def test_fairness_invariants(sim):
    rng = np.random.default_rng(0)
    conns = rng.integers(1, 9, (8, 8)).astype(float)
    np.fill_diagonal(conns, 0)
    bw = sim.waterfill(conns)
    off = ~np.eye(8, dtype=bool)
    single = sim.link_bw_now()
    # per-connection rate never exceeds the single-connection ceiling
    rate = bw / np.maximum(conns, 1e-9)
    assert (rate[off] <= single[off] * 1.001).all()
    # path cap: knee * single
    assert (bw[off] <= single[off] * sim.knee * 1.001).all()
    # NIC caps
    out_tot = np.where(off, bw, 0).sum(axis=1)
    in_tot = np.where(off, bw, 0).sum(axis=0)
    assert (out_tot <= sim.nic_cap * 1.001).all()
    assert (in_tot <= sim.nic_cap * 1.001).all()


def test_contention_reduces_bw(sim):
    """Runtime (all pairs) BW <= solo BW on every link."""
    solo = sim.measure_static_independent()
    allp = sim.waterfill(np.ones((8, 8)))
    off = ~np.eye(8, dtype=bool)
    assert (allp[off] <= solo[off] * 1.05).all()


def test_heterogeneous_beats_uniform_min_bw():
    """The Fig. 2 story on the simulator: WANify's heterogeneous
    connections lift the cluster's minimum BW vs uniform-8."""
    from repro.core.global_opt import global_optimize
    sim = WanSimulator(seed=2)
    pred = sim.measure_runtime()
    plan = global_optimize(pred, M=8)
    off = ~np.eye(8, dtype=bool)
    uni = sim.measure_simultaneous(np.full((8, 8), 8.0))
    het = sim.measure_simultaneous(plan.max_cons.astype(float))
    assert het[off].min() > uni[off].min()


def test_association_multiple_vms():
    """§3.3.3: more VMs per DC => proportionally more NIC capacity."""
    sim1 = WanSimulator(seed=3)
    sim2 = WanSimulator(seed=3, vms_per_dc=np.full(8, 2.0))
    c = np.full((8, 8), 8.0)
    b1 = sim1.waterfill(c)
    b2 = sim2.waterfill(c)
    off = ~np.eye(8, dtype=bool)
    assert b2[off].sum() > b1[off].sum() * 1.2


def test_provider_refactoring():
    """§3.3.3: provider factor scales link BW proportionally."""
    pf = np.ones(8)
    pf[:4] = 0.5
    sim = WanSimulator(seed=4, provider_factor=pf)
    base = WanSimulator(seed=4)
    assert sim.base[0, 1] < base.base[0, 1]
