"""Fault plane: chaos injection + graceful degradation (gated).

``REPRO_FAULTS=off`` (default): no fault code runs, every trace golden
replays byte-identical. ``on``: engines construct a graceful
:class:`FaultPlane` and the control loop degrades through the ladder
(retry → bounded staleness → SnapshotPredictor rung → quarantine →
plan rollback) instead of crashing. Timelines that script fault events
under the off gate get an ungraceful plane — the naive-crash ablation
the chaos harness compares against.
"""
from repro.faults.events import (FLEET_FAULT_EVENTS, DcBlackout,
                                 DcRestore, FaultEvent, MonitorOutage,
                                 NetworkPartition, PartitionHeal,
                                 PredictorFault, ProbeLoss, ProbeTimeout,
                                 SolverFault, chaos_schedule)
from repro.faults.plane import (FAULT_MODES, FaultConfig, FaultPlane,
                                ProbeTimeoutError, faults_mode)

__all__ = ["FAULT_MODES", "FaultConfig", "FaultPlane",
           "ProbeTimeoutError", "faults_mode", "FaultEvent",
           "DcBlackout", "DcRestore", "NetworkPartition",
           "PartitionHeal", "ProbeTimeout", "ProbeLoss",
           "MonitorOutage", "PredictorFault", "SolverFault",
           "FLEET_FAULT_EVENTS", "chaos_schedule"]
