"""FaultPlane — chaos injection state + the graceful-degradation ladder.

One plane per engine run. It is the single boundary through which
control-plane faults enter the closed loop, and (when graceful) the
single place the loop degrades instead of crashing:

**Injection** (applies in BOTH modes — a fault is a fault):

  * reachability — blacked-out DCs and network partitions compose into
    one bool mask installed on the simulator
    (:meth:`WanSimulator.set_reachable`): a dead pair carries ZERO
    bandwidth, not merely low BW;
  * probe faults — a replan-time snapshot capture times out
    (:class:`ProbeTimeoutError`) or loses a deterministic subset of
    pairs (NaN holes);
  * monitor outage — the per-step monitor and replan captures return
    the last pre-outage measurement, frozen, with an age counter;
  * predictor faults — NaN or garbage-scaled rows poison the predicted
    matrix;
  * solver faults — the engine's water-fill raises
    :class:`~repro.wan.simulator.WaterfillDivergence` on schedule.

**The ladder** (graceful mode only; ``REPRO_FAULTS=on``):

  1. probe retry with capped exponential backoff, every attempt priced
     through Eq. 1 (:func:`repro.wan.monitor.probe_cost_usd`);
  2. bounded staleness — fall back to the last-good capture with a
     per-step staleness discount (``stale_discount ** age``);
  3. the :class:`~repro.core.predictor.SnapshotPredictor` rung — past
     ``max_stale_steps`` the RF is bypassed entirely and the plan is
     built on the discounted last-good snapshot itself;
  4. NaN/outlier quarantine of poisoned predictor rows (backfilled
     from the last finite prediction);
  5. last-known-good plan rollback on water-fill divergence
     (:meth:`WanifyController.rollback_plan` — a plan-cache hit, not a
     re-lower).

With ``REPRO_FAULTS=off`` and no fault events scripted, NO plane is
constructed and no fault code runs — every historical trace golden
replays byte-identical. A timeline that scripts fault events under the
off gate gets an UNGRACEFUL plane (raw injection, no ladder): the
naive-crash ablation the chaos harness (:mod:`repro.faults.harness`)
compares against.

Determinism: the plane draws from its own named stream (spawned from
the engine seed, disjoint from the simulator's fluctuation /
observation / host streams), so fault runs replay deterministically
without perturbing the non-fault streams.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.obs.registry import MetricsRegistry

FAULT_MODES = ("off", "on")

# the plane's own RNG stream tag (disjoint from the simulator's
# SeedSequence.spawn(3) children by construction)
_FAULT_STREAM = 0xFA17


def faults_mode(mode: Optional[str] = None) -> str:
    """Resolve the fault gate: an explicit argument wins, then the
    ``REPRO_FAULTS`` environment variable, then ``off`` (the
    byte-identical historical path)."""
    m = mode or os.environ.get("REPRO_FAULTS", "off")
    if m not in FAULT_MODES:
        raise ValueError(f"unknown faults mode {m!r}; "
                         f"expected one of {FAULT_MODES}")
    return m


class ProbeTimeoutError(RuntimeError):
    """A replan-time snapshot capture timed out (injected). The naive
    ablation lets this propagate — the run dies exactly like a
    deployment with no retry/staleness ladder would."""


@dataclass
class FaultConfig:
    """Knobs of the degradation ladder."""

    probe_retries: int = 3        # capture retry budget per replan
    backoff_base: float = 2.0     # retry k costs base**k snapshots...
    backoff_cap: float = 4.0      # ...capped at this multiple (Eq. 1)
    stale_discount: float = 0.9   # last-good BW haircut per stale step
    max_stale_steps: int = 6      # beyond: the SnapshotPredictor rung
    outlier_factor: float = 4.0   # pred > factor x last-good = poisoned
    loss_frac: float = 0.5        # pair-drop probability under ProbeLoss


class FaultPlane:
    """Injection state + graceful-degradation ladder for one run."""

    def __init__(self, n_dcs: int, graceful: bool = True, seed: int = 0,
                 cfg: Optional[FaultConfig] = None):
        self.N = int(n_dcs)
        self.graceful = bool(graceful)
        self.cfg = cfg or FaultConfig()
        self.step = 0                      # synced by the owning engine
        self.rng = np.random.default_rng(
            np.random.SeedSequence([_FAULT_STREAM, int(seed)]))
        self.log: List[str] = []
        # -- injection state ------------------------------------------
        self.down: Set[int] = set()            # blacked-out DC indices
        self.partition: Optional[Tuple[Tuple[int, ...], ...]] = None
        self._probe_kind: Optional[str] = None     # "timeout" | "loss"
        self._probe_until = -1
        self._probe_frac = self.cfg.loss_frac
        self._outage_start = -1
        self._outage_until = -1
        self._pred_kind = "nan"
        self._pred_until = -1
        self._pred_rows = 2
        self._solver_until = -1
        # -- ladder state ---------------------------------------------
        self.last_good: Optional[Dict[str, np.ndarray]] = None
        self.last_good_step = -1
        self.last_measure: Optional[np.ndarray] = None
        self.last_pred: Optional[np.ndarray] = None
        # -- counters (obs plane; watched by the engine tracer) -------
        self.metrics = MetricsRegistry("faults")
        self._m_retries = self.metrics.counter(
            "probe_retries", help="capture retries under probe faults")
        self._m_retry_usd = self.metrics.counter(
            "retry_usd", help="Eq. 1 dollars spent on capture retries")
        self._m_stale = self.metrics.counter(
            "stale_fallbacks", help="replans served the last-good "
            "capture with a staleness discount")
        self._m_snapfall = self.metrics.counter(
            "snapshot_fallbacks", help="replans past max_stale_steps — "
            "RF bypassed for the SnapshotPredictor rung")
        self._m_backfill = self.metrics.counter(
            "pairs_backfilled", help="lost probe pairs filled from the "
            "last-good capture")
        self._m_rows = self.metrics.counter(
            "rows_quarantined", help="poisoned predictor rows replaced")
        self._m_rollbacks = self.metrics.counter(
            "rollbacks", help="last-known-good plan rollbacks after "
            "water-fill divergence")
        self._m_outage = self.metrics.counter(
            "outage_ticks", help="steps served a frozen measurement")

    # ------------------------------------------------------------------
    # injection setters (fault-event targets)
    # ------------------------------------------------------------------
    def _note(self, kind: str, msg: str) -> None:
        self.metrics.counter("injected", labels={"kind": kind}).inc()
        self.log.append(f"step {self.step}: {msg}")

    def blackout(self, dc: int) -> None:
        """Full-node loss: every link touching `dc` goes unreachable."""
        self.down.add(int(dc))
        self._note("dc_blackout", f"DC {dc} blacked out")

    def restore(self, dc: int) -> None:
        """Bring a blacked-out DC back."""
        self.down.discard(int(dc))
        self._note("dc_restore", f"DC {dc} restored")

    def set_partition(self, groups: Sequence[Sequence[int]]) -> None:
        """Network partition: DCs in DIFFERENT groups cannot reach each
        other; DCs in no group keep full reachability."""
        self.partition = tuple(tuple(int(d) for d in g) for g in groups)
        self._note("partition", f"partition {self.partition}")

    def heal_partition(self) -> None:
        """Heal the partition (blackouts, if any, stay in force)."""
        self.partition = None
        self._note("partition_heal", "partition healed")

    def probe_fault(self, kind: str, duration: int,
                    frac: Optional[float] = None) -> None:
        """Probes fail for `duration` steps from now: ``"timeout"``
        (the whole capture hangs) or ``"loss"`` (a `frac` subset of
        pairs returns nothing per attempt)."""
        if kind not in ("timeout", "loss"):
            raise ValueError(f"unknown probe fault kind {kind!r}")
        self._probe_kind = kind
        self._probe_until = self.step + int(duration)
        if frac is not None:
            self._probe_frac = float(frac)
        self._note(f"probe_{kind}", f"probes {kind} for {duration} steps")

    def monitor_outage(self, duration: int) -> None:
        """The monitoring pipeline freezes: every measurement for
        `duration` steps repeats the last pre-outage value."""
        self._outage_start = self.step
        self._outage_until = self.step + int(duration)
        self._note("monitor_outage", f"monitor dark for {duration} steps")

    def predictor_fault(self, duration: int, kind: str = "nan",
                        rows: int = 2) -> None:
        """Poison `rows` predicted-BW rows per replan for `duration`
        steps: ``"nan"`` rows or ``"garbage"`` (lognormal-scaled)."""
        if kind not in ("nan", "garbage"):
            raise ValueError(f"unknown predictor fault kind {kind!r}")
        self._pred_kind = kind
        self._pred_until = self.step + int(duration)
        self._pred_rows = int(rows)
        self._note("predictor_fault",
                   f"predictor emits {kind} rows for {duration} steps")

    def solver_fault(self, duration: int = 1) -> None:
        """The engine's water-fill diverges for `duration` steps."""
        self._solver_until = self.step + int(duration)
        self._note("solver_fault", f"water-fill diverges for "
                   f"{duration} steps")

    # ------------------------------------------------------------------
    # injection queries
    # ------------------------------------------------------------------
    def probe_failing(self, step: int) -> Optional[str]:
        """The active probe-fault kind at `step`, or None."""
        return self._probe_kind if step < self._probe_until else None

    def monitor_dark(self, step: int) -> bool:
        """True while the monitoring pipeline is frozen."""
        return step < self._outage_until

    def predictor_failing(self, step: int) -> bool:
        """True while predictor rows are being poisoned."""
        return step < self._pred_until

    def solver_failing(self, step: int) -> bool:
        """True while the water-fill is scripted to diverge."""
        return step < self._solver_until

    def reachable_mask(self) -> Optional[np.ndarray]:
        """Compose blackouts + partition into one bool [N,N] mask
        (None = fully reachable, the no-mask historical path)."""
        if not self.down and self.partition is None:
            return None
        m = np.ones((self.N, self.N), bool)
        for d in self.down:
            m[d, :] = False
            m[:, d] = False
        if self.partition is not None:
            group = {}
            for gi, g in enumerate(self.partition):
                for d in g:
                    group[d] = gi
            for i, gi in group.items():
                for j, gj in group.items():
                    if gi != gj:
                        m[i, j] = False
        np.fill_diagonal(m, True)
        return m

    def apply_reachability(self, sim: Any) -> None:
        """Install the composed mask on the simulator (fault-event
        epilogue; None clears any previous mask)."""
        sim.set_reachable(self.reachable_mask())

    # ------------------------------------------------------------------
    # the degradation ladder (controller/engine call-ins)
    # ------------------------------------------------------------------
    def _charge_retry(self, attempt: int, n_dcs: int) -> None:
        from repro.wan.monitor import SNAPSHOT_SECONDS, probe_cost_usd
        mult = min(self.cfg.backoff_base ** attempt, self.cfg.backoff_cap)
        self._m_retries.inc()
        self._m_retry_usd.inc(probe_cost_usd(SNAPSHOT_SECONDS, n_dcs)
                              * mult)

    def _remember(self, raw: Dict[str, np.ndarray]) -> None:
        self.last_good = {k: (np.array(v, copy=True)
                              if isinstance(v, np.ndarray) else v)
                          for k, v in raw.items()}
        self.last_good_step = self.step

    def _stale_capture(self) -> Tuple[Dict[str, np.ndarray],
                                      Optional[np.ndarray]]:
        """Rungs 2-3: the last-good capture with a staleness discount on
        its BW; past ``max_stale_steps`` also return a prediction
        override (the SnapshotPredictor rung — planning on a heavily
        discounted snapshot instead of feeding the RF fossil data)."""
        age = max(self.step - self.last_good_step, 1)
        disc = self.cfg.stale_discount ** age
        raw = {k: (np.array(v, copy=True)
                   if isinstance(v, np.ndarray) else v)
               for k, v in self.last_good.items()}
        raw["snapshot_bw"] = raw["snapshot_bw"] * disc
        if age > self.cfg.max_stale_steps:
            self._m_snapfall.inc()
            from repro.wan.topology import INTRA_DC_BW
            pred = np.maximum(raw["snapshot_bw"], 1.0)
            np.fill_diagonal(pred, INTRA_DC_BW)
            return raw, pred
        self._m_stale.inc()
        return raw, None

    def captured(self, monitor: Any, conns: np.ndarray
                 ) -> Tuple[Dict[str, np.ndarray], Optional[np.ndarray]]:
        """The controller's replan-time capture through the fault
        boundary. Returns ``(raw, pred_override)`` — `pred_override`
        is non-None only when the ladder bottomed out at the
        SnapshotPredictor rung. Naive mode applies the raw injection
        (timeout raises, loss leaves NaN holes, outage silently serves
        frozen data) with no ladder at all."""
        step = self.step
        n = monitor.sim.N
        if self.monitor_dark(step) and self.last_good is not None:
            if not self.graceful:
                # naive: silently stale — planning on a fossil capture
                return ({k: (np.array(v, copy=True)
                             if isinstance(v, np.ndarray) else v)
                         for k, v in self.last_good.items()}, None)
            return self._stale_capture()
        kind = self.probe_failing(step)
        if kind == "timeout":
            if not self.graceful:
                raise ProbeTimeoutError(
                    f"snapshot capture timed out at step {step}")
            # rung 1: retry with capped exponential backoff, each
            # attempt Eq. 1-priced; the fault window covers the whole
            # step, so every retry fails and we fall through to rungs
            # 2-3 (unless there is no last-good capture yet, in which
            # case a real capture is the only option left)
            for a in range(self.cfg.probe_retries):
                self._charge_retry(a, n)
            if self.last_good is not None:
                return self._stale_capture()
        if kind == "loss":
            return self._lossy_capture(monitor, conns)
        _, raw = monitor.capture(conns)
        self._remember(raw)
        return raw, None

    def _lossy_capture(self, monitor: Any, conns: np.ndarray
                       ) -> Tuple[Dict[str, np.ndarray],
                                  Optional[np.ndarray]]:
        """ProbeLoss: each attempt loses a deterministic subset of
        pairs. Naive keeps the holes (NaN snapshot entries flow into
        the predictor). Graceful retries per-pair (each attempt Eq. 1
        priced) and backfills any still-missing pair from the
        discounted last-good capture."""
        n = monitor.sim.N
        off = ~np.eye(self.N, dtype=bool)
        _, raw = monitor.capture(conns)
        snap = np.array(raw["snapshot_bw"], copy=True)
        lost = (self.rng.random((self.N, self.N)) < self._probe_frac) & off
        snap[lost] = np.nan
        if not self.graceful:
            raw = dict(raw)
            raw["snapshot_bw"] = snap
            return raw, None
        for a in range(self.cfg.probe_retries):
            if not np.isnan(snap).any():
                break
            self._charge_retry(a, n)
            _, again = monitor.capture(conns)
            redrop = (self.rng.random((self.N, self.N))
                      < self._probe_frac) & off
            fresh = np.array(again["snapshot_bw"], copy=True)
            fresh[redrop] = np.nan
            hole = np.isnan(snap) & ~np.isnan(fresh)
            snap[hole] = fresh[hole]
        hole = np.isnan(snap)
        if hole.any():
            self._m_backfill.inc(int(hole.sum()))
            if self.last_good is not None:
                age = max(self.step - self.last_good_step, 1)
                disc = self.cfg.stale_discount ** age
                snap[hole] = (self.last_good["snapshot_bw"] * disc)[hole]
            else:
                snap[hole] = 1.0           # the monitor's floor value
        raw = dict(raw)
        raw["snapshot_bw"] = snap
        self._remember(raw)
        return raw, None

    def measured(self, monitor: Any, conns: np.ndarray
                 ) -> Tuple[np.ndarray, bool]:
        """The engine's per-step monitor sample through the fault
        boundary. Returns ``(monitored, ok)`` — ``ok=False`` flags a
        frozen (outage) sample so the lifecycle drift detector skips
        the tick instead of learning from a fossil measurement."""
        if self.monitor_dark(self.step) and self.last_measure is not None:
            self._m_outage.inc()
            return np.array(self.last_measure, copy=True), False
        m = monitor.measure(conns)
        self.last_measure = np.array(m, copy=True)
        return m, True

    def predicted(self, pred: np.ndarray,
                  snapshot: np.ndarray) -> np.ndarray:
        """The controller's post-prediction hook: inject any scripted
        predictor fault (both modes), then — graceful only — rung 4:
        quarantine non-finite / negative / outlier entries, backfilled
        from the last finite prediction (or the snapshot floor)."""
        pred = np.array(pred, np.float64, copy=True)
        if self.predictor_failing(self.step):
            k = min(self._pred_rows, self.N)
            rows = self.rng.choice(self.N, size=k, replace=False)
            if self._pred_kind == "nan":
                pred[rows, :] = np.nan
            else:
                pred[rows, :] *= self.rng.lognormal(4.0, 1.0,
                                                    (k, 1))
        if self.graceful:
            pred = self.sanitize_matrix(pred, snapshot,
                                        reference=self.last_pred)
            self.last_pred = np.array(pred, copy=True)
        return pred

    def sanitize_matrix(self, pred: np.ndarray, snapshot: np.ndarray,
                        reference: Optional[np.ndarray] = None
                        ) -> np.ndarray:
        """Rung 4, stateless form (the fleet uses this per job):
        replace non-finite, negative, or outlier entries (beyond
        ``outlier_factor`` x the reference) with the reference value —
        the last finite prediction, else the snapshot clamped to the
        monitor's 1 Mbps floor."""
        pred = np.array(pred, np.float64, copy=True)
        ref = reference if reference is not None \
            else np.maximum(np.asarray(snapshot, np.float64), 1.0)
        bad = (~np.isfinite(pred)) | (pred < 0.0) \
            | (pred > self.cfg.outlier_factor * np.maximum(ref, 1.0))
        if bad.any():
            self._m_rows.inc(len(np.unique(np.argwhere(bad)[:, 0])))
            pred[bad] = ref[bad]
        return pred

    def note_rollback(self) -> None:
        """Count a last-known-good plan rollback (rung 5)."""
        self._m_rollbacks.inc()

    # ------------------------------------------------------------------
    @property
    def rollbacks(self) -> int:
        """Plan rollbacks performed (registry-backed alias)."""
        return int(self._m_rollbacks.value)

    @property
    def retry_usd(self) -> float:
        """Eq. 1 dollars spent on capture retries (registry-backed)."""
        return float(self._m_retry_usd.value)
