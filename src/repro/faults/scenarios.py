"""Chaos scenario library — scripted fault timelines with metadata.

A separate registry from :mod:`repro.scenarios.library` on purpose:
the named scenarios there feed the golden-trace collection, and chaos
timelines are *meant* to be run twice — graceful (``faults="on"``) vs
the naive-crash ablation (``faults="off"``; the scripted fault events
still build a plane, just an ungraceful one).

Each entry carries the metadata the harness needs to score recovery:

  * ``fault_steps`` — the injection steps MTTR is measured from;
  * ``eval_from``   — first step of the degraded-floor evaluation
    window (after warmup, so init-transient floors don't count);
  * ``dead_steps``  — steps where progress was *impossible* (a
    blacked-out ring hop carries zero BW for every controller),
    excluded from the degraded-floor minimum;
  * ``fleet``       — the spec is a FleetScenarioSpec (fleet harness
    path) rather than a single-job ScenarioSpec.

All chaos scenarios run the QUIET simulator (no fluctuation /
observation noise): every floor excursion in the trace is the fault —
or the recovery — and nothing else.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Tuple

from repro.faults.events import (DcBlackout, DcRestore, MonitorOutage,
                                 NetworkPartition, PartitionHeal,
                                 PredictorFault, ProbeTimeout,
                                 SolverFault, chaos_schedule)
from repro.fleet.controller import JobSpec
from repro.fleet.scenario import FleetScenarioSpec
from repro.scenarios.engine import ScenarioSpec
from repro.scenarios.events import LinkDegrade, LinkRestore, at

QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0)


@dataclass
class ChaosSpec:
    """One chaos scenario + the recovery-scoring metadata."""

    spec: Any                               # ScenarioSpec | FleetScenarioSpec
    fault_steps: Tuple[int, ...]            # injections MTTR keys on
    eval_from: int = 0                      # degraded-floor window start
    dead_steps: Tuple[int, ...] = ()        # progress-impossible steps
    fleet: bool = False
    naive_crashes: bool = False             # the off/naive run MUST die


def probe_blackhole() -> ChaosSpec:
    """Probes time out exactly while a ring hop silently degrades: the
    naive loop dies at the first in-window replan; the ladder replans
    from the discounted last-good capture and recovers when probes
    return."""
    spec = ScenarioSpec(
        name="probe_blackhole", steps=30,
        description="probe timeouts (steps 8-18) across a silent "
                    "us-east<->us-west degrade at step 10",
        events=(at(8, ProbeTimeout(10)),
                at(10, LinkDegrade(("us-east", "us-west"), 0.3)),
                at(20, LinkRestore(("us-east", "us-west"))),),
        sim_kwargs=dict(QUIET), cfg_kwargs=dict(replan_every=5))
    return ChaosSpec(spec, fault_steps=(8, 10), eval_from=4,
                     naive_crashes=True)


def monitor_freeze() -> ChaosSpec:
    """The monitoring pipeline freezes, then the WAN shifts under the
    frozen readings: the ladder plans on discounted stale data (and
    past max_stale_steps, on the snapshot rung) until the monitor
    thaws."""
    spec = ScenarioSpec(
        name="monitor_freeze", steps=34,
        description="monitor dark steps 8-20; a silent degrade at 12 "
                    "happens entirely inside the outage",
        events=(at(8, MonitorOutage(12)),
                at(12, LinkDegrade(("us-east", "ap-south"), 0.4)),
                at(22, LinkRestore(("us-east", "ap-south"))),),
        sim_kwargs=dict(QUIET), cfg_kwargs=dict(replan_every=5))
    return ChaosSpec(spec, fault_steps=(8, 12), eval_from=4)


def dc_blackout() -> ChaosSpec:
    """A ring DC blacks out AND probes time out (the realistic pair:
    the dead DC is why the probes hang). Progress over the dead hop is
    impossible for everyone — those steps are excluded from the floor;
    the score is how fast each mode recovers after restore."""
    spec = ScenarioSpec(
        name="dc_blackout", steps=30,
        description="ap-se blacks out steps 10-18 with probe timeouts; "
                    "restore at 18",
        events=(at(10, DcBlackout("ap-se")),
                at(10, ProbeTimeout(6)),
                at(18, DcRestore("ap-se")),),
        sim_kwargs=dict(QUIET), cfg_kwargs=dict(replan_every=5))
    return ChaosSpec(spec, fault_steps=(10,), eval_from=4,
                     dead_steps=tuple(range(10, 18)),
                     naive_crashes=True)


def predictor_poison() -> ChaosSpec:
    """The RF emits NaN rows for six steps: naive planning feeds NaN
    into the optimizer (collapsed/garbage plans); the ladder
    quarantines the poisoned rows and keeps the floor."""
    spec = ScenarioSpec(
        name="predictor_poison", steps=30,
        description="NaN predictor rows, steps 10-16",
        events=(at(10, PredictorFault(6, kind="nan", rows=2)),),
        sim_kwargs=dict(QUIET), cfg_kwargs=dict(replan_every=5))
    return ChaosSpec(spec, fault_steps=(10,), eval_from=4)


def partition() -> ChaosSpec:
    """The mesh partitions across the ring (us links | ap links): both
    cross-group ring hops die. Floor scoring excludes the partitioned
    window; recovery speed after heal is the score."""
    spec = ScenarioSpec(
        name="partition", steps=30,
        description="(us-east,us-west) | (ap-south,ap-se) partition, "
                    "steps 10-18",
        events=(at(10, NetworkPartition((("us-east", "us-west"),
                                         ("ap-south", "ap-se")))),
                at(18, PartitionHeal()),),
        sim_kwargs=dict(QUIET), cfg_kwargs=dict(replan_every=5))
    return ChaosSpec(spec, fault_steps=(10,), eval_from=4,
                     dead_steps=tuple(range(10, 18)))


def solver_flake() -> ChaosSpec:
    """The water-fill diverges for two steps: naive crashes at step
    12; graceful rolls back to the last-known-good plan (a plan-cache
    hit) and rides it out."""
    spec = ScenarioSpec(
        name="solver_flake", steps=26,
        description="injected water-fill divergence, steps 12-13",
        events=(at(12, SolverFault(2)),),
        sim_kwargs=dict(QUIET), cfg_kwargs=dict(replan_every=5))
    return ChaosSpec(spec, fault_steps=(12,), eval_from=4,
                     naive_crashes=True)


def chaos_storm() -> ChaosSpec:
    """A seeded storm from :func:`chaos_schedule` — whatever it draws,
    the graceful loop must survive with zero uncaught exceptions."""
    events = tuple(chaos_schedule(seed=7, steps=40,
                                  regions=["ap-se2", "ap-ne"]))
    spec = ScenarioSpec(
        name="chaos_storm", steps=40,
        description="seeded multi-fault storm (chaos_schedule seed 7)",
        events=events,
        sim_kwargs=dict(QUIET), cfg_kwargs=dict(replan_every=5))
    return ChaosSpec(spec, fault_steps=tuple(sorted({t.step
                                                     for t in events})),
                     eval_from=4)


def fleet_blackout() -> ChaosSpec:
    """Fleet quarantine: two disjoint jobs share the mesh; ap-se (in
    the serving job's slice only) blacks out for four ticks. The
    arbiter quarantines the dead DC — the touched job's envelope
    shrinks while the untouched batch job keeps its plan series."""
    spec = FleetScenarioSpec(
        name="fleet_blackout", steps=12,
        description="ap-se blacks out ticks 4-8 under a 2-job fleet "
                    "with disjoint slices",
        jobs=(JobSpec("serving", dcs=(0, 1, 2, 3), priority=2.0),
              JobSpec("batch", dcs=(4, 5, 6, 7), priority=1.0)),
        events=(at(4, DcBlackout("ap-se")),
                at(8, DcRestore("ap-se")),),
        sim_kwargs=dict(QUIET))
    return ChaosSpec(spec, fault_steps=(4,), eval_from=1,
                     dead_steps=tuple(range(4, 8)), fleet=True)


CHAOS_SCENARIOS: Dict[str, Callable[[], ChaosSpec]] = {
    "probe_blackhole": probe_blackhole,
    "monitor_freeze": monitor_freeze,
    "dc_blackout": dc_blackout,
    "predictor_poison": predictor_poison,
    "partition": partition,
    "solver_flake": solver_flake,
    "chaos_storm": chaos_storm,
    "fleet_blackout": fleet_blackout,
}


def get_chaos_scenario(name: str) -> ChaosSpec:
    """Fresh ChaosSpec by name (KeyError lists the known names)."""
    if name not in CHAOS_SCENARIOS:
        raise KeyError(f"unknown chaos scenario {name!r}; "
                       f"have {sorted(CHAOS_SCENARIOS)}")
    return CHAOS_SCENARIOS[name]()


def chaos_scenario_names() -> List[str]:
    """All named chaos scenarios, library order."""
    return list(CHAOS_SCENARIOS)
