"""Chaos harness — graceful ladder vs naive-crash ablation, scored.

For each chaos scenario the harness runs the SAME timeline twice:

  * ``mode="ladder"`` — ``faults="on"``: the graceful degradation
    ladder (retry, bounded staleness, quarantine, plan rollback, DC
    quarantine in the fleet arbiter);
  * ``mode="naive"``  — ``faults="off"``: the scripted fault events
    still build a plane, but an UNGRACEFUL one — injections apply raw
    and the first unhandled failure kills the run, exactly like a
    deployment with no fault handling.

Every run is scored on the same three axes (exported to
``BENCH_faults.json`` by benchmarks/faults_bench.py):

  * **crashed / error** — did the run die, and with what? The ladder
    must never crash; several naive scenarios must.
  * **MTTR** — mean steps from each fault injection to the floor
    recovering to 90% of its pre-fault median (the obs
    responsiveness SLE, :func:`repro.obs.sle.fault_sle`). A crashed
    run's floor is padded with zeros to the scenario length, so its
    MTTR is censored at run end — a crash never "recovers".
  * **degraded-mode min-BW floor** — the worst per-step floor over
    the evaluation window, excluding steps where progress was
    impossible for any controller (a blacked-out ring hop). A crashed
    run's padded zeros land here as a 0.0 floor.

The floor series is collected through the engines' ``step_hook`` so
it survives a mid-run crash: every step that completed before the
death still counts.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

from repro.faults.scenarios import CHAOS_SCENARIOS, get_chaos_scenario
from repro.fleet.scenario import FleetEngine
from repro.obs.sle import fault_sle
from repro.scenarios.engine import ScenarioEngine


def run_chaos(name: str, seed: int = 3,
              graceful: bool = True) -> Dict[str, Any]:
    """Run one chaos scenario end to end and score it.

    Returns ``{scenario, mode, crashed, error, steps_completed,
    mttr_steps, degraded_min_bw, injected, rollbacks, retry_usd}``.
    """
    chaos = get_chaos_scenario(name)
    mode = "on" if graceful else "off"
    floor: List[float] = []
    if chaos.fleet:
        eng: Any = FleetEngine(chaos.spec, seed=seed, faults=mode)

        def hook(_eng, row):
            floor.append(min((r["achieved_min"] for r in row.jobs),
                             default=0.0))
    else:
        eng = ScenarioEngine(chaos.spec, seed=seed, faults=mode)

        def hook(_eng, row):
            floor.append(float(row.achieved_min))
    eng.step_hook = hook
    crashed, error = False, None
    try:
        eng.run()
    except Exception as exc:                # noqa: BLE001 — the naive
        # ablation dies by DESIGN; the harness's job is to record how
        crashed, error = True, f"{type(exc).__name__}: {exc}"
    completed = len(floor)
    # a crashed run made zero progress from its death onward: pad the
    # floor with zeros so MTTR/degraded-floor score the crash honestly
    padded = floor + [0.0] * (chaos.spec.steps - completed)
    sle = fault_sle(padded, chaos.fault_steps,
                    dead_steps=chaos.dead_steps)
    plane = eng.faults
    injected = 0
    if plane is not None:
        injected = int(sum(v for k, v in plane.metrics.counters().items()
                           if k.startswith("injected")))
    return {
        "scenario": name,
        "mode": "ladder" if graceful else "naive",
        "crashed": crashed,
        "error": error,
        "steps_completed": completed,
        "steps_total": int(chaos.spec.steps),
        "mttr_steps": sle["mttr_steps"],
        "degraded_min_bw": sle["degraded_min_bw"],
        "injected": injected,
        "rollbacks": plane.rollbacks if plane is not None else 0,
        "retry_usd": round(plane.retry_usd, 6) if plane is not None
        else 0.0,
    }


def chaos_report(names: Optional[Sequence[str]] = None,
                 seed: int = 3) -> Dict[str, Any]:
    """Run the whole chaos library in both modes and roll up.

    The summary block carries the headline comparisons the CI guard
    pins: the ladder's crash count (must be 0), the naive crash count
    (must be > 0 — the ablation is only meaningful if naive actually
    dies), and mean MTTR / worst degraded floor per mode."""
    names = list(names) if names is not None else list(CHAOS_SCENARIOS)
    rows = []
    for n in names:
        rows.append(run_chaos(n, seed=seed, graceful=True))
        rows.append(run_chaos(n, seed=seed, graceful=False))
    ladder = [r for r in rows if r["mode"] == "ladder"]
    naive = [r for r in rows if r["mode"] == "naive"]

    def _mean_mttr(rs):
        vals = [r["mttr_steps"] for r in rs if r["mttr_steps"] is not None]
        return round(sum(vals) / len(vals), 3) if vals else None

    summary = {
        "scenarios": len(names),
        "ladder_crashes": sum(r["crashed"] for r in ladder),
        "naive_crashes": sum(r["crashed"] for r in naive),
        "ladder_mean_mttr": _mean_mttr(ladder),
        "naive_mean_mttr": _mean_mttr(naive),
        "ladder_min_floor": round(min(r["degraded_min_bw"]
                                      for r in ladder), 6),
        "naive_min_floor": round(min(r["degraded_min_bw"]
                                     for r in naive), 6),
    }
    return {"seed": seed, "runs": rows, "summary": summary}
