"""Fault events for scenario timelines (repro.faults).

These extend the scenarios DSL (:mod:`repro.scenarios.events`) with
control-plane failures. Unlike `LinkDegrade` — which models a SLOW
link — these model BROKEN components: a blacked-out DC carries zero
bandwidth on every touching link, a partition makes whole groups
mutually unreachable, a probe fault makes the measurement pipeline
itself fail.

Every fault event routes through the engine's
:class:`~repro.faults.plane.FaultPlane` (`eng.faults`); an engine
whose timeline scripts a fault event constructs a plane automatically
even under ``REPRO_FAULTS=off`` — an *ungraceful* one, so the off gate
doubles as the naive-crash ablation the chaos harness compares
against. Timelines without fault events under the off gate get no
plane at all and replay byte-identical.

:func:`chaos_schedule` composes a deterministic storm of these events
from a seed, for soak-style chaos scenarios.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.scenarios.events import Event, Timed, at

__all__ = ["FaultEvent", "DcBlackout", "DcRestore", "NetworkPartition",
           "PartitionHeal", "ProbeTimeout", "ProbeLoss", "MonitorOutage",
           "PredictorFault", "SolverFault", "FLEET_FAULT_EVENTS",
           "chaos_schedule"]


@dataclass(frozen=True)
class FaultEvent(Event):
    """Base of all fault events: resolves the engine's fault plane."""

    def _plane(self, eng):
        if getattr(eng, "faults", None) is None:
            raise RuntimeError(
                f"{type(self).__name__} scripted but the engine has no "
                f"fault plane — construct it with faults='on'/'off' or "
                f"let the engine auto-detect fault events")
        return eng.faults


# ----------------------------------------------------------------------
# Reachability faults (also valid on fleet timelines)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class DcBlackout(FaultEvent):
    """Full-node loss: every link touching `region` goes unreachable
    (zero BW, not merely low) until :class:`DcRestore`."""
    region: str

    def apply(self, eng) -> None:
        """Execute against the engine."""
        plane = self._plane(eng)
        plane.blackout(eng.dc(self.region))
        plane.apply_reachability(eng.sim)


@dataclass(frozen=True)
class DcRestore(FaultEvent):
    """Bring a blacked-out DC back online."""
    region: str

    def apply(self, eng) -> None:
        """Execute against the engine."""
        plane = self._plane(eng)
        plane.restore(eng.dc(self.region))
        plane.apply_reachability(eng.sim)


@dataclass(frozen=True)
class NetworkPartition(FaultEvent):
    """Partition the WAN: regions in different `groups` cannot reach
    each other (a reachability mask, not just low BW); regions named
    in no group keep full connectivity."""
    groups: Tuple[Tuple[str, ...], ...]

    def apply(self, eng) -> None:
        """Execute against the engine."""
        plane = self._plane(eng)
        plane.set_partition([[eng.dc(r) for r in g]
                             for g in self.groups])
        plane.apply_reachability(eng.sim)


@dataclass(frozen=True)
class PartitionHeal(FaultEvent):
    """Heal the partition (blackouts, if any, stay in force)."""

    def apply(self, eng) -> None:
        """Execute against the engine."""
        plane = self._plane(eng)
        plane.heal_partition()
        plane.apply_reachability(eng.sim)


# ----------------------------------------------------------------------
# Control-plane faults (single-job engine)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ProbeTimeout(FaultEvent):
    """Replan-time snapshot captures time out for `duration` steps.
    Naive mode dies with :class:`~repro.faults.plane.ProbeTimeoutError`
    at the next replan; graceful mode climbs the retry/staleness
    ladder."""
    duration: int

    def apply(self, eng) -> None:
        """Execute against the engine."""
        self._plane(eng).probe_fault("timeout", self.duration)


@dataclass(frozen=True)
class ProbeLoss(FaultEvent):
    """Each capture attempt loses a `frac` subset of pairs for
    `duration` steps (naive: NaN holes flow into the predictor)."""
    duration: int
    frac: float = 0.5

    def apply(self, eng) -> None:
        """Execute against the engine."""
        self._plane(eng).probe_fault("loss", self.duration, self.frac)


@dataclass(frozen=True)
class MonitorOutage(FaultEvent):
    """The monitoring pipeline freezes for `duration` steps: every
    measurement repeats the last pre-outage value with a rising age."""
    duration: int

    def apply(self, eng) -> None:
        """Execute against the engine."""
        self._plane(eng).monitor_outage(self.duration)


@dataclass(frozen=True)
class PredictorFault(FaultEvent):
    """The RF emits poisoned rows (`kind`: ``"nan"`` or ``"garbage"``)
    for `duration` steps, `rows` rows per replan."""
    duration: int
    kind: str = "nan"
    rows: int = 2

    def apply(self, eng) -> None:
        """Execute against the engine."""
        self._plane(eng).predictor_fault(self.duration, self.kind,
                                         self.rows)


@dataclass(frozen=True)
class SolverFault(FaultEvent):
    """The engine's water-fill diverges for `duration` steps (raises
    :class:`~repro.wan.simulator.WaterfillDivergence`); graceful mode
    rolls back to the last-known-good plan instead of crashing."""
    duration: int = 1

    def apply(self, eng) -> None:
        """Execute against the engine."""
        self._plane(eng).solver_fault(self.duration)


# reachability faults are job-agnostic WAN state, so fleet timelines
# accept them (repro.fleet.scenario extends FLEET_EVENTS with these)
FLEET_FAULT_EVENTS = (DcBlackout, DcRestore, NetworkPartition,
                      PartitionHeal)

_CHAOS_STREAM = 0xC4A05


def chaos_schedule(seed: int, steps: int,
                   regions: Optional[Sequence[str]] = None,
                   n_faults: int = 4,
                   kinds: Optional[Sequence[str]] = None) -> List[Timed]:
    """Compose a deterministic fault storm from a seed.

    Draws `n_faults` (kind, step, duration) triples from a dedicated
    stream — same seed, same storm, independent of the simulator's
    named streams. Fault starts land in ``[steps//8, 3*steps//4)`` so
    the loop has a warm baseline before the first hit and room to
    recover after the last; reachability faults get a paired restore.
    `regions` supplies DcBlackout targets (omit it to skip blackout
    faults)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([_CHAOS_STREAM, int(seed)]))
    pool = list(kinds) if kinds is not None else \
        ["probe_timeout", "probe_loss", "monitor_outage",
         "predictor_fault", "solver"] + (["blackout"] if regions else [])
    lo, hi = max(steps // 8, 1), max(3 * steps // 4, 2)
    timeline: List[Timed] = []
    for _ in range(int(n_faults)):
        kind = pool[int(rng.integers(len(pool)))]
        start = int(rng.integers(lo, hi))
        dur = int(rng.integers(2, max(steps // 6, 3)))
        if kind == "blackout":
            region = regions[int(rng.integers(len(regions)))]
            timeline.append(at(start, DcBlackout(region)))
            timeline.append(at(min(start + dur, steps - 1),
                               DcRestore(region)))
        elif kind == "probe_timeout":
            timeline.append(at(start, ProbeTimeout(dur)))
        elif kind == "probe_loss":
            timeline.append(at(start, ProbeLoss(dur)))
        elif kind == "monitor_outage":
            timeline.append(at(start, MonitorOutage(dur)))
        elif kind == "predictor_fault":
            timeline.append(at(start, PredictorFault(dur)))
        elif kind == "solver":
            timeline.append(at(start, SolverFault(min(dur, 2))))
        else:                                    # pragma: no cover
            raise ValueError(f"unknown chaos kind {kind!r}")
    timeline.sort(key=lambda t: t.step)
    return timeline
