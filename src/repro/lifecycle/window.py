"""Sliding-window state for the online predictor lifecycle.

Two consumers share the window idea:

  * :class:`SlidingWindow` — the refresh layer's training buffer: live
    ``(features, achieved_bw)`` rows harvested from the traffic the
    controller already serves (iftop-style observation of the
    workload's own transfers is free — no probe traffic, the paper's
    §1 cost axis), bounded to the newest ``capacity`` rows.
  * :class:`WindowedPercentileEstimator` — the cloudgenix
    95th-percentile-over-PCM approach (SNIPPETS.md §1): per-pair
    capacity as a percentile of the last W achieved-BW samples. No ML,
    a few hundred floats of state — the fallback estimator when no
    forest is available, and a sanity clamp on RF outputs (a refreshed
    forest mid-drift must not promise BW the link has never shown).
"""
from __future__ import annotations

from collections import deque
from typing import Optional, Tuple

import numpy as np


class SlidingWindow:
    """FIFO row buffer of (X [n,F], y [n]) harvest chunks, trimmed to
    the newest `capacity` rows (oldest rows fall off chunk by chunk,
    partially when a chunk straddles the boundary)."""

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._chunks: deque = deque()
        self.n_rows = 0

    def push(self, X: np.ndarray, y: np.ndarray) -> None:
        """Append one harvest chunk (rows are kept newest-first at the
        tail; the head is trimmed down to `capacity` total rows)."""
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float32).reshape(-1)
        if X.shape[0] != y.shape[0]:
            raise ValueError(f"X rows {X.shape[0]} != y rows {y.shape[0]}")
        self._chunks.append((X, y))
        self.n_rows += len(y)
        while self.n_rows > self.capacity:
            cx, cy = self._chunks.popleft()
            excess = self.n_rows - self.capacity
            if len(cy) <= excess:
                self.n_rows -= len(cy)
            else:
                self._chunks.appendleft((cx[excess:], cy[excess:]))
                self.n_rows -= excess
        assert self.n_rows <= self.capacity

    def clear(self) -> None:
        """Drop every buffered row (drift invalidates the harvest: rows
        observed before the signal describe the regime that died)."""
        self._chunks.clear()
        self.n_rows = 0

    def rows(self) -> Tuple[np.ndarray, np.ndarray]:
        """The buffered (X, y), oldest row first (empty arrays when no
        harvest has landed yet)."""
        if not self._chunks:
            return np.zeros((0, 6), np.float32), np.zeros(0, np.float32)
        return (np.concatenate([c[0] for c in self._chunks]),
                np.concatenate([c[1] for c in self._chunks]))


class WindowedPercentileEstimator:
    """Per-pair q-th-percentile capacity over the last `window`
    achieved-BW samples (linear-interpolation percentile, so the
    output always lies within the window's per-pair data range and is
    monotone in q — both pinned by hypothesis properties)."""

    def __init__(self, shape: Tuple[int, ...], window: int = 16,
                 q: float = 95.0):
        if window <= 0:
            raise ValueError(f"window must be positive, got {window}")
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"q must be in [0, 100], got {q}")
        self.shape = tuple(shape)
        self.window = int(window)
        self.q = float(q)
        self._buf: deque = deque(maxlen=self.window)

    @property
    def n_samples(self) -> int:
        """Samples currently in the window (<= `window`)."""
        return len(self._buf)

    def push(self, sample: np.ndarray) -> None:
        """Add one achieved-BW sample (oldest rolls off at capacity)."""
        s = np.asarray(sample, np.float64).reshape(self.shape)
        self._buf.append(s.copy())

    def capacity(self, q: Optional[float] = None) -> Optional[np.ndarray]:
        """The per-pair percentile over the window (None before any
        sample has been pushed)."""
        if not self._buf:
            return None
        stack = np.stack(list(self._buf))
        return np.percentile(stack, self.q if q is None else float(q),
                             axis=0)

    def clamp_matrix(self, pred: np.ndarray, headroom: float = 1.5,
                     floor: float = 1.0) -> np.ndarray:
        """Sanity-clamp an RF prediction matrix: no off-diagonal pair
        may promise more than ``headroom`` x its windowed percentile
        capacity (the diagonal — intra-DC BW — is never touched, and
        with an empty window the prediction passes through unchanged).
        """
        cap = self.capacity()
        out = np.asarray(pred, np.float64).copy()
        if cap is None:
            return out
        limit = np.maximum(headroom * cap, floor)
        off = ~np.eye(out.shape[0], dtype=bool)
        out[off] = np.minimum(out[off], limit[off])
        return out
