"""Lifecycle evaluation harness: pretrain-from-live-traffic plus the
frozen-vs-lifecycle comparison the bench and the pin test share.

The deployment story being reproduced: a predictor is fit on harvest
from the workload's own pre-shift operation (accurate, by
construction, for the regime it watched), the provider then migrates
half the DCs to half the WAN capacity, and the question is what the
operator pays — a frozen predictor plus Tetrium's periodic full
probing, or the lifecycle layer that detects the drift from free
residuals, spends a few targeted probes, and refits.

Imports of :mod:`repro.scenarios` stay inside the functions — the
scenario engine imports this package's manager module, and the lazy
import keeps the package graph acyclic.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core.forest import RandomForest
from repro.core.predictor import BwPredictor
from repro.lifecycle.manager import LifecycleConfig, LifecycleManager
from repro.lifecycle.probes import baseline_probe_spend


def harvest_scenario_rows(spec: Any, seed: int = 0,
                          steps: Optional[int] = None
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """Run `spec` for its first `steps` steps (default: all) with the
    snapshot-ablation predictor and lifecycle off, harvesting one
    (Table-3 features, achieved BW) row set per tick via a shadow
    manager — the live-traffic training set a deployed predictor
    starts from. Returns (X [rows, 6], y [rows])."""
    from repro.scenarios.engine import ScenarioEngine
    run_spec = spec if steps is None \
        else dataclasses.replace(spec, steps=int(steps))
    eng = ScenarioEngine(run_spec, seed=seed)
    n = eng.sim.N
    cap = max(1, run_spec.steps) * n * (n - 1)
    mgr = LifecycleManager(eng.controller.predictor, n, active=False,
                           cfg=LifecycleConfig(window_rows=cap))
    eng.lifecycle = mgr
    eng.run()
    return mgr.window.rows()


def pretrain_predictor(spec: Any, seed: int = 0, pre_steps: int = 15,
                       n_trees: int = 12, depth: int = 8,
                       min_leaf: int = 4, forest_seed: int = 0
                       ) -> Tuple[BwPredictor, np.ndarray, np.ndarray]:
    """A :class:`BwPredictor` fit on the scenario's own pre-event
    operation (steps [0, pre_steps)) — deterministic: the same (spec,
    seed, hyperparameters) always yields bit-identical packed tensors.
    `min_leaf` > 1 matters under noisy snapshots: leaves average
    several observations instead of memorizing one noise draw.
    Returns (predictor, seed_X, seed_y); the rows double as the
    refresh layer's decaying seed set."""
    X, y = harvest_scenario_rows(spec, seed=seed, steps=pre_steps)
    rf = RandomForest(n_trees=n_trees, depth=depth, min_leaf=min_leaf,
                      seed=forest_seed).fit(X, y)
    return BwPredictor(forest=rf), X, y


def run_lifecycle_comparison(scenario: str = "provider_shift_drift",
                             seed: int = 3, pre_steps: int = 15,
                             cfg: Optional[LifecycleConfig] = None
                             ) -> Dict[str, Any]:
    """Run `scenario` twice from the same pretrained predictor — once
    frozen (shadow manager: observe + account only), once with the
    full lifecycle — and return the comparison the headline pin
    asserts on:

      * per-step ``resid`` accuracy series (un-gated EWMA of mean
        |relative residual|) for both modes;
      * ``monitor_usd`` per mode, the frozen side priced as snapshots
        plus Tetrium's 30-simulated-minute full-probe cadence, the
        lifecycle side as snapshots plus its drift-gated probes;
      * the lifecycle run's refresh/probe/signal telemetry.
    """
    from repro.scenarios.engine import ScenarioEngine
    from repro.scenarios.library import get_scenario

    out: Dict[str, Any] = {"scenario": scenario, "seed": int(seed),
                           "pre_steps": int(pre_steps)}
    modes: Dict[str, Dict[str, Any]] = {}
    for mode in ("frozen", "lifecycle"):
        spec = get_scenario(scenario)
        # an independently pretrained (bit-identical) predictor per
        # run: the lifecycle run's refresh must not leak into frozen
        predictor, sX, sy = pretrain_predictor(spec, seed=seed,
                                               pre_steps=pre_steps)
        mgr = LifecycleManager(predictor, len(spec.regions)
                               if spec.regions else 8,
                               seed_X=sX, seed_y=sy, cfg=cfg,
                               active=(mode == "lifecycle"))
        eng = ScenarioEngine(spec, seed=seed, predictor=predictor,
                             lifecycle=mgr)
        result = eng.run()
        usd = mgr.scheduler.spend_usd
        if mode == "frozen":
            usd += baseline_probe_spend(spec.steps, eng.sim.N,
                                        mgr.cfg.probes)
        modes[mode] = {
            "resid": [r.resid_ewma for r in mgr.records],
            "monitor_usd": float(usd),
            "full_probes": mgr.scheduler.full_probes,
            "snapshots": mgr.scheduler.snapshots,
            "refreshes": mgr.refreshes,
            "refresh_steps": [r.step for r in mgr.records if r.refreshed],
            "signal_steps": sorted({s.step for s in mgr.signals}),
            "steps": spec.steps,
            "trace_sha": hashlib.sha256(
                result.trace.to_json().encode()).hexdigest(),
        }
    out["modes"] = modes
    return out
