"""LifecycleManager — the online predictor lifecycle as one subsystem.

Orchestrates the four lifecycle pieces per engine tick, off the hot
path (the controller calls back only for the prediction clamp):

  1. **observe** (free): the per-pair relative residual between the BW
     the workload actually achieved and what the predictor implies for
     the current snapshot — iftop-style observation of served traffic,
     no probe traffic;
  2. **detect**: feed the residual matrix to the EWMA drift detector
     (:mod:`repro.lifecycle.drift`) and the un-gated accuracy EWMA;
  3. **probe** (priced): while drift is suspected, spend a full
     >=20-second runtime probe (Eq. 1 dollars, cooldown-limited) to put
     clean labels in the harvest window;
  4. **refresh**: a :class:`DriftSignal` opens a collection phase — the
     window is cleared (pre-signal harvest describes the regime that
     died) and once enough fresh rows accumulate the forest is refit on
     decayed-seed ∪ window (:mod:`repro.lifecycle.refresh`), swapped
     into the predictor with one reference assignment, the detector
     re-baselined, and an immediate ``reason="lifecycle"`` replan
     issued.

Gating mirrors the overlay layer: ``lifecycle_mode()`` resolves an
explicit argument, then ``$REPRO_LIFECYCLE``, then ``off`` — and off
means NO manager exists and no lifecycle code runs, keeping every
historical trace golden byte-identical. ``active=False`` builds a
*shadow* manager: it observes, detects and accounts snapshot spend
(the frozen-predictor baseline the bench compares against) but never
clamps, probes, or refreshes — the workload replays exactly as with no
manager at all.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, List, Optional

import numpy as np

from repro.core.predictor import assemble_features
from repro.lifecycle.drift import (DriftConfig, DriftSignal,
                                   EwmaDriftDetector, ResidualStats)
from repro.obs.registry import MetricsRegistry
from repro.lifecycle.probes import ProbeConfig, ProbeScheduler
from repro.lifecycle.refresh import RefreshConfig, refresh_forest
from repro.lifecycle.window import (SlidingWindow,
                                    WindowedPercentileEstimator)

LIFECYCLE_MODES = ("off", "on")


def lifecycle_mode(mode: Optional[str] = None) -> str:
    """Resolve the lifecycle gate: an explicit argument wins, then the
    ``REPRO_LIFECYCLE`` environment variable, then ``off`` (the
    byte-identical historical path)."""
    m = mode or os.environ.get("REPRO_LIFECYCLE", "off")
    if m not in LIFECYCLE_MODES:
        raise ValueError(f"unknown lifecycle mode {m!r}; "
                         f"expected one of {LIFECYCLE_MODES}")
    return m


@dataclass
class LifecycleConfig:
    """Knobs of the full lifecycle loop (sub-configs per piece)."""

    drift: DriftConfig = field(default_factory=DriftConfig)
    refresh: RefreshConfig = field(default_factory=RefreshConfig)
    probes: ProbeConfig = field(default_factory=ProbeConfig)
    window_rows: int = 1024      # harvest-window capacity (rows)
    percentile_window: int = 16  # capacity-estimator sample window
    percentile_q: float = 95.0   # cloudgenix-style capacity percentile
    clamp_headroom: float = 1.5  # RF may promise <= headroom x capacity
    resid_alpha: float = 0.4     # accuracy-EWMA smoothing


@dataclass(frozen=True)
class LifecycleRecord:
    """One tick of lifecycle telemetry (the bench/test series)."""

    step: int
    resid_ewma: float            # un-gated EWMA of mean |residual|
    z_max: float                 # worst standardized residual this tick
    consec_max: int              # longest live suspicious streak
    suspicious: bool             # any pair's streak live this tick
    full_probe: bool             # a full 20 s probe fired this tick
    refreshed: bool              # the forest was refit+swapped this tick
    spend_usd: float             # cumulative Eq. 1 monitoring dollars
    skipped: bool = False        # tick skipped (fault-plane outage: the
    #                              measurement was a frozen fossil)


class LifecycleManager:
    """One per controller. `predictor` is the SAME object the
    controller predicts with (the refresh swap must be visible to it);
    `seed_X`/`seed_y` are the rows its current forest was trained on
    (decayed into every refit). ``active=False`` = shadow mode."""

    def __init__(self, predictor: Any, n_dcs: int,
                 seed_X: Optional[np.ndarray] = None,
                 seed_y: Optional[np.ndarray] = None,
                 cfg: Optional[LifecycleConfig] = None,
                 active: bool = True):
        self.predictor = predictor
        self.n_dcs = int(n_dcs)
        self.cfg = cfg or LifecycleConfig()
        self.active = bool(active)
        self.seed_X = None if seed_X is None \
            else np.asarray(seed_X, np.float32)
        self.seed_y = None if seed_y is None \
            else np.asarray(seed_y, np.float32).reshape(-1)
        shape = (self.n_dcs, self.n_dcs)
        self.detector = EwmaDriftDetector(shape, self.cfg.drift)
        self.stats = ResidualStats(alpha=self.cfg.resid_alpha)
        self.window = SlidingWindow(self.cfg.window_rows)
        self.estimator = WindowedPercentileEstimator(
            shape, window=self.cfg.percentile_window,
            q=self.cfg.percentile_q)
        self.scheduler = ProbeScheduler(self.n_dcs, self.cfg.probes)
        self.records: List[LifecycleRecord] = []
        self.signals: List[DriftSignal] = []
        # lifecycle tallies on the obs registry (`refreshes` stays
        # readable as a back-compat property)
        self.metrics = MetricsRegistry("lifecycle")
        self._m_refreshes = self.metrics.counter(
            "refreshes", help="forest refits swapped in")
        self._m_signals = self.metrics.counter(
            "drift_signals", help="drift signals raised")
        self._m_ticks = self.metrics.counter(
            "ticks", help="lifecycle iterations run")
        self._last_refresh: Optional[int] = None
        self._drift_pending: Optional[int] = None   # step of open signal
        self._seen_records = 0

    # ------------------------------------------------------------------
    @property
    def refreshes(self) -> int:
        """Forest refits swapped in (registry-backed alias)."""
        return int(self._m_refreshes.value)

    def can_refresh(self) -> bool:
        """True when the predictor carries a fitted, swappable forest
        (the SnapshotPredictor ablation has none — the manager then
        detects and probes but never refits)."""
        rf = getattr(self.predictor, "forest", None)
        return rf is not None and getattr(rf, "feat", None) is not None

    def adjust_prediction(self, pred: np.ndarray) -> np.ndarray:
        """The controller's replan hook: sanity-clamp the predicted-BW
        matrix against the windowed percentile capacity (pass-through
        in shadow mode or before any sample has been observed)."""
        if not self.active:
            return np.asarray(pred, np.float64)
        return self.estimator.clamp_matrix(
            pred, headroom=self.cfg.clamp_headroom)

    # ------------------------------------------------------------------
    def tick(self, step: int, ctl: Any, sim: Any, conns: np.ndarray,
             achieved: np.ndarray,
             monitored: np.ndarray,
             measurement_ok: bool = True) -> LifecycleRecord:
        """One lifecycle iteration, called by the scenario engine after
        the step's achieved/monitored BW is known (and before the trace
        row is cut, so a lifecycle replan lands in that step's row).

        ``measurement_ok=False`` (the fault plane flags a monitor
        outage: `monitored` is a frozen fossil) skips the tick entirely
        — learning a residual against stale data would teach the drift
        detector that the PREDICTOR moved when only the monitor died."""
        N = self.n_dcs
        off = ~np.eye(N, dtype=bool)
        if not measurement_ok:
            self.metrics.counter(
                "ticks_skipped",
                help="ticks skipped on fault-plane outages").inc()
            rec = LifecycleRecord(
                step=int(step),
                resid_ewma=float(self.stats.value or 0.0),
                z_max=0.0,
                consec_max=int(self.detector.consec.max()) if N else 0,
                suspicious=self.detector.suspicious(),
                full_probe=False, refreshed=False,
                spend_usd=float(self.scheduler.spend_usd),
                skipped=True)
            self.records.append(rec)
            return rec
        achieved = np.asarray(achieved, np.float64)

        # 1. observe (free): what does the predictor say RIGHT NOW for
        # the snapshot the engine already measured, vs the BW the
        # served traffic actually achieved? Evaluating at the current
        # tick (not the last replan's stale matrix) keeps plan/AIMD
        # drift between replans out of the residual — only genuine
        # model error moves it.
        mem, cpu, retr = sim.host_metrics(conns, bw=monitored)
        pred = np.asarray(self.predictor.predict_matrix(
            N, monitored, mem, cpu, retr, sim.dist), np.float64)
        resid = np.zeros((N, N))
        resid[off] = achieved[off] / np.maximum(pred[off], 1e-9) - 1.0
        ewma = self.stats.update(resid[off])

        # 2. detect
        self._m_ticks.inc()
        sig = self.detector.update(resid, step=step)
        if sig is not None:
            self.signals.append(sig)
            self._m_signals.inc()
        suspicious = self.detector.suspicious()
        z_max = float(self.detector.last_z.max()) if N else 0.0
        consec_max = int(self.detector.consec.max()) if N else 0
        in_cooldown = (self._last_refresh is not None and
                       step - self._last_refresh
                       < self.cfg.refresh.cooldown_ticks)
        if (self.active and sig is not None and not in_cooldown
                and self._drift_pending is None and self.can_refresh()):
            # open a collection phase: everything harvested BEFORE the
            # signal describes the regime that just died — drop it and
            # refit only once enough fresh post-drift rows accumulate
            self._drift_pending = int(step)
            self.window.clear()

        # harvest: snapshot features at the in-force matrix, labeled
        # with the BW the workload actually achieved there
        X = assemble_features(N, monitored, mem, cpu, retr, sim.dist)
        self.window.push(X, achieved[off])
        self.estimator.push(achieved)

        # 3. probe: full 20 s measurement only while drift is suspected
        # (a live streak, or an open collection phase labeling the
        # refit window with clean stable-runtime rows)
        full_probe = False
        want = suspicious or self._drift_pending is not None
        if self.active and self.scheduler.want_full(step, want):
            probed = np.asarray(ctl.monitor.probe(conns), np.float64)
            self.scheduler.charge_full(step)
            self.window.push(X, probed[off])
            full_probe = True

        # 4. refresh: refit + atomic swap + re-baseline + replan
        refreshed = False
        if (self.active and self._drift_pending is not None
                and self.window.n_rows >= self.cfg.refresh.min_rows):
            wX, wy = self.window.rows()
            new_rf = refresh_forest(self.predictor.forest, wX, wy,
                                    self.seed_X, self.seed_y,
                                    self.cfg.refresh)
            self.predictor.forest = new_rf       # the atomic swap
            self._m_refreshes.inc()
            self._last_refresh = step
            self._drift_pending = None
            self.detector.reset()
            refreshed = True
            ctl.replan(reason="lifecycle", step=step)

        # snapshot accounting: every controller replan since the last
        # tick captured one 1-second snapshot (incl. a refresh replan)
        new_caps = len(ctl.record) - self._seen_records
        if new_caps > 0:
            self.scheduler.charge_snapshot(new_caps)
        self._seen_records = len(ctl.record)

        rec = LifecycleRecord(
            step=int(step), resid_ewma=float(ewma), z_max=z_max,
            consec_max=consec_max, suspicious=bool(suspicious),
            full_probe=full_probe, refreshed=refreshed,
            spend_usd=float(self.scheduler.spend_usd))
        self.records.append(rec)
        return rec
