"""Online predictor lifecycle: drift detection over free residuals,
cost-aware probe scheduling, and deterministic incremental RF refresh.

Gated by ``$REPRO_LIFECYCLE`` / explicit ``lifecycle=`` arguments
(default off — zero lifecycle code on the historical replay path; the
trace goldens pin this byte-identically). See
:class:`repro.lifecycle.manager.LifecycleManager` for the loop.
"""
from repro.lifecycle.drift import (DriftConfig, DriftSignal,
                                   EwmaDriftDetector, ResidualStats)
from repro.lifecycle.harness import (harvest_scenario_rows,
                                     pretrain_predictor,
                                     run_lifecycle_comparison)
from repro.lifecycle.manager import (LIFECYCLE_MODES, LifecycleConfig,
                                     LifecycleManager, LifecycleRecord,
                                     lifecycle_mode)
from repro.lifecycle.probes import (ProbeConfig, ProbeScheduler,
                                    baseline_probe_spend)
from repro.lifecycle.refresh import (RefreshConfig, decay_seed_data,
                                     refresh_forest)
from repro.lifecycle.window import (SlidingWindow,
                                    WindowedPercentileEstimator)

__all__ = [
    "DriftConfig", "DriftSignal", "EwmaDriftDetector", "ResidualStats",
    "LIFECYCLE_MODES", "LifecycleConfig", "LifecycleManager",
    "LifecycleRecord", "lifecycle_mode",
    "ProbeConfig", "ProbeScheduler", "baseline_probe_spend",
    "RefreshConfig", "decay_seed_data", "refresh_forest",
    "SlidingWindow", "WindowedPercentileEstimator",
    "harvest_scenario_rows", "pretrain_predictor",
    "run_lifecycle_comparison",
]
