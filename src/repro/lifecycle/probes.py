"""Cost-aware probe scheduling — spend Eq. 1 dollars only on suspicion.

The paper's Table 2 prices the two measurement modes: a full >=20 s
runtime probe (`MONITOR_SECONDS`) costs ~20x the 1-second snapshot,
and Tetrium-style periodic full probing at `MONITOR_EVERY_MIN` cadence
is the expensive baseline prediction replaces. The scheduler turns
that static cadence into an adaptive one:

  * observing the workload's own achieved BW (iftop-style) is free;
  * every controller replan already pays for one snapshot capture;
  * a FULL probe fires only while the drift detector is suspicious,
    rate-limited by a cooldown — when the predictor is healthy the
    full-probe spend is zero.

`spend_usd` accumulates the run's monitoring dollars through
:func:`repro.wan.monitor.probe_cost_usd`, so a bench can put the
lifecycle run and the frozen + periodic-full-probe baseline on the
same axis: accuracy AND dollars.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.obs.registry import MetricsRegistry
from repro.wan.monitor import (MONITOR_EVERY_MIN, MONITOR_SECONDS,
                               SNAPSHOT_SECONDS, probe_cost_usd)


@dataclass
class ProbeConfig:
    """Knobs of the adaptive probe cadence."""

    step_minutes: float = 10.0    # simulated minutes per engine step
    cooldown_ticks: int = 3       # min ticks between two full probes
    probe_seconds: float = MONITOR_SECONDS
    snapshot_seconds: float = SNAPSHOT_SECONDS


def baseline_probe_spend(steps: int, n_dcs: int,
                         cfg: Optional[ProbeConfig] = None,
                         cadence_min: float = MONITOR_EVERY_MIN) -> float:
    """$ a frozen-predictor deployment pays for periodic full probes
    over `steps` engine steps at the Tetrium `cadence_min` cadence
    (the Table-2 runtime-monitoring row, scaled to the run length)."""
    cfg = cfg or ProbeConfig()
    n_probes = int(steps * cfg.step_minutes // cadence_min)
    return n_probes * probe_cost_usd(cfg.probe_seconds, n_dcs)


class ProbeScheduler:
    """Adaptive monitor cadence with dollar accounting."""

    def __init__(self, n_dcs: int, cfg: Optional[ProbeConfig] = None):
        self.n_dcs = int(n_dcs)
        self.cfg = cfg or ProbeConfig()
        # probe tallies + Eq. 1 dollars live on the obs registry;
        # `full_probes` / `snapshots` / `spend_usd` remain as properties
        self.metrics = MetricsRegistry("probes")
        self._m_full = self.metrics.counter(
            "full_probes", help="full >=20 s runtime probes fired")
        self._m_snaps = self.metrics.counter(
            "snapshots", help="1-second snapshot captures charged")
        self._m_usd = self.metrics.counter(
            "spend_usd", help="cumulative Eq. 1 monitoring dollars")
        self._last_full: Optional[int] = None

    def want_full(self, step: int, suspicious: bool) -> bool:
        """True when a full probe should fire THIS tick: the detector
        is suspicious and the cooldown since the last full probe has
        elapsed. Quiet ticks never probe."""
        if not suspicious:
            return False
        if self._last_full is not None and \
                step - self._last_full < self.cfg.cooldown_ticks:
            return False
        return True

    def charge_full(self, step: int) -> float:
        """Account one full probe fired at `step`; returns its $."""
        cost = probe_cost_usd(self.cfg.probe_seconds, self.n_dcs)
        self._m_full.inc()
        self._m_usd.inc(cost)
        self._last_full = int(step)
        return cost

    def charge_snapshot(self, count: int = 1) -> float:
        """Account `count` snapshot captures (one per controller
        replan); returns the $ added."""
        cost = count * probe_cost_usd(self.cfg.snapshot_seconds,
                                      self.n_dcs)
        self._m_snaps.inc(count)
        self._m_usd.inc(cost)
        return cost

    # -- back-compat aliases onto the obs registry ---------------------
    @property
    def full_probes(self) -> int:
        """Full probes fired (registry-backed)."""
        return int(self._m_full.value)

    @property
    def snapshots(self) -> int:
        """Snapshot captures charged (registry-backed)."""
        return int(self._m_snaps.value)

    @property
    def spend_usd(self) -> float:
        """Cumulative Eq. 1 dollars (registry-backed)."""
        return float(self._m_usd.value)
