"""Deterministic incremental RF refresh (paper §3.3.4, taken online).

The frozen predictor's failure mode is a regime change it never
trained on (a `provider_shift` halves every link; its trees keep
predicting pre-shift runtime BW for post-shift snapshots). The refresh
path refits the forest on

    decayed seed data  ∪  the live harvest window

where the seed set — the rows the current forest was originally
trained on — is DETERMINISTICALLY subsampled down to a `seed_decay`
fraction (same seed, same subsample), so old-regime knowledge fades
instead of vanishing, and the fresh window anchors the new regime.

Everything is seeded: the same (seed data, window, seed) always yields
bit-identical packed ``(feat, thr, leaf)`` tensors, which is what
makes the atomic swap safe to reason about — the swapped-in model is a
pure function of its inputs, and the controller's plan-cache
signatures change only because the *predictions* change, never because
retraining itself is noisy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.forest import RandomForest


@dataclass
class RefreshConfig:
    """Knobs of the incremental refit."""

    min_rows: int = 224          # fresh post-drift rows before a refit
    #                              (4 ticks of an 8-DC mesh: refitting
    #                              on a sliver of the new regime swaps
    #                              in a worse forest than waiting)
    seed_decay: float = 0.25     # fraction of seed rows kept per refit
    #                              (retention, not domination: the new
    #                              regime's rows must outweigh the old)
    cooldown_ticks: int = 5      # min ticks between two refits
    seed: int = 0                # rng seed for subsample AND tree fits


def decay_seed_data(X: np.ndarray, y: np.ndarray, decay: float,
                    seed: int) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic subsample keeping ``floor(decay * n)`` seed rows
    (sorted indices, so row order — and therefore the downstream fit —
    is reproducible; decay<=0 or an empty seed set yields 0 rows)."""
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.float32).reshape(-1)
    n = len(y)
    keep = int(np.floor(max(0.0, min(1.0, decay)) * n))
    if keep <= 0:
        return X[:0], y[:0]
    idx = np.sort(np.random.default_rng(seed).choice(n, size=keep,
                                                     replace=False))
    return X[idx], y[idx]


def refresh_forest(template: RandomForest,
                   window_X: np.ndarray, window_y: np.ndarray,
                   seed_X: Optional[np.ndarray] = None,
                   seed_y: Optional[np.ndarray] = None,
                   cfg: Optional[RefreshConfig] = None) -> RandomForest:
    """Fit a NEW forest (``template.spawn``'s hyperparameters) on the
    harvest window plus the decayed seed set, and return it — the
    caller swaps it in with one reference assignment. Raises on an
    empty training set; never mutates `template`."""
    cfg = cfg or RefreshConfig()
    parts_X = [np.asarray(window_X, np.float32)]
    parts_y = [np.asarray(window_y, np.float32).reshape(-1)]
    if seed_X is not None and seed_y is not None and len(seed_y):
        dx, dy = decay_seed_data(seed_X, seed_y, cfg.seed_decay, cfg.seed)
        if len(dy):
            parts_X.insert(0, dx)
            parts_y.insert(0, dy)
    X = np.concatenate(parts_X)
    y = np.concatenate(parts_y)
    if len(y) == 0:
        raise ValueError("refresh_forest: empty training set "
                         "(no window rows and no seed data)")
    return template.spawn(seed=cfg.seed).fit(X, y)
