"""EWMA drift detection over predicted-vs-achieved residuals.

The predictor exists to *replace* expensive runtime monitoring (paper
§1, Table 2) — so the cheapest possible health check is the traffic it
already serves: per pair, the relative residual between the achieved
runtime BW and the BW the predictor implies for the current snapshot.
The detector keeps
wanctl-style EWMA baselines (SNIPPETS.md §2: a slow `alpha_baseline`
mean with an EWMA variance next to it) and standardizes each new
residual against them:

    z_ij = |r_ij - mean_ij| / sqrt(max(var_ij, var_floor))

A pair is *suspicious* while z exceeds ``threshold``; the baseline is
frozen for suspicious pairs (updating it under suspicion would absorb
the very drift being measured) and a structured :class:`DriftSignal`
is raised once a pair stays suspicious for ``k_consecutive`` ticks.

Contract (pinned by the hypothesis properties in
``tests/test_lifecycle.py``):

  * a zero-residual stream never trips (z is identically 0);
  * any sustained residual step of standardized magnitude > threshold
    is signalled within ``k_consecutive`` ticks of its onset;
  * detection is invariant to the residual sign convention — feeding
    ``-r`` trips at exactly the same ticks as ``r``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


@dataclass
class DriftConfig:
    """Knobs of the EWMA residual detector."""

    threshold: float = 4.0       # standardized-residual trip level
    k_consecutive: int = 3       # K suspicious ticks => DriftSignal
    alpha: float = 0.2           # EWMA smoothing of the mean/var baseline
    warmup: int = 10             # ticks of unconditional baseline learning
    #                              (the variance EWMA needs ~10 samples
    #                              before z-scores mean anything)
    var_floor: float = 1e-3      # variance floor (quiet streams must not
    #                              divide by ~0 and trip on roundoff:
    #                              std >= ~0.032, so a residual must move
    #                              >= threshold*0.032 from baseline)


@dataclass(frozen=True)
class DriftSignal:
    """Structured drift alarm: which pairs tripped, how hard, when."""

    step: int                               # tick index of the alarm
    pairs: Tuple[Tuple[int, int], ...]      # (i, j) with consec >= K
    z_max: float                            # worst standardized residual
    consec_max: int                         # longest suspicious streak


class EwmaDriftDetector:
    """Vectorized per-pair detector over residual matrices (pass
    ``shape=()`` for a scalar stream). ``update`` consumes one residual
    sample per tick and returns a :class:`DriftSignal` on alarm ticks,
    else None; `suspicious()` exposes the cheaper any-pair-over-
    threshold view the probe scheduler keys full probes on."""

    def __init__(self, shape: Tuple[int, ...] = (),
                 cfg: Optional[DriftConfig] = None):
        self.shape = tuple(shape)
        self.cfg = cfg or DriftConfig()
        self.reset()
        # cumulative across resets: poisoned entries seen and skipped
        # (a NaN/inf residual must never touch the EWMA baselines — one
        # NaN would otherwise corrupt mean/var permanently)
        self.nan_skipped = 0

    def reset(self) -> None:
        """Forget all baselines and streaks (post-refresh re-baseline:
        the refreshed predictor's residual regime is new)."""
        self.mean = np.zeros(self.shape)
        self.var = np.zeros(self.shape)
        self.consec = np.zeros(self.shape, np.int64)
        self.ticks = 0
        self.last_z = np.zeros(self.shape)

    def suspicious(self) -> bool:
        """True while any pair's streak is live (z over threshold on
        the latest tick) — the probe scheduler's trigger."""
        return bool((self.consec > 0).any())

    def _baseline_update(self, r: np.ndarray, where: np.ndarray) -> None:
        a = self.cfg.alpha
        d = r - self.mean
        self.mean = np.where(where, self.mean + a * d, self.mean)
        # EWMA variance around the *updated* mean (West-style):
        self.var = np.where(where, (1 - a) * (self.var + a * d * d),
                            self.var)

    def update(self, resid: np.ndarray,
               step: Optional[int] = None) -> Optional[DriftSignal]:
        """Feed one tick's residual(s); returns the DriftSignal on
        alarm ticks (every tick a streak is >= K until reset), else
        None."""
        r = np.asarray(resid, np.float64).reshape(self.shape)
        # quarantine poisoned entries: a single NaN/inf residual (a
        # lost probe, a dead link's 0/0) would otherwise corrupt the
        # EWMA mean/var PERMANENTLY. Skip-and-count: poisoned entries
        # never touch the baselines and standardize to z = 0 for the
        # tick (a poisoned tick is not evidence of drift).
        finite = np.isfinite(r)
        if not finite.all():
            self.nan_skipped += int((~finite).sum())
            fill = self.mean if self.ticks else np.zeros(self.shape)
            r = np.where(finite, r, fill)
        if self.ticks == 0:
            # seed the baseline at the first sample so constant streams
            # standardize to exactly z = 0 forever
            self.mean = r.astype(np.float64).copy()
            self.var = np.zeros(self.shape)
            self.ticks = 1
            self.last_z = np.zeros(self.shape)
            return None
        if self.ticks < self.cfg.warmup:
            self._baseline_update(r, finite)
            self.ticks += 1
            self.last_z = np.zeros(self.shape)
            return None
        z = np.abs(r - self.mean) / np.sqrt(
            np.maximum(self.var, self.cfg.var_floor))
        over = z > self.cfg.threshold
        self.consec = np.where(over, self.consec + 1, 0)
        # learn only from calm pairs: a suspicious pair's baseline is
        # frozen so sustained drift cannot talk its way into the mean
        # (and poisoned entries stay out of it entirely)
        self._baseline_update(r, ~over & finite)
        self.ticks += 1
        self.last_z = z
        tripped = self.consec >= self.cfg.k_consecutive
        if not tripped.any():
            return None
        idx = np.argwhere(tripped)
        pairs = tuple(tuple(int(v) for v in row) for row in idx)
        return DriftSignal(step=self.ticks - 1 if step is None else int(step),
                           pairs=pairs, z_max=float(z.max()),
                           consec_max=int(self.consec.max()))


@dataclass
class ResidualStats:
    """A plain (un-gated) EWMA of the mean |relative residual| — the
    accuracy series the recovery pin and the bench compare across
    frozen vs lifecycle runs, independent of detector state/resets."""

    alpha: float = 0.4
    value: Optional[float] = None
    history: list = field(default_factory=list)

    def update(self, resid: np.ndarray) -> float:
        """Feed one tick's residual matrix/vector; returns the EWMA of
        its mean absolute value. Non-finite entries (poisoned probes)
        are excluded from the mean — an all-poisoned tick repeats the
        previous value."""
        r = np.abs(np.asarray(resid, np.float64))
        finite = np.isfinite(r)
        if finite.any():
            m = float(r[finite].mean())
            self.value = m if self.value is None else \
                (1 - self.alpha) * self.value + self.alpha * m
        self.history.append(0.0 if self.value is None else self.value)
        return self.history[-1]
