"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------
# Block-wise symmetric quantization (gradient compression)
# ----------------------------------------------------------------------
def quantize_ref(x: jax.Array, bits: int, block: int = 256
                 ) -> Tuple[jax.Array, jax.Array]:
    """x [n, d] -> (q int8 [n, d], scales f32 [n/block, d/block]).
    Symmetric per-tile scaling; bits in {4, 8} (int4 stored in int8)."""
    n, d = x.shape
    assert n % block == 0 and d % block == 0, (n, d, block)
    qmax = (1 << (bits - 1)) - 1
    xt = x.reshape(n // block, block, d // block, block).transpose(0, 2, 1, 3)
    amax = jnp.max(jnp.abs(xt.astype(jnp.float32)), axis=(2, 3))
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(xt.astype(jnp.float32) / scale[:, :, None, None]),
                 -qmax, qmax).astype(jnp.int8)
    q = q.transpose(0, 2, 1, 3).reshape(n, d)
    return q, scale


def dequantize_ref(q: jax.Array, scale: jax.Array, block: int = 256,
                   dtype=jnp.float32) -> jax.Array:
    """Oracle inverse of :func:`quantize_ref`."""
    n, d = q.shape
    qt = q.reshape(n // block, block, d // block, block).transpose(0, 2, 1, 3)
    x = qt.astype(jnp.float32) * scale[:, :, None, None]
    return x.transpose(0, 2, 1, 3).reshape(n, d).astype(dtype)


# ----------------------------------------------------------------------
# Random-forest inference (complete-binary-tree layout)
# ----------------------------------------------------------------------
def rf_predict_ref(feat: jax.Array, thr: jax.Array, leaf: jax.Array,
                   X: jax.Array, depth: int) -> jax.Array:
    """Oracle forest inference (matches rf_predict_pallas)."""
    from repro.core.predictor import forest_predict_jnp
    return forest_predict_jnp(feat, thr, leaf, X, depth)


# ----------------------------------------------------------------------
# SSD within-chunk scan (Mamba-2): diagonal block + boundary states
# ----------------------------------------------------------------------
def ssd_chunk_ref(xq: jax.Array, Bq: jax.Array, Cq: jax.Array,
                  da: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One chunk, no inter-chunk state (that recurrence is cheap and
    stays outside the kernel).

    xq [Q,H,P] (pre-multiplied by dt), Bq,Cq [Q,N], da [H,Q] ->
      y_diag [Q,H,P], states [H,P,N], plus decay vectors the caller needs:
      returns (y_diag, states).
    """
    cum = jnp.cumsum(da.astype(jnp.float32), axis=-1)        # [H,Q]
    seg = cum[:, :, None] - cum[:, None, :]
    Q = xq.shape[0]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(tri, seg, -1e30))                  # [H,Q,Q]
    cb = jnp.einsum("qn,kn->qk", Cq.astype(jnp.float32),
                    Bq.astype(jnp.float32))
    scores = cb[None] * L
    y_diag = jnp.einsum("hqk,khp->qhp", scores, xq.astype(jnp.float32))
    dec_r = jnp.exp(cum[:, -1:] - cum)                       # [H,Q]
    states = jnp.einsum("hk,kn,khp->hpn", dec_r, Bq.astype(jnp.float32),
                        xq.astype(jnp.float32))
    return y_diag, states
