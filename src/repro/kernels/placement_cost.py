"""JAX backend for the batched placement-cost evaluator.

`repro.placement.cost.estimate_cost_batch` prices M candidate
placements in one array-program pass; this module is its accelerator
path, sitting next to `rf_predict` so placement search rides the same
launch style as RF prediction (`REPRO_PLACEMENT_BACKEND=jax` selects
it; the numpy path stays the bit-exact default).

The program is the same packed evaluation the numpy core runs —
einsum-style shuffle volumes ``vol[m,i,j] = held[m,i] * frac[m,j]``,
broadcast bottleneck max over off-diagonal pairs, per-source egress
pricing — jit-compiled under 64-bit mode (`jax.experimental.
enable_x64`, so magnitudes match the float64 reference; reductions may
still differ in the last ulp, which is why decisions — not raw metric
bytes — are what the cross-backend tests pin).

Launch shapes are BUCKETED like the controller's plan cache: the
candidate count M is padded up to a power-of-two bucket (min 64) with
copies of row 0, so a greedy search whose per-round move count drifts
by a few candidates reuses one compiled program per (bucket, S, N)
instead of recompiling every round. `compile_count()` exposes the
number of distinct traces for tests/benchmarks.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

_MIN_BUCKET = 64
_TRACES = 0


def bucket(m: int) -> int:
    """Pad a candidate count up to its power-of-two launch bucket."""
    return 1 << max(_MIN_BUCKET.bit_length() - 1, (m - 1).bit_length())


def compile_count() -> int:
    """How many distinct (bucket, S, N) shapes have been traced."""
    return _TRACES


def _eval_core(placements, bw, inputs, speed, price, out_ratio, comp_s,
               waves, rate):
    """The packed evaluator as a jax program (see the numpy reference
    `repro.placement.cost._eval_packed_numpy` for the contract)."""
    global _TRACES
    _TRACES += 1
    M, S, N = placements.shape
    bwc = jnp.maximum(bw, 1e-6)
    off = ~jnp.eye(N, dtype=bool)
    compute_s = waves[:, 0] * (inputs * comp_s[:, 0:1] / speed).max(axis=1)
    held = inputs * out_ratio[:, 0:1]
    net_s = jnp.zeros(1, placements.dtype)
    egress_gb = jnp.zeros(1, placements.dtype)
    egress_usd = jnp.zeros(1, placements.dtype)
    for k in range(1, S + 1):
        frac = placements[:, k - 1, :]
        vol = jnp.einsum("mi,mj->mij", held * jnp.ones_like(frac), frac)
        vol = jnp.where(off, vol, 0.0)
        t = jnp.where(off, vol * 1000.0 / bwc, -jnp.inf)
        st_net = waves[:, k] * t.max(axis=(1, 2))
        new_held = held.sum(axis=1)[:, None] * frac
        st_comp = waves[:, k] * (new_held * comp_s[:, k:k + 1]
                                 / speed).max(axis=1)
        st_gb = waves[:, k] * vol.reshape(M, -1).sum(axis=1) / 8.0
        st_usd = waves[:, k] * ((vol.sum(axis=2) / 8.0
                                 * price).sum(axis=1))
        net_s = net_s + st_net
        compute_s = compute_s + st_comp
        egress_gb = egress_gb + st_gb
        egress_usd = egress_usd + st_usd
        held = new_held * out_ratio[:, k:k + 1]
    makespan = jnp.broadcast_to(net_s + compute_s, (M,))
    instance = makespan / 3600.0 * N * rate
    bc = (makespan, net_s, compute_s, egress_gb, egress_usd, instance)
    return tuple(jnp.broadcast_to(a, (M,)) for a in bc)


_eval_jit = jax.jit(_eval_core)


def _pad_rows(a: np.ndarray, m_pad: int) -> np.ndarray:
    """Pad a per-candidate array out to the launch bucket with copies
    of row 0 (kept valid so padded rows run the same program)."""
    pad = m_pad - a.shape[0]
    if pad <= 0:
        return a
    return np.concatenate(
        [a, np.broadcast_to(a[:1], (pad,) + a.shape[1:])])


def eval_packed_jax(placements: np.ndarray, bw: np.ndarray,
                    inputs: np.ndarray, speed: np.ndarray,
                    price: np.ndarray, out_ratio: np.ndarray,
                    comp_s: np.ndarray, waves: np.ndarray,
                    instance_usd_per_hour) -> Tuple[np.ndarray, ...]:
    """Price a packed batch on the jit path; returns the six metric
    vectors ``(makespan_s, net_s, compute_s, egress_gb, egress_usd,
    instance_usd)``, each [M] float64, matching
    :class:`repro.placement.cost.PlacementCostBatch` field order.

    Shared inputs ([N]/[N,N]/[S+1]) ride along at broadcast size 1;
    per-candidate inputs ([M,...], the fused fleet path) are padded to
    the bucket alongside the placements.
    """
    M = placements.shape[0]
    m_pad = bucket(M)

    def lift(a: np.ndarray, per_cand_ndim: int) -> np.ndarray:
        a = np.asarray(a, np.float64)
        if a.ndim == per_cand_ndim:          # per-candidate: pad rows
            return _pad_rows(a, m_pad)
        return a[None]                       # shared: broadcast dim 1
    with enable_x64():
        out = _eval_jit(
            _pad_rows(np.asarray(placements, np.float64), m_pad),
            lift(bw, 3), lift(inputs, 2), lift(speed, 2), lift(price, 2),
            lift(out_ratio, 2), lift(comp_s, 2), lift(waves, 2),
            jnp.float64(instance_usd_per_hour))
    return tuple(np.asarray(a, np.float64)[:M] for a in out)
