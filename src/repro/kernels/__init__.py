"""repro.kernels — Pallas TPU kernels (RF inference, wire quantization,
SSD scan) with jnp oracles in `ref.py`; call through `ops.py`, which
resolves interpret-vs-compiled per backend."""
