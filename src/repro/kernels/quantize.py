"""Pallas TPU kernel: block-wise symmetric quantize / dequantize.

Used by the WANify gradient-compression stage (SAGQ analogue, paper
§5.6): gradients are tiled (block x block), each tile gets an f32 scale
and int8/int4 payload before crossing the inter-pod "WAN" hop.

TPU adaptation: tiles are (256, 256) — multiples of the (8,128) VREG
lane layout; abs-max reduction and scaling run on the VPU entirely in
VMEM; one tile per grid cell.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256


def _quant_kernel(x_ref, q_ref, s_ref, *, qmax: float):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[0, 0] = scale


def _dequant_kernel(q_ref, s_ref, x_ref, *, out_dtype):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[0, 0]).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block", "interpret"))
def quantize_pallas(x: jax.Array, bits: int = 8, block: int = BLOCK,
                    interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """x [n, d] (n, d multiples of block) -> (q int8, scale [n/b, d/b])."""
    n, d = x.shape
    qmax = float((1 << (bits - 1)) - 1)
    grid = (n // block, d // block)
    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=grid,
        in_specs=[pl.BlockSpec((block, block), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.int8),
            jax.ShapeDtypeStruct(grid, jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s


@functools.partial(jax.jit, static_argnames=("block", "interpret", "out_dtype"))
def dequantize_pallas(q: jax.Array, scale: jax.Array, block: int = BLOCK,
                      out_dtype=jnp.float32, interpret: bool = True) -> jax.Array:
    """Invert :func:`quantize_pallas` (per-tile scales broadcast back)."""
    n, d = q.shape
    grid = (n // block, d // block)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), out_dtype),
        interpret=interpret,
    )(q, scale)
