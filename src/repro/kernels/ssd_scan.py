"""Pallas TPU kernel: Mamba-2 SSD within-chunk scan (the compute hot-spot
of the ssm/hybrid architectures).

Per grid cell: one (batch, chunk, head-block) computes
  * cumulative log-decay, the [Q,Q] decay mask L (VPU exp/cumsum)
  * cb = Cq @ Bq^T on the MXU
  * y_diag = (cb * L) @ (dt*x)  and the chunk-boundary states

The cross-chunk linear recurrence is O(S/Q) and stays outside (lax.scan
in the caller) — it is bandwidth-trivial.

VMEM budget per cell (Q=256, BH=8, P=64, N=128, f32):
  seg/L: 8*256*256*4 = 2 MB, xq: 256*8*64*4 = 0.5 MB, rest < 1 MB.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

HEAD_BLOCK = 8


def _ssd_kernel(xq_ref, bq_ref, cq_ref, da_ref, y_ref, st_ref):
    xq = xq_ref[0, 0].astype(jnp.float32)         # [Q, BH, P]
    Bq = bq_ref[0, 0].astype(jnp.float32)         # [Q, N]
    Cq = cq_ref[0, 0].astype(jnp.float32)         # [Q, N]
    da = da_ref[0, 0].astype(jnp.float32)         # [BH, Q]
    Q = xq.shape[0]

    cum = jnp.cumsum(da, axis=-1)                 # [BH, Q]
    seg = cum[:, :, None] - cum[:, None, :]       # [BH, Q, Q]
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.exp(jnp.where((qi >= ki)[None], seg, -1e30))

    cb = jnp.dot(Cq, Bq.T, preferred_element_type=jnp.float32)  # [Q, Q]
    scores = cb[None] * L                          # [BH, Q, Q]
    # y[q,h,p] = sum_k scores[h,q,k] * xq[k,h,p]
    y = jax.lax.dot_general(
        scores, xq.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)        # [BH, Q, P]
    y_ref[0, 0] = y.transpose(1, 0, 2)

    dec_r = jnp.exp(cum[:, -1:] - cum)             # [BH, Q]
    xw = xq.transpose(1, 0, 2) * dec_r[:, :, None]  # [BH, Q, P]
    st = jax.lax.dot_general(
        xw, jnp.broadcast_to(Bq[None], (xw.shape[0],) + Bq.shape),
        (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)        # [BH, P, N]
    st_ref[0, 0] = st


@functools.partial(jax.jit, static_argnames=("head_block", "interpret"))
def ssd_chunk_pallas(xq: jax.Array, Bq: jax.Array, Cq: jax.Array,
                     da: jax.Array, head_block: int = HEAD_BLOCK,
                     interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Batched over (B, nC): xq [B,nC,Q,H,P], Bq/Cq [B,nC,Q,N],
    da [B,nC,H,Q] -> (y_diag [B,nC,Q,H,P], states [B,nC,H,P,N])."""
    B, nC, Q, H, P = xq.shape
    N = Bq.shape[-1]
    BH = min(head_block, H)
    assert H % BH == 0
    grid = (B, nC, H // BH)
    y, st = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, BH, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, BH, Q), lambda b, c, h: (b, c, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, BH, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, BH, P, N), lambda b, c, h: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nC, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nC, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(xq, Bq, Cq, da)
    return y, st
