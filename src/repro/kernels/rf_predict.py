"""Pallas TPU kernel: Random-Forest ensemble inference.

TPU adaptation of tree traversal (DESIGN.md §2): trees live in a
COMPLETE-binary-tree array layout, so level-order descent is pure index
arithmetic (node -> 2*node+1+go_right) — no pointers, no data-dependent
control flow. Gathers are expressed as ONE-HOT CONTRACTIONS (VPU/MXU
friendly; TPU Pallas has no efficient dynamic row gather), which is the
idiomatic TPU formulation for small tables:

  thr[t, node_s]  ==  sum_k onehot(node_s)[k] * thr[t, k]

Grid: one cell per sample block; the whole forest (feat/thr/leaf) is
resident in VMEM per cell (e.g. 100 trees x depth 8 ~= 0.4 MB).

Backend selection: ``interpret=None`` (the default) resolves per
backend — compiled Pallas on TPU, interpret mode elsewhere (CPU/GPU
containers run the same kernel body for correctness). Pass an explicit
bool to force either path; `repro.kernels.ops` additionally honors the
``REPRO_PALLAS_INTERPRET`` environment variable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SAMPLE_BLOCK = 128


def default_interpret() -> bool:
    """Backend-aware interpret default: compiled on TPU, interpret
    everywhere else (the kernel targets the TPU lowering; interpret
    executes the same body where no TPU is present)."""
    return jax.default_backend() != "tpu"


def _rf_kernel(feat_ref, thr_ref, leaf_ref, x_ref, out_ref, *, depth: int,
               n_trees: int):
    X = x_ref[...].astype(jnp.float32)            # [BS, F]
    BS, F = X.shape
    NN = thr_ref.shape[1]                          # 2^depth - 1
    NL = leaf_ref.shape[1]                         # 2^depth

    def tree_body(t, acc):
        """Descend all samples through tree `t`; add its leaf values."""
        feat_t = feat_ref[t, :]                    # [NN] int32
        thr_t = thr_ref[t, :]                      # [NN] f32
        leaf_t = leaf_ref[t, :]                    # [NL] f32
        node = jnp.zeros((BS,), jnp.int32)
        for _ in range(depth):
            oh = (node[:, None] == jax.lax.broadcasted_iota(
                jnp.int32, (BS, NN), 1)).astype(jnp.float32)    # [BS,NN]
            f_s = oh @ feat_t.astype(jnp.float32)               # [BS]
            t_s = oh @ thr_t                                    # [BS]
            f_i = jnp.maximum(f_s, 0.0).astype(jnp.int32)
            fh = (f_i[:, None] == jax.lax.broadcasted_iota(
                jnp.int32, (BS, F), 1)).astype(jnp.float32)     # [BS,F]
            x_s = jnp.sum(fh * X, axis=1)                       # [BS]
            go_right = (x_s > t_s).astype(jnp.int32)
            node = 2 * node + 1 + go_right
        lidx = node - (NN)                                       # leaf index
        lh = (lidx[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (BS, NL), 1)).astype(jnp.float32)
        return acc + lh @ leaf_t

    acc = jax.lax.fori_loop(0, n_trees, tree_body, jnp.zeros((BS,), jnp.float32))
    out_ref[...] = acc / n_trees


@functools.partial(jax.jit,
                   static_argnames=("depth", "block", "interpret"))
def rf_predict_pallas(feat: jax.Array, thr: jax.Array, leaf: jax.Array,
                      X: jax.Array, depth: int, block: int = SAMPLE_BLOCK,
                      interpret: bool = None) -> jax.Array:
    """feat/thr [T, 2^d-1], leaf [T, 2^d], X [n, F] -> [n] predictions.

    ``interpret=None`` resolves via :func:`default_interpret` (compiled
    on TPU, interpret elsewhere); it is a static argument, so each
    resolved value compiles once.
    """
    if interpret is None:
        interpret = default_interpret()
    n, F = X.shape
    T = feat.shape[0]
    pad = (-n) % block
    if pad:
        X = jnp.pad(X, ((0, pad), (0, 0)))
    npad = X.shape[0]
    grid = (npad // block,)
    out = pl.pallas_call(
        functools.partial(_rf_kernel, depth=depth, n_trees=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec(feat.shape, lambda i: (0, 0)),
            pl.BlockSpec(thr.shape, lambda i: (0, 0)),
            pl.BlockSpec(leaf.shape, lambda i: (0, 0)),
            pl.BlockSpec((block, F), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=interpret,
    )(feat, thr, leaf, X)
    return out[:n]
