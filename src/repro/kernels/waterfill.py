"""JAX backend for the progressive water-fill rate solver.

`repro.wan.simulator.WanSimulator._fill_rates` is the repo's ground-
truth contention model: RTT-biased weighted max-min filling where each
iteration raises every unfrozen pair's per-connection rate along a
shared fill level until some constraint (single-connection ceiling,
parallelism-knee path cap, NIC egress/ingress) binds, then freezes the
binding pairs. The numpy loop is exact but runs one Python iteration
per freeze event — the interpreter cost the fused fleet tick cannot
afford at 100+ jobs x thousand-step scenario sweeps.

This module is the same algorithm as a fixed-bound `lax.while_loop`
over `[B, N, N]` AGGREGATE-connection tensors:

  * the freeze/increment loop becomes mask updates — `frozen`, the
    per-batch `done` flag, and the stall exit are all boolean tensors,
    so one program serves any batch of fills (a fleet tick's probe /
    capture / achieved fills, a scenario grid's B variants);
  * every iteration freezes at least one pair or stalls, so the loop
    provably terminates within ``8 * N * N`` iterations; the actual
    per-fill iteration count and a convergence flag are returned so a
    non-converging fill FAILS LOUDLY instead of returning partial
    rates (mirroring the simulator's `last_fill_iters` contract);
  * arithmetic is float64 under `jax.experimental.enable_x64`, so
    rates match the numpy reference to roundoff (the hypothesis
    property in tests/test_waterfill_kernel.py pins atol/rtol);

`fill_rates_loop` is the raw traced function — embed it inside larger
jit programs (the fused fleet tick in `repro.fleet.fused` scans it).
`fill_rates` is the numpy-in/numpy-out wrapper the simulator's
``REPRO_WATERFILL_BACKEND=jax`` dispatch calls; the numpy loop stays
the bit-exact default (all trace goldens are pinned on it).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

EPS_DEN = 1e-12          # weight-denominator clip (matches numpy)
EPS_INC = 1e-9           # smallest meaningful fill-level increment
EPS_SAT = 1e-6           # constraint-saturation slack


def max_fill_iters(n: int) -> int:
    """The provable iteration bound of the progressive fill: each
    iteration freezes >=1 of the N*(N-1) pairs or stalls; 8*N*N is the
    historical (very generous) cap the numpy loop used silently."""
    return 8 * n * n


def fill_rates_loop(c: jax.Array, single: jax.Array, egress: jax.Array,
                    ingress: jax.Array, w: jax.Array, path_cap: jax.Array
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Batched progressive filling as a traceable jax program.

    c / single / path_cap: [..., N, N] aggregate flow counts, single-
    connection BW, and per-pair path caps (knee x single, already
    min'd with any §3.2.2 throttle); egress / ingress: [..., N] NIC
    caps; w: [N, N] (or broadcastable) per-connection RTT weights.

    Returns ``(rate, iters, converged)``: per-connection rates
    [..., N, N], the per-batch iteration count [...], and a per-batch
    convergence flag [...] (False only if the ``8*N*N`` bound was hit
    with unfrozen pairs left — the caller should raise).
    """
    n = c.shape[-1]
    cap_iters = max_fill_iters(n)
    w = jnp.broadcast_to(w, c.shape)
    cw = c * w
    w_pos = w > 0
    cw_pos = cw > 0
    w_den = jnp.maximum(w, EPS_DEN)
    cw_den = jnp.maximum(cw, EPS_DEN)
    rate0 = jnp.zeros_like(c)
    frozen0 = c <= 0
    done0 = jnp.all(frozen0, axis=(-2, -1))
    iters0 = jnp.zeros(c.shape[:-2], jnp.int32)

    def cond(state):
        _, _, done, _, it = state
        return (it < cap_iters) & jnp.any(~done)

    def body(state):
        rate, frozen, done, iters, it = state
        act = (~frozen) & (~done)[..., None, None]
        cw_act = jnp.where(act, cw, 0.0)
        we = cw_act.sum(-1)                     # active weight per egress
        wi = cw_act.sum(-2)
        load = rate * c
        head_e = egress - load.sum(-1)
        head_i = ingress - load.sum(-2)
        inc_e = jnp.where(we > 0, head_e / jnp.maximum(we, EPS_DEN),
                          jnp.inf)
        inc_i = jnp.where(wi > 0, head_i / jnp.maximum(wi, EPS_DEN),
                          jnp.inf)
        # per-pair bounds in fill-level units (rate grows as t * w)
        inc_conn = jnp.where(act & w_pos, (single - rate) / w_den, jnp.inf)
        inc_path = jnp.where(act & cw_pos, (path_cap - load) / cw_den,
                             jnp.inf)
        inc_pair = jnp.minimum(inc_conn, inc_path)
        inc = jnp.minimum(jnp.minimum(inc_e.min(-1), inc_i.min(-1)),
                          inc_pair.min(axis=(-2, -1)))
        inc = jnp.where(jnp.isfinite(inc) & (inc >= EPS_INC), inc, 0.0)
        rate = jnp.where(act, rate + inc[..., None, None] * w, rate)
        load = rate * c
        hit = act & (((single - rate) < EPS_SAT) |
                     ((path_cap - load) < EPS_SAT))
        sat_e = (egress - load.sum(-1)) < EPS_SAT
        sat_i = (ingress - load.sum(-2)) < EPS_SAT
        hit = hit | (act & (sat_e[..., :, None] | sat_i[..., None, :]))
        frozen = frozen | hit
        stalled = (~jnp.any(hit, axis=(-2, -1))) & (inc == 0.0)
        iters = iters + (~done).astype(jnp.int32)
        done = done | jnp.all(frozen, axis=(-2, -1)) | stalled
        return rate, frozen, done, iters, it + 1

    rate, _, done, iters, _ = lax.while_loop(
        cond, body, (rate0, frozen0, done0, iters0, jnp.int32(0)))
    return rate, iters, done


_fill_jit = jax.jit(fill_rates_loop)


def fill_rates(c: np.ndarray, single: np.ndarray, egress: np.ndarray,
               ingress: np.ndarray, w: np.ndarray, path_cap: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Numpy-in/numpy-out batched fill on the jit path (float64).

    Accepts a single [N, N] fill or any batch [..., N, N]; one compiled
    program per (batch-shape, N). Returns numpy ``(rate, iters,
    converged)`` with the same leading shape.
    """
    with enable_x64():
        rate, iters, ok = _fill_jit(
            jnp.asarray(c, jnp.float64), jnp.asarray(single, jnp.float64),
            jnp.asarray(egress, jnp.float64),
            jnp.asarray(ingress, jnp.float64),
            jnp.asarray(w, jnp.float64),
            jnp.asarray(path_cap, jnp.float64))
    return (np.asarray(rate, np.float64), np.asarray(iters),
            np.asarray(ok))
