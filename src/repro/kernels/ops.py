"""jit'd public wrappers for the Pallas kernels.

On this CPU container kernels run in interpret mode (the TPU lowering is
the target; interpret executes the same kernel body for correctness).
Set REPRO_PALLAS_INTERPRET=0 on real TPUs.
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import quantize as _q
from repro.kernels import rf_predict as _rf
from repro.kernels import ssd_scan as _ssd

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def quantize(x: jax.Array, bits: int = 8, block: int = _q.BLOCK
             ) -> Tuple[jax.Array, jax.Array]:
    return _q.quantize_pallas(x, bits=bits, block=block, interpret=INTERPRET)


def dequantize(q: jax.Array, scale: jax.Array, block: int = _q.BLOCK,
               out_dtype=jnp.float32) -> jax.Array:
    return _q.dequantize_pallas(q, scale, block=block, out_dtype=out_dtype,
                                interpret=INTERPRET)


def rf_predict(feat: jax.Array, thr: jax.Array, leaf: jax.Array,
               X: jax.Array, depth: int) -> jax.Array:
    return _rf.rf_predict_pallas(feat, thr, leaf, X, depth=depth,
                                 interpret=INTERPRET)


def ssd_chunk(xq: jax.Array, Bq: jax.Array, Cq: jax.Array, da: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    return _ssd.ssd_chunk_pallas(xq, Bq, Cq, da, interpret=INTERPRET)
