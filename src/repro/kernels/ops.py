"""jit'd public wrappers for the Pallas kernels.

Interpret-vs-compiled is BACKEND-AWARE by default: on a TPU backend the
kernels compile through the Pallas TPU lowering; on CPU/GPU containers
they run in interpret mode (same kernel body, correctness-equivalent).
``REPRO_PALLAS_INTERPRET`` overrides the automatic choice in either
direction — set ``0`` to force compiled lowering (e.g. TPU CI that
masquerades as CPU during import) or ``1`` to force interpret mode on
a TPU (kernel debugging); leave it unset to trust the backend probe.
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import quantize as _q
from repro.kernels import rf_predict as _rf
from repro.kernels import ssd_scan as _ssd


def interpret_mode() -> bool:
    """Resolve interpret-vs-compiled LAZILY (first kernel call, not
    import): probing `jax.default_backend()` initializes and locks the
    JAX platform, which must not happen as an import side effect. The
    env var wins; otherwise the backend probe decides, memoized."""
    global _INTERPRET
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env != "0"
    if _INTERPRET is None:
        _INTERPRET = _rf.default_interpret()
    return _INTERPRET


_INTERPRET: bool = None


def quantize(x: jax.Array, bits: int = 8, block: int = _q.BLOCK
             ) -> Tuple[jax.Array, jax.Array]:
    """Block-symmetric quantize x -> (payload, per-tile scales)."""
    return _q.quantize_pallas(x, bits=bits, block=block,
                              interpret=interpret_mode())


def dequantize(q: jax.Array, scale: jax.Array, block: int = _q.BLOCK,
               out_dtype=jnp.float32) -> jax.Array:
    """Invert :func:`quantize` back to `out_dtype`."""
    return _q.dequantize_pallas(q, scale, block=block, out_dtype=out_dtype,
                                interpret=interpret_mode())


def rf_predict(feat: jax.Array, thr: jax.Array, leaf: jax.Array,
               X: jax.Array, depth: int) -> jax.Array:
    """Forest inference over packed trees: X [n, F] -> [n]."""
    return _rf.rf_predict_pallas(feat, thr, leaf, X, depth=depth,
                                 interpret=interpret_mode())


def ssd_chunk(xq: jax.Array, Bq: jax.Array, Cq: jax.Array, da: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """One SSD chunk scan step (see kernels/ssd_scan.py)."""
    return _ssd.ssd_chunk_pallas(xq, Bq, Cq, da, interpret=interpret_mode())
