"""JAX version-compatibility shims.

The repo targets the modern mesh/shard_map surface (``jax.make_mesh``
with ``axis_types``, ``jax.set_mesh``, ``jax.shard_map`` with
``axis_names``/``check_vma``) but must also run on jax 0.4.x, where
those spell differently:

  * ``jax.sharding.AxisType`` does not exist — ``make_mesh`` takes no
    ``axis_types`` keyword (all axes are Auto, which is what we want).
  * ``jax.set_mesh`` does not exist — ``Mesh`` itself is the context
    manager.
  * ``jax.shard_map`` does not exist — it lives in
    ``jax.experimental.shard_map`` and spells partial-manual meshes as
    ``auto=<complement>`` with ``check_rep`` instead of ``check_vma``.

Everything in the repo (src, tests, examples) goes through these three
helpers instead of touching the raw API.
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence, Set

import jax

HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")
HAS_SET_MESH = hasattr(jax, "set_mesh")
HAS_TOP_LEVEL_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with explicit Auto axis types when supported."""
    if HAS_AXIS_TYPES:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def use_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh
    (``jax.set_mesh`` on new jax, the Mesh context manager on 0.4.x)."""
    if HAS_SET_MESH:
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[Iterable[str]] = None,
              check_vma: bool = False):
    """Partial-manual shard_map: `axis_names` are manual, the rest stay
    auto. Maps onto ``auto=``/``check_rep=`` on jax 0.4.x."""
    manual: Set[str] = set(axis_names) if axis_names is not None \
        else set(mesh.axis_names)
    if HAS_TOP_LEVEL_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=manual,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(set(mesh.axis_names) - manual)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)
