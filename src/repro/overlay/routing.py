"""Terra-style overlay routing: relay a weak transfer through an
intermediate DC.

WANify's premise is that the achievable-BW surface between distant DCs
is richer than one direct connection (paper §2.2, §3.2); inter-DC
throughput also violates the triangle inequality — a one-hop detour
i -> k -> j can sustain more than the direct link i -> j (Terra,
arxiv 1904.08480; "Bandwidth in the Cloud", arxiv 1512.01129). The
overlay layer splits a pair's planned parallel connections between the
direct path and at most one relay path per pair:

  * :func:`plan_routes` is a bounded search over candidate relays,
    pruned by Algorithm-1 closeness (`relay_candidates`) and scored by
    predicted per-connection store-and-forward BW
    ``min(pred[i,k], pred[k,j])``; a relay is only taken when it beats
    the direct prediction by ``gain_min``.
  * :class:`RoutedPlan` is the frozen result: the residual direct
    connection matrix plus ``(src, via, dst, conns)`` relay specs, with
    a `signature()` for plan-cache identity.
  * Lowering is honest about contention: a relay's connections are
    folded onto BOTH hop links (`expanded_conns`), and
    `WanSimulator.waterfill_routed` charges them on both hops in the
    water-fill, crediting the store-and-forward minimum of the two hop
    rates — a relay through a NIC-saturated DC buys nothing.

Gating: ``REPRO_OVERLAY=off|on`` (off is the default), resolved by
:func:`overlay_mode`; with the overlay off no routed code path runs,
so every existing trace/golden replays byte-identical.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.global_opt import relay_candidates
from repro.core.relations import infer_dc_relations

OVERLAY_MODES = ("off", "on")

# a relay must beat the direct prediction by this factor before any
# connections are moved off the direct path (relaying is not free: the
# flows contend on two links and occupy the via-DC's NIC both ways, so
# a marginal predicted edge — snapshot contention skews far pairs by
# ~1.5x — must not trigger a detour; a real cut clears 2x by decades)
DEFAULT_GAIN_MIN = 2.0


def overlay_mode(mode: Optional[str] = None) -> str:
    """Resolve the overlay gate: an explicit argument wins, then the
    ``REPRO_OVERLAY`` environment variable, then ``off`` (the byte-
    identical historical path)."""
    m = mode or os.environ.get("REPRO_OVERLAY", "off")
    if m not in OVERLAY_MODES:
        raise ValueError(f"unknown overlay mode {m!r}; "
                         f"expected one of {OVERLAY_MODES}")
    return m


@dataclass(frozen=True)
class RoutedPlan:
    """A transfer plan with per-pair path sets: the residual direct
    connection matrix plus one-hop relay paths, each with its own
    connection count. `signature()` is the routed compile-cache
    identity (the base `WanPlan.signature()` does not see routing)."""

    n_pods: int
    direct: Tuple[Tuple[int, ...], ...]   # [P,P] conns left on the
    #                                       direct path per pair
    relays: Tuple[Tuple[int, int, int, int], ...]  # (src, via, dst,
    #                                       conns), sorted, deduped
    pred_bw: Tuple[Tuple[float, ...], ...]  # [P,P] per-conn predicted
    #                                       BW the routes were scored on

    def signature(self) -> Tuple:
        """Hashable identity of the routed lowering (direct conns plus
        the chosen relay paths)."""
        return (self.n_pods, self.direct, self.relays)

    def expanded_conns(self) -> np.ndarray:
        """The [P,P] connection matrix the WAN actually sees: direct
        connections plus each relay's connections folded onto BOTH of
        its hop links (the contention truth of store-and-forward)."""
        c = np.asarray(self.direct, np.float64).copy()
        for i, k, j, cr in self.relays:
            c[i, k] += cr
            c[k, j] += cr
        return c

    def routed_pred_bw(self) -> np.ndarray:
        """Predicted end-to-end surface [P,P]: direct conns x per-conn
        prediction, plus each relay's conns x the store-and-forward
        bottleneck ``min`` of its hop predictions. (The placement
        layer's `achievable_bw(routing=...)` is the knee/capture-aware
        version of this.)"""
        pred = np.asarray(self.pred_bw, np.float64)
        bw = pred * np.asarray(self.direct, np.float64)
        for i, k, j, cr in self.relays:
            bw[i, j] += cr * min(pred[i, k], pred[k, j])
        return bw


def plan_routes(pred_bw: np.ndarray, conns: np.ndarray, *,
                dc_rel: Optional[np.ndarray] = None, D: float = 100.0,
                gain_min: float = DEFAULT_GAIN_MIN,
                max_candidates: int = 4, min_direct: int = 1,
                max_relay_conns: int = 4,
                capture_conns: Optional[np.ndarray] = None) -> RoutedPlan:
    """Bounded one-hop route search over the predicted BW surface.

    `pred_bw` is the predicted pair BW at the operating point it was
    measured at; `capture_conns` is that operating point (the conns
    matrix the snapshot ran at — `WanifyController.last_capture_conns`).
    Scoring normalizes to per-connection units ``pred / capture_conns``
    so pairs planned at different connection counts compare fairly;
    without `capture_conns` the prediction is taken as already
    per-connection.

    For every pair (i, j) the candidate relays are pruned by
    Algorithm-1 closeness (:func:`repro.core.global_opt.
    relay_candidates`: both hops must sit in a closeness class no
    farther than the direct pair's, closest classes first, at most
    `max_candidates` scored); the best candidate by per-connection
    store-and-forward BW ``min(unit[i,k], unit[k,j])`` wins, and only
    if it beats the direct per-connection rate by `gain_min`. The
    pair's planned connections are then split proportionally to the
    two paths' per-connection rates, keeping at least `min_direct` on
    the direct link (so the monitor keeps observing it) and at most
    `max_relay_conns` on the detour — a relay borrows the via-DC's NIC
    and the healthy hops' capacity both ways, so its transit footprint
    is bounded no matter how many connections AIMD grants the pair.
    Deterministic: ties break toward the lower DC index.
    """
    pred = np.asarray(pred_bw, np.float64)
    P = pred.shape[0]
    c = np.rint(np.asarray(conns, np.float64)).astype(np.int64)
    unit = pred
    if capture_conns is not None:
        cap = np.asarray(capture_conns, np.float64)[:P, :P]
        unit = pred / np.maximum(cap, 1.0)
    rel = infer_dc_relations(pred, D) if dc_rel is None \
        else np.asarray(dc_rel)
    direct = c.copy()
    relays = []
    for i in range(P):
        for j in range(P):
            if i == j or c[i, j] <= min_direct:
                continue
            best_k, best_bw = -1, 0.0
            for k in relay_candidates(rel, i, j, max_candidates):
                path_bw = min(float(unit[i, k]), float(unit[k, j]))
                if path_bw > best_bw:
                    best_k, best_bw = k, path_bw
            if best_k < 0 or best_bw < gain_min * float(unit[i, j]):
                continue
            total = int(c[i, j])
            share = best_bw / max(best_bw + float(unit[i, j]), 1e-12)
            cr = int(round(total * share))
            cr = min(max(cr, 1), total - min_direct, int(max_relay_conns))
            direct[i, j] -= cr
            relays.append((i, best_k, j, cr))
    return RoutedPlan(
        n_pods=P,
        direct=tuple(tuple(int(v) for v in row) for row in direct),
        relays=tuple(sorted(relays)),
        pred_bw=tuple(tuple(float(v) for v in row) for row in unit))
