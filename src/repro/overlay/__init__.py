"""Overlay routing through intermediate DCs (Terra-style cross-layer).

Public surface: :func:`plan_routes` (the bounded relay search),
:class:`RoutedPlan` (the frozen per-pair path sets), and
:func:`overlay_mode` (the ``REPRO_OVERLAY`` gate). The consumer stack
— `WanifyController(overlay=...)`, the scenario engine's routed
execution, and `placement.cost.achievable_bw(routing=...)` — rides
these; `WanSimulator.waterfill_routed` is the ground truth that
charges relay flows on both hops.
"""
from repro.overlay.routing import (DEFAULT_GAIN_MIN, OVERLAY_MODES,
                                   RoutedPlan, overlay_mode, plan_routes)

__all__ = [
    "DEFAULT_GAIN_MIN",
    "OVERLAY_MODES",
    "RoutedPlan",
    "overlay_mode",
    "plan_routes",
]
