"""Placement cost model — latency + egress cost of one candidate
placement, priced against per-pair achievable WAN bandwidth.

Latency follows the paper's bottleneck formula (Fig. 2d): a shuffle
moving `V[i,j]` Gb finishes in `max_ij V_ij / BW_ij`; stage compute is
the slowest DC's assigned volume over its compute speed; a stage with
`waves > 1` repeats both. Cost is AWS-style: instance time (every DC
runs for the makespan) plus per-GB egress priced at each *source*
region's rate (`repro.wan.monitor.egress_price_vector`).

Achievable BW comes from the control plane: `achievable_bw(plan)` is
the plan's predicted single-connection BW x its heterogeneous
connection counts (the Eq. 2-3 linearity the paper validates
empirically), optionally clamped by an arbitrated fleet envelope's
`link_cap`. Tests validate this pricing against the `WanSimulator`
water-fill ground truth (`tests/test_placement.py`).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, Union

import numpy as np

from repro.core.plan import WanPlan
from repro.placement.query import QuerySpec
from repro.wan.monitor import NET_COST_PER_GB
from repro.wan.topology import INTRA_DC_BW, KNEE_CONNS

# t2.medium + vCPU burst, the paper's worker class (same basis as the
# benchmark query model)
INSTANCE_USD_PER_HOUR = 0.0464 + 2 * 0.05


def achievable_bw(plan: WanPlan,
                  link_cap: Optional[np.ndarray] = None,
                  capture_conns: Optional[np.ndarray] = None,
                  knee: Optional[float] = KNEE_CONNS,
                  intra_dc_bw: float = INTRA_DC_BW,
                  routing: Optional[Any] = None) -> np.ndarray:
    """Per-pair achievable BW [P,P] in Mbps a placement prices against:
    predicted BW x connection count — the paper's "runtime BW grows
    linearly with the connections" — scaled from the operating point
    the prediction was measured at and saturated at the §2.2
    parallelism knee.

    `capture_conns` is the operating point
    (`WanifyController.last_capture_conns`, pod-sliced): when the
    snapshot was taken at the in-force matrix, the predicted BW is
    already the aggregate there and only the *ratio* to the plan's
    conns applies; the default (ones, a from-scratch capture) reduces
    to plain predicted-BW x conns. `knee` caps the effective
    connection count on both sides of the ratio (parallelism gains
    saturate ~8-9 streams; `None` = pure linearity). An arbitrated
    fleet envelope's `link_cap` clamps the result. Diagonal = intra-DC
    BW.

    `routing` (a `repro.overlay.RoutedPlan`, from
    `WanifyController.routed`) prices the ROUTED surface instead: the
    direct term uses the routing's residual direct connections, and
    each relay (i, k, j, conns) adds its store-and-forward credit —
    the knee-capped connection count times the weaker hop's per-
    connection predicted BW — onto the end-to-end pair (i, j). With
    `routing=None` (the default, overlay off) the arithmetic is
    unchanged."""
    pred = np.asarray(plan.pred_bw, np.float64)
    if routing is None:
        conns = np.asarray(plan.conns, np.float64)
    else:
        if routing.n_pods != plan.n_pods:
            raise ValueError(
                f"routing spans {routing.n_pods} pods != plan scale "
                f"{plan.n_pods}")
        conns = np.asarray(routing.direct, np.float64)
    if capture_conns is None:
        base = np.ones_like(conns)
    else:
        base = np.maximum(np.asarray(capture_conns, np.float64), 1.0)
        if base.shape != conns.shape:
            raise ValueError(
                f"capture_conns shape {base.shape} != plan scale "
                f"{conns.shape}")
    if knee is not None:
        conns = np.minimum(conns, knee)
        base = np.minimum(base, knee)
    bw = pred * conns / base
    if routing is not None:
        # per-connection prediction on each hop, at the hop's own
        # capture operating point; a relay connection sustains the
        # weaker hop's per-connection rate (store-and-forward)
        unit = pred / base
        for i, k, j, cr in routing.relays:
            eff = min(float(cr), knee) if knee is not None else float(cr)
            bw[i, j] += eff * min(float(unit[i, k]), float(unit[k, j]))
    if link_cap is not None:
        cap = np.asarray(link_cap, np.float64)
        if cap.shape != bw.shape:
            raise ValueError(
                f"link_cap shape {cap.shape} != plan scale {bw.shape}")
        off = ~np.eye(plan.n_pods, dtype=bool)
        bw[off] = np.minimum(bw, cap)[off]
    np.fill_diagonal(bw, intra_dc_bw)
    return bw


def shuffle_matrix(held_gb: np.ndarray, frac: np.ndarray) -> np.ndarray:
    """All-to-all shuffle volumes [N,N] (Gb): DC i ships
    `held_i * frac_j` to DC j; the diagonal (data that stays) is 0."""
    v = np.outer(np.asarray(held_gb, np.float64),
                 np.asarray(frac, np.float64))
    np.fill_diagonal(v, 0.0)
    return v


def bottleneck_time_s(volume_gb: np.ndarray, bw_mbps: np.ndarray) -> float:
    """Slowest-link shuffle time in seconds (paper Fig. 2d):
    `max_ij V_ij / BW_ij` over off-diagonal pairs."""
    off = ~np.eye(volume_gb.shape[0], dtype=bool)
    gb = volume_gb[off]
    bw = np.maximum(bw_mbps[off], 1e-6)
    t = gb * 1000.0 / bw                       # Gb -> Mb over Mbps
    return float(t.max()) if len(t) else 0.0


@dataclass(frozen=True)
class StageCost:
    """One placed stage's contribution (already multiplied by waves)."""

    name: str
    net_s: float
    compute_s: float
    egress_gb: float          # GB shipped off-DC (all waves)


@dataclass(frozen=True)
class PlacementCost:
    """Estimated execution of one placement: latency plus dollars."""

    makespan_s: float
    net_s: float
    compute_s: float
    egress_gb: float          # GB
    egress_usd: float
    instance_usd: float
    stages: Tuple[StageCost, ...]

    @property
    def total_usd(self) -> float:
        """Instance time + egress, the paper's §5 cost metric."""
        return self.instance_usd + self.egress_usd


def estimate_cost(query: QuerySpec, placement: np.ndarray,
                  bw_mbps: np.ndarray, *,
                  egress_usd_per_gb: Union[float, np.ndarray, None] = None,
                  instance_usd_per_hour: float = INSTANCE_USD_PER_HOUR
                  ) -> PlacementCost:
    """Price `placement` ([n_shuffles, N] task fractions, rows sum to 1)
    against per-pair `bw_mbps` [N,N].

    `egress_usd_per_gb` is a scalar or per-source-DC vector (default:
    the Table-2 average rate). Returns the full latency/cost breakdown;
    the optimizer minimizes `makespan_s` with `egress_usd` as the
    near-tie preference.
    """
    n = query.n
    bw = np.asarray(bw_mbps, np.float64)
    if bw.shape != (n, n):
        raise ValueError(f"bw shape {bw.shape} != ({n}, {n})")
    placement = np.atleast_2d(np.asarray(placement, np.float64))
    if placement.shape != (query.n_shuffles(), n):
        raise ValueError(
            f"placement shape {placement.shape} != "
            f"({query.n_shuffles()}, {n})")
    if (placement < -1e-9).any() or \
            not np.allclose(placement.sum(axis=1), 1.0, atol=1e-6):
        raise ValueError("each stage's fractions must be >= 0, sum to 1")
    price = np.full(n, NET_COST_PER_GB) if egress_usd_per_gb is None \
        else np.broadcast_to(
            np.asarray(egress_usd_per_gb, np.float64), (n,))
    speed = query.speeds()

    held = query.inputs()
    s0 = query.stages[0]
    compute_s = s0.waves * float(
        (held * s0.compute_s_per_gb / speed).max())
    net_s = 0.0
    egress_gb = 0.0
    egress_usd = 0.0
    rows = [StageCost(s0.name, 0.0, compute_s, 0.0)]
    held = held * s0.out_ratio
    for k, stage in enumerate(query.stages[1:]):
        frac = placement[k]
        vol = shuffle_matrix(held, frac)
        st_net = stage.waves * bottleneck_time_s(vol, bw)
        new_held = held.sum() * frac
        st_comp = stage.waves * float(
            (new_held * stage.compute_s_per_gb / speed).max())
        st_gb = stage.waves * float(vol.sum()) / 8.0        # Gb -> GB
        st_usd = stage.waves * float(
            (vol.sum(axis=1) / 8.0 * price).sum())
        rows.append(StageCost(stage.name, st_net, st_comp, st_gb))
        net_s += st_net
        compute_s += st_comp
        egress_gb += st_gb
        egress_usd += st_usd
        held = new_held * stage.out_ratio
    makespan = net_s + compute_s
    instance_usd = makespan / 3600.0 * n * instance_usd_per_hour
    return PlacementCost(makespan_s=makespan, net_s=net_s,
                         compute_s=compute_s, egress_gb=egress_gb,
                         egress_usd=egress_usd, instance_usd=instance_usd,
                         stages=tuple(rows))


# ----------------------------------------------------------------------
# Batched evaluation — price M candidate placements in one pass
# ----------------------------------------------------------------------
PLACEMENT_BACKENDS = ("numpy", "jax", "scalar")


def placement_backend(backend: Optional[str] = None) -> str:
    """Resolve the batched-evaluator backend: an explicit argument wins,
    then the ``REPRO_PLACEMENT_BACKEND`` environment variable, then
    ``numpy``. ``scalar`` routes every candidate through the readable
    per-placement :func:`estimate_cost` reference (tests/benchmarks)."""
    if backend is None:
        backend = os.environ.get("REPRO_PLACEMENT_BACKEND", "numpy")
    if backend not in PLACEMENT_BACKENDS:
        raise ValueError(f"backend {backend!r} not in {PLACEMENT_BACKENDS}")
    return backend


@dataclass(frozen=True)
class PlacementCostBatch:
    """Per-candidate cost vectors for a batch of M placements — the
    same numbers :class:`PlacementCost` carries, without the per-stage
    breakdown (built lazily, winner-only, via :func:`estimate_cost`)."""

    makespan_s: np.ndarray            # [M]
    net_s: np.ndarray                 # [M]
    compute_s: np.ndarray             # [M]
    egress_gb: np.ndarray             # [M]
    egress_usd: np.ndarray            # [M]
    instance_usd: np.ndarray          # [M]

    def __len__(self) -> int:
        return len(self.makespan_s)

    @property
    def total_usd(self) -> np.ndarray:
        """Instance time + egress per candidate (the §5 cost metric)."""
        return self.instance_usd + self.egress_usd


def _price_vector(egress_usd_per_gb, n: int) -> np.ndarray:
    """The per-source-DC egress rate vector the scalar path uses."""
    if egress_usd_per_gb is None:
        return np.full(n, NET_COST_PER_GB)
    return np.broadcast_to(
        np.asarray(egress_usd_per_gb, np.float64), (n,))


def pack_query(query: QuerySpec, egress_usd_per_gb=None
               ) -> Dict[str, np.ndarray]:
    """The query's stage chain as flat arrays for the packed evaluator:
    ``inputs``/``speed``/``price`` [N] and ``out_ratio``/``comp_s``/
    ``waves`` [S+1] (stage 0 first)."""
    return {
        "inputs": query.inputs(),
        "speed": query.speeds(),
        "price": _price_vector(egress_usd_per_gb, query.n),
        "out_ratio": np.array([s.out_ratio for s in query.stages],
                              np.float64),
        "comp_s": np.array([s.compute_s_per_gb for s in query.stages],
                           np.float64),
        "waves": np.array([float(s.waves) for s in query.stages],
                          np.float64),
    }


def _eval_packed_numpy(placements: np.ndarray, bw: np.ndarray,
                       inputs: np.ndarray, speed: np.ndarray,
                       price: np.ndarray, out_ratio: np.ndarray,
                       comp_s: np.ndarray, waves: np.ndarray,
                       instance_usd_per_hour) -> PlacementCostBatch:
    """The vectorized core: one pass over all M candidates.

    `placements` is [M, S, N]; every other input is either shared
    ([N], [N,N], [S+1]) or per-candidate ([M,N], [M,N,N], [M,S+1]) —
    per-candidate forms let the fleet driver fuse different jobs'
    searches into one launch. Reduction order matches the scalar
    :func:`estimate_cost` exactly (row-wise sums over the same
    contiguous axes, order-independent maxes), so the per-candidate
    outputs are bit-identical to the scalar reference — the property
    `tests/test_placement_batch.py` pins.
    """
    M, S, N = placements.shape
    bw3 = bw if bw.ndim == 3 else bw[None]
    bwc = np.maximum(bw3, 1e-6)
    inputs2 = inputs if inputs.ndim == 2 else inputs[None]
    speed2 = speed if speed.ndim == 2 else speed[None]
    price2 = price if price.ndim == 2 else price[None]
    out2 = out_ratio if out_ratio.ndim == 2 else out_ratio[None]
    comp2 = comp_s if comp_s.ndim == 2 else comp_s[None]
    waves2 = waves if waves.ndim == 2 else waves[None]
    off = ~np.eye(N, dtype=bool)
    diag = np.arange(N)

    compute_s = waves2[:, 0] * (inputs2 * comp2[:, 0:1] / speed2).max(axis=1)
    held = inputs2 * out2[:, 0:1]
    net_s = np.zeros(1)
    egress_gb = np.zeros(1)
    egress_usd = np.zeros(1)
    for k in range(1, S + 1):
        frac = placements[:, k - 1, :]
        vol = held[:, :, None] * frac[:, None, :]          # [M,N,N]
        vol[:, diag, diag] = 0.0
        t = vol * 1000.0 / bwc
        st_net = waves2[:, k] * t[:, off].max(axis=1)
        new_held = held.sum(axis=1)[:, None] * frac
        st_comp = waves2[:, k] * (new_held * comp2[:, k:k + 1]
                                  / speed2).max(axis=1)
        st_gb = waves2[:, k] * vol.reshape(M, -1).sum(axis=1) / 8.0
        st_usd = waves2[:, k] * ((vol.sum(axis=2) / 8.0
                                  * price2).sum(axis=1))
        net_s = net_s + st_net
        compute_s = compute_s + st_comp
        egress_gb = egress_gb + st_gb
        egress_usd = egress_usd + st_usd
        held = new_held * out2[:, k:k + 1]
    makespan = np.broadcast_to(net_s + compute_s, (M,))
    instance = makespan / 3600.0 * N * instance_usd_per_hour

    def bc(a: np.ndarray) -> np.ndarray:
        """Materialize a possibly-broadcast vector at full batch size."""
        return np.ascontiguousarray(np.broadcast_to(a, (M,)))

    return PlacementCostBatch(
        makespan_s=bc(makespan), net_s=bc(net_s), compute_s=bc(compute_s),
        egress_gb=bc(egress_gb), egress_usd=bc(egress_usd),
        instance_usd=bc(instance))


def _eval_packed(placements, bw, packed, instance_usd_per_hour,
                 backend: str) -> PlacementCostBatch:
    """Dispatch one packed batch to the resolved backend."""
    if backend == "jax":
        from repro.kernels.placement_cost import eval_packed_jax
        return PlacementCostBatch(*eval_packed_jax(
            placements, bw, packed["inputs"], packed["speed"],
            packed["price"], packed["out_ratio"], packed["comp_s"],
            packed["waves"], instance_usd_per_hour))
    return _eval_packed_numpy(
        placements, bw, packed["inputs"], packed["speed"],
        packed["price"], packed["out_ratio"], packed["comp_s"],
        packed["waves"], instance_usd_per_hour)


def _validate_batch(query: QuerySpec, placements: np.ndarray,
                    bw: np.ndarray) -> None:
    """The scalar path's shape/positivity/sum checks, batched."""
    n = query.n
    if bw.shape[-2:] != (n, n):
        raise ValueError(f"bw shape {bw.shape} != (..., {n}, {n})")
    if placements.ndim != 3 or \
            placements.shape[1:] != (query.n_shuffles(), n):
        raise ValueError(
            f"placements shape {placements.shape} != "
            f"(M, {query.n_shuffles()}, {n})")
    if (placements < -1e-9).any() or \
            not np.allclose(placements.sum(axis=2), 1.0, atol=1e-6):
        raise ValueError("each stage's fractions must be >= 0, sum to 1")


def estimate_cost_batch(query: QuerySpec, placements: np.ndarray,
                        bw_mbps: np.ndarray, *,
                        egress_usd_per_gb: Union[float, np.ndarray,
                                                 None] = None,
                        instance_usd_per_hour: float =
                        INSTANCE_USD_PER_HOUR,
                        backend: Optional[str] = None
                        ) -> PlacementCostBatch:
    """Price M candidate placements ([M, n_shuffles, N]) against one
    per-pair `bw_mbps` [N,N] in a single vectorized pass.

    The ``numpy`` backend is bit-identical to mapping
    :func:`estimate_cost` over the batch (the scalar function stays the
    readable reference; the search builds the winner's full
    :class:`StageCost` breakdown from it lazily). ``jax`` runs the same
    program jit-compiled (`repro.kernels.placement_cost`); ``scalar``
    actually maps the reference, for tests and the benchmark baseline.
    """
    backend = placement_backend(backend)
    placements = np.ascontiguousarray(np.asarray(placements, np.float64))
    bw = np.asarray(bw_mbps, np.float64)
    _validate_batch(query, placements, bw)
    if len(placements) == 0:       # empty batch: empty vectors, any backend
        empty = np.zeros(0)
        return PlacementCostBatch(*([empty] * 6))
    if backend == "scalar":
        rows = [estimate_cost(query, p, bw,
                              egress_usd_per_gb=egress_usd_per_gb,
                              instance_usd_per_hour=instance_usd_per_hour)
                for p in placements]
        return PlacementCostBatch(
            makespan_s=np.array([r.makespan_s for r in rows]),
            net_s=np.array([r.net_s for r in rows]),
            compute_s=np.array([r.compute_s for r in rows]),
            egress_gb=np.array([r.egress_gb for r in rows]),
            egress_usd=np.array([r.egress_usd for r in rows]),
            instance_usd=np.array([r.instance_usd for r in rows]))
    packed = pack_query(query, egress_usd_per_gb)
    return _eval_packed(placements, bw, packed, instance_usd_per_hour,
                        backend)
