"""Placement cost model — latency + egress cost of one candidate
placement, priced against per-pair achievable WAN bandwidth.

Latency follows the paper's bottleneck formula (Fig. 2d): a shuffle
moving `V[i,j]` Gb finishes in `max_ij V_ij / BW_ij`; stage compute is
the slowest DC's assigned volume over its compute speed; a stage with
`waves > 1` repeats both. Cost is AWS-style: instance time (every DC
runs for the makespan) plus per-GB egress priced at each *source*
region's rate (`repro.wan.monitor.egress_price_vector`).

Achievable BW comes from the control plane: `achievable_bw(plan)` is
the plan's predicted single-connection BW x its heterogeneous
connection counts (the Eq. 2-3 linearity the paper validates
empirically), optionally clamped by an arbitrated fleet envelope's
`link_cap`. Tests validate this pricing against the `WanSimulator`
water-fill ground truth (`tests/test_placement.py`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union

import numpy as np

from repro.core.plan import WanPlan
from repro.placement.query import QuerySpec
from repro.wan.monitor import NET_COST_PER_GB
from repro.wan.topology import INTRA_DC_BW, KNEE_CONNS

# t2.medium + vCPU burst, the paper's worker class (same basis as the
# benchmark query model)
INSTANCE_USD_PER_HOUR = 0.0464 + 2 * 0.05


def achievable_bw(plan: WanPlan,
                  link_cap: Optional[np.ndarray] = None,
                  capture_conns: Optional[np.ndarray] = None,
                  knee: Optional[float] = KNEE_CONNS,
                  intra_dc_bw: float = INTRA_DC_BW) -> np.ndarray:
    """Per-pair achievable BW [P,P] in Mbps a placement prices against:
    predicted BW x connection count — the paper's "runtime BW grows
    linearly with the connections" — scaled from the operating point
    the prediction was measured at and saturated at the §2.2
    parallelism knee.

    `capture_conns` is the operating point
    (`WanifyController.last_capture_conns`, pod-sliced): when the
    snapshot was taken at the in-force matrix, the predicted BW is
    already the aggregate there and only the *ratio* to the plan's
    conns applies; the default (ones, a from-scratch capture) reduces
    to plain predicted-BW x conns. `knee` caps the effective
    connection count on both sides of the ratio (parallelism gains
    saturate ~8-9 streams; `None` = pure linearity). An arbitrated
    fleet envelope's `link_cap` clamps the result. Diagonal = intra-DC
    BW."""
    pred = np.asarray(plan.pred_bw, np.float64)
    conns = np.asarray(plan.conns, np.float64)
    if capture_conns is None:
        base = np.ones_like(conns)
    else:
        base = np.maximum(np.asarray(capture_conns, np.float64), 1.0)
        if base.shape != conns.shape:
            raise ValueError(
                f"capture_conns shape {base.shape} != plan scale "
                f"{conns.shape}")
    if knee is not None:
        conns = np.minimum(conns, knee)
        base = np.minimum(base, knee)
    bw = pred * conns / base
    if link_cap is not None:
        cap = np.asarray(link_cap, np.float64)
        if cap.shape != bw.shape:
            raise ValueError(
                f"link_cap shape {cap.shape} != plan scale {bw.shape}")
        off = ~np.eye(plan.n_pods, dtype=bool)
        bw[off] = np.minimum(bw, cap)[off]
    np.fill_diagonal(bw, intra_dc_bw)
    return bw


def shuffle_matrix(held_gb: np.ndarray, frac: np.ndarray) -> np.ndarray:
    """All-to-all shuffle volumes [N,N] (Gb): DC i ships
    `held_i * frac_j` to DC j; the diagonal (data that stays) is 0."""
    v = np.outer(np.asarray(held_gb, np.float64),
                 np.asarray(frac, np.float64))
    np.fill_diagonal(v, 0.0)
    return v


def bottleneck_time_s(volume_gb: np.ndarray, bw_mbps: np.ndarray) -> float:
    """Slowest-link shuffle time in seconds (paper Fig. 2d):
    `max_ij V_ij / BW_ij` over off-diagonal pairs."""
    off = ~np.eye(volume_gb.shape[0], dtype=bool)
    gb = volume_gb[off]
    bw = np.maximum(bw_mbps[off], 1e-6)
    t = gb * 1000.0 / bw                       # Gb -> Mb over Mbps
    return float(t.max()) if len(t) else 0.0


@dataclass(frozen=True)
class StageCost:
    """One placed stage's contribution (already multiplied by waves)."""

    name: str
    net_s: float
    compute_s: float
    egress_gb: float          # GB shipped off-DC (all waves)


@dataclass(frozen=True)
class PlacementCost:
    """Estimated execution of one placement: latency plus dollars."""

    makespan_s: float
    net_s: float
    compute_s: float
    egress_gb: float          # GB
    egress_usd: float
    instance_usd: float
    stages: Tuple[StageCost, ...]

    @property
    def total_usd(self) -> float:
        """Instance time + egress, the paper's §5 cost metric."""
        return self.instance_usd + self.egress_usd


def estimate_cost(query: QuerySpec, placement: np.ndarray,
                  bw_mbps: np.ndarray, *,
                  egress_usd_per_gb: Union[float, np.ndarray, None] = None,
                  instance_usd_per_hour: float = INSTANCE_USD_PER_HOUR
                  ) -> PlacementCost:
    """Price `placement` ([n_shuffles, N] task fractions, rows sum to 1)
    against per-pair `bw_mbps` [N,N].

    `egress_usd_per_gb` is a scalar or per-source-DC vector (default:
    the Table-2 average rate). Returns the full latency/cost breakdown;
    the optimizer minimizes `makespan_s` with `egress_usd` as the
    near-tie preference.
    """
    n = query.n
    bw = np.asarray(bw_mbps, np.float64)
    if bw.shape != (n, n):
        raise ValueError(f"bw shape {bw.shape} != ({n}, {n})")
    placement = np.atleast_2d(np.asarray(placement, np.float64))
    if placement.shape != (query.n_shuffles(), n):
        raise ValueError(
            f"placement shape {placement.shape} != "
            f"({query.n_shuffles()}, {n})")
    if (placement < -1e-9).any() or \
            not np.allclose(placement.sum(axis=1), 1.0, atol=1e-6):
        raise ValueError("each stage's fractions must be >= 0, sum to 1")
    price = np.full(n, NET_COST_PER_GB) if egress_usd_per_gb is None \
        else np.broadcast_to(
            np.asarray(egress_usd_per_gb, np.float64), (n,))
    speed = query.speeds()

    held = query.inputs()
    s0 = query.stages[0]
    compute_s = s0.waves * float(
        (held * s0.compute_s_per_gb / speed).max())
    net_s = 0.0
    egress_gb = 0.0
    egress_usd = 0.0
    rows = [StageCost(s0.name, 0.0, compute_s, 0.0)]
    held = held * s0.out_ratio
    for k, stage in enumerate(query.stages[1:]):
        frac = placement[k]
        vol = shuffle_matrix(held, frac)
        st_net = stage.waves * bottleneck_time_s(vol, bw)
        new_held = held.sum() * frac
        st_comp = stage.waves * float(
            (new_held * stage.compute_s_per_gb / speed).max())
        st_gb = stage.waves * float(vol.sum()) / 8.0        # Gb -> GB
        st_usd = stage.waves * float(
            (vol.sum(axis=1) / 8.0 * price).sum())
        rows.append(StageCost(stage.name, st_net, st_comp, st_gb))
        net_s += st_net
        compute_s += st_comp
        egress_gb += st_gb
        egress_usd += st_usd
        held = new_held * stage.out_ratio
    makespan = net_s + compute_s
    instance_usd = makespan / 3600.0 * n * instance_usd_per_hour
    return PlacementCost(makespan_s=makespan, net_s=net_s,
                         compute_s=compute_s, egress_gb=egress_gb,
                         egress_usd=egress_usd, instance_usd=instance_usd,
                         stages=tuple(rows))
