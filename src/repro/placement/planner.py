"""PlacementPlanner — the consumer that closes the paper's loop: from
predicted runtime WAN BW to the data/task placement decisions it is
supposed to improve (§2's motivating example, §5's latency/cost
tables).

The planner rides a :class:`WanifyController`: it registers on the
controller's replan trace stream (`add_trace_hook`), so every trigger
the paper replans on — periodic, straggler, topology change, BW shift,
a fleet tick — also re-places the query under the fresh plan. Pricing
is `achievable_bw(plan)` (predicted BW x heterogeneous connections),
clamped by the controller's arbitrated :class:`BudgetEnvelope` when the
job runs in a fleet — a low-priority tenant prices its placement
against its fair share, not the raw link.

Two backends reproduce the paper's comparison:

  * ``wanify`` — re-places on every replan, priced at the plan's
    predicted BW x conns; the workload executes at the plan's
    heterogeneous connection matrix.
  * ``static`` — the existing-GDA-systems ablation: one expensive
    static single-connection measurement up front (`measure_static_
    independent`), one placement, never revisited; the workload
    executes single-connection.

`records` is the per-query placement trace (step, trigger reason,
estimated makespan/egress, the fraction vectors) a harness can line up
against ground truth.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.control import WanifyController
from repro.placement.cost import PlacementCost, achievable_bw, estimate_cost
from repro.placement.optimizer import (PlacementDecision, SearchTask,
                                       greedy_place)
from repro.placement.query import QuerySpec
from repro.wan.monitor import egress_price_vector
from repro.wan.topology import KNEE_CONNS

BACKENDS = ("wanify", "static")


@dataclass(frozen=True)
class PlacementRecord:
    """One (re-)placement: when, why, and what the planner believed."""

    step: Optional[int]
    reason: str
    backend: str
    makespan_est_s: float
    egress_est_usd: float
    placement: Tuple[Tuple[float, ...], ...]


class PlacementPlanner:
    """BW-aware placement for one query riding one controller."""

    def __init__(self, controller: WanifyController, query: QuerySpec, *,
                 backend: str = "wanify",
                 static_bw: Optional[np.ndarray] = None,
                 egress_usd_per_gb: Any = None,
                 coarse: float = 0.1, fine: float = 0.02,
                 rel_tol: float = 0.01):
        """`static_bw` overrides the ``static`` backend's one-shot
        estimate (required when the controller's sim has no
        `measure_static_independent`, e.g. a fleet `TenantView`)."""
        if backend not in BACKENDS:
            raise ValueError(f"backend {backend!r} not in {BACKENDS}")
        if query.n != controller.n_pods:
            raise ValueError(
                f"query spans {query.n} DCs but the controller plans "
                f"{controller.n_pods} pods; build the workload with "
                f"n={controller.n_pods}")
        self.controller = controller
        self.query = query
        self.backend = backend
        self._opt = dict(coarse=coarse, fine=fine, rel_tol=rel_tol)
        if egress_usd_per_gb is None:
            regions = getattr(controller.sim, "regions", None)
            if regions is not None:
                egress_usd_per_gb = egress_price_vector(
                    regions[:controller.n_pods])
        self.egress_usd_per_gb = egress_usd_per_gb
        self._static_bw: Optional[np.ndarray] = None
        if backend == "static":
            if static_bw is None:
                measure = getattr(controller.sim,
                                  "measure_static_independent", None)
                if measure is None:
                    raise ValueError(
                        "static backend needs static_bw= when the sim "
                        "has no measure_static_independent (fleet "
                        "TenantView slices don't)")
                P = controller.n_pods
                static_bw = measure()[:P, :P]
            self._static_bw = np.asarray(static_bw, np.float64)
            if self._static_bw.shape != (query.n, query.n):
                raise ValueError(
                    f"static_bw shape {self._static_bw.shape} != "
                    f"({query.n}, {query.n})")
        self.records: List[PlacementRecord] = []
        self.placement: np.ndarray = np.zeros(0)
        self._detached = False
        self._deferred = False
        self._pending: Optional[Tuple[str, Optional[int]]] = None
        self._replace(reason="init", step=None)
        if backend == "wanify":
            controller.add_trace_hook(self._on_replan)

    def detach(self) -> None:
        """Stop re-placing on controller replans (the hook itself stays
        chained but becomes a no-op). Call this before building a
        replacement planner on the same controller — e.g. a second
        `FleetController.job_planner` for the same job — so the
        abandoned planner stops burning search work every tick."""
        self._detached = True

    # ------------------------------------------------------------------
    # pricing
    # ------------------------------------------------------------------
    def priced_bw(self) -> np.ndarray:
        """The [P,P] achievable-BW matrix the next placement prices
        against: the plan's predicted BW x conns under the arbitrated
        envelope cap (``wanify``), or the frozen one-shot static
        single-connection estimate (``static``)."""
        if self.backend == "static":
            return self._static_bw.copy()
        ctl = self.controller
        env = ctl.envelope
        cap = env.link_cap if env is not None else None
        P = ctl.n_pods
        capture = getattr(ctl, "last_capture_conns", None)
        if capture is not None:
            capture = np.asarray(capture, np.float64)[:P, :P]
        knee = getattr(ctl.sim, "knee", None)
        if knee is None:                 # a fleet TenantView: the mesh's
            knee = getattr(getattr(ctl.sim, "shared", None), "knee",
                           KNEE_CONNS)
        # with the overlay on, price the ROUTED surface: a cut link's
        # pair carries its relay credit, so the search stops fleeing
        # DCs the overlay can still reach
        return achievable_bw(ctl.plan, link_cap=cap,
                             capture_conns=capture, knee=knee,
                             routing=ctl.routed)

    def exec_conns(self) -> np.ndarray:
        """The [P,P] connection matrix the workload's shuffles would
        actually run at (plan conns for ``wanify``, single connection
        for the ``static`` ablation)."""
        P = self.controller.n_pods
        if self.backend == "static":
            return np.ones((P, P))
        return np.asarray(self.controller.plan.conns, np.float64)

    # ------------------------------------------------------------------
    # (re-)placement
    # ------------------------------------------------------------------
    def _on_replan(self, rec) -> None:
        """Controller trace hook: re-place under the fresh plan — or,
        in deferred mode (a fleet tick), just record the trigger so the
        fleet can fuse every job's search into shared launches."""
        if self._detached:
            return
        if self._deferred:
            self._pending = (rec.get("reason", "replan"),
                             rec.get("step"))
            return
        self._replace(reason=rec.get("reason", "replan"),
                      step=rec.get("step"))

    def _replace(self, reason: str, step: Optional[int]) -> None:
        decision = greedy_place(self.query, self.priced_bw(),
                                egress_usd_per_gb=self.egress_usd_per_gb,
                                **self._opt)
        self._apply(decision, reason, step)

    def _apply(self, decision: PlacementDecision, reason: str,
               step: Optional[int]) -> None:
        """Install a search result and append its trace record."""
        self.placement = decision.frac()
        self.records.append(PlacementRecord(
            step=step, reason=reason, backend=self.backend,
            makespan_est_s=decision.cost.makespan_s,
            egress_est_usd=decision.cost.egress_usd,
            placement=decision.placement))

    # ------------------------------------------------------------------
    # deferred (fleet-fused) re-placement
    # ------------------------------------------------------------------
    def defer_replans(self) -> None:
        """Switch to deferred mode: replan triggers set a pending
        marker instead of searching, and the owner (the fleet tick)
        collects :meth:`pending_task` from every planner, drives them
        through one `optimizer.search_many` lock-step pass, and
        commits each result. Pricing is unchanged —
        `priced_bw()` reads the job's own plan/envelope, which other
        jobs' replans never touch — so a deferred search returns the
        same decision an immediate one would."""
        self._deferred = True

    def pending_task(self) -> Optional[SearchTask]:
        """The deferred search to run, as a `SearchTask` priced at the
        current plan — or None when no replan fired since the last
        commit (or the planner is detached)."""
        if self._pending is None or self._detached:
            return None
        return SearchTask(query=self.query, bw=self.priced_bw(),
                          egress_usd_per_gb=self.egress_usd_per_gb,
                          **self._opt)

    def commit(self, decision: PlacementDecision) -> None:
        """Install the result of the pending deferred search."""
        if self._pending is None:
            raise ValueError(
                "no deferred re-placement is pending (commit pairs "
                "with a pending_task() taken after a replan trigger)")
        reason, step = self._pending
        self._pending = None
        self._apply(decision, reason, step)

    # ------------------------------------------------------------------
    # evaluation
    # ------------------------------------------------------------------
    def estimated(self) -> PlacementCost:
        """The current placement priced at the planner's own estimate."""
        return estimate_cost(self.query, self.placement, self.priced_bw(),
                             egress_usd_per_gb=self.egress_usd_per_gb)

    def evaluate(self, true_bw: np.ndarray) -> PlacementCost:
        """Execute the current placement under ground-truth achieved BW
        [P,P] (e.g. the simulator's water-fill at `exec_conns()`)."""
        return estimate_cost(self.query, self.placement, true_bw,
                             egress_usd_per_gb=self.egress_usd_per_gb)
