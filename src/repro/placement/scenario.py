"""Event-driven placement runs — the paper's §5 latency/cost story
under scripted WAN dynamics, with byte-replayable traces.

`run_placement_scenario` rides a named scenario (repro.scenarios) with
a :class:`PlacementPlanner` attached to the engine's controller: every
step, after the closed loop has reacted to the timeline's events, the
query's current placement is *executed* against the simulator's
ground-truth water-fill (at the plan's heterogeneous connections for
the ``wanify`` backend, at single connections for the ``static``
ablation) and one :class:`PlacementStepTrace` row is appended. Same
spec + seed + backend replays to byte-identical
:meth:`PlacementTrace.to_json` output — the planner is deterministic
(no RNG in the search) and the simulator's named streams make the WAN
evolution identical across runs, so the two backends of
:func:`compare_backends` see the *same* network weather.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.placement.planner import PlacementPlanner
from repro.placement.query import QuerySpec, scan_agg
from repro.scenarios.engine import ScenarioEngine, ScenarioSpec
from repro.scenarios.events import Rescale
from repro.scenarios.library import get_scenario


@dataclass
class PlacementStepTrace:
    """One step of a placement run: what the placement in force costs
    under that step's ground-truth achieved BW."""

    step: int
    events: Tuple[str, ...]          # events applied this step
    replaced: bool                   # did the planner re-place now?
    plan_sig: str                    # controller plan in force (hash)
    makespan_s: float                # simulated query makespan
    net_s: float
    egress_usd: float
    achieved_min: float              # min pod-pair BW the query saw
    placement: Tuple[Tuple[float, ...], ...]


@dataclass
class PlacementTrace:
    """A whole placement run; `to_json()` is the byte-comparable form."""

    scenario: str
    query: str
    backend: str
    seed: int
    steps: List[PlacementStepTrace] = field(default_factory=list)

    def to_json(self) -> str:
        """Canonical bytes for replay comparison (sorted keys, no
        whitespace drift)."""
        payload = {"scenario": self.scenario, "query": self.query,
                   "backend": self.backend, "seed": self.seed,
                   "steps": [asdict(s) for s in self.steps]}
        return json.dumps(payload, sort_keys=True,
                          separators=(",", ":"))

    def replaced_steps(self) -> List[int]:
        """Steps at which the planner re-placed the query."""
        return [s.step for s in self.steps if s.replaced]


@dataclass
class PlacementScenarioResult:
    """A completed placement run plus summary helpers."""

    trace: PlacementTrace
    records: Tuple[Any, ...]         # the planner's PlacementRecords

    def summary(self) -> Dict[str, Any]:
        """Roll the run up into one benchmark row."""
        steps = self.trace.steps
        return {
            "scenario": self.trace.scenario,
            "query": self.trace.query,
            "backend": self.trace.backend,
            "seed": self.trace.seed,
            "steps": len(steps),
            "makespan_total_s": sum(s.makespan_s for s in steps),
            "makespan_mean_s": sum(s.makespan_s for s in steps)
            / max(len(steps), 1),
            "makespan_final_s": steps[-1].makespan_s if steps else 0.0,
            "egress_usd_total": sum(s.egress_usd for s in steps),
            "replacements": sum(1 for s in steps if s.replaced),
        }


def _round_placement(p: np.ndarray) -> Tuple[Tuple[float, ...], ...]:
    """Trace form of a placement (6-decimal, deterministic)."""
    return tuple(tuple(round(float(v), 6) for v in row) for row in p)


def run_placement_scenario(spec: Union[str, ScenarioSpec],
                           query: Optional[QuerySpec] = None,
                           seed: int = 0, backend: str = "wanify",
                           predictor: Any = None,
                           overlay: Optional[str] = None
                           ) -> PlacementScenarioResult:
    """Drive one scenario with a placement planner riding the loop.

    `spec` is a named scenario or a full :class:`ScenarioSpec`
    (timelines containing `Rescale` are rejected — a placed query's DC
    span is fixed); `query` defaults to the `scan_agg` workload over
    the spec's pod count. `overlay` gates Terra-style relay routing
    (None defers to $REPRO_OVERLAY): when on, the ``wanify`` backend
    prices AND executes against the routed surface — relayed pairs
    carry their store-and-forward credit in the ground-truth fill.
    """
    if isinstance(spec, str):
        spec = get_scenario(spec)
    if any(isinstance(t.event, Rescale) for t in spec.events):
        raise ValueError(
            f"scenario {spec.name!r} rescales the pod count mid-run; a "
            f"placed query spans a fixed DC set — use a non-elastic "
            f"timeline for placement runs")
    if query is None:
        query = scan_agg(spec.n_pods)
    eng = ScenarioEngine(spec, seed=seed, predictor=predictor,
                         overlay=overlay)
    planner = PlacementPlanner(eng.controller, query, backend=backend)
    trace = PlacementTrace(scenario=spec.name, query=query.name,
                           backend=backend, seed=seed)
    seen = [len(planner.records)]

    def hook(engine: ScenarioEngine, row) -> None:
        P = engine.controller.n_pods
        routing = None
        if backend == "wanify":
            conns = engine.controller.current_conns()
            routing = engine.controller.current_routing()
        else:
            conns = np.ones((engine.sim.N, engine.sim.N))
        if routing is None:
            true_bw = engine.sim.waterfill(conns)[:P, :P]
        else:
            true_bw = engine.sim.waterfill_routed(*routing)[:P, :P]
        cost = planner.evaluate(true_bw)
        off = ~np.eye(P, dtype=bool)
        trace.steps.append(PlacementStepTrace(
            step=row.step, events=row.events,
            replaced=len(planner.records) > seen[0],
            plan_sig=row.plan_sig,
            makespan_s=float(cost.makespan_s),
            net_s=float(cost.net_s),
            egress_usd=float(cost.egress_usd),
            achieved_min=float(true_bw[off].min()),
            placement=_round_placement(planner.placement)))
        seen[0] = len(planner.records)

    eng.step_hook = hook
    eng.run()
    return PlacementScenarioResult(trace=trace,
                                   records=tuple(planner.records))


def compare_backends(spec: Union[str, ScenarioSpec],
                     query: Optional[QuerySpec] = None,
                     seed: int = 0) -> Dict[str, Any]:
    """The paper's comparison on one scenario: WANify-predicted-BW
    placement vs the static single-connection ablation, same seed, same
    WAN weather. Positive deltas mean WANify is better (lower)."""
    wan = run_placement_scenario(spec, query=query, seed=seed,
                                 backend="wanify").summary()
    static = run_placement_scenario(spec, query=query, seed=seed,
                                    backend="static").summary()
    return {
        "scenario": wan["scenario"],
        "query": wan["query"],
        "seed": seed,
        "wanify": wan,
        "static": static,
        "latency_delta_pct": (1.0 - wan["makespan_total_s"]
                              / max(static["makespan_total_s"], 1e-9))
        * 100.0,
        "egress_delta_pct": (1.0 - wan["egress_usd_total"]
                             / max(static["egress_usd_total"], 1e-9))
        * 100.0,
    }
