"""repro.placement — the GDA query layer that consumes WANify BW.

The paper's value proposition is that accurate runtime WAN bandwidth
lets geo-distributed analytics place tasks and data better (§2, §5);
this package is that consumer: a stage-DAG query model with named
workloads (`query.py`), a latency + egress-cost estimator priced
against predicted-BW x heterogeneous connections — with a batched
evaluator that prices thousands of candidates per launch
(`cost.py::estimate_cost_batch`, numpy bit-exact / jax jit backends) —
a deterministic batched placement search with an exhaustive reference
and a lock-step multi-job driver (`optimizer.py`), a
:class:`PlacementPlanner` that re-places on every controller replan
trigger (`planner.py`), and scripted placement runs with
byte-replayable traces plus the static-BW ablation comparison
(`scenario.py`). See DESIGN.md ("The placement planner", "Batched
placement search").
"""
from repro.placement.cost import (INSTANCE_USD_PER_HOUR,
                                  PLACEMENT_BACKENDS, PlacementCost,
                                  PlacementCostBatch, StageCost,
                                  achievable_bw, bottleneck_time_s,
                                  estimate_cost, estimate_cost_batch,
                                  placement_backend, shuffle_matrix)
from repro.placement.optimizer import (PlacementDecision, SearchTask,
                                       better, exhaustive_place,
                                       greedy_place, initial_placement,
                                       search_many)
from repro.placement.planner import (BACKENDS, PlacementPlanner,
                                     PlacementRecord)
from repro.placement.query import (WORKLOADS, QuerySpec, Stage,
                                   get_workload, iterative, scan_agg,
                                   skewed_partitions, two_stage_join,
                                   workload_names)
from repro.placement.scenario import (PlacementScenarioResult,
                                      PlacementStepTrace, PlacementTrace,
                                      compare_backends,
                                      run_placement_scenario)

__all__ = [
    "QuerySpec", "Stage", "skewed_partitions",
    "WORKLOADS", "get_workload", "workload_names",
    "scan_agg", "two_stage_join", "iterative",
    "PlacementCost", "StageCost", "estimate_cost", "achievable_bw",
    "shuffle_matrix", "bottleneck_time_s", "INSTANCE_USD_PER_HOUR",
    "PlacementCostBatch", "estimate_cost_batch", "placement_backend",
    "PLACEMENT_BACKENDS",
    "PlacementDecision", "greedy_place", "exhaustive_place",
    "initial_placement", "better", "SearchTask", "search_many",
    "PlacementPlanner", "PlacementRecord", "BACKENDS",
    "PlacementTrace", "PlacementStepTrace", "PlacementScenarioResult",
    "run_placement_scenario", "compare_backends",
]
