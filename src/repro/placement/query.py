"""GDA query model (paper §2) — the analytics workload whose placement
WANify's runtime-BW gauging improves.

A geo-distributed query is a chain of stages over per-DC input
partitions: stage 0 (the map) processes each partition where it sits;
every later stage is placed — a per-DC task-fraction vector decides
where its tasks (and therefore the shuffle's destination bytes) go.
Between consecutive stages the intermediate data is shuffled all-to-all
(DC i ships `held_i * frac_j` to DC j), which is exactly the transfer
matrix the paper's Fig. 2d bottleneck formula prices against per-pair
runtime BW.

The model deliberately carries the paper's three heterogeneity knobs:

  * skewed partitions (§3.3.1) — `skewed_partitions` builds per-DC
    input sizes with a deterministic skew factor;
  * heterogeneous compute (§5.4) — `QuerySpec.compute_speed` scales
    each DC's task throughput;
  * varying DC count (§3.3.2 / §5.5) — every workload builder takes
    `n` so the same query shape spans 3..8 DCs.

`WORKLOADS` names the library: a TPC-style scan→aggregate, a two-stage
join (two shuffles), and an iterative multi-wave job whose shuffle
repeats (PageRank-style) so network time dominates.

Volumes are in Gb (gigabits), matching the benchmark query model; the
cost layer (`repro.placement.cost`) converts to GB for egress pricing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class Stage:
    """One query stage.

    `out_ratio` is output Gb per input Gb (selectivity), `compute_s_per_gb`
    the task time per input Gb at unit compute speed, and `waves` repeats
    the stage's shuffle+compute (iterative jobs re-shuffle the same
    volume every wave).
    """

    name: str
    out_ratio: float
    compute_s_per_gb: float
    waves: int = 1


@dataclass(frozen=True)
class QuerySpec:
    """A named stage chain over per-DC input partitions.

    `input_gb` are the per-DC partition sizes (Gb); stage 0 runs in
    place on them, and each of the remaining `n_shuffles()` stages is
    placed by a task-fraction vector. `compute_speed` (default all
    ones) is the per-DC relative task throughput — the §5.4
    heterogeneous-compute knob.
    """

    name: str
    input_gb: Tuple[float, ...]
    stages: Tuple[Stage, ...]
    compute_speed: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        """Validate shapes and positivity once, at construction."""
        if len(self.stages) < 1:
            raise ValueError("a query needs at least one stage")
        if len(self.input_gb) < 2:
            raise ValueError("a GDA query spans >= 2 DCs")
        if any(v < 0 for v in self.input_gb):
            raise ValueError("input partition sizes must be >= 0")
        if self.compute_speed is not None and \
                len(self.compute_speed) != len(self.input_gb):
            raise ValueError(
                f"compute_speed has {len(self.compute_speed)} entries "
                f"for {len(self.input_gb)} DCs")

    @property
    def n(self) -> int:
        """Number of DCs the query spans."""
        return len(self.input_gb)

    def n_shuffles(self) -> int:
        """Number of placed stages (= shuffle boundaries)."""
        return len(self.stages) - 1

    def inputs(self) -> np.ndarray:
        """Per-DC input partition sizes as an array (Gb)."""
        return np.asarray(self.input_gb, np.float64)

    def speeds(self) -> np.ndarray:
        """Per-DC compute speeds (default all ones)."""
        if self.compute_speed is None:
            return np.ones(self.n)
        return np.asarray(self.compute_speed, np.float64)


def skewed_partitions(n: int, total_gb: float,
                      skew: float = 1.0) -> Tuple[float, ...]:
    """Deterministic per-DC partition sizes summing to `total_gb`:
    DC 0 carries `skew`x the weight of DC n-1, linear in between
    (the §3.3.1 data-skew knob, reproducible without an RNG)."""
    if n < 2:
        raise ValueError("need >= 2 DCs")
    w = np.array([1.0 + (skew - 1.0) * (n - 1 - i) / (n - 1)
                  for i in range(n)])
    w = np.maximum(w, 1e-6)
    return tuple(float(v) for v in w / w.sum() * total_gb)


# ----------------------------------------------------------------------
# The workload library — named, deterministic query shapes
# ----------------------------------------------------------------------
def scan_agg(n: int, total_gb: float = 60.0, skew: float = 2.0,
             speed: Optional[Tuple[float, ...]] = None) -> QuerySpec:
    """TPC-style scan -> aggregate: one selective map, one shuffle into
    a cheap reduction (the paper's light query class, e.g. q82/q95)."""
    return QuerySpec(
        name="scan_agg",
        input_gb=skewed_partitions(n, total_gb, skew),
        stages=(Stage("scan", out_ratio=0.4, compute_s_per_gb=2.0),
                Stage("agg", out_ratio=0.05, compute_s_per_gb=1.0)),
        compute_speed=speed)


def two_stage_join(n: int, total_gb: float = 90.0, skew: float = 3.0,
                   speed: Optional[Tuple[float, ...]] = None) -> QuerySpec:
    """Two-shuffle join: scan -> join (output grows) -> aggregate (the
    paper's heavy class, e.g. q78 — two placed stages couple through
    the first stage's destination distribution)."""
    return QuerySpec(
        name="two_stage_join",
        input_gb=skewed_partitions(n, total_gb, skew),
        stages=(Stage("scan", out_ratio=0.6, compute_s_per_gb=1.5),
                Stage("join", out_ratio=1.2, compute_s_per_gb=3.0),
                Stage("agg", out_ratio=0.1, compute_s_per_gb=1.0)),
        compute_speed=speed)


def iterative(n: int, total_gb: float = 40.0, skew: float = 1.5,
              waves: int = 5,
              speed: Optional[Tuple[float, ...]] = None) -> QuerySpec:
    """Iterative multi-wave job (PageRank-style): one placed stage whose
    shuffle+compute repeats `waves` times, so the network term — and
    therefore BW-aware placement — dominates the makespan."""
    return QuerySpec(
        name="iterative",
        input_gb=skewed_partitions(n, total_gb, skew),
        stages=(Stage("prepare", out_ratio=1.0, compute_s_per_gb=1.0),
                Stage("iterate", out_ratio=1.0, compute_s_per_gb=2.0,
                      waves=waves)),
        compute_speed=speed)


WORKLOADS: Dict[str, Callable[..., QuerySpec]] = {
    "scan_agg": scan_agg,
    "two_stage_join": two_stage_join,
    "iterative": iterative,
}


def get_workload(name: str, n: int, **kwargs) -> QuerySpec:
    """Build a named workload over `n` DCs (KeyError lists the names)."""
    if name not in WORKLOADS:
        raise KeyError(f"unknown workload {name!r}; "
                       f"have {sorted(WORKLOADS)}")
    return WORKLOADS[name](n, **kwargs)


def workload_names() -> List[str]:
    """All named workloads, library order."""
    return list(WORKLOADS)
