"""BW-aware task placement search (paper §2, §5's latency/cost tables).

A placement assigns each shuffle stage a per-DC task-fraction vector.
The search minimizes the estimated query makespan under a given
achievable-BW matrix, preferring lower egress cost among near-equal
makespans (the paper's placements cut latency up to 26% AND cost up to
16% — latency first, dollars as the tie-break within `rel_tol`).

Three deterministic searches, no RNG anywhere (placement traces must
byte-replay):

  * `greedy_place` — data-proportional start, then coarse+fine
    mass-move local search (move `delta` of one stage's fraction from
    DC a to DC b whenever it helps);
  * `exhaustive_place` — the reference optimum on a fraction grid for
    N <= 4 (tests pin the greedy search against it);
  * `initial_placement` — the Iridium-style leave-data-in-place
    baseline both start from.

The hot path is BATCHED: every round's feasible moves are materialized
as one ``[M, S, N]`` candidate tensor (base placement + sparse ±delta
updates, no per-move copies) and priced in a single
:func:`repro.placement.cost.estimate_cost_batch` launch; only the
winner's full breakdown is built from the scalar reference. Searches
are written as generators yielding candidate tensors, so
:func:`search_many` can drive many jobs' searches in lock-step and fuse
same-shape rounds into shared evaluator launches (the fleet tick path).
Decisions are byte-identical to the historical one-`estimate_cost`-
per-move search (`tests/test_placement_batch.py` pins the goldens).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import (Any, Dict, Generator, Iterator, List, Optional, Tuple,
                    Union)

import numpy as np

from repro.placement.cost import (INSTANCE_USD_PER_HOUR, PlacementCost,
                                  _eval_packed, estimate_cost,
                                  estimate_cost_batch, pack_query,
                                  placement_backend)
from repro.placement.query import QuerySpec

EXHAUSTIVE_CHUNK = 4096       # candidate rows per exhaustive-grid launch


@dataclass(frozen=True)
class PlacementDecision:
    """A search result: the placement, its estimated cost, and how many
    cost evaluations the search spent."""

    placement: Tuple[Tuple[float, ...], ...]    # [n_shuffles, N]
    cost: PlacementCost
    evals: int

    def frac(self) -> np.ndarray:
        """The placement as a mutable [n_shuffles, N] array."""
        return np.asarray(self.placement, np.float64)


def _better_vals(mk_a: float, eg_a: float, mk_b: float, eg_b: float,
                 rel_tol: float = 0.01) -> bool:
    """:func:`better` on raw (makespan, egress) values — what the
    batched rounds compare without building cost objects."""
    if mk_a < mk_b * (1.0 - rel_tol):
        return True
    return mk_a <= mk_b * (1.0 + rel_tol) and eg_a < eg_b * (1.0 - 1e-9)


def better(a: PlacementCost, b: PlacementCost,
           rel_tol: float = 0.01) -> bool:
    """True when `a` beats `b` as a *candidate within one round*:
    makespan lower by more than `rel_tol`, or makespan within the band
    and egress strictly cheaper. This orders candidate moves (dollars
    break latency near-ties); *acceptance* of a move over the current
    placement always requires a strict makespan improvement, so the
    egress preference can never walk the latency uphill."""
    return _better_vals(a.makespan_s, a.egress_usd,
                        b.makespan_s, b.egress_usd, rel_tol)


def initial_placement(query: QuerySpec) -> np.ndarray:
    """Data-proportional start ([n_shuffles, N]): every stage keeps
    tasks where the input partitions sit (Iridium's default), which is
    also the egress-friendly anchor the local search refines from."""
    inputs = query.inputs()
    total = inputs.sum()
    frac = inputs / total if total > 0 else np.ones(query.n) / query.n
    return np.tile(frac, (query.n_shuffles(), 1))


def _moves(placement: np.ndarray, delta: float
           ) -> Tuple[np.ndarray, List[Tuple[int, int, int]]]:
    """Materialize every feasible (stage, src, dst, delta) mass move of
    one round as a single candidate tensor.

    Returns ``(cands [M,S,N], moves)`` where row m is the base
    placement with ``delta`` moved from `moves[m] = (s, a, b)` —
    built with one allocation plus two sparse scatters instead of M
    per-move copies. Enumeration order (stage, src, dst) matches the
    historical scalar search, so sequential tie-breaks are unchanged.
    """
    S, n = placement.shape
    moves: List[Tuple[int, int, int]] = []
    for s in range(S):
        for a in range(n):
            if placement[s, a] < delta - 1e-12:
                continue
            for b in range(n):
                if a != b:
                    moves.append((s, a, b))
    M = len(moves)
    cands = np.broadcast_to(placement, (M, S, n)).copy()
    if M:
        mv = np.asarray(moves, np.intp)
        idx = np.arange(M)
        cands[idx, mv[:, 0], mv[:, 1]] -= delta
        cands[idx, mv[:, 0], mv[:, 2]] += delta
    return cands, moves


# A search generator yields candidate tensors [M,S,N] and receives the
# batch's (makespan_s [M], egress_usd [M]) back; its return value is
# (final placement, evals spent).
SearchGen = Generator[np.ndarray, Tuple[np.ndarray, np.ndarray],
                      Tuple[np.ndarray, int]]


def _greedy_gen(placement: np.ndarray, coarse: float, fine: float,
                rel_tol: float, max_rounds: int) -> SearchGen:
    """The greedy search as a batch-request generator: steepest-descent
    rounds at coarse then fine granularity (latency-strict acceptance,
    egress breaks near-ties via :func:`_better_vals`), then the
    anchored egress-polish walk along the converged-makespan plateau.
    One yield per round prices every feasible move at once."""
    evals = 0
    best_mk = best_eg = None
    for delta in (coarse, fine):
        if delta <= 0:
            continue
        mks, egs = yield placement[None]        # price the current start
        evals += 1
        best_mk, best_eg = float(mks[0]), float(egs[0])
        for _ in range(max_rounds):
            cands, moves = _moves(placement, delta)
            if not moves:
                break
            mks, egs = yield cands
            evals += len(moves)
            # acceptance is latency-strict; `_better_vals` then picks
            # the round winner in enumeration order (deterministic)
            cand: Optional[int] = None
            for i in np.nonzero(mks < best_mk * (1.0 - 1e-9))[0]:
                if cand is None or _better_vals(mks[i], egs[i],
                                                mks[cand], egs[cand],
                                                rel_tol):
                    cand = int(i)
            if cand is None:
                break
            s, a, b = moves[cand]
            placement[s, a] -= delta
            placement[s, b] += delta
            best_mk, best_eg = float(mks[cand]), float(egs[cand])
    if best_mk is None:             # search disabled: price the baseline
        mks, egs = yield placement[None]
        evals += 1
        best_mk, best_eg = float(mks[0]), float(egs[0])
    if fine > 0:
        # walk the makespan plateau toward cheaper egress: the anchored
        # bound never ratchets, and egress strictly decreases each
        # accepted move, so this terminates
        anchor = best_mk * (1.0 + 1e-9)
        for _ in range(max_rounds):
            cands, moves = _moves(placement, fine)
            if not moves:
                break
            mks, egs = yield cands
            evals += len(moves)
            ok = (mks <= anchor) & (egs < best_eg * (1.0 - 1e-12))
            cand = None
            for i in np.nonzero(ok)[0]:
                if cand is None or (egs[i], mks[i]) < (egs[cand],
                                                       mks[cand]):
                    cand = int(i)
            if cand is None:
                break
            s, a, b = moves[cand]
            placement[s, a] -= fine
            placement[s, b] += fine
            best_mk, best_eg = float(mks[cand]), float(egs[cand])
    return placement, evals


def _compositions(levels: int, n: int) -> Iterator[Tuple[int, ...]]:
    """All length-`n` tuples of non-negative ints summing to `levels`."""
    if n == 1:
        yield (levels,)
        return
    for head in range(levels + 1):
        for tail in _compositions(levels - head, n - 1):
            yield (head,) + tail


def _exhaustive_gen(query: QuerySpec, levels: int,
                    chunk: int = EXHAUSTIVE_CHUNK) -> SearchGen:
    """The composition-grid reference as a batch-request generator:
    the grid is priced in chunked launches, and each chunk's winner is
    the first index attaining the chunk-minimal (makespan, egress)
    pair (stable lexsort == the historical sequential strict-< scan)."""
    grid = np.asarray(list(_compositions(levels, query.n)),
                      np.float64) / levels                   # [K, N]
    S = query.n_shuffles()
    evals = 0
    best: Optional[Tuple[float, float]] = None
    best_p: Optional[np.ndarray] = None
    combos = itertools.product(range(len(grid)), repeat=S)
    while True:
        idx = np.asarray(list(itertools.islice(combos, chunk)), np.intp)
        if not len(idx):
            break
        cands = grid[idx]                                    # [m, S, N]
        mks, egs = yield cands
        evals += len(idx)
        # plain lexicographic (makespan, egress) — transitive, so the
        # reference optimum is enumeration-order independent
        w = int(np.lexsort((egs, mks))[0])
        if best is None or (float(mks[w]), float(egs[w])) < best:
            best = (float(mks[w]), float(egs[w]))
            best_p = cands[w]
    return best_p, evals


# ----------------------------------------------------------------------
# drivers — one search, or many in lock-step
# ----------------------------------------------------------------------
@dataclass
class SearchTask:
    """One placement search to drive: the query, the achievable-BW
    matrix it prices against, and the search knobs. `gen` defaults to
    the greedy search; :func:`search_many` batches rounds of many tasks
    into shared evaluator launches."""

    query: QuerySpec
    bw: np.ndarray
    egress_usd_per_gb: Any = None
    coarse: float = 0.1
    fine: float = 0.02
    rel_tol: float = 0.01
    max_rounds: int = 200
    gen: Optional[SearchGen] = field(default=None, repr=False)

    def start(self) -> SearchGen:
        """Build (once) and return the underlying search generator."""
        if self.gen is not None and self.gen.gi_frame is None:
            raise ValueError(
                "this SearchTask's search already ran to completion; "
                "build a fresh SearchTask to search again")
        if self.gen is None:
            self.gen = _greedy_gen(initial_placement(self.query),
                                   self.coarse, self.fine, self.rel_tol,
                                   self.max_rounds)
        return self.gen


def _finish(task: SearchTask, placement: np.ndarray,
            evals: int) -> PlacementDecision:
    """Build the winner's full breakdown — the one scalar
    :func:`estimate_cost` call of the whole search."""
    cost = estimate_cost(task.query, placement, task.bw,
                         egress_usd_per_gb=task.egress_usd_per_gb)
    return PlacementDecision(
        placement=tuple(tuple(float(v) for v in row) for row in placement),
        cost=cost, evals=evals)


def _drive_single(task: SearchTask,
                  backend: Optional[str]) -> PlacementDecision:
    """Run one search generator to completion against the backend."""
    gen = task.start()
    try:
        req = next(gen)
        while True:
            batch = estimate_cost_batch(
                task.query, req, task.bw,
                egress_usd_per_gb=task.egress_usd_per_gb,
                backend=backend)
            req = gen.send((batch.makespan_s, batch.egress_usd))
    except StopIteration as stop:
        placement, evals = stop.value
    return _finish(task, placement, evals)


def search_many(tasks: List[SearchTask],
                backend: Optional[str] = None) -> List[PlacementDecision]:
    """Drive many searches in lock-step, fusing each round's candidate
    tensors into shared evaluator launches.

    Tasks whose pending requests share a (n_shuffles, N) shape are
    concatenated along the candidate axis and priced in ONE packed
    backend call (per-candidate bw/price/speed/stage rows — bit-exact
    per row, so fusing never changes a decision); tasks with different
    shapes fall into separate groups. This is the fleet-tick path: J
    jobs' per-tick searches cost rounds-many launches total instead of
    J independent Python searches (`fleet/controller.py`).
    """
    backend = placement_backend(backend)
    gens = [t.start() for t in tasks]
    pending: Dict[int, np.ndarray] = {}
    results: Dict[int, PlacementDecision] = {}
    for i, gen in enumerate(gens):
        try:
            pending[i] = next(gen)
        except StopIteration as stop:
            results[i] = _finish(tasks[i], *stop.value)
    while pending:
        groups: Dict[Tuple[int, int], List[int]] = {}
        for i, req in pending.items():
            groups.setdefault(req.shape[1:], []).append(i)
        replies: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        for members in groups.values():
            if backend == "scalar" or len(members) == 1:
                for i in members:
                    b = estimate_cost_batch(
                        tasks[i].query, pending[i], tasks[i].bw,
                        egress_usd_per_gb=tasks[i].egress_usd_per_gb,
                        backend=backend)
                    replies[i] = (b.makespan_s, b.egress_usd)
                continue
            sizes = [len(pending[i]) for i in members]
            cands = np.concatenate([pending[i] for i in members])
            n = cands.shape[2]
            bw3 = np.concatenate([
                np.broadcast_to(tasks[i].bw[None], (m, n, n))
                for i, m in zip(members, sizes)])
            packs = [pack_query(tasks[i].query,
                                tasks[i].egress_usd_per_gb)
                     for i in members]
            packed = {key: np.concatenate([
                np.broadcast_to(p[key][None],
                                (m,) + p[key].shape)
                for p, m in zip(packs, sizes)])
                for key in packs[0]}
            batch = _eval_packed(cands, bw3, packed,
                                 INSTANCE_USD_PER_HOUR, backend)
            lo = 0
            for i, m in zip(members, sizes):
                replies[i] = (batch.makespan_s[lo:lo + m],
                              batch.egress_usd[lo:lo + m])
                lo += m
        nxt: Dict[int, np.ndarray] = {}
        for i, reply in replies.items():
            try:
                nxt[i] = gens[i].send(reply)
            except StopIteration as stop:
                results[i] = _finish(tasks[i], *stop.value)
        pending = nxt
    return [results[i] for i in range(len(tasks))]


# ----------------------------------------------------------------------
# public searches
# ----------------------------------------------------------------------
def greedy_place(query: QuerySpec, bw_mbps: np.ndarray, *,
                 egress_usd_per_gb: Union[float, np.ndarray, None] = None,
                 coarse: float = 0.1, fine: float = 0.02,
                 rel_tol: float = 0.01,
                 max_rounds: int = 200,
                 backend: Optional[str] = None) -> PlacementDecision:
    """Greedy reducer placement + local-search refinement: start from
    the data-proportional baseline, descend with `coarse` mass moves,
    polish with `fine` ones, then consolidate free (plateau) mass
    toward cheaper egress without giving back any converged makespan.
    Deterministic; O(rounds * S * N^2) cost evaluations, batched one
    launch per round (`backend` as in :func:`estimate_cost_batch`)."""
    task = SearchTask(query=query,
                      bw=np.asarray(bw_mbps, np.float64),
                      egress_usd_per_gb=egress_usd_per_gb,
                      coarse=coarse, fine=fine, rel_tol=rel_tol,
                      max_rounds=max_rounds)
    return _drive_single(task, backend)


def exhaustive_place(query: QuerySpec, bw_mbps: np.ndarray, *,
                     egress_usd_per_gb: Union[float, np.ndarray,
                                              None] = None,
                     levels: int = 5,
                     backend: Optional[str] = None) -> PlacementDecision:
    """Reference optimum on the fraction grid `{0, 1/levels, ...}` —
    every per-stage composition, every stage combination, priced in
    chunked batches. Exponential; guarded to N <= 4 (its job is to pin
    `greedy_place` in tests)."""
    if query.n > 4:
        raise ValueError(
            f"exhaustive reference is for N <= 4 DCs (got {query.n}); "
            f"use greedy_place for larger meshes")
    task = SearchTask(query=query,
                      bw=np.asarray(bw_mbps, np.float64),
                      egress_usd_per_gb=egress_usd_per_gb,
                      gen=_exhaustive_gen(query, levels))
    return _drive_single(task, backend)
