"""BW-aware task placement search (paper §2, §5's latency/cost tables).

A placement assigns each shuffle stage a per-DC task-fraction vector.
The search minimizes the estimated query makespan under a given
achievable-BW matrix, preferring lower egress cost among near-equal
makespans (the paper's placements cut latency up to 26% AND cost up to
16% — latency first, dollars as the tie-break within `rel_tol`).

Three deterministic searches, no RNG anywhere (placement traces must
byte-replay):

  * `greedy_place` — data-proportional start, then coarse+fine
    mass-move local search (move `delta` of one stage's fraction from
    DC a to DC b whenever it helps);
  * `exhaustive_place` — the reference optimum on a fraction grid for
    N <= 4 (tests pin the greedy search against it);
  * `initial_placement` — the Iridium-style leave-data-in-place
    baseline both start from.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple, Union

import numpy as np

from repro.placement.cost import PlacementCost, estimate_cost
from repro.placement.query import QuerySpec


@dataclass(frozen=True)
class PlacementDecision:
    """A search result: the placement, its estimated cost, and how many
    cost evaluations the search spent."""

    placement: Tuple[Tuple[float, ...], ...]    # [n_shuffles, N]
    cost: PlacementCost
    evals: int

    def frac(self) -> np.ndarray:
        """The placement as a mutable [n_shuffles, N] array."""
        return np.asarray(self.placement, np.float64)


def better(a: PlacementCost, b: PlacementCost,
           rel_tol: float = 0.01) -> bool:
    """True when `a` beats `b` as a *candidate within one round*:
    makespan lower by more than `rel_tol`, or makespan within the band
    and egress strictly cheaper. This orders candidate moves (dollars
    break latency near-ties); *acceptance* of a move over the current
    placement always requires a strict makespan improvement, so the
    egress preference can never walk the latency uphill."""
    if a.makespan_s < b.makespan_s * (1.0 - rel_tol):
        return True
    return (a.makespan_s <= b.makespan_s * (1.0 + rel_tol)
            and a.egress_usd < b.egress_usd * (1.0 - 1e-9))


def initial_placement(query: QuerySpec) -> np.ndarray:
    """Data-proportional start ([n_shuffles, N]): every stage keeps
    tasks where the input partitions sit (Iridium's default), which is
    also the egress-friendly anchor the local search refines from."""
    inputs = query.inputs()
    total = inputs.sum()
    frac = inputs / total if total > 0 else np.ones(query.n) / query.n
    return np.tile(frac, (query.n_shuffles(), 1))


def _moves(placement: np.ndarray, delta: float
           ) -> Iterator[Tuple[int, int, int]]:
    """All (stage, src, dst) mass moves of `delta` currently feasible."""
    S, n = placement.shape
    for s in range(S):
        for a in range(n):
            if placement[s, a] < delta - 1e-12:
                continue
            for b in range(n):
                if a != b:
                    yield s, a, b


def _improve(query: QuerySpec, placement: np.ndarray,
             bw: np.ndarray, delta: float, *,
             egress_usd_per_gb, rel_tol: float,
             max_rounds: int) -> Tuple[np.ndarray, PlacementCost, int]:
    """Steepest-descent mass moves at one granularity: per round,
    evaluate every feasible (stage, src, dst, delta) move; only moves
    that strictly lower the makespan are acceptable, and among those
    the `better` ordering picks the winner (egress breaks latency
    near-ties). Ties fall to enumeration order — deterministic."""
    best = estimate_cost(query, placement, bw,
                         egress_usd_per_gb=egress_usd_per_gb)
    evals = 1
    for _ in range(max_rounds):
        cand_cost: Optional[PlacementCost] = None
        cand_move: Optional[Tuple[int, int, int]] = None
        for s, a, b in _moves(placement, delta):
            trial = placement.copy()
            trial[s, a] -= delta
            trial[s, b] += delta
            c = estimate_cost(query, trial, bw,
                              egress_usd_per_gb=egress_usd_per_gb)
            evals += 1
            if c.makespan_s >= best.makespan_s * (1.0 - 1e-9):
                continue                     # acceptance is latency-strict
            if cand_cost is None or better(c, cand_cost, rel_tol):
                cand_cost, cand_move = c, (s, a, b)
        if cand_move is None:
            break
        s, a, b = cand_move
        placement[s, a] -= delta
        placement[s, b] += delta
        best = cand_cost
    return placement, best, evals


def _polish_egress(query: QuerySpec, placement: np.ndarray,
                   bw: np.ndarray, delta: float, *,
                   egress_usd_per_gb, best: PlacementCost,
                   max_rounds: int) -> Tuple[np.ndarray, PlacementCost,
                                             int]:
    """Walk the makespan plateau toward cheaper egress: the bottleneck
    `max` leaves non-critical mass free to consolidate, so moves that
    strictly cut egress WITHOUT exceeding the converged makespan
    (anchored — the bound never ratchets) are free money. Egress
    strictly decreases each accepted move, so this terminates."""
    anchor = best.makespan_s * (1.0 + 1e-9)
    evals = 0
    for _ in range(max_rounds):
        cand_cost: Optional[PlacementCost] = None
        cand_move: Optional[Tuple[int, int, int]] = None
        for s, a, b in _moves(placement, delta):
            trial = placement.copy()
            trial[s, a] -= delta
            trial[s, b] += delta
            c = estimate_cost(query, trial, bw,
                              egress_usd_per_gb=egress_usd_per_gb)
            evals += 1
            if c.makespan_s > anchor or \
                    c.egress_usd >= best.egress_usd * (1.0 - 1e-12):
                continue
            if cand_cost is None or \
                    (c.egress_usd, c.makespan_s) < \
                    (cand_cost.egress_usd, cand_cost.makespan_s):
                cand_cost, cand_move = c, (s, a, b)
        if cand_move is None:
            break
        s, a, b = cand_move
        placement[s, a] -= delta
        placement[s, b] += delta
        best = cand_cost
    return placement, best, evals


def greedy_place(query: QuerySpec, bw_mbps: np.ndarray, *,
                 egress_usd_per_gb: Union[float, np.ndarray, None] = None,
                 coarse: float = 0.1, fine: float = 0.02,
                 rel_tol: float = 0.01,
                 max_rounds: int = 200) -> PlacementDecision:
    """Greedy reducer placement + local-search refinement: start from
    the data-proportional baseline, descend with `coarse` mass moves,
    polish with `fine` ones, then consolidate free (plateau) mass
    toward cheaper egress without giving back any converged makespan.
    Deterministic; O(rounds * S * N^2) cost evaluations."""
    bw = np.asarray(bw_mbps, np.float64)
    placement = initial_placement(query)
    cost: Optional[PlacementCost] = None
    evals = 0
    for delta in (coarse, fine):
        if delta <= 0:
            continue
        placement, cost, e = _improve(
            query, placement, bw, delta,
            egress_usd_per_gb=egress_usd_per_gb, rel_tol=rel_tol,
            max_rounds=max_rounds)
        evals += e
    if cost is None:            # search disabled: price the baseline
        cost = estimate_cost(query, placement, bw,
                             egress_usd_per_gb=egress_usd_per_gb)
        evals += 1
    if fine > 0:
        placement, cost, e = _polish_egress(
            query, placement, bw, fine,
            egress_usd_per_gb=egress_usd_per_gb, best=cost,
            max_rounds=max_rounds)
        evals += e
    return PlacementDecision(
        placement=tuple(tuple(float(v) for v in row) for row in placement),
        cost=cost, evals=evals)


def _compositions(levels: int, n: int) -> Iterator[Tuple[int, ...]]:
    """All length-`n` tuples of non-negative ints summing to `levels`."""
    if n == 1:
        yield (levels,)
        return
    for head in range(levels + 1):
        for tail in _compositions(levels - head, n - 1):
            yield (head,) + tail


def exhaustive_place(query: QuerySpec, bw_mbps: np.ndarray, *,
                     egress_usd_per_gb: Union[float, np.ndarray,
                                              None] = None,
                     levels: int = 5) -> PlacementDecision:
    """Reference optimum on the fraction grid `{0, 1/levels, ...}` —
    every per-stage composition, every stage combination. Exponential;
    guarded to N <= 4 (its job is to pin `greedy_place` in tests)."""
    if query.n > 4:
        raise ValueError(
            f"exhaustive reference is for N <= 4 DCs (got {query.n}); "
            f"use greedy_place for larger meshes")
    bw = np.asarray(bw_mbps, np.float64)
    grid: List[np.ndarray] = [np.asarray(c, np.float64) / levels
                              for c in _compositions(levels, query.n)]
    best: Optional[PlacementCost] = None
    best_p: Optional[np.ndarray] = None
    evals = 0
    for combo in itertools.product(grid, repeat=query.n_shuffles()):
        p = np.stack(combo)
        c = estimate_cost(query, p, bw,
                          egress_usd_per_gb=egress_usd_per_gb)
        evals += 1
        # plain lexicographic (makespan, egress) — transitive, so the
        # reference optimum is enumeration-order independent
        if best is None or (c.makespan_s, c.egress_usd) < \
                (best.makespan_s, best.egress_usd):
            best, best_p = c, p
    return PlacementDecision(
        placement=tuple(tuple(float(v) for v in row) for row in best_p),
        cost=best, evals=evals)
