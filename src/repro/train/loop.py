"""Host-side training orchestration.

Per step: data -> jit'd train step. Around it, the pieces a 1000-node
deployment needs:

  * WANify control plane — the Trainer consumes plans from the shared
    `repro.control.WanifyController` (snapshot -> RF prediction ->
    global optimization -> AIMD -> WanPlan). Periodic and straggler
    triggers swap in new plans; the controller's plan cache is keyed by
    plan signature so oscillating plans never recompile.
  * fault tolerance — async sharded checkpoints every `ckpt_every`;
    `Trainer.restore_or_init` resumes from the newest complete manifest
    (crash/restart contract). Simulated step failures retry from the last
    checkpoint.
  * straggler mitigation — per-step wall-time EWMA; a step slower than
    `straggler_factor` x EWMA triggers an AIMD multiplicative-decrease on
    the slow pod's links + immediate re-plan (and is recorded).
  * elastic rescale — `Trainer.rescale(new_mesh)` rebuilds the step for a
    new pod count; the RF predicts BW for the new cluster size (§3.3.2)
    and checkpoints are mesh-agnostic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro import compat
from repro.checkpoint import ckpt as ckpt_lib
from repro.configs.base import ModelConfig
from repro.control import ControllerConfig, WanifyController
from repro.core.plan import WanPlan
from repro.core.predictor import BwPredictor
from repro.data.pipeline import DataConfig, batches, pod_skew_weights, prefetch
from repro.models import registry
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step
from repro.wan.simulator import WanSimulator


@dataclass
class LoopConfig:
    steps: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 25
    log_every: int = 10
    sync: str = "wanify"             # wanify | psum
    compress: bool = False
    replan_every: int = 20
    straggler_factor: float = 2.5
    max_conns: int = 8
    use_skew_weights: bool = True
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, dcfg: DataConfig,
                 loop: LoopConfig = LoopConfig(),
                 opt: Optional[AdamWConfig] = None,
                 sim: Optional[WanSimulator] = None,
                 predictor: Optional[BwPredictor] = None):
        self.cfg, self.mesh, self.dcfg, self.loop = cfg, mesh, dcfg, loop
        self.opt = opt or AdamWConfig()
        self.n_pods = mesh.shape.get("pod", 1)
        self.multi_pod = "pod" in mesh.axis_names and self.n_pods > 1
        self.sim = sim
        self.predictor = predictor
        self._step_cache: Dict[Any, Any] = {}
        self.history: List[Dict[str, float]] = []
        self.events: List[str] = []
        # ---- WANify control plane (repro.control) ---------------------
        # The closed loop (snapshot -> prediction -> global optimization
        # -> AIMD -> plan) lives in the shared controller; the Trainer
        # only consumes plans and compiled steps.
        self.controller: Optional[WanifyController] = None
        if self.multi_pod and self.loop.sync == "wanify" and \
                sim is not None and predictor is not None:
            self.controller = WanifyController(
                sim=sim, predictor=predictor, n_pods=self.n_pods,
                cfg=ControllerConfig(
                    max_conns=self.loop.max_conns,
                    replan_every=self.loop.replan_every,
                    straggler_factor=self.loop.straggler_factor),
                events=self.events)
            self._plan: Optional[WanPlan] = None
        elif self.multi_pod:
            self._plan = WanPlan.uniform(self.n_pods)
        else:
            self._plan = None

    @property
    def plan(self) -> Optional[WanPlan]:
        """The plan in force — always the controller's latest when a
        control plane is attached (never a stale copy)."""
        if self.controller is not None:
            return self.controller.plan
        return self._plan

    # ------------------------------------------------------------------
    def _build_step(self, plan: Optional[WanPlan]):
        return jax.jit(
            make_train_step(self.cfg, self.mesh, plan=plan, opt=self.opt,
                            sync=self.loop.sync,
                            compress=self.loop.compress),
            donate_argnums=(0, 1))

    def _get_step(self):
        if self.controller is not None:
            # keyed on plan.signature(): oscillating plans never recompile
            return self.controller.compiled(
                (self.loop.sync, self.loop.compress), self._build_step)
        key = (self.plan.signature() if self.plan else ("single",),
               self.loop.sync, self.loop.compress)
        if key not in self._step_cache:
            self._step_cache[key] = self._build_step(self.plan)
        return self._step_cache[key]

    # ------------------------------------------------------------------
    def restore_or_init(self, key: jax.Array):
        params = registry.init_params(self.cfg, key)
        opt_state = init_opt_state(params)
        start = 0
        if self.loop.ckpt_dir:
            latest = ckpt_lib.latest_step(self.loop.ckpt_dir)
            if latest is not None:
                state = ckpt_lib.restore(self.loop.ckpt_dir,
                                         {"p": params, "o": opt_state})
                params, opt_state = state["p"], state["o"]
                start = latest
                self.events.append(f"restored step {latest}")
        if self.multi_pod:
            # vmap-over-pods formulation: explicit pod-replicated leading
            # dim (checkpoints stay pod-free => elastic across pod counts)
            from repro.train.train_step import broadcast_to_pods
            params = broadcast_to_pods(params, self.n_pods)
            opt_state = broadcast_to_pods(opt_state, self.n_pods)
        return params, opt_state, start

    # ------------------------------------------------------------------
    def run(self, key: jax.Array, fail_at: Optional[int] = None):
        """fail_at: inject a simulated node failure at that step (the
        fault-tolerance test path)."""
        with compat.use_mesh(self.mesh):
            return self._run(key, fail_at)

    def _run(self, key: jax.Array, fail_at: Optional[int] = None):
        params, opt_state, start = self.restore_or_init(key)
        data = prefetch(batches(self.cfg, self.dcfg))
        step_fn = self._get_step()
        writer = None
        step = start
        while step < self.loop.steps:
            batch = {k: jax.numpy.asarray(v) for k, v in next(data).items()}
            t0 = time.perf_counter()
            if fail_at is not None and step == fail_at:
                fail_at = None
                self.events.append(f"simulated failure at step {step}")
                # crash/restart: reload newest complete checkpoint
                params, opt_state, step = self.restore_or_init(key)
                step_fn = self._get_step()
                continue
            params, opt_state, out = step_fn(params, opt_state, batch)
            dt = time.perf_counter() - t0
            # ---- straggler trigger (controller-owned EWMA + AIMD MD) ----
            if self.controller is not None:
                if self.controller.observe_step_time(dt, step=step) \
                        is not None:
                    step_fn = self._get_step()
            # ---- logging -------------------------------------------------
            rec = {"step": step, "loss": float(out["loss"]),
                   "grad_norm": float(out["grad_norm"]), "time": dt}
            self.history.append(rec)
            # ---- WANify periodic re-plan --------------------------------
            if self.controller is not None and \
                    self.controller.replan_due(step):
                skw = pod_skew_weights(np.asarray(batch["tokens"]),
                                       self.n_pods, self.cfg.vocab) \
                    if self.loop.use_skew_weights else None
                if self.controller.maybe_replan(step, skew_w=skw) \
                        is not None:
                    step_fn = self._get_step()
            # ---- checkpoint ----------------------------------------------
            if self.loop.ckpt_dir and (step + 1) % self.loop.ckpt_every == 0:
                if writer is not None:
                    writer.join()
                if self.multi_pod:
                    from repro.train.train_step import strip_pods
                    tree = {"p": strip_pods(params), "o": strip_pods(opt_state)}
                else:
                    tree = {"p": params, "o": opt_state}
                writer = ckpt_lib.save(self.loop.ckpt_dir, step + 1, tree,
                                       async_=True)
            step += 1
        if writer is not None:
            writer.join()
        return params, opt_state

    # ------------------------------------------------------------------
    def rescale(self, new_mesh) -> "Trainer":
        """Elastic scale: new pod count; the controller re-plans for the
        new cluster size (§3.3.2) and checkpoints are mesh-agnostic."""
        t = Trainer(self.cfg, new_mesh, self.dcfg, self.loop, self.opt,
                    self.sim, self.predictor)
        # prepend in place: t.events is shared with t.controller's log
        t.events[:0] = self.events + [f"rescaled to {dict(new_mesh.shape)}"]
        return t
