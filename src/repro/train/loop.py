"""Host-side training orchestration: the WANify runtime controller.

Per step: data -> jit'd train step. Around it, the pieces a 1000-node
deployment needs:

  * WANify controller — every `replan_every` steps takes a 1-second
    snapshot of the (simulated) network, predicts runtime BW with the RF,
    re-runs global optimization, advances the per-pod AIMD agents against
    monitored BW, and swaps in the new WanPlan (jit re-lowers; the cache
    is keyed by plan signature so oscillating plans never recompile).
  * fault tolerance — async sharded checkpoints every `ckpt_every`;
    `Trainer.restore_or_init` resumes from the newest complete manifest
    (crash/restart contract). Simulated step failures retry from the last
    checkpoint.
  * straggler mitigation — per-step wall-time EWMA; a step slower than
    `straggler_factor` x EWMA triggers an AIMD multiplicative-decrease on
    the slow pod's links + immediate re-plan (and is recorded).
  * elastic rescale — `Trainer.rescale(new_mesh)` rebuilds the step for a
    new pod count; the RF predicts BW for the new cluster size (§3.3.2)
    and checkpoints are mesh-agnostic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs.base import ModelConfig
from repro.core.global_opt import global_optimize
from repro.core.local_opt import AimdAgent
from repro.core.plan import WanPlan
from repro.core.predictor import BwPredictor
from repro.data.pipeline import DataConfig, batches, pod_skew_weights, prefetch
from repro.models import registry
from repro.models.sharding import batch_specs, param_specs
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step
from repro.wan.monitor import SnapshotMonitor
from repro.wan.simulator import WanSimulator


@dataclass
class LoopConfig:
    steps: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 25
    log_every: int = 10
    sync: str = "wanify"             # wanify | psum
    compress: bool = False
    replan_every: int = 20
    straggler_factor: float = 2.5
    max_conns: int = 8
    use_skew_weights: bool = True
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, dcfg: DataConfig,
                 loop: LoopConfig = LoopConfig(),
                 opt: Optional[AdamWConfig] = None,
                 sim: Optional[WanSimulator] = None,
                 predictor: Optional[BwPredictor] = None):
        self.cfg, self.mesh, self.dcfg, self.loop = cfg, mesh, dcfg, loop
        self.opt = opt or AdamWConfig()
        self.n_pods = mesh.shape.get("pod", 1)
        self.multi_pod = "pod" in mesh.axis_names and self.n_pods > 1
        self.sim = sim
        self.predictor = predictor
        self._step_cache: Dict[Any, Any] = {}
        self._agents: Optional[List[AimdAgent]] = None
        self.plan = self._initial_plan()
        self.history: List[Dict[str, float]] = []
        self.events: List[str] = []

    # ------------------------------------------------------------------
    # WANify controller
    # ------------------------------------------------------------------
    def _initial_plan(self) -> Optional[WanPlan]:
        if not self.multi_pod:
            return None
        if self.sim is None or self.predictor is None or \
                self.loop.sync != "wanify":
            return WanPlan.uniform(self.n_pods)
        return self._replan()

    def _replan(self, skew_w: Optional[np.ndarray] = None) -> WanPlan:
        mon = SnapshotMonitor(self.sim)
        _, raw = mon.capture()
        pred = self.predictor.predict_matrix(
            self.sim.N, raw["snapshot_bw"], raw["mem_util"],
            raw["cpu_load"], raw["retrans"], raw["dist"])
        pods = pred[:self.n_pods, :self.n_pods]
        gp = global_optimize(pods, M=self.loop.max_conns, w_s=skew_w)
        if self._agents is None:
            self._agents = [AimdAgent.from_plan(gp, i)
                            for i in range(self.n_pods)]
        else:
            # fine-tune inside new bounds with monitored BW (local agents)
            monitored = self.sim.measure_snapshot()[:self.n_pods, :self.n_pods]
            for i, ag in enumerate(self._agents):
                ag.min_cons, ag.max_cons = gp.min_cons[i], gp.max_cons[i]
                ag.min_bw, ag.max_bw = gp.min_bw[i], gp.max_bw[i]
                ag.unit_bw, ag.throttle = gp.pred_bw[i], gp.throttle[i]
                ag.step(monitored[i])
        cons = np.stack([ag.cons for ag in self._agents]) \
            if self._agents else gp.max_cons
        gp2 = gp
        object.__setattr__  # noqa: B018  (WanPlan is frozen; rebuild)
        return WanPlan(
            n_pods=self.n_pods,
            conns=tuple(tuple(int(v) for v in row) for row in cons),
            pred_bw=tuple(tuple(float(v) for v in row) for row in gp2.pred_bw),
            compress_bits=WanPlan.from_global(gp2).compress_bits,
        )

    def _get_step(self):
        key = self.plan.signature() if self.plan else ("single",)
        key = (key, self.loop.sync, self.loop.compress)
        if key not in self._step_cache:
            self._step_cache[key] = jax.jit(
                make_train_step(self.cfg, self.mesh, plan=self.plan,
                                opt=self.opt, sync=self.loop.sync,
                                compress=self.loop.compress),
                donate_argnums=(0, 1))
        return self._step_cache[key]

    # ------------------------------------------------------------------
    def restore_or_init(self, key: jax.Array):
        params = registry.init_params(self.cfg, key)
        opt_state = init_opt_state(params)
        start = 0
        if self.loop.ckpt_dir:
            latest = ckpt_lib.latest_step(self.loop.ckpt_dir)
            if latest is not None:
                state = ckpt_lib.restore(self.loop.ckpt_dir,
                                         {"p": params, "o": opt_state})
                params, opt_state = state["p"], state["o"]
                start = latest
                self.events.append(f"restored step {latest}")
        if self.multi_pod:
            # vmap-over-pods formulation: explicit pod-replicated leading
            # dim (checkpoints stay pod-free => elastic across pod counts)
            from repro.train.train_step import broadcast_to_pods
            params = broadcast_to_pods(params, self.n_pods)
            opt_state = broadcast_to_pods(opt_state, self.n_pods)
        return params, opt_state, start

    # ------------------------------------------------------------------
    def run(self, key: jax.Array, fail_at: Optional[int] = None):
        """fail_at: inject a simulated node failure at that step (the
        fault-tolerance test path)."""
        with jax.set_mesh(self.mesh):
            return self._run(key, fail_at)

    def _run(self, key: jax.Array, fail_at: Optional[int] = None):
        params, opt_state, start = self.restore_or_init(key)
        data = prefetch(batches(self.cfg, self.dcfg))
        step_fn = self._get_step()
        ewma = None
        writer = None
        step = start
        while step < self.loop.steps:
            batch = {k: jax.numpy.asarray(v) for k, v in next(data).items()}
            t0 = time.perf_counter()
            if fail_at is not None and step == fail_at:
                fail_at = None
                self.events.append(f"simulated failure at step {step}")
                # crash/restart: reload newest complete checkpoint
                params, opt_state, step = self.restore_or_init(key)
                step_fn = self._get_step()
                continue
            params, opt_state, out = step_fn(params, opt_state, batch)
            dt = time.perf_counter() - t0
            # ---- straggler detection -------------------------------------
            if ewma is None:
                ewma = dt
            if dt > self.loop.straggler_factor * ewma and self.multi_pod \
                    and self._agents:
                self.events.append(f"straggler at step {step} ({dt:.2f}s)")
                for ag in self._agents:     # multiplicative decrease
                    ag.step(np.zeros_like(ag.target_bw))
                self.plan = self._replan()
                step_fn = self._get_step()
            ewma = 0.9 * ewma + 0.1 * dt
            # ---- logging -------------------------------------------------
            rec = {"step": step, "loss": float(out["loss"]),
                   "grad_norm": float(out["grad_norm"]), "time": dt}
            self.history.append(rec)
            # ---- WANify re-plan -----------------------------------------
            if self.multi_pod and self.loop.sync == "wanify" and \
                    self.sim is not None and \
                    (step + 1) % self.loop.replan_every == 0:
                self.sim.advance()
                skw = pod_skew_weights(np.asarray(batch["tokens"]),
                                       self.n_pods, self.cfg.vocab) \
                    if self.loop.use_skew_weights else None
                new_plan = self._replan(skew_w=skw)
                if new_plan.signature() != self.plan.signature():
                    self.plan = new_plan
                    step_fn = self._get_step()
                    self.events.append(f"replanned at step {step}")
            # ---- checkpoint ----------------------------------------------
            if self.loop.ckpt_dir and (step + 1) % self.loop.ckpt_every == 0:
                if writer is not None:
                    writer.join()
                if self.multi_pod:
                    from repro.train.train_step import strip_pods
                    tree = {"p": strip_pods(params), "o": strip_pods(opt_state)}
                else:
                    tree = {"p": params, "o": opt_state}
                writer = ckpt_lib.save(self.loop.ckpt_dir, step + 1, tree,
                                       async_=True)
            step += 1
        if writer is not None:
            writer.join()
        return params, opt_state

    # ------------------------------------------------------------------
    def rescale(self, new_mesh) -> "Trainer":
        """Elastic scale: new pod count; RF covers the new cluster size."""
        t = Trainer(self.cfg, new_mesh, self.dcfg, self.loop, self.opt,
                    self.sim, self.predictor)
        t.events = self.events + [f"rescaled to {dict(new_mesh.shape)}"]
        return t
