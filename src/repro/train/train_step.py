"""Train step: loss -> grads -> WANify cross-pod sync -> AdamW.

Two composition modes:
  * single-pod mesh ("data","model"): a plain pjit step; XLA owns all
    collectives (FSDP/TP from sharding constraints).
  * multi-pod mesh ("pod","data","model"): the WHOLE step runs inside
    shard_map with ONLY the pod axis manual — per-pod gradients are
    synchronized by wan_allreduce (the paper's technique; baseline
    psum_allreduce selectable), then the optimizer update is applied
    identically on every pod (params stay pod-replicated).

Optional microbatching (gradient accumulation) shrinks activation
memory; optional wire compression (SAGQ analogue) rides the WAN hop.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.plan import WanPlan
from repro.core.wansync import psum_allreduce, wan_allreduce
from repro.models import registry
from repro.models.layers import ShardCtx
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def _grads_of(cfg: ModelConfig, ctx: ShardCtx, dp_size: int, microbatch: int,
              accum_dtype=jnp.float32):
    loss_f = registry.loss_fn(cfg, ctx, dp_size)

    def whole(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_f(p, batch), has_aux=True)(params)
        return loss, metrics, grads

    if microbatch <= 1:
        return whole

    def accumulated(params, batch):
        def split(x):
            b = x.shape[0]
            return x.reshape(microbatch, b // microbatch, *x.shape[1:])
        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            loss_a, grads_a = carry
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_f(p, mb), has_aux=True)(params)
            grads_a = jax.tree.map(
                lambda a, g: (a + g.astype(accum_dtype)).astype(accum_dtype),
                grads_a, grads)
            return (loss_a + loss, grads_a), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
        (loss, grads), metrics = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), micro)
        grads = jax.tree.map(lambda g: g / microbatch, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss / microbatch, metrics, grads

    return accumulated


def make_train_step(cfg: ModelConfig, mesh, *, plan: Optional[WanPlan] = None,
                    opt: Optional[AdamWConfig] = None,
                    sync: str = "wanify",          # wanify | psum | none
                    compress: bool = False,
                    microbatch: int = 1,
                    accum_dtype=jnp.float32,
                    ctx: Optional[ShardCtx] = None) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, out)."""
    opt = opt or AdamWConfig()
    axes = mesh.axis_names
    multi_pod = "pod" in axes
    # inside the pod-manual shard_map only the auto axes may appear in
    # sharding constraints; the batch is already pod-local there.
    batch_axes = ("data",) if "data" in axes else ()
    dp_size = mesh.shape.get("data", 1)
    ctx = ctx or ShardCtx(batch_axes=batch_axes, model_axis="model"
                          if "model" in axes else None)
    grads_fn = _grads_of(cfg, ctx, dp_size, microbatch, accum_dtype)

    def core(params, opt_state, batch):
        loss, metrics, grads = grads_fn(params, batch)
        new_params, new_state, om = adamw_update(opt, params, grads, opt_state)
        out = {"loss": loss, **om,
               "ce": metrics.get("ce", loss),
               "expert_load": metrics.get("expert_load")}
        return new_params, new_state, out

    if not multi_pod:
        return core

    # ------------------------------------------------------------------
    # Multi-pod: vmap-over-pods formulation. Params / optimizer state /
    # batch carry an explicit leading pod dim sharded over "pod" (memory
    # per device identical to replication). Per-pod grads come from
    # vmapping the loss; the WANify schedule is jnp.roll over the pod dim
    # (lowers to collective-permute). The shard_map formulation
    # (wan_allreduce) is kept for TPU stacks — XLA-CPU CHECK-crashes on
    # partially-manual meshes (DESIGN.md §multi-pod note).
    # ------------------------------------------------------------------
    from repro.core.wansync import (psum_allreduce_batched,
                                    wan_allreduce_batched)
    n_pods = mesh.shape["pod"]

    def step(params_p, opt_state_p, batch):
        def split(x):
            return x.reshape(n_pods, x.shape[0] // n_pods, *x.shape[1:])
        batch_p = jax.tree.map(split, batch)

        def pod_grads(pp, bb):
            loss, metrics, grads = grads_fn(pp, bb)
            return loss, metrics, grads

        loss_p, metrics_p, grads_p = jax.vmap(pod_grads)(params_p, batch_p)
        loss = jnp.mean(loss_p)
        if sync == "wanify":
            assert plan is not None, "wanify sync needs a WanPlan"
            grads_p = wan_allreduce_batched(grads_p, plan, compress=compress)
        elif sync == "psum":
            grads_p = psum_allreduce_batched(grads_p, n_pods)
        new_params, new_state, om = jax.vmap(
            lambda p, g, s: adamw_update(opt, p, g, s)
        )(params_p, grads_p, opt_state_p)
        out = {"loss": loss,
               "grad_norm": jnp.mean(om["grad_norm"]),
               "lr": om["lr"][0],
               "ce": jnp.mean(metrics_p.get("ce", loss_p)),
               "expert_load": jnp.mean(metrics_p["expert_load"], axis=0)}
        return new_params, new_state, out

    return step


def broadcast_to_pods(tree: Any, n_pods: int) -> Any:
    """Add the explicit leading pod dim (replicated-in-value)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape), tree)


def strip_pods(tree: Any) -> Any:
    """Drop the pod dim (slices are value-identical after sync)."""
    return jax.tree.map(lambda x: x[0], tree)


def pod_specs(spec_tree: Any) -> Any:
    """Prepend the pod axis to every PartitionSpec."""
    return jax.tree.map(lambda s: P("pod", *s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def init_train_state(cfg: ModelConfig, key: jax.Array):
    params = registry.init_params(cfg, key)
    return params, init_opt_state(params)


def abstract_train_state(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_train_state(cfg, k),
                          jax.random.key(0))
