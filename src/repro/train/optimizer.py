"""AdamW with global-norm clipping and cosine schedule (pure JAX)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    # bf16 moments halve optimizer HBM (236B-scale models on v5e);
    # moment math still runs in f32 (upcast/downcast around the update).
    state_dtype: str = "float32"


def lr_at(c: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(c.warmup_steps, 1)
    prog = jnp.clip((s - c.warmup_steps) /
                    jnp.maximum(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = c.min_lr_frac + (1 - c.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return c.lr * jnp.minimum(warm, cos)


def init_opt_state(params: Any, state_dtype="float32") -> Dict[str, Any]:
    dt = jnp.dtype(state_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def adamw_update(c: AdamWConfig, params: Any, grads: Any, state: Dict
                 ) -> Tuple[Any, Dict, Dict[str, jax.Array]]:
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gn, 1e-9))
    lr = lr_at(c, step)
    b1c = 1 - c.b1 ** step.astype(jnp.float32)
    b2c = 1 - c.b2 ** step.astype(jnp.float32)

    sdt = jnp.dtype(c.state_dtype)

    def upd_slice(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = c.b1 * m.astype(jnp.float32) + (1 - c.b1) * g
        v2 = c.b2 * v.astype(jnp.float32) + (1 - c.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        delta = mh / (jnp.sqrt(vh) + c.eps) + \
            c.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m2.astype(sdt), v2.astype(sdt))

    def upd(p, g, m, v):
        # layer-stacked leaves (multi-GiB at 236B scale): update in
        # chunks along the stack dim with in-place writes, so the f32
        # temporaries of the elementwise chain cover a few layers, not
        # the whole stack. XLA aliases the output buffers in place.
        if p.ndim >= 3 and p.shape[0] >= 16 and p.size >= (1 << 27):
            L = p.shape[0]
            ch = max(1, L // 8)
            po, mo, vo = p, m, v
            for lo_i in range(0, L, ch):
                n = min(ch, L - lo_i)
                sl = lambda t: jax.lax.dynamic_slice_in_dim(t, lo_i, n, 0)
                np_, nm, nv = upd_slice(sl(p), sl(g), sl(m), sl(v))
                po = jax.lax.dynamic_update_slice_in_dim(po, np_, lo_i, 0)
                mo = jax.lax.dynamic_update_slice_in_dim(mo, nm, lo_i, 0)
                vo = jax.lax.dynamic_update_slice_in_dim(vo, nv, lo_i, 0)
            return po, mo, vo
        return upd_slice(p, g, m, v)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gn, "lr": lr}
