"""Deterministic synthetic data pipeline with per-pod skew.

Produces shardable global batches (tokens/targets + modality stubs).
Skew mode draws token-ids from pod-dependent distributions, creating
the per-pod expert-load imbalance that feeds WANify's w_s (§3.3.1).
Host-side double-buffered prefetch hides generation latency.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class DataConfig:
    batch: int
    seq: int
    vocab: int
    n_pods: int = 1
    skew: float = 0.0          # 0 = iid across pods; 1 = fully disjoint
    seed: int = 0


def _pod_batch(rng: np.random.Generator, c: DataConfig, pod: int,
               per_pod: int) -> np.ndarray:
    """Zipf-ish tokens with a pod-dependent offset when skewed."""
    base = rng.zipf(1.3, size=(per_pod, c.seq + 1)).astype(np.int64)
    tok = (base - 1) % c.vocab
    if c.skew > 0:
        width = max(1, int(c.vocab * (1 - c.skew) / c.n_pods))
        lo = (pod * c.vocab) // c.n_pods
        tok = lo + tok % max(width, 1)
    return tok % c.vocab


def batches(cfg: ModelConfig, c: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(c.seed)
    per_pod = c.batch // max(c.n_pods, 1)
    while True:
        toks = np.concatenate(
            [_pod_batch(rng, c, p, per_pod) for p in range(max(c.n_pods, 1))])
        out = {"tokens": toks[:, :-1].astype(np.int32),
               "targets": toks[:, 1:].astype(np.int32)}
        if cfg.is_encdec:
            out["enc_frames"] = rng.normal(
                0, 1, (c.batch, cfg.encoder.source_len, cfg.encoder.d_model)
            ).astype(np.float32)
        if cfg.is_vlm:
            out["patch_embeds"] = rng.normal(
                0, 0.02, (c.batch, cfg.encoder.source_len, cfg.d_model)
            ).astype(np.float32)
        yield out


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    threading.Thread(target=worker, daemon=True).start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item


def pod_skew_weights(batch_tokens: np.ndarray, n_pods: int,
                     vocab: int) -> np.ndarray:
    """Data-volume proxy per pod (w_s input): entropy-weighted token mass.
    Skewed pods concentrate tokens -> heavier shuffle volume."""
    per = np.split(batch_tokens, n_pods, axis=0)
    weights = []
    for chunk in per:
        _, counts = np.unique(chunk, return_counts=True)
        p = counts / counts.sum()
        ent = -(p * np.log(p + 1e-12)).sum()
        weights.append(1.0 + 1.0 / max(ent, 0.3))
    w = np.asarray(weights)
    return w / w.mean()
