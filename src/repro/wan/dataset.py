"""Bandwidth-Analyzer dataset generation (paper §4.1.1 / §5.1).

Samples (snapshot features -> stable runtime BW) across varying cluster
sizes [2, N_max], DC subsets, connection mixes and fluctuation states —
the 600-sample methodology of §5.1 scaled as requested.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.forest import RandomForest
from repro.core.predictor import assemble_features
from repro.wan import topology as topo
from repro.wan.simulator import WanSimulator


def generate_dataset(n_samples: int = 600, n_max: int = 8, seed: int = 7,
                     max_conns: int = 8,
                     regions: Optional[List[str]] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (X [rows, 6], y [rows]) where each sample contributes one
    row per ordered DC pair."""
    rng = np.random.default_rng(seed)
    all_regions = regions or list(topo.DEFAULT_8DC)
    Xs, ys = [], []
    for s in range(n_samples):
        n = int(rng.integers(2, n_max + 1))
        sub = list(rng.choice(all_regions, size=n, replace=False))
        sim = WanSimulator(regions=sub, seed=int(rng.integers(1 << 30)))
        sim.advance(int(rng.integers(1, 40)))       # random network state
        # connection mix active during the workload
        conns = rng.integers(1, max_conns + 1, (n, n)).astype(float)
        np.fill_diagonal(conns, 0)
        snap = sim.measure_snapshot(conns)
        mem, cpu, retr = sim.host_metrics(conns, bw=snap)
        stable = sim.measure_runtime(conns)
        X = assemble_features(n, snap, mem, cpu, retr, sim.dist)
        off = ~np.eye(n, dtype=bool)
        Xs.append(X)
        ys.append(stable[off])
    return np.concatenate(Xs), np.concatenate(ys)


def train_default_forest(n_samples: int = 600, seed: int = 7,
                         **forest_kw) -> Tuple[RandomForest, float, float]:
    """Train the WAN Prediction Model; returns (forest, train_acc, r2)."""
    X, y = generate_dataset(n_samples=n_samples, seed=seed)
    n = len(y)
    cut = int(n * 0.85)
    rf = RandomForest(**forest_kw).fit(X[:cut], y[:cut])
    acc = rf.training_accuracy(X[:cut], y[:cut])
    r2 = rf.score(X[cut:], y[cut:])
    return rf, acc, r2
