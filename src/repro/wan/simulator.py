"""WAN contention simulator — the ground truth the paper measures with
iPerf on AWS, reproduced as a max-min-fair water-filling model.

Resources:
  * per-DC NIC egress / ingress caps (WAN-throttled, §2.1)
  * per-path cap = bw_single(d) * KNEE_CONNS  (parallelism knee, §2.2)
  * per-connection cap = bw_single(d) (one TCP stream saturates at the
    single-connection BW for that distance)

A transfer session (i->j, c connections) contributes c identical flows.
Sharing is RTT-BIASED weighted max-min (progressive filling): a TCP
flow's share of a contended resource scales with 1/RTT (~1/distance) —
the paper's core premise that "nearby DCs occupy most of the available
network" (Fig. 2b), which heterogeneous connection counts counteract
(more flows on far links ~ more aggregate weight).

Measurement modes (paper §2.2):
  static-independent   one pair at a time, everything else idle
  static-simultaneous  all pairs at once (expensive: full-mesh iPerf)
  runtime              all pairs at once, during workload, w/ fluctuation
  snapshot             1-second runtime sample (extra observation noise)

Fluctuation follows an AR(1) log-normal per-link process ([38]'s
minutes-scale predictability).

Randomness is split into NAMED streams spawned from one seed
(fluctuation / observation / host), so the same network state yields
the same measurement regardless of call interleaving — the determinism
contract the scenario replay harness (repro.scenarios) relies on.
Scripted dynamics hook in through `set_link_factor` (per-link scripted
degradation), `modulation` (global diurnal multiplier),
`background_conns` (cross-traffic that contends in the water-filling
but is never credited to the workload), and `set_provider_factor`
(provider migration, §3.3.3).

Multi-tenant sharing (repro.fleet): `set_tenant_conns` registers a
named tenant's connection matrix. Registered tenants CONTEND like
cross-traffic but, unlike `background_conns`, their share is CREDITED:
`waterfill(c, tenant=...)` excludes the caller's own registration (so
its in-flight matrix is not double-counted) while every other tenant's
flows fight it out in the same fill, and `waterfill_tenants` solves
ONE fill for the whole fleet and credits each tenant rate x own-conns.
Flows on the same pair share the pair's per-connection rate, so the
aggregate fill is exact, not an approximation.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.registry import MetricsRegistry
from repro.wan import topology as topo


class WaterfillDivergence(RuntimeError):
    """A progressive fill hit its iteration bound with unfrozen pairs
    left — the rates would be partial, so the fill fails loudly."""


@dataclass
class WanSimulator:
    """The shared WAN ground truth (see module docstring)."""

    regions: List[str] = field(default_factory=lambda: list(topo.DEFAULT_8DC))
    # sustained WAN egress/ingress cap of a t2.medium-class worker;
    # calibrated so all-pairs contention reproduces Table 1 (18 pairs with
    # >100 Mbps static-vs-runtime gaps on the 8-DC mesh).
    nic_cap: float = 2600.0
    knee: float = topo.KNEE_CONNS
    seed: int = 0
    fluct_sigma: float = 0.12          # log-sd of slow link fluctuation
    fluct_rho: float = 0.9             # AR(1) coefficient
    snapshot_sigma: float = 0.08       # extra 1-second observation noise
    runtime_sigma: float = 0.015       # residual noise of 20 s averages
    # observation noise symmetric across i->j / j->i (links are modelled
    # symmetric in advance(); symmetric noise keeps a snapshot of a
    # symmetric network symmetric — see test_symmetric_obs_noise_default)
    symmetric_obs_noise: bool = True
    # per-DC VM multiplicity (association §3.3.3) and provider refactor
    vms_per_dc: Optional[np.ndarray] = None
    provider_factor: Optional[np.ndarray] = None
    # cross-traffic [N,N] connection counts: contend in waterfill, never
    # credited to the workload's achieved BW (scenario engine knob)
    background_conns: Optional[np.ndarray] = None
    # named tenants' [N,N] connection matrices: contend like cross-
    # traffic but their share IS credited (fleet arbitration)
    tenant_conns: Dict[str, np.ndarray] = field(default_factory=dict)
    # host-metric noise scale (mem/cpu normal sd; 0 additionally skips
    # the retransmission poisson, making host metrics DETERMINISTIC —
    # the operating mode the fused fleet tick replicates in one jit
    # program). Default keeps the historical draws byte-identical.
    host_sigma: float = 0.02
    # water-fill backend: None defers to $REPRO_WATERFILL_BACKEND
    # (default "numpy", the bit-exact reference the trace goldens pin);
    # "jax" dispatches `_fill_rates` to the batched
    # `repro.kernels.waterfill` while_loop kernel (roundoff-equal)
    waterfill_backend: Optional[str] = None

    def __post_init__(self):
        self.N = len(self.regions)
        # named streams spawned from one seed: measurement draws do not
        # depend on how fluctuation/observation/host calls interleave
        s_fluct, s_obs, s_host = np.random.SeedSequence(self.seed).spawn(3)
        self.rng_fluct = np.random.default_rng(s_fluct)
        self.rng_obs = np.random.default_rng(s_obs)
        self.rng_host = np.random.default_rng(s_host)
        self.dist = topo.distance_matrix(self.regions)
        self._rebuild_base()
        self._fluct = np.zeros((self.N, self.N))   # log-space AR(1) state
        self._link_factor = np.ones((self.N, self.N))  # scripted events
        self.modulation = 1.0                      # scripted diurnal cycle
        # scripted reachability (repro.faults): None = fully reachable
        # (the historical path — no mask is ever multiplied in); a bool
        # [N,N] mask zeroes unreachable links in link_bw_now()
        self._reachable: Optional[np.ndarray] = None
        # convergence accounting of the most recent / all fills (the
        # historical loop capped silently at 8*N*N; now surfaced) —
        # kept on the obs registry, with `fill_calls` /
        # `last_fill_iters` as back-compat property aliases
        self.metrics = MetricsRegistry("sim")
        self._m_fill_calls = self.metrics.counter(
            "fill_calls", help="water-fill invocations")
        self._m_last_iters = self.metrics.gauge(
            "last_fill_iters", help="iterations of the most recent fill")
        self._m_iters_total = self.metrics.counter(
            "fill_iters_total", help="cumulative fill iterations")
        self._m_iters_hist = self.metrics.histogram(
            "fill_iters", buckets=(4, 8, 16, 32, 64, 128, 256, 512),
            help="per-fill iteration distribution")

    @property
    def fill_iter_cap(self) -> int:
        """The fill's iteration bound (divergence past this raises)."""
        return 8 * self.N * self.N

    # -- back-compat aliases onto the obs registry ---------------------
    def _note_fill(self, iters: int) -> None:
        self._m_fill_calls.inc()
        self._m_last_iters.set(int(iters))
        self._m_iters_total.inc(int(iters))
        self._m_iters_hist.observe(int(iters))

    @property
    def fill_calls(self) -> int:
        """Total water-fill invocations (registry-backed)."""
        return int(self._m_fill_calls.value)

    @fill_calls.setter
    def fill_calls(self, v: int) -> None:
        """Legacy reset path (tests zero the tally between phases)."""
        self._m_fill_calls.reset(int(v))

    @property
    def last_fill_iters(self) -> int:
        """Iterations of the most recent fill (registry-backed)."""
        return int(self._m_last_iters.value)

    @last_fill_iters.setter
    def last_fill_iters(self, v: int) -> None:
        """Legacy reset path for the iteration gauge."""
        self._m_last_iters.set(int(v))

    def _rebuild_base(self) -> None:
        self.base = topo.bw_single_matrix(self.regions)
        if self.provider_factor is not None:
            pf = np.sqrt(np.outer(self.provider_factor, self.provider_factor))
            off = ~np.eye(self.N, dtype=bool)
            self.base[off] = (self.base * pf)[off]

    # ------------------------------------------------------------------
    # Scripted dynamics (repro.scenarios event targets)
    # ------------------------------------------------------------------
    def set_link_factor(self, i: int, j: int, factor: float) -> None:
        """Scripted symmetric degradation/restoration of one link
        (factor 1.0 = nominal; links are modelled symmetric)."""
        self._link_factor[i, j] = self._link_factor[j, i] = float(factor)

    def set_provider_factor(self, pf: Optional[np.ndarray]) -> None:
        """Provider migration (§3.3.3): rebuild base BW under new per-DC
        provider factors."""
        self.provider_factor = None if pf is None else np.asarray(pf, float)
        self._rebuild_base()

    def set_reachable(self, mask: Optional[np.ndarray]) -> None:
        """Scripted reachability (repro.faults): `mask` is a bool [N,N]
        matrix; False pairs (a blacked-out DC, a network partition)
        carry ZERO bandwidth — not merely low BW, so a dead pair
        freezes at rate 0 in every fill and a solo measurement of it
        reads 0. None restores full reachability (and restores the
        exact historical arithmetic: no mask is multiplied in at all).
        The diagonal is forced True — a DC always reaches itself."""
        if mask is None:
            self._reachable = None
            return
        m = np.asarray(mask, bool).copy()
        if m.shape != (self.N, self.N):
            raise ValueError(f"reachability mask must be "
                             f"[{self.N},{self.N}], got {m.shape}")
        np.fill_diagonal(m, True)
        self._reachable = m

    def set_background(self, i: int, j: int, conns: float) -> None:
        """Cross-traffic on link i->j (0 clears)."""
        if self.background_conns is None:
            self.background_conns = np.zeros((self.N, self.N))
        self.background_conns[i, j] = float(conns)

    def set_tenant_conns(self, tenant: str, conns: np.ndarray) -> None:
        """Register tenant's [N,N] connection matrix (fleet workloads).

        Registered flows contend in every fill; pass ``tenant=`` to
        :meth:`waterfill` / the measure_* modes so the caller's own
        registration is excluded instead of double-counted.
        """
        c = np.asarray(conns, np.float64).copy()
        if c.shape != (self.N, self.N):
            raise ValueError(f"tenant conns must be [{self.N},{self.N}]")
        np.fill_diagonal(c, 0.0)
        self.tenant_conns[tenant] = np.maximum(c, 0.0)

    def clear_tenant(self, tenant: str) -> None:
        """Drop a tenant's registered flows (job departure)."""
        self.tenant_conns.pop(tenant, None)

    # ------------------------------------------------------------------
    def advance(self, steps: int = 1) -> None:
        """Advance the fluctuation process (call once per epoch/minute)."""
        for _ in range(steps):
            eps = self.rng_fluct.normal(0.0, self.fluct_sigma,
                                        (self.N, self.N))
            eps = (eps + eps.T) / 2                     # symmetric links
            self._fluct = self.fluct_rho * self._fluct + \
                np.sqrt(1 - self.fluct_rho ** 2) * eps

    def link_bw_now(self) -> np.ndarray:
        """Current single-connection BW per link (fluctuation x scripted
        link factors x diurnal modulation, zeroed on unreachable pairs
        when a fault-plane reachability mask is installed)."""
        bw = self.base * np.exp(self._fluct) * self._link_factor \
            * self.modulation
        if self._reachable is not None:
            bw = bw * self._reachable
        return bw

    def _caps(self):
        vms = self.vms_per_dc if self.vms_per_dc is not None \
            else np.ones(self.N)
        egress = self.nic_cap * vms
        ingress = self.nic_cap * vms
        return egress, ingress

    # ------------------------------------------------------------------
    # Max-min fair water-filling over all active (i,j) sessions
    # ------------------------------------------------------------------
    # TCP throughput ~ MSS/(RTT*sqrt(p)); under bursty WAN loss the
    # effective share skew is steeper than 1/RTT. beta=2 calibrated so
    # uniform-8 starves the far link at ~120 Mbps (paper Fig. 2b).
    rtt_beta: float = 2.0

    def rtt_weight(self) -> np.ndarray:
        """Per-connection contention weight ~ (1/RTT)^beta, normalized so
        the closest link has weight 1.

        Cached: the weight depends only on `dist` (fixed at
        construction and only ever replaced wholesale, never mutated in
        place) and `rtt_beta`, yet every water-fill used to rebuild it;
        the cache is invalidated when either changes."""
        cached = getattr(self, "_rtt_w_cache", None)
        if cached is not None and cached[0] is self.dist \
                and cached[1] == self.rtt_beta:
            return cached[2]
        d = np.maximum(self.dist, 1.0)
        w = (d[~np.eye(self.N, dtype=bool)].min() / d) ** self.rtt_beta
        np.fill_diagonal(w, 0.0)
        w.setflags(write=False)
        # key on the dist OBJECT (kept alive by the cache itself, so a
        # wholesale replacement can never alias its id) plus the beta
        self._rtt_w_cache = (self.dist, self.rtt_beta, w)
        return w

    def _contending_conns(self, own: np.ndarray,
                          tenant: Optional[str] = None) -> np.ndarray:
        """Aggregate flow count per pair: the caller's own flows plus
        uncredited cross-traffic plus every OTHER registered tenant
        (the caller's registration, named by `tenant`, is excluded so a
        tenant measuring at its in-force matrix is not double-counted).
        """
        c = own.copy()
        if self.background_conns is not None:
            bg = np.asarray(self.background_conns, np.float64).copy()
            np.fill_diagonal(bg, 0.0)
            c = c + np.maximum(bg, 0.0)            # cross-traffic contends
        for name, tc in self.tenant_conns.items():
            if name != tenant:
                c = c + tc                         # rival tenants contend
        return c

    def waterfill(self, conns: np.ndarray,
                  active: Optional[np.ndarray] = None,
                  cap: Optional[np.ndarray] = None,
                  tenant: Optional[str] = None) -> np.ndarray:
        """Achieved BW per pair [N,N] in Mbps for one workload.

        conns: [N,N] parallel connections per pair (0 or diag = idle).
        RTT-biased weighted progressive filling. `cap` is an optional
        per-pair BW ceiling — WANify's TC throttling of BW-rich links
        (Section 3.2.2). `tenant` names the caller so its own
        registered flows (see :meth:`set_tenant_conns`) are excluded
        from the contention aggregate.
        """
        own = np.asarray(conns, np.float64).copy()
        np.fill_diagonal(own, 0.0)
        if active is not None:
            own = own * active
        c = self._contending_conns(own, tenant)
        rate = self._fill_rates(c, cap)
        bw = rate * own              # uncredited traffic earns nothing
        np.fill_diagonal(bw, topo.INTRA_DC_BW)
        return bw

    def waterfill_routed(self, direct: np.ndarray,
                         relays: Sequence[Tuple[int, int, int, float]],
                         cap: Optional[np.ndarray] = None,
                         tenant: Optional[str] = None) -> np.ndarray:
        """Achieved END-TO-END BW per pair [N,N] for a routed workload
        (repro.overlay): `direct` is the [N,N] direct-path connection
        matrix; each relay ``(i, k, j, conns)`` sends `conns` extra
        connections over the one-hop path i -> k -> j.

        Relay flows are charged on BOTH hops: the fill solves one
        expanded connection matrix in which a relay's connections
        appear on (i, k) AND (k, j), contending with every direct flow
        there (and with background / rival tenants, exactly like
        :meth:`waterfill`). Crediting is store-and-forward: each relay
        connection sustains ``min(rate[i,k], rate[k,j])`` — the
        bottleneck hop's per-connection rate — so a relay through a
        DC whose NIC is saturated buys nothing. The faster hop's
        surplus is NOT redistributed (conservative; the surplus decays
        as AIMD rebalances), and the credit lands on the end-to-end
        pair (i, j), which is what a shuffle/ring consumer observes.
        """
        own = np.asarray(direct, np.float64).copy()
        np.fill_diagonal(own, 0.0)
        expanded = own.copy()
        for i, k, j, cr in relays:
            if i == j or cr <= 0:
                continue
            expanded[i, k] += cr
            expanded[k, j] += cr
        c = self._contending_conns(expanded, tenant)
        rate = self._fill_rates(c, cap)
        bw = rate * own
        for i, k, j, cr in relays:
            if i == j or cr <= 0:
                continue
            bw[i, j] += cr * min(float(rate[i, k]), float(rate[k, j]))
        np.fill_diagonal(bw, topo.INTRA_DC_BW)
        return bw

    def waterfill_tenants(self, conns_by_tenant: Dict[str, np.ndarray],
                          cap: Optional[np.ndarray] = None
                          ) -> Dict[str, np.ndarray]:
        """ONE fill for a whole fleet: all tenants' flows (plus any
        uncredited background) contend together, and each tenant is
        credited its per-connection rate x its own connection count.
        Exact because flows on the same pair share the pair's rate —
        and a single solve instead of one per job is what keeps the
        fleet tick sublinear in job count.

        The PASSED matrices are authoritative: a tenant mid-replan may
        price a candidate matrix that differs from its
        :meth:`set_tenant_conns` registration, and both the contention
        aggregate and the crediting use the candidate. (The historical
        add-every-registration-then-subtract form only netted out to
        this for exactly-representable counts; with fractional conns
        the float round-trip left contention and crediting disagreeing
        by roundoff — now the registration of a passed tenant never
        enters the aggregate at all.) Registered tenants NOT passed
        here still contend as uncredited rivals.
        """
        stack = {}
        for name, conns in conns_by_tenant.items():
            c = np.asarray(conns, np.float64).copy()
            np.fill_diagonal(c, 0.0)
            stack[name] = np.maximum(c, 0.0)
        total = np.zeros((self.N, self.N))
        for c in stack.values():
            total += c
        if self.background_conns is not None:
            bg = np.asarray(self.background_conns, np.float64).copy()
            np.fill_diagonal(bg, 0.0)
            total += np.maximum(bg, 0.0)           # cross-traffic contends
        for name, tc in self.tenant_conns.items():
            if name not in stack:
                total += tc                        # rival tenants contend
        rate = self._fill_rates(total, cap)
        out = {}
        for name, c in stack.items():
            bw = rate * c
            np.fill_diagonal(bw, topo.INTRA_DC_BW)
            out[name] = bw
        return out

    def _fill_backend(self) -> str:
        """Resolve the fill backend: the instance field wins, then
        ``$REPRO_WATERFILL_BACKEND``, then the bit-exact numpy loop."""
        b = self.waterfill_backend or \
            os.environ.get("REPRO_WATERFILL_BACKEND", "numpy")
        if b not in ("numpy", "jax"):
            raise ValueError(f"unknown waterfill backend {b!r}; "
                             f"expected 'numpy' or 'jax'")
        return b

    def fill_inputs(self, cap: Optional[np.ndarray] = None
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray, np.ndarray]:
        """The fill's loop-invariant inputs at the CURRENT network
        state: ``(single, egress, ingress, w, path_cap)`` — the
        single-connection BW, NIC caps, RTT weights (cached across
        fills) and the knee path cap (min'd with any §3.2.2 `cap`).
        Shared by the numpy loop, the jax kernel dispatch, and the
        fused fleet tick's schedule precomputation."""
        single = self.link_bw_now()
        egress, ingress = self._caps()
        w = self.rtt_weight()                      # per-connection weight
        path_cap = single * self.knee              # parallelism knee
        if cap is not None:
            path_cap = np.minimum(path_cap, np.asarray(cap, np.float64))
        return single, egress, ingress, w, path_cap

    def _fill_rates(self, c: np.ndarray,
                    cap: Optional[np.ndarray] = None) -> np.ndarray:
        """Per-connection rate [N,N] for an aggregate flow matrix `c`
        (diagonal ignored; every flow on a pair gets the same rate).

        Converges within `fill_iter_cap` iterations or raises
        :class:`WaterfillDivergence`; the actual iteration count is
        surfaced on ``last_fill_iters`` (and ``fill_calls`` counts
        fills) so harnesses can assert convergence headroom.
        """
        N = self.N
        single, egress, ingress, w, path_cap = self.fill_inputs(cap)
        if self._fill_backend() == "jax":
            from repro.kernels import waterfill as wfk
            rate, iters, ok = wfk.fill_rates(c, single, egress, ingress,
                                             w, path_cap)
            self._note_fill(int(iters))
            if not bool(ok):
                raise WaterfillDivergence(
                    f"jax water-fill hit the {self.fill_iter_cap}-"
                    f"iteration bound with unfrozen pairs left")
            return rate
        # every input of the fill is loop-invariant: the single-conn BW,
        # NIC caps, RTT weights (cached across fills), and the clipped
        # weight denominators are computed ONCE here, not per filling
        # iteration
        cw = c * w                                 # aggregate pair weight
        w_pos = w > 0
        cw_pos = cw > 0
        w_den = np.maximum(w, 1e-12)
        cw_den = np.maximum(cw, 1e-12)
        per_conn_cap = single                      # one stream's ceiling
        rate = np.zeros((N, N))                    # per-connection rate
        frozen = c <= 0
        iters = 0

        # progressive filling on the weighted fill level t:
        # rate_ij = t * w_ij while unfrozen
        while True:
            if frozen.all():
                break
            if iters >= self.fill_iter_cap:
                self._note_fill(iters)
                raise WaterfillDivergence(
                    f"water-fill hit the {self.fill_iter_cap}-iteration "
                    f"bound with {int((~frozen).sum())} unfrozen pairs "
                    f"left")
            act = ~frozen
            we = (cw * act).sum(axis=1)            # active weight per egress
            wi = (cw * act).sum(axis=0)
            head_e = egress - (rate * c).sum(axis=1)
            head_i = ingress - (rate * c).sum(axis=0)
            inc_e = np.where(we > 0, head_e / np.maximum(we, 1e-12), np.inf)
            inc_i = np.where(wi > 0, head_i / np.maximum(wi, 1e-12), np.inf)
            # per-pair bounds in fill-level units (rate grows as t*w)
            inc_conn = np.where(act & w_pos,
                                (per_conn_cap - rate) / w_den,
                                np.inf)
            inc_path = np.where(act & cw_pos,
                                (path_cap - rate * c) / cw_den,
                                np.inf)
            inc_pair = np.minimum(inc_conn, inc_path)
            inc = min(float(np.min(inc_e)), float(np.min(inc_i)),
                      float(np.min(inc_pair)))
            if not np.isfinite(inc) or inc < 1e-9:
                inc = 0.0
            rate = np.where(act, rate + inc * w, rate)
            hit = act & (((per_conn_cap - rate) < 1e-6) |
                         ((path_cap - rate * c) < 1e-6))
            tot_e = (rate * c).sum(axis=1)
            tot_i = (rate * c).sum(axis=0)
            sat_e = egress - tot_e < 1e-6
            sat_i = ingress - tot_i < 1e-6
            hit |= act & (sat_e[:, None] | sat_i[None, :])
            iters += 1
            if not hit.any() and inc == 0.0:
                break
            frozen |= hit
        self._note_fill(iters)
        return rate

    # ------------------------------------------------------------------
    # Measurement modes
    # ------------------------------------------------------------------
    def measure_static_independent(self, conns_per_pair: int = 1,
                                   tenant: Optional[str] = None
                                   ) -> np.ndarray:
        """One pair at a time (existing GDA systems' iPerf methodology).

        With the network otherwise idle, a solo pair's fill has a
        closed form — the progressive filling freezes it in one step at
        the tightest of its four constraints — so the historical
        N(N-1)-waterfill loop collapses to one vectorized expression:

            bw_ij = min(single_ij * c,            # per-connection cap
                        single_ij * knee,         # parallelism knee
                        egress_i, ingress_j)      # NIC caps

        computed with the exact arithmetic of the filling loop (the
        min of the loop's fill-level quotients times ``w * c``), so it
        equals the loop BIT-FOR-BIT — `tests/test_simulator.py` pins
        that on the 8-DC mesh. Cross-traffic or RIVAL registered
        tenants would contend even with a solo measurement pair, so
        those cases fall back to the per-pair fills.

        `tenant` names the caller like in every other measure_* mode:
        its own :meth:`set_tenant_conns` registration is excluded, so
        a registered tenant measuring static-independent sees the solo
        closed form (or self-excluded fills) instead of double-
        counting its in-force flows as rival traffic.
        """
        N = self.N
        bg = self.background_conns
        rivals = any(name != tenant for name in self.tenant_conns)
        if rivals or (bg is not None and (np.asarray(bg) > 0).any()):
            out = np.full((N, N), topo.INTRA_DC_BW)
            for i in range(N):
                for j in range(N):
                    if i == j:
                        continue
                    c = np.zeros((N, N))
                    c[i, j] = conns_per_pair
                    out[i, j] = self.waterfill(c, tenant=tenant)[i, j]
            return out
        single = self.link_bw_now()
        egress, ingress = self._caps()
        w = self.rtt_weight()
        c = float(conns_per_pair)
        w_den = np.maximum(w, 1e-12)
        cw_den = np.maximum(c * w, 1e-12)
        # the loop's fill level: min over the four binding constraints,
        # in fill-level units (rate grows as t * w)
        inc = np.minimum(
            np.minimum(single / w_den, (single * self.knee) / cw_den),
            np.minimum(egress[:, None] / cw_den, ingress[None, :] / cw_den))
        inc = np.where(np.isfinite(inc) & (inc >= 1e-9), inc, 0.0)
        out = (inc * w) * c
        np.fill_diagonal(out, topo.INTRA_DC_BW)
        return out

    def measure_simultaneous(self, conns: Optional[np.ndarray] = None,
                             noise: float = 0.0,
                             cap: Optional[np.ndarray] = None,
                             tenant: Optional[str] = None) -> np.ndarray:
        """All pairs at once (runtime / static-simultaneous)."""
        N = self.N
        c = np.ones((N, N)) if conns is None else np.asarray(conns, float)
        bw = self.waterfill(c, cap=cap, tenant=tenant)
        if noise > 0:
            off = ~np.eye(N, dtype=bool)
            eps = self.rng_obs.normal(0, noise, (N, N))
            if self.symmetric_obs_noise:
                # /sqrt(2) keeps the per-link marginal sd at `noise`
                eps = (eps + eps.T) / np.sqrt(2.0)
            bw = np.where(off, bw * np.exp(eps), bw)
        return bw

    def measure_runtime(self, conns: Optional[np.ndarray] = None,
                        cap: Optional[np.ndarray] = None,
                        tenant: Optional[str] = None) -> np.ndarray:
        """Stable >=20 s all-pairs measurement (small residual noise)."""
        return self.measure_simultaneous(conns, noise=self.runtime_sigma,
                                         cap=cap, tenant=tenant)

    def measure_snapshot(self, conns: Optional[np.ndarray] = None,
                         tenant: Optional[str] = None) -> np.ndarray:
        """Cheap 1-second sample: same ground truth, more noise."""
        return self.measure_simultaneous(conns, noise=self.snapshot_sigma,
                                         tenant=tenant)

    # ------------------------------------------------------------------
    def host_metrics(self, conns: np.ndarray, bw: Optional[np.ndarray] = None,
                     tenant: Optional[str] = None):
        """Simulated node metrics for Table-3 features:
        mem_util[j] (receiver buffers scale with incoming connections),
        cpu_load[i] (sender), retrans[i,j] (congestion proxy)."""
        c = np.asarray(conns, float).copy()
        np.fill_diagonal(c, 0)
        if bw is None:
            bw = self.waterfill(c, tenant=tenant)
        total_in = c.sum(axis=0)
        total_out = c.sum(axis=1)
        # host_sigma == 0 skips every host draw (normal AND poisson):
        # fully deterministic node metrics, the regime the fused fleet
        # tick (repro.fleet.fused) reproduces inside one jit program
        mem_eps = cpu_eps = 0.0
        poisson = 0.0
        if self.host_sigma > 0:
            mem_eps = self.rng_host.normal(0, self.host_sigma, self.N)
            cpu_eps = self.rng_host.normal(0, self.host_sigma, self.N)
            poisson = self.rng_host.poisson(1.0, (self.N, self.N))
        mem_util = np.clip(0.15 + 0.02 * total_in + mem_eps, 0.05, 0.98)
        cpu_load = np.clip(0.10 + 0.015 * total_out + cpu_eps, 0.02, 0.98)
        # retransmissions rise when a pair is squeezed below its solo BW
        solo = self.link_bw_now()
        squeeze = np.maximum(0.0, 1.0 - bw / np.maximum(solo * c, 1e-9))
        retrans = np.rint(squeeze * 40 + poisson).astype(float)
        np.fill_diagonal(retrans, 0)
        return mem_util, cpu_load, retrans
