"""The paper's 8-DC AWS testbed (Fig. 1): regions, geo-coordinates,
pairwise distances, and the distance-calibrated single-connection BW
model. Calibrated against the paper's published measurements:

  US East <-> US West : 1700 Mbps (max, single connection)
  US East <-> AP SE   :  121 Mbps (min, single connection)
  AP SE   @ 9 conns   : ~1 Gbps   (parallel-connection knee ~8-9)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

# region id -> (name, lat, lon)
AWS_REGIONS: Dict[str, Tuple[str, float, float]] = {
    "us-east": ("US East (N. Virginia)", 38.95, -77.45),
    "us-west": ("US West (N. California)", 37.35, -121.96),
    "ap-south": ("AP South (Mumbai)", 19.08, 72.88),
    "ap-se": ("AP SE (Singapore)", 1.35, 103.82),
    "ap-se2": ("AP SE-2 (Sydney)", -33.87, 151.21),
    "ap-ne": ("AP NE (Tokyo)", 35.68, 139.65),
    "eu-west": ("EU West (Ireland)", 53.35, -6.26),
    "sa-east": ("SA East (Sao Paulo)", -23.55, -46.63),
}

DEFAULT_8DC: List[str] = list(AWS_REGIONS)


def haversine_miles(a: Tuple[float, float], b: Tuple[float, float]) -> float:
    """Great-circle distance in miles between two (lat, lon) points."""
    R = 3958.8
    la1, lo1, la2, lo2 = map(math.radians, (a[0], a[1], b[0], b[1]))
    h = math.sin((la2 - la1) / 2) ** 2 + \
        math.cos(la1) * math.cos(la2) * math.sin((lo2 - lo1) / 2) ** 2
    return 2 * R * math.asin(math.sqrt(h))


def distance_matrix(regions: List[str]) -> np.ndarray:
    """Pairwise great-circle distances [N,N] for named regions."""
    N = len(regions)
    d = np.zeros((N, N))
    for i in range(N):
        for j in range(N):
            if i != j:
                d[i, j] = haversine_miles(AWS_REGIONS[regions[i]][1:],
                                          AWS_REGIONS[regions[j]][1:])
    return d


# ----------------------------------------------------------------------
# Single-connection BW(distance) — power-law calibrated to Fig. 1
#   1700 Mbps @ ~2405 mi (us-east <-> us-west)
#    121 Mbps @ ~9660 mi (us-east <-> ap-se)
# ----------------------------------------------------------------------
_D_REF = haversine_miles(AWS_REGIONS["us-east"][1:], AWS_REGIONS["us-west"][1:])
_D_FAR = haversine_miles(AWS_REGIONS["us-east"][1:], AWS_REGIONS["ap-se"][1:])
_ALPHA = math.log(1700.0 / 121.0) / math.log(_D_FAR / _D_REF)
_A = 1700.0 * _D_REF ** _ALPHA

BW_SINGLE_MAX = 2200.0     # Mbps cap for very close DCs
BW_SINGLE_MIN = 60.0
KNEE_CONNS = 8.5           # parallelism gain saturates ~8-9 connections
NIC_CAP_MBPS = 4700.0      # per-VM WAN cap (~half of 10 Gbps, §2.1)
INTRA_DC_BW = 10000.0


def bw_single(dist_miles: float) -> float:
    """Distance-calibrated single-connection BW (Mbps); see module
    docstring for the calibration anchors."""
    if dist_miles <= 0:
        return INTRA_DC_BW
    return float(np.clip(_A / dist_miles ** _ALPHA,
                         BW_SINGLE_MIN, BW_SINGLE_MAX))


def bw_single_matrix(regions: List[str]) -> np.ndarray:
    """Single-connection BW [N,N] (INTRA_DC_BW on the diagonal)."""
    d = distance_matrix(regions)
    N = len(regions)
    out = np.full((N, N), INTRA_DC_BW)
    for i in range(N):
        for j in range(N):
            if i != j:
                out[i, j] = bw_single(d[i, j])
    return out
