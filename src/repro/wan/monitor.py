"""Monitoring: snapshot-feature capture (Table 3) and the Eq. 1 / Table 2
cost model (AWS t3.nano monitoring VM, 30-minute cadence per Tetrium).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.predictor import assemble_features
from repro.core.plan import monitoring_cost, prediction_cost
from repro.wan.simulator import WanSimulator

# ---- Table 2 cost constants ------------------------------------------
T3_NANO_PER_SEC = 0.0052 / 3600.0       # $/instance-second
NET_COST_PER_GB = 0.09                  # $/GB egress (inter-region avg)

# AWS list-price egress ($/GB) per source region of the 8-DC testbed —
# the placement cost layer (repro.placement.cost) prices each DC's
# shuffle egress at its own source rate instead of the Table-2 average.
EGRESS_USD_PER_GB = {
    "us-east": 0.09, "us-west": 0.09, "eu-west": 0.09,
    "ap-south": 0.1093, "ap-se": 0.12, "ap-se2": 0.114,
    "ap-ne": 0.114, "sa-east": 0.15,
}


def egress_price_vector(regions) -> np.ndarray:
    """Per-DC egress $/GB for named regions (unknown regions fall back
    to the Table-2 average `NET_COST_PER_GB`)."""
    return np.array([EGRESS_USD_PER_GB.get(r, NET_COST_PER_GB)
                     for r in regions], np.float64)
MONITOR_SECONDS = 20.0                  # stable runtime needs >=20 s
SNAPSHOT_SECONDS = 1.0
MONITOR_EVERY_MIN = 30.0                # Tetrium's suggestion
AVG_BW_MBPS = 200.0                     # Table 2's network-cost basis


def measurement_net_cost(seconds: float, n_peers: int,
                         avg_bw_mbps: float = AVG_BW_MBPS) -> float:
    """$ for the data a node exchanges during one measurement."""
    gb = avg_bw_mbps / 8.0 * seconds * n_peers / 1024.0
    return gb * NET_COST_PER_GB


def probe_cost_usd(seconds: float, n_dcs: int) -> float:
    """$ for ONE Eq. 1 measurement occurrence across the cluster:
    every node pays `seconds` of monitoring-VM time plus the egress of
    the measurement traffic it exchanges with its N-1 peers. A full
    20-second probe (`MONITOR_SECONDS`) is ~20x the 1-second snapshot
    (`SNAPSHOT_SECONDS`) — the cost axis the lifecycle probe scheduler
    (repro.lifecycle.probes) optimizes."""
    z = measurement_net_cost(seconds, n_dcs - 1)
    return n_dcs * (T3_NANO_PER_SEC * seconds + z)


def annual_costs(n_dcs: int) -> Dict[str, float]:
    """Reproduces one row of Table 2."""
    O = 365 * 24 * 60 / MONITOR_EVERY_MIN
    z_full = measurement_net_cost(MONITOR_SECONDS, n_dcs - 1)
    z_snap = measurement_net_cost(SNAPSHOT_SECONDS, n_dcs - 1)
    full = monitoring_cost(O, n_dcs, T3_NANO_PER_SEC, MONITOR_SECONDS, z_full)
    pred = prediction_cost(O, n_dcs, T3_NANO_PER_SEC, z_snap)
    return {"runtime_monitoring": full, "prediction": pred,
            "savings_frac": 1.0 - pred / full}


@dataclass
class SnapshotMonitor:
    """Captures one cheap snapshot of the cluster (1-second features).
    The last raw capture is kept on `last_raw` so a trace harness can
    line up what the controller saw against ground truth."""
    sim: WanSimulator
    last_raw: Optional[Dict[str, np.ndarray]] = None

    def capture(self, conns: Optional[np.ndarray] = None
                ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
        """Returns (X features [N*(N-1), 6], raw feature dict)."""
        N = self.sim.N
        c = np.ones((N, N)) if conns is None else conns
        snap = self.sim.measure_snapshot(c)
        mem, cpu, retr = self.sim.host_metrics(c, bw=snap)
        X = assemble_features(N, snap, mem, cpu, retr, self.sim.dist)
        self.last_raw = {"snapshot_bw": snap, "mem_util": mem,
                         "cpu_load": cpu, "retrans": retr,
                         "dist": self.sim.dist}
        return X, self.last_raw

    def measure(self, conns: Optional[np.ndarray] = None) -> np.ndarray:
        """Lightweight monitored BW at the given connection matrix — the
        iftop analogue the AIMD agents consume (§3.2.2). Pass the
        connection matrix actually in force; an idle default-of-ones
        measurement describes a traffic regime the workload is not in."""
        return self.sim.measure_snapshot(conns)

    def probe(self, conns: Optional[np.ndarray] = None) -> np.ndarray:
        """FULL runtime probe: the stable >=20-second all-pairs
        measurement of §2.2 (small residual noise, `MONITOR_SECONDS` of
        measurement traffic). ~20x the snapshot's Eq. 1 cost
        (`probe_cost_usd`), so callers should spend it deliberately —
        the lifecycle layer fires one only when drift is suspected."""
        return self.sim.measure_runtime(conns)
