"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop BODY ONCE — with
scan-over-layers (and microbatch scans) that under-weights flops, bytes
and collective traffic by the trip count. This analyzer parses the
optimized HLO text, builds the computation call graph (while bodies,
fusions, calls, conditionals), weights every computation by the product
of enclosing ``known_trip_count``s, and accumulates:

  * dot FLOPs (2 x result x contracting) — the MXU work
  * HBM byte proxy — operand+result bytes of top-level (non-fused)
    instructions; fusion internals cost 0 bytes (VMEM/registers)
  * collective bytes by kind, split intra-pod (ICI) / inter-pod (DCI)

All weighted by loop multiplicity. This feeds EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DT = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
       "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
       "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*\)\s*->", re.M)
_SHAPE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                    r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
                    r"([\w\-]+)\(", re.M)
_TRIP = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CALLEE = {
    "while": re.compile(r"body=%?([\w.\-]+)"),
    "cond": re.compile(r"condition=%?([\w.\-]+)"),
    "fusion": re.compile(r"calls=%?([\w.\-]+)"),
    "call": re.compile(r"to_apply=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
    "reducer": re.compile(r"to_apply=%?([\w.\-]+)"),
}
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")
_GROUPS_LIT_RE = re.compile(r"replica_groups=\{((?:\{[\d,]+\},?)+)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")

COLLECTIVES = {"all-gather", "all-gather-start", "all-reduce",
               "all-reduce-start", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-permute-start"}

# alias/structural ops: no HBM traffic of their own
_NO_BYTES = {"parameter", "tuple", "get-tuple-element", "while",
             "conditional", "call", "bitcast", "constant", "iota",
             "after-all", "opt-barrier", "partition-id", "replica-id"}


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _all_shape_bytes(text: str) -> int:
    return sum(_shape_elems(m.group(2)) * _DT[m.group(1)]
               for m in _SHAPE.finditer(text))


_DEF_RE = re.compile(r"^\s+(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*"
                     r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                     r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]", re.M)
_DOT_OPS = re.compile(r"dot\((%[\w.\-]+)(?:,\s*(%[\w.\-]+))?\)")


def _dot_flops(line: str, shapes: Dict[str, List[int]]) -> float:
    """2 x prod(result) x prod(lhs contracting dims); operand shapes come
    from the symbol table (HLO operands are bare names)."""
    head = line.split("dot(")[0]
    rm = _SHAPE.search(head)
    if not rm:
        return 0.0
    result = _shape_elems(rm.group(2))
    om = _DOT_OPS.search(line)
    lhs_dims = shapes.get(om.group(1), []) if om else []
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contract = 1
    if m and m.group(1):
        for i in m.group(1).split(","):
            if int(i) < len(lhs_dims):
                contract *= lhs_dims[int(i)]
    return 2.0 * result * contract


def _is_interpod(line: str, pod_stride: int) -> bool:
    m = _PAIRS_RE.search(line)
    if m:
        pairs = re.findall(r"\{(\d+),(\d+)\}", m.group(1))
        return any(abs(int(a) - int(b)) >= pod_stride for a, b in pairs)
    m = _GROUPS_LIT_RE.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in re.findall(r"\d+", grp)]
            if ids and max(ids) - min(ids) >= pod_stride:
                return True
        return False
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as np
        g, k = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")] if m.group(4) \
            else list(range(len(dims)))
        ids = np.arange(int(np.prod(dims))).reshape(dims).transpose(perm)
        ids = ids.reshape(g, k)
        return bool((ids.max(1) - ids.min(1) >= pod_stride).any())
    return False


@dataclass
class Costs:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0
    dci_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    n_collectives: int = 0
    n_whiles: int = 0


def analyze(hlo_text: str, pod_stride: int = 1 << 60) -> Costs:
    # ---- split into computations -------------------------------------
    # headers look like:  [ENTRY ]%name (args...) -> type {   — arg lists
    # can contain nested parens (tuple types), so match loosely.
    comps: Dict[str, List[str]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        s = line.rstrip()
        if s.endswith("{") and "->" in s and not line.startswith(" "):
            tok = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
            cur = tok.lstrip("%")
            comps[cur] = []
            if s.startswith("ENTRY"):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)

    # ---- symbol table: %name -> dims (global; names are unique-ish,
    # collisions across computations resolve to identical shapes in
    # practice for the operands we care about) ------------------------
    shapes: Dict[str, List[int]] = {}
    for m in _DEF_RE.finditer(hlo_text):
        shapes[m.group(1)] = [int(x) for x in m.group(3).split(",") if x]

    # ---- per-computation raw costs + call edges ----------------------
    edges: Dict[str, List[Tuple[str, float, bool]]] = defaultdict(list)
    # edge: (callee, multiplier, passes_bytes) — fusion internals get no
    # byte accounting
    local = {}
    n_whiles = 0
    for name, lines in comps.items():
        c = Costs()
        for line in lines:
            mi = _INSTR.match(line)
            if not mi:
                continue
            result_part, op = mi.group(1), mi.group(2)
            if op == "dot":
                c.dot_flops += _dot_flops(line, shapes)
            if op in COLLECTIVES:
                kind = op.replace("-start", "")
                b = _all_shape_bytes(result_part)
                c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0.0) + b
                c.n_collectives += 1
                if _is_interpod(line, pod_stride):
                    c.dci_bytes += b
                else:
                    c.ici_bytes += b
            # HBM byte proxy: operands + results of instructions that
            # actually MOVE data. Structural ops (tuple plumbing, loop
            # headers re-listing the whole carry, parameters, bitcasts)
            # are aliases — counting them charges scan carries per
            # iteration (~1000x phantom bytes for decode caches).
            if op in _NO_BYTES:
                pass
            elif op == "dynamic-slice":
                c.hbm_bytes += 2 * _all_shape_bytes(result_part)
            elif op == "dynamic-update-slice":
                # in-place: traffic ~ the update slice, not the buffer
                all_b = _all_shape_bytes(line)
                big = max((_shape_elems(m.group(2)) * _DT[m.group(1)]
                           for m in _SHAPE.finditer(line)), default=0)
                c.hbm_bytes += max(all_b - 2 * big, 0)
            else:
                c.hbm_bytes += _all_shape_bytes(line)
            # call edges
            if op == "while":
                n_whiles += 1
                trip = 1.0
                mt = _TRIP.search(line)
                if mt:
                    trip = float(mt.group(1))
                for key in ("while", "cond"):
                    mb = _CALLEE[key].search(line)
                    if mb:
                        edges[name].append((mb.group(1), trip, True))
            elif op == "fusion":
                mb = _CALLEE["fusion"].search(line)
                if mb:
                    edges[name].append((mb.group(1), 1.0, False))
            elif op in ("call", "async-start", "custom-call", "reduce",
                        "reduce-window", "sort", "scatter", "select-and-scatter",
                        "map", "all-reduce", "reduce-scatter"):
                mb = _CALLEE["call"].search(line)
                if mb:
                    edges[name].append((mb.group(1), 1.0, False))
            elif op == "conditional":
                mb = _CALLEE["branches"].search(line)
                if mb:
                    for b in re.findall(r"%?([\w.\-]+)", mb.group(1)):
                        edges[name].append((b, 1.0, True))
        local[name] = c

    # ---- weight propagation ------------------------------------------
    weights: Dict[str, float] = defaultdict(float)
    byte_weights: Dict[str, float] = defaultdict(float)
    if entry is None:
        entry = next(iter(comps), None)
    if entry is None:
        return Costs()
    stack = [(entry, 1.0, 1.0)]
    seen_guard = 0
    while stack:
        seen_guard += 1
        if seen_guard > 200000:
            break
        name, w, bw = stack.pop()
        weights[name] += w
        byte_weights[name] += bw
        for callee, mult, passes in edges.get(name, ()):  # noqa: B007
            if callee in comps:
                stack.append((callee, w * mult, bw * mult if passes else 0.0))

    total = Costs(n_whiles=n_whiles)
    for name, c in local.items():
        w = weights.get(name, 0.0)
        bw = byte_weights.get(name, 0.0)
        total.dot_flops += c.dot_flops * w
        total.hbm_bytes += c.hbm_bytes * bw
        total.ici_bytes += c.ici_bytes * w
        total.dci_bytes += c.dci_bytes * w
        total.n_collectives += int(c.n_collectives * max(w, 1.0)) \
            if c.n_collectives else 0
        for k, v in c.coll_by_kind.items():
            total.coll_by_kind[k] = total.coll_by_kind.get(k, 0.0) + v * w
    return total
