"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms (seconds, per chip — cost_analysis of the SPMD module is already
per-partition):
  compute    = HLO_FLOPs / PEAK_FLOPS
  memory     = HLO_bytes / HBM_BW
  collective = ici_bytes / ICI_BW  +  dci_bytes / DCI_BW

collective bytes are parsed from the compiled HLO: operand+result bytes
of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, classified inter-pod (device-id stride >= pod size)
vs intra-pod from replica_groups / source_target_pairs.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (intra-pod)
DCI_BW = 25e9                # B/s inter-pod ("WAN" hop of the paper)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\S+?))\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(", re.M)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
# literal groups: replica_groups={{0,256},{1,257},...}
_GROUPS_LIT_RE = re.compile(r"replica_groups=\{((?:\{[\d,]+\},?)+)\}")
# iota v2 format: replica_groups=[G,K]<=[d0,d1,...]T(p0,p1,...)
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _is_interpod(line: str, pod_stride: int) -> bool:
    """True when participants span device ids >= pod_stride apart."""
    m = _PAIRS_RE.search(line)
    if m:
        pairs = re.findall(r"\{(\d+),(\d+)\}", m.group(1))
        return any(abs(int(a) - int(b)) >= pod_stride for a, b in pairs)
    m = _GROUPS_LIT_RE.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in re.findall(r"\d+", grp)]
            if ids and max(ids) - min(ids) >= pod_stride:
                return True
        return False
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as np
        g, k = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = [int(x) for x in m.group(4).split(",")] if m.group(4) \
            else list(range(len(dims)))
        ids = np.arange(int(np.prod(dims))).reshape(dims).transpose(perm)
        ids = ids.reshape(g, k)
        return bool((ids.max(axis=1) - ids.min(axis=1) >= pod_stride).any())
    return False


@dataclass
class CollectiveStats:
    ici_bytes: int = 0
    dci_bytes: int = 0
    by_kind: Dict[str, int] = field(default_factory=dict)
    count: int = 0


def collective_bytes(hlo_text: str, pod_stride: int = 1 << 60
                     ) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in the HLO."""
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3).replace("-start", "")
        # result shape(s) precede the op name on the line
        head = line[:m.end(3)]
        nbytes = _shape_bytes(head.split("=")[1])
        st.count += 1
        st.by_kind[kind] = st.by_kind.get(kind, 0) + nbytes
        if _is_interpod(line, pod_stride):
            st.dci_bytes += nbytes
        else:
            st.ici_bytes += nbytes
    return st


@dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    ici_bytes: float
    dci_bytes: float
    model_flops_per_chip: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.ici_bytes / ICI_BW + self.dci_bytes / DCI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops_per_chip / max(self.flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful model FLOP/s achieved at the bound, vs chip peak:
        (MODEL_FLOPS / t_bound) / PEAK."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return (self.model_flops_per_chip / t) / PEAK_FLOPS

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "ici_bytes": self.ici_bytes, "dci_bytes": self.dci_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "model_flops_per_chip": self.model_flops_per_chip,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape_kind: str, global_tokens: int, n_chips: int,
                param_count: int, active_param_count: int) -> float:
    """MODEL_FLOPS = 6*N*D (train, N_active for MoE) or 2*N*D (fwd-only
    prefill/decode), per chip."""
    n = active_param_count
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n * global_tokens / n_chips
