"""Serving launcher: batched request serving with KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --requests 8 --max-new 16
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced as reduce_cfg
from repro.models import registry
from repro.serve.engine import Engine, Request, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    params = registry.init_params(cfg, jax.random.key(args.seed))
    eng = Engine(cfg, params, ServeConfig(batch=args.batch,
                                          s_max=args.s_max, tp=1))
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        rng.integers(4, 17)).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    out = eng.serve(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in out.values())
    print(f"[serve] {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")
    for rid in sorted(out)[:4]:
        print(f"[serve] req {rid}: {out[rid]}")


if __name__ == "__main__":
    main()
