"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 50 --pods 2 --data 2 --model 2 --sync wanify --compress

On this CPU container use --reduced (small same-family config) and a
small mesh; on real hardware drop --reduced and use the production mesh.
"""
import os

if "XLA_FLAGS" not in os.environ:       # allow multi-device CPU testing
    n = os.environ.get("REPRO_HOST_DEVICES")
    if n:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n}"

import argparse

import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced as reduce_cfg
from repro.core.predictor import BwPredictor
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_mesh
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import AdamWConfig
from repro.wan.dataset import train_default_forest
from repro.wan.simulator import WanSimulator


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--sync", default="wanify", choices=["wanify", "psum"])
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--skew", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    mesh = make_mesh(args.pods, args.data, args.model)
    dcfg = DataConfig(batch=args.batch, seq=args.seq, vocab=cfg.vocab,
                      n_pods=max(args.pods, 1), skew=args.skew,
                      seed=args.seed)
    sim = pred = None
    if args.pods > 1 and args.sync == "wanify":
        print("[train] training WAN prediction model ...")
        rf, acc, r2 = train_default_forest(n_samples=150, n_trees=40)
        print(f"[train] forest train_acc={acc:.3f} holdout_r2={r2:.3f}")
        sim, pred = WanSimulator(seed=args.seed), BwPredictor(rf)
    tr = Trainer(cfg, mesh, dcfg,
                 LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                            sync=args.sync, compress=args.compress),
                 opt=AdamWConfig(lr=args.lr, total_steps=args.steps),
                 sim=sim, predictor=pred)
    if tr.plan:
        print(f"[train] WanPlan conns={tr.plan.conns} "
              f"bits={tr.plan.compress_bits}")
    tr.run(jax.random.key(args.seed))
    for h in tr.history[:: max(1, len(tr.history) // 20)]:
        print(f"[train] step {h['step']:5d} loss {h['loss']:.4f} "
              f"({h['time']:.2f}s)")
    print(f"[train] events: {tr.events}")


if __name__ == "__main__":
    main()
