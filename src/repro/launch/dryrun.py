"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh)
cell on the production meshes, record memory/cost analysis + collective
bytes, and emit the roofline table (EXPERIMENTS.md §Dry-run/§Roofline).

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out benchmarks/results

NOTE the first two executable lines below: they MUST run before any jax
import (jax locks the device count on first init). The 512 placeholder
host devices exist ONLY for the dry-run; smoke tests / benches see 1.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (no `from __future__` here: the env var lines above must be the first
# executable statements in the module)

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, applicable, input_specs
from repro.core.plan import WanPlan
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.models.layers import ShardCtx
from repro.models.sharding import batch_specs, cache_specs, param_specs
from repro.train.optimizer import init_opt_state
from repro.train.train_step import make_train_step


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree)


# Per-arch train-cell knobs (production choices at this scale): bf16
# optimizer moments halve state HBM; microbatching (gradient
# accumulation) divides activation residency by the factor.
TRAIN_OVERRIDES = {
    # 236B on a 256-chip pod: bf16 weights + bf16 moments + bf16 grad
    # accumulation + 16-way microbatching (f32 AdamW state alone would be
    # 2.8 TB — 70% of pod HBM)
    "deepseek-v2-236b": {"microbatch": 16, "state_dtype": "bfloat16",
                         "param_dtype": "bfloat16",
                         "accum_dtype": "bfloat16"},
    "llama3-8b": {"microbatch": 2, "state_dtype": "bfloat16"},
    "minicpm3-4b": {"state_dtype": "bfloat16"},
    "qwen3-4b": {"state_dtype": "bfloat16"},
    "mamba2-2.7b": {"state_dtype": "bfloat16"},
    "zamba2-2.7b": {"state_dtype": "bfloat16"},
}


def default_plan(n_pods: int) -> WanPlan:
    """Paper-faithful default: heterogeneous conns from the calibrated
    8-DC simulator restricted to the pod count (offline prediction)."""
    if n_pods <= 1:
        return WanPlan.uniform(max(n_pods, 1))
    from repro.core.global_opt import global_optimize
    from repro.wan.simulator import WanSimulator
    sim = WanSimulator(seed=0)
    bw = sim.measure_runtime()[:n_pods, :n_pods]
    return WanPlan.from_global(global_optimize(bw))


def build_lowered(arch: str, shape_name: str, mesh, *,
                  sync: str = "wanify", compress: bool = True,
                  ctx_over: Optional[Dict] = None):
    """Returns (lowered, meta) for one cell."""
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    axes = mesh.axis_names
    multi_pod = "pod" in axes
    n_pods = mesh.shape.get("pod", 1)
    data_size = mesh.shape.get("data", 1)
    model_size = mesh.shape.get("model", 1)
    dp = n_pods * data_size
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    cdict = dict(batch_axes=batch_axes, model_axis="model", remat="full")
    if ctx_over:
        cdict.update(ctx_over)
    ctx = ShardCtx(**cdict)

    params_s = registry.abstract_params(cfg)
    pspecs = param_specs(params_s, data_size=data_size, model_size=model_size)
    ins = input_specs(cfg, shape_name, tp=model_size)

    if spec.kind == "train":
        from repro.train.optimizer import AdamWConfig
        ov = TRAIN_OVERRIDES.get(arch, {})
        if "param_dtype" in ov:
            cfg = cfg.replace(param_dtype=ov["param_dtype"])
            params_s = registry.abstract_params(cfg)
            pspecs = param_specs(params_s, data_size=data_size,
                                 model_size=model_size)
        opt_cfg = AdamWConfig(state_dtype=ov.get("state_dtype", "float32"))
        plan = default_plan(n_pods)
        step = make_train_step(cfg, mesh, plan=plan, opt=opt_cfg,
                               sync=sync if multi_pod else "none",
                               compress=compress,
                               microbatch=ov.get("microbatch", 1),
                               accum_dtype=jnp.dtype(
                                   ov.get("accum_dtype", "float32")),
                               ctx=ctx if not multi_pod else None)
        opt_s = jax.eval_shape(
            lambda p: init_opt_state(p, opt_cfg.state_dtype), params_s)
        ospecs = {"m": pspecs, "v": pspecs, "step": P()}
        if multi_pod:
            # vmap-over-pods formulation: explicit leading pod dim
            from repro.train.train_step import broadcast_to_pods, pod_specs
            params_s = jax.eval_shape(
                lambda t: broadcast_to_pods(t, n_pods), params_s)
            opt_s = jax.eval_shape(
                lambda t: broadcast_to_pods(t, n_pods), opt_s)
            pspecs = pod_specs(pspecs)
            ospecs = pod_specs(ospecs)
        bspecs = batch_specs(ins, batch_axes=batch_axes, batch_size=dp)
        jf = jax.jit(step, in_shardings=(
            _named(mesh, pspecs), _named(mesh, ospecs), _named(mesh, bspecs)),
            donate_argnums=(0, 1))
        lowered = jf.lower(params_s, opt_s, ins)
        tokens = spec.global_batch * spec.seq_len
    elif spec.kind == "prefill":
        fn = registry.prefill_fn(cfg, ctx, S_max=spec.seq_len, tp=model_size,
                                 dp_size=dp)
        bspecs = batch_specs(ins, batch_axes=batch_axes, batch_size=dp)
        jf = jax.jit(fn, in_shardings=(_named(mesh, pspecs),
                                       _named(mesh, bspecs)))
        lowered = jf.lower(params_s, ins)
        tokens = spec.global_batch * spec.seq_len
    else:  # decode
        fn = registry.decode_fn(cfg, ctx, dp_size=dp)
        cspecs = cache_specs(ins["cache"], batch_axes=batch_axes,
                             data_size=data_size, model_size=model_size,
                             dp_size=dp)
        tok_spec = P(batch_axes if spec.global_batch % dp == 0 else None, None)
        jf = jax.jit(fn, in_shardings=(
            _named(mesh, pspecs), _named(mesh, cspecs),
            NamedSharding(mesh, tok_spec), NamedSharding(mesh, P())))
        lowered = jf.lower(params_s, ins["cache"], ins["tokens"], ins["pos"])
        tokens = spec.global_batch
    meta = {"arch": arch, "shape": shape_name, "kind": spec.kind,
            "tokens": tokens, "chips": int(np.prod(list(mesh.shape.values())))}
    return lowered, meta


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             **kw) -> Dict[str, Any]:
    cfg = get_config(arch)
    skip = applicable(cfg, shape_name)
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if skip:
        cell.update(status="skipped", reason=skip)
        return cell
    t0 = time.time()
    try:
        with compat.use_mesh(mesh):
            lowered, meta = build_lowered(arch, shape_name, mesh, **kw)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):   # jax 0.4.x: per-device list
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
        pod_stride = 256 if "pod" in mesh.axis_names else 1 << 60
        # trip-count-weighted static analysis (XLA cost_analysis counts
        # while bodies once — see launch/hlo_analysis.py)
        from repro.launch import hlo_analysis as ha
        w = ha.analyze(hlo, pod_stride=pod_stride)
        n_chips = meta["chips"]
        mf = rl.model_flops(cfg, meta["kind"], meta["tokens"], n_chips,
                            registry.param_count(cfg),
                            registry.active_param_count(cfg))
        roof = rl.Roofline(
            flops=float(w.dot_flops),
            bytes_accessed=float(w.hbm_bytes),
            ici_bytes=float(w.ici_bytes), dci_bytes=float(w.dci_bytes),
            model_flops_per_chip=mf)
        cell.update(
            status="ok",
            t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1),
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            hbm_per_device=mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes,
            collectives={k: float(v) for k, v in w.coll_by_kind.items()},
            n_collectives=w.n_collectives,
            xla_cost_raw={"flops": float(cost.get("flops", 0.0)),
                          "bytes": float(cost.get("bytes accessed", 0.0))},
            roofline=roof.to_dict(),
        )
    except Exception as e:  # a failure here is a bug in the system
        cell.update(status="error", error=f"{type(e).__name__}: {e}",
                    trace=traceback.format_exc()[-2000:])
    return cell


def _run_cell_subprocess(arch, shape, args, mesh_name):
    import subprocess
    import sys
    import tempfile
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tf:
        rf = tf.name
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh_name, "--sync", args.sync,
           "--remat", args.remat, "--result-file", rf]
    if args.no_compress:
        cmd.append("--no-compress")
    if args.no_seq_shard:
        cmd.append("--no-seq-shard")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)               # let the child set its own
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=3600)
    try:
        with open(rf) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return {"arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "error",
                "error": f"subprocess crashed (rc={r.returncode})",
                "trace": (r.stdout + r.stderr)[-1500:]}
    finally:
        if os.path.exists(rf):
            os.unlink(rf)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--sync", default="wanify", choices=["wanify", "psum"])
    ap.add_argument("--no-compress", action="store_true")
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--result-file", default=None,
                    help="single-cell mode: write the cell JSON here")
    args = ap.parse_args()

    meshes = {}
    if args.mesh in ("single", "both"):
        meshes["single"] = make_production_mesh(multi_pod=False)
    if args.mesh in ("multi", "both"):
        meshes["multi"] = make_production_mesh(multi_pod=True)

    cells = []
    if args.all:
        targets = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        targets = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    ctx_over = {"remat": args.remat,
                "seq_shard_activations": not args.no_seq_shard}
    out_path = os.path.join(
        args.out, f"dryrun_{args.mesh}_{args.sync}.json")
    for mesh_name, mesh in meshes.items():
        for arch, shape in targets:
            print(f"[dryrun] {arch} x {shape} x {mesh_name} ...", flush=True)
            if args.all:
                # subprocess isolation: an XLA CHECK-crash in one cell
                # must not kill the sweep
                cell = _run_cell_subprocess(arch, shape, args, mesh_name)
            else:
                cell = run_cell(arch, shape, mesh, mesh_name, sync=args.sync,
                                compress=not args.no_compress,
                                ctx_over=ctx_over)
            status = cell["status"]
            extra = ""
            if status == "ok":
                r = cell["roofline"]
                extra = (f" dom={r['dominant']} "
                         f"tc={r['t_compute']:.3e} tm={r['t_memory']:.3e} "
                         f"tx={r['t_collective']:.3e} "
                         f"hbm={cell['hbm_per_device']/2**30:.2f}GiB "
                         f"[lower {cell['t_lower_s']}s compile {cell['t_compile_s']}s]")
            elif status == "error":
                extra = " " + cell["error"][:160]
            print(f"[dryrun]   -> {status}{extra}", flush=True)
            cells.append(cell)
            if args.result_file:
                with open(args.result_file, "w") as f:
                    json.dump(cell, f)
            else:
                with open(out_path, "w") as f:
                    json.dump(cells, f, indent=1)
    if not args.result_file:
        print(f"[dryrun] wrote {out_path}")


if __name__ == "__main__":
    main()
