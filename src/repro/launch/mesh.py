"""Production meshes. Importing this module never touches jax device
state — meshes are built only inside the factory functions.

All mesh construction goes through `repro.compat.make_mesh`, which
passes `axis_types=Auto` on jax versions that support it and omits the
keyword on jax 0.4.x (where `jax.sharding.AxisType` does not exist and
all axes are Auto by default).
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds the 2-pod WAN axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(pods: int = 1, data: int = 16, model: int = 16):
    """General mesh factory (elastic scaling: any pod count)."""
    if pods > 1:
        return compat.make_mesh((pods, data, model), ("pod", "data", "model"))
    return compat.make_mesh((data, model), ("data", "model"))
