"""Production meshes. Importing this module never touches jax device
state — meshes are built only inside the factory functions."""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds the 2-pod WAN axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(pods: int = 1, data: int = 16, model: int = 16):
    """General mesh factory (elastic scaling: any pod count)."""
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
