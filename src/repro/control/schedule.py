"""Plan -> wire lowering: the public schedule + codec API.

Every subsystem that moves bytes across the WAN (training gradient
sync, serving KV-cache migration, future bulk transfer paths) lowers a
``WanPlan`` to the same two primitives:

  * :func:`offset_schedule` — per offset class (pod ``i <-> (i+o) % P``,
    the paper's closeness classes on a geo-ring), the chunk multiplicity
    (heterogeneous parallel connections) and the wire bits (SAGQ-style
    BW-aware quantization from the weakest predicted link in the class).
  * :func:`wire_encode` / :func:`wire_decode` — the quantizing wire
    codec. ``axes=None`` gives one scalar scale per segment (the
    shard_map formulation, one segment per device); ``axes=(1, ...)``
    gives per-slice scales rolled along with the payload (the batched
    vmap-over-pods formulation). Previously these were two near-
    duplicate private codecs in ``core/wansync.py``.

``pick_bits`` (the BW -> bits policy) is re-exported from
``core/plan.py`` so consumers need only this module.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import WanPlan, pick_bits

__all__ = ["offset_schedule", "wire_encode", "wire_decode", "pick_bits"]

MAX_CHUNKS = 16


# ----------------------------------------------------------------------
# Plan -> per-offset schedule
# ----------------------------------------------------------------------
def offset_schedule(plan: WanPlan) -> List[Dict[str, int]]:
    """For each offset o in [1, P-1]: chunk multiplicity (max conns over
    the pairs in that class — the WANify heterogeneous connections) and
    wire bits (from the weakest predicted link in the class)."""
    P = plan.n_pods
    bits = plan.offset_bits()      # part of plan.signature(): replans
    sched = []                     # with equal signatures lower equally
    for o in range(1, P):
        conns = max(plan.conns[i][(i + o) % P] for i in range(P))
        # round to a power of two so chunk splits always divide segments
        chunks = 1 << max(0, int(np.ceil(np.log2(max(1, int(conns))))))
        sched.append({"offset": o, "chunks": min(chunks, MAX_CHUNKS),
                      "bits": bits[o - 1]})
    return sched


# ----------------------------------------------------------------------
# Wire codec (segment-scalar or per-slice scale; fine-grained blockwise
# scaling is the Pallas kernel on real TPUs — kernels/quantize.py)
# ----------------------------------------------------------------------
def wire_encode(x: jax.Array, bits: int,
                axes: Optional[Tuple[int, ...]] = None):
    """Quantize `x` for the wire. Returns (payload, scale-or-None).

    axes=None  -> one scalar scale over the whole segment.
    axes=(...) -> scales reduced over `axes` with keepdims (one scale
                  per remaining slice, e.g. per pod slice when
                  axes=range(1, ndim)).
    """
    if bits >= 32:
        return x, None
    if bits == 16:
        return x.astype(jnp.bfloat16), None
    qmax = float((1 << (bits - 1)) - 1)
    mag = jnp.abs(x.astype(jnp.float32))
    amax = jnp.max(mag) if axes is None \
        else jnp.max(mag, axis=axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int8), scale


def wire_decode(q: jax.Array, scale, dtype, bits: int):
    """Inverse of :func:`wire_encode` (scalar and per-slice scales share
    one decode path)."""
    if bits >= 32:
        return q
    if bits == 16:
        return q.astype(dtype)
    return (q.astype(jnp.float32) * scale).astype(dtype)
