"""WanifyController — the paper's closed loop as a first-class subsystem.

The loop (cheap snapshot -> RF runtime-BW prediction -> global
connection-range optimization -> per-DC AIMD adaptation -> transfer
plan) used to live as private machinery inside the training loop; this
controller owns it once, shared by training, serving, and planning:

  * monitoring   — a :class:`SnapshotMonitor` captured at the CURRENT
    connection matrix (the seed measured at all-ones, so the agents
    adapted against traffic-free links);
  * prediction   — any object with ``predict_matrix`` (the RF
    :class:`BwPredictor`, or :class:`SnapshotPredictor` for the paper's
    no-prediction ablation);
  * optimization — :func:`global_optimize` ranges + per-DC AIMD agents
    fine-tuning inside them;
  * triggers     — periodic (:meth:`maybe_replan`), straggler
    (:meth:`observe_step_time`), explicit topology change
    (:meth:`topology_changed`), elastic rescale (:meth:`rescale`,
    paper §3.3.2) and on-demand (:meth:`replan`, e.g. serve-side);
  * plan cache   — :meth:`compiled` memoizes consumer-built artifacts
    (jitted steps, lowered migrations) on ``WanPlan.signature()`` so
    oscillating plans never recompile; `cache_builds`/`cache_hits`
    count lowerings vs reuses;
  * event log    — human-readable `events` (shareable with a consumer's
    own log) plus a structured `record` of every replan, mirrored to an
    optional `trace_hook` callable (the scenario engine's tap); the
    last predicted matrix is kept on `last_pred` so a harness can line
    up predicted vs achieved BW per step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.global_opt import global_optimize
from repro.core.local_opt import AimdAgent
from repro.core.plan import WanPlan
from repro.obs.registry import MetricsRegistry
from repro.obs.spans import NULL_TRACER
from repro.overlay.routing import RoutedPlan, overlay_mode, plan_routes
from repro.wan.monitor import SnapshotMonitor
from repro.wan.simulator import WanSimulator


@dataclass(frozen=True)
class BudgetEnvelope:
    """Externally arbitrated resource envelope for one job (tenant).

    A fleet controller (repro.fleet) computes one of these per job
    before each arbitration epoch: `max_conns` replaces the job's own
    per-host budget M for its next `global_optimize`, and `link_cap`
    ([P,P] Mbps at the job's pod scale, np.inf = uncapped) joins the
    §3.2.2 throttle so the job never targets more than its weighted
    fair share of a contended link. A job without an envelope plans
    exactly as before — the envelope is opt-in, not a new code path.
    """
    max_conns: int
    link_cap: Optional[np.ndarray] = None


@dataclass
class ControllerConfig:
    """Tuning knobs of one controller's triggers and budget."""

    max_conns: int = 8               # M, per-host connection budget
    replan_every: int = 20           # periodic trigger cadence (steps)
    straggler_factor: float = 2.5    # step slower than factor x EWMA
    straggler_cooldown: int = 0      # min steps between straggler replans
    #                                  (0 = trigger on every slow step)
    ewma_alpha: float = 0.1          # step-time EWMA smoothing
    advance_sim: bool = True         # advance link fluctuation on the
    #                                  periodic trigger (simulated time)

    def __post_init__(self) -> None:
        """Fail loudly at construction — a bad knob here otherwise
        misbehaves ticks later (replan_every=0 divides by zero, a
        non-positive straggler factor replans every single step)."""
        if self.max_conns < 1:
            raise ValueError(f"max_conns must be >= 1, got "
                             f"{self.max_conns}")
        if self.replan_every < 1:
            raise ValueError(f"replan_every must be >= 1, got "
                             f"{self.replan_every}")
        if self.straggler_factor <= 0:
            raise ValueError(f"straggler_factor must be > 0, got "
                             f"{self.straggler_factor}")
        if self.straggler_cooldown < 0:
            raise ValueError(f"straggler_cooldown must be >= 0, got "
                             f"{self.straggler_cooldown}")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha must be in (0, 1], got "
                             f"{self.ewma_alpha}")


class WanifyController:
    """One instance per workload (a Trainer, a serving Engine, a
    planner); `n_pods` may be smaller than the monitored cluster."""

    def __init__(self, sim: WanSimulator, predictor: Any, n_pods: int,
                 cfg: Optional[ControllerConfig] = None,
                 events: Optional[List[str]] = None,
                 trace_hook: Optional[Callable[[Dict[str, Any]], None]]
                 = None,
                 envelope: Optional[BudgetEnvelope] = None,
                 overlay: Optional[str] = None,
                 lifecycle: Optional[Any] = None):
        self.sim = sim
        self.predictor = predictor
        self.n_pods = int(n_pods)
        self.cfg = cfg or ControllerConfig()
        # Terra-style overlay routing gate: "on" runs the relay search
        # on every replan and exposes the result on `routed` /
        # `current_routing()`; "off" (default, or $REPRO_OVERLAY) runs
        # no routed code path at all, keeping replays byte-identical
        self.overlay = overlay_mode(overlay)
        self.routed: Optional[RoutedPlan] = None
        # online predictor lifecycle (repro.lifecycle): when a manager
        # is attached, every replan's predicted matrix passes through
        # its capacity clamp; None (default) runs no lifecycle code
        self.lifecycle = lifecycle
        self.monitor = SnapshotMonitor(sim)
        # a consumer may hand in its own log list; both append to it
        self.events: List[str] = events if events is not None else []
        self.record: List[Dict[str, Any]] = []
        self.trace_hook = trace_hook
        self.plan_cache: Dict[Tuple, Any] = {}
        # ad-hoc counters live on the obs registry (repro.obs);
        # `cache_builds`/`cache_hits` stay readable as properties
        self.metrics = MetricsRegistry("controller")
        self._m_builds = self.metrics.counter(
            "cache_builds", help="plan-cache misses (artifacts lowered)")
        self._m_hits = self.metrics.counter(
            "cache_hits", help="plan-cache reuses")
        self._m_replans = self.metrics.counter(
            "replans_total", help="full loop iterations run")
        # span tracer: NULL_TRACER unless a harness installs a real one
        # (scenario engine / fleet controller with REPRO_OBS=on)
        self.tracer = NULL_TRACER
        self.last_pred: Optional[np.ndarray] = None
        self.envelope = envelope     # arbitrated budget (None = own M)
        # fault plane (repro.faults): when an engine attaches one,
        # replan captures/predictions route through its degradation
        # ladder; None (default) runs no fault code at all
        self.faults: Optional[Any] = None
        self._prev_plan: Optional[WanPlan] = None
        self._agents: Optional[List[AimdAgent]] = None
        self._ewma: Optional[float] = None
        self._last_straggler: Optional[int] = None
        self._obs_count = 0
        self.plan = self.replan(reason="init")

    # ------------------------------------------------------------------
    # The closed loop
    # ------------------------------------------------------------------
    def current_conns(self) -> np.ndarray:
        """Connection matrix currently in force, at monitor scale
        (idle/unmanaged links run a single connection)."""
        c = np.ones((self.sim.N, self.sim.N))
        if self._agents is not None:
            for i, ag in enumerate(self._agents):
                c[i, :self.n_pods] = ag.cons
        return c

    def set_envelope(self, envelope: Optional[BudgetEnvelope]) -> None:
        """Adopt (or clear) an arbitrated budget/throttle envelope; it
        takes effect at the next replan."""
        self.envelope = envelope

    def add_trace_hook(self, fn: Callable[[Dict[str, Any]], None]) -> None:
        """Compose `fn` onto the replan trace stream, keeping any hook
        already installed — the scenario engine's tap and a placement
        planner's re-place trigger can both listen to one controller."""
        prev = self.trace_hook
        if prev is None:
            self.trace_hook = fn
        else:
            def both(rec, _prev=prev, _fn=fn):
                _prev(rec)
                _fn(rec)
            self.trace_hook = both

    def replan(self, skew_w: Optional[np.ndarray] = None,
               reason: str = "explicit",
               step: Optional[int] = None, *,
               capture: Optional[Dict[str, np.ndarray]] = None,
               pred: Optional[np.ndarray] = None) -> WanPlan:
        """Run one full loop iteration and return the resulting plan.

        `capture` / `pred` let an outer orchestrator supply the raw
        snapshot and the predicted-BW matrix instead of this controller
        capturing/predicting itself — the fleet controller captures
        every job first, stacks the feature rows, runs ONE batched RF
        kernel launch, then hands each job its slice here. Both must be
        at monitor scale ([N,N] of `self.sim`); AIMD feedback still
        comes from the capture's snapshot.
        """
        tr = self.tracer
        conns = self.current_conns()
        # the matrix the snapshot was measured at: consumers scaling
        # predicted BW to a different connection count (the placement
        # planner's achievable-BW pricing) scale from this operating
        # point via the paper's BW-grows-linearly-with-conns claim
        self.last_capture_conns = conns
        pred_override = None
        if capture is None:
            with tr.span("snapshot"):
                if self.faults is not None:
                    # the fault boundary: injected probe faults /
                    # monitor outages surface here; graceful mode
                    # climbs the retry/staleness ladder and may hand
                    # back a prediction override (the SnapshotPredictor
                    # rung) when the capture is too stale to trust
                    capture, pred_override = self.faults.captured(
                        self.monitor, conns)
                else:
                    _, capture = self.monitor.capture(conns)
        raw = capture
        if pred is None:
            if pred_override is not None:
                pred = pred_override
            else:
                with tr.span("predict"):
                    pred = self.predictor.predict_matrix(
                        self.sim.N, raw["snapshot_bw"], raw["mem_util"],
                        raw["cpu_load"], raw["retrans"], raw["dist"])
            if self.faults is not None:
                # inject any scripted predictor fault, then (graceful)
                # quarantine poisoned rows before they reach the solver
                pred = self.faults.predicted(pred, raw["snapshot_bw"])
        if self.lifecycle is not None:
            # sanity clamp: the RF may not promise BW beyond what the
            # lifecycle's windowed percentile capacity has ever seen
            pred = self.lifecycle.adjust_prediction(pred)
        pods = pred[:self.n_pods, :self.n_pods]
        M = self.cfg.max_conns
        link_cap = None
        if self.envelope is not None:
            M = int(self.envelope.max_conns)
            if self.envelope.link_cap is not None:
                link_cap = np.asarray(self.envelope.link_cap, np.float64)
                if link_cap.shape != (self.n_pods, self.n_pods):
                    # a mesh-scale cap silently prefix-sliced would cap
                    # the WRONG links for any non-prefix DC slice
                    raise ValueError(
                        f"envelope link_cap shape {link_cap.shape} != "
                        f"({self.n_pods}, {self.n_pods}); slice caps to "
                        f"the controller's pod scale first (the fleet "
                        f"does this via TenantView.extract)")
        with tr.span("optimize"):
            gp = global_optimize(pods, M=M, w_s=skew_w, link_cap=link_cap)
        with tr.span("aimd"):
            if self._agents is None or len(self._agents) != self.n_pods:
                self._agents = [AimdAgent.from_plan(gp, i)
                                for i in range(self.n_pods)]
            else:
                # fine-tune inside the new global bounds against BW
                # monitored at the connection matrix actually in force —
                # the capture above already measured at `conns`, so
                # reuse it instead of paying a second waterfill + noise
                # draw
                monitored = raw["snapshot_bw"][:self.n_pods, :self.n_pods]
                for i, ag in enumerate(self._agents):
                    ag.min_cons, ag.max_cons = gp.min_cons[i], gp.max_cons[i]
                    ag.min_bw, ag.max_bw = gp.min_bw[i], gp.max_bw[i]
                    ag.unit_bw, ag.throttle = gp.pred_bw[i], gp.throttle[i]
                    ag.step(monitored[i])
            cons = np.stack([ag.cons for ag in self._agents])
        plan = WanPlan(
            n_pods=self.n_pods,
            conns=tuple(tuple(int(v) for v in row) for row in cons),
            pred_bw=tuple(tuple(float(v) for v in row)
                          for row in gp.pred_bw),
            compress_bits=WanPlan.from_global(gp).compress_bits,
        )
        self._prev_plan = getattr(self, "plan", None)
        self.plan = plan
        self.last_pred = pred
        off = ~np.eye(self.n_pods, dtype=bool)
        rec = {"reason": reason, "step": step,
               "signature": plan.signature(), "n_pods": self.n_pods,
               "pred_min": float(pods[off].min()) if off.any() else 0.0,
               "pred_mean": float(pods[off].mean()) if off.any() else 0.0}
        if self.overlay == "on":
            # route selection rides every replan: split each pair's
            # planned connections between the direct link and the best
            # closeness-pruned one-hop relay on the predicted surface
            with tr.span("route"):
                self.routed = plan_routes(
                    gp.pred_bw, cons, dc_rel=gp.dc_rel,
                    capture_conns=self.last_capture_conns)
            rec["overlay"] = "on"
            rec["relays"] = self.routed.relays
            rec["routed_signature"] = self.routed.signature()
        self._m_replans.inc()
        self.metrics.counter("replans", labels={"reason": reason}).inc()
        self.record.append(rec)
        if self.trace_hook is not None:
            self.trace_hook(rec)
        return plan

    def current_routing(self) -> Optional[Tuple[np.ndarray, Tuple]]:
        """The in-force overlay routing lowered to monitor scale, or
        None when the overlay is off (or chose no relays): a
        ``(direct, relays)`` pair for
        :meth:`WanSimulator.waterfill_routed` — the [N,N] direct
        connection matrix (relay shares already moved off the weak
        pairs) plus the chosen ``(src, via, dst, conns)`` paths."""
        if self.routed is None or not self.routed.relays:
            return None
        direct = self.current_conns()
        P = self.n_pods
        direct[:P, :P] = np.asarray(self.routed.direct, np.float64)
        return direct, self.routed.relays

    def rollback_plan(self, step: Optional[int] = None
                      ) -> Optional[WanPlan]:
        """Restore the last-known-good plan (fault-plane rung 5).

        Called by an engine when executing the CURRENT plan failed
        downstream (a diverging water-fill): re-adopt the previous
        plan and reseat every AIMD agent's connection vector on it, so
        the next step runs a configuration that is known to have
        executed. The restored plan's signature is already in the plan
        cache, so the consumer's re-lower is a cache hit, not a
        rebuild. Returns the restored plan, or None when there is no
        previous plan to roll back to (the bad plan stays in force)."""
        prev = self._prev_plan
        if prev is None:
            return None
        self.plan = prev
        self._prev_plan = None       # don't ping-pong between two plans
        if self._agents is not None and len(prev.conns) == self.n_pods:
            for i, ag in enumerate(self._agents):
                ag.cons = np.array(prev.conns[i], np.int64)
        self.events.append(f"rolled back to last-known-good plan at "
                           f"step {step}")
        rec = {"reason": "rollback", "step": step,
               "signature": prev.signature(), "n_pods": self.n_pods,
               "pred_min": 0.0, "pred_mean": 0.0}
        self.record.append(rec)
        if self.trace_hook is not None:
            self.trace_hook(rec)
        return prev

    # ------------------------------------------------------------------
    # Triggers
    # ------------------------------------------------------------------
    def replan_due(self, step: int) -> bool:
        """True when the periodic trigger fires at this step."""
        return (step + 1) % self.cfg.replan_every == 0

    def maybe_replan(self, step: int,
                     skew_w: Optional[np.ndarray] = None
                     ) -> Optional[WanPlan]:
        """Periodic trigger: returns the new plan iff it is due AND its
        signature differs (a signature-stable replan needs no re-lower,
        so the consumer can keep its compiled step)."""
        if not self.replan_due(step):
            return None
        if self.cfg.advance_sim:
            self.sim.advance()
        old_sig = self.plan.signature()
        new = self.replan(skew_w=skew_w, reason="periodic", step=step)
        if new.signature() == old_sig:
            return None
        self.events.append(f"replanned at step {step}")
        return new

    def observe_step_time(self, dt: float,
                          step: Optional[int] = None
                          ) -> Optional[WanPlan]:
        """Straggler trigger: feed per-step wall time; a step slower
        than `straggler_factor` x EWMA forces an AIMD multiplicative
        decrease on every agent plus an immediate replan."""
        eff_step = self._obs_count if step is None else step
        self._obs_count += 1
        if self._ewma is None:
            self._ewma = dt
        plan = None
        in_cooldown = (self._last_straggler is not None and
                       eff_step - self._last_straggler
                       < self.cfg.straggler_cooldown)
        if dt > self.cfg.straggler_factor * self._ewma and not in_cooldown:
            self.events.append(f"straggler at step {eff_step} ({dt:.2f}s)")
            self._last_straggler = eff_step
            for ag in self._agents or []:
                ag.step(np.zeros_like(ag.target_bw))
            plan = self.replan(reason="straggler", step=eff_step)
        self._ewma = (1 - self.cfg.ewma_alpha) * self._ewma \
            + self.cfg.ewma_alpha * dt
        return plan

    def topology_changed(self) -> WanPlan:
        """Explicit trigger: the cluster changed under us (links added /
        removed, provider migration). Discard adapted state — the old
        AIMD bounds no longer describe the network."""
        self._agents = None
        self._ewma = None
        self._last_straggler = None
        self.events.append("topology changed; replanning from scratch")
        return self.replan(reason="topology")

    def rescale(self, n_pods: int,
                skew_w: Optional[np.ndarray] = None) -> WanPlan:
        """Elastic rescale (§3.3.2): plan for a new pod count. The
        predictor covers the new cluster size (n_dcs is a Table-3
        feature); agents restart from the new global ranges."""
        if n_pods > self.sim.N:
            raise ValueError(
                f"n_pods={n_pods} exceeds monitored cluster ({self.sim.N})")
        self.n_pods = int(n_pods)
        self._agents = None
        self._ewma = None        # step times change scale with pod count
        self._last_straggler = None
        self.events.append(f"rescaled controller to {n_pods} pods")
        return self.replan(skew_w=skew_w, reason=f"rescale:{n_pods}")

    # ------------------------------------------------------------------
    # Plan cache
    # ------------------------------------------------------------------
    def compiled(self, extra_key: Tuple, build: Callable[[WanPlan], Any]):
        """Memoize `build(plan)` on (plan.signature(), *extra_key):
        re-plans that oscillate back to a seen signature reuse the
        compiled artifact instead of re-lowering."""
        key = (self.plan.signature(),) + tuple(extra_key)
        if key not in self.plan_cache:
            self._m_builds.inc()
            self.plan_cache[key] = build(self.plan)
        else:
            self._m_hits.inc()
        return self.plan_cache[key]

    # -- back-compat aliases onto the obs registry ---------------------
    @property
    def cache_builds(self) -> int:
        """Plan-cache misses (artifacts lowered); registry-backed."""
        return int(self._m_builds.value)

    @cache_builds.setter
    def cache_builds(self, v: int) -> None:
        """Legacy reset path (tests zero the tally between phases)."""
        self._m_builds.reset(int(v))

    @property
    def cache_hits(self) -> int:
        """Plan-cache reuses; registry-backed."""
        return int(self._m_hits.value)

    @cache_hits.setter
    def cache_hits(self, v: int) -> None:
        """Legacy reset path for the reuse tally."""
        self._m_hits.reset(int(v))
