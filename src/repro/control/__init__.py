"""repro.control — the unified WANify control plane.

One subsystem owns the paper's closed loop (snapshot -> prediction ->
global optimization -> AIMD -> plan) and the plan -> wire lowering;
training (`train/loop.py`), serving (`serve/engine.py`), and planning
(`examples/wan_planning.py`) are thin consumers. See DESIGN.md.
"""
from repro.control.controller import (BudgetEnvelope, ControllerConfig,
                                      WanifyController)
from repro.control.schedule import (offset_schedule, pick_bits,
                                    wire_decode, wire_encode)

__all__ = [
    "BudgetEnvelope",
    "ControllerConfig",
    "WanifyController",
    "offset_schedule",
    "pick_bits",
    "wire_encode",
    "wire_decode",
]
