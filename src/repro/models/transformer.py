"""Decoder-only LM assembly for dense / moe / ssm / hybrid families.

Layers are stacked ([L, ...] leaves) and applied with lax.scan (small HLO,
fast multi-pod compile). `first_dense_layers` (DeepSeek-V2) run as an
unstacked prologue. Hybrid (Zamba2) interleaves ONE shared attention+MLP
block (single param set, its own KV cache per application) every
`shared_attn_every` SSM layers.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as att
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (KeyGen, ShardCtx, dense_init, rms_norm,
                                 shard, shard_act, swiglu)

AUX_LOSS_COEF = 0.01


# ======================================================================
# Init
# ======================================================================
def _init_block(kg: KeyGen, cfg: ModelConfig, dtype, kind: str, stack: int = 0):
    """kind: dense | moe | ssm | shared_attn."""
    L = (stack,) if stack else ()
    d = cfg.d_model
    blk: Dict[str, Any] = {}
    if kind in ("dense", "moe", "shared_attn"):
        blk["ln1"] = jnp.ones(L + (d,), dtype)
        blk["ln2"] = jnp.ones(L + (d,), dtype)
        if stack:
            ap = [init_attn(kg, cfg, dtype) for _ in range(stack)]
            blk["attn"] = jax.tree.map(lambda *xs: jnp.stack(xs), *ap)
        else:
            blk["attn"] = init_attn(kg, cfg, dtype)
        if kind == "moe":
            blk["moe"] = moe_mod.init_moe_params(kg, cfg, dtype, stack=stack)
        else:
            f = cfg.d_ff
            blk["mlp"] = {
                "w1": dense_init(kg(), L + (d, f), dtype),
                "w3": dense_init(kg(), L + (d, f), dtype),
                "w2": dense_init(kg(), L + (f, d), dtype),
            }
    elif kind == "ssm":
        blk["ln1"] = jnp.ones(L + (d,), dtype)
        blk["ssm"] = ssm_mod.init_ssm_params(kg, cfg, dtype, stack=stack)
    return blk


def init_attn(kg: KeyGen, cfg: ModelConfig, dtype):
    if cfg.is_mla:
        return att.init_mla_params(kg, cfg, dtype)
    return att.init_gqa_params(kg, cfg, dtype)


def init_lm_params(cfg: ModelConfig, key: jax.Array,
                   dtype: Optional[Any] = None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    kg = KeyGen(key)
    d, V = cfg.d_model, cfg.vocab
    params: Dict[str, Any] = {
        "embed": dense_init(kg(), (V, d), dtype, scale=0.02),
        "final_norm": jnp.ones((d,), dtype),
        "lm_head": dense_init(kg(), (d, V), dtype),
    }
    n_pro = cfg.moe.first_dense_layers if cfg.is_moe else 0
    n_scan = cfg.n_layers - n_pro
    if n_pro:
        params["prologue"] = [_init_block(kg, cfg, dtype, "dense")
                              for _ in range(n_pro)]
    if cfg.family == "ssm":
        params["blocks"] = _init_block(kg, cfg, dtype, "ssm", stack=n_scan)
    elif cfg.family == "hybrid":
        params["blocks"] = _init_block(kg, cfg, dtype, "ssm", stack=n_scan)
        params["shared_attn"] = _init_block(kg, cfg, dtype, "shared_attn")
    elif cfg.is_moe:
        params["blocks"] = _init_block(kg, cfg, dtype, "moe", stack=n_scan)
    else:
        params["blocks"] = _init_block(kg, cfg, dtype, "dense", stack=n_scan)
    return params


# ======================================================================
# Forward blocks (full sequence)
# ======================================================================
def _attn_mlp_block(blk, x, positions, cfg: ModelConfig, ctx: ShardCtx,
                    dp_size: int):
    """Returns (x, aux_loss, expert_load)."""
    h = rms_norm(x, blk["ln1"], cfg.norm_eps)
    if cfg.is_mla:
        a = att.mla_forward(blk["attn"], h, ctx, cfg, positions)
    else:
        a = att.gqa_forward(blk["attn"], h, ctx, cfg, positions)
    x = x + a
    h = rms_norm(x, blk["ln2"], cfg.norm_eps)
    if "moe" in blk:
        y, aux, load = moe_mod.moe_forward(blk["moe"], h, ctx, cfg, dp_size)
    else:
        y = swiglu(h, blk["mlp"]["w1"], blk["mlp"]["w3"], blk["mlp"]["w2"], ctx)
        aux = jnp.zeros((), jnp.float32)
        load = jnp.zeros((max(cfg.moe.n_experts, 1),), jnp.float32)
    x = shard_act(x + y, ctx)
    return x, aux, load


def _ssm_block(blk, x, cfg: ModelConfig, ctx: ShardCtx):
    h = rms_norm(x, blk["ln1"], cfg.norm_eps)
    return shard_act(x + ssm_mod.ssm_forward(blk["ssm"], h, ctx, cfg), ctx)


def _maybe_remat(fn, ctx: ShardCtx):
    if ctx.remat == "none":
        return fn
    if ctx.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def lm_backbone(params: Dict, x: jax.Array, positions: jax.Array,
                cfg: ModelConfig, ctx: ShardCtx, dp_size: int = 1
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Embedded input -> final hidden. Returns (h, aux_loss, load[E])."""
    aux_total = jnp.zeros((), jnp.float32)
    load_total = jnp.zeros((max(cfg.moe.n_experts, 1),), jnp.float32)

    for blk in params.get("prologue", []):
        fn = _maybe_remat(
            lambda b, v: _attn_mlp_block(b, v, positions, cfg, ctx, dp_size), ctx)
        x, aux, _ = fn(blk, x)
        aux_total += aux

    if cfg.family in ("ssm", "hybrid"):
        n_scan = jax.tree.leaves(params["blocks"])[0].shape[0]
        every = cfg.shared_attn_every

        def body(carry, xs):
            h = carry
            blk, use_attn = xs
            if every:
                def with_attn(v):
                    o, _, _ = _attn_mlp_block(params["shared_attn"], v,
                                              positions, cfg, ctx, dp_size)
                    return o
                h = jax.lax.cond(use_attn, with_attn, lambda v: v, h)
            h = _ssm_block(blk, h, cfg, ctx)
            return h, None

        flags = (jnp.arange(n_scan) % every == 0) if every else \
            jnp.zeros((n_scan,), bool)
        x, _ = jax.lax.scan(_maybe_remat(lambda c, s: body(c, s), ctx),
                            x, (params["blocks"], flags))
    else:
        def body(carry, blk):
            h, aux, load = carry
            h, a, l = _attn_mlp_block(blk, h, positions, cfg, ctx, dp_size)
            return (h, aux + a, load + l), None

        (x, aux_total, load_total), _ = jax.lax.scan(
            _maybe_remat(lambda c, b: body(c, b), ctx),
            (x, aux_total, load_total), params["blocks"])

    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux_total, load_total


def lm_logits(params, h):
    return h @ params["lm_head"]


def lm_forward(params: Dict, tokens: jax.Array, cfg: ModelConfig,
               ctx: ShardCtx, dp_size: int = 1,
               extra_embeds: Optional[jax.Array] = None):
    """tokens [B,S] (+optional prepended embeddings) -> logits [B,S*,V]."""
    cdt = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(cdt)[tokens]
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cdt), x], axis=1)
    S = x.shape[1]
    x = shard_act(x, ctx)
    positions = jnp.arange(S)
    pc = _cast_params(params, cdt)
    h, aux, load = lm_backbone(pc, x, positions, cfg, ctx, dp_size)
    return lm_logits(pc, h), aux, load


def _cast_params(params, dtype):
    def cast(x):
        return x.astype(dtype) if x.dtype in (jnp.float32, jnp.bfloat16) and \
            x.ndim >= 2 else x
    return jax.tree.map(cast, params)


def lm_loss(params: Dict, batch: Dict, cfg: ModelConfig, ctx: ShardCtx,
            dp_size: int = 1) -> Tuple[jax.Array, Dict]:
    """Backbone -> chunked CE (never materializes [B,S,V] f32 logits)."""
    cdt = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    x = params["embed"].astype(cdt)[tokens]
    if "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(cdt), x], axis=1)
    x = shard_act(x, ctx)
    positions = jnp.arange(x.shape[1])
    pc = _cast_params(params, cdt)
    h, aux, load = lm_backbone(pc, x, positions, cfg, ctx, dp_size)
    if "patch_embeds" in batch:
        h = h[:, batch["patch_embeds"].shape[1]:]
    from repro.models.layers import chunked_xent
    ce = chunked_xent(h, pc["lm_head"], batch["targets"], ctx)
    loss = ce + AUX_LOSS_COEF * aux
    return loss, {"ce": ce, "aux": aux, "expert_load": load}


# ======================================================================
# Prefill / decode
# ======================================================================
def kv_eff_heads(cfg: ModelConfig, tp: int) -> int:
    """Replicate KV heads up to the TP degree (never beyond the query
    head count) so the cache shards fully."""
    kv = cfg.n_kv_heads
    tp = min(tp, cfg.n_heads or tp)
    if kv >= tp or kv == 0:
        return kv
    r = -(-tp // kv)
    return min(kv * r, cfg.n_heads)


def lm_cache_spec(cfg: ModelConfig, B: int, S_max: int, tp: int = 16,
                  dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    n_pro = cfg.moe.first_dense_layers if cfg.is_moe else 0
    n_scan = cfg.n_layers - n_pro
    D = cfg.resolved_head_dim if cfg.n_heads else 0

    def attn_spec(L):
        if cfg.is_mla:
            m = cfg.mla
            shp = (L,) if L else ()
            return {
                "c_kv": jax.ShapeDtypeStruct(shp + (B, S_max, m.kv_lora_rank), dtype),
                "k_rope": jax.ShapeDtypeStruct(shp + (B, S_max, m.qk_rope_head_dim), dtype),
            }
        kve = kv_eff_heads(cfg, tp)
        S_c = min(S_max, cfg.sliding_window) if cfg.sliding_window else S_max
        shp = (L,) if L else ()
        return {
            "k": jax.ShapeDtypeStruct(shp + (B, kve, S_c, D), dtype),
            "v": jax.ShapeDtypeStruct(shp + (B, kve, S_c, D), dtype),
        }

    spec: Dict[str, Any] = {}
    if cfg.family == "ssm":
        spec["blocks"] = _stack_spec(ssm_mod.ssm_cache_spec(cfg, B, dtype), n_scan)
    elif cfg.family == "hybrid":
        spec["blocks"] = _stack_spec(ssm_mod.ssm_cache_spec(cfg, B, dtype), n_scan)
        n_apps = -(-n_scan // cfg.shared_attn_every)
        spec["shared_attn"] = _stack_spec(attn_spec(0), n_apps)
    else:
        if n_pro:
            spec["prologue"] = [attn_spec(0) for _ in range(n_pro)]
        spec["blocks"] = attn_spec(n_scan)
    return spec


def _stack_spec(tree, L):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((L,) + s.shape, s.dtype), tree)


def _attn_prefill_cache(blk, h_pre, cfg, ctx, positions, S_max, tp):
    """h_pre: post-ln1 activations feeding attention."""
    if cfg.is_mla:
        c_kv, k_rope = att.mla_make_cache(blk["attn"], h_pre, cfg, positions, S_max)
        return {"c_kv": c_kv, "k_rope": k_rope}
    kve = kv_eff_heads(cfg, tp)
    S_c = min(S_max, cfg.sliding_window) if cfg.sliding_window else S_max
    if cfg.sliding_window and h_pre.shape[1] > S_c:
        h_win = h_pre[:, -S_c:]
        pos_win = positions[-S_c:]
    else:
        h_win, pos_win = h_pre, positions
    k, v = att.gqa_make_cache(blk["attn"], h_win, cfg, ctx, pos_win, S_c, kve)
    return {"k": k, "v": v}


def lm_prefill(params: Dict, tokens: jax.Array, cfg: ModelConfig,
               ctx: ShardCtx, S_max: int, tp: int = 16, dp_size: int = 1,
               extra_embeds: Optional[jax.Array] = None):
    """Forward pass that also builds the decode cache. Returns
    (last_logits [B,V], cache)."""
    cdt = jnp.dtype(cfg.dtype)
    pc = _cast_params(params, cdt)
    x = pc["embed"][tokens]
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cdt), x], axis=1)
    S = x.shape[1]
    x = shard_act(x, ctx)
    positions = jnp.arange(S)
    cache: Dict[str, Any] = {}

    if "prologue" in pc:
        cache["prologue"] = []
        for blk in pc["prologue"]:
            h = rms_norm(x, blk["ln1"], cfg.norm_eps)
            cache["prologue"].append(
                _attn_prefill_cache(blk, h, cfg, ctx, positions, S_max, tp))
            x, _, _ = _attn_mlp_block(blk, x, positions, cfg, ctx, dp_size)

    if cfg.family in ("ssm", "hybrid"):
        every = cfg.shared_attn_every

        def body(carry, xs):
            h = carry
            blk, use_attn = xs
            out = {}
            if every:
                def mk_cache(v):
                    hp = rms_norm(v, pc["shared_attn"]["ln1"], cfg.norm_eps)
                    return _attn_prefill_cache(pc["shared_attn"], hp, cfg, ctx,
                                               positions, S_max, tp)

                struct = jax.eval_shape(mk_cache, h)
                out["attn_cache"] = jax.lax.cond(
                    use_attn, mk_cache,
                    lambda v: jax.tree.map(
                        lambda s: jnp.zeros(s.shape, s.dtype), struct), h)

                def with_attn(v):
                    o, _, _ = _attn_mlp_block(pc["shared_attn"], v, positions,
                                              cfg, ctx, dp_size)
                    return o
                h = jax.lax.cond(use_attn, with_attn, lambda v: v, h)
            hn = rms_norm(h, blk["ln1"], cfg.norm_eps)
            ssm_cache = _ssm_prefill_cache(blk["ssm"], hn, cfg, ctx)
            h = shard_act(h + ssm_mod.ssm_forward(blk["ssm"], hn, ctx, cfg), ctx)
            out["ssm_cache"] = ssm_cache
            return h, out

        n_scan = jax.tree.leaves(pc["blocks"])[0].shape[0]
        flags = (jnp.arange(n_scan) % every == 0) if every else \
            jnp.zeros((n_scan,), bool)
        x, ys = jax.lax.scan(body, x, (pc["blocks"], flags))
        cache["blocks"] = ys["ssm_cache"]
        if every:
            idx = jnp.nonzero(np_flags(n_scan, every), size=n_apps_of(n_scan, every))[0]
            cache["shared_attn"] = jax.tree.map(lambda t: t[idx], ys["attn_cache"])
    else:
        def body(carry, blk):
            h = carry
            hp = rms_norm(h, blk["ln1"], cfg.norm_eps)
            c = _attn_prefill_cache(blk, hp, cfg, ctx, positions, S_max, tp)
            h, _, _ = _attn_mlp_block(blk, h, positions, cfg, ctx, dp_size)
            return h, c

        x, cache["blocks"] = jax.lax.scan(body, x, pc["blocks"])

    h = rms_norm(x, pc["final_norm"], cfg.norm_eps)
    logits = lm_logits(pc, h[:, -1])
    return logits, cache


def np_flags(n, every):
    import numpy as np
    return np.arange(n) % every == 0


def n_apps_of(n, every):
    return -(-n // every)


def _ssm_prefill_cache(p, h, cfg, ctx):
    """Run the pieces of the ssm block needed to extract decode state."""
    s = cfg.ssm
    d_inner, H, conv_ch, _ = ssm_mod.ssm_dims(cfg)
    N, P = s.d_state, s.head_dim
    B, S, _ = h.shape
    zxbcdt = h @ p["in_proj"]
    xBC_raw = zxbcdt[..., d_inner:d_inner + conv_ch]
    dt_raw = zxbcdt[..., d_inner + conv_ch:]
    conv_state = xBC_raw[:, -(s.d_conv - 1):]
    xBC = jax.nn.silu(ssm_mod._causal_conv(xBC_raw, p["conv_w"], p["conv_b"]))
    xs = xBC[..., :d_inner]
    Bc = xBC[..., d_inner:d_inner + N]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    da = dt * a
    xh_dt = (xs.reshape(B, S, H, P).astype(jnp.float32) * dt[..., None]
             ).astype(h.dtype)
    Cc = xBC[..., d_inner + N:]
    _, final = ssm_mod.ssd_chunked(xh_dt, Bc, Cc, da, s.chunk)
    return {"conv": conv_state, "state": final}


def lm_decode(params: Dict, cache: Dict, tokens: jax.Array, pos: jax.Array,
              cfg: ModelConfig, ctx: ShardCtx, dp_size: int = 1):
    """One-token decode step. tokens [B,1] -> (logits [B,V], new cache)."""
    cdt = jnp.dtype(cfg.dtype)
    pc = _cast_params(params, cdt)
    x = pc["embed"][tokens]                                 # [B,1,d]
    new_cache: Dict[str, Any] = {}

    def attn_dec(blk, c, h):
        hp = rms_norm(h, blk["ln1"], cfg.norm_eps)
        if cfg.is_mla:
            o, ck, kr = att.mla_decode(blk["attn"], c["c_kv"], c["k_rope"],
                                       hp, pos, cfg, ctx)
            nc = {"c_kv": ck, "k_rope": kr}
        else:
            o, k, v = att.gqa_decode(blk["attn"], c["k"], c["v"], hp, pos,
                                     cfg, ctx, window=cfg.sliding_window)
            nc = {"k": k, "v": v}
        h = h + o
        hp = rms_norm(h, blk["ln2"], cfg.norm_eps)
        if "moe" in blk:
            y, _, _ = moe_mod.moe_forward(blk["moe"], hp, ctx, cfg, dp_size)
        else:
            y = swiglu(hp, blk["mlp"]["w1"], blk["mlp"]["w3"], blk["mlp"]["w2"], ctx)
        return h + y, nc

    if "prologue" in pc:
        new_cache["prologue"] = []
        for blk, c in zip(pc["prologue"], cache["prologue"]):
            x, nc = attn_dec(blk, c, x)
            new_cache["prologue"].append(nc)

    if cfg.family in ("ssm", "hybrid"):
        every = cfg.shared_attn_every
        n_scan = jax.tree.leaves(pc["blocks"])[0].shape[0]

        if every:
            def body(carry, xs):
                h, ac = carry
                blk, sc, use_attn, app_idx = xs

                def with_attn(operand):
                    h_, ac_ = operand
                    c_l = jax.tree.map(lambda t: t[app_idx], ac_)
                    h2, nc = attn_dec(pc["shared_attn"], c_l, h_)
                    ac2 = jax.tree.map(
                        lambda buf, upd: jax.lax.dynamic_update_index_in_dim(
                            buf, upd, app_idx, 0), ac_, nc)
                    return h2, ac2

                h, ac = jax.lax.cond(use_attn, with_attn, lambda o: o, (h, ac))
                hn = rms_norm(h, blk["ln1"], cfg.norm_eps)
                y, nsc = ssm_mod.ssm_decode(blk["ssm"], sc, hn, cfg, ctx)
                return (h + y, ac), nsc

            flags = jnp.arange(n_scan) % every == 0
            app_idx = jnp.cumsum(flags) - 1
            (x, ac), new_cache["blocks"] = jax.lax.scan(
                body, (x, cache["shared_attn"]),
                (pc["blocks"], cache["blocks"], flags, app_idx))
            new_cache["shared_attn"] = ac
        else:
            def body(h, xs):
                blk, sc = xs
                hn = rms_norm(h, blk["ln1"], cfg.norm_eps)
                y, nsc = ssm_mod.ssm_decode(blk["ssm"], sc, hn, cfg, ctx)
                return h + y, nsc

            x, new_cache["blocks"] = jax.lax.scan(
                body, x, (pc["blocks"], cache["blocks"]))
    else:
        def body(h, xs):
            blk, c = xs
            h, nc = attn_dec(blk, c, h)
            return h, nc

        x, new_cache["blocks"] = jax.lax.scan(body, x, (pc["blocks"], cache["blocks"]))

    h = rms_norm(x, pc["final_norm"], cfg.norm_eps)
    return lm_logits(pc, h[:, -1]), new_cache
