"""Family dispatch: one uniform API over all 10 assigned architectures.

  init_params(cfg, key)                     -> param pytree
  loss_fn(cfg, ctx, dp_size)(params,batch)  -> (loss, metrics)
  prefill_fn(cfg, ctx, S_max, tp)(params,batch) -> (logits, cache)
  decode_fn(cfg, ctx)(params,cache,tokens,pos)  -> (logits, cache)
  cache_spec(cfg, B, S_max, tp)             -> ShapeDtypeStruct pytree
  param_count(cfg) / active_param_count(cfg)
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer, vlm
from repro.models.layers import ShardCtx


def init_params(cfg: ModelConfig, key: jax.Array, dtype=None):
    if cfg.is_encdec:
        return encdec.init_encdec_params(cfg, key, dtype)
    if cfg.is_vlm:
        return vlm.init_vlm_params(cfg, key, dtype)
    return transformer.init_lm_params(cfg, key, dtype)


def abstract_params(cfg: ModelConfig, dtype=None):
    """ShapeDtypeStruct param tree — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.key(0))


def loss_fn(cfg: ModelConfig, ctx: ShardCtx, dp_size: int = 1) -> Callable:
    if cfg.is_encdec:
        return functools.partial(encdec.encdec_loss, cfg=cfg, ctx=ctx,
                                 dp_size=dp_size)
    if cfg.is_vlm:
        return functools.partial(vlm.vlm_loss, cfg=cfg, ctx=ctx,
                                 dp_size=dp_size)
    return functools.partial(transformer.lm_loss, cfg=cfg, ctx=ctx,
                             dp_size=dp_size)


def prefill_fn(cfg: ModelConfig, ctx: ShardCtx, S_max: int, tp: int = 16,
               dp_size: int = 1) -> Callable:
    if cfg.is_encdec:
        return lambda p, b: encdec.encdec_prefill(p, b, cfg, ctx, S_max, tp,
                                                  dp_size)
    if cfg.is_vlm:
        return lambda p, b: vlm.vlm_prefill(p, b, cfg, ctx, S_max, tp, dp_size)
    return lambda p, b: transformer.lm_prefill(p, b["tokens"], cfg, ctx,
                                               S_max, tp, dp_size)


def decode_fn(cfg: ModelConfig, ctx: ShardCtx, dp_size: int = 1) -> Callable:
    if cfg.is_encdec:
        return lambda p, c, t, pos: encdec.encdec_decode(p, c, t, pos, cfg,
                                                         ctx, dp_size)
    if cfg.is_vlm:
        return lambda p, c, t, pos: vlm.vlm_decode(p, c, t, pos, cfg, ctx,
                                                   dp_size)
    return lambda p, c, t, pos: transformer.lm_decode(p, c, t, pos, cfg, ctx,
                                                      dp_size)


def cache_spec(cfg: ModelConfig, B: int, S_max: int, tp: int = 16, dtype=None):
    if cfg.is_encdec:
        return encdec.encdec_cache_spec(cfg, B, S_max, tp, dtype)
    if cfg.is_vlm:
        return vlm.vlm_cache_spec(cfg, B, S_max, tp, dtype)
    return transformer.lm_cache_spec(cfg, B, S_max, tp, dtype)


# ----------------------------------------------------------------------
# Param accounting (roofline MODEL_FLOPS = 6*N*D, N_active for MoE)
# ----------------------------------------------------------------------
def param_count(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top_k + shared experts only)."""
    total = param_count(cfg)
    if not cfg.is_moe:
        return total
    m = cfg.moe
    # per-MoE-layer routed expert params
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    n_moe_layers = cfg.n_layers - m.first_dense_layers
    inactive = n_moe_layers * per_expert * (m.n_experts - m.top_k)
    return total - inactive
