"""Attention: GQA / MLA / SWA / qk-norm; flash (online-softmax) for
train & prefill; cached decode with KV-head replication for TP>n_kv and
XLA-partitionable softmax over sharded cache sequence dims.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (KeyGen, ShardCtx, apply_rope, dense_init,
                                 einsum_f32, head_rms_norm, shard)

NEG_INF = -1e30


# ======================================================================
# Flash attention — pure-jnp online softmax with a CUSTOM VJP: the
# backward recomputes per-block probabilities from saved (q,k,v,out,lse)
# (the classic flash backward), so AD never stores the per-block
# residuals of the forward scan. O(S) memory both directions. The TPU
# production path is a Pallas kernel; this is the dry-run/oracle path.
# ======================================================================
def _mask_for(i, bk, Sq, Sk, q_offset, causal, window):
    # qpos/kpos are built HERE so no tracer is closed over by the
    # custom_vjp fwd/bwd (jnp.arange stages a tracer under jit)
    qpos = q_offset + jnp.arange(Sq)
    kpos = i * bk + jnp.arange(bk)
    mask = jnp.broadcast_to(kpos[None, :] < Sk, (Sq, bk))
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    return mask


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_k: int = 512,
                    scale: Optional[float] = None) -> jax.Array:
    """q: [B,K,G,Sq,Dq]  k: [B,K,Sk,Dq]  v: [B,K,Sk,Dv] -> [B,K,G,Sq,Dv].

    K = kv heads, G = query group size (Hq = K*G). Scans over key blocks
    with a running (m, l, acc) softmax state; never materializes the
    [Sq, Sk] score matrix.
    """
    B, K, G, Sq, Dq = q.shape
    Sk, Dv = k.shape[2], v.shape[3]
    sc = scale if scale is not None else Dq ** -0.5
    bk = min(block_k, Sk)
    if Sk % bk:                                # pad keys; masked out below
        pad = bk - Sk % bk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nb = k.shape[2] // bk

    def _blocks(k, v):
        kb = k.reshape(B, K, nb, bk, Dq).transpose(2, 0, 1, 3, 4)
        vb = v.reshape(B, K, nb, bk, Dv).transpose(2, 0, 1, 3, 4)
        return kb, vb

    def _fwd_impl(q, k, v):
        kb, vb = _blocks(k, v)

        def body(carry, xs):
            m, l, acc = carry
            i, kblk, vblk = xs
            s = einsum_f32("bkgsd,bktd->bkgst", q, kblk) * sc
            s = jnp.where(_mask_for(i, bk, Sq, Sk, q_offset, causal, window),
                          s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + einsum_f32(
                "bkgst,bktd->bkgsd", p.astype(v.dtype), vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
        a0 = jnp.zeros((B, K, G, Sq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      (jnp.arange(nb), kb, vb))
        l_safe = jnp.maximum(l, 1e-30)
        out = (acc / l_safe[..., None]).astype(v.dtype)
        lse = m + jnp.log(l_safe)
        return out, lse

    @jax.custom_vjp
    def _flash(q, k, v):
        return _fwd_impl(q, k, v)[0]

    def _vjp_fwd(q, k, v):
        out, lse = _fwd_impl(q, k, v)
        return out, (q, k, v, out, lse)

    def _vjp_bwd(res, g):
        q, k, v, out, lse = res
        g32 = g.astype(jnp.float32)
        delta = jnp.sum(g32 * out.astype(jnp.float32), axis=-1)  # [B,K,G,Sq]
        kb, vb = _blocks(k, v)

        def body(dq, xs):
            i, kblk, vblk = xs
            s = einsum_f32("bkgsd,bktd->bkgst", q, kblk) * sc
            s = jnp.where(_mask_for(i, bk, Sq, Sk, q_offset, causal, window),
                          s, NEG_INF)
            p = jnp.exp(s - lse[..., None])                      # exact probs
            dv_b = einsum_f32("bkgst,bkgsd->bktd", p, g32)
            dp = einsum_f32("bkgsd,bktd->bkgst", g32, vblk)
            ds = p * (dp - delta[..., None])
            dq = dq + einsum_f32("bkgst,bktd->bkgsd", ds, kblk) * sc
            dk_b = einsum_f32("bkgst,bkgsd->bktd", ds, q) * sc
            return dq, (dk_b, dv_b)

        dq0 = jnp.zeros((B, K, G, Sq, Dq), jnp.float32)
        dq, (dk_b, dv_b) = jax.lax.scan(body, dq0, (jnp.arange(nb), kb, vb))
        # cotangents match the (possibly padded) operands of _flash; the
        # outer jnp.pad's own VJP slices back to the caller's Sk.
        dk = dk_b.transpose(1, 2, 0, 3, 4).reshape(B, K, nb * bk, Dq)
        dv = dv_b.transpose(1, 2, 0, 3, 4).reshape(B, K, nb * bk, Dv)
        return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

    _flash.defvjp(_vjp_fwd, _vjp_bwd)
    return _flash(q, k, v)


def swa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: int, scale: Optional[float] = None) -> jax.Array:
    """Banded local attention, O(S * 2W): q/k/v blocked by the window size;
    block i attends to blocks {i-1, i} with an exact band mask.
    q: [B,K,G,S,D] k,v: [B,K,S,D]."""
    B, K, G, S, Dq = q.shape
    Dv = v.shape[-1]
    W = window
    if S <= W:
        return flash_attention(q, k, v, causal=True, window=W, scale=scale)
    assert S % W == 0, f"S={S} not divisible by window={W}"
    nb = S // W
    sc = scale if scale is not None else Dq ** -0.5

    qb = q.reshape(B, K, G, nb, W, Dq)
    kb = k.reshape(B, K, nb, W, Dq)
    vb = v.reshape(B, K, nb, W, Dv)
    # previous block (block -1 is zeros and fully masked)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :, :1]), kb[:, :, :-1]], axis=2)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :, :1]), vb[:, :, :-1]], axis=2)
    k2 = jnp.concatenate([kprev, kb], axis=3)          # [B,K,nb,2W,Dq]
    v2 = jnp.concatenate([vprev, vb], axis=3)
    s = einsum_f32("bkgnsd,bkntd->bkgnst", qb * sc, k2)
    qpos = jnp.arange(W)[:, None]                       # within-block
    kpos = jnp.arange(2 * W)[None, :] - W               # relative to block start
    band = (qpos >= kpos) & ((qpos - kpos) < W)
    first = jnp.arange(nb) == 0                         # block -1 invalid for block 0
    valid_prev = (~first)[:, None, None] | (kpos[None] >= 0)
    mask = band[None] & valid_prev
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = einsum_f32("bkgnst,bkntd->bkgnsd", p.astype(v.dtype), v2)
    return out.reshape(B, K, G, S, Dv).astype(v.dtype)


# ======================================================================
# GQA (with optional qk-norm, SWA)
# ======================================================================
def init_gqa_params(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    d, H, KV, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    p = {
        "wq": dense_init(kg(), (d, H * D), dtype),
        "wk": dense_init(kg(), (d, KV * D), dtype),
        "wv": dense_init(kg(), (d, KV * D), dtype),
        "wo": dense_init(kg(), (H * D, d), dtype),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((D,), dtype)
        p["k_scale"] = jnp.ones((D,), dtype)
    return p


def _split_heads(x, n, d):
    B, S, _ = x.shape
    return x.reshape(B, S, n, d).transpose(0, 2, 1, 3)      # [B,n,S,d]


def gqa_forward(p: Dict, x: jax.Array, ctx: ShardCtx, cfg: ModelConfig,
                positions: jax.Array, *, cross_kv: Optional[Tuple] = None,
                causal: bool = True) -> jax.Array:
    """Full-sequence GQA used in train/prefill. positions: [S]."""
    B, S, d = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = _split_heads(x @ p["wq"], H, D)
    if cross_kv is None:
        k = _split_heads(x @ p["wk"], KV, D)
        v = _split_heads(x @ p["wv"], KV, D)
    else:
        k, v = cross_kv
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_scale"])
        k = head_rms_norm(k, p["k_scale"]) if cross_kv is None else k
    if cfg.rope_theta > 0 and cross_kv is None:
        q = apply_rope(q, positions[None, None, :], cfg.rope_theta)
        k = apply_rope(k, positions[None, None, :], cfg.rope_theta)
    elif cfg.rope_theta > 0:
        q = apply_rope(q, positions[None, None, :], cfg.rope_theta)
    ma = ctx.model_axis
    q = shard(q, ctx, ctx.batch_axes or None, ma, None, None)
    # Expand KV heads to the full query-head count before attention: the
    # grouped [B,KV,G,S,*] layout cannot shard KV(<TP) over the model
    # axis, and XLA then REPLICATES every per-block score tensor in the
    # flash scans (~2 GiB x layers x blocks of all-gather traffic).
    # Expanded [B,H,S,*] shards H/TP cleanly; the repeat's VJP sums dk/dv
    # back over groups. (EXPERIMENTS.md §Perf iteration 1.)
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=1)
        v = jnp.repeat(v, G, axis=1)
    k = shard(k, ctx, ctx.batch_axes or None, ma, None, None)
    v = shard(v, ctx, ctx.batch_axes or None, ma, None, None)
    qg = q[:, :, None]                                     # [B,H,1,S,D]
    if cfg.sliding_window and causal:
        o = swa_attention(qg, k, v, window=cfg.sliding_window)
    else:
        o = flash_attention(qg, k, v, causal=causal, block_k=ctx.flash_block)
    o = o[:, :, 0].transpose(0, 2, 1, 3).reshape(B, S, H * D)
    return o @ p["wo"]


def gqa_make_cache(p: Dict, x: jax.Array, cfg: ModelConfig, ctx: ShardCtx,
                   positions: jax.Array, S_max: int, kv_eff: int) -> Tuple:
    """Build a decode cache from prefill activations; pads to S_max and
    replicates KV heads to kv_eff (TP > n_kv)."""
    B, S, _ = x.shape
    KV, D = cfg.n_kv_heads, cfg.resolved_head_dim
    k = _split_heads(x @ p["wk"], KV, D)
    v = _split_heads(x @ p["wv"], KV, D)
    if cfg.qk_norm:
        k = head_rms_norm(k, p["k_scale"])
    if cfg.rope_theta > 0:
        k = apply_rope(k, positions[None, None, :], cfg.rope_theta)
    r = kv_eff // KV
    if r > 1:
        k = jnp.repeat(k, r, axis=1)
        v = jnp.repeat(v, r, axis=1)
    pad = S_max - S
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return k, v


def gqa_decode(p: Dict, cache_k: jax.Array, cache_v: jax.Array, x: jax.Array,
               pos: jax.Array, cfg: ModelConfig, ctx: ShardCtx,
               window: int = 0) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: [B,1,d]; cache: [B,KV_eff,S,D] (S may be a ring
    buffer of size `window` for SWA archs). Returns (out, new_k, new_v)."""
    B, _, d = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    KVe, S = cache_k.shape[1], cache_k.shape[2]
    r = KVe // KV
    q = _split_heads(x @ p["wq"], H, D)                     # [B,H,1,D]
    k = _split_heads(x @ p["wk"], KV, D)
    v = _split_heads(x @ p["wv"], KV, D)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_scale"])
        k = head_rms_norm(k, p["k_scale"])
    if cfg.rope_theta > 0:
        pp = pos[None, None, None] if pos.ndim == 0 else pos
        q = apply_rope(q, pp, cfg.rope_theta)
        k = apply_rope(k, pp, cfg.rope_theta)
    if r > 1:
        k, v = jnp.repeat(k, r, axis=1), jnp.repeat(v, r, axis=1)
    slot = pos % S if window else jnp.minimum(pos, S - 1)
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=2)
    G = H // KVe
    qg = q.reshape(B, KVe, G, 1, D)
    s = einsum_f32("bkgqd,bksd->bkgqs", qg * (D ** -0.5), ck)
    idx = jnp.arange(S)
    if window:
        valid = (idx <= (pos % S)) | (pos >= S)             # ring buffer: all valid once wrapped
    else:
        valid = idx <= pos
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = einsum_f32("bkgqs,bksd->bkgqd", pr.astype(cv.dtype), cv)
    o = o.reshape(B, H, 1, D).transpose(0, 2, 1, 3).reshape(B, 1, H * D)
    return (o @ p["wo"]).astype(x.dtype), ck, cv


# ======================================================================
# MLA — Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)
# ======================================================================
def init_mla_params(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    p: Dict = {}
    if m.q_lora_rank > 0:
        p["wq_a"] = dense_init(kg(), (d, m.q_lora_rank), dtype)
        p["q_norm"] = jnp.ones((m.q_lora_rank,), dtype)
        p["wq_b"] = dense_init(kg(), (m.q_lora_rank, H * qd), dtype)
    else:
        p["wq"] = dense_init(kg(), (d, H * qd), dtype)
    p["wkv_a"] = dense_init(kg(), (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype)
    p["kv_norm"] = jnp.ones((m.kv_lora_rank,), dtype)
    p["wkv_b"] = dense_init(
        kg(), (m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim)), dtype)
    p["wo"] = dense_init(kg(), (H * m.v_head_dim, d), dtype)
    return p


def _mla_q(p, x, cfg, positions):
    m, H = cfg.mla, cfg.n_heads
    nd, rd = m.qk_nope_head_dim, m.qk_rope_head_dim
    B, S, _ = x.shape
    if "wq_a" in p:
        from repro.models.layers import rms_norm
        q = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, S, H, nd + rd).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions[None, None, :], cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(p, x, cfg, positions):
    from repro.models.layers import rms_norm
    m = cfg.mla
    kv = x @ p["wkv_a"]
    c_kv = rms_norm(kv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, m.kv_lora_rank:].transpose(0, 2, 1, 3),
                        positions[None, None, :], cfg.rope_theta)    # [B,1,S,rd]
    return c_kv, k_rope


def mla_forward(p: Dict, x: jax.Array, ctx: ShardCtx, cfg: ModelConfig,
                positions: jax.Array) -> jax.Array:
    """Full-sequence MLA: expand k_nope/v from the latent and run flash
    with KV == H (MHA over expanded heads)."""
    m, H = cfg.mla, cfg.n_heads
    nd, rd, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    B, S, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_ckv(p, x, cfg, positions)
    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, H, nd + vd)
    k_nope = einsum_f32("bsr,rhd->bhsd", c_kv, wkv_b[..., :nd])
    v = jnp.einsum("bsr,rhd->bhsd", c_kv, wkv_b[..., nd:])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, H, S, rd))], axis=-1)
    ma = ctx.model_axis
    q = shard(q, ctx, ctx.batch_axes or None, ma, None, None)
    k = shard(k, ctx, ctx.batch_axes or None, ma, None, None)
    v = shard(v, ctx, ctx.batch_axes or None, ma, None, None)
    o = flash_attention(q[:, :, None], k, v, causal=True,
                        block_k=ctx.flash_block, scale=(nd + rd) ** -0.5)
    o = o[:, :, 0].transpose(0, 2, 1, 3).reshape(B, S, H * vd)
    return o @ p["wo"]


def mla_make_cache(p: Dict, x: jax.Array, cfg: ModelConfig,
                   positions: jax.Array, S_max: int) -> Tuple:
    """MLA decode cache = compressed latent (+ shared rope key): the memory
    win that makes deepseek-v2 32k decode cheap."""
    B, S, _ = x.shape
    c_kv, k_rope = _mla_ckv(p, x, cfg, positions)
    k_rope = k_rope[:, 0]                                   # [B,S,rd]
    pad = S_max - S
    if pad > 0:
        c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    return c_kv, k_rope


def mla_decode(p: Dict, c_kv: jax.Array, k_rope: jax.Array, x: jax.Array,
               pos: jax.Array, cfg: ModelConfig, ctx: ShardCtx) -> Tuple:
    """Absorbed-matmul MLA decode: attends directly over the latent cache,
    never materializing per-head K/V."""
    m, H = cfg.mla, cfg.n_heads
    nd, rd, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    B = x.shape[0]
    S = c_kv.shape[1]
    q_nope, q_rope = _mla_q(p, x, cfg, jnp.broadcast_to(pos, (1,)))
    new_ckv, new_krope = _mla_ckv(p, x, cfg, jnp.broadcast_to(pos, (1,)))
    c_kv = jax.lax.dynamic_update_slice_in_dim(c_kv, new_ckv, pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(k_rope, new_krope[:, 0], pos, axis=1)
    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, H, nd + vd)
    # absorb W_uk into q:   [B,H,1,nd] x [R,H,nd] -> [B,H,R]
    q_abs = jnp.einsum("bhqd,rhd->bhr", q_nope, wkv_b[..., :nd])
    sc = (nd + rd) ** -0.5
    s = (jnp.einsum("bhr,bsr->bhs", q_abs, c_kv)
         + einsum_f32("bhqd,bsd->bhs", q_rope, k_rope)) * sc
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o_lat = einsum_f32("bhs,bsr->bhr", pr.astype(c_kv.dtype), c_kv).astype(x.dtype)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, wkv_b[..., nd:])  # absorb W_uv
    o = o.reshape(B, 1, H * vd)
    return (o @ p["wo"]).astype(x.dtype), c_kv, k_rope
