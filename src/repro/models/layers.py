"""Shared layers: norms, SwiGLU MLP, RoPE, sharding helpers, init."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ----------------------------------------------------------------------
# Shard context: models are mesh-agnostic; the launcher passes axis names.
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ShardCtx:
    """Axis names for sharding constraints; all None => no constraints
    (single-device smoke tests)."""
    batch_axes: Tuple[str, ...] = ()     # e.g. ("pod", "data")
    model_axis: Optional[str] = None     # e.g. "model"
    # sequence-parallel layer boundaries (Megatron-SP analogue): shard the
    # seq dim of [B,S,D] activations over model_axis between blocks.
    seq_shard_activations: bool = True
    remat: str = "full"                  # "none" | "full" | "dots"
    flash_block: int = 512
    moe_capacity_factor: Optional[float] = None  # override config cf

    @property
    def enabled(self) -> bool:
        return bool(self.batch_axes) or self.model_axis is not None

    def batch_spec(self) -> P:
        return P(self.batch_axes if self.batch_axes else None)


def shard(x: jax.Array, ctx: ShardCtx, *spec) -> jax.Array:
    """with_sharding_constraint if ctx has a mesh; no-op otherwise."""
    if not ctx.enabled:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_act(x: jax.Array, ctx: ShardCtx) -> jax.Array:
    """Layer-boundary [B,S,D] activation sharding: batch over DP axes and,
    when sequence-parallel is on, seq over the model axis."""
    if not ctx.enabled:
        return x
    b = ctx.batch_axes if ctx.batch_axes else None
    s = ctx.model_axis if (ctx.seq_shard_activations and x.shape[1] > 1) else None
    return shard(x, ctx, b, s, None)


# ----------------------------------------------------------------------
# f32-accumulating einsum.
# On TPU the MXU takes bf16 inputs and accumulates f32
# (preferred_element_type). XLA-CPU's DotThunk lacks BF16xBF16=F32 for
# some shapes, so on CPU we cast inputs to f32 (exact superset of the
# TPU numerics; documented in EXPERIMENTS.md SSDry-run notes).
# ----------------------------------------------------------------------
_ON_CPU = jax.default_backend() == "cpu"


def einsum_f32(spec: str, *ops: jax.Array) -> jax.Array:
    if _ON_CPU:
        return jnp.einsum(spec, *[o.astype(jnp.float32) for o in ops])
    return jnp.einsum(spec, *ops, preferred_element_type=jnp.float32)


# ----------------------------------------------------------------------
# Norms / activations
# ----------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Stats in f32, VALUE path in the compute dtype: a full-f32 value
    path makes every activation gradient f32, doubling the bytes of all
    TP/SP collectives touching [B,S,d] tensors
    (EXPERIMENTS.md §Perf iteration 2)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def head_rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """qk-norm over the head_dim (last axis) — qwen3-style."""
    return rms_norm(x, scale, eps)


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
           ctx: ShardCtx) -> jax.Array:
    h = jax.nn.silu(x @ w1) * (x @ w3)
    h = shard(h, ctx, ctx.batch_axes or None, None, ctx.model_axis)
    return h @ w2


def gelu_mlp(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array,
             b2: jax.Array, ctx: ShardCtx) -> jax.Array:
    h = jax.nn.gelu(x @ w1 + b1)
    h = shard(h, ctx, ctx.batch_axes or None, None, ctx.model_axis)
    return h @ w2 + b2


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, D] (D even), positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                # [D/2]
    ang = positions.astype(jnp.float32)[..., None] * inv      # [..., S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings [S, D]."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10000.0 ** (jnp.arange(dim // 2, dtype=jnp.float32) / (dim // 2 - 1)))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------
def dense_init(key: jax.Array, shape: Sequence[int], dtype,
               scale: Optional[float] = None) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, tuple(shape), jnp.float32) * s).astype(dtype)


class KeyGen:
    """Deterministic sub-key dispenser so init is order-stable."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


# ----------------------------------------------------------------------
# Cross-entropy with V-sharded logits
# ----------------------------------------------------------------------
def softmax_xent(logits: jax.Array, targets: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """logits [.., V] f32-upcast stable CE; targets [..] int32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    tl = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = lse - tl
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_xent(h: jax.Array, lm_head: jax.Array, targets: jax.Array,
                 ctx: "ShardCtx", chunk: int = 1024) -> jax.Array:
    """Sequence-chunked CE: logits [B,chunk,V] are (re)computed per chunk
    inside a rematerialized scan, so the full [B,S,V] f32 logits tensor
    (GiBs at 128k vocab) never exists. h: [B,S,d], lm_head: [d,V]."""
    B, S, d = h.shape
    if S % chunk or S <= chunk:
        return softmax_xent(h @ lm_head, targets)
    nC = S // chunk
    hc = h.reshape(B, nC, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nC, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(tot, xs):
        hh, tt = xs
        logits = hh @ lm_head
        # keep V sharded over the model axis: the lm_head shard stays
        # local (no 1-GiB table all-gather per chunk)
        logits = shard(logits, ctx, ctx.batch_axes or None, None,
                       ctx.model_axis)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        tl = jnp.take_along_axis(lf, tt[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - tl), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc))
    return tot / (B * S)
