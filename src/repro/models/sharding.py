"""Parameter / cache / batch PartitionSpec rules.

FSDP on the `data` axis (params+optimizer sharded), TP/EP on `model`,
pure DP across `pod` (params replicated — the WANify sync domain).
Rules are (leaf-name, ndim)-based so they cover every family uniformly.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

# leaves whose LAST dim is the "wide" (heads / d_ff / experts-out) dim
_COL_PARALLEL = {"wq", "wk", "wv", "wq_a", "wq_b", "wkv_a", "wkv_b",
                 "w1", "w3", "ws1", "ws3", "in_proj", "enc_proj"}
# leaves whose FIRST (non-stack) dim is wide
_ROW_PARALLEL = {"wo", "w2", "ws2", "out_proj"}
_REPLICATED = {"q_scale", "k_scale", "q_norm", "kv_norm", "A_log", "D",
               "dt_bias"}


def _key_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return p.key
    return ""


def _divides(n: int, size: Optional[int]) -> bool:
    return bool(size) and size > 0 and n % size == 0


def param_spec(path, shape: Tuple[int, ...], *, data: str = "data",
               model: str = "model", data_size: int = 0,
               model_size: int = 0) -> P:
    """Sharding rule for one parameter leaf."""
    name = _key_name(path)
    nd = len(shape)

    def ok(dim_i, size):
        return _divides(shape[dim_i], size)

    if name in _REPLICATED or nd == 0:
        return P()
    if name == "embed":                      # [V, d]
        return P(model if ok(0, model_size) else None,
                 data if ok(1, data_size) else None)
    if name == "lm_head":                    # [d, V]
        return P(data if ok(0, data_size) else None,
                 model if ok(1, model_size) else None)
    if name == "router":                     # [(L,) d, E] — E replicated
        lead = (None,) * (nd - 2)
        return P(*lead, data if ok(nd - 2, data_size) else None, None)
    is_moe_expert = nd >= 3 and name in ("w1", "w2", "w3") and \
        "moe" in [getattr(p, "key", "") for p in path]
    if is_moe_expert:                        # [(L,) E, a, b]
        lead = (None,) * (nd - 3)
        e_ax = model if ok(nd - 3, model_size) else None
        if name == "w2":                     # [E, f, d]
            return P(*lead, e_ax, None, data if ok(nd - 1, data_size) else None)
        return P(*lead, e_ax, data if ok(nd - 2, data_size) else None, None)
    if name in _COL_PARALLEL and nd >= 2:
        lead = (None,) * (nd - 2)
        return P(*lead, data if ok(nd - 2, data_size) else None,
                 model if ok(nd - 1, model_size) else None)
    if name in _ROW_PARALLEL and nd >= 2:
        lead = (None,) * (nd - 2)
        return P(*lead, model if ok(nd - 2, model_size) else None,
                 data if ok(nd - 1, data_size) else None)
    if name == "conv_w":                     # [(L,) k, C]
        lead = (None,) * (nd - 2)
        return P(*lead, None, model if ok(nd - 1, model_size) else None)
    if name in ("conv_b", "norm"):           # [(L,) C]
        lead = (None,) * (nd - 1)
        return P(*lead, model if ok(nd - 1, model_size) else None)
    return P()                               # ln1/ln2/final_norm etc.


def param_specs(params_struct: Any, *, data: str = "data",
                model: str = "model", data_size: int = 0,
                model_size: int = 0) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf.shape, data=data,
                                      model=model, data_size=data_size,
                                      model_size=model_size),
        params_struct)


# ----------------------------------------------------------------------
# Batch / cache
# ----------------------------------------------------------------------
def batch_specs(batch_struct: Any, *, batch_axes=("data",),
                batch_size: int = 0) -> Any:
    """Shard dim-0 (global batch) over the DP axes when divisible."""
    def rule(path, leaf):
        shape = leaf.shape
        if not shape:
            return P()
        if _divides(shape[0], batch_size):
            return P(batch_axes, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))
    return jax.tree_util.tree_map_with_path(rule, batch_struct)


def cache_spec_sharding(path, shape: Tuple[int, ...], *, batch_axes,
                        dp_size: int, data: str, model: str,
                        data_size: int, model_size: int) -> P:
    """Decode-cache rules. Layouts:
      k/v/self_k/...  [L, B, KVe, S, D]
      c_kv/k_rope     [L, B, S, R]
      conv [L, B, K-1, C]     state [L, B, H, Pd, N]
    B shards over the DP axes when divisible; otherwise the SEQUENCE dim
    takes the data axis — context-parallel decode for giant caches
    (e.g. zamba2 long_500k, B=1)."""
    name = _key_name(path)
    nd = len(shape)
    spec: list = [None] * nd
    if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v") and nd == 5:
        _, B, KV, S, _ = shape
        if _divides(B, dp_size):
            spec[1] = batch_axes
        elif _divides(B, data_size):
            spec[1] = data
        elif _divides(S, data_size):
            spec[3] = data
        if _divides(KV, model_size):
            spec[2] = model
        elif spec[3] is None and _divides(S, model_size):
            spec[3] = model
    elif name in ("c_kv", "k_rope") and nd == 4:
        _, B, S, _ = shape
        if _divides(B, dp_size):
            spec[1] = batch_axes
        elif _divides(B, data_size):
            spec[1] = data
        elif _divides(S, data_size):
            spec[2] = data
        if spec[2] is None and _divides(S, model_size):
            spec[2] = model
    elif name == "conv" and nd == 4:
        if _divides(shape[1], dp_size):
            spec[1] = batch_axes
        elif _divides(shape[1], data_size):
            spec[1] = data
        if _divides(shape[3], model_size):
            spec[3] = model
    elif name == "state" and nd == 5:
        if _divides(shape[1], dp_size):
            spec[1] = batch_axes
        elif _divides(shape[1], data_size):
            spec[1] = data
        if _divides(shape[2], model_size):
            spec[2] = model
    return P(*spec)


def cache_specs(cache_struct: Any, *, batch_axes=("data",), data="data",
                model="model", data_size: int = 0, model_size: int = 0,
                dp_size: int = 0) -> Any:
    dp = dp_size or data_size
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec_sharding(
            path, leaf.shape, batch_axes=batch_axes, dp_size=dp, data=data,
            model=model, data_size=data_size, model_size=model_size),
        cache_struct)
