"""Whisper-style encoder-decoder. The conv audio frontend is a STUB:
``input_specs`` supplies precomputed frame embeddings [B, 1500, d_enc].
Positions are sinusoidal on both towers (design note: real whisper uses
learned decoder positions; sinusoidal keeps the param tree shape-static
across input shapes — recorded in DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as att
from repro.models.layers import (KeyGen, ShardCtx, dense_init, einsum_f32,
                                 rms_norm, shard_act,
                                 sinusoidal_positions, swiglu)
from repro.models.transformer import (_cast_params, _maybe_remat, init_attn,
                                      kv_eff_heads)


def init_encdec_params(cfg: ModelConfig, key: jax.Array, dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    kg = KeyGen(key)
    e = cfg.encoder
    d = cfg.d_model

    def enc_block():
        return {
            "ln1": jnp.ones((e.d_model,), dtype),
            "ln2": jnp.ones((e.d_model,), dtype),
            "attn": init_attn(kg, cfg.replace(
                d_model=e.d_model, n_heads=e.n_heads, n_kv_heads=e.n_heads), dtype),
            "mlp": {"w1": dense_init(kg(), (e.d_model, e.d_ff), dtype),
                    "w3": dense_init(kg(), (e.d_model, e.d_ff), dtype),
                    "w2": dense_init(kg(), (e.d_ff, e.d_model), dtype)},
        }

    def dec_block():
        return {
            "ln1": jnp.ones((d,), dtype),
            "ln_x": jnp.ones((d,), dtype),
            "ln2": jnp.ones((d,), dtype),
            "attn": init_attn(kg, cfg, dtype),
            "xattn": init_attn(kg, cfg, dtype),
            "mlp": {"w1": dense_init(kg(), (d, cfg.d_ff), dtype),
                    "w3": dense_init(kg(), (d, cfg.d_ff), dtype),
                    "w2": dense_init(kg(), (cfg.d_ff, d), dtype)},
        }

    enc = [enc_block() for _ in range(e.n_layers)]
    dec = [dec_block() for _ in range(cfg.n_layers)]
    return {
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "enc_norm": jnp.ones((e.d_model,), dtype),
        "enc_proj": dense_init(kg(), (e.d_model, d), dtype) if e.d_model != d
        else None,
        "embed": dense_init(kg(), (cfg.vocab, d), dtype, scale=0.02),
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "final_norm": jnp.ones((d,), dtype),
        "lm_head": dense_init(kg(), (d, cfg.vocab), dtype),
    }


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    e = cfg.encoder
    return cfg.replace(d_model=e.d_model, n_heads=e.n_heads,
                       n_kv_heads=e.n_heads, rope_theta=0.0)


def encode(params: Dict, frames: jax.Array, cfg: ModelConfig,
           ctx: ShardCtx) -> jax.Array:
    """frames [B,F,d_enc] -> encoder states [B,F,d_model]."""
    e = cfg.encoder
    ecfg = _enc_cfg(cfg)
    x = frames + sinusoidal_positions(frames.shape[1], e.d_model
                                      ).astype(frames.dtype)[None]
    x = shard_act(x, ctx)
    positions = jnp.arange(frames.shape[1])

    def body(h, blk):
        hp = rms_norm(h, blk["ln1"], cfg.norm_eps)
        h = h + att.gqa_forward(blk["attn"], hp, ctx, ecfg, positions,
                                causal=False)
        hp = rms_norm(h, blk["ln2"], cfg.norm_eps)
        h = shard_act(h + swiglu(hp, blk["mlp"]["w1"], blk["mlp"]["w3"],
                                 blk["mlp"]["w2"], ctx), ctx)
        return h, None

    x, _ = jax.lax.scan(_maybe_remat(lambda c, b: body(c, b), ctx),
                        x, params["enc_blocks"])
    x = rms_norm(x, params["enc_norm"], cfg.norm_eps)
    if params.get("enc_proj") is not None:
        x = x @ params["enc_proj"]
    return x


def _dec_block(blk, h, enc_out, positions, cfg, ctx):
    hp = rms_norm(h, blk["ln1"], cfg.norm_eps)
    h = h + att.gqa_forward(blk["attn"], hp, ctx, cfg, positions)
    hp = rms_norm(h, blk["ln_x"], cfg.norm_eps)
    KV, D = cfg.n_kv_heads, cfg.resolved_head_dim
    xk = (enc_out @ blk["xattn"]["wk"]).reshape(
        enc_out.shape[0], enc_out.shape[1], KV, D).transpose(0, 2, 1, 3)
    xv = (enc_out @ blk["xattn"]["wv"]).reshape(
        enc_out.shape[0], enc_out.shape[1], KV, D).transpose(0, 2, 1, 3)
    h = h + att.gqa_forward(blk["xattn"], hp, ctx, cfg, positions,
                            cross_kv=(xk, xv), causal=False)
    hp = rms_norm(h, blk["ln2"], cfg.norm_eps)
    h = shard_act(h + swiglu(hp, blk["mlp"]["w1"], blk["mlp"]["w3"],
                             blk["mlp"]["w2"], ctx), ctx)
    return h


def encdec_loss(params: Dict, batch: Dict, cfg: ModelConfig, ctx: ShardCtx,
                dp_size: int = 1) -> Tuple[jax.Array, Dict]:
    cdt = jnp.dtype(cfg.dtype)
    pc = _cast_params(params, cdt)
    enc_out = encode(pc, batch["enc_frames"].astype(cdt), cfg, ctx)
    tokens = batch["tokens"]
    x = pc["embed"][tokens] + sinusoidal_positions(
        tokens.shape[1], cfg.d_model).astype(cdt)[None]
    x = shard_act(x, ctx)
    positions = jnp.arange(tokens.shape[1])

    def body(h, blk):
        return _dec_block(blk, h, enc_out, positions, cfg, ctx), None

    x, _ = jax.lax.scan(_maybe_remat(lambda c, b: body(c, b), ctx),
                        x, pc["dec_blocks"])
    x = rms_norm(x, pc["final_norm"], cfg.norm_eps)
    from repro.models.layers import chunked_xent
    ce = chunked_xent(x, pc["lm_head"], batch["targets"], ctx)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32),
                "expert_load": jnp.zeros((1,), jnp.float32)}


# ----------------------------------------------------------------------
# Serving
# ----------------------------------------------------------------------
def encdec_cache_spec(cfg: ModelConfig, B: int, S_max: int, tp: int = 16,
                      dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    L, D = cfg.n_layers, cfg.resolved_head_dim
    kve = kv_eff_heads(cfg, tp)
    F = cfg.encoder.source_len
    return {
        "self_k": jax.ShapeDtypeStruct((L, B, kve, S_max, D), dtype),
        "self_v": jax.ShapeDtypeStruct((L, B, kve, S_max, D), dtype),
        "cross_k": jax.ShapeDtypeStruct((L, B, kve, F, D), dtype),
        "cross_v": jax.ShapeDtypeStruct((L, B, kve, F, D), dtype),
    }


def encdec_prefill(params: Dict, batch: Dict, cfg: ModelConfig, ctx: ShardCtx,
                   S_max: int, tp: int = 16, dp_size: int = 1):
    """Encode audio + consume decoder prompt; build self+cross caches."""
    cdt = jnp.dtype(cfg.dtype)
    pc = _cast_params(params, cdt)
    enc_out = encode(pc, batch["enc_frames"].astype(cdt), cfg, ctx)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = pc["embed"][tokens] + sinusoidal_positions(S, cfg.d_model
                                                   ).astype(cdt)[None]
    x = shard_act(x, ctx)
    positions = jnp.arange(S)
    KV, D = cfg.n_kv_heads, cfg.resolved_head_dim
    kve = kv_eff_heads(cfg, tp)
    r = kve // KV

    def body(h, blk):
        hp = rms_norm(h, blk["ln1"], cfg.norm_eps)
        k, v = att.gqa_make_cache(blk["attn"], hp, cfg, ctx, positions,
                                  S_max, kve)
        xk = (enc_out @ blk["xattn"]["wk"]).reshape(
            B, -1, KV, D).transpose(0, 2, 1, 3)
        xv = (enc_out @ blk["xattn"]["wv"]).reshape(
            B, -1, KV, D).transpose(0, 2, 1, 3)
        if r > 1:
            xk, xv = jnp.repeat(xk, r, axis=1), jnp.repeat(xv, r, axis=1)
        h = _dec_block(blk, h, enc_out, positions, cfg, ctx)
        return h, {"self_k": k, "self_v": v, "cross_k": xk, "cross_v": xv}

    x, cache = jax.lax.scan(body, x, pc["dec_blocks"])
    x = rms_norm(x, pc["final_norm"], cfg.norm_eps)
    return x[:, -1] @ pc["lm_head"], cache


def encdec_decode(params: Dict, cache: Dict, tokens: jax.Array,
                  pos: jax.Array, cfg: ModelConfig, ctx: ShardCtx,
                  dp_size: int = 1):
    cdt = jnp.dtype(cfg.dtype)
    pc = _cast_params(params, cdt)
    B = tokens.shape[0]
    # closed-form sinusoidal row at runtime position (rope-free decoder)
    half = cfg.d_model // 2
    inv = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / (half - 1)))
    ang = pos.astype(jnp.float32) * inv
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]
    x = pc["embed"][tokens] + pe.astype(cdt)
    H, D = cfg.n_heads, cfg.resolved_head_dim

    def body(h, xs):
        blk, ck, cv, xk, xv = xs
        hp = rms_norm(h, blk["ln1"], cfg.norm_eps)
        o, nk, nv = att.gqa_decode(blk["attn"], ck, cv, hp, pos, cfg, ctx)
        h = h + o
        hp = rms_norm(h, blk["ln_x"], cfg.norm_eps)
        q = (hp @ blk["xattn"]["wq"]).reshape(B, 1, H, D).transpose(0, 2, 1, 3)
        KVe = xk.shape[1]
        qg = q.reshape(B, KVe, H // KVe, 1, D)
        s = einsum_f32("bkgqd,bksd->bkgqs", qg * (D ** -0.5), xk)
        p_ = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        o = jnp.einsum("bkgqs,bksd->bkgqd", p_.astype(xv.dtype), xv)
        o = o.reshape(B, H, 1, D).transpose(0, 2, 1, 3).reshape(B, 1, H * D)
        h = h + (o @ blk["xattn"]["wo"]).astype(h.dtype)
        hp = rms_norm(h, blk["ln2"], cfg.norm_eps)
        h = h + swiglu(hp, blk["mlp"]["w1"], blk["mlp"]["w3"],
                       blk["mlp"]["w2"], ctx)
        return h, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (pc["dec_blocks"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    new_cache = dict(cache, self_k=nk, self_v=nv)
    x = rms_norm(x, pc["final_norm"], cfg.norm_eps)
    return x[:, -1] @ pc["lm_head"], new_cache
