from repro.models.layers import ShardCtx  # noqa: F401
