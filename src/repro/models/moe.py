"""Mixture-of-Experts: token-choice top-k routing with capacity-bounded
scatter/gather dispatch (honest FLOPs ~= k/cf of dense-all-experts),
shared experts (DeepSeek-style), load-balance aux loss, and per-pod
expert-load statistics that feed WANify's skew weights (w_s, §3.3.1).

Dispatch is grouped: tokens are viewed as [G, T_g, d] where G equals the
number of data-parallel shards, so the scatter is shard-local and the
expert einsum shards E over the model axis (EP inside TP).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import KeyGen, ShardCtx, dense_init, shard


def init_moe_params(kg: KeyGen, cfg: ModelConfig, dtype, stack: int = 0) -> Dict:
    """stack>0 => leading layer dim for scan."""
    m = cfg.moe
    d, E, f = cfg.d_model, m.n_experts, m.d_ff_expert
    L = (stack,) if stack else ()
    p = {
        "router": dense_init(kg(), L + (d, E), jnp.float32),
        "w1": dense_init(kg(), L + (E, d, f), dtype),
        "w3": dense_init(kg(), L + (E, d, f), dtype),
        "w2": dense_init(kg(), L + (E, f, d), dtype),
    }
    if m.n_shared_experts > 0:
        fs = f * m.n_shared_experts
        p["ws1"] = dense_init(kg(), L + (d, fs), dtype)
        p["ws3"] = dense_init(kg(), L + (d, fs), dtype)
        p["ws2"] = dense_init(kg(), L + (fs, d), dtype)
    return p


def _capacity(t_per_group: int, cfg: ModelConfig, ctx: ShardCtx) -> int:
    m = cfg.moe
    cf = ctx.moe_capacity_factor or m.capacity_factor
    c = int(t_per_group * m.top_k * cf / m.n_experts) + 1
    return max(4, -(-c // 4) * 4)                         # round up to x4


def moe_forward(p: Dict, x: jax.Array, ctx: ShardCtx, cfg: ModelConfig,
                dp_size: int = 1) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: [B,S,d] -> (y, aux_loss, expert_load[E]).

    expert_load is the per-expert assignment fraction — the skew signal
    WANify's global optimizer consumes as w_s.
    """
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.n_experts, m.top_k
    T = B * S
    G = dp_size if (T % dp_size == 0 and T >= dp_size) else 1
    Tg = T // G
    C = _capacity(Tg, cfg, ctx)

    xg = x.reshape(G, Tg, d)
    xg = shard(xg, ctx, ctx.batch_axes or None, None, None)

    logits = (xg @ p["router"].astype(jnp.float32))        # [G,Tg,E] f32
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                  # [G,Tg,k]
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (Switch-style) + load stats -------------
    onehot_any = jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=2)
    load = jnp.mean(onehot_any, axis=(0, 1)) / k           # [E] fraction
    imp = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(load * imp)

    # ---- capacity positions via one-hot cumsum --------------------------
    # positions are computed over the FLATTENED (token, choice) stream so
    # different choices of one token land in distinct capacity slots
    ef = eidx.reshape(G, Tg * k)
    oh = jax.nn.one_hot(ef, E, dtype=jnp.int32)            # [G,Tg*k,E]
    oh = shard(oh, ctx, ctx.batch_axes or None, None, ctx.model_axis)
    pos = jnp.take_along_axis(jnp.cumsum(oh, axis=1) - 1, ef[..., None],
                              axis=2)[..., 0]              # [G,Tg*k]
    keep = (pos < C).reshape(G, Tg, k)
    pos_c = jnp.where(keep.reshape(G, Tg * k), pos, 0).reshape(G, Tg, k)

    # ---- dispatch: k sequential scatters of [G,Tg,d] ---------------------
    # (never materializes the [G,Tg*k,d] repeated-token tensor; the
    # scatter value keeps d sharded over the model axis so the transient
    # E-replicated buffer is 1/TP of the naive size)
    xs_ = shard(xg, ctx, ctx.batch_axes or None, None, ctx.model_axis)

    def scat(buf, ev, pv, val):
        return buf.at[ev, pv].add(val)

    buf = jnp.zeros((G, E, C, d), x.dtype)
    buf = shard(buf, ctx, ctx.batch_axes or None, None, None, ctx.model_axis)
    for j in range(k):
        vals = jnp.where(keep[:, :, j][..., None], xs_, 0)
        buf = jax.vmap(scat)(buf, eidx[:, :, j], pos_c[:, :, j], vals)
    buf = shard(buf, ctx, ctx.batch_axes or None, ctx.model_axis, None, None)

    # ---- expert FFN (E sharded over model axis => EP) --------------------
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w1"])) \
        * jnp.einsum("gecd,edf->gecf", buf, p["w3"])
    ob = jnp.einsum("gecf,efd->gecd", h, p["w2"])
    ob = shard(ob, ctx, ctx.batch_axes or None, ctx.model_axis, None, None)

    # ---- gather back + combine (k sequential gathers) --------------------
    def gath(o, ev, pv):
        return o[ev, pv]

    y = jnp.zeros((G, Tg, d), x.dtype)
    gatesd = gates.astype(x.dtype)
    for j in range(k):
        yj = jax.vmap(gath)(ob, eidx[:, :, j], pos_c[:, :, j])
        y = y + jnp.where(keep[:, :, j][..., None], yj, 0) \
            * gatesd[:, :, j][..., None]

    if m.n_shared_experts > 0:
        hs = jax.nn.silu(xg @ p["ws1"]) * (xg @ p["ws3"])
        y = y + hs @ p["ws2"]

    return y.reshape(B, S, d), aux.astype(jnp.float32), load
