"""InternVL2-style VLM: stubbed InternViT frontend (precomputed patch
embeddings already in the LM embedding space) prepended to the token
stream of an InternLM2 (GQA) backbone. Loss covers text positions only.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.models.layers import ShardCtx
from repro.models.transformer import (init_lm_params, lm_cache_spec,
                                      lm_decode, lm_prefill)


def init_vlm_params(cfg: ModelConfig, key: jax.Array, dtype=None) -> Dict:
    # The vision tower is stubbed; the LM backbone carries all params.
    return init_lm_params(cfg, key, dtype)


def vlm_loss(params: Dict, batch: Dict, cfg: ModelConfig, ctx: ShardCtx,
             dp_size: int = 1) -> Tuple[jax.Array, Dict]:
    # lm_loss handles the patch prefix (and uses chunked CE)
    from repro.models.transformer import lm_loss
    return lm_loss(params, batch, cfg, ctx, dp_size)


def vlm_cache_spec(cfg: ModelConfig, B: int, S_max: int, tp: int = 16,
                   dtype=None) -> Dict:
    # cache covers patches + text
    return lm_cache_spec(cfg, B, S_max + cfg.encoder.source_len, tp, dtype)


def vlm_prefill(params: Dict, batch: Dict, cfg: ModelConfig, ctx: ShardCtx,
                S_max: int, tp: int = 16, dp_size: int = 1):
    return lm_prefill(params, batch["tokens"], cfg, ctx,
                      S_max + cfg.encoder.source_len, tp, dp_size,
                      extra_embeds=batch["patch_embeds"])


def vlm_decode(params: Dict, cache: Dict, tokens: jax.Array, pos: jax.Array,
               cfg: ModelConfig, ctx: ShardCtx, dp_size: int = 1):
    # decode positions are offset by the patch prefix
    return lm_decode(params, cache, tokens, pos + cfg.encoder.source_len,
                     cfg, ctx, dp_size)
