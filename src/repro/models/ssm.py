"""Mamba-2 (SSD — state-space duality) block: chunked quadratic-within-
chunk / linear-across-chunk scan, causal depthwise conv, gated RMSNorm.

The within-chunk computation is the compute hot-spot; kernels/ssd_scan.py
provides the Pallas TPU kernel, this module is the pure-jnp path (also
the oracle for the kernel tests).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (KeyGen, ShardCtx, dense_init, einsum_f32,
                                 rms_norm, shard)


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.n_groups * s.d_state
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + H
    return d_inner, H, conv_ch, d_in_proj


def init_ssm_params(kg: KeyGen, cfg: ModelConfig, dtype, stack: int = 0) -> Dict:
    s = cfg.ssm
    d_inner, H, conv_ch, d_in_proj = ssm_dims(cfg)
    L = (stack,) if stack else ()
    import numpy as np
    return {
        "in_proj": dense_init(kg(), L + (cfg.d_model, d_in_proj), dtype),
        "conv_w": dense_init(kg(), L + (s.d_conv, conv_ch), dtype, scale=0.5),
        "conv_b": jnp.zeros(L + (conv_ch,), dtype),
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)), L + (H,)).copy(),
        "D": jnp.ones(L + (H,), jnp.float32),
        "dt_bias": jnp.broadcast_to(
            jnp.log(jnp.expm1(jnp.linspace(0.001, 0.1, H, dtype=jnp.float32))),
            L + (H,)).copy(),
        "norm": jnp.ones(L + (d_inner,), dtype),
        "out_proj": dense_init(kg(), L + (d_inner, cfg.d_model), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x [B,S,C], w [K,C] -> [B,S,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(xh: jax.Array, Bc: jax.Array, Cc: jax.Array, da: jax.Array,
                chunk: int, init_state=None) -> Tuple[jax.Array, jax.Array]:
    """SSD scan (n_groups=1 broadcast over heads).

    xh: [B,S,H,P] (already multiplied by dt)  Bc,Cc: [B,S,N]
    da: [B,S,H] per-step log decay (dt * a, a<0). Returns (y [B,S,H,P],
    final_state [B,H,P,N]).
    """
    B, S, H, P = xh.shape
    N = Bc.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:                      # pad tail: x=0 contributes nothing and
        pad = Q - S % Q            # da=0 leaves the carried state intact
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
        S = xh.shape[1]
    nC = S // Q

    xq = xh.reshape(B, nC, Q, H, P)
    Bq = Bc.reshape(B, nC, Q, N)
    Cq = Cc.reshape(B, nC, Q, N)
    daq = da.reshape(B, nC, Q, H).transpose(0, 1, 3, 2)     # [B,nC,H,Q]
    cum = jnp.cumsum(daq.astype(jnp.float32), axis=-1)       # [B,nC,H,Q]

    # -- within-chunk (quadratic) part --------------------------------
    seg = cum[..., :, None] - cum[..., None, :]              # [B,nC,H,Q,Q]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: upper-triangle seg is positive (decays are
    # negative-cumulative) and exp would overflow; where-after-exp also
    # poisons the backward with 0*inf=NaN.
    L = jnp.exp(jnp.where(tri, seg, -1e30))
    cb = einsum_f32("bcqn,bckn->bcqk", Cq, Bq)      # [B,nC,Q,Q]
    scores = cb[:, :, None] * L                              # [B,nC,H,Q,Q]
    y_diag = einsum_f32("bchqk,bckhp->bcqhp", scores, xq)

    # -- chunk boundary states ----------------------------------------
    dec_r = jnp.exp(cum[..., -1:] - cum)                     # [B,nC,H,Q]
    states = einsum_f32("bchk,bckn,bckhp->bchpn", dec_r, Bq, xq)  # [B,nC,H,P,N]

    # -- inter-chunk recurrence (linear scan over nC) ------------------
    chunk_decay = jnp.exp(cum[..., -1])                      # [B,nC,H]

    def body(carry, xs):
        st_c, dec = xs
        new = carry * dec[..., None, None] + st_c
        return new, carry                                    # emit state ENTERING chunk

    s0 = jnp.zeros((B, H, P, N), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)
    final, entered = jax.lax.scan(
        body, s0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    entered = entered.transpose(1, 0, 2, 3, 4)               # [B,nC,H,P,N]

    # -- off-diagonal contribution -------------------------------------
    dec_in = jnp.exp(cum)                                    # decay from chunk start
    y_off = einsum_f32("bcqn,bchpn,bchq->bcqhp", Cq, entered, dec_in)

    y = (y_diag + y_off).reshape(B, S, H, P)
    return y[:, :S_orig].astype(xh.dtype), final


def ssm_forward(p: Dict, x: jax.Array, ctx: ShardCtx, cfg: ModelConfig
                ) -> jax.Array:
    """Full-sequence Mamba2 block. x: [B,S,d] -> [B,S,d]."""
    s = cfg.ssm
    d_inner, H, conv_ch, _ = ssm_dims(cfg)
    N, P = s.d_state, s.head_dim
    B, S, _ = x.shape

    zxbcdt = x @ p["in_proj"]
    zxbcdt = shard(zxbcdt, ctx, ctx.batch_axes or None, None, ctx.model_axis)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_ch]
    dt_raw = zxbcdt[..., d_inner + conv_ch:]

    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
    xs = xBC[..., :d_inner]
    Bc = xBC[..., d_inner:d_inner + N]
    Cc = xBC[..., d_inner + N:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])                                 # [H] < 0
    da = dt * a

    xh = xs.reshape(B, S, H, P)
    xh_dt = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    y, _ = ssd_chunked(xh_dt, Bc, Cc, da, s.chunk)
    y = y + p["D"][None, None, :, None].astype(jnp.float32) * xh

    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"]


# ----------------------------------------------------------------------
# Decode (recurrent state update — O(1) per token)
# ----------------------------------------------------------------------
def ssm_cache_spec(cfg: ModelConfig, B: int, dtype):
    s = cfg.ssm
    d_inner, H, conv_ch, _ = ssm_dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((B, s.d_conv - 1, conv_ch), dtype),
        "state": jax.ShapeDtypeStruct((B, H, s.head_dim, s.d_state), jnp.float32),
    }


def ssm_decode(p: Dict, cache: Dict, x: jax.Array, cfg: ModelConfig,
               ctx: ShardCtx) -> Tuple[jax.Array, Dict]:
    """x: [B,1,d]; cache: {conv [B,K-1,C], state [B,H,P,N]}."""
    s = cfg.ssm
    d_inner, H, conv_ch, _ = ssm_dims(cfg)
    N, P = s.d_state, s.head_dim
    B = x.shape[0]

    zxbcdt = (x[:, 0] @ p["in_proj"])                        # [B, dip]
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_ch]
    dt_raw = zxbcdt[..., d_inner + conv_ch:]

    hist = jnp.concatenate([cache["conv"], xBC[:, None]], axis=1)  # [B,K,C]
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xBC = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = hist[:, 1:]

    xs = xBC[..., :d_inner]
    Bc = xBC[..., d_inner:d_inner + N]
    Cc = xBC[..., d_inner + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # [B,H]
    a = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * a)                                    # [B,H]

    xh = xs.reshape(B, H, P).astype(jnp.float32)
    st = cache["state"] * dec[..., None, None] + \
        jnp.einsum("bhp,bn,bh->bhpn", xh, Bc.astype(jnp.float32), dt)
    y = jnp.einsum("bn,bhpn->bhp", Cc.astype(jnp.float32), st)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return (y @ p["out_proj"])[:, None], {"conv": new_conv, "state": st}
