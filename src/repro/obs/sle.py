"""Mist-style SLE (service-level-expectation) health rollups.

The Mist WAN-performance exemplar (PAPERS.md; see also "Wide Area
Network Intelligence with Application to Multimedia Service") grades a
WAN control loop on a handful of normalized health expectations rather
than raw throughput numbers. This module computes those rollups from
the repo's OWN deterministic traces (`repro.scenarios.trace` /
`repro.fleet.trace`) — pure functions of recorded values, no clock, no
RNG — so every scenario's health is one comparable block in the bench
JSON:

  * **accuracy** — prediction-accuracy SLE: the fraction of trace
    samples (the per-step achieved-vs-predicted min AND mean series)
    whose relative residual |achieved/predicted - 1| lies within
    `band`;
  * **capacity** — capacity-attainment SLE: mean per-step achieved
    min-BW as a fraction of the run's own 95th-percentile floor (the
    cloudgenix percentile-capacity convention) — 1.0 means the floor
    never sags below what the run showed it can sustain;
  * **fairness** — Jain's index: across tenants' priority-normalized
    min BW for fleet traces, across the per-step floor series
    (temporal evenness) for single-job scenario traces;
  * **responsiveness** — replan responsiveness: mean steps from a
    scripted event to the floor recovering to `frac` x its pre-event
    median (censored at run end when it never recovers);
  * **monitoring_usd** — the paper's §1/Eq. 1 cost axis as a tracked
    metric: every trace-visible measurement (the engine's per-step
    snapshot sample plus one snapshot capture per replan; per-job
    captures plus the capacity probe per fleet tick) priced through
    :func:`repro.wan.monitor.probe_cost_usd`.

Fleet traces carry no predicted-BW columns (their serialization is
golden-pinned), so :func:`fleet_sle` reports ``accuracy: None`` —
honestly absent rather than fabricated.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

SLE_BAND = 0.25          # default relative-residual accuracy band
CAPACITY_Q = 95.0        # percentile defining the run's own capacity
RECOVERY_FRAC = 0.9      # floor counts as recovered at this fraction
BASELINE_WINDOW = 5      # pre-event steps defining the baseline median


def jain_index(xs: Sequence[float]) -> float:
    """Jain's fairness index (sum x)^2 / (n * sum x^2), in (0, 1];
    1.0 for an empty or all-zero vector (nothing to be unfair about)."""
    v = np.asarray(xs, np.float64)
    if v.size == 0 or not np.any(v):
        return 1.0
    return float(v.sum() ** 2 / (v.size * (v ** 2).sum()))


def accuracy_sle(trace, band: float = SLE_BAND) -> float:
    """Fraction of per-step (min, mean) achieved-vs-predicted samples
    with |achieved/predicted - 1| <= band."""
    ok = n = 0
    for s in trace.steps:
        for a, p in ((s.achieved_min, s.predicted_min),
                     (s.achieved_mean, s.predicted_mean)):
            n += 1
            if abs(a / max(p, 1e-9) - 1.0) <= band:
                ok += 1
    return ok / n if n else 1.0


def capacity_sle(floor: Sequence[float], q: float = CAPACITY_Q) -> float:
    """Mean per-step floor as a fraction of the series' own q-th
    percentile (capped at 1.0 per step)."""
    v = np.asarray(floor, np.float64)
    if v.size == 0:
        return 1.0
    ref = float(np.percentile(v, q))
    if ref <= 0:
        return 1.0
    return float(np.minimum(v / ref, 1.0).mean())


def responsiveness_steps(event_steps: Sequence[int],
                         floor: Sequence[float],
                         frac: float = RECOVERY_FRAC,
                         window: int = BASELINE_WINDOW
                         ) -> Optional[float]:
    """Mean steps from each event to the floor recovering to `frac` x
    the pre-event median; None when the run scripted no events. An
    event the run never recovers from is censored at run end (it
    contributes the remaining step count — a lower bound, not a
    fabricated recovery)."""
    v = list(floor)
    lags: List[float] = []
    for e in event_steps:
        base = float(np.median(v[max(0, e - window):e])) if e > 0 \
            else float(v[e])
        target = frac * base
        lag = len(v) - e                       # censored default
        for t in range(e, len(v)):
            if v[t] >= target:
                lag = t - e
                break
        lags.append(float(lag))
    return float(np.mean(lags)) if lags else None


def fault_sle(floor: Sequence[float], fault_steps: Sequence[int],
              dead_steps: Sequence[int] = (),
              frac: float = RECOVERY_FRAC) -> Dict[str, Any]:
    """The fault-plane recovery block (repro.faults.harness): MTTR via
    the responsiveness SLE (mean steps from each fault injection to
    the floor recovering to `frac` x its pre-fault median, censored at
    run end) plus the degraded-mode min-BW floor — the worst per-step
    floor over the steps where progress was POSSIBLE (`dead_steps`,
    e.g. a blacked-out ring hop, are excluded: no controller can move
    bytes over a link that does not exist)."""
    v = np.asarray(list(floor), np.float64)
    dead = set(int(d) for d in dead_steps)
    alive = [float(v[t]) for t in range(len(v)) if t not in dead]
    return {
        "mttr_steps": responsiveness_steps(fault_steps, v, frac=frac),
        "degraded_min_bw": round(min(alive), 6) if alive else 0.0,
    }


# ----------------------------------------------------------------------
# Eq. 1 monitoring-cost meter
# ----------------------------------------------------------------------
def scenario_monitoring_usd(trace, n_dcs: int) -> float:
    """Eq. 1 dollars for a scenario run's trace-visible measurements:
    one 1-second snapshot per engine step (the per-step monitor sample)
    plus one snapshot capture per replan."""
    # local import: repro.wan.monitor pulls in the simulator, which
    # itself imports repro.obs — importing it lazily keeps the obs
    # package importable from anywhere without a cycle
    from repro.wan.monitor import SNAPSHOT_SECONDS, probe_cost_usd
    snap = probe_cost_usd(SNAPSHOT_SECONDS, n_dcs)
    n_replans = len(trace.replan_reasons())
    return (len(trace.steps) + n_replans) * snap


def fleet_monitoring_usd(trace, n_dcs: int) -> float:
    """Eq. 1 dollars for a fleet run: per tick, one snapshot capture
    per job plus the arbiter's 1-second capacity probe."""
    from repro.wan.monitor import SNAPSHOT_SECONDS, probe_cost_usd
    snap = probe_cost_usd(SNAPSHOT_SECONDS, n_dcs)
    return sum((s.n_jobs + 1) * snap for s in trace.steps)


# ----------------------------------------------------------------------
# Rollup blocks (the "sle" block in BENCH_scenarios / BENCH_fleet)
# ----------------------------------------------------------------------
def scenario_sle(trace, n_dcs: int = 8, band: float = SLE_BAND
                 ) -> Dict[str, Any]:
    """The SLE health block for one single-job scenario trace."""
    floor = [s.achieved_min for s in trace.steps]
    events = [s.step for s in trace.steps if s.events]
    return {
        "band": band,
        "accuracy": round(accuracy_sle(trace, band), 4),
        "capacity": round(capacity_sle(floor), 4),
        "fairness": round(jain_index(floor), 4),
        "responsiveness_steps": responsiveness_steps(events, floor),
        "monitoring_usd": round(scenario_monitoring_usd(trace, n_dcs), 6),
    }


def fleet_sle(trace, n_dcs: int = 8) -> Dict[str, Any]:
    """The SLE health block for one fleet trace. Fairness is Jain over
    per-job mean floor normalized by priority (1.0 = weighted-fair);
    capacity/responsiveness use the fleet-wide per-tick min floor."""
    floor = [min((row["achieved_min"] for row in s.jobs),
                 default=0.0) for s in trace.steps]
    events = [s.tick - trace.steps[0].tick for s in trace.steps
              if s.events]
    norm = []
    for name in trace.job_names():
        mins = trace.job_series(name, "achieved_min")
        prios = trace.job_series(name, "priority")
        norm.append(float(np.mean(mins)) / max(float(prios[-1]), 1e-9))
    return {
        "accuracy": None,      # fleet traces carry no predicted columns
        "capacity": round(capacity_sle(floor), 4),
        "fairness": round(jain_index(norm), 4),
        "responsiveness_steps": responsiveness_steps(events, floor),
        "monitoring_usd": round(fleet_monitoring_usd(trace, n_dcs), 6),
    }
