"""Canonical export, diff, check, and human-readable reporting.

One run's observability — metric registries, span rollups, SLE health
— exports as ONE canonical JSON document (`obs_schema` versioned,
sorted keys), which `tools/obsctl.py` summarizes, diffs against
another run, and gates in CI. The renderers here are the single
human-readable report path: `benchmarks/report.py` is a thin wrapper
over :func:`render_dryrun_summary` / :func:`render_dryrun_table`, and
:func:`summarize` also understands the repo's `BENCH_<name>.json`
trajectory documents, so there is one report implementation, not two
drifting ones.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

from repro.obs.registry import MetricsRegistry
from repro.obs.spans import SpanTracer

OBS_SCHEMA = 1

SLE_KEYS = ("accuracy", "capacity", "fairness", "responsiveness_steps",
            "monitoring_usd")
# SLE ratios live in [0, 1]; the rest only need to be non-negative
_RATIO_KEYS = ("accuracy", "capacity", "fairness")


# ----------------------------------------------------------------------
# Building and writing the canonical document
# ----------------------------------------------------------------------
def export_run(name: str, *, seed: Optional[int] = None,
               registries: Iterable[MetricsRegistry] = (),
               tracer: Optional[SpanTracer] = None,
               sle: Optional[Dict[str, Any]] = None,
               summary: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Assemble the canonical run document from the live objects."""
    metrics: Dict[str, Any] = {}
    for i, reg in enumerate(registries):
        key = reg.namespace or f"reg{i}"
        while key in metrics:                      # two sims, two jobs...
            key += "'"
        metrics[key] = reg.snapshot()
    doc: Dict[str, Any] = {
        "obs_schema": OBS_SCHEMA, "kind": "run", "name": name,
        "seed": seed, "metrics": metrics,
    }
    if tracer is not None and getattr(tracer, "enabled", False):
        doc["spans"] = {"count": len(tracer.spans),
                        "dropped": tracer.dropped,
                        "stages": tracer.by_stage()}
    if sle is not None:
        doc["sle"] = sle
    if summary is not None:
        doc["summary"] = summary
    return doc


def export_scenario(result, engine, name: Optional[str] = None
                    ) -> Dict[str, Any]:
    """Convenience: the run document for one completed
    :class:`repro.scenarios.ScenarioEngine` run — gathers the engine's
    registries (simulator, controller, lifecycle if attached), its
    tracer, the trace summary, and the scenario SLE block."""
    from repro.obs.sle import scenario_sle
    regs = [engine.sim.metrics, engine.controller.metrics]
    if engine.lifecycle is not None:
        regs += [engine.lifecycle.metrics,
                 engine.lifecycle.scheduler.metrics]
    return export_run(
        name or result.trace.scenario, seed=result.trace.seed,
        registries=regs, tracer=getattr(engine, "tracer", None),
        sle=scenario_sle(result.trace, n_dcs=engine.sim.N),
        summary=result.summary())


def to_json(doc: Mapping[str, Any]) -> str:
    """Canonical serialization: sorted keys, stable separators."""
    return json.dumps(doc, sort_keys=True, indent=2) + "\n"


def write_json(doc: Mapping[str, Any], path: str) -> str:
    """Write the canonical document; returns `path`."""
    with open(path, "w") as f:
        f.write(to_json(doc))
    return path


def write_spans_jsonl(tracer: SpanTracer, path: str) -> str:
    """One span per line (completion order), for external tooling."""
    with open(path, "w") as f:
        for row in tracer.spans:
            f.write(json.dumps(row, sort_keys=True,
                               separators=(",", ":")) + "\n")
    return path


def load(path: str) -> Any:
    """Read back any JSON document this plane (or a bench) wrote."""
    with open(path) as f:
        return json.load(f)


# ----------------------------------------------------------------------
# Diff and check (the obsctl gates)
# ----------------------------------------------------------------------
def flatten(doc: Any, prefix: str = "") -> Dict[str, float]:
    """All numeric leaves of a nested document as {dotted.path: value}
    (bools excluded; list elements are indexed)."""
    out: Dict[str, float] = {}
    if isinstance(doc, bool) or doc is None:
        return out
    if isinstance(doc, (int, float)):
        out[prefix or "value"] = float(doc)
    elif isinstance(doc, Mapping):
        for k in doc:
            out.update(flatten(doc[k], f"{prefix}.{k}" if prefix else k))
    elif isinstance(doc, (list, tuple)):
        for i, v in enumerate(doc):
            out.update(flatten(v, f"{prefix}[{i}]"))
    return out


def diff_runs(a: Any, b: Any) -> Dict[str, Dict[str, Any]]:
    """Numeric-leaf diff of two documents: {path: {a, b, rel}} for
    every changed leaf plus entries present on only one side."""
    fa, fb = flatten(a), flatten(b)
    out: Dict[str, Dict[str, Any]] = {}
    for k in sorted(set(fa) | set(fb)):
        va, vb = fa.get(k), fb.get(k)
        if va == vb:
            continue
        row: Dict[str, Any] = {"a": va, "b": vb}
        if va is not None and vb is not None and va != 0:
            row["rel"] = (vb - va) / abs(va)
        out[k] = row
    return out


def check_run(doc: Any, min_accuracy: Optional[float] = None,
              min_capacity: Optional[float] = None,
              min_fairness: Optional[float] = None,
              max_usd: Optional[float] = None) -> List[str]:
    """Validate a run document's schema and SLE floors; returns the
    list of problems (empty = pass)."""
    problems: List[str] = []
    if not isinstance(doc, Mapping):
        return [f"not a JSON object: {type(doc).__name__}"]
    if doc.get("obs_schema") != OBS_SCHEMA:
        problems.append(f"obs_schema != {OBS_SCHEMA}: "
                        f"{doc.get('obs_schema')!r}")
    if doc.get("kind") != "run":
        problems.append(f"kind != 'run': {doc.get('kind')!r}")
    if not doc.get("name"):
        problems.append("missing run name")
    if not isinstance(doc.get("metrics"), Mapping):
        problems.append("missing metrics block")
    sle = doc.get("sle")
    if not isinstance(sle, Mapping):
        problems.append("missing sle block")
        return problems
    for key in SLE_KEYS:
        if key not in sle:
            problems.append(f"sle missing {key!r}")
    for key in _RATIO_KEYS:
        v = sle.get(key)
        if v is not None and not (isinstance(v, (int, float))
                                  and 0.0 <= v <= 1.0):
            problems.append(f"sle.{key} not in [0, 1]: {v!r}")
    usd = sle.get("monitoring_usd")
    if not (isinstance(usd, (int, float)) and usd >= 0.0):
        problems.append(f"sle.monitoring_usd not >= 0: {usd!r}")
    floors = (("accuracy", min_accuracy, True),
              ("capacity", min_capacity, True),
              ("fairness", min_fairness, True),
              ("monitoring_usd", max_usd, False))
    for key, bound, is_floor in floors:
        if bound is None:
            continue
        v = sle.get(key)
        if v is None:
            problems.append(f"sle.{key} is null but a bound was set")
        elif is_floor and v < bound:
            problems.append(f"sle.{key} {v} < floor {bound}")
        elif not is_floor and v > bound:
            problems.append(f"sle.{key} {v} > ceiling {bound}")
    return problems


# ----------------------------------------------------------------------
# The one human-readable report
# ----------------------------------------------------------------------
def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _summarize_run(doc: Mapping[str, Any]) -> str:
    out = [f"run: {doc.get('name')} (seed {doc.get('seed')})"]
    sle = doc.get("sle")
    if sle:
        cells = "  ".join(f"{k}={_fmt(sle[k])}" for k in SLE_KEYS
                          if k in sle)
        out.append(f"  sle: {cells}")
    summary = doc.get("summary")
    if summary:
        cells = "  ".join(f"{k}={_fmt(v)}" for k, v in sorted(
            summary.items()) if isinstance(v, (int, float)))
        out.append(f"  summary: {cells}")
    for ns in sorted(doc.get("metrics", {})):
        snap = doc["metrics"][ns]
        cells = []
        for name in sorted(snap):
            m = snap[name]
            if m.get("kind") in ("counter", "gauge"):
                cells.append(f"{name}={_fmt(m['value'])}")
            elif m.get("kind") == "histogram" and m.get("count"):
                cells.append(f"{name}: n={m['count']} "
                             f"mean={_fmt(m['sum'] / m['count'])}")
        if cells:
            out.append(f"  {ns}: " + "  ".join(cells))
    spans = doc.get("spans")
    if spans:
        out.append(f"  spans: {spans['count']} recorded "
                   f"({spans['dropped']} dropped)")
        stages = spans.get("stages", {})
        for name in sorted(stages, key=lambda n: -stages[n]["total_s"]):
            st = stages[name]
            line = (f"    {name:<12} x{st['count']:<5} "
                    f"total {st['total_s'] * 1e3:8.2f} ms  "
                    f"mean {st['mean_s'] * 1e6:8.1f} us")
            if st.get("delta"):
                line += "  " + " ".join(f"{k}+{_fmt(v)}" for k, v in
                                        sorted(st["delta"].items()))
            out.append(line)
    return "\n".join(out)


def _summarize_bench(doc: Mapping[str, Any]) -> str:
    out = [f"bench: {doc['bench']} (schema {doc.get('schema')}, "
           f"{len(doc['rows'])} rows)"]
    for row in doc["rows"]:
        cells = "  ".join(f"{k}={_fmt(v)}" for k, v in sorted(row.items())
                          if isinstance(v, (int, float))
                          and not isinstance(v, bool))
        out.append(f"  - {cells}")
        sle = row.get("sle")
        if isinstance(sle, Mapping):
            cells = "  ".join(f"{k}={_fmt(sle[k])}" for k in SLE_KEYS
                              if sle.get(k) is not None)
            out.append(f"      sle: {cells}")
    return "\n".join(out)


def summarize(doc: Any) -> str:
    """Render ANY of the repo's JSON observability documents — an obs
    run export, a `BENCH_<name>.json` trajectory document, or a dryrun
    cell list — through the one canonical report path."""
    if isinstance(doc, Mapping) and doc.get("kind") == "run":
        return _summarize_run(doc)
    if isinstance(doc, Mapping) and "bench" in doc and "rows" in doc:
        return _summarize_bench(doc)
    if isinstance(doc, list) and doc and isinstance(doc[0], Mapping) \
            and "status" in doc[0]:
        return render_dryrun_table(doc, "dryrun")
    return json.dumps(doc, indent=2, sort_keys=True)


# -- the EXPERIMENTS dry-run tables (formerly benchmarks/report.py) ----
def _fmt_bytes(b: float) -> str:
    return f"{b / 2 ** 30:.2f}"


def render_dryrun_table(cells: List[Mapping[str, Any]], mesh: str) -> str:
    """The per-mesh dry-run/roofline markdown table."""
    out = [f"\n### {mesh}-pod mesh "
           f"({'2x16x16 (pod,data,model)' if mesh == 'multi' else '16x16 (data,model)'})\n",
           "| arch | shape | HBM/dev GiB | t_comp s | t_mem s | t_coll s"
           " | dominant | useful-FLOPs | roofline-frac | notes |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["status"] == "skipped":
            out.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | — |"
                       f" — | — | SKIP: {c['reason'][:60]} |")
            continue
        if c["status"] == "error":
            out.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | — |"
                       f" — | — | ERROR {c['error'][:60]} |")
            continue
        r = c["roofline"]
        note = "over 16GB HBM" if c["hbm_per_device"] > 16e9 else ""
        dci = f" dci={r['dci_bytes'] / 2 ** 30:.2f}GiB" \
            if r["dci_bytes"] else ""
        out.append(
            f"| {c['arch']} | {c['shape']} | {_fmt_bytes(c['hbm_per_device'])}"
            f" | {r['t_compute']:.2e} | {r['t_memory']:.2e}"
            f" | {r['t_collective']:.2e} | {r['dominant']}"
            f" | {r['useful_flops_ratio']:.2f}"
            f" | {r['roofline_fraction']:.3f} | {note}{dci} |")
    return "\n".join(out)


def render_dryrun_summary(cells_by_mesh: Mapping[str, List[Mapping[str, Any]]]
                          ) -> str:
    """The cross-mesh dry-run summary bullets."""
    rows = []
    for mesh, cells in cells_by_mesh.items():
        ok = [c for c in cells if c["status"] == "ok"]
        if not ok:
            continue
        doms: Dict[str, int] = {}
        for c in ok:
            doms[c["roofline"]["dominant"]] = \
                doms.get(c["roofline"]["dominant"], 0) + 1
        worst = min(ok, key=lambda c: c["roofline"]["roofline_fraction"])
        coll = max(ok, key=lambda c: c["roofline"]["t_collective"] /
                   max(c["roofline"]["t_compute"] +
                       c["roofline"]["t_memory"], 1e-12))
        rows.append(f"- **{mesh}**: {len(ok)} ok / "
                    f"{sum(c['status'] == 'skipped' for c in cells)} skipped; "
                    f"dominant terms: {doms}; worst roofline fraction "
                    f"{worst['roofline']['roofline_fraction']:.3f} "
                    f"({worst['arch']}x{worst['shape']}); most "
                    f"collective-bound: {coll['arch']}x{coll['shape']}")
    return "\n".join(rows)
