"""MetricsRegistry — deterministic process-local metrics primitives.

The paper dedicates an axis to WAN monitoring *cost* (§1, Eq. 1), yet
until this plane existed the repo's own runtime was observed only
through scattered ad-hoc counters (`WanifyController.cache_builds`,
`WanSimulator.fill_calls`, `BatchedRfPredictor.kernel_calls`, ...).
The registry gives every subsystem the same four primitives:

  * :class:`Counter`   — monotone accumulator (ints or Eq. 1 dollars);
  * :class:`Gauge`     — last-write-wins scalar (e.g. the most recent
    fill's iteration count);
  * :class:`Histogram` — fixed-bucket distribution (bucket uppers are
    chosen at creation, never adapted, so two runs bucket identically);
  * :class:`Series`    — bounded labeled append log (label, value)
    for per-reason / per-stage breakdowns.

Determinism contract (the reason obs can stay ON under the trace
goldens): the registry draws NO randomness, reads NO wall clock, and
recording or reading a metric never feeds back into any control
decision. Recorded *values* are exactly what callers pass. Reads are
pure: `snapshot()` / `counters()` build fresh dicts and never mutate
metric state (pinned by a hypothesis property in tests/test_obs.py).

Metric names within one registry are unique per kind; `labels=` folds
a label mapping into the name canonically (sorted keys), so
``counter("replans", labels={"reason": "periodic"})`` is the metric
``replans{reason=periodic}`` every run.
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple


def _label_name(name: str, labels: Optional[Mapping[str, str]]) -> str:
    """Canonical metric key: ``name{k1=v1,k2=v2}`` with sorted keys."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotone accumulator; `inc` only (use `reset` for back-compat
    attribute setters, never on the hot path)."""

    __slots__ = ("name", "help", "_value")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value: float = 0

    def inc(self, n: float = 1) -> None:
        """Add `n` (must be >= 0 — counters never go backwards)."""
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease "
                             f"(inc({n}))")
        self._value += n

    def reset(self, value: float = 0) -> None:
        """Back-compat escape hatch for the legacy attribute setters."""
        self._value = value

    @property
    def value(self) -> float:
        """Current cumulative value (int-valued unless floats added)."""
        return self._value

    def to_dict(self) -> Dict[str, Any]:
        """Export form: {"kind", "value"}."""
        return {"kind": self.kind, "value": self._value}


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "help", "_value")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value: float = 0

    def set(self, value: float) -> None:
        """Record the current level."""
        self._value = value

    @property
    def value(self) -> float:
        """Most recently set value."""
        return self._value

    def to_dict(self) -> Dict[str, Any]:
        """Export form: {"kind", "value"}."""
        return {"kind": self.kind, "value": self._value}


class Histogram:
    """Fixed-bucket distribution: bucket uppers are pinned at creation
    (no adaptive resizing — two runs bucket identically), with a +inf
    overflow bucket appended implicitly."""

    __slots__ = ("name", "help", "buckets", "counts", "_sum", "_count")
    kind = "histogram"

    def __init__(self, name: str, buckets: Sequence[float], help: str = ""):
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(f"histogram {name!r} buckets must be a "
                             f"non-empty strictly increasing sequence")
        self.name = name
        self.help = help
        self.buckets: Tuple[float, ...] = tuple(float(b) for b in buckets)
        self.counts: List[int] = [0] * (len(self.buckets) + 1)
        self._sum = 0.0
        self._count = 0

    def observe(self, value: float) -> None:
        """Record one sample into its bucket (last bucket = overflow)."""
        self.counts[bisect_left(self.buckets, value)] += 1
        self._sum += value
        self._count += 1

    @property
    def count(self) -> int:
        """Total samples observed."""
        return self._count

    @property
    def sum(self) -> float:
        """Sum of all observed samples."""
        return self._sum

    @property
    def mean(self) -> float:
        """Mean observed sample (0.0 before any observation)."""
        return self._sum / self._count if self._count else 0.0

    def to_dict(self) -> Dict[str, Any]:
        """Export form: {"kind", "buckets", "counts", "sum", "count"}."""
        return {"kind": self.kind, "buckets": list(self.buckets),
                "counts": list(self.counts), "sum": self._sum,
                "count": self._count}


class Series:
    """Bounded labeled append log: `record(value, label=...)` keeps the
    LAST `cap` points as (label, value) pairs — per-reason replan logs,
    per-stage tallies — without unbounded growth on long runs."""

    __slots__ = ("name", "help", "cap", "points", "dropped")
    kind = "series"

    def __init__(self, name: str, cap: int = 4096, help: str = ""):
        self.name = name
        self.help = help
        self.cap = int(cap)
        self.points: List[Tuple[str, float]] = []
        self.dropped = 0

    def record(self, value: float, label: str = "") -> None:
        """Append one labeled point (oldest points drop past `cap`)."""
        self.points.append((label, value))
        if len(self.points) > self.cap:
            del self.points[0]
            self.dropped += 1

    def __len__(self) -> int:
        return len(self.points)

    def by_label(self) -> Dict[str, int]:
        """Count of retained points per label (deterministic order)."""
        out: Dict[str, int] = {}
        for label, _ in self.points:
            out[label] = out.get(label, 0) + 1
        return out

    def to_dict(self) -> Dict[str, Any]:
        """Export form: {"kind", "n", "dropped", "by_label"}."""
        return {"kind": self.kind, "n": len(self.points),
                "dropped": self.dropped, "by_label": self.by_label()}


class MetricsRegistry:
    """One namespace of metrics, owned by one subsystem object.

    Get-or-create accessors (`counter` / `gauge` / `histogram` /
    `series`) are idempotent per (name, kind); asking for an existing
    name as a different kind raises — a name means one thing.
    """

    def __init__(self, namespace: str = ""):
        self.namespace = namespace
        self._metrics: Dict[str, Any] = {}

    # -- get-or-create accessors --------------------------------------
    def _get(self, cls, name: str, labels: Optional[Mapping[str, str]],
             **kwargs):
        key = _label_name(name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = cls(key, **kwargs)
            self._metrics[key] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {key!r} already registered as "
                            f"{m.kind}, not {cls.kind}")
        return m

    def counter(self, name: str,
                labels: Optional[Mapping[str, str]] = None,
                help: str = "") -> Counter:
        """Get-or-create a :class:`Counter`."""
        return self._get(Counter, name, labels, help=help)

    def gauge(self, name: str,
              labels: Optional[Mapping[str, str]] = None,
              help: str = "") -> Gauge:
        """Get-or-create a :class:`Gauge`."""
        return self._get(Gauge, name, labels, help=help)

    def histogram(self, name: str, buckets: Sequence[float],
                  labels: Optional[Mapping[str, str]] = None,
                  help: str = "") -> Histogram:
        """Get-or-create a :class:`Histogram` (buckets fixed at first
        creation; later calls ignore the argument)."""
        return self._get(Histogram, name, labels, buckets=buckets,
                         help=help)

    def series(self, name: str, cap: int = 4096,
               labels: Optional[Mapping[str, str]] = None,
               help: str = "") -> Series:
        """Get-or-create a :class:`Series`."""
        return self._get(Series, name, labels, cap=cap, help=help)

    # -- pure reads ---------------------------------------------------
    def names(self) -> List[str]:
        """Registered metric keys, insertion-ordered."""
        return list(self._metrics)

    def get(self, name: str) -> Any:
        """The metric object under `name` (KeyError if absent)."""
        return self._metrics[name]

    def counters(self) -> Dict[str, float]:
        """{name: value} over counters AND gauges only — the cheap
        snapshot the span tracer deltas against."""
        return {k: m.value for k, m in self._metrics.items()
                if isinstance(m, (Counter, Gauge))}

    def snapshot(self) -> Dict[str, Any]:
        """Full export: {name: to_dict()} for every metric, sorted by
        name so two identical runs serialize identically."""
        return {k: self._metrics[k].to_dict()
                for k in sorted(self._metrics)}
