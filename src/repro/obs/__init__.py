"""Unified observability plane: metrics, spans, SLE rollups, export.

See DESIGN.md "Observability plane". The registry is always on (it is
where the legacy ad-hoc counters now live); span tracing is gated
`REPRO_OBS=off|on` (off default) and is passive either way — every
trace golden replays byte-identical with obs on.
"""
from repro.obs.export import (OBS_SCHEMA, check_run, diff_runs,
                              export_run, export_scenario, flatten,
                              load, render_dryrun_summary,
                              render_dryrun_table, summarize, to_json,
                              write_json, write_spans_jsonl)
from repro.obs.registry import (Counter, Gauge, Histogram,
                                MetricsRegistry, Series)
from repro.obs.sle import (SLE_BAND, accuracy_sle, capacity_sle, fault_sle,
                           fleet_monitoring_usd, fleet_sle, jain_index,
                           responsiveness_steps, scenario_monitoring_usd,
                           scenario_sle)
from repro.obs.spans import (NULL_TRACER, OBS_MODES, NullTracer,
                             SpanTracer, obs_mode)

__all__ = [
    "OBS_SCHEMA", "OBS_MODES", "SLE_BAND", "NULL_TRACER",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Series",
    "NullTracer", "SpanTracer", "obs_mode",
    "accuracy_sle", "capacity_sle", "fault_sle", "jain_index",
    "responsiveness_steps", "scenario_monitoring_usd",
    "fleet_monitoring_usd", "scenario_sle", "fleet_sle",
    "export_run", "export_scenario", "to_json", "write_json",
    "write_spans_jsonl", "load", "flatten", "diff_runs", "check_run",
    "summarize", "render_dryrun_table", "render_dryrun_summary",
]
