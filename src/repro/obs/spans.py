"""Nested span tracing around one tick of the closed loop.

"Where does a tick go?" — snapshot -> predict -> optimize/route ->
lower -> water-fill -> AIMD — was unanswerable before this module:
wall time existed only as whole-bench aggregates. A :class:`SpanTracer`
records a nested span per stage with

  * wall time (``time.perf_counter`` deltas — the ONLY place the obs
    plane touches a clock, and it flows solely into span records /
    exports, never into trace values or control decisions);
  * optional counter deltas from watched registries (fill iterations,
    kernel launches, cache hits) on spans opened with ``delta=True``.

Gating (`REPRO_OBS=off|on`, off default, resolved by :func:`obs_mode`)
follows the overlay/lifecycle pattern: off installs the shared
:data:`NULL_TRACER`, whose `span()` returns a reused no-op context
manager — the hot path pays one attribute lookup and an empty
``with``. On is *passive* by construction: spans observe the stages
the caller already runs, in the order it already runs them, so every
historical trace golden replays byte-identical with obs on (pinned in
tests/test_obs.py).
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional

from repro.obs.registry import MetricsRegistry

OBS_MODES = ("off", "on")


def obs_mode(mode: Optional[str] = None) -> str:
    """Resolve the observability gate: an explicit argument wins, then
    the ``REPRO_OBS`` environment variable, then ``off``."""
    m = mode or os.environ.get("REPRO_OBS", "off")
    if m not in OBS_MODES:
        raise ValueError(f"unknown obs mode {m!r}; "
                         f"expected one of {OBS_MODES}")
    return m


class _NullSpan:
    """Reusable no-op context manager (the off path's entire cost)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The off-gate tracer: every span is the shared no-op."""

    enabled = False
    spans: List[Dict[str, Any]] = []

    def span(self, name: str, delta: bool = False, **attrs) -> _NullSpan:
        """Return the shared no-op span."""
        return _NULL_SPAN

    def watch(self, registry: MetricsRegistry) -> None:
        """No-op (nothing is ever recorded)."""


NULL_TRACER = NullTracer()


class _SpanCtx:
    """One live span: context manager that records itself on exit."""

    __slots__ = ("tracer", "name", "attrs", "delta", "sid", "parent",
                 "depth", "t0", "before")

    def __init__(self, tracer: "SpanTracer", name: str, delta: bool,
                 attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.delta = delta
        self.before: Optional[Dict[str, float]] = None

    def __enter__(self):
        tr = self.tracer
        self.sid = tr._seq
        tr._seq += 1
        self.parent = tr._stack[-1] if tr._stack else -1
        self.depth = len(tr._stack)
        tr._stack.append(self.sid)
        if self.delta and tr._watched:
            self.before = {f"{reg.namespace}.{k}": v
                           for reg in tr._watched
                           for k, v in reg.counters().items()}
        self.t0 = tr._clock()
        return self

    def __exit__(self, *exc):
        tr = self.tracer
        dur = tr._clock() - self.t0
        tr._stack.pop()
        row: Dict[str, Any] = {
            "sid": self.sid, "parent": self.parent, "depth": self.depth,
            "name": self.name, "t": self.t0 - tr._t0, "dur_s": dur,
        }
        if self.attrs:
            row["attrs"] = self.attrs
        if self.before is not None:
            after = {f"{reg.namespace}.{k}": v
                     for reg in tr._watched
                     for k, v in reg.counters().items()}
            # metrics created DURING the span delta from 0
            d = {k: v - self.before.get(k, 0) for k, v in after.items()
                 if v != self.before.get(k, 0)}
            if d:
                row["delta"] = d
        tr._record(row)
        return False


class SpanTracer:
    """Collects nested spans; one per engine/fleet when obs is on.

    ``watch(registry)`` registers a :class:`MetricsRegistry` whose
    counter/gauge movement is captured as a per-span delta on spans
    opened with ``delta=True`` (delta keys are namespaced
    ``<registry.namespace>.<metric>``). Spans past `max_spans` are
    dropped (counted on `dropped`) so long runs stay bounded.
    """

    enabled = True

    def __init__(self, max_spans: int = 200_000, clock=time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self.max_spans = int(max_spans)
        self.spans: List[Dict[str, Any]] = []
        self.dropped = 0
        self._stack: List[int] = []
        self._seq = 0
        self._watched: List[MetricsRegistry] = []

    def watch(self, registry: MetricsRegistry) -> None:
        """Delta this registry's counters on ``delta=True`` spans."""
        if registry not in self._watched:
            self._watched.append(registry)

    def span(self, name: str, delta: bool = False, **attrs) -> _SpanCtx:
        """Open a span; use as ``with tracer.span("waterfill"): ...``."""
        return _SpanCtx(self, name, delta, attrs)

    def _record(self, row: Dict[str, Any]) -> None:
        if len(self.spans) >= self.max_spans:
            self.dropped += 1
            return
        self.spans.append(row)

    def reset(self) -> None:
        """Drop all recorded spans (watched registries are kept)."""
        self.spans.clear()
        self._stack.clear()
        self.dropped = 0
        self._seq = 0
        self._t0 = self._clock()

    # -- rollups ------------------------------------------------------
    def by_stage(self) -> Dict[str, Dict[str, Any]]:
        """Aggregate spans by name: count, total/mean wall seconds, and
        the summed counter deltas — the "where does a tick go" table."""
        out: Dict[str, Dict[str, Any]] = {}
        for row in self.spans:
            agg = out.setdefault(row["name"],
                                 {"count": 0, "total_s": 0.0, "delta": {}})
            agg["count"] += 1
            agg["total_s"] += row["dur_s"]
            for k, v in row.get("delta", {}).items():
                agg["delta"][k] = agg["delta"].get(k, 0) + v
        for agg in out.values():
            agg["mean_s"] = agg["total_s"] / agg["count"]
            if not agg["delta"]:
                del agg["delta"]
        return out
