"""Zamba2-2.7B — hybrid: Mamba2 backbone + shared attention blocks.

54L d_model=2560 32H (kv=32) d_ff=10240 ssm_state=64 vocab=32000.
One shared attention+MLP block (single param set) interleaved every 6
Mamba2 layers. [arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,                  # shared block MLP
    vocab=32000,
    shared_attn_every=6,
    ssm=SSMConfig(
        d_state=64,
        d_conv=4,
        expand=2,
        head_dim=64,
        n_groups=1,
        chunk=256,
    ),
    source="arXiv:2411.15242; hf",
)
