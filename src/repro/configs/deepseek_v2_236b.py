"""DeepSeek-V2 236B — MoE with Multi-head Latent Attention.

60L d_model=5120 128H (MLA kv_lora=512) expert d_ff=1536 vocab=102400,
2 shared + 160 routed experts, top-6. [arXiv:2405.04434; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,                      # dense FFN for the first (non-MoE) layer
    vocab=102400,
    head_dim=192,                    # qk_nope(128) + qk_rope(64)
    rope_theta=10000.0,
    moe=MoEConfig(
        n_experts=160,
        top_k=6,
        d_ff_expert=1536,
        n_shared_experts=2,
        first_dense_layers=1,
        capacity_factor=1.25,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    source="arXiv:2405.04434; hf",
)
