"""InternVL2-2B — InternViT (STUB frontend) + InternLM2 backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553. The vision tower
is a stub: input_specs() provides precomputed patch embeddings already
projected into the LM embedding space. [arXiv:2404.16821; hf]
"""
from repro.configs.base import ModelConfig, EncoderConfig

CONFIG = ModelConfig(
    arch_id="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    rope_theta=1000000.0,
    encoder=EncoderConfig(
        n_layers=0,              # stubbed: no vision tower compute
        d_model=2048,
        n_heads=0,
        d_ff=0,
        source_len=256,          # 256 patch embeddings per image
        frontend="stub",
    ),
    source="arXiv:2404.16821; hf",
)
