"""Mamba2-2.7B — attention-free SSD (state-space duality).

64L d_model=2560 ssm_state=128 vocab=50280.
[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,               # attention-free
    n_kv_heads=0,
    d_ff=0,                  # no MLP blocks: mamba2 blocks only
    vocab=50280,
    ssm=SSMConfig(
        d_state=128,
        d_conv=4,
        expand=2,            # d_inner = 5120
        head_dim=64,         # 80 ssm heads
        n_groups=1,
        chunk=256,
    ),
    source="arXiv:2405.21060; unverified",
)
