"""Base configuration dataclasses for the model zoo.

Every assigned architecture is expressed as a ``ModelConfig``. Families:
  dense | moe | ssm | hybrid | audio (enc-dec) | vlm
Attention variants are flags: GQA (n_kv_heads), MLA (kv_lora_rank>0),
SWA (sliding_window>0), qk_norm.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 0
    d_ff_expert: int = 0          # per-expert intermediate size
    n_shared_experts: int = 0     # always-on shared experts (deepseek-style)
    capacity_factor: float = 1.25
    first_dense_layers: int = 0   # leading dense layers (deepseek v2 uses 1)
    router_jitter: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 0         # compressed kv dim (c_kv)
    q_lora_rank: int = 0          # 0 => full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 0              # N
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64            # P
    n_groups: int = 1
    chunk: int = 256              # SSD chunk length


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for enc-dec (whisper) / vlm (InternViT stub)."""
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    d_ff: int = 0
    source_len: int = 0           # audio frames / image patches
    frontend: str = "stub"        # modality frontend is a stub: input_specs()
                                  # provides precomputed frame/patch embeddings


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 => d_model // n_heads
    qk_norm: bool = False
    sliding_window: int = 0       # 0 => full attention
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    encoder: EncoderConfig = field(default_factory=EncoderConfig)
    # hybrid (zamba2-style): a shared attention+MLP block is interleaved
    # every `shared_attn_every` ssm layers, reusing ONE set of params.
    shared_attn_every: int = 0
    # dtype policy
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    source: str = ""              # provenance tag

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.moe.n_experts > 0

    @property
    def is_mla(self) -> bool:
        return self.mla.kv_lora_rank > 0

    @property
    def is_ssm(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def is_encdec(self) -> bool:
        return self.family == "audio"

    @property
    def is_vlm(self) -> bool:
        return self.family == "vlm"

    @property
    def subquadratic(self) -> bool:
        """True when the arch can run long_500k decode (sub-quadratic /
        bounded-state sequence mixing)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def has_decoder(self) -> bool:
        """All assigned archs autogress; encoder-only would return False."""
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A small same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=256,
        vocab=512,
        head_dim=32 if cfg.head_dim else 0,
    )
    if cfg.is_moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            first_dense_layers=min(cfg.moe.first_dense_layers, 1))
    if cfg.is_mla:
        kw["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=32, q_lora_rank=0,
            qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
    if cfg.family in ("ssm", "hybrid"):
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=16)
        kw["n_layers"] = min(cfg.n_layers, 4)
    if cfg.shared_attn_every:
        kw["shared_attn_every"] = 2
    if cfg.encoder.n_layers:
        kw["encoder"] = dataclasses.replace(
            cfg.encoder, n_layers=2, d_model=128, n_heads=4, d_ff=256,
            source_len=16)
    if cfg.sliding_window:
        kw["sliding_window"] = 32
    return cfg.replace(**kw)
