"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, reduced  # noqa: F401

_ARCH_MODULES = {
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "llama3-8b": "repro.configs.llama3_8b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "whisper-medium": "repro.configs.whisper_medium",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "internvl2-2b": "repro.configs.internvl2_2b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; known: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
