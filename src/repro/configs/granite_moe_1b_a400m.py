"""IBM Granite 3.0 1B-A400M base — small MoE.

24L d_model=1024 16H (GQA kv=8) expert d_ff=512 vocab=49155,
32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    rope_theta=10000.0,
    moe=MoEConfig(
        n_experts=32,
        top_k=8,
        d_ff_expert=512,
        n_shared_experts=0,
        capacity_factor=1.25,
    ),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
