"""Assigned input shapes and ShapeDtypeStruct input_specs per (arch, shape).

Shapes (LM transformer: seq_len x global_batch):
  train_4k     seq=4096    gb=256  -> train_step
  prefill_32k  seq=32768   gb=32   -> prefill (inference)
  decode_32k   seq=32768   gb=128  -> serve_step (1 new token, KV cache of seq)
  long_500k    seq=524288  gb=1    -> serve_step; sub-quadratic archs only

``input_specs`` allocates nothing: pure ShapeDtypeStructs (the
shannon/kernels pattern), weak-type-correct and shardable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

SHAPE_NAMES = list(SHAPES)


def applicable(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; otherwise the skip reason
    (recorded in EXPERIMENTS.md / DESIGN.md)."""
    spec = SHAPES[shape_name]
    if spec.name == "long_500k" and not cfg.subquadratic:
        return ("full quadratic attention: 512k-token decode cache/attention "
                "is out of scope per assignment (sub-quadratic archs only)")
    if spec.kind == "decode" and not cfg.has_decoder:
        return "encoder-only arch has no decode step"
    return None


def input_specs(cfg: ModelConfig, shape_name: str,
                tp: int = 16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of the lowered step.

    train  -> tokens/targets (+ modality stub embeddings)
    prefill-> tokens (+ stubs)
    decode -> cache + single-token batch + position
    """
    spec = SHAPES[shape_name]
    B, S = spec.global_batch, spec.seq_len
    f = jnp.dtype(cfg.dtype)
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if spec.kind in ("train", "prefill"):
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if spec.kind == "train":
            out["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.is_encdec:
            e = cfg.encoder
            out["enc_frames"] = jax.ShapeDtypeStruct((B, e.source_len, e.d_model), f)
        if cfg.is_vlm:
            e = cfg.encoder
            out["patch_embeds"] = jax.ShapeDtypeStruct((B, e.source_len, cfg.d_model), f)
    else:  # decode
        from repro.models.registry import cache_spec  # lazy: avoid cycle
        out["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        out["cache"] = cache_spec(cfg, B, S, tp=tp)
    return out
