"""Whisper-medium — encoder-decoder; conv audio frontend is a STUB
(input_specs provides precomputed frame embeddings).

24L(enc)+24L(dec) d_model=1024 16H d_ff=4096 vocab=51865.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig, EncoderConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium",
    family="audio",
    n_layers=24,                 # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    rope_theta=0.0,              # whisper uses learned/sinusoidal positions
    encoder=EncoderConfig(
        n_layers=24,
        d_model=1024,
        n_heads=16,
        d_ff=4096,
        source_len=1500,         # 30 s of audio after conv frontend
        frontend="stub",
    ),
    source="arXiv:2212.04356; unverified",
)
