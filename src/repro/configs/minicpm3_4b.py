"""MiniCPM3-4B — dense with Multi-head Latent Attention.

62L d_model=2560 40H d_ff=6400 vocab=73448, MLA.
[hf:openbmb/MiniCPM3-4B; hf]
"""
from repro.configs.base import ModelConfig, MLAConfig

CONFIG = ModelConfig(
    arch_id="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    head_dim=96,                      # qk_nope(64) + qk_rope(32)
    rope_theta=10000.0,
    mla=MLAConfig(
        kv_lora_rank=256,
        q_lora_rank=768,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    source="hf:openbmb/MiniCPM3-4B; hf",
)
