"""Serving engine: batched prefill + decode with KV caches, continuous
request batching, and WANify-scheduled cross-pod KV-cache migration for
disaggregated prefill/decode serving (the paper's "data transfer between
DCs" in inference form).

Plans come from the shared WANify control plane: hand the engine a
`repro.control.WanifyController` and call :meth:`Engine.replan` whenever
the WAN shifts (periodically, or when migration latency degrades) — the
next `kv_migrate` picks up the new chunking/bits.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.control import WanifyController, offset_schedule, \
    wire_decode, wire_encode
from repro.core.plan import WanPlan
from repro.models import registry
from repro.models.layers import ShardCtx


@dataclass
class Request:
    """One generation request (prompt in, generated ids out)."""

    rid: int
    prompt: np.ndarray                  # [S_prompt] int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class ServeConfig:
    """Engine shape: slot count, max sequence, tensor-parallel width."""

    batch: int = 8
    s_max: int = 256
    tp: int = 1
    greedy: bool = True


class Engine:
    """Static-batch engine: slot-based continuous batching; prefill joins
    new requests into free slots, decode advances all live slots."""

    def __init__(self, cfg: ModelConfig, params: Any, sc: ServeConfig,
                 ctx: Optional[ShardCtx] = None,
                 controller: Optional[WanifyController] = None,
                 plan: Optional[WanPlan] = None):
        self.cfg, self.params, self.sc = cfg, params, sc
        self.ctx = ctx or ShardCtx()
        self._prefill = jax.jit(registry.prefill_fn(
            cfg, self.ctx, sc.s_max, tp=sc.tp))
        self._decode = jax.jit(registry.decode_fn(cfg, self.ctx))
        self.cache = None
        self.pos = 0
        # WANify control plane for KV-cache migration plans
        self.controller = controller
        self._static_plan = plan

    @property
    def plan(self) -> Optional[WanPlan]:
        """The migration plan in force — always the shared controller's
        latest (never a stale snapshot), unless an explicit static plan
        was handed in."""
        if self._static_plan is not None:
            return self._static_plan
        return self.controller.plan if self.controller is not None else None

    @plan.setter
    def plan(self, value: Optional[WanPlan]) -> None:
        """Pin a static plan (overrides the live controller)."""
        self._static_plan = value

    # ------------------------------------------------------------------
    # WANify control plane hooks
    # ------------------------------------------------------------------
    def replan(self, skew_w: Optional[np.ndarray] = None) -> WanPlan:
        """Run one control-loop iteration (snapshot -> prediction ->
        optimization -> AIMD) and adopt the resulting migration plan
        (dropping any static override in favor of the live controller)."""
        if self.controller is None:
            raise RuntimeError("Engine.replan() needs a WanifyController")
        self._static_plan = None
        self.controller.replan(skew_w=skew_w, reason="serve")
        return self.plan

    def migration_schedule(self) -> List[Dict[str, int]]:
        """Per-offset chunk/bits schedule `kv_migrate` will use under the
        current plan."""
        if self.plan is None:
            raise RuntimeError("no migration plan (pass controller/plan)")
        return offset_schedule(self.plan)

    def prefill(self, batch_tokens: np.ndarray,
                extras: Optional[Dict] = None) -> np.ndarray:
        """Run prefill over a token batch; returns next-token argmax."""
        batch = {"tokens": jnp.asarray(batch_tokens)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        logits, self.cache = self._prefill(self.params, batch)
        self.pos = batch_tokens.shape[1]
        return np.asarray(jnp.argmax(logits, axis=-1))

    def decode(self, tokens: np.ndarray) -> np.ndarray:
        """Advance every live slot one step; returns next-token argmax."""
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens[:, None]),
            jnp.int32(self.pos))
        self.pos += 1
        return np.asarray(jnp.argmax(logits, axis=-1))

    # ------------------------------------------------------------------
    def serve(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Batched generation over a request list (pads to the engine
        batch; greedy decoding)."""
        out: Dict[int, List[int]] = {}
        B = self.sc.batch
        for i in range(0, len(requests), B):
            group = requests[i:i + B]
            S = max(len(r.prompt) for r in group)
            toks = np.zeros((B, S), np.int32)
            for gi, r in enumerate(group):
                toks[gi, S - len(r.prompt):] = r.prompt   # left-pad
            nxt = self.prefill(toks)
            maxn = max(r.max_new for r in group)
            cur = nxt
            gen = [[] for _ in range(B)]
            for t in range(maxn):
                for gi in range(len(group)):
                    gen[gi].append(int(cur[gi]))
                cur = self.decode(cur.astype(np.int32))
            for gi, r in enumerate(group):
                r.out = gen[gi][:r.max_new]
                r.done = True
                out[r.rid] = r.out
        return out


# ----------------------------------------------------------------------
# Disaggregated serving: migrate a prefill pod's KV cache to decode pods
# over the WANify-scheduled inter-pod links.
# ----------------------------------------------------------------------
def kv_migrate(cache: Any, plan: WanPlan, src_pod: int, *,
               axis: str = "pod", compress: bool = True) -> Any:
    """Broadcast `cache` (valid on src_pod) to all pods with per-offset
    chunking + wire compression from the plan. Call inside shard_map with
    the pod axis manual."""
    P_ = plan.n_pods
    if P_ <= 1:
        return cache
    sched = offset_schedule(plan)
    rank = jax.lax.axis_index(axis)

    def leaf(x):
        """Migrate one cache leaf through the offset phases."""
        out = x
        for ph in sched:
            o, chunks, bits = ph["offset"], ph["chunks"], ph["bits"]
            if not compress:
                bits = 32
            perm = [(i, (i + o) % P_) for i in range(P_)]
            flat = out.reshape(-1)
            pad = (-flat.shape[0]) % max(chunks, 1)
            if pad:
                flat = jnp.pad(flat, (0, pad))
            parts = jnp.split(flat, chunks) if chunks > 1 else [flat]
            rec = []
            for part in parts:
                enc, scale = wire_encode(part, bits)
                enc_r = jax.lax.ppermute(enc, axis, perm)
                s_r = jax.lax.ppermute(scale, axis, perm) \
                    if scale is not None else None
                rec.append(wire_decode(enc_r, s_r, x.dtype, bits))
            recv = jnp.concatenate(rec) if chunks > 1 else rec[0]
            recv = recv[:out.size].reshape(out.shape)
            # keep own copy if we are within `o` hops downstream of src
            came_from = (rank - o) % P_
            out = jnp.where(came_from == src_pod, recv, out)
        return out

    return jax.tree.map(leaf, cache)
