"""Scenario event DSL.

A scenario timeline is a list of ``at(step, event)`` entries; each
event mutates the simulated WAN, the controller, or the engine's
synthetic workload when its step comes up:

  * :class:`LinkDegrade` / :class:`LinkRestore` — scripted symmetric
    degradation of one named link (a congested submarine cable, a
    peering change); ``notify=True`` additionally tells the controller
    the topology changed (visible maintenance vs silent congestion).
  * :func:`flap` — degrade-then-restore convenience pair.
  * :class:`CrossTraffic` — background flows on a named link that
    contend in the water-filling but are never credited to the
    workload (Table 1's runtime-vs-static gap, on demand).
  * :class:`DiurnalCycle` — sinusoidal global BW modulation (the
    business-hours cycle of [38]).
  * :class:`Rescale` — elastic DC join/leave (§3.3.2).
  * :class:`ProviderShift` — per-DC provider factors change under the
    workload (§3.3.3); always a visible topology change.
  * :class:`SkewRamp` — data-skew weights ramp linearly over a window
    (§3.3.1).
  * :class:`Straggler` — multiply the synthetic step time for a window
    of steps (a slow host, not a slow network).

Fleet timelines (repro.fleet.scenario.FleetEngine) add cross-job
events:

  * :class:`JobArrive` / :class:`JobDepart` — a workload joins or
    leaves the shared WAN; the fleet re-arbitrates every survivor's
    budget/capacity envelope.
  * :class:`PriorityShift` — a job's fair-share weight changes (an SLO
    promotion, a batch job yielding to serving traffic).

The fleet events target the fleet engine only (they call
``eng.add_job`` / ``eng.remove_job`` / ``eng.set_priority``). Of the
events above, only the WAN-state ones (`LinkDegrade` / `LinkRestore`
with ``notify=False``, `CrossTraffic`, `DiurnalCycle`) work on both
engines; the workload events (`Rescale`, `SkewRamp`, `Straggler`,
`ProviderShift`, and ``notify=True``) drive the single-job engine's
synthetic workload/controller and are REJECTED by fleet timeline
validation (`repro.fleet.scenario.FLEET_EVENTS`).

Events name links by region pair; the engine resolves indices. All
events are frozen dataclasses so timelines are hashable and their
``describe()`` strings are stable across runs (part of the trace).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Tuple

__all__ = ["at", "flap", "Timed", "Event", "LinkDegrade", "LinkRestore",
           "CrossTraffic", "DiurnalCycle", "Rescale", "ProviderShift",
           "SkewRamp", "Straggler", "JobArrive", "JobDepart",
           "PriorityShift"]


@dataclass(frozen=True)
class Event:
    """Base event: `apply(engine)` mutates sim/controller/engine."""

    def apply(self, eng) -> None:               # pragma: no cover - abstract
        """Execute the event against the engine."""
        raise NotImplementedError

    def describe(self) -> str:
        """Stable one-line form (part of the trace bytes)."""
        args = ", ".join(f"{k}={v}" for k, v in vars(self).items())
        return f"{type(self).__name__}({args})"


@dataclass(frozen=True)
class Timed:
    """An event pinned to a timeline step (build with :func:`at`)."""

    step: int
    event: Event


def at(step: int, event: Event) -> Timed:
    """``at(step=K, event=...)`` — schedule an event on the timeline."""
    return Timed(int(step), event)


# ----------------------------------------------------------------------
# Link events
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class LinkDegrade(Event):
    """Scripted symmetric collapse of one link to `factor` x nominal."""
    pair: Tuple[str, str]
    factor: float
    notify: bool = False          # visible maintenance vs silent congestion

    def apply(self, eng) -> None:
        """Execute against the engine."""
        i, j = eng.link(self.pair)
        eng.sim.set_link_factor(i, j, self.factor)
        if self.notify:
            eng.controller.topology_changed()


@dataclass(frozen=True)
class LinkRestore(Event):
    """Restore a degraded link to nominal."""

    pair: Tuple[str, str]
    notify: bool = False

    def apply(self, eng) -> None:
        """Execute against the engine."""
        i, j = eng.link(self.pair)
        eng.sim.set_link_factor(i, j, 1.0)
        if self.notify:
            eng.controller.topology_changed()


def flap(step: int, pair: Tuple[str, str], factor: float,
         down_steps: int, notify: bool = True) -> List[Timed]:
    """A link flap: degrade at `step`, restore `down_steps` later."""
    return [at(step, LinkDegrade(pair, factor, notify)),
            at(step + down_steps, LinkRestore(pair, notify))]


@dataclass(frozen=True)
class CrossTraffic(Event):
    """`conns` background flows on the link (0 clears the burst)."""
    pair: Tuple[str, str]
    conns: float

    def apply(self, eng) -> None:
        """Execute against the engine."""
        i, j = eng.link(self.pair)
        eng.sim.set_background(i, j, self.conns)
        eng.sim.set_background(j, i, self.conns)


# ----------------------------------------------------------------------
# Cluster-wide events
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class DiurnalCycle(Event):
    """From this step on, all links swing by +-`amplitude` over
    `period` steps (peak at +period/4)."""
    amplitude: float
    period: int

    def apply(self, eng) -> None:
        """Execute against the engine."""
        eng.diurnal = (self.amplitude, self.period, eng.step)


@dataclass(frozen=True)
class Rescale(Event):
    """Elastic DC join/leave: re-plan for `n_pods` pods (§3.3.2)."""
    n_pods: int

    def apply(self, eng) -> None:
        """Execute against the engine."""
        eng.controller.rescale(
            self.n_pods, skew_w=eng.skew_for_pods(self.n_pods))


@dataclass(frozen=True)
class ProviderShift(Event):
    """Per-DC provider factors change (§3.3.3) — a visible migration,
    so the controller replans from scratch."""
    factors: Tuple[float, ...]

    def apply(self, eng) -> None:
        """Execute against the engine."""
        eng.sim.set_provider_factor(list(self.factors))
        eng.controller.topology_changed()


@dataclass(frozen=True)
class SkewRamp(Event):
    """Ramp the per-DC data-skew weights linearly to `weights` over
    `over` steps, starting now (§3.3.1)."""
    weights: Tuple[float, ...]
    over: int

    def apply(self, eng) -> None:
        """Execute against the engine."""
        eng.start_skew_ramp(self.weights, self.over)


# ----------------------------------------------------------------------
# Fleet events (repro.fleet.scenario.FleetEngine timelines)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class JobArrive(Event):
    """A new job joins the fleet (`job` is a repro.fleet JobSpec; typed
    loosely here to keep the DSL import-free of the fleet package)."""
    job: Any

    def apply(self, eng) -> None:
        """Execute against the engine."""
        eng.add_job(self.job)


@dataclass(frozen=True)
class JobDepart(Event):
    """A job leaves; its flows are withdrawn and survivors re-share."""
    name: str

    def apply(self, eng) -> None:
        """Execute against the engine."""
        eng.remove_job(self.name)


@dataclass(frozen=True)
class PriorityShift(Event):
    """A job's fair-share weight changes at runtime."""
    name: str
    priority: float

    def apply(self, eng) -> None:
        """Execute against the engine."""
        eng.set_priority(self.name, self.priority)


@dataclass(frozen=True)
class Straggler(Event):
    """Multiply the synthetic step time by `slowdown` for `duration`
    steps (a slow host; the network itself is untouched)."""
    slowdown: float
    duration: int = 1

    def apply(self, eng) -> None:
        """Execute against the engine."""
        eng.straggler_mult = self.slowdown
        eng.straggler_until = eng.step + self.duration
