"""Scenario engine: drives the full closed loop (simulator -> monitor
-> predictor -> global opt -> AIMD -> plan) through a scripted timeline
of WAN events and records a structured per-step trace.

Each step:

  1. apply the events scheduled ``at(step)`` (events.py);
  2. advance scripted processes (diurnal modulation, skew ramp) and the
     simulator's AR(1) fluctuation — the engine owns simulated time, so
     the controller runs with ``advance_sim=False``;
  3. measure the ground-truth achieved BW at the connection matrix in
     force and derive a synthetic step time (compute + ring transfer at
     the slowest pod hop), times any injected straggler slowdown;
  4. feed the step time to the straggler trigger and poll the periodic
     trigger (with the current skew weights);
  5. lower the plan through the controller's compile cache — a replan
     that oscillates back to a seen signature is a cache hit, not a
     rebuild;
  6. append a :class:`StepTrace` row (monitored vs predicted vs
     achieved BW, replans with reasons, plan signature, cache state).

Determinism: with the simulator's named RNG streams, the same spec and
seed replay to byte-identical traces (``ScenarioTrace.to_json()``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.control import ControllerConfig, WanifyController
from repro.core.predictor import SnapshotPredictor
from repro.faults.plane import FaultPlane, faults_mode
from repro.lifecycle.manager import LifecycleManager, lifecycle_mode
from repro.obs.spans import NULL_TRACER, SpanTracer, obs_mode
from repro.scenarios.events import Timed
from repro.scenarios.trace import (ScenarioResult, ScenarioTrace, StepTrace,
                                   sig_hash)
from repro.wan.simulator import WanSimulator, WaterfillDivergence


@dataclass
class ScenarioSpec:
    """A named, replayable stress scenario for the control plane."""
    name: str
    steps: int
    events: Tuple[Timed, ...] = ()
    description: str = ""
    n_pods: int = 4
    regions: Optional[List[str]] = None      # default: the 8-DC testbed
    sim_kwargs: Dict[str, Any] = field(default_factory=dict)
    cfg_kwargs: Dict[str, Any] = field(default_factory=dict)
    payload_mb: float = 256.0                # per-step ring payload
    compute_s: float = 0.5                   # non-network step time


class ScenarioEngine:
    """One deterministic run of a :class:`ScenarioSpec`."""

    def __init__(self, spec: ScenarioSpec, seed: int = 0,
                 predictor: Any = None, overlay: Optional[str] = None,
                 lifecycle: Any = None, obs: Optional[str] = None,
                 faults: Any = None):
        self.spec = spec
        self.seed = int(seed)
        sim_kw = dict(spec.sim_kwargs)
        if spec.regions is not None:
            sim_kw.setdefault("regions", list(spec.regions))
        self.sim = WanSimulator(seed=self.seed, **sim_kw)
        cfg_kw = dict(spec.cfg_kwargs)
        cfg_kw.pop("advance_sim", None)    # the engine owns simulated time
        cfg = ControllerConfig(advance_sim=False, **cfg_kw)
        pred_obj = predictor or SnapshotPredictor()
        # `lifecycle` gates the online predictor lifecycle
        # (repro.lifecycle): a ready LifecycleManager is used as-is; a
        # mode string / None resolves via $REPRO_LIFECYCLE (default
        # off = no manager, no lifecycle code, byte-identical replays)
        self.lifecycle: Optional[LifecycleManager] = None
        if isinstance(lifecycle, LifecycleManager):
            self.lifecycle = lifecycle
        elif lifecycle_mode(lifecycle) == "on":
            self.lifecycle = LifecycleManager(pred_obj, self.sim.N)
        # `overlay` gates Terra-style relay routing (None defers to
        # $REPRO_OVERLAY, default off): when on, the workload executes
        # at the controller's routed lowering — relay flows charged on
        # both hops, credited at the store-and-forward bottleneck
        self.controller = WanifyController(
            sim=self.sim, predictor=pred_obj,
            n_pods=spec.n_pods, cfg=cfg, overlay=overlay,
            lifecycle=self.lifecycle)
        # `obs` gates span tracing (repro.obs; None defers to
        # $REPRO_OBS, default off = the shared no-op tracer). Spans are
        # PASSIVE: they wrap the stages the loop already runs, in the
        # order it already runs them, so traces replay byte-identical
        # either way (pinned in tests/test_obs.py).
        self.tracer = NULL_TRACER
        if obs_mode(obs) == "on":
            self.tracer = SpanTracer()
            self.tracer.watch(self.sim.metrics)
            self.tracer.watch(self.controller.metrics)
            if self.lifecycle is not None:
                self.tracer.watch(self.lifecycle.metrics)
                self.tracer.watch(self.lifecycle.scheduler.metrics)
            self.controller.tracer = self.tracer
        # `faults` gates the fault plane (repro.faults): a ready
        # FaultPlane is used as-is; a mode string / None resolves via
        # $REPRO_FAULTS. "on" builds a graceful plane (the degradation
        # ladder). Under "off" a timeline that scripts fault events
        # still gets a plane — an UNGRACEFUL one (raw injection, no
        # ladder): the naive-crash ablation the chaos harness compares
        # against. Off + no fault events = no plane, no fault code,
        # byte-identical replays.
        self.faults: Optional[FaultPlane] = None
        if isinstance(faults, FaultPlane):
            self.faults = faults
        else:
            # imported lazily: faults.events subclasses the event DSL
            # of this package, so a module-level import would be
            # circular through repro.scenarios.__init__
            from repro.faults.events import FaultEvent
            mode = faults_mode(faults)
            if mode == "on" or any(isinstance(t.event, FaultEvent)
                                   for t in spec.events):
                self.faults = FaultPlane(self.sim.N,
                                         graceful=(mode == "on"),
                                         seed=self.seed)
        if self.faults is not None:
            self.controller.faults = self.faults
            if self.tracer is not NULL_TRACER:
                self.tracer.watch(self.faults.metrics)
        self._last_achieved: Optional[np.ndarray] = None
        self.step = 0
        # a per-step tap for ride-along harnesses (repro.placement):
        # called as step_hook(engine, step_trace_row) after each step's
        # trace row is appended; it must not mutate sim/controller state
        self.step_hook: Optional[Any] = None
        # scripted-process state (mutated by events)
        self.diurnal: Optional[Tuple[float, int, int]] = None
        self.straggler_mult = 1.0
        self.straggler_until = -1
        self._skew: Optional[np.ndarray] = None          # current weights
        self._skew_ramp: Optional[Tuple[np.ndarray, np.ndarray, int, int]] \
            = None                                       # (from, to, at, over)
        self._timeline: Dict[int, List[Timed]] = {}
        for t in spec.events:
            self._timeline.setdefault(t.step, []).append(t)

    # ------------------------------------------------------------------
    # Helpers the events call back into
    # ------------------------------------------------------------------
    def link(self, pair: Sequence[str]) -> Tuple[int, int]:
        """Resolve a (region, region) pair to simulator indices."""
        a, b = pair
        return self.sim.regions.index(a), self.sim.regions.index(b)

    def dc(self, region: str) -> int:
        """Resolve one region name to its simulator index (fault
        events target single DCs, not link pairs)."""
        return self.sim.regions.index(region)

    def start_skew_ramp(self, weights: Sequence[float], over: int) -> None:
        """Begin ramping the skew weights to `weights` over `over`
        steps (SkewRamp event target)."""
        # refit any previous skew to the new vector's length (neutral
        # weight for pods it did not cover) so ramps compose with
        # rescales of either direction
        start = np.ones(len(weights))
        if self._skew is not None:
            k = min(len(start), len(self._skew))
            start[:k] = self._skew[:k]
        self._skew_ramp = (start, np.asarray(weights, float), self.step,
                           max(1, int(over)))

    def skew_for_pods(self, n_pods: Optional[int] = None
                      ) -> Optional[np.ndarray]:
        """Current skew weights fitted to `n_pods` (default: the
        controller's current count; a Rescale event passes its target
        count). Pods that joined after the ramp started carry neutral
        weight."""
        if self._skew is None:
            return None
        P = self.controller.n_pods if n_pods is None else int(n_pods)
        w = np.ones(P)
        k = min(P, len(self._skew))
        w[:k] = self._skew[:k]
        return w

    # ------------------------------------------------------------------
    # The synthetic workload: one ring exchange per step
    # ------------------------------------------------------------------
    def _full_conns(self) -> np.ndarray:
        return self.controller.current_conns()

    def _ring_min_bw(self, achieved: np.ndarray) -> float:
        P = self.controller.n_pods
        if P < 2:
            return float("inf")
        return min(float(achieved[i, (i + 1) % P]) for i in range(P))

    def _step_time(self, achieved: np.ndarray) -> float:
        ring = max(self._ring_min_bw(achieved), 1e-6)
        dt = self.spec.compute_s + self.spec.payload_mb * 8.0 / ring
        if self.step < self.straggler_until:
            dt *= self.straggler_mult
        return dt

    # ------------------------------------------------------------------
    def _advance_scripted(self) -> None:
        if self.diurnal is not None:
            amp, period, start = self.diurnal
            phase = 2.0 * math.pi * (self.step - start) / max(period, 1)
            self.sim.modulation = 1.0 + amp * math.sin(phase)
        if self._skew_ramp is not None:
            w0, w1, at_step, over = self._skew_ramp
            frac = min(1.0, (self.step - at_step) / over)
            self._skew = w0 + (w1 - w0) * frac
            if frac >= 1.0:
                self._skew_ramp = None

    def _recover_divergence(self, k: int,
                            exc: WaterfillDivergence) -> np.ndarray:
        """Water-fill divergence at step `k`: graceful mode rolls the
        controller back to the last-known-good plan (fault-plane rung
        5) and retries; without a graceful plane the divergence
        propagates with scenario/step context attached."""
        fp = self.faults
        if fp is None or not fp.graceful:
            raise WaterfillDivergence(
                f"{exc} (scenario {self.spec.name!r}, step {k})") from exc
        ctl = self.controller
        with self.tracer.span("recover"):
            fp.note_rollback()
            ctl.rollback_plan(step=k)
            if not fp.solver_failing(k):
                # a genuine divergence: the rolled-back plan is known
                # to have executed — retry the fill on it
                try:
                    return self.sim.waterfill(self._full_conns())
                except WaterfillDivergence:
                    pass
            # solver still down (or the retry failed): freeze at the
            # last achieved surface — degraded, but alive
            if self._last_achieved is not None:
                return np.array(self._last_achieved, copy=True)
            return np.zeros((self.sim.N, self.sim.N))

    def run(self) -> ScenarioResult:
        """Drive the timeline to completion and return the trace."""
        ctl, sim, tr = self.controller, self.sim, self.tracer
        trace = ScenarioTrace(self.spec.name, self.seed)
        seen_records = len(ctl.record)
        # lower the initial plan once (the consumer's first compile)
        ctl.compiled((self.spec.name,), lambda p: p.signature())
        for k in range(self.spec.steps):
            self.step = k
            if self.faults is not None:
                self.faults.step = k     # fault windows key on loop time
            with tr.span("events"):
                applied = tuple(t.event.describe()
                                for t in self._timeline.get(k, ()))
                for t in self._timeline.get(k, ()):
                    t.event.apply(self)
                self._advance_scripted()
                sim.advance()

            with tr.span("waterfill", delta=True):
                conns = self._full_conns()
                routing = ctl.current_routing()
                try:
                    if self.faults is not None \
                            and self.faults.solver_failing(k):
                        raise WaterfillDivergence(
                            "injected water-fill divergence (SolverFault)")
                    if routing is None:
                        achieved = sim.waterfill(conns)
                    else:
                        # overlay in force: execute the routed lowering
                        # — the end-to-end credit on a relayed pair is
                        # what the ring consumer observes
                        achieved = sim.waterfill_routed(*routing)
                except WaterfillDivergence as exc:
                    achieved = self._recover_divergence(k, exc)
                    conns = self._full_conns()   # rollback changed them
            self._last_achieved = achieved
            with tr.span("control", delta=True):
                dt = self._step_time(achieved)
                ctl.observe_step_time(dt, step=k)
                ctl.maybe_replan(k, skew_w=self.skew_for_pods())
            # every plan in force goes through the compile cache: a
            # signature seen before is a hit, not a rebuild
            with tr.span("lower", delta=True):
                ctl.compiled((self.spec.name,), lambda p: p.signature())

            # sampled at the same matrix as `achieved`, so in a quiet
            # scenario monitored == achieved exactly, replan step or not
            meas_ok = True
            with tr.span("measure"):
                if self.faults is not None:
                    # the fault boundary: a monitor outage serves the
                    # last pre-outage sample, frozen, with ok=False so
                    # downstream learners skip the fossil tick
                    monitored, meas_ok = self.faults.measured(
                        ctl.monitor, conns)
                else:
                    monitored = ctl.monitor.measure(conns)
            if self.lifecycle is not None:
                # lifecycle tick before the trace row is cut, so a
                # drift-triggered refresh replan lands in this step's
                # `replans` (and its prediction in this step's columns)
                with tr.span("lifecycle", delta=True):
                    self.lifecycle.tick(k, ctl, sim, conns, achieved,
                                        monitored,
                                        measurement_ok=meas_ok)
            P = ctl.n_pods
            off = ~np.eye(P, dtype=bool)
            pred = ctl.last_pred[:P, :P]
            replans = tuple(
                {"reason": r["reason"], "step": r["step"],
                 "signature": sig_hash(r["signature"])}
                for r in ctl.record[seen_records:])
            seen_records = len(ctl.record)
            plan = ctl.plan
            trace.steps.append(StepTrace(
                step=k, events=applied, dt=float(dt),
                achieved_min=float(achieved[:P, :P][off].min()),
                achieved_mean=float(achieved[:P, :P][off].mean()),
                monitored_min=float(monitored[:P, :P][off].min()),
                monitored_mean=float(monitored[:P, :P][off].mean()),
                predicted_min=float(pred[off].min()),
                predicted_mean=float(pred[off].mean()),
                plan_sig=sig_hash(plan.signature()),
                n_pods=P,
                conns_total=int(sum(plan.conns[i][j]
                                    for i in range(P) for j in range(P)
                                    if i != j)),
                replans=replans,
                cache_builds=ctl.cache_builds,
                cache_hits=ctl.cache_hits,
            ))
            if self.step_hook is not None:
                self.step_hook(self, trace.steps[-1])
        return ScenarioResult(trace=trace, payload_mb=self.spec.payload_mb)


def run_scenario(spec: ScenarioSpec, seed: int = 0,
                 predictor: Any = None,
                 overlay: Optional[str] = None,
                 lifecycle: Any = None,
                 obs: Optional[str] = None,
                 faults: Any = None) -> ScenarioResult:
    """Build a fresh engine and run the scenario to completion
    (`overlay` gates relay routing, `lifecycle` the predictor
    lifecycle, `obs` span tracing, `faults` the fault plane; None
    defers to $REPRO_OVERLAY / $REPRO_LIFECYCLE / $REPRO_OBS /
    $REPRO_FAULTS)."""
    return ScenarioEngine(spec, seed=seed, predictor=predictor,
                          overlay=overlay, lifecycle=lifecycle,
                          obs=obs, faults=faults).run()
