"""Named scenario library — the paper's §5 settings as scripted,
replayable timelines. Each entry is a zero-argument builder so specs
are fresh (and independently mutable) per run.

The quiet scenarios (no fluctuation / observation noise) pin down
exact controller behavior — e.g. `flap` asserts the post-recovery plan
signature returns to the pre-flap one (a compile-cache hit); the noisy
ones (`diurnal`, `runtime_fluctuation`) exercise the loop under the
AR(1) dynamics of [38].
"""
from __future__ import annotations

from typing import Callable, Dict, List

from repro.scenarios.engine import ScenarioSpec
from repro.scenarios.events import (CrossTraffic, DiurnalCycle, LinkDegrade,
                                    ProviderShift, Rescale, SkewRamp,
                                    Straggler, at, flap)

QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0)

# Every scenario plans over the first 4 pods of the monitored 8-DC mesh
# (us-east, us-west, ap-south, ap-se): the ring hops mix near and far
# links, so plans react to both closeness classes.


def steady() -> ScenarioSpec:
    """§5.2 static baseline: no events; replans stay periodic-only."""
    return ScenarioSpec(
        name="steady", steps=40,
        description="static WAN; only init + periodic replans",
        sim_kwargs=dict(QUIET),
        cfg_kwargs=dict(replan_every=10))


def diurnal() -> ScenarioSpec:
    """Business-hours BW cycle ([38]): all links swing +-40%."""
    return ScenarioSpec(
        name="diurnal", steps=60,
        description="sinusoidal global BW cycle + mild AR(1) fluctuation",
        events=(at(0, DiurnalCycle(amplitude=0.4, period=30)),),
        sim_kwargs=dict(fluct_sigma=0.05, snapshot_sigma=0.02,
                        runtime_sigma=0.0),
        cfg_kwargs=dict(replan_every=5))


def runtime_fluctuation() -> ScenarioSpec:
    """Table 1's regime: pure AR(1) link fluctuation, snapshot noise."""
    return ScenarioSpec(
        name="runtime_fluctuation", steps=50,
        description="AR(1) fluctuation only; the predictor's home turf",
        sim_kwargs=dict(fluct_sigma=0.12, snapshot_sigma=0.08,
                        runtime_sigma=0.015),
        cfg_kwargs=dict(replan_every=5))


def congestion() -> ScenarioSpec:
    """Sudden cross-traffic burst on a ring hop: the step time spikes,
    the straggler trigger fires exactly once (cooldown outlasts the
    burst), AIMD backs off."""
    return ScenarioSpec(
        name="congestion", steps=30,
        description="cross-traffic burst on us-east<->us-west, steps 10-15",
        events=(at(10, CrossTraffic(("us-east", "us-west"), conns=64)),
                at(15, CrossTraffic(("us-east", "us-west"), conns=0))),
        sim_kwargs=dict(QUIET),
        cfg_kwargs=dict(replan_every=100, straggler_factor=2.0,
                        straggler_cooldown=30))


def link_flap() -> ScenarioSpec:
    """A link flaps (visible maintenance) and recovers: the post-
    recovery plan oscillates back to the pre-flap signature, so the
    consumer reuses the compiled step instead of re-lowering."""
    return ScenarioSpec(
        name="link_flap", steps=30,
        description="us-east<->us-west collapses 20x at step 10, "
                    "restores at step 20; plan-cache hit on recovery",
        events=tuple(flap(10, ("us-east", "us-west"), factor=0.05,
                          down_steps=10, notify=True)),
        sim_kwargs=dict(QUIET),
        cfg_kwargs=dict(replan_every=100))


def cable_cut() -> ScenarioSpec:
    """Silent persistent degradation (no notify): only the periodic
    trigger can discover it."""
    return ScenarioSpec(
        name="cable_cut", steps=40,
        description="ap-south<->ap-se silently collapses 50x at step 12",
        events=(at(12, LinkDegrade(("ap-south", "ap-se"), factor=0.02)),),
        sim_kwargs=dict(QUIET),
        cfg_kwargs=dict(replan_every=5))


def cable_cut_reroute() -> ScenarioSpec:
    """A silent cut on a FAR ring hop, staged for overlay routing
    (repro.overlay): us-west<->ap-south collapses 50x, so its direct
    path is pinned at the knee cap (8.5x a tiny degraded single-conn
    BW) no matter how many connections AIMD pumps — while one-hop
    detours (us-west -> us-east -> ap-south, or via ap-se) keep the
    healthy far-class capacity. With ``REPRO_OVERLAY=on`` (or
    ``run_scenario(..., overlay="on")``) the first post-cut replan
    routes around the cut and the pair's achieved BW recovers to the
    relay bottleneck — strictly better than direct-only, pinned in
    `tests/test_overlay.py` and tracked in BENCH_overlay.json. With
    the overlay off (the default) this replays the direct-only
    controller against the same weather."""
    return ScenarioSpec(
        name="cable_cut_reroute", steps=40,
        description="us-west<->ap-south silently collapses 50x at step "
                    "12; overlay=on relays around it via us-east/ap-se",
        events=(at(12, LinkDegrade(("us-west", "ap-south"), factor=0.02)),),
        sim_kwargs=dict(QUIET),
        cfg_kwargs=dict(replan_every=5))


def straggler_host() -> ScenarioSpec:
    """An injected slow host (§3.2.2): the straggler trigger forces an
    AIMD multiplicative decrease plus an immediate replan."""
    return ScenarioSpec(
        name="straggler_host", steps=30,
        description="4x step-time spike at step 15 for 2 steps",
        events=(at(15, Straggler(slowdown=4.0, duration=2)),),
        sim_kwargs=dict(QUIET),
        cfg_kwargs=dict(replan_every=100, straggler_factor=2.0,
                        straggler_cooldown=5))


def elastic() -> ScenarioSpec:
    """Elastic DC counts (§3.3.2 / §5.5): join two DCs, later leave."""
    return ScenarioSpec(
        name="elastic", steps=40,
        description="4 -> 6 pods at step 12, back to 4 at step 28",
        events=(at(12, Rescale(n_pods=6)), at(28, Rescale(n_pods=4))),
        sim_kwargs=dict(QUIET),
        cfg_kwargs=dict(replan_every=10))


def provider_shift() -> ScenarioSpec:
    """Provider heterogeneity shift (§3.3.3): half the DCs migrate to
    a provider with half the WAN capacity."""
    return ScenarioSpec(
        name="provider_shift", steps=30,
        description="DCs 0-3 shift to 0.5x provider at step 15",
        events=(at(15, ProviderShift(factors=(0.5, 0.5, 0.5, 0.5,
                                              1.0, 1.0, 1.0, 1.0))),),
        sim_kwargs=dict(QUIET),
        cfg_kwargs=dict(replan_every=10))


def provider_shift_drift() -> ScenarioSpec:
    """Provider shift staged for the predictor lifecycle
    (repro.lifecycle). The snapshot is NOISY (the paper's premise: a
    1-second sample is a rough sketch of stable runtime BW), so a
    forest fit on pre-shift operation learns to denoise via the stable
    per-pair features — knowledge the provider migration silently
    invalidates: post-shift it keeps predicting pre-shift BW (~2x
    high) no matter what the snapshot says. A longer post-shift tail
    measures recovery; link fluctuation and host noise stay off so the
    runs are deterministic per seed. With the lifecycle off this is a
    frozen-predictor replay; with it on, the drift detector catches
    the post-shift residual step from free observations, a couple of
    targeted full probes label the harvest window, and the refreshed
    forest recovers residual accuracy a frozen predictor never does —
    the headline pinned in tests/test_lifecycle.py and
    BENCH_lifecycle.json."""
    return ScenarioSpec(
        name="provider_shift_drift", steps=40,
        description="DCs 0-3 shift to 0.5x provider at step 15 under "
                    "noisy snapshots; lifecycle=on detects and refits",
        events=(at(15, ProviderShift(factors=(0.5, 0.5, 0.5, 0.5,
                                              1.0, 1.0, 1.0, 1.0))),),
        sim_kwargs=dict(fluct_sigma=0.0, snapshot_sigma=0.45,
                        runtime_sigma=0.0, host_sigma=0.0),
        cfg_kwargs=dict(replan_every=5))


def skew_ramp() -> ScenarioSpec:
    """Data skew ramps onto one DC (§3.3.1): its pairs earn a larger
    share of the connection budget."""
    return ScenarioSpec(
        name="skew_ramp", steps=40,
        description="DC 0's skew weight ramps 1 -> 4 over steps 10-20",
        events=(at(10, SkewRamp(weights=(4.0, 1.0, 1.0, 1.0), over=10)),),
        sim_kwargs=dict(QUIET),
        cfg_kwargs=dict(replan_every=5))


SCENARIOS: Dict[str, Callable[[], ScenarioSpec]] = {
    "steady": steady,
    "diurnal": diurnal,
    "runtime_fluctuation": runtime_fluctuation,
    "congestion": congestion,
    "link_flap": link_flap,
    "cable_cut": cable_cut,
    "cable_cut_reroute": cable_cut_reroute,
    "straggler_host": straggler_host,
    "elastic": elastic,
    "provider_shift": provider_shift,
    "provider_shift_drift": provider_shift_drift,
    "skew_ramp": skew_ramp,
}


def get_scenario(name: str) -> ScenarioSpec:
    """Fresh spec by name (KeyError lists the known names)."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(SCENARIOS)}")
    return SCENARIOS[name]()


def scenario_names() -> List[str]:
    """All named scenarios, library order."""
    return list(SCENARIOS)
