"""Structured per-step scenario trace + canonical serialization.

The trace is the determinism contract: two runs of the same scenario
with the same seed must produce byte-identical ``to_json()`` output —
same replan steps, same reasons, same plan signatures, same BW floats.
That holds because every random draw comes from the simulator's named
streams (see wan/simulator.py) and the engine performs the same calls
in the same order each run; nothing reads the wall clock.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Tuple


def sig_hash(signature: Any) -> str:
    """Short stable hash of a WanPlan.signature() tuple."""
    return hashlib.md5(repr(signature).encode()).hexdigest()[:12]


@dataclass
class StepTrace:
    """One engine step: what happened, what a per-step monitor sample
    shows (the engine's own iftop analogue, taken every step — the
    controller itself only measures on replans), what the controller
    believed at its last replan (predicted), and what the network
    actually delivered (achieved ground truth)."""
    step: int
    events: Tuple[str, ...]          # describe() of events applied now
    dt: float                        # synthetic step wall time (s)
    achieved_min: float              # over pod off-diagonal pairs, Mbps
    achieved_mean: float
    monitored_min: float
    monitored_mean: float
    predicted_min: float             # from the last replan's prediction
    predicted_mean: float
    plan_sig: str                    # sig_hash of the plan now in force
    n_pods: int
    conns_total: int                 # sum of the plan's off-diag conns
    replans: Tuple[Dict[str, Any], ...]   # {reason, step, signature} now
    cache_builds: int                # cumulative lowerings
    cache_hits: int                  # cumulative compile-cache reuses


@dataclass
class ScenarioTrace:
    """The whole run; `to_json()` is the byte-comparable replay form."""

    scenario: str
    seed: int
    steps: List[StepTrace] = field(default_factory=list)

    def to_json(self) -> str:
        """Canonical bytes for replay comparison (sorted keys, no
        whitespace drift)."""
        payload = {"scenario": self.scenario, "seed": self.seed,
                   "steps": [asdict(s) for s in self.steps]}
        return json.dumps(payload, sort_keys=True,
                          separators=(",", ":"))

    # ---- convenience views ------------------------------------------
    def replan_steps(self, reason: str | None = None) -> List[int]:
        """Steps that replanned (optionally only for one reason)."""
        return [s.step for s in self.steps for r in s.replans
                if reason is None or r["reason"] == reason]

    def replan_reasons(self) -> List[str]:
        """Every replan reason, in trace order."""
        return [r["reason"] for s in self.steps for r in s.replans]

    def signatures(self) -> List[str]:
        """The in-force plan signature hash per step."""
        return [s.plan_sig for s in self.steps]


@dataclass
class ScenarioResult:
    """A completed run plus summary helpers."""

    trace: ScenarioTrace
    payload_mb: float                # per-step ring payload

    def summary(self) -> Dict[str, Any]:
        """Roll the trace up into the benchmark-row dict."""
        steps = self.trace.steps
        reasons: Dict[str, int] = {}
        for r in self.trace.replan_reasons():
            reasons[r] = reasons.get(r, 0) + 1
        total_dt = sum(s.dt for s in steps)
        return {
            "scenario": self.trace.scenario,
            "seed": self.trace.seed,
            "steps": len(steps),
            "replans": reasons,
            "throughput_mbps": (len(steps) * self.payload_mb * 8.0
                                / max(total_dt, 1e-9)),
            "achieved_min_mbps": min(s.achieved_min for s in steps),
            "achieved_mean_mbps": (sum(s.achieved_mean for s in steps)
                                   / len(steps)),
            "distinct_plans": len(set(self.trace.signatures())),
            "cache_builds": steps[-1].cache_builds,
            "cache_hits": steps[-1].cache_hits,
        }
