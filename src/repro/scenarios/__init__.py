"""repro.scenarios — scripted WAN dynamics + deterministic replay.

A scenario is a timeline of WAN events (`events.py` DSL) driven
through the full closed loop by `engine.py`; `library.py` names ~10
timelines reproducing the paper's §5 settings, and `trace.py` defines
the per-step trace whose canonical JSON is byte-identical across
same-seed replays. See DESIGN.md ("The scenario engine").
"""
from repro.scenarios.engine import (ScenarioEngine, ScenarioSpec,
                                    run_scenario)
from repro.scenarios.events import (CrossTraffic, DiurnalCycle, JobArrive,
                                    JobDepart, LinkDegrade, LinkRestore,
                                    PriorityShift, ProviderShift, Rescale,
                                    SkewRamp, Straggler, at, flap)
from repro.scenarios.library import SCENARIOS, get_scenario, scenario_names
from repro.scenarios.trace import (ScenarioResult, ScenarioTrace, StepTrace,
                                   sig_hash)

__all__ = [
    "ScenarioEngine", "ScenarioSpec", "run_scenario",
    "ScenarioResult", "ScenarioTrace", "StepTrace", "sig_hash",
    "SCENARIOS", "get_scenario", "scenario_names",
    "at", "flap", "LinkDegrade", "LinkRestore", "CrossTraffic",
    "DiurnalCycle", "Rescale", "ProviderShift", "SkewRamp", "Straggler",
    "JobArrive", "JobDepart", "PriorityShift",
]
