"""Static global optimization (paper §3.2.1, Eq. 2-3).

Converts predicted runtime BWs into an optimal RANGE of heterogeneous
connection counts per DC pair — weak/distant links get more parallel
connections from each DC's limited per-host budget M; achievable BW is
modelled as (predicted single-connection BW x connections), which the
paper validates empirically ("runtime BW grows linearly with the
connections").

Paper worked example (tested in tests/test_global_opt.py):
  DC_rel={1,2,3;2,1,3;3,3,1}, M=8 -> minCons all ones,
  maxCons (formula, before diagonal override) = {3,6,8;6,3,8;8,8,3}.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.relations import infer_dc_relations


@dataclass
class GlobalPlan:
    pred_bw: np.ndarray        # [N,N] predicted runtime BW (Mbps)
    dc_rel: np.ndarray         # [N,N] closeness indices
    min_cons: np.ndarray       # [N,N] int
    max_cons: np.ndarray       # [N,N] int
    min_bw: np.ndarray         # [N,N] achievable @ min_cons
    max_bw: np.ndarray         # [N,N] achievable @ max_cons
    throttle: np.ndarray       # [N,N] per-link BW cap (inf = uncapped)

    @property
    def n(self) -> int:
        return self.pred_bw.shape[0]


def _pair_weights(N: int, w_s: Optional[np.ndarray]) -> np.ndarray:
    """Skew weights (§3.3.1): per-DC data-volume weights -> pair weights,
    normalized to mean 1 so the total connection budget is preserved."""
    if w_s is None:
        return np.ones((N, N))
    w = np.asarray(w_s, np.float64)
    pair = np.maximum(w[:, None], w[None, :])
    off = ~np.eye(N, dtype=bool)
    pair = pair / pair[off].mean()
    return pair


def _refactor(N: int, r_vec: Optional[np.ndarray]) -> np.ndarray:
    """Provider/VM heterogeneity (§3.3.3): per-DC factors -> pairwise
    geometric-mean matrix (default all ones)."""
    if r_vec is None:
        return np.ones((N, N))
    r = np.asarray(r_vec, np.float64)
    if r.ndim == 2:
        return r
    return np.sqrt(r[:, None] * r[None, :])


def global_optimize(pred_bw: np.ndarray, *, M: int = 8, D: float = 100.0,
                    w_s: Optional[np.ndarray] = None,
                    r_vec: Optional[np.ndarray] = None,
                    throttle_enabled: bool = True,
                    dc_rel: Optional[np.ndarray] = None) -> GlobalPlan:
    """pred_bw: [N,N] predicted runtime BW; M: per-host max parallel
    connections; D: min significant BW difference (Algorithm 1 input)."""
    bw = np.asarray(pred_bw, np.float64)
    N = bw.shape[0]
    rel = infer_dc_relations(bw, D) if dc_rel is None else np.asarray(dc_rel)
    ws = _pair_weights(N, w_s)
    rv = _refactor(N, r_vec)

    # Eq. 2
    sum_all = float(rel.sum() - N)                 # skip closeness-1 diagonal
    max_r = rel.max(axis=1).astype(np.float64)     # row-wise maxima

    # Eq. 3
    min_candidate = np.floor(rel / sum_all * (M - 1))
    min_cons = np.maximum(min_candidate, 1.0) * ws
    max_cons = np.ceil(M * rel / max_r[:, None]) * ws
    np.fill_diagonal(min_cons, 1.0)
    np.fill_diagonal(max_cons, 1.0)                # single conn within a DC
    min_cons = np.clip(np.rint(min_cons), 1, 2 * M).astype(np.int64)
    max_cons = np.clip(np.rint(max_cons), 1, 2 * M).astype(np.int64)
    max_cons = np.maximum(max_cons, min_cons)

    min_bw = bw * min_cons * rv
    max_bw = bw * max_cons * rv

    # Throttling (§3.2.2): cap BW-rich destinations at the row mean of
    # achievable BW so distant pairs can use the shared NIC capacity.
    throttle = np.full((N, N), np.inf)
    if throttle_enabled and N > 1:
        off = ~np.eye(N, dtype=bool)
        for i in range(N):
            T = max_bw[i][off[i]].mean()
            rich = max_bw[i] > T
            rich[i] = False
            throttle[i][rich] = T
    return GlobalPlan(bw, rel, min_cons, max_cons, min_bw, max_bw, throttle)
