"""Static global optimization (paper §3.2.1, Eq. 2-3).

Converts predicted runtime BWs into an optimal RANGE of heterogeneous
connection counts per DC pair — weak/distant links get more parallel
connections from each DC's limited per-host budget M; achievable BW is
modelled as (predicted single-connection BW x connections), which the
paper validates empirically ("runtime BW grows linearly with the
connections").

Paper worked example (tested in tests/test_global_opt.py):
  DC_rel={1,2,3;2,1,3;3,3,1}, M=8 -> minCons all ones,
  maxCons (formula, before diagonal override) = {3,6,8;6,3,8;8,8,3}.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.relations import infer_dc_relations


@dataclass
class GlobalPlan:
    """Eq. 2-3 output: per-pair connection RANGES plus the achievable
    BW at each end of the range and the §3.2.2 throttle caps."""

    pred_bw: np.ndarray        # [N,N] predicted runtime BW (Mbps)
    dc_rel: np.ndarray         # [N,N] closeness indices
    min_cons: np.ndarray       # [N,N] int
    max_cons: np.ndarray       # [N,N] int
    min_bw: np.ndarray         # [N,N] achievable @ min_cons
    max_bw: np.ndarray         # [N,N] achievable @ max_cons
    throttle: np.ndarray       # [N,N] per-link BW cap (inf = uncapped)

    @property
    def n(self) -> int:
        """Number of DCs the plan covers."""
        return self.pred_bw.shape[0]


def _pair_weights(N: int, w_s: Optional[np.ndarray]) -> np.ndarray:
    """Skew weights (§3.3.1): per-DC data-volume weights -> pair weights,
    normalized to mean 1 so the total connection budget is preserved."""
    if w_s is None:
        return np.ones((N, N))
    w = np.asarray(w_s, np.float64)
    pair = np.maximum(w[:, None], w[None, :])
    off = ~np.eye(N, dtype=bool)
    pair = pair / pair[off].mean()
    return pair


def _refactor(N: int, r_vec: Optional[np.ndarray]) -> np.ndarray:
    """Provider/VM heterogeneity (§3.3.3): per-DC factors -> pairwise
    geometric-mean matrix (default all ones)."""
    if r_vec is None:
        return np.ones((N, N))
    r = np.asarray(r_vec, np.float64)
    if r.ndim == 2:
        return r
    return np.sqrt(r[:, None] * r[None, :])


def split_budget(M: int, weights: np.ndarray) -> np.ndarray:
    """Weighted fair-share of a per-host connection budget M across
    tenants (fleet arbitration): largest-remainder apportionment of
    ``M * w_j / sum(w)`` with a floor of one connection per tenant.

    Invariants (tested): every share >= 1; ``sum(shares) <= M``
    whenever ``M >= len(weights)`` (a host's connection table is never
    oversubscribed); shares are monotone in weight.
    """
    w = np.asarray(weights, np.float64)
    J = len(w)
    if J == 0:
        return np.zeros(0, np.int64)
    w = np.maximum(w, 1e-9)
    if M <= J:
        return np.ones(J, np.int64)        # floor dominates; may equal M=J
    quota = M * w / w.sum()
    share = np.floor(quota).astype(np.int64)
    frac = quota - share
    # stable largest-remainder: ties break toward the earlier tenant
    order = np.argsort(-frac, kind="stable")
    share[order[:M - int(share.sum())]] += 1
    share = np.maximum(share, 1)
    while share.sum() > M:                 # repay the floor bumps
        rich = int(np.argmax(share))
        if share[rich] <= 1:
            break
        share[rich] -= 1
    return share


def relay_candidates(dc_rel: np.ndarray, i: int, j: int,
                     max_candidates: int = 4) -> List[int]:
    """Closeness-pruned one-hop relay candidates for the pair (i, j)
    (the overlay router's bounded search, `repro.overlay.routing`).

    A DC k qualifies when BOTH hops i->k and k->j sit in a closeness
    class at least as near as the direct pair's (Algorithm 1 indices:
    smaller = closer) — a relay whose hops are farther than the link it
    bypasses can't beat it under the distance-monotone BW model, so it
    is never scored. Candidates are ordered nearest classes first
    (ties toward the lower DC index) and truncated to `max_candidates`.
    """
    rel = np.asarray(dc_rel)
    P = rel.shape[0]
    out = [k for k in range(P)
           if k != i and k != j
           and rel[i, k] <= rel[i, j] and rel[k, j] <= rel[i, j]]
    out.sort(key=lambda k: (int(rel[i, k]) + int(rel[k, j]), k))
    return out[:max_candidates]


def global_optimize(pred_bw: np.ndarray, *, M: int = 8, D: float = 100.0,
                    w_s: Optional[np.ndarray] = None,
                    r_vec: Optional[np.ndarray] = None,
                    throttle_enabled: bool = True,
                    dc_rel: Optional[np.ndarray] = None,
                    link_cap: Optional[np.ndarray] = None) -> GlobalPlan:
    """pred_bw: [N,N] predicted runtime BW; M: per-host max parallel
    connections; D: min significant BW difference (Algorithm 1 input).

    `link_cap` is an externally arbitrated per-link BW ceiling [N,N]
    (np.inf = uncapped) — a fleet controller's fair-share envelope. It
    clamps `max_cons` (budget spent past the cap buys nothing) and
    joins the §3.2.2 throttle, so a capped plan never targets more
    than its credited share.
    """
    bw = np.asarray(pred_bw, np.float64)
    N = bw.shape[0]
    rel = infer_dc_relations(bw, D) if dc_rel is None else np.asarray(dc_rel)
    ws = _pair_weights(N, w_s)
    rv = _refactor(N, r_vec)

    # Eq. 2
    sum_all = float(rel.sum() - N)                 # skip closeness-1 diagonal
    max_r = rel.max(axis=1).astype(np.float64)     # row-wise maxima

    # Eq. 3
    min_candidate = np.floor(rel / sum_all * (M - 1))
    min_cons = np.maximum(min_candidate, 1.0) * ws
    max_cons = np.ceil(M * rel / max_r[:, None]) * ws
    np.fill_diagonal(min_cons, 1.0)
    np.fill_diagonal(max_cons, 1.0)                # single conn within a DC
    min_cons = np.clip(np.rint(min_cons), 1, 2 * M).astype(np.int64)
    max_cons = np.clip(np.rint(max_cons), 1, 2 * M).astype(np.int64)
    max_cons = np.maximum(max_cons, min_cons)

    if link_cap is not None:
        lc = np.asarray(link_cap, np.float64)
        capped = np.isfinite(lc) & ~np.eye(N, dtype=bool)
        # connections past ceil(cap / unit_bw) cannot raise credited BW
        cap_cons = np.ceil(lc / np.maximum(bw * rv, 1e-9))
        cap_cons = np.maximum(np.where(capped, cap_cons, max_cons), 1)
        cap_cons = np.minimum(cap_cons, 2 * M)     # int-safe ceiling
        max_cons = np.minimum(max_cons, cap_cons.astype(np.int64))
        max_cons = np.maximum(max_cons, 1)
        min_cons = np.minimum(min_cons, max_cons)

    min_bw = bw * min_cons * rv
    max_bw = bw * max_cons * rv

    # Throttling (§3.2.2): cap BW-rich destinations at the row mean of
    # achievable BW so distant pairs can use the shared NIC capacity.
    # Vectorized over rows; max_bw[off].reshape(N, N-1) keeps each
    # row's off-diagonal entries contiguous in the historical order,
    # so the row means are bit-identical to the per-row loop.
    throttle = np.full((N, N), np.inf)
    if throttle_enabled and N > 1:
        off = ~np.eye(N, dtype=bool)
        T = max_bw[off].reshape(N, N - 1).mean(axis=1)
        rich = off & (max_bw > T[:, None])
        throttle[rich] = np.broadcast_to(T[:, None], (N, N))[rich]
    if link_cap is not None:
        off = ~np.eye(N, dtype=bool)
        throttle[off] = np.minimum(throttle, np.asarray(link_cap,
                                                        np.float64))[off]
    return GlobalPlan(bw, rel, min_cons, max_cons, min_bw, max_bw, throttle)
