"""repro.core — the paper's algorithms: plans, Eq. 2-3 global
optimization (+ fleet budget splitting), §3.2.2 AIMD local agents,
Algorithm-1 closeness inference, the §3.1 Random Forest and feature
assembly, and the scheduled cross-pod all-reduce (wansync)."""
