"""Dynamic local optimization (paper §3.2.2): per-VM AIMD agent.

Each worker starts at the MAXIMUM of the global optimizer's range and
adapts between [min, max] using Additive-Increase / Multiplicative-
Decrease driven by lightweight monitoring (iftop analogue):

  * monitored BW significantly below target (Delta > 100 Mbps) =>
    congestion: halve connections & target BW (not below the minimum)
  * monitored ~ target => additive: +1 connection, +1 linear BW unit
  * transfers < 1 MB skip the toggle entirely (negligible utilization)

Throttling caps BW-rich destinations at the row-mean threshold T.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.global_opt import GlobalPlan

SIGNIFICANT_MBPS = 100.0          # [13, 24] in the paper
MIN_TRANSFER_BYTES = 1 << 20      # 1 MB


@dataclass
class AimdAgent:
    """Local agent for one source DC (one VM)."""
    src: int
    min_cons: np.ndarray          # [N]
    max_cons: np.ndarray          # [N]
    min_bw: np.ndarray            # [N]
    max_bw: np.ndarray            # [N]
    unit_bw: np.ndarray           # [N] predicted per-connection BW
    throttle: np.ndarray          # [N] cap (inf = none)
    cons: np.ndarray = field(init=False)
    target_bw: np.ndarray = field(init=False)
    epochs: int = field(default=0, init=False)

    def __post_init__(self):
        # start from maximum throughput (reduces RTT bias — paper's
        # motivation for AIMD from the top)
        self.cons = self.max_cons.astype(np.int64).copy()
        self.target_bw = np.minimum(self.max_bw, self.throttle).copy()

    @classmethod
    def from_plan(cls, plan: GlobalPlan, src: int) -> "AimdAgent":
        """Build the agent for source DC `src` from a global plan's
        ranges (copies, so later replans don't mutate a live agent)."""
        return cls(
            src=src,
            min_cons=plan.min_cons[src].copy(),
            max_cons=plan.max_cons[src].copy(),
            min_bw=plan.min_bw[src].copy(),
            max_bw=plan.max_bw[src].copy(),
            unit_bw=plan.pred_bw[src].copy(),
            throttle=plan.throttle[src].copy(),
        )

    # ------------------------------------------------------------------
    def step(self, monitored_bw: np.ndarray,
             transfer_bytes: Optional[np.ndarray] = None,
             delta: float = SIGNIFICANT_MBPS) -> None:
        """One local-optimizer epoch (the paper uses 5-second epochs)."""
        self.epochs += 1
        N = len(self.cons)
        for j in range(N):
            if j == self.src:
                continue
            if transfer_bytes is not None and \
                    transfer_bytes[j] < MIN_TRANSFER_BYTES:
                continue                          # skip toggle (<1MB)
            cap = min(self.max_bw[j], self.throttle[j])
            if monitored_bw[j] < self.target_bw[j] - delta:
                # multiplicative decrease: half or minimum, whichever higher
                self.cons[j] = max(int(self.min_cons[j]), self.cons[j] // 2)
                self.target_bw[j] = max(self.min_bw[j], self.target_bw[j] / 2)
            elif abs(monitored_bw[j] - self.target_bw[j]) <= delta:
                # additive increase up to the global max / throttle cap
                self.cons[j] = min(int(self.max_cons[j]), self.cons[j] + 1)
                self.target_bw[j] = min(cap, self.target_bw[j] + self.unit_bw[j])
            # else: monitored far ABOVE target — leave state (stale target
            # will catch up via additive mode next epoch)
            self.target_bw[j] = float(np.clip(self.target_bw[j],
                                              self.min_bw[j], cap))


def run_agents(plan: GlobalPlan, monitor_fn, steps: int,
               transfer_bytes: Optional[np.ndarray] = None):
    """Drive one agent per DC for `steps` epochs.

    monitor_fn(conns [N,N]) -> monitored BW matrix [N,N]; returns the
    final connection matrix and the per-epoch target-BW history (the
    Fig. 9 trace).
    """
    N = plan.n
    agents = [AimdAgent.from_plan(plan, i) for i in range(N)]
    history = []
    conns = plan.max_cons.copy()
    for _ in range(steps):
        mon = monitor_fn(conns)
        for i, ag in enumerate(agents):
            tb = transfer_bytes[i] if transfer_bytes is not None else None
            ag.step(mon[i], tb)
            conns[i] = ag.cons
        history.append(np.stack([ag.target_bw.copy() for ag in agents]))
    return conns, np.asarray(history)
