"""WanPlan — the bridge from the paper's connection matrices to the TPU
cross-pod collective schedule, plus the Eq. 1 monitoring cost model.

The plan carries, per pod-pair (the "DC pair"), the heterogeneous stream
multiplicity (the "parallel connections") and the compression bits
chosen from predicted link bandwidth (SAGQ-style, §5.6). wansync.py
consumes `ring_chunks` to build the chunked ppermute schedule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple


from repro.core.global_opt import GlobalPlan


@dataclass(frozen=True)
class WanPlan:
    """The frozen transfer plan consumers lower to the wire: per-pair
    stream multiplicities, predicted BW, and per-hop wire bits.
    `signature()` is the compile-cache identity."""

    n_pods: int
    conns: Tuple[Tuple[int, ...], ...]      # [P,P] stream multiplicity
    pred_bw: Tuple[Tuple[float, ...], ...]  # [P,P] Mbps (predicted runtime)
    compress_bits: Tuple[int, ...]          # per ring-hop quantization bits
    # ring hop i sends pod i -> pod (i+1) % P
    # the frozen (threshold, bits) policy the per-hop bits were picked
    # with: `offset_bits()` must use the SAME policy, or a custom-
    # policy plan's signature would mix per-hop bits from one policy
    # with per-offset bits from the default
    bits_policy: Tuple[Tuple[float, int], ...] = None  # type: ignore

    def __post_init__(self):
        if self.bits_policy is None:
            object.__setattr__(self, "bits_policy",
                               freeze_bits_policy(None))

    @classmethod
    def from_global(cls, plan: GlobalPlan, *, use_max: bool = True,
                    bits_policy: Optional[dict] = None) -> "WanPlan":
        """Freeze a GlobalPlan at one end of its range (max by
        default — the paper starts AIMD from maximum throughput) and
        pick per-hop compression bits from predicted BW. The policy is
        stored on the plan so `offset_bits()` quantizes with the same
        thresholds."""
        cons = plan.max_cons if use_max else plan.min_cons
        P = plan.n
        bits = []
        for i in range(P):
            j = (i + 1) % P
            bits.append(pick_bits(plan.pred_bw[i][j], bits_policy))
        return cls(
            n_pods=P,
            conns=tuple(tuple(int(v) for v in row) for row in cons),
            pred_bw=tuple(tuple(float(v) for v in row) for row in plan.pred_bw),
            compress_bits=tuple(bits),
            bits_policy=freeze_bits_policy(bits_policy),
        )

    @classmethod
    def uniform(cls, n_pods: int, conns: int = 1, bits: int = 32) -> "WanPlan":
        """Paper baseline: single connection (or uniform-k), no compression."""
        c = tuple(tuple(conns if i != j else 1 for j in range(n_pods))
                  for i in range(n_pods))
        bw = tuple(tuple(1000.0 for _ in range(n_pods)) for _ in range(n_pods))
        return cls(n_pods, c, bw, tuple(bits for _ in range(n_pods)))

    # ------------------------------------------------------------------
    def ring_chunks(self) -> List[int]:
        """Stream multiplicity per ring hop (pod i -> i+1). This is the
        WANify heterogeneous-connections knob: more chunks on weak hops
        => more in-flight pipelined transfers on that link."""
        P = self.n_pods
        return [max(1, self.conns[i][(i + 1) % P]) for i in range(P)]

    def max_ring_chunks(self) -> int:
        """Largest hop multiplicity (sizes shared pipeline buffers)."""
        return max(self.ring_chunks()) if self.n_pods > 1 else 1

    def offset_bits(self) -> Tuple[int, ...]:
        """Wire bits per offset class (offset o exchanges pod
        i <-> (i+o) % P): quantization chosen from the weakest predicted
        link in the class, under the SAME frozen policy the per-hop
        `compress_bits` were picked with (a custom `from_global(bits_
        policy=...)` used to fall back to the default here, yielding a
        signature whose two bit sets disagreed). The schedule lowering
        consumes this, so it must be part of the compile-cache
        identity."""
        P = self.n_pods
        pol = dict(self.bits_policy)
        return tuple(
            pick_bits(min(self.pred_bw[i][(i + o) % P] for i in range(P)),
                      pol)
            for o in range(1, P))

    def signature(self) -> Tuple:
        """Hashable identity for jit-cache keying when the controller
        re-plans. Covers everything the lowered collective depends on:
        connection counts (chunk multiplicities) and wire bits are
        compile-time constants."""
        return (self.n_pods, self.conns, self.compress_bits,
                self.offset_bits())


DEFAULT_BITS_POLICY: dict = {200.0: 8, 600.0: 16, float("inf"): 32}


def freeze_bits_policy(policy: Optional[dict]) -> Tuple[Tuple[float, int],
                                                        ...]:
    """A policy dict as the hashable sorted (threshold, bits) tuple a
    frozen :class:`WanPlan` stores (None = the default policy)."""
    pol = DEFAULT_BITS_POLICY if policy is None else policy
    return tuple(sorted((float(t), int(b)) for t, b in pol.items()))


def pick_bits(link_bw_mbps: float, policy: Optional[dict] = None) -> int:
    """BW-aware gradient-compression bits (SAGQ analogue): weaker link =>
    fewer bits. Thresholds in Mbps; a BW above every threshold (a
    policy without the ``inf`` sentinel) falls back to full 32-bit."""
    pol = policy or DEFAULT_BITS_POLICY
    for thr in sorted(pol):
        if link_bw_mbps <= thr:
            return pol[thr]
    return 32


# ----------------------------------------------------------------------
# Eq. 1 — annual monitoring cost:  O x N x (x*y + z)
# ----------------------------------------------------------------------
def monitoring_cost(O: float, N: int, x: float, y: float, z: float) -> float:
    """O: occurrences/year, N: nodes, x: $/instance-second,
    y: seconds/measurement, z: $/instance network cost per measurement."""
    return O * N * (x * y + z)


def prediction_cost(O: float, N: int, x: float, z_snapshot: float,
                    train_cost: float = 0.0) -> float:
    """Snapshot-based prediction: y shrinks to ~1 s and z to the snapshot
    traffic; training is a one-off amortized cost."""
    return O * N * (x * 1.0 + z_snapshot) + train_cost
