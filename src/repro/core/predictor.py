"""Runtime-BW prediction (paper §3.1): Table-3 feature assembly + forest
inference. Inference has three interchangeable backends:
  numpy  — RandomForest.predict (training-side)
  jnp    — forest_predict_jnp (jit-able, used inside controllers)
  pallas — kernels.rf_predict (TPU kernel; validated vs the jnp oracle)
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.forest import RandomForest

FEATURE_NAMES = ("n_dcs", "snapshot_bw", "mem_util", "cpu_load",
                 "retransmissions", "distance_miles")


def assemble_features_loop(n_dcs: int, snap_bw: np.ndarray,
                           mem_util: np.ndarray, cpu_load: np.ndarray,
                           retrans: np.ndarray,
                           dist: np.ndarray) -> np.ndarray:
    """Reference double-loop form of :func:`assemble_features` (the
    historical implementation, kept as the bit-identity test oracle)."""
    N = snap_bw.shape[0]
    rows = []
    for i in range(N):
        for j in range(N):
            if i == j:
                continue
            rows.append([n_dcs, snap_bw[i, j], mem_util[j], cpu_load[i],
                         retrans[i, j], dist[i, j]])
    return np.asarray(rows, np.float32)


def assemble_features(n_dcs: int, snap_bw: np.ndarray, mem_util: np.ndarray,
                      cpu_load: np.ndarray, retrans: np.ndarray,
                      dist: np.ndarray) -> np.ndarray:
    """Vectorize Table 3 into per-pair rows.

    snap_bw/retrans/dist: [N,N]; mem_util (receiver)/cpu_load (sender): [N].
    Returns X [N*(N-1), 6] for all ordered off-diagonal pairs, in
    row-major (i, j) order skipping the diagonal — bit-identical to
    :func:`assemble_features_loop` (this is the per-tick AND harvest
    hot path, so it builds the [N,N,6] block in one shot and masks the
    diagonal instead of appending N*(N-1) Python lists)."""
    snap_bw = np.asarray(snap_bw)
    N = snap_bw.shape[0]
    block = np.empty((N, N, 6), np.float64)
    block[:, :, 0] = float(n_dcs)
    block[:, :, 1] = snap_bw
    block[:, :, 2] = np.asarray(mem_util)[None, :]       # receiver j
    block[:, :, 3] = np.asarray(cpu_load)[:, None]       # sender i
    block[:, :, 4] = np.asarray(retrans)
    block[:, :, 5] = np.asarray(dist)
    off = ~np.eye(N, dtype=bool)
    return block[off].astype(np.float32)


def matrix_from_pairs_loop(vals: np.ndarray, N: int,
                           diag: float = 0.0) -> np.ndarray:
    """Reference loop form of :func:`matrix_from_pairs` (test oracle)."""
    out = np.full((N, N), diag, np.float64)
    k = 0
    for i in range(N):
        for j in range(N):
            if i != j:
                out[i, j] = vals[k]
                k += 1
    return out


def matrix_from_pairs(vals: np.ndarray, N: int,
                      diag: float = 0.0) -> np.ndarray:
    """Inverse of `assemble_features`'s row order: fold N*(N-1)
    per-pair values back into an [N,N] matrix with `diag` filled in
    (one boolean-mask scatter; bit-identical to
    :func:`matrix_from_pairs_loop`, whose row-major order the mask
    indexing reproduces)."""
    out = np.full((N, N), diag, np.float64)
    out[~np.eye(N, dtype=bool)] = np.asarray(vals, np.float64)
    return out


# ----------------------------------------------------------------------
# jit-able forest inference over the complete-binary-tree layout
# ----------------------------------------------------------------------
def forest_predict_jnp(feat: jax.Array, thr: jax.Array, leaf: jax.Array,
                       X: jax.Array, depth: int) -> jax.Array:
    """feat [T, 2^d-1] int32, thr [T, 2^d-1] f32, leaf [T, 2^d] f32,
    X [n, F] -> [n] predictions. `depth` gather steps, no control flow."""
    T = feat.shape[0]
    n = X.shape[0]
    node = jnp.zeros((T, n), jnp.int32)
    tidx = jnp.arange(T)[:, None]
    for _ in range(depth):
        f = feat[tidx, node]                      # [T,n]
        t = thr[tidx, node]
        fx = jnp.where(f < 0, 0, f)
        xv = jnp.take_along_axis(
            jnp.broadcast_to(X.T[None], (T,) + X.T.shape),
            fx[:, None, :], axis=1)[:, 0, :]
        go_right = xv > t
        node = 2 * node + 1 + go_right.astype(jnp.int32)
    leaf_idx = node - (2 ** depth - 1)
    vals = jnp.take_along_axis(leaf, leaf_idx, axis=1)
    return jnp.mean(vals, axis=0)


@dataclass
class BwPredictor:
    """End-to-end: snapshot features -> predicted runtime BW matrix."""
    forest: RandomForest

    def predict_matrix(self, n_dcs: int, snap_bw: np.ndarray,
                       mem_util: np.ndarray, cpu_load: np.ndarray,
                       retrans: np.ndarray, dist: np.ndarray,
                       intra_dc_bw: float = 10000.0,
                       backend: str = "numpy") -> np.ndarray:
        """Snapshot features -> predicted runtime BW matrix [N,N]
        (floored at 1 Mbps, `intra_dc_bw` on the diagonal); `backend`
        picks numpy / jnp / pallas inference."""
        X = assemble_features(n_dcs, snap_bw, mem_util, cpu_load,
                              retrans, dist)
        if backend == "numpy":
            vals = self.forest.predict(X)
        elif backend == "jnp":
            f, t, l = self.forest.packed()
            vals = np.asarray(forest_predict_jnp(
                jnp.asarray(f), jnp.asarray(t), jnp.asarray(l),
                jnp.asarray(X), self.forest.depth))
        elif backend == "pallas":
            from repro.kernels import ops
            f, t, l = self.forest.packed()
            vals = np.asarray(ops.rf_predict(
                jnp.asarray(f), jnp.asarray(t), jnp.asarray(l),
                jnp.asarray(X), depth=self.forest.depth))
        else:
            raise ValueError(backend)
        vals = np.maximum(vals, 1.0)             # BW is positive
        return matrix_from_pairs(vals, snap_bw.shape[0], diag=intra_dc_bw)


@dataclass
class SnapshotPredictor:
    """No-RF ablation backend: trust the 1-second snapshot as-is (the
    paper's no-prediction baseline). Drop-in for :class:`BwPredictor`
    wherever training a forest is overkill — controller tests,
    lightweight serve-side control planes."""

    def predict_matrix(self, n_dcs: int, snap_bw: np.ndarray,
                       mem_util: np.ndarray, cpu_load: np.ndarray,
                       retrans: np.ndarray, dist: np.ndarray,
                       intra_dc_bw: float = 10000.0,
                       backend: str = "numpy") -> np.ndarray:
        """Return the snapshot itself as the 'prediction' (`backend`
        is accepted for interface parity and ignored)."""
        out = np.maximum(np.asarray(snap_bw, np.float64).copy(), 1.0)
        np.fill_diagonal(out, intra_dc_bw)
        return out
