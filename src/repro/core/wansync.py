"""WANify cross-pod gradient synchronization.

The paper's all-to-all shuffle maps onto a DIRECT (flat) all-reduce over
the `pod` mesh axis: reduce-scatter + all-gather built from offset-phase
``lax.ppermute`` exchanges, so every pod-pair link carries traffic
simultaneously — exactly the contention regime WANify gauges. The
heterogeneous "parallel connections" become per-offset-class CHUNK
multiplicities: a phase whose links are weak is split into more
independently pipelined collective-permutes (more in-flight streams on
the weak link), and its payload is quantized to the bits the predicted
link BW affords (SAGQ analogue).

Must be called inside shard_map with the pod axis manual
(axis_names={"pod"}); data/model axes stay auto so XLA keeps each
transfer shard-local.

Offset classes: phase `o` exchanges pod i <-> pod (i+o)%P. On a
geo-ring of pods, offset correlates with distance, mirroring
Algorithm 1's closeness classes.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.control.schedule import offset_schedule, wire_decode, wire_encode
from repro.core.plan import WanPlan


def _permute(x, axis_name, perm):
    return jax.lax.ppermute(x, axis_name, perm)


# ----------------------------------------------------------------------
# Direct (flat) all-reduce with WANify schedule — per leaf
# ----------------------------------------------------------------------
def _pad_to(x: jax.Array, mult: int) -> Tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        cfg = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        x = jnp.pad(x, cfg)
    return x, pad


def _leaf_wan_allreduce(g: jax.Array, sched, P: int, axis: str,
                        rank: jax.Array, compress: bool) -> jax.Array:
    """Direct all-reduce of one gradient leaf over the pod axis.

    Segments along axis 0 (layer-stacked dim — unsharded within a pod,
    so slicing never reshards data/model)."""
    orig_shape, orig_dtype = g.shape, g.dtype
    if g.ndim == 0:
        g = g[None]
    cmax = max(ph["chunks"] for ph in sched) if sched else 1
    g, pad = _pad_to(g, P * cmax)
    seg = g.shape[0] // P

    def segment(x, idx):
        """Slice one chunk segment out of a flat leaf."""
        return jax.lax.dynamic_slice_in_dim(x, idx * seg, seg, axis=0)

    # ---- reduce-scatter: after this, every pod holds the reduced segment
    # for ALL indices it will later need? No — direct RS: pod r reduces
    # segment r. Phase o: send segment ((rank + o) % P) to pod rank+o.
    acc = segment(g, rank)                        # own contribution
    for ph in sched:
        o, chunks, bits = ph["offset"], ph["chunks"], ph["bits"]
        if not compress:
            bits = 32
        perm = [(i, (i + o) % P) for i in range(P)]
        dest_idx = (rank + o) % P
        payload = segment(g, dest_idx)
        parts = jnp.split(payload, chunks, axis=0) if chunks > 1 else [payload]
        recvd = []
        for part in parts:                        # parallel "connections"
            enc, scale = wire_encode(part, bits)
            enc_r = _permute(enc, axis, perm)
            scale_r = _permute(scale, axis, perm) if scale is not None else None
            recvd.append(wire_decode(enc_r, scale_r, g.dtype, bits))
        acc = acc + jnp.concatenate(recvd, axis=0) if chunks > 1 \
            else acc + recvd[0]

    # ---- all-gather: broadcast my reduced segment to every pod ---------
    gathered = {0: acc}                           # my own segment
    for ph in sched:
        o, chunks, bits = ph["offset"], ph["chunks"], ph["bits"]
        if not compress:
            bits = 32
        perm = [(i, (i + o) % P) for i in range(P)]
        parts = jnp.split(acc, chunks, axis=0) if chunks > 1 else [acc]
        recvd = []
        for part in parts:
            enc, scale = wire_encode(part, bits)
            enc_r = _permute(enc, axis, perm)
            scale_r = _permute(scale, axis, perm) if scale is not None else None
            recvd.append(wire_decode(enc_r, scale_r, g.dtype, bits))
        gathered[o] = jnp.concatenate(recvd, axis=0) if chunks > 1 else recvd[0]

    # Phase o delivered pod (rank-o)'s reduced segment, i.e. absolute
    # segment (rank-o) % P. Ordering [gathered[0], gathered[P-1], ...,
    # gathered[1]] lays segments out as [rank, rank+1, ..., rank+P-1];
    # a roll by rank*seg rotates them into absolute order.
    ordered = [gathered[0]] + [gathered[o] for o in range(P - 1, 0, -1)]
    out = jnp.concatenate(ordered, axis=0)
    out = jnp.roll(out, shift=rank * seg, axis=0)
    if pad:
        out = out[:orig_shape[0] if orig_shape else 1]
    out = out.reshape(orig_shape).astype(orig_dtype)
    return out


def wan_allreduce(tree: Any, plan: WanPlan, *, axis: str = "pod",
                  compress: bool = False, mean: bool = True) -> Any:
    """WANify-scheduled all-reduce of a pytree over the pod axis.
    Call inside shard_map(axis_names={axis})."""
    P = plan.n_pods
    if P <= 1:
        return tree
    sched = offset_schedule(plan)
    rank = jax.lax.axis_index(axis)
    scale = 1.0 / P if mean else 1.0

    def per_leaf(g):
        """Apply the phase schedule to one gradient leaf."""
        out = _leaf_wan_allreduce(g, sched, P, axis, rank, compress)
        return out * scale if mean else out

    return jax.tree.map(per_leaf, tree)


def psum_allreduce(tree: Any, *, axis: str = "pod", mean: bool = True) -> Any:
    """Baseline: XLA's own all-reduce (single logical connection — the
    paper's 'vanilla' transfer)."""
    n = jax.lax.axis_size(axis)

    def per_leaf(g):
        """Apply the phase schedule to one gradient leaf."""
        s = jax.lax.psum(g, axis)
        return s / n if mean else s

    return jax.tree.map(per_leaf, tree)


# ======================================================================
# BATCHED (vmap-over-pods) formulation — no manual mesh axes.
#
# Gradients carry an explicit leading pod dim sharded over "pod";
# jnp.roll along that dim lowers to collective-permute, so the offset-
# phase schedule below emits exactly the same wire pattern as the
# shard_map version. This is the default on CPU: XLA's SPMD partitioner
# CHECK-crashes on partially-manual meshes (spmd_partitioner_util.cc:504
# — documented in DESIGN.md); on TPU either path works.
# ======================================================================
def wan_allreduce_batched(tree: Any, plan: WanPlan, *,
                          compress: bool = False, mean: bool = True) -> Any:
    """tree leaves: [P, ...] per-pod values (dim 0 sharded over pod).
    Returns the synchronized tree, every pod slice holding the sum/mean.

    Direct exchange: phase o rolls pod p's contribution to pod p+o —
    every pod-pair link is active simultaneously (the paper's all-to-all
    shuffle regime). Per-offset chunk multiplicity + wire bits implement
    the heterogeneous parallel connections / SAGQ compression."""
    P = plan.n_pods
    if P <= 1:
        return tree
    sched = offset_schedule(plan)
    out_scale = 1.0 / P if mean else 1.0

    def per_leaf(g):
        """Apply the phase schedule to one gradient leaf."""
        # f32 accumulation only when lossy wire compression is active;
        # a blanket f32 copy of 236B-scale grads costs GiBs of HBM
        any_lossy = compress and any(ph["bits"] < 32 for ph in sched)
        acc = g.astype(jnp.float32) if any_lossy else g
        for ph in sched:
            o, chunks, bits = ph["offset"], ph["chunks"], ph["bits"]
            if not compress:
                bits = 32
            if g.ndim > 1 and chunks > 1 and g.shape[1] % chunks == 0:
                parts = jnp.split(g, chunks, axis=1)
            else:
                parts = [g]
            rec = []
            for part in parts:
                # per-pod-slice scales (rolled along with the payload)
                enc, scl = wire_encode(part, bits,
                                       axes=tuple(range(1, part.ndim)))
                enc_r = jnp.roll(enc, o, axis=0)          # -> ppermute
                scl_r = jnp.roll(scl, o, axis=0) if scl is not None else None
                rec.append(wire_decode(enc_r, scl_r, jnp.float32, bits))
            got = jnp.concatenate(rec, axis=1) if len(rec) > 1 else rec[0]
            acc = acc + got
        return (acc * out_scale).astype(g.dtype)

    return jax.tree.map(per_leaf, tree)


def psum_allreduce_batched(tree: Any, n_pods: int, *, mean: bool = True
                           ) -> Any:
    """Baseline in the batched formulation: mean over the pod dim
    broadcast back — XLA inserts its own all-reduce."""
    def per_leaf(g):
        """Apply the phase schedule to one gradient leaf."""
        s = jnp.sum(g, axis=0, keepdims=True)
        if mean:
            s = s / n_pods
        return jnp.broadcast_to(s, g.shape).astype(g.dtype)
    return jax.tree.map(per_leaf, tree)
