"""Algorithm 1 — INFER_DC_RELATIONS (paper-exact).

Given a runtime BW matrix `bw` (NxN, diagonal = intra-DC) and a minimum
significant difference `D`, derive the closeness index per DC pair:
index 1 = closest (highest BW class), larger = farther.

Paper worked example: bw = {1000,400,120; 380,1000,130; 110,120,1000},
D = 30  =>  unique {110,120,130,380,400,1000} -> filtered {110,380,1000};
closeness: 1000->1, {400,380}->2, {130,120,110}->3.
"""
from __future__ import annotations


import numpy as np


def infer_dc_relations(bw: np.ndarray, D: float) -> np.ndarray:
    """Returns DC_rel (NxN int array of closeness indices, diagonal 1)."""
    bw = np.asarray(bw, dtype=np.float64)
    N = bw.shape[0]
    assert bw.shape == (N, N), "bw must be square"

    # lines 3-8: unique sorted BWs; reverse traversal removing entries
    # within D of their smaller neighbour
    bw_u = sorted(set(bw.reshape(-1).tolist()))
    i = len(bw_u) - 1
    while i >= 1:
        if bw_u[i] - bw_u[i - 1] < D:
            del bw_u[i]
        i -= 1
    bw_u = np.asarray(bw_u)
    n_u = len(bw_u)

    # lines 9-22: closeness index per pair via binary search into bw_u
    rel = np.ones((N, N), dtype=np.int64)
    for r in range(N):
        for c in range(N):
            if r == c:
                rel[r, c] = 1
                continue
            val = bw[r, c]
            k = int(np.searchsorted(bw_u, val))
            if k < n_u and bw_u[k] == val:           # match found
                rel[r, c] = n_u - (k + 1) + 1        # 1-based
            else:                                    # interval: nearest rep
                lo, hi = max(k - 1, 0), min(k, n_u - 1)
                pick = lo if (abs(val - bw_u[lo]) <= abs(bw_u[hi] - val)) else hi
                rel[r, c] = n_u - (pick + 1) + 1
    return rel
