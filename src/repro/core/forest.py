"""From-scratch CART Random-Forest regressor (paper §3.1).

Training is pure numpy (no sklearn available offline). Trees are stored
in a COMPLETE-BINARY-TREE array layout of fixed depth — node k's children
are 2k+1 / 2k+2 — so inference is branch-free index arithmetic rather
than pointer chasing. That layout is the TPU adaptation: the Pallas
kernel (kernels/rf_predict.py) walks all trees for a batch of samples
with `depth` vectorized gather steps, no dynamic control flow.

Supports warm-start retraining (§3.3.4): ``fit(..., warm=True)`` keeps
existing trees and appends new ones trained on the fresh data.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np


# ----------------------------------------------------------------------
# Single-tree CART (MSE split criterion)
# ----------------------------------------------------------------------
def _best_split(X: np.ndarray, y: np.ndarray, feat_idx: np.ndarray,
                min_leaf: int) -> Optional[Tuple[int, float]]:
    """Best (feature, threshold) by SSE reduction over candidate features."""
    n = len(y)
    if n < 2 * min_leaf:
        return None
    best_gain, best = 0.0, None
    sse_parent = float(np.sum((y - y.mean()) ** 2))
    for f in feat_idx:
        order = np.argsort(X[:, f], kind="stable")
        xs, ys = X[order, f], y[order]
        csum = np.cumsum(ys)
        csq = np.cumsum(ys ** 2)
        tot, tot2 = csum[-1], csq[-1]
        ks = np.arange(min_leaf, n - min_leaf + 1)
        if len(ks) == 0:
            continue
        valid = xs[ks - 1] < xs[np.minimum(ks, n - 1)]   # distinct boundary
        if not valid.any():
            continue
        ks = ks[valid]
        sl, sl2 = csum[ks - 1], csq[ks - 1]
        nl = ks.astype(np.float64)
        nr = n - nl
        sse = (sl2 - sl ** 2 / nl) + ((tot2 - sl2) - (tot - sl) ** 2 / nr)
        i = int(np.argmin(sse))
        gain = sse_parent - float(sse[i])
        if gain > best_gain + 1e-12:
            k = int(ks[i])
            thr = 0.5 * (xs[k - 1] + xs[k])
            best_gain, best = gain, (int(f), float(thr))
    return best


def _fit_tree(X: np.ndarray, y: np.ndarray, depth: int, min_leaf: int,
              n_feats: int, rng: np.random.Generator
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (feat[2^d-1] int32, thr[2^d-1] f32, leaf[2^d] f32)."""
    n_int = 2 ** depth - 1
    feat = np.full(n_int, -1, np.int32)
    # pass-through sentinel: feature -1 with a LARGE FINITE threshold
    # (x > 1e30 is always False => go left). Finite, not inf, so the
    # Pallas kernel's one-hot contraction never multiplies 0 * inf = NaN.
    thr = np.full(n_int, 1e30, np.float32)
    leaf = np.zeros(2 ** depth, np.float32)

    def recurse(node: int, idx: np.ndarray, lvl: int):
        """Grow the subtree at `node` over samples `idx`."""
        ys = y[idx]
        if lvl == depth:
            leaf[node - n_int] = float(ys.mean()) if len(ys) else 0.0
            return
        split = None
        if len(idx) >= 2 * min_leaf and ys.std() > 1e-9:
            fs = rng.choice(X.shape[1], size=min(n_feats, X.shape[1]),
                            replace=False)
            split = _best_split(X[idx], ys, fs, min_leaf)
        if split is None:
            # fill entire subtree with the node mean (pass-through)
            val = float(ys.mean()) if len(ys) else 0.0
            stack = [(node, lvl)]
            while stack:
                nd, lv = stack.pop()
                if lv == depth:
                    leaf[nd - n_int] = val
                else:
                    stack.append((2 * nd + 1, lv + 1))
                    stack.append((2 * nd + 2, lv + 1))
            return
        f, t = split
        feat[node], thr[node] = f, t
        mask = X[idx, f] <= t
        recurse(2 * node + 1, idx[mask], lvl + 1)
        recurse(2 * node + 2, idx[~mask], lvl + 1)

    recurse(0, np.arange(len(y)), 0)
    return feat, thr, leaf


# ----------------------------------------------------------------------
# Forest
# ----------------------------------------------------------------------
@dataclass
class RandomForest:
    """Bootstrap-aggregated CART regressor in the complete-binary-tree
    array layout (`feat`/`thr`/`leaf` stacked per tree) — the form the
    jnp and Pallas inference backends consume directly."""

    n_trees: int = 100
    depth: int = 10
    min_leaf: int = 1
    feature_frac: float = 0.6
    seed: int = 0
    # flattened model (set by fit)
    feat: Optional[np.ndarray] = None     # [T, 2^d - 1] int32
    thr: Optional[np.ndarray] = None      # [T, 2^d - 1] f32
    leaf: Optional[np.ndarray] = None     # [T, 2^d] f32

    def fit(self, X: np.ndarray, y: np.ndarray, warm: bool = False,
            n_new: Optional[int] = None) -> "RandomForest":
        """Fit on bootstrap resamples; ``warm=True`` keeps existing
        trees and appends `n_new` (default n_trees/4) trained on the
        fresh data (§3.3.4 retraining)."""
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float32)
        rng = np.random.default_rng(self.seed if not warm else self.seed + 1)
        n_feats = max(1, int(round(self.feature_frac * X.shape[1])))
        add = self.n_trees if not warm else (n_new or max(self.n_trees // 4, 1))
        feats, thrs, leaves = [], [], []
        for _ in range(add):
            idx = rng.integers(0, len(y), size=len(y))      # bootstrap
            f, t, l = _fit_tree(X[idx], y[idx], self.depth, self.min_leaf,
                                n_feats, rng)
            feats.append(f)
            thrs.append(t)
            leaves.append(l)
        newf = np.stack(feats)
        newt = np.stack(thrs)
        newl = np.stack(leaves)
        if warm and self.feat is not None:
            self.feat = np.concatenate([self.feat, newf])
            self.thr = np.concatenate([self.thr, newt])
            self.leaf = np.concatenate([self.leaf, newl])
        else:
            self.feat, self.thr, self.leaf = newf, newt, newl
        return self

    def spawn(self, seed: Optional[int] = None) -> "RandomForest":
        """An UNFITTED forest with this forest's hyperparameters (and
        `seed`, default: same seed). The online-refresh path
        (repro.lifecycle) fits the spawn on fresh data and swaps it in
        as one reference assignment, so a consumer never observes a
        half-retrained model: the packed (feat, thr, leaf) tensors
        always come from exactly one completed fit."""
        return RandomForest(n_trees=self.n_trees, depth=self.depth,
                            min_leaf=self.min_leaf,
                            feature_frac=self.feature_frac,
                            seed=self.seed if seed is None else int(seed))

    # ------------------------------------------------------------------
    def predict(self, X: np.ndarray) -> np.ndarray:
        """Reference numpy inference over the complete-tree layout."""
        assert self.feat is not None, "fit first"
        X = np.asarray(X, np.float32)
        n, T = X.shape[0], self.feat.shape[0]
        node = np.zeros((T, n), np.int64)
        for _ in range(self.depth):
            f = self.feat[np.arange(T)[:, None], node]       # [T,n]
            t = self.thr[np.arange(T)[:, None], node]
            fx = np.where(f < 0, 0, f)
            go_right = X[np.arange(n)[None, :], fx] > t
            node = 2 * node + 1 + go_right.astype(np.int64)
        leaf_idx = node - (2 ** self.depth - 1)
        vals = self.leaf[np.arange(T)[:, None], leaf_idx]
        return vals.mean(axis=0)

    def score(self, X: np.ndarray, y: np.ndarray) -> float:
        """R^2."""
        p = self.predict(X)
        y = np.asarray(y, np.float64)
        ss = np.sum((y - p) ** 2)
        st = np.sum((y - y.mean()) ** 2)
        return float(1.0 - ss / max(st, 1e-12))

    def training_accuracy(self, X, y, tol_frac: float = 0.1) -> float:
        """Fraction of predictions within tol_frac of truth (the paper
        reports 98.51% 'training accuracy')."""
        p = self.predict(X)
        y = np.asarray(y, np.float64)
        return float(np.mean(np.abs(p - y) <= tol_frac * np.maximum(y, 1.0)))

    def packed(self):
        """The (feat, thr, leaf) arrays the jnp/Pallas kernels take."""
        return self.feat, self.thr, self.leaf
