"""Fleet trace: per-tick, per-job records with canonical serialization.

Same determinism contract as the single-job scenario trace
(repro.scenarios.trace): two runs of the same fleet scenario with the
same seed must produce byte-identical ``to_json()`` output — per-job
plan signatures, budgets, envelope caps, credited BW, and the
cumulative RF-kernel-launch counter included. Every random draw comes
from the shared simulator's named streams, and the fleet visits jobs
in arrival order, so the draw sequence is replay-stable.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Tuple

from repro.scenarios.trace import sig_hash


@dataclass
class FleetStepTrace:
    """One fleet tick: fleet-wide counters plus one row per job."""
    tick: int
    events: Tuple[str, ...]          # describe() of events applied now
    n_jobs: int
    kernel_calls: int                # cumulative RF launches (== ticks)
    jobs: Tuple[Dict[str, Any], ...]
    # job row keys: name, priority, budget, cap_min, plan_sig,
    # achieved_min, achieved_mean, conns_total


@dataclass
class FleetTrace:
    """The whole run; `to_json()` is the byte-comparable replay form."""
    scenario: str
    seed: int
    steps: List[FleetStepTrace] = field(default_factory=list)

    def to_json(self) -> str:
        """Canonical bytes for replay comparison (sorted keys, no
        whitespace drift; infinities serialize as `Infinity`, which is
        byte-stable even though it is a JSON extension)."""
        payload = {"scenario": self.scenario, "seed": self.seed,
                   "steps": [asdict(s) for s in self.steps]}
        return json.dumps(payload, sort_keys=True,
                          separators=(",", ":"))

    # ---- convenience views ------------------------------------------
    def job_names(self) -> List[str]:
        """Every job name that ever appears in the trace."""
        seen: List[str] = []
        for s in self.steps:
            for row in s.jobs:
                if row["name"] not in seen:
                    seen.append(row["name"])
        return seen

    def job_series(self, name: str, key: str) -> List[Any]:
        """One job's per-tick values of `key` (ticks it was absent are
        skipped)."""
        return [row[key] for s in self.steps for row in s.jobs
                if row["name"] == name]


def tick_to_step(record: Dict[str, Any],
                 events: Tuple[str, ...] = ()) -> FleetStepTrace:
    """Fold a `FleetController.tick()` record into a trace row (plan
    signatures are hashed here so the trace stays compact)."""
    jobs = tuple(dict(row, plan_sig=sig_hash(row["plan_sig"]))
                 for row in record["jobs"])
    return FleetStepTrace(tick=record["tick"], events=tuple(events),
                          n_jobs=record["n_jobs"],
                          kernel_calls=record["kernel_calls"], jobs=jobs)


@dataclass
class FleetResult:
    """A completed fleet run plus summary helpers."""
    trace: FleetTrace

    def summary(self) -> Dict[str, Any]:
        """Fleet-level rollup: job count range, launches, fairness."""
        steps = self.trace.steps
        last = steps[-1]
        per_job = {}
        for name in self.trace.job_names():
            mins = self.trace.job_series(name, "achieved_min")
            per_job[name] = {
                "ticks": len(mins),
                "achieved_min_mbps": min(mins),
                "achieved_min_mean_mbps": sum(mins) / len(mins),
            }
        return {
            "scenario": self.trace.scenario,
            "seed": self.trace.seed,
            "ticks": len(steps),
            "kernel_calls": last.kernel_calls,
            "n_jobs_final": last.n_jobs,
            "jobs": per_job,
        }
