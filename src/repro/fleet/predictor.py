"""Batched RF inference: one Pallas kernel launch per fleet tick.

Per-job prediction would launch one `rf_predict_pallas` call per job
per tick (J kernel launches, each on a handful of rows). The fleet
instead stacks every job's Table-3 feature rows into a single [R, 6]
batch and launches ONCE — the kernel's grid is over sample blocks, so
R rows from 8 jobs cost the same launch overhead as one job's rows,
and the forest stays resident in VMEM across the whole batch.

`kernel_calls` counts launches; the fleet invariant (asserted in
tests/test_fleet.py) is exactly one per tick regardless of job count.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.forest import RandomForest
from repro.obs.registry import MetricsRegistry


class BatchedRfPredictor:
    """One shared forest, one kernel launch per fleet tick."""

    def __init__(self, forest: RandomForest):
        """`forest` must be fitted; its packed complete-binary-tree
        arrays are transferred to the device once, not per call."""
        if forest.feat is None:
            raise ValueError("forest must be fitted before batching")
        self.forest = forest
        f, t, l = forest.packed()
        self._packed = (jnp.asarray(f), jnp.asarray(t), jnp.asarray(l))
        # launch accounting on the obs registry; `kernel_calls` stays
        # readable as a back-compat property
        self.metrics = MetricsRegistry("predictor")
        self._m_calls = self.metrics.counter(
            "kernel_calls", help="batched RF Pallas launches")
        self._m_rows = self.metrics.counter(
            "rows_total", help="feature rows predicted")

    def predict_rows(self, X: np.ndarray) -> np.ndarray:
        """Predict runtime BW for stacked feature rows [R, 6] -> [R].

        One Pallas launch regardless of how many jobs contributed rows;
        predictions are floored at 1 Mbps (BW is positive).
        """
        from repro.kernels import ops
        self._m_calls.inc()
        self._m_rows.inc(int(np.asarray(X).shape[0]))
        vals = ops.rf_predict(*self._packed, jnp.asarray(X, jnp.float32),
                              depth=self.forest.depth)
        return np.maximum(np.asarray(vals, np.float64), 1.0)

    @property
    def kernel_calls(self) -> int:
        """Total Pallas launches (registry-backed back-compat alias)."""
        return int(self._m_calls.value)

    @kernel_calls.setter
    def kernel_calls(self, v: int) -> None:
        """Legacy reset path (tests zero the tally between phases)."""
        self._m_calls.reset(int(v))

    def split_rows(self, vals: np.ndarray,
                   row_counts: Sequence[int]) -> list:
        """Un-stack a batched prediction back into per-job vectors."""
        out, ofs = [], 0
        for k in row_counts:
            out.append(vals[ofs:ofs + k])
            ofs += k
        if ofs != len(vals):
            raise ValueError(
                f"row counts {list(row_counts)} != batch size {len(vals)}")
        return out


def default_fleet_forest(n_samples: int = 60, n_trees: int = 8,
                         depth: int = 5, seed: int = 7,
                         cache: Optional[dict] = {}) -> RandomForest:
    """A small, deterministic forest for demos/benchmarks (module-level
    memo keyed by the arguments; pass ``cache=None`` to bypass it).
    Real deployments train via `repro.wan.dataset.train_default_forest`.
    """
    key = (n_samples, n_trees, depth, seed)
    if cache is not None and key in cache:
        return cache[key]
    from repro.wan.dataset import generate_dataset
    X, y = generate_dataset(n_samples=n_samples, seed=seed)
    rf = RandomForest(n_trees=n_trees, depth=depth, seed=seed).fit(X, y)
    if cache is not None:
        cache[key] = rf
    return rf
