"""Fleet scenarios: scripted multi-job timelines with replayable traces.

The single-job scenario engine (repro.scenarios) stresses ONE closed
loop; the fleet engine drives a whole :class:`FleetController` through
the same `at(step, event)` DSL — WAN events (`LinkDegrade`,
`CrossTraffic`, `DiurnalCycle`, ...) mutate the shared simulator, and
the fleet events (`JobArrive`/`JobDepart`/`PriorityShift`) churn the
job set. Each tick appends a :class:`FleetStepTrace` row; same spec +
same seed replays to byte-identical `FleetTrace.to_json()` output.

`notify=True` WAN events are a single-job-engine concept (fleet ticks
replan every job each epoch); use the silent variants here.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.faults.events import FLEET_FAULT_EVENTS, FaultEvent
from repro.faults.plane import FaultPlane, faults_mode
from repro.fleet.controller import FleetController, JobSpec
from repro.fleet.predictor import BatchedRfPredictor, default_fleet_forest
from repro.fleet.trace import FleetResult, FleetTrace, tick_to_step
from repro.scenarios.events import (CrossTraffic, DiurnalCycle, JobArrive,
                                    JobDepart, LinkDegrade, LinkRestore,
                                    PriorityShift, Timed, at)
from repro.wan.simulator import WanSimulator

QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0)

# Events a fleet timeline may carry. Single-job workload events
# (Rescale, SkewRamp, Straggler, ProviderShift) target the single-job
# engine's synthetic workload / controller and would silently no-op or
# crash here, so they are rejected at spec validation instead. Of the
# fault events, only the reachability ones are job-agnostic WAN state;
# the control-plane faults (ProbeTimeout, MonitorOutage, ...) target
# the single-job capture path and stay rejected.
FLEET_EVENTS = (LinkDegrade, LinkRestore, CrossTraffic, DiurnalCycle,
                JobArrive, JobDepart, PriorityShift) + FLEET_FAULT_EVENTS


@dataclass
class FleetScenarioSpec:
    """A named, replayable multi-job timeline."""
    name: str
    steps: int
    jobs: Tuple[JobSpec, ...]                # admitted before tick 1
    events: Tuple[Timed, ...] = ()
    description: str = ""
    m_total: int = 8
    regions: Optional[List[str]] = None      # default: the 8-DC testbed
    sim_kwargs: Dict[str, Any] = field(default_factory=dict)


class FleetEngine:
    """One deterministic run of a :class:`FleetScenarioSpec`."""

    def __init__(self, spec: FleetScenarioSpec, seed: int = 0,
                 forest: Any = None, obs: Optional[str] = None,
                 faults: Any = None):
        """`forest`: a fitted RandomForest shared by every job's RF
        inference (defaults to the memoized small demo forest); `obs`
        gates span tracing (None defers to $REPRO_OBS, default off);
        `faults` gates the fault plane (a FaultPlane is used as-is,
        else $REPRO_FAULTS — "on" = graceful; a timeline scripting
        fault events under "off" gets the ungraceful naive ablation)."""
        self.spec = spec
        self.seed = int(seed)
        sim_kw = dict(spec.sim_kwargs)
        if spec.regions is not None:
            sim_kw.setdefault("regions", list(spec.regions))
        self.sim = WanSimulator(seed=self.seed, **sim_kw)
        if not isinstance(faults, FaultPlane):
            mode = faults_mode(faults)
            if mode == "on" or any(isinstance(t.event, FaultEvent)
                                   for t in spec.events):
                faults = FaultPlane(self.sim.N, graceful=(mode == "on"),
                                    seed=self.seed)
            else:
                faults = None
        self.fleet = FleetController(
            self.sim, BatchedRfPredictor(forest or default_fleet_forest()),
            m_total=spec.m_total, jobs=spec.jobs, obs=obs, faults=faults)
        self.faults = self.fleet.faults
        self.tracer = self.fleet.tracer
        # a per-tick tap for harnesses: called as
        # step_hook(engine, fleet_step_trace_row) after each row is
        # appended; it must not mutate fleet/simulator state
        self.step_hook: Optional[Callable] = None
        self.step = 0
        self.diurnal: Optional[Tuple[float, int, int]] = None
        self._timeline: Dict[int, List[Timed]] = {}
        for t in spec.events:
            if not isinstance(t.event, FLEET_EVENTS):
                raise ValueError(
                    f"{type(t.event).__name__} is a single-job-engine "
                    f"event; fleet timelines accept "
                    f"{[e.__name__ for e in FLEET_EVENTS]}")
            if getattr(t.event, "notify", False):
                raise ValueError(
                    "notify=True is a single-job-engine concept; fleet "
                    "ticks replan every job each epoch")
            self._timeline.setdefault(t.step, []).append(t)

    # ------------------------------------------------------------------
    # event targets (shared-DSL surface; see scenarios/events.py)
    # ------------------------------------------------------------------
    def link(self, pair) -> Tuple[int, int]:
        """Resolve a (region, region) pair to shared-mesh indices."""
        a, b = pair
        return self.sim.regions.index(a), self.sim.regions.index(b)

    def dc(self, region: str) -> int:
        """Resolve one region name to its shared-mesh index (fault
        events target single DCs)."""
        return self.sim.regions.index(region)

    def add_job(self, spec: JobSpec) -> None:
        """`JobArrive` target."""
        self.fleet.add_job(spec)

    def remove_job(self, name: str) -> None:
        """`JobDepart` target."""
        self.fleet.remove_job(name)

    def set_priority(self, name: str, priority: float) -> None:
        """`PriorityShift` target."""
        self.fleet.set_priority(name, priority)

    # ------------------------------------------------------------------
    def _advance_scripted(self) -> None:
        if self.diurnal is not None:
            amp, period, start = self.diurnal
            phase = 2.0 * math.pi * (self.step - start) / max(period, 1)
            self.sim.modulation = 1.0 + amp * math.sin(phase)

    def run(self) -> FleetResult:
        """Drive the timeline to completion and return the trace."""
        trace = FleetTrace(self.spec.name, self.seed)
        for k in range(self.spec.steps):
            self.step = k
            if self.faults is not None:
                self.faults.step = k     # fault windows key on loop time
            due = self._timeline.get(k, ())
            applied = tuple(t.event.describe() for t in due)
            for t in due:
                t.event.apply(self)
            self._advance_scripted()
            record = self.fleet.tick()
            trace.steps.append(tick_to_step(record, events=applied))
            if self.step_hook is not None:
                self.step_hook(self, trace.steps[-1])
        return FleetResult(trace=trace)


def run_fleet_scenario(spec: FleetScenarioSpec, seed: int = 0,
                       forest: Any = None,
                       obs: Optional[str] = None,
                       faults: Any = None) -> FleetResult:
    """Build a fresh engine and run the fleet scenario to completion
    (`obs` gates span tracing, `faults` the fault plane; None defers
    to $REPRO_OBS / $REPRO_FAULTS)."""
    return FleetEngine(spec, seed=seed, forest=forest, obs=obs,
                       faults=faults).run()


# ----------------------------------------------------------------------
# Named fleet scenarios — contention regimes the paper never runs
# ----------------------------------------------------------------------
# Slices deliberately overlap: DCs 0-3 carry two jobs, so their per-host
# budget and the shared links are genuinely contended.

def fleet_steady() -> FleetScenarioSpec:
    """Three fixed jobs, priorities 4:2:1, overlapping slices."""
    return FleetScenarioSpec(
        name="fleet_steady", steps=12,
        description="3 concurrent jobs share the mesh; no churn",
        jobs=(JobSpec("serving", dcs=(0, 1, 2, 3), priority=4.0),
              JobSpec("training", dcs=(0, 1, 4, 5), priority=2.0),
              JobSpec("batch", dcs=(2, 3, 6, 7), priority=1.0)),
        sim_kwargs=dict(QUIET))


def fleet_churn() -> FleetScenarioSpec:
    """Jobs arrive and depart; survivors re-share the freed capacity."""
    from repro.scenarios.events import JobArrive, JobDepart
    return FleetScenarioSpec(
        name="fleet_churn", steps=14,
        description="start with 2 jobs; a third arrives at tick 4 and "
                    "the batch job departs at tick 9",
        jobs=(JobSpec("serving", dcs=(0, 1, 2, 3), priority=3.0),
              JobSpec("batch", dcs=(0, 1, 4, 5), priority=1.0)),
        events=(at(4, JobArrive(JobSpec("etl", dcs=(2, 3, 6, 7),
                                        priority=2.0))),
                at(9, JobDepart("batch"))),
        sim_kwargs=dict(QUIET))


def fleet_priority_shift() -> FleetScenarioSpec:
    """A batch job is promoted mid-run (SLO escalation)."""
    from repro.scenarios.events import PriorityShift
    return FleetScenarioSpec(
        name="fleet_priority_shift", steps=12,
        description="batch promoted 1 -> 6 at tick 6 on a fully "
                    "shared 4-DC slice",
        jobs=(JobSpec("serving", dcs=(0, 1, 2, 3), priority=4.0),
              JobSpec("batch", dcs=(0, 1, 2, 3), priority=1.0)),
        events=(at(6, PriorityShift("batch", 6.0)),),
        sim_kwargs=dict(QUIET))


def fleet_congestion() -> FleetScenarioSpec:
    """Uncredited cross-traffic bursts onto links two jobs share."""
    from repro.scenarios.events import CrossTraffic
    return FleetScenarioSpec(
        name="fleet_congestion", steps=12,
        description="background burst on us-east<->us-west, ticks 4-8, "
                    "under two contending jobs",
        jobs=(JobSpec("serving", dcs=(0, 1, 2, 3), priority=3.0),
              JobSpec("training", dcs=(0, 1, 4, 5), priority=1.0)),
        events=(at(4, CrossTraffic(("us-east", "us-west"), conns=48)),
                at(8, CrossTraffic(("us-east", "us-west"), conns=0))),
        sim_kwargs=dict(QUIET))


FLEET_SCENARIOS: Dict[str, Callable[[], FleetScenarioSpec]] = {
    "fleet_steady": fleet_steady,
    "fleet_churn": fleet_churn,
    "fleet_priority_shift": fleet_priority_shift,
    "fleet_congestion": fleet_congestion,
}


def get_fleet_scenario(name: str) -> FleetScenarioSpec:
    """Fresh spec by name (KeyError lists the known names)."""
    if name not in FLEET_SCENARIOS:
        raise KeyError(f"unknown fleet scenario {name!r}; "
                       f"have {sorted(FLEET_SCENARIOS)}")
    return FLEET_SCENARIOS[name]()


def fleet_scenario_names() -> List[str]:
    """All named fleet scenarios, library order."""
    return list(FLEET_SCENARIOS)
