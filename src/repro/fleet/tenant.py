"""TenantView — one job's sliced, tenant-credited view of the shared WAN.

A fleet job plans over its own topology slice (a subset of the
monitored DCs) and must see every OTHER job's transfers as real
contention while never double-counting its own. `TenantView` gives a
`WanifyController` exactly that without the controller knowing the
fleet exists: it quacks like a `WanSimulator` restricted to the job's
DCs (``N``, ``regions``, ``dist``, ``measure_snapshot``,
``host_metrics``, ``waterfill``, ``advance``), embedding slice-scale
connection matrices into the shared mesh, measuring with
``tenant=<job>`` (so the job's registered flows are excluded and every
rival tenant's flows contend — and are credited on *their* side), and
slicing results back down.

Noise accounting is unchanged: each measurement draws from the shared
simulator's named observation stream exactly once, so fleet replays
stay byte-identical as long as jobs are visited in a fixed order.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.wan.simulator import WanSimulator


class TenantView:
    """Restrict a shared :class:`WanSimulator` to one tenant's DC slice.

    Drop-in for the `sim` argument of `WanifyController` /
    `SnapshotMonitor`; only the surface those two consume (plus
    `waterfill` for harnesses) is implemented.
    """

    def __init__(self, shared: WanSimulator, tenant: str,
                 dcs: Sequence[int]):
        """`dcs`: global DC indices of this tenant's topology slice
        (order defines the slice's pod numbering)."""
        ix = np.asarray(list(dcs), np.int64)
        if len(ix) < 1 or len(set(ix.tolist())) != len(ix):
            raise ValueError(f"invalid DC slice {list(dcs)}")
        if ix.min() < 0 or ix.max() >= shared.N:
            raise ValueError(
                f"DC slice {list(dcs)} outside monitored mesh "
                f"(N={shared.N})")
        self.shared = shared
        self.tenant = str(tenant)
        self.ix = ix
        self.N = len(ix)
        self.regions = [shared.regions[i] for i in ix]
        self.dist = shared.dist[np.ix_(ix, ix)]

    # ------------------------------------------------------------------
    # slice <-> mesh
    # ------------------------------------------------------------------
    def embed(self, mat: np.ndarray, fill: float = 0.0) -> np.ndarray:
        """Lift a slice-scale [P,P] matrix to mesh scale [N,N]."""
        full = np.full((self.shared.N, self.shared.N), float(fill))
        full[np.ix_(self.ix, self.ix)] = np.asarray(mat, np.float64)
        return full

    def extract(self, full: np.ndarray) -> np.ndarray:
        """Project a mesh-scale [N,N] matrix down to the slice [P,P]."""
        return np.asarray(full, np.float64)[np.ix_(self.ix, self.ix)]

    # ------------------------------------------------------------------
    # the WanSimulator surface the control plane consumes
    # ------------------------------------------------------------------
    def advance(self, steps: int = 1) -> None:
        """Advance the SHARED fluctuation process (all tenants see it).

        Under a fleet controller the fleet owns simulated time and jobs
        run with ``advance_sim=False``, so this is only exercised by a
        standalone consumer of the view.
        """
        self.shared.advance(steps)

    def waterfill(self, conns: np.ndarray,
                  active: Optional[np.ndarray] = None,
                  cap: Optional[np.ndarray] = None) -> np.ndarray:
        """Tenant-credited achieved BW on the slice at slice conns."""
        full = self.embed(conns if active is None else conns * active)
        full_cap = None if cap is None else self.embed(cap, fill=np.inf)
        return self.extract(self.shared.waterfill(
            full, cap=full_cap, tenant=self.tenant))

    def measure_snapshot(self, conns: Optional[np.ndarray] = None
                         ) -> np.ndarray:
        """1-second snapshot of the slice, rivals contending."""
        c = np.ones((self.N, self.N)) if conns is None else conns
        return self.extract(self.shared.measure_snapshot(
            self.embed(c), tenant=self.tenant))

    def measure_runtime(self, conns: Optional[np.ndarray] = None
                        ) -> np.ndarray:
        """Stable >=20 s measurement of the slice, rivals contending."""
        c = np.ones((self.N, self.N)) if conns is None else conns
        return self.extract(self.shared.measure_runtime(
            self.embed(c), tenant=self.tenant))

    def host_metrics(self, conns: np.ndarray,
                     bw: Optional[np.ndarray] = None
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Slice-scale Table-3 host metrics (mem/cpu/retrans)."""
        full_c = self.embed(conns)
        full_bw = None if bw is None else self.embed(bw)
        mem, cpu, retr = self.shared.host_metrics(full_c, bw=full_bw,
                                                  tenant=self.tenant)
        return mem[self.ix], cpu[self.ix], retr[np.ix_(self.ix, self.ix)]

    def register(self, conns: np.ndarray) -> None:
        """Publish this tenant's slice-scale in-force connections as
        its registered flows on the shared mesh."""
        self.shared.set_tenant_conns(self.tenant, self.embed(conns))

    def unregister(self) -> None:
        """Withdraw this tenant's flows (job departure)."""
        self.shared.clear_tenant(self.tenant)
