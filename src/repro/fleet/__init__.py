"""repro.fleet — multi-job WAN sharing with batched RF prediction.

N concurrent WANify jobs (each a full `WanifyController` over its own
topology slice, skew weights, and priority) share ONE simulated WAN:
an arbiter splits the per-host connection budget and contended-link
capacity by priority-weighted fair share BEFORE each job plans, every
job's RF inference batches into a single Pallas kernel launch per
fleet tick, and achieved BW is credited per tenant from one fleet-wide
water-fill. See DESIGN.md ("The fleet controller").
"""
from repro.fleet.arbiter import arbitrate, connection_budgets, link_shares
from repro.fleet.controller import FleetController, FleetJob, JobSpec
from repro.fleet.fused import FusedFleet, make_schedule
from repro.fleet.predictor import BatchedRfPredictor, default_fleet_forest
from repro.fleet.scenario import (FLEET_SCENARIOS, FleetEngine,
                                  FleetScenarioSpec, fleet_scenario_names,
                                  get_fleet_scenario, run_fleet_scenario)
from repro.fleet.tenant import TenantView
from repro.fleet.trace import (FleetResult, FleetStepTrace, FleetTrace,
                               tick_to_step)

__all__ = [
    "FleetController", "FleetJob", "JobSpec",
    "FusedFleet", "make_schedule",
    "TenantView",
    "BatchedRfPredictor", "default_fleet_forest",
    "arbitrate", "connection_budgets", "link_shares",
    "FleetEngine", "FleetScenarioSpec", "run_fleet_scenario",
    "FLEET_SCENARIOS", "get_fleet_scenario", "fleet_scenario_names",
    "FleetResult", "FleetStepTrace", "FleetTrace", "tick_to_step",
]
