"""One fused fleet tick — the whole arbitrated closed loop as ONE jit
program, scanned over steps and vmapped over scenario grids.

The sequential :meth:`FleetController.tick` already batches the two
array-heavy stages (one RF launch, one fleet-wide water-fill), but the
glue between them — per-job Algorithm-1 relations, Eq. 2-3 connection
ranges, the budget/capacity arbitration, AIMD — runs as Python between
kernel launches, so a thousand-step scenario sweep pays interpreter
overhead per job per tick. This module expresses the ENTIRE tick as a
single traced program over stacked job tensors:

  stacked snapshot capture (one batched water-fill credits every
  tenant) -> Table-3 feature rows -> stacked RF predict
  (`forest_predict_jnp`) -> Algorithm-1 relations -> Eq. 2-3 ranges +
  §3.2.2 throttle -> priority-weighted budget split & link shares ->
  AIMD clamp -> register -> ONE fleet water-fill with per-tenant
  crediting

`lax.scan` drives T ticks in one launch (`FusedFleet.run`), and
`jax.vmap` over precomputed WAN schedules sweeps B scenario variants
x T steps in one launch (`FusedFleet.sweep`) — the monitoring-cost
story of §3.2 at fleet scale: the control loop is only worth running
at high frequency if a tick is nearly free.

Determinism contract: the fused program reproduces the sequential tick
on a DETERMINISTIC simulator — ``fluct_sigma`` may be nonzero (the
AR(1) draws are consumed while precomputing the schedule, exactly as
``sim.advance`` would), but ``snapshot_sigma`` and ``host_sigma`` must
be 0 so captures draw no observation/host noise. Under that contract
`tests/test_fused_tick.py` pins fused == sequential per-tick integer
connection totals and budgets exactly and achieved BW to roundoff.
The numpy path stays the repo's byte-identical default; the fused
engine is the opt-in fast path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64

from repro.core.local_opt import SIGNIFICANT_MBPS
from repro.core.predictor import forest_predict_jnp
from repro.kernels.waterfill import fill_rates_loop
from repro.scenarios.events import (CrossTraffic, DiurnalCycle, LinkDegrade,
                                    LinkRestore, Timed)
from repro.wan.topology import INTRA_DC_BW

D_DEFAULT = 100.0          # Algorithm-1 minimum significant BW difference

# WAN-state events a fused schedule can replay (job churn / priority
# shifts change the stacked tensor shapes and are rejected)
SCHEDULE_EVENTS = (LinkDegrade, LinkRestore, CrossTraffic, DiurnalCycle)


# ----------------------------------------------------------------------
# jax ports of the per-tick Python stages (all float64 under x64)
# ----------------------------------------------------------------------
def relations_jnp(bw: jax.Array, D: float) -> jax.Array:
    """Algorithm 1 (INFER_DC_RELATIONS) as fixed-shape array ops.

    The reverse-traversal unique filter keeps value v[k] iff it is the
    smallest unique value or sits >= D above its ORIGINAL sorted-unique
    neighbour (deleting an entry never changes later comparisons), so
    the data-dependent Python loop collapses to one mask; closeness
    lookup is a searchsorted into the kept values padded with +inf.
    Matches `repro.core.relations.infer_dc_relations` exactly.
    """
    n = bw.shape[0]
    v = jnp.sort(bw.reshape(-1))
    k_tot = v.shape[0]
    first = jnp.arange(k_tot) == 0
    prev = jnp.concatenate([v[:1], v[:-1]])
    uniq = first | (v != prev)
    keep = uniq & (first | (v - prev >= D))
    kv = jnp.sort(jnp.where(keep, v, jnp.inf))
    n_u = keep.sum()
    val = bw.reshape(-1)
    k = jnp.searchsorted(kv, val)
    found = (k < n_u) & (kv[jnp.clip(k, 0, k_tot - 1)] == val)
    lo = jnp.maximum(k - 1, 0)
    hi = jnp.minimum(k, n_u - 1)
    pick = jnp.where(jnp.abs(val - kv[lo]) <= jnp.abs(kv[hi] - val), lo, hi)
    rel = jnp.where(found, n_u - k, n_u - pick).reshape(n, n)
    return jnp.where(jnp.eye(n, dtype=bool), 1, rel).astype(jnp.int32)


def global_ranges_jnp(bw: jax.Array, M: jax.Array, ws_pair: jax.Array,
                      link_cap: jax.Array, D: float = D_DEFAULT
                      ) -> Dict[str, jax.Array]:
    """Eq. 2-3 connection ranges + §3.2.2 throttle as a traced program
    (the `global_optimize` fleet path: no provider refactor, skew pair
    weights precomputed, arbitrated `link_cap` joins the throttle)."""
    n = bw.shape[0]
    eye = jnp.eye(n, dtype=bool)
    off = ~eye
    rel = relations_jnp(bw, D).astype(bw.dtype)
    M = M.astype(bw.dtype)

    sum_all = rel.sum() - n                        # skip closeness-1 diag
    max_r = rel.max(axis=1)
    min_cons = jnp.maximum(jnp.floor(rel / sum_all * (M - 1)), 1.0) * ws_pair
    max_cons = jnp.ceil(M * rel / max_r[:, None]) * ws_pair
    min_cons = jnp.where(eye, 1.0, min_cons)
    max_cons = jnp.where(eye, 1.0, max_cons)
    min_cons = jnp.clip(jnp.round(min_cons), 1, 2 * M)
    max_cons = jnp.clip(jnp.round(max_cons), 1, 2 * M)
    max_cons = jnp.maximum(max_cons, min_cons)

    capped = jnp.isfinite(link_cap) & off
    cap_cons = jnp.ceil(link_cap / jnp.maximum(bw, 1e-9))
    cap_cons = jnp.maximum(jnp.where(capped, cap_cons, max_cons), 1)
    cap_cons = jnp.minimum(cap_cons, 2 * M)
    max_cons = jnp.maximum(jnp.minimum(max_cons, cap_cons), 1)
    min_cons = jnp.minimum(min_cons, max_cons)

    min_bw = bw * min_cons
    max_bw = bw * max_cons
    T = jnp.where(off, max_bw, 0.0).sum(axis=1) / (n - 1)
    throttle = jnp.where(off & (max_bw > T[:, None]), T[:, None], jnp.inf)
    throttle = jnp.where(off, jnp.minimum(throttle, link_cap), throttle)
    return {"min_cons": min_cons.astype(jnp.int32),
            "max_cons": max_cons.astype(jnp.int32),
            "min_bw": min_bw, "max_bw": max_bw,
            "unit_bw": bw, "throttle": throttle}


def split_budget_jnp(m_total: int, w: jax.Array, present: jax.Array
                     ) -> jax.Array:
    """Masked port of `core.global_opt.split_budget`: largest-remainder
    shares of `m_total` over the PRESENT jobs (floor 1, repayment of
    floor bumps); absent jobs return `m_total` so a min-reduction over
    DCs ignores them."""
    n_present = present.sum()
    wp = jnp.where(present, jnp.maximum(w, 1e-9), 0.0)
    quota = jnp.where(present,
                      m_total * wp / jnp.maximum(wp.sum(), 1e-300), 0.0)
    share = jnp.floor(quota)
    # absent jobs rank last (frac -1) so floor bumps stay with the
    # present; stable argsort ties break toward the earlier tenant
    frac = jnp.where(present, quota - share, -1.0)
    order = jnp.argsort(-frac, stable=True)
    rank = jnp.argsort(order)
    leftover = m_total - share.sum()
    share = share + (rank < leftover)
    share = jnp.where(present, jnp.maximum(share, 1.0), 0.0)

    def cond(s):
        over = jnp.where(present, s, 0.0).sum() > m_total
        return over & (jnp.max(jnp.where(present, s, 0.0)) > 1)

    def body(s):
        rich = jnp.argmax(jnp.where(present, s, -1.0))
        return s.at[rich].add(-1.0)

    share = lax.while_loop(cond, body, share)
    share = jnp.where(m_total <= n_present, 1.0, share)
    return jnp.where(present, share, float(m_total))


def connection_budgets_jnp(presence: jax.Array, weights: jax.Array,
                           m_total: int) -> jax.Array:
    """Per-job scalar budgets [J]: min over the job's DCs of its
    largest-remainder share at that DC (`fleet.arbiter` port)."""
    shares = jax.vmap(lambda p: split_budget_jnp(m_total, weights, p))(
        presence.T)                                        # [N,J]
    budgets = jnp.minimum(shares.min(axis=0), float(m_total))
    return jnp.maximum(budgets, 1.0)


def link_shares_jnp(presence: jax.Array, weights: jax.Array,
                    cap_est: jax.Array) -> jax.Array:
    """Per-job per-link caps [J,N,N] (`fleet.arbiter.link_shares`
    port): pairs contended by >1 job split `cap_est` by priority
    weight; sole-tenant and unused pairs stay uncapped."""
    pres = presence.astype(cap_est.dtype)                  # [J,N]
    wpres = weights[:, None] * pres
    weight_sum = jnp.einsum("ja,jb->ab", wpres, pres)
    count = jnp.einsum("ja,jb->ab", pres, pres)
    on_pair = pres[:, :, None] * pres[:, None, :] > 0      # [J,N,N]
    mask = (count > 1)[None] & on_pair
    split = cap_est[None] * weights[:, None, None] \
        / jnp.maximum(weight_sum, 1e-12)[None]
    return jnp.where(mask, split, jnp.inf)


def aimd_step_jnp(cons: jax.Array, target: jax.Array,
                  ranges: Dict[str, jax.Array], monitored: jax.Array,
                  delta: float = SIGNIFICANT_MBPS
                  ) -> Tuple[jax.Array, jax.Array]:
    """`AimdAgent.step` for every source row at once ([..., P, P]
    elementwise; the diagonal — each agent's own DC — is untouched)."""
    n = cons.shape[-1]
    eye = jnp.eye(n, dtype=bool)
    cap = jnp.minimum(ranges["max_bw"], ranges["throttle"])
    dec = monitored < target - delta
    inc = jnp.abs(monitored - target) <= delta
    new_cons = jnp.where(
        dec, jnp.maximum(ranges["min_cons"], cons // 2),
        jnp.where(inc, jnp.minimum(ranges["max_cons"], cons + 1), cons))
    new_t = jnp.where(
        dec, jnp.maximum(ranges["min_bw"], target / 2),
        jnp.where(inc, jnp.minimum(cap, target + ranges["unit_bw"]),
                  target))
    new_t = jnp.clip(new_t, ranges["min_bw"], cap)
    return (jnp.where(eye, cons, new_cons),
            jnp.where(eye, target, new_t))


# ----------------------------------------------------------------------
# WAN schedule precomputation (the numpy side of the contract)
# ----------------------------------------------------------------------
class _ScheduleShim:
    """The tiny engine surface WAN events mutate while a schedule is
    precomputed (`event.apply(eng)` wants `.sim`, `.link`, `.diurnal`,
    `.step`)."""

    def __init__(self, sim):
        self.sim = sim
        self.diurnal: Optional[Tuple[float, int, int]] = None
        self.step = 0

    def link(self, pair: Sequence[str]) -> Tuple[int, int]:
        """Resolve a (region, region) pair to simulator indices."""
        a, b = pair
        return self.sim.regions.index(a), self.sim.regions.index(b)


def make_schedule(sim, steps: int, events: Tuple[Timed, ...] = ()
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Precompute the WAN inputs of `steps` fused ticks:
    ``(single[T,N,N], background[T,N,N])``.

    MUTATES `sim` exactly as `steps` sequential fleet ticks would
    (events applied at their step, diurnal modulation, one
    ``advance()`` per tick), so a `FusedFleet.run` leaves the shared
    simulator where the sequential engine would have left it and
    sequential ticks can continue afterwards. Only WAN-state events
    (`SCHEDULE_EVENTS`) are accepted — job churn changes tensor shapes.
    """
    import math
    shim = _ScheduleShim(sim)
    timeline: Dict[int, List[Timed]] = {}
    for t in events:
        if not isinstance(t.event, SCHEDULE_EVENTS):
            raise ValueError(
                f"{type(t.event).__name__} is not replayable in a fused "
                f"schedule; accepted: "
                f"{[e.__name__ for e in SCHEDULE_EVENTS]}")
        if getattr(t.event, "notify", False):
            raise ValueError("notify=True is a single-job-engine concept")
        timeline.setdefault(t.step, []).append(t)
    n = sim.N
    single = np.empty((steps, n, n))
    bg = np.zeros((steps, n, n))
    for k in range(steps):
        shim.step = k
        for t in timeline.get(k, ()):
            t.event.apply(shim)
        if shim.diurnal is not None:
            amp, period, start = shim.diurnal
            phase = 2.0 * math.pi * (k - start) / max(period, 1)
            sim.modulation = 1.0 + amp * math.sin(phase)
        sim.advance()
        single[k] = sim.link_bw_now()
        if sim.background_conns is not None:
            b = np.asarray(sim.background_conns, np.float64).copy()
            np.fill_diagonal(b, 0.0)
            bg[k] = np.maximum(b, 0.0)
    return single, bg


# ----------------------------------------------------------------------
# The fused engine
# ----------------------------------------------------------------------
@dataclass
class FusedState:
    """The persistent cross-tick state: each job's in-force connection
    matrix and AIMD target BW at slice scale."""
    cons: np.ndarray          # [J,P,P] int32
    target: np.ndarray        # [J,P,P] float64


class FusedFleet:
    """A :class:`FleetController`'s job set compiled into one tick
    program (see module docstring for the determinism contract)."""

    def __init__(self, fleet):
        """Snapshot the fleet's static spec and live AIMD state.
        Requires a deterministic capture path (``snapshot_sigma == 0``,
        ``host_sigma == 0``), a fixed job set with equal slice sizes,
        and no attached deferred planners (their `search_many` flush is
        host-side Python)."""
        sim = fleet.sim
        if sim.snapshot_sigma != 0 or sim.host_sigma != 0:
            raise ValueError(
                "fused ticks need a deterministic capture path: build "
                "the simulator with snapshot_sigma=0 and host_sigma=0")
        if fleet._planners:
            raise ValueError("fused ticks do not flush deferred "
                             "placement planners; detach them first")
        jobs = list(fleet.jobs.values())
        if not jobs:
            raise ValueError("fused fleet needs at least one job")
        sizes = {len(j.spec.dcs) for j in jobs}
        if len(sizes) != 1:
            raise ValueError(f"fused fleet needs equal slice sizes, "
                             f"got {sorted(sizes)}")
        self.fleet = fleet
        self.sim = sim
        self.jobs = jobs
        self.J = len(jobs)
        self.N = sim.N
        self.P = sizes.pop()
        self.m_total = int(fleet.m_total)
        self.ix = np.stack([np.asarray(j.spec.dcs, np.int64)
                            for j in jobs])                # [J,P]
        self.presence = np.zeros((self.J, self.N), bool)
        for j, row in enumerate(self.ix):
            self.presence[j, row] = True
        self.priorities = np.array([max(j.priority, 1e-9) for j in jobs])
        # §3.3.1 pair weights, precomputed numpy-side for exact parity
        from repro.core.global_opt import _pair_weights
        self.ws_pair = np.stack([
            _pair_weights(self.P, j.skew()) for j in jobs])  # [J,P,P]
        self.dists = np.stack([sim.dist[np.ix_(r, r)] for r in self.ix])
        forest = fleet.predictor.forest
        f, t, l = forest.packed()
        self._forest = (jnp.asarray(f), jnp.asarray(t), jnp.asarray(l))
        self._depth = forest.depth
        self._tick_fn = None
        self._scan_cache: Dict[Any, Any] = {}

    # ------------------------------------------------------------------
    def state(self) -> FusedState:
        """Read the live controllers' AIMD state into stacked tensors."""
        cons = np.zeros((self.J, self.P, self.P), np.int32)
        target = np.zeros((self.J, self.P, self.P))
        for j, job in enumerate(self.jobs):
            cons[j] = job.controller.current_conns().astype(np.int32)
            for i, ag in enumerate(job.controller._agents):
                target[j, i] = ag.target_bw
        return FusedState(cons=cons, target=target)

    # ------------------------------------------------------------------
    def _build_tick(self):
        """Trace-time closure: one full arbitrated tick, stacked over
        jobs. Inputs `(carry, (single, bg))`; outputs per-tick stats
        plus the ranges needed to sync agents back after a run."""
        J, P, N = self.J, self.P, self.N
        ix = jnp.asarray(self.ix)
        jidx = jnp.arange(J)
        idx_i, idx_j = np.nonzero(~np.eye(P, dtype=bool))   # static
        n_pairs = len(idx_i)
        eye_p = jnp.eye(P, dtype=bool)
        off_p = ~eye_p
        eye_n = jnp.eye(N, dtype=bool)
        off_n = ~eye_n
        presence = jnp.asarray(self.presence)
        weights = jnp.asarray(self.priorities)
        ws_pair = jnp.asarray(self.ws_pair)
        dists = jnp.asarray(self.dists)
        knee = float(self.sim.knee)
        m_total = self.m_total
        vms = self.sim.vms_per_dc if self.sim.vms_per_dc is not None \
            else np.ones(N)
        egress = jnp.asarray(self.sim.nic_cap * np.asarray(vms, float))
        ingress = egress
        w_rtt = jnp.asarray(np.asarray(self.sim.rtt_weight()))
        feat, thr, leaf = self._forest
        depth = self._depth

        def embed(mats):
            """[J,P,P] -> [J,N,N] (zero elsewhere, diagonal zeroed)."""
            m = jnp.where(off_p, mats, 0.0)
            return jnp.zeros((J, N, N), mats.dtype).at[
                jidx[:, None, None], ix[:, :, None], ix[:, None, :]].set(m)

        def extract(full):
            """[N,N] or [J,N,N] -> [J,P,P] per-job slices."""
            if full.ndim == 2:
                full = jnp.broadcast_to(full, (J, N, N))
            return full[jidx[:, None, None], ix[:, :, None], ix[:, None, :]]

        def fill(aggregates, single):
            """Batched water-fill at this step's link state."""
            b = aggregates.shape[0]
            sb = jnp.broadcast_to(single, (b, N, N))
            rate, iters, ok = fill_rates_loop(
                aggregates, sb, jnp.broadcast_to(egress, (b, N)),
                jnp.broadcast_to(ingress, (b, N)), w_rtt, sb * knee)
            return rate, iters, ok

        def tick(carry, x):
            cons, target = carry                  # [J,P,P] int32/f64
            single, bg = x                        # [N,N]
            reg = embed(cons.astype(single.dtype))            # [J,N,N]
            total = reg.sum(0) + bg

            # probe (capacity estimate) + capture fills share a launch
            ones_off = jnp.where(off_n, 1.0, 0.0)
            rate2, it2, ok2 = fill(
                jnp.stack([ones_off + total, total]), single)
            probe_bw = jnp.where(eye_n, INTRA_DC_BW, rate2[0] * ones_off)
            cap_est = probe_bw * knee

            # arbitration: budgets + per-link caps at slice scale
            budgets = connection_budgets_jnp(presence, weights, m_total)
            caps = link_shares_jnp(presence, weights, cap_est)
            env_cap = extract(caps)                           # [J,P,P]

            # capture: per-tenant credited snapshot at in-force conns
            snap = extract(jnp.where(eye_n, INTRA_DC_BW, rate2[1] * reg))

            # deterministic Table-3 host metrics (host_sigma == 0)
            c_off = jnp.where(off_p, cons.astype(single.dtype), 0.0)
            mem = jnp.clip(0.15 + 0.02 * c_off.sum(-2), 0.05, 0.98)
            cpu = jnp.clip(0.10 + 0.015 * c_off.sum(-1), 0.02, 0.98)
            solo = extract(single)
            squeeze = jnp.maximum(
                0.0, 1.0 - snap / jnp.maximum(solo * c_off, 1e-9))
            retr = jnp.where(off_p, jnp.round(squeeze * 40.0), 0.0)

            # stacked RF predict: one forest pass for the whole fleet
            X = jnp.stack([
                jnp.full((J, n_pairs), float(P), single.dtype),
                snap[:, idx_i, idx_j], mem[:, idx_j], cpu[:, idx_i],
                retr[:, idx_i, idx_j], dists[:, idx_i, idx_j],
            ], axis=-1).reshape(J * n_pairs, 6).astype(jnp.float32)
            vals = forest_predict_jnp(feat, thr, leaf, X, depth)
            vals = jnp.maximum(vals.astype(single.dtype), 1.0)
            pred = jnp.full((J, P, P), INTRA_DC_BW, single.dtype).at[
                :, idx_i, idx_j].set(vals.reshape(J, n_pairs))

            # Eq. 2-3 ranges inside each job's envelope, then AIMD
            ranges = jax.vmap(
                lambda bw_j, m_j, ws_j, lc_j:
                global_ranges_jnp(bw_j, m_j, ws_j, lc_j))(
                    pred, budgets, ws_pair, env_cap)
            new_cons, new_target = aimd_step_jnp(cons, target, ranges,
                                                 snap)

            # register + ONE fleet fill, credited and envelope-clamped
            reg_new = embed(new_cons.astype(single.dtype))
            rate1, it1, ok1 = fill((reg_new.sum(0) + bg)[None], single)
            ach = extract(jnp.where(eye_n, INTRA_DC_BW, rate1[0] * reg_new))
            ach = jnp.where(off_p, jnp.minimum(ach, env_cap), ach)

            ach_off = ach[:, idx_i, idx_j]
            out = {
                "achieved_min": ach_off.min(-1),
                "achieved_mean": ach_off.mean(-1),
                "conns_total": new_cons[:, idx_i, idx_j].sum(-1),
                "budget": budgets,
                "cap_min": env_cap[:, idx_i, idx_j].min(-1),
                "fill_iters": jnp.concatenate([it2, it1]),
                "converged": jnp.all(ok2) & jnp.all(ok1),
                "ranges": ranges,
                "pred": pred,
                "env_cap": env_cap,
            }
            return (new_cons, new_target), out

        return tick

    def _scan_fn(self, detail: bool):
        """jit'd `(carry0, singles, bgs) -> (carry, outs)` over T steps
        (`detail=False` drops the per-tick ranges/pred tensors — the
        shape the B-scenario sweep vmaps)."""
        key = bool(detail)
        if key in self._scan_cache:
            return self._scan_cache[key]
        tick = self._tick_fn or self._build_tick()
        self._tick_fn = tick

        def step(carry, x):
            carry, out = tick(carry, x)
            if not detail:
                out = {k: v for k, v in out.items()
                       if k not in ("ranges", "pred", "env_cap")}
            return carry, out

        fn = jax.jit(lambda carry, singles, bgs:
                     lax.scan(step, carry, (singles, bgs)))
        self._scan_cache[key] = fn
        return fn

    # ------------------------------------------------------------------
    def run(self, steps: int, events: Tuple[Timed, ...] = ()
            ) -> List[Dict[str, Any]]:
        """Run `steps` arbitration epochs in ONE scanned launch, sync
        the resulting AIMD state back into the live controllers (so
        sequential ticks can continue), and return per-tick records
        (the fleet-trace row body minus plan signatures, which are a
        host-side concept)."""
        single, bg = make_schedule(self.sim, steps, events)
        st = self.state()
        with enable_x64():
            (cons, target), outs = self._scan_fn(detail=True)(
                (jnp.asarray(st.cons), jnp.asarray(st.target)),
                jnp.asarray(single), jnp.asarray(bg))
        outs = jax.tree_util.tree_map(np.asarray, outs)
        if not outs["converged"].all():
            from repro.wan.simulator import WaterfillDivergence
            conv = np.asarray(outs["converged"]).reshape(-1)
            bad = int(np.argmax(~conv))
            raise WaterfillDivergence(
                f"a fused-tick water-fill hit its iteration bound at "
                f"tick {bad + 1} of {len(conv)}")
        self._sync_back(np.asarray(cons), np.asarray(target), outs)
        return self._records(steps, outs)

    def sweep(self, singles: np.ndarray, bgs: np.ndarray
              ) -> Dict[str, np.ndarray]:
        """Sweep B scenario variants x T steps in ONE launch from the
        CURRENT fleet state (vmapped scan; state is not written back —
        a sweep is analysis, not execution). `singles`/`bgs`:
        [B,T,N,N] schedules from :func:`make_schedule` over variant
        simulators. Returns stacked per-tick stats [B,T,...]."""
        st = self.state()
        if "sweep" not in self._scan_cache:
            scan = self._scan_fn(detail=False)
            self._scan_cache["sweep"] = jax.jit(
                jax.vmap(scan, in_axes=(None, 0, 0)))
        with enable_x64():
            _, outs = self._scan_cache["sweep"](
                (jnp.asarray(st.cons), jnp.asarray(st.target)),
                jnp.asarray(singles), jnp.asarray(bgs))
        return jax.tree_util.tree_map(np.asarray, outs)

    # ------------------------------------------------------------------
    def _sync_back(self, cons: np.ndarray, target: np.ndarray,
                   outs: Dict[str, Any]) -> None:
        """Install the post-run state into the live fleet: agent conns
        and targets, the final tick's Eq. 2-3 bounds, registered flows,
        and each job's last arbitrated envelope."""
        from repro.control import BudgetEnvelope
        ranges = outs["ranges"]
        for j, job in enumerate(self.jobs):
            ctl = job.controller
            for i, ag in enumerate(ctl._agents):
                ag.cons = cons[j, i].astype(np.int64)
                ag.target_bw = target[j, i].astype(np.float64)
                ag.min_cons = ranges["min_cons"][-1, j, i].astype(np.int64)
                ag.max_cons = ranges["max_cons"][-1, j, i].astype(np.int64)
                ag.min_bw = ranges["min_bw"][-1, j, i]
                ag.max_bw = ranges["max_bw"][-1, j, i]
                ag.unit_bw = ranges["unit_bw"][-1, j, i]
                ag.throttle = ranges["throttle"][-1, j, i]
            ctl.set_envelope(BudgetEnvelope(
                max_conns=int(outs["budget"][-1, j]),
                link_cap=np.asarray(outs["env_cap"][-1, j], np.float64)))
            job.view.register(ctl.current_conns())
        self.fleet.tick_count += len(outs["budget"])

    def _records(self, steps: int, outs: Dict[str, Any]
                 ) -> List[Dict[str, Any]]:
        """Per-tick record dicts compatible with the sequential tick's
        row body (minus `plan_sig`/`kernel_calls`)."""
        base = self.fleet.tick_count - steps
        recs = []
        for t in range(steps):
            rows = [{
                "name": job.name,
                "priority": float(self.priorities[j]),
                "budget": int(outs["budget"][t, j]),
                "cap_min": float(outs["cap_min"][t, j]),
                "achieved_min": float(outs["achieved_min"][t, j]),
                "achieved_mean": float(outs["achieved_mean"][t, j]),
                "conns_total": int(outs["conns_total"][t, j]),
            } for j, job in enumerate(self.jobs)]
            recs.append({"tick": base + t + 1, "n_jobs": self.J,
                         "fill_iters": outs["fill_iters"][t].tolist(),
                         "jobs": rows})
        return recs
