"""Fleet arbitration: split the shared WAN across jobs BEFORE they plan.

Two resources are arbitrated every fleet tick, both by priority-weighted
fair share (Terra-style cross-job scheduling; see PAPERS.md):

* **Per-host connection budget M.** Each DC host can sustain at most
  ``m_total`` parallel WAN connections. For every DC, the jobs whose
  topology slice includes it split the budget by
  :func:`repro.core.global_opt.split_budget` (largest remainder, floor
  of 1); a job's scalar budget is the MINIMUM over its DCs, so the sum
  of budgets at any host never exceeds ``m_total``.
* **Per-link capacity.** For every DC pair shared by more than one
  job, the link's estimated saturation capacity (single-connection
  snapshot BW x the parallelism knee) is split in proportion to
  priority weight. The resulting cap enters each job's
  `global_optimize` via :class:`repro.control.BudgetEnvelope` — it
  clamps ``max_cons`` and joins the §3.2.2 throttle. Links used by a
  single job stay uncapped (there is no cross-job contention to
  arbitrate; WANify's own throttle still applies).

Everything here is vectorized over jobs (presence masks, one einsum per
resource), which together with the batched RF launch and the single
fleet-wide water-fill keeps the per-tick cost sublinear in job count.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.control import BudgetEnvelope
from repro.core.global_opt import split_budget


def connection_budgets(presence: np.ndarray, weights: np.ndarray,
                       m_total: int) -> np.ndarray:
    """Per-job scalar connection budgets.

    presence: [J,N] bool (job j uses DC d); weights: [J] priorities.
    Returns [J] ints: job j's budget = min over its DCs of its
    largest-remainder share of ``m_total`` at that DC.
    """
    J, N = presence.shape
    budgets = np.full(J, m_total, np.int64)
    for d in range(N):
        here = np.flatnonzero(presence[:, d])
        if len(here) == 0:
            continue
        share = split_budget(m_total, weights[here])
        budgets[here] = np.minimum(budgets[here], share)
    return np.maximum(budgets, 1)


def link_shares(presence: np.ndarray, weights: np.ndarray,
                cap_est: np.ndarray) -> np.ndarray:
    """Per-job per-link capacity caps [J,N,N] (np.inf = uncapped).

    ``cap_est`` [N,N] estimates each link's saturation capacity. A pair
    contended by >1 job is split by priority weight; sole-tenant and
    unused pairs stay uncapped.
    """
    pres = presence.astype(np.float64)                       # [J,N]
    wpres = weights[:, None] * pres                          # [J,N]
    weight_sum = np.einsum("ja,jb->ab", wpres, pres)         # [N,N]
    count = np.einsum("ja,jb->ab", pres, pres)               # [N,N]
    shared = count > 1
    caps = np.full(presence.shape[:1] + cap_est.shape, np.inf)
    for j in range(len(weights)):
        on_pair = np.outer(pres[j], pres[j]) > 0
        mask = shared & on_pair
        caps[j][mask] = (cap_est * weights[j]
                         / np.maximum(weight_sum, 1e-12))[mask]
    return caps


def arbitrate(jobs: Sequence[Tuple[str, Sequence[int], float]],
              n_dcs: int, m_total: int, cap_est: np.ndarray,
              reachable: Optional[np.ndarray] = None
              ) -> Dict[str, BudgetEnvelope]:
    """Compute one :class:`BudgetEnvelope` per job.

    jobs: (name, dc_indices, priority) triples; ``cap_est`` [N,N] is
    the fleet's per-link capacity estimate at mesh scale. Each
    envelope's ``link_cap`` is returned at MESH scale — the fleet
    slices it to the job's pod scale before handing it over.

    ``reachable`` (fault plane, optional) is a bool [N,N] mask of live
    links: a DC that can reach no other DC is QUARANTINED — it stops
    counting toward budget splits (jobs that avoided the dead DC grow
    into the freed share) and every unreachable pair's cap is zeroed
    for the jobs spanning it (their envelopes shrink; the §3.2.2
    throttle then steers their connections onto surviving links).
    """
    J = len(jobs)
    if J == 0:
        return {}
    presence = np.zeros((J, n_dcs), bool)
    weights = np.ones(J)
    for j, (_, dcs, prio) in enumerate(jobs):
        presence[j, list(dcs)] = True
        weights[j] = max(float(prio), 1e-9)
    effective = presence
    if reachable is not None:
        off = ~np.eye(n_dcs, dtype=bool)
        live_dc = (np.asarray(reachable, bool) & off).any(axis=1)
        effective = presence & live_dc[None, :]
    budgets = connection_budgets(effective, weights, m_total)
    caps = link_shares(effective, weights, cap_est)
    if reachable is not None:
        dead_pair = ~np.asarray(reachable, bool)
        for j in range(J):
            # zero the cap on every dead pair the job spans — including
            # sole-tenant pairs, which link_shares leaves uncapped
            on_pair = np.outer(presence[j], presence[j])
            caps[j][on_pair & dead_pair] = 0.0
    return {name: BudgetEnvelope(max_conns=int(budgets[j]),
                                 link_cap=caps[j])
            for j, (name, _, _) in enumerate(jobs)}
