"""FleetController — N concurrent WANify jobs over ONE shared WAN.

The paper evaluates one workload at a time (§5); a production fleet
runs many, and their transfers contend on the same inter-DC links —
exactly the "dynamic and simultaneous transfer among DCs" regime
static measurement gets wrong. The fleet controller closes that gap:

* every job is a full :class:`WanifyController` over its own topology
  slice (a :class:`TenantView` of the shared simulator), with its own
  skew weights and priority;
* before any job plans, the :mod:`arbiter` splits the per-host
  connection budget and contended-link capacity into per-job
  :class:`BudgetEnvelope`s by priority-weighted fair share;
* each tick captures every job's snapshot (rival tenants contending —
  and credited), stacks the feature rows, and launches the RF kernel
  ONCE for the whole fleet (:class:`BatchedRfPredictor`);
* achieved BW is solved with ONE fleet-wide water-fill
  (`waterfill_tenants`) and credited per tenant, with each job's
  envelope cap applied as TC shaping;
* attached placement planners (:meth:`FleetController.job_planner`)
  run DEFERRED: the tick flushes every job's pending re-placement
  through one `placement.optimizer.search_many` lock-step pass, fusing
  same-shape search rounds across jobs into shared batched-evaluator
  launches instead of J independent Python searches.

A fleet tick is one arbitration epoch (the paper's 5-second local-
optimizer cadence, fleet-wide): all active jobs replan together so the
batched kernel launch and the single water-fill amortize across jobs —
per-tick cost grows sublinearly in job count (benchmarks/fleet_bench).

Job arrival bootstraps its controller's init plan from the snapshot-
as-prediction ablation (no RF launch), under an envelope arbitrated at
arrival — the one-launch-per-tick invariant holds through churn.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.control import ControllerConfig, WanifyController
from repro.core.predictor import SnapshotPredictor, matrix_from_pairs
from repro.faults.plane import FaultPlane, faults_mode
from repro.fleet import arbiter
from repro.fleet.predictor import BatchedRfPredictor
from repro.fleet.tenant import TenantView
from repro.obs.spans import NULL_TRACER, SpanTracer, obs_mode
from repro.wan.simulator import WanSimulator, WaterfillDivergence
from repro.wan.topology import INTRA_DC_BW


@dataclass(frozen=True)
class JobSpec:
    """One fleet job: a workload slice with a priority.

    `dcs` are global indices into the shared mesh (order = the job's
    pod numbering); `priority` weights every fair-share split;
    `skew_w` is the job's own §3.3.1 data-skew vector (len == len(dcs)).
    """
    name: str
    dcs: Tuple[int, ...]
    priority: float = 1.0
    skew_w: Optional[Tuple[float, ...]] = None


class FleetJob:
    """Runtime state of one admitted job."""

    def __init__(self, spec: JobSpec, view: TenantView,
                 controller: Optional[WanifyController]):
        """Built by :meth:`FleetController.add_job`; not user-facing."""
        self.spec = spec
        self.view = view
        self.controller = controller
        self.priority = float(spec.priority)

    @property
    def name(self) -> str:
        """The job's fleet-unique name (its tenant id on the mesh)."""
        return self.spec.name

    def skew(self) -> Optional[np.ndarray]:
        """The job's skew weights as an array (None = uniform)."""
        if self.spec.skew_w is None:
            return None
        return np.asarray(self.spec.skew_w, np.float64)


class FleetController:
    """Arbitrate one shared WAN across N concurrent WANify jobs."""

    def __init__(self, sim: WanSimulator, predictor: BatchedRfPredictor,
                 m_total: int = 8, jobs: Tuple[JobSpec, ...] = (),
                 obs: Optional[str] = None, faults: Any = None):
        """`m_total` is the per-host connection budget the whole fleet
        shares at each DC; `predictor` serves every job's RF inference
        in one launch per tick. `obs` gates span tracing (repro.obs;
        None defers to $REPRO_OBS, default off) — passive either way.
        `faults` gates the fault plane (repro.faults; a FaultPlane is
        used as-is, else the mode resolves via $REPRO_FAULTS): when
        graceful, blacked-out DCs are quarantined in arbitration,
        poisoned predictions sanitized, and water-fill divergence
        recovered by rolling every job back to its last-good plan."""
        self.sim = sim
        self.predictor = predictor
        self.m_total = int(m_total)
        self.jobs: Dict[str, FleetJob] = {}
        self.tick_count = 0
        self.events: List[str] = []
        self._planners: List[Tuple[str, Any]] = []
        self.tracer = NULL_TRACER
        if obs_mode(obs) == "on":
            self.tracer = SpanTracer()
            self.tracer.watch(self.sim.metrics)
            self.tracer.watch(self.predictor.metrics)
        self.faults: Optional[FaultPlane] = None
        if isinstance(faults, FaultPlane):
            self.faults = faults
        elif faults_mode(faults) == "on":
            self.faults = FaultPlane(self.sim.N, graceful=True)
        if self.faults is not None and self.tracer.enabled:
            self.tracer.watch(self.faults.metrics)
        for spec in jobs:
            self.add_job(spec)

    # ------------------------------------------------------------------
    # membership
    # ------------------------------------------------------------------
    def add_job(self, spec: JobSpec) -> FleetJob:
        """Admit a job: arbitrate envelopes for the grown fleet, then
        bootstrap its controller (snapshot-ablation init plan, no RF
        launch) and register its flows on the shared mesh."""
        if spec.name in self.jobs:
            raise ValueError(f"job {spec.name!r} already in fleet")
        if len(spec.dcs) < 2:
            raise ValueError(
                f"job {spec.name!r} spans {len(spec.dcs)} DC(s); a fleet "
                f"job needs >= 2 (a single DC has no WAN pairs to plan)")
        view = TenantView(self.sim, spec.name, spec.dcs)
        job = FleetJob(spec, view, controller=None)
        self.jobs[spec.name] = job
        envs = self._arbitrate()
        cfg = ControllerConfig(max_conns=self.m_total, advance_sim=False)
        # overlay pinned off: the arbiter splits budgets and credits
        # achieved BW over DIRECT per-pair flows; a job routing through
        # a relay would consume a third DC's share the envelopes don't
        # model (fleet-level overlay is future work), so a global
        # $REPRO_OVERLAY=on must not leak into fleet jobs
        ctl = WanifyController(sim=view, predictor=SnapshotPredictor(),
                               n_pods=view.N, cfg=cfg,
                               envelope=envs[spec.name], overlay="off")
        # the job's internal replan stages (optimize/aimd) show up in
        # the fleet's span tree; its registry joins the delta watch
        # under a per-job namespace so jobs don't clobber each other
        ctl.metrics.namespace = f"job.{spec.name}"
        ctl.tracer = self.tracer
        if self.tracer.enabled:
            self.tracer.watch(ctl.metrics)
        job.controller = ctl
        view.register(ctl.current_conns())
        self.events.append(f"job {spec.name} arrived "
                           f"(dcs={list(spec.dcs)}, prio={job.priority})")
        return job

    def remove_job(self, name: str) -> None:
        """Withdraw a job's flows and drop it; survivors re-arbitrate
        at the next tick (their envelopes grow into the freed share)."""
        job = self.jobs.pop(name)
        job.view.unregister()
        self._planners = [(n, p) for n, p in self._planners if n != name]
        self.events.append(f"job {name} departed")

    def set_priority(self, name: str, priority: float) -> None:
        """Shift a job's weight; takes effect at the next arbitration."""
        self.jobs[name].priority = float(priority)
        self.events.append(f"job {name} priority -> {priority}")

    def job_planner(self, name: str, query, **kwargs):
        """Attach a :class:`repro.placement.PlacementPlanner` to one
        admitted job: the planner prices the query against the job's
        arbitrated :class:`BudgetEnvelope` (its `link_cap` clamps the
        achievable BW), and re-places on every fleet-tick replan. A
        low-priority tenant therefore plans around its fair share of a
        contended link, not the raw capacity.

        Fleet planners run DEFERRED: a tick's replans only mark each
        planner pending, and :meth:`tick` flushes all J pending
        searches through one `placement.optimizer.search_many`
        lock-step pass — same-shape rounds across jobs fuse into
        single batched-evaluator launches instead of J independent
        Python searches."""
        from repro.placement.planner import PlacementPlanner
        planner = PlacementPlanner(self.jobs[name].controller, query,
                                   **kwargs)
        planner.defer_replans()
        self._planners.append((name, planner))
        return planner

    def _flush_planners(self) -> None:
        """Run every pending deferred placement search in one fused
        `search_many` pass and commit the results (detached planners —
        the documented replacement flow — are pruned here, so a job
        that rotates planners doesn't accumulate dead entries)."""
        from repro.placement.optimizer import search_many
        self._planners = [(n, p) for n, p in self._planners
                          if not p._detached]
        owners, tasks = [], []
        for _, planner in self._planners:
            task = planner.pending_task()
            if task is not None:
                owners.append(planner)
                tasks.append(task)
        if not tasks:
            return
        for planner, decision in zip(owners, search_many(tasks)):
            planner.commit(decision)

    # ------------------------------------------------------------------
    # the arbitrated, batched fleet tick
    # ------------------------------------------------------------------
    def capacity_estimate(self) -> np.ndarray:
        """Per-link saturation capacity [N,N] to arbitrate: a 1-second
        single-connection probe under the fleet's current load, scaled
        by the parallelism knee (§2.2)."""
        probe = self.sim.measure_snapshot(np.ones((self.sim.N, self.sim.N)))
        return probe * self.sim.knee

    def _arbitrate(self) -> Dict[str, Any]:
        """Compute and install one envelope per job (slice-scale cap)."""
        triples = [(j.name, j.spec.dcs, j.priority)
                   for j in self.jobs.values()]
        reach = None
        if self.faults is not None and self.faults.graceful:
            # DC quarantine: dead DCs stop counting toward budget
            # splits and dead pairs' caps go to zero, so survivors
            # grow into the freed share while touched jobs shrink
            reach = self.faults.reachable_mask()
        envs = arbiter.arbitrate(triples, self.sim.N, self.m_total,
                                 self.capacity_estimate(),
                                 reachable=reach)
        sliced = {}
        for job in self.jobs.values():
            env = envs[job.name]
            env = type(env)(max_conns=env.max_conns,
                            link_cap=job.view.extract(env.link_cap))
            sliced[job.name] = env
            if job.controller is not None:
                job.controller.set_envelope(env)
        return sliced

    def tick(self, advance: bool = True) -> Dict[str, Any]:
        """One arbitration epoch. Returns a structured record (the
        fleet trace row body; see fleet/trace.py).

        Order per tick: advance simulated time -> arbitrate envelopes
        -> capture every job (batched features) -> ONE RF launch ->
        per-job replan inside its envelope -> register new flows ->
        ONE fleet-wide water-fill for credited achieved BW.
        """
        tr = self.tracer
        self.tick_count += 1
        with tr.span("tick", tick=self.tick_count):
            if advance:
                self.sim.advance()
            with tr.span("arbitrate"):
                envs = self._arbitrate()

            # capture first, all jobs, against LAST tick's registered
            # flows
            with tr.span("capture"):
                captures = []
                for job in self.jobs.values():
                    conns = job.controller.current_conns()
                    X, raw = job.controller.monitor.capture(conns)
                    captures.append((job, X, raw))
            rows: List[Dict[str, Any]] = []
            if captures:
                with tr.span("predict", delta=True):
                    X_all = np.vstack([X for _, X, _ in captures])
                    vals = self.predictor.predict_rows(X_all)  # ONE launch
                    parts = self.predictor.split_rows(
                        vals, [len(X) for _, X, _ in captures])
                with tr.span("replan", delta=True):
                    for (job, _, raw), v in zip(captures, parts):
                        P = job.controller.n_pods
                        pred = matrix_from_pairs(v, P, diag=INTRA_DC_BW)
                        if self.faults is not None and self.faults.graceful:
                            # quarantine poisoned rows before the job's
                            # solver sees them (raw is at slice scale —
                            # the job's monitor wraps its TenantView)
                            pred = self.faults.sanitize_matrix(
                                pred, raw["snapshot_bw"])
                        job.controller.replan(
                            skew_w=job.skew(), reason="fleet",
                            step=self.tick_count, capture=raw, pred=pred)
                        job.view.register(job.controller.current_conns())
            with tr.span("planners"):
                self._flush_planners()
            with tr.span("waterfill", delta=True):
                try:
                    if self.faults is not None \
                            and self.faults.solver_failing(self.faults.step):
                        raise WaterfillDivergence(
                            "injected water-fill divergence (SolverFault)")
                    achieved = self.achieved()
                except WaterfillDivergence as exc:
                    achieved = self._recover_divergence(exc)
            for job in self.jobs.values():
                P = job.controller.n_pods
                off = ~np.eye(P, dtype=bool)
                bw = achieved[job.name]
                env = envs[job.name]
                cap_off = env.link_cap[off]
                rows.append({
                    "name": job.name,
                    "priority": job.priority,
                    "budget": int(env.max_conns),
                    "cap_min": float(cap_off.min()),
                    "plan_sig": job.controller.plan.signature(),
                    "achieved_min": float(bw[off].min()),
                    "achieved_mean": float(bw[off].mean()),
                    "conns_total": int(job.controller.current_conns()[off]
                                       .sum()),
                })
            return {"tick": self.tick_count, "n_jobs": len(self.jobs),
                    "kernel_calls": self.predictor.kernel_calls,
                    "jobs": rows}

    def _recover_divergence(self, exc: WaterfillDivergence
                            ) -> Dict[str, np.ndarray]:
        """Fleet-wide water-fill divergence: graceful mode rolls EVERY
        job back to its last-known-good plan (the registered flows a
        previous tick is known to have filled) and retries the fill
        once; without a graceful plane the divergence propagates with
        tick context attached."""
        fp = self.faults
        if fp is None or not fp.graceful:
            raise WaterfillDivergence(
                f"{exc} (fleet tick {self.tick_count})") from exc
        with self.tracer.span("recover"):
            fp.note_rollback()
            for job in self.jobs.values():
                job.controller.rollback_plan(step=self.tick_count)
                job.view.register(job.controller.current_conns())
            try:
                return self.achieved()
            except WaterfillDivergence as exc2:
                raise WaterfillDivergence(
                    f"{exc2} (fleet tick {self.tick_count}, after "
                    f"last-known-good rollback)") from exc2

    def fused(self):
        """Compile the CURRENT job set into a :class:`repro.fleet.fused.
        FusedFleet` — the whole tick as one jit program, scanned over
        steps / vmapped over scenario grids. Requires the fused
        determinism contract (deterministic captures, fixed jobs with
        equal slice sizes, no deferred planners); see fused.py.

        Memoized on the job set / priorities / budget, so repeated
        `run_fused` calls reuse the compiled scan instead of retracing
        (live AIMD state is read fresh at each run).

        Obs spans cover the SEQUENTIAL tick only: the fused path is one
        jit program with no per-stage Python boundaries to time."""
        from repro.fleet.fused import FusedFleet
        key = (tuple((j.name, j.spec.dcs, j.priority, j.spec.skew_w)
                     for j in self.jobs.values()),
               self.m_total, id(self.predictor.forest),
               tuple(n for n, _ in self._planners))
        cached = getattr(self, "_fused_cache", None)
        if cached is not None and cached[0] == key:
            return cached[1]
        ff = FusedFleet(self)
        self._fused_cache = (key, ff)
        return ff

    def run_fused(self, steps: int, events: Tuple = ()
                  ) -> List[Dict[str, Any]]:
        """Run `steps` arbitration epochs in ONE scanned jit launch and
        sync the resulting AIMD state back into the live controllers
        (sequential `tick()` calls can continue afterwards). Returns
        per-tick records (the `tick()` row body minus plan signatures)."""
        return self.fused().run(steps, events=events)

    def achieved(self) -> Dict[str, np.ndarray]:
        """Credited achieved BW per job at slice scale: ONE fleet-wide
        water-fill over every registered tenant, then each job's
        envelope cap applied as TC shaping (§3.2.2)."""
        regs = {name: self.sim.tenant_conns[name]
                for name in self.jobs if name in self.sim.tenant_conns}
        per_tenant = self.sim.waterfill_tenants(regs)
        out = {}
        for job in self.jobs.values():
            bw = job.view.extract(per_tenant[job.name])
            env = job.controller.envelope
            if env is not None and env.link_cap is not None:
                off = ~np.eye(job.view.N, dtype=bool)
                bw = np.where(off, np.minimum(bw, env.link_cap), bw)
            out[job.name] = bw
        return out
