"""Sharded checkpointing with manifest + elastic resharding.

Layout:  <dir>/step_<N>/
           manifest.json        {step, leaf paths, shapes, dtypes, done}
           shard_<i>.npz        flattened leaves (chunked by size)

Writes are atomic (tmp dir + rename) so a crash mid-write never corrupts
the restore point; `latest_step` only returns manifests marked done —
that is the restart contract for node failures. Elastic rescale: params
are stored UNSHARDED (gathered), so a restart may use any mesh/pod count
— the WANify RF covers the new cluster size (paper §3.3.2).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SHARD_BYTES = 512 << 20


def _flatten(tree: Any) -> Tuple[List[Tuple[str, np.ndarray]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = [(jax.tree_util.keystr(path), np.asarray(leaf))
             for path, leaf in flat]
    return items, treedef


# npz cannot serialize ml_dtypes (bfloat16 etc.) — store raw uint bytes
# plus the dtype name in the manifest.
def _encode(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name == "bfloat16":
        return arr.view(np.uint16), name
    if name.startswith("float8"):
        return arr.view(np.uint8), name
    return arr, name


def _decode(arr: np.ndarray, name: str) -> np.ndarray:
    if name == arr.dtype.name:
        return arr
    import ml_dtypes
    return arr.view(np.dtype(getattr(ml_dtypes, name)))


def save(ckpt_dir: str, step: int, tree: Any, *, async_: bool = False
         ) -> Optional[threading.Thread]:
    """Atomic checkpoint write; async_=True returns the writer thread
    (overlaps the next train steps — fault-tolerance without stalls)."""
    items, _ = _flatten(tree)

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
        try:
            shards, cur, cur_bytes = [], {}, 0
            dtypes = {}
            for name, arr in items:
                enc, dt = _encode(arr)
                dtypes[name] = dt
                cur[name] = enc
                cur_bytes += arr.nbytes
                if cur_bytes >= _SHARD_BYTES:
                    shards.append(cur)
                    cur, cur_bytes = {}, 0
            if cur:
                shards.append(cur)
            names = []
            for i, sh in enumerate(shards):
                np.savez(os.path.join(tmp, f"shard_{i}.npz"), **sh)
                names.append(f"shard_{i}.npz")
            manifest = {
                "step": step,
                "shards": names,
                "leaves": [n for n, _ in items],
                "dtypes": dtypes,
                "done": True,
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    os.makedirs(ckpt_dir, exist_ok=True)
    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_"):
            mf = os.path.join(ckpt_dir, d, "manifest.json")
            if os.path.exists(mf):
                try:
                    with open(mf) as f:
                        m = json.load(f)
                    if m.get("done"):
                        steps.append(m["step"])
                except (json.JSONDecodeError, KeyError):
                    continue
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of `tree_like`; `shardings` (optional
    pytree of NamedSharding) places leaves for the CURRENT mesh — this is
    the elastic-rescale path (checkpoint is mesh-agnostic)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data: Dict[str, np.ndarray] = {}
    dtypes = manifest.get("dtypes", {})
    for sh in manifest["shards"]:
        with np.load(os.path.join(d, sh)) as z:
            for k in z.files:
                data[k] = _decode(z[k], dtypes.get(k, z[k].dtype.name))
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_leaves(shardings)
    out = []
    for i, (path, like) in enumerate(flat):
        key = jax.tree_util.keystr(path)
        arr = data[key]
        if hasattr(like, "dtype") and arr.dtype != like.dtype:
            arr = arr.astype(like.dtype)
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
