"""Lifecycle benchmark — frozen predictor + periodic full probing vs
the online lifecycle (drift -> targeted probes -> refresh), as one
tracked artifact.

Each row in `BENCH_lifecycle.json` is one (seed, mode) run of the
`provider_shift_drift` scenario from the SAME pretrained predictor:

  * ``mode="frozen"`` — the predictor never refits; monitoring is
    priced as snapshots plus Tetrium's 30-simulated-minute full-probe
    cadence (the paper's Table-2 baseline);
  * ``mode="lifecycle"`` — the full loop: free residual observation,
    EWMA drift detection, drift-gated >=20 s probes, collection-phase
    refit + atomic forest swap.

The tracked contract (smoke-guarded in CI): the lifecycle run's
post-shift residual beats the frozen run's AND its Eq. 1 monitoring
dollars come in below the frozen baseline's — accuracy recovered for
LESS money, the whole point of replacing cadence with drift gating.

``--smoke`` keeps the full 40-step shift+recovery window (the run is
already CI-sized; shortening it would void the contract being gated).

Run:  PYTHONPATH=src python benchmarks/lifecycle_bench.py
          [--seed N] [--out FILE] [--json [PATH]] [--smoke]
"""
from __future__ import annotations

import time

try:
    from benchmarks.common import bench_parser, emit
except ImportError:            # run as a script: sys.path[0] is benchmarks/
    from common import bench_parser, emit
from repro.lifecycle import run_lifecycle_comparison

SCENARIO = "provider_shift_drift"
PRE_STEPS = 15                 # pretrain window = the pre-shift regime
SHIFT_STEP = 15                # provider shift lands here
POST_FROM = 25                 # post-recovery accuracy window start


def bench_lifecycle(seed: int = 3, smoke: bool = False):
    """Two rows per seed — the same drift weather replayed frozen vs
    lifecycle from bit-identical pretrained predictors."""
    del smoke                  # full window always (see module docstring)
    t0 = time.time()
    cmp_ = run_lifecycle_comparison(scenario=SCENARIO, seed=seed,
                                    pre_steps=PRE_STEPS)
    elapsed = time.time() - t0
    rows = []
    for mode in ("frozen", "lifecycle"):
        m = cmp_["modes"][mode]
        resid = m["resid"]
        rows.append({
            "kind": "scenario",
            "scenario": SCENARIO,
            "mode": mode,
            "seed": seed,
            "steps": m["steps"],
            "resid_pre": round(sum(resid[:SHIFT_STEP])
                               / SHIFT_STEP, 4),
            "resid_post": round(sum(resid[POST_FROM:])
                                / len(resid[POST_FROM:]), 4),
            "resid_end": round(resid[-1], 4),
            "signal_steps": m["signal_steps"],
            "refresh_steps": m["refresh_steps"],
            "refreshes": m["refreshes"],
            "full_probes": m["full_probes"],
            "snapshots": m["snapshots"],
            "monitor_usd": round(m["monitor_usd"], 4),
            "trace_sha": m["trace_sha"][:16],
            "elapsed_s": round(elapsed, 3),
        })
    return rows


def main() -> None:
    """CLI entry point (see module docstring for the flags)."""
    ap = bench_parser(__doc__.splitlines()[0], name="lifecycle",
                      default_seed=3)
    args = ap.parse_args()
    rows = bench_lifecycle(seed=args.seed, smoke=args.smoke)
    emit("lifecycle", rows, args)


if __name__ == "__main__":
    main()
