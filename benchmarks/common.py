"""Shared benchmark machinery: the GDA query model used by every
latency/cost table (Table 4, Fig. 5-10), plus the machine-readable
output writer every JSON benchmark shares.

A query stage moves an intermediate-data volume matrix V[i,j] (Gb)
between DCs; its network time is the paper's bottleneck formula
max_ij V_ij / BW_ij (Fig. 2d). A WAN-aware placement (Tetrium/Kimchi
stand-in) chooses per-DC task fractions from ESTIMATED BWs; latency is
then evaluated under the TRUE runtime BW — inaccurate estimates yield
sub-optimal placements exactly as in §2.2. (The richer stage-DAG
placement layer lives in `repro.placement`; this module keeps the
original single-vector model the paper-table benches consume.)

Machine-readable output: every JSON bench builds its CLI with
`bench_parser(name=...)` and finishes with `emit(name, rows, args)` —
`--json [PATH]` writes `BENCH_<name>.json` ({"bench", "schema",
"rows"}) next to the working directory so the perf trajectory is
tracked across PRs instead of scraped from stdout; `--smoke` asks the
bench for CI-sized inputs.
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.core.global_opt import GlobalPlan, global_optimize
# single source of truth for the worker price and the Fig. 2d
# bottleneck formula (the placement layer owns both)
from repro.placement.cost import INSTANCE_USD_PER_HOUR, bottleneck_time_s
from repro.wan.monitor import NET_COST_PER_GB as EGRESS_USD_PER_GB
from repro.wan.simulator import WanSimulator

BENCH_SCHEMA = 1


def bench_parser(description: str, name: str,
                 default_seed: int = 0) -> argparse.ArgumentParser:
    """Shared CLI for the JSON benchmarks: `--seed`, `--out` (pretty
    JSON to a file instead of stdout), `--json [PATH]` (machine-
    readable `BENCH_<name>.json`), and `--smoke` (tiny CI sizes)."""
    ap = argparse.ArgumentParser(description=description)
    ap.add_argument("--seed", type=int, default=default_seed)
    ap.add_argument("--out", type=str, default=None,
                    help="write pretty JSON here instead of stdout")
    ap.add_argument("--json", nargs="?", const=f"BENCH_{name}.json",
                    default=None, metavar="PATH",
                    help=f"also write machine-readable "
                         f"BENCH_{name}.json (or PATH)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes so CI can smoke-run the bench")
    return ap


def write_bench_json(name: str, rows: List[Any],
                     path: Optional[str] = None) -> str:
    """Write the cross-PR trajectory document `BENCH_<name>.json`
    ({"bench", "schema", "rows"}) and return the path written."""
    path = path or f"BENCH_{name}.json"
    doc = {"bench": name, "schema": BENCH_SCHEMA, "rows": rows}
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def emit(name: str, rows: List[Any], args: argparse.Namespace) -> None:
    """Finish a bench run: pretty JSON to stdout (or `--out`), plus the
    machine-readable `BENCH_<name>.json` when `--json` was passed."""
    doc = json.dumps(rows, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(doc + "\n")
        sys.stderr.write(f"[{name}] wrote {args.out}\n")
    else:
        print(doc)
    if getattr(args, "json", None):
        path = write_bench_json(name, rows, args.json)
        sys.stderr.write(f"[{name}] wrote {path}\n")


def stage_network_time(volume_gb: np.ndarray, bw_mbps: np.ndarray) -> float:
    """Slowest link time in seconds (paper Fig. 2d) — delegates to the
    placement layer's bottleneck formula so the two can't diverge."""
    return bottleneck_time_s(volume_gb, bw_mbps)


def shuffle_volumes(data_gb: np.ndarray, frac: np.ndarray) -> np.ndarray:
    """All-to-all shuffle: DC i sends data_i * frac_j to DC j."""
    v = np.outer(data_gb, frac)
    np.fill_diagonal(v, 0.0)
    return v


def place_tasks(data_gb: np.ndarray, bw_est: np.ndarray,
                iters: int = 200) -> np.ndarray:
    """Greedy placement minimizing the bottleneck under estimated BW
    (the heterogeneous-BW-aware move of Tetrium/Kimchi)."""
    n = len(data_gb)
    frac = np.ones(n) / n
    best = stage_network_time(shuffle_volumes(data_gb, frac), bw_est)
    rng = np.random.default_rng(0)
    for _ in range(iters):
        i, j = rng.integers(0, n, 2)
        if i == j:
            continue
        delta = min(0.05, frac[i])
        cand = frac.copy()
        cand[i] -= delta
        cand[j] += delta
        t = stage_network_time(shuffle_volumes(data_gb, cand), bw_est)
        if t < best:
            best, frac = t, cand
    return frac


@dataclass
class QueryResult:
    latency_s: float
    cost_usd: float
    min_bw: float
    net_s: float = 0.0


def run_query(sim: WanSimulator, data_gb: np.ndarray,
              bw_est: np.ndarray, *, conns: Optional[np.ndarray] = None,
              cap: Optional[np.ndarray] = None,
              compute_s: float = 120.0, n_stages: int = 2) -> QueryResult:
    """Place with `bw_est`, execute under the simulator's TRUE runtime
    BW with `conns` parallel connections (default single)."""
    n = sim.N
    frac = place_tasks(data_gb, bw_est)
    c = np.ones((n, n)) if conns is None else np.asarray(conns, float)
    true_bw = sim.measure_simultaneous(c, cap=cap)
    vol = shuffle_volumes(data_gb, frac)
    t_net = n_stages * stage_network_time(vol, true_bw)
    latency = compute_s + t_net
    egress_gb = float(vol.sum()) / 8.0 * n_stages      # Gb -> GB
    cost = latency / 3600.0 * n * INSTANCE_USD_PER_HOUR \
        + egress_gb * EGRESS_USD_PER_GB
    off = ~np.eye(n, dtype=bool)
    return QueryResult(latency, cost, float(true_bw[off].min()), t_net)


def wanify_inputs(sim: WanSimulator, predictor=None, M: int = 8,
                  w_s=None) -> Tuple[np.ndarray, GlobalPlan]:
    """Predicted runtime BW (RF if given, else true runtime + noise) and
    the global plan."""
    if predictor is not None:
        from repro.wan.monitor import SnapshotMonitor
        _, raw = SnapshotMonitor(sim).capture()
        pred = predictor.predict_matrix(
            sim.N, raw["snapshot_bw"], raw["mem_util"], raw["cpu_load"],
            raw["retrans"], raw["dist"])
    else:
        pred = sim.measure_runtime()
    plan = global_optimize(pred, M=M, w_s=w_s)
    return pred, plan


# The paper's TPC-DS query classes: (name, total intermediate Gb,
# compute seconds) — light 82, average 95/11, heavy 78 (§5.2)
TPCDS = {
    "q82": (6.0, 180.0),
    "q95": (60.0, 240.0),
    "q11": (90.0, 300.0),
    "q78": (160.0, 420.0),
}


def query_volumes(total_gb: float, n: int, seed: int = 0,
                  skew: Optional[np.ndarray] = None) -> np.ndarray:
    rng = np.random.default_rng(seed)
    d = rng.dirichlet(np.ones(n) * 3) * total_gb
    if skew is not None:
        d = d * skew
        d = d / d.sum() * total_gb
    return d
