"""Micro-benchmarks of the Pallas kernels (interpret mode on CPU — the
numbers are correctness-path timings, not TPU perf) and the wansync
schedule's analytic wire model."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _timeit(fn, *args, reps=5) -> float:
    fn(*args)                                  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def bench_kernels() -> List[Row]:
    from repro.kernels import ops
    rows = []
    x = jax.random.normal(jax.random.key(0), (1024, 1024), jnp.float32)
    us = _timeit(lambda v: ops.quantize(v, bits=8), x)
    rows.append(("kernel.quantize_1Mx4B_us", us,
                 f"{x.nbytes / (us / 1e6) / 1e9:.2f} GB/s interpret"))
    q, s = ops.quantize(x, bits=8)
    us = _timeit(lambda a, b: ops.dequantize(a, b), q, s)
    rows.append(("kernel.dequantize_us", us, ""))

    from repro.core.forest import RandomForest
    rng = np.random.default_rng(0)
    X = rng.normal(size=(400, 6)).astype(np.float32)
    y = X[:, 0].astype(np.float32)
    rf = RandomForest(n_trees=100, depth=10).fit(X, y)
    f, t, l = [jnp.asarray(a) for a in rf.packed()]
    Xt = jnp.asarray(rng.normal(size=(128, 6)).astype(np.float32))
    us = _timeit(lambda v: ops.rf_predict(f, t, l, v, depth=10), Xt)
    rows.append(("kernel.rf_predict_128x100trees_us", us, ""))

    B, nC, Q, H, P, N = 1, 2, 64, 8, 32, 32
    ks = jax.random.split(jax.random.key(1), 4)
    xq = jax.random.normal(ks[0], (B, nC, Q, H, P)) * 0.1
    Bq = jax.random.normal(ks[1], (B, nC, Q, N)) * 0.3
    Cq = jax.random.normal(ks[2], (B, nC, Q, N)) * 0.3
    da = -jnp.abs(jax.random.normal(ks[3], (B, nC, H, Q))) * 0.1
    us = _timeit(lambda a, b, c, d: ops.ssd_chunk(a, b, c, d), xq, Bq, Cq, da)
    rows.append(("kernel.ssd_chunk_us", us, ""))
    return rows


def bench_wansync_model() -> List[Row]:
    """Analytic cross-pod sync time on the calibrated WAN: bytes on each
    offset class / link BW, with and without the WANify plan."""
    from repro.core.plan import WanPlan
    from repro.control import offset_schedule
    from repro.core.global_opt import global_optimize
    from repro.wan.simulator import WanSimulator
    rows = []
    grad_gb = 8 * 8                       # 8 GB of grads in Gb
    for pods in (2, 4, 8):
        sim = WanSimulator(seed=3)
        pred = sim.measure_runtime()[:pods, :pods]
        plan = WanPlan.from_global(global_optimize(pred, M=8))
        base_plan = WanPlan.uniform(pods)
        for name, p in [("wanify", plan), ("uniform", base_plan)]:
            conns = np.array(p.conns, float)
            bw = sim.measure_simultaneous(
                np.pad(conns, (0, 8 - pods)))[:pods, :pods]
            off = ~np.eye(pods, dtype=bool)
            sched = offset_schedule(p)
            t = 0.0
            for ph in sched:
                o = ph["offset"]
                bits = ph["bits"] if name == "wanify" else 32
                pair_bw = min(bw[i][(i + o) % pods] for i in range(pods))
                t += (grad_gb / pods) * (bits / 32.0) * 1000.0 / max(pair_bw, 1)
            rows.append((f"wansync.p{pods}.{name}_s", t,
                         f"min_link={bw[off].min():.0f}Mbps"))
    return rows
