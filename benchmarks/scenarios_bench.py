"""Scenario benchmark — runs every named scenario from
repro.scenarios.library through the closed loop and emits one JSON
document of per-scenario throughput / replan / compile-cache metrics.

Run:  PYTHONPATH=src python benchmarks/scenarios_bench.py
          [--out FILE] [--json [PATH]] [--smoke]

`--json` additionally writes the machine-readable BENCH_scenarios.json
trajectory document; `--smoke` truncates every scenario to a few steps
so CI can run the bench end to end.

Output schema (per scenario):
  {"scenario": ..., "seed": ..., "steps": ..., "replans": {reason: n},
   "throughput_mbps": ..., "achieved_min_mbps": ...,
   "achieved_mean_mbps": ..., "distinct_plans": ...,
   "cache_builds": ..., "cache_hits": ..., "wall_s": ...,
   "sle": {"band", "accuracy", "capacity", "fairness",
           "responsiveness_steps", "monitoring_usd"}}

The `sle` block is the Mist-style health rollup from repro.obs.sle:
prediction-accuracy / capacity / fairness SLEs, replan responsiveness,
and the Eq. 1 monitoring-cost meter.
"""
from __future__ import annotations

import sys
import time

try:
    from benchmarks.common import bench_parser, emit
except ImportError:            # run as a script: sys.path[0] is benchmarks/
    from common import bench_parser, emit
from repro.obs import scenario_sle
from repro.scenarios import ScenarioEngine, get_scenario, scenario_names

SEED = 0
SMOKE_STEPS = 8


def bench_scenarios(seed: int = SEED, smoke: bool = False):
    rows = []
    for name in scenario_names():
        spec = get_scenario(name)
        if smoke:
            spec.steps = min(spec.steps, SMOKE_STEPS)
        t0 = time.time()
        eng = ScenarioEngine(spec, seed=seed)
        res = eng.run()
        row = res.summary()
        row["wall_s"] = round(time.time() - t0, 3)
        row["sle"] = scenario_sle(res.trace, n_dcs=eng.sim.N)
        rows.append(row)
        sys.stderr.write(f"[scenarios] {name} done in {row['wall_s']}s\n")
    return rows


def main() -> None:
    args = bench_parser(__doc__, "scenarios", default_seed=SEED).parse_args()
    emit("scenarios", bench_scenarios(args.seed, smoke=args.smoke), args)


if __name__ == "__main__":
    main()
