"""Scenario benchmark — runs every named scenario from
repro.scenarios.library through the closed loop and emits one JSON
document of per-scenario throughput / replan / compile-cache metrics.

Run:  PYTHONPATH=src python benchmarks/scenarios_bench.py [--out FILE]

Output schema (per scenario):
  {"scenario": ..., "seed": ..., "steps": ..., "replans": {reason: n},
   "throughput_mbps": ..., "achieved_min_mbps": ...,
   "achieved_mean_mbps": ..., "distinct_plans": ...,
   "cache_builds": ..., "cache_hits": ..., "wall_s": ...}
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.scenarios import get_scenario, run_scenario, scenario_names

SEED = 0


def bench_scenarios(seed: int = SEED):
    rows = []
    for name in scenario_names():
        t0 = time.time()
        res = run_scenario(get_scenario(name), seed=seed)
        row = res.summary()
        row["wall_s"] = round(time.time() - t0, 3)
        rows.append(row)
        sys.stderr.write(f"[scenarios] {name} done in {row['wall_s']}s\n")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--out", type=str, default=None,
                    help="write JSON here instead of stdout")
    args = ap.parse_args()
    doc = json.dumps(bench_scenarios(args.seed), indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(doc + "\n")
        sys.stderr.write(f"[scenarios] wrote {args.out}\n")
    else:
        print(doc)


if __name__ == "__main__":
    main()
