"""Fleet benchmark — tick latency and min-BW fairness vs job count.

Runs a fleet of 1..8 identical-slice-pattern jobs over one shared WAN
and reports, per fleet size:

  * mean/max tick wall time (the batched-RF + single-water-fill tick
    should scale sublinearly in job count — one kernel launch and one
    fill regardless of J);
  * RF kernel launches (== ticks, fleet-size independent);
  * per-job credited min-link BW plus Jain's fairness index over the
    priority-normalized min BW (bw_j / w_j): 1.0 = perfectly
    weighted-fair;
  * an `sle` block per fleet size — the Mist-style health rollup from
    repro.obs.sle over the run's tick trace (capacity, fairness,
    responsiveness, Eq. 1 monitoring dollars; accuracy is null — fleet
    traces carry no predicted-BW columns).

Run:  PYTHONPATH=src python benchmarks/fleet_bench.py
          [--out FILE] [--json [PATH]] [--smoke]

`--json` additionally writes the machine-readable BENCH_fleet.json
trajectory document; `--smoke` shrinks the sweep to 2 fleet sizes x 2
ticks for CI.
"""
from __future__ import annotations

import sys
import time

import numpy as np

try:
    from benchmarks.common import bench_parser, emit
except ImportError:            # run as a script: sys.path[0] is benchmarks/
    from common import bench_parser, emit
from repro.fleet import (BatchedRfPredictor, FleetController, JobSpec,
                         default_fleet_forest)
from repro.fleet.trace import FleetTrace, tick_to_step
from repro.obs import fleet_sle, jain_index
from repro.wan.simulator import WanSimulator

QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0)
JOB_SIZES = (1, 2, 4, 8)
SMOKE_JOB_SIZES = (1, 2)
TICKS = 6
# priorities cycle 1/2/4 so every fleet size mixes weights
PRIORITIES = (1.0, 2.0, 4.0)


def build_fleet(n_jobs: int, forest, seed: int = 0) -> FleetController:
    """`n_jobs` 4-DC jobs whose slices tile-and-overlap the 8-DC mesh."""
    sim = WanSimulator(seed=seed, **QUIET)
    jobs = tuple(
        JobSpec(name=f"job{j}",
                dcs=tuple((j + k) % 8 for k in range(4)),
                priority=PRIORITIES[j % len(PRIORITIES)])
        for j in range(n_jobs))
    return FleetController(sim, BatchedRfPredictor(forest), m_total=8,
                           jobs=jobs)


def bench_fleet(seed: int = 0, ticks: int = TICKS, smoke: bool = False):
    """One row per fleet size: latency scaling + weighted fairness
    (`jain_index` comes from repro.obs — one fairness definition
    repo-wide)."""
    forest = default_fleet_forest()
    rows = []
    sizes = SMOKE_JOB_SIZES if smoke else JOB_SIZES
    for n_jobs in sizes:
        fleet = build_fleet(n_jobs, forest, seed=seed)
        fleet.tick()                              # warm the jit caches
        wall = []
        last = None
        trace = FleetTrace(f"bench_{n_jobs}jobs", seed)
        for _ in range(ticks):
            t0 = time.perf_counter()
            last = fleet.tick()
            wall.append(time.perf_counter() - t0)
            trace.steps.append(tick_to_step(last))
        norm_min_bw = np.array([r["achieved_min"] / r["priority"]
                                for r in last["jobs"]])
        rows.append({
            "n_jobs": n_jobs,
            "ticks": ticks,
            "tick_mean_ms": round(1e3 * float(np.mean(wall)), 2),
            "tick_max_ms": round(1e3 * float(np.max(wall)), 2),
            "kernel_calls": fleet.predictor.kernel_calls,
            "min_bw_mbps": {r["name"]: round(r["achieved_min"], 1)
                            for r in last["jobs"]},
            "weighted_fairness_jain": round(jain_index(norm_min_bw), 3),
            "sle": fleet_sle(trace, n_dcs=fleet.sim.N),
        })
        sys.stderr.write(f"[fleet] {n_jobs} jobs: "
                         f"{rows[-1]['tick_mean_ms']} ms/tick\n")
    base = rows[0]["tick_mean_ms"]
    for row in rows:
        row["tick_vs_1job"] = round(row["tick_mean_ms"] / base, 2)
    return rows


def main() -> None:
    """CLI entry point; prints (or writes) one JSON document."""
    ap = bench_parser(__doc__, "fleet")
    ap.add_argument("--ticks", type=int, default=TICKS)
    args = ap.parse_args()
    ticks = 2 if args.smoke else args.ticks
    emit("fleet", bench_fleet(args.seed, ticks, smoke=args.smoke), args)


if __name__ == "__main__":
    main()
