"""Render the EXPERIMENTS.md dry-run / roofline tables from the
dryrun JSONs.  PYTHONPATH=src:. python -m benchmarks.report

Thin wrapper: the actual renderers live in `repro.obs.export`
(`render_dryrun_table` / `render_dryrun_summary`), the one canonical
human-readable report path `tools/obsctl.py summarize` also uses —
this module only resolves the benchmarks/results/ file layout.
"""
from __future__ import annotations

import json
import os

from repro.obs.export import render_dryrun_summary, render_dryrun_table


def load(mesh, sync="wanify"):
    p = os.path.join(os.path.dirname(__file__), "results",
                     f"dryrun_{mesh}_{sync}.json")
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return json.load(f)


def table(mesh):
    return render_dryrun_table(load(mesh), mesh)


def summary():
    return render_dryrun_summary({mesh: load(mesh)
                                  for mesh in ("single", "multi")})


if __name__ == "__main__":
    print(summary())
    print(table("single"))
    print(table("multi"))
