"""Render the EXPERIMENTS.md dry-run / roofline tables from the
dryrun JSONs.  PYTHONPATH=src:. python -m benchmarks.report"""
from __future__ import annotations

import json
import os


def fmt_bytes(b):
    return f"{b / 2 ** 30:.2f}"


def load(mesh, sync="wanify"):
    p = os.path.join(os.path.dirname(__file__), "results",
                     f"dryrun_{mesh}_{sync}.json")
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return json.load(f)


def table(mesh):
    cells = load(mesh)
    out = []
    out.append(f"\n### {mesh}-pod mesh "
               f"({'2x16x16 (pod,data,model)' if mesh == 'multi' else '16x16 (data,model)'})\n")
    out.append("| arch | shape | HBM/dev GiB | t_comp s | t_mem s | t_coll s"
               " | dominant | useful-FLOPs | roofline-frac | notes |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c["status"] == "skipped":
            out.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | — |"
                       f" — | — | SKIP: {c['reason'][:60]} |")
            continue
        if c["status"] == "error":
            out.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | — |"
                       f" — | — | ERROR {c['error'][:60]} |")
            continue
        r = c["roofline"]
        note = ""
        if c["hbm_per_device"] > 16e9:
            note = "over 16GB HBM"
        dci = f" dci={r['dci_bytes'] / 2 ** 30:.2f}GiB" \
            if r["dci_bytes"] else ""
        out.append(
            f"| {c['arch']} | {c['shape']} | {fmt_bytes(c['hbm_per_device'])}"
            f" | {r['t_compute']:.2e} | {r['t_memory']:.2e}"
            f" | {r['t_collective']:.2e} | {r['dominant']}"
            f" | {r['useful_flops_ratio']:.2f}"
            f" | {r['roofline_fraction']:.3f} | {note}{dci} |")
    return "\n".join(out)


def summary():
    rows = []
    for mesh in ("single", "multi"):
        cells = load(mesh)
        ok = [c for c in cells if c["status"] == "ok"]
        if not ok:
            continue
        doms = {}
        for c in ok:
            doms[c["roofline"]["dominant"]] = \
                doms.get(c["roofline"]["dominant"], 0) + 1
        worst = min(ok, key=lambda c: c["roofline"]["roofline_fraction"])
        coll = max(ok, key=lambda c: c["roofline"]["t_collective"] /
                   max(c["roofline"]["t_compute"] +
                       c["roofline"]["t_memory"], 1e-12))
        rows.append(f"- **{mesh}**: {len(ok)} ok / "
                    f"{sum(c['status'] == 'skipped' for c in cells)} skipped; "
                    f"dominant terms: {doms}; worst roofline fraction "
                    f"{worst['roofline']['roofline_fraction']:.3f} "
                    f"({worst['arch']}x{worst['shape']}); most "
                    f"collective-bound: {coll['arch']}x{coll['shape']}")
    return "\n".join(rows)


if __name__ == "__main__":
    print(summary())
    print(table("single"))
    print(table("multi"))
