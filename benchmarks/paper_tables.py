"""One benchmark per paper table/figure. Each returns CSV rows
(name, value, derived) consumed by benchmarks/run.py."""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks.common import (TPCDS, query_volumes, run_query,
                               wanify_inputs)
from repro.core.local_opt import AimdAgent, run_agents
from repro.core.plan import pick_bits
from repro.wan.monitor import annual_costs
from repro.wan.simulator import WanSimulator

Row = Tuple[str, float, str]
OFF8 = ~np.eye(8, dtype=bool)


def bench_table1() -> List[Row]:
    """Static-independent vs runtime BW gaps (paper: 18 significant)."""
    sim = WanSimulator(seed=1)
    si = sim.measure_static_independent()
    sim.advance(10)
    rt = sim.measure_runtime()
    gaps = np.abs(rt - si)[OFF8]
    b = [int(((gaps > 100) & (gaps <= 200)).sum()),
         int(((gaps > 200) & (gaps <= 250)).sum()),
         int((gaps > 250).sum())]
    return [("table1.significant_pairs", float(sum(b)),
             f"buckets(100-200/200-250/>250)={b[0]}/{b[1]}/{b[2]} paper=18")]


def bench_table2() -> List[Row]:
    rows = []
    for n in (4, 6, 8):
        c = annual_costs(n)
        rows.append((f"table2.savings_n{n}", c["savings_frac"] * 100,
                     f"monitor=${c['runtime_monitoring']:.0f} "
                     f"pred=${c['prediction']:.0f} paper~96%"))
    return rows


def bench_fig2() -> List[Row]:
    """3-DC heterogeneous connections demo (paper: 2.1x min BW)."""
    sim = WanSimulator(regions=["us-east", "us-west", "ap-se"], seed=2)
    off = ~np.eye(3, dtype=bool)
    u1 = sim.measure_simultaneous(np.ones((3, 3)))
    u8 = sim.measure_simultaneous(np.full((3, 3), 8.0))
    het = np.array([[0, 2, 11], [2, 0, 13], [11, 13, 0]], float)  # Fig 2c
    hb = sim.measure_simultaneous(het)
    # Fig 2d network latency: 3 Gb to/from DC3, 9 Gb between DC1-DC2
    vol = np.array([[0, 9, 3], [9, 0, 3], [3, 3, 0]], float)
    t = lambda bw: float((vol[off] * 1000 / np.maximum(bw[off], 1e-6)).max())
    return [
        ("fig2.min_bw_single", float(u1[off].min()), "1 conn per link"),
        ("fig2.min_bw_uniform8", float(u8[off].min()), "paper: ~120 Mbps"),
        ("fig2.min_bw_heterogeneous", float(hb[off].min()),
         f"gain={hb[off].min() / u8[off].min():.2f}x paper=2.1x"),
        ("fig2.net_latency_uniform8_s", t(u8), ""),
        ("fig2.net_latency_het_s", t(hb),
         f"speedup={t(u8) / t(hb):.2f}x"),
    ]


def bench_table4() -> List[Row]:
    """Latency/cost gains from simultaneous/predicted BWs vs
    static-independent placement (paper: up to ~16-18% latency)."""
    from repro.core.predictor import BwPredictor
    from repro.wan.dataset import train_default_forest
    rf, _, _ = train_default_forest(n_samples=120, n_trees=40)
    rows = []
    for q, (gb, comp) in TPCDS.items():
        sim = WanSimulator(seed=hash(q) % 1000)
        sim.advance(5)
        data = query_volumes(gb, 8, seed=3)
        si = sim.measure_static_independent()
        base = run_query(sim, data, si, compute_s=comp)
        simu = run_query(sim, data, sim.measure_runtime(), compute_s=comp)
        pred, _ = wanify_inputs(sim, BwPredictor(rf))
        prq = run_query(sim, data, pred, compute_s=comp)
        rows.append((f"table4.{q}.perf_simultaneous_pct",
                     (1 - simu.latency_s / base.latency_s) * 100,
                     f"cost {(1 - simu.cost_usd / base.cost_usd) * 100:.1f}%"))
        rows.append((f"table4.{q}.perf_predicted_pct",
                     (1 - prq.latency_s / base.latency_s) * 100,
                     f"cost {(1 - prq.cost_usd / base.cost_usd) * 100:.1f}% "
                     f"paper<=18%"))
    return rows


def bench_fig5() -> List[Row]:
    """TeraSort PDT variants: vanilla / uniform-P / Dynamic / TC."""
    sim = WanSimulator(seed=4)
    data = np.full(8, 100.0 / 8)      # TeraSort: uniform all-to-all, 100 GB
    pred, plan = wanify_inputs(sim)
    rows = []
    variants = {
        "vanilla_1conn": dict(bw=pred, conns=None, cap=None),
        "wanify_P_uniform8": dict(bw=pred, conns=np.full((8, 8), 8.0),
                                  cap=None),
        "wanify_dynamic": dict(bw=pred, conns=plan.max_cons.astype(float),
                               cap=None),
        "wanify_TC": dict(bw=pred, conns=plan.max_cons.astype(float),
                          cap=plan.throttle),
    }
    for name, kw in variants.items():
        r = run_query(sim, data, kw["bw"], conns=kw["conns"], cap=kw["cap"],
                      compute_s=600.0, n_stages=3)
        rows.append((f"fig5.{name}.latency_s", r.latency_s,
                     f"cost=${r.cost_usd:.2f} min_bw={r.min_bw:.0f}Mbps"))
    return rows


def bench_fig6() -> List[Row]:
    """Shuffle-size sweep: WANify vs single connection."""
    rows = []
    for mb in (2.06, 3.63, 7.4, 14.8, 29.6, 59.2):
        sim = WanSimulator(seed=6)
        data = query_volumes(mb * 8 / 1000.0, 8, seed=6)   # MB -> Gb scale
        pred, plan = wanify_inputs(sim)
        base = run_query(sim, data, pred, compute_s=60.0)
        wan = run_query(sim, data, pred, conns=plan.max_cons.astype(float),
                        cap=plan.throttle, compute_s=60.0)
        rows.append((f"fig6.size_{mb}MB.net_speedup",
                     max(base.net_s, 1e-9) / max(wan.net_s, 1e-9),
                     f"minbw {base.min_bw:.0f}->{wan.min_bw:.0f} "
                     f"(gains grow with shuffle size, paper Fig 6)"))
    return rows


def bench_fig8() -> List[Row]:
    """Ablation: Global-only / Local-only / full WANify + error injection."""
    sim = WanSimulator(seed=8)
    data = query_volumes(160.0, 8, seed=8)
    pred, plan = wanify_inputs(sim)
    vanilla = run_query(sim, data, sim.measure_static_independent(),
                        compute_s=420.0)
    glob = run_query(sim, data, pred, conns=plan.max_cons.astype(float),
                     compute_s=420.0)
    # local-only: static 1-8 window with solo-BW priors; AIMD fine-tunes
    si = sim.measure_static_independent()
    from repro.core.global_opt import GlobalPlan
    ones = np.ones((8, 8), np.int64)
    static_plan = GlobalPlan(
        pred_bw=si, dc_rel=ones, min_cons=ones,
        max_cons=np.where(np.eye(8, dtype=bool), 1, 8).astype(np.int64),
        min_bw=si, max_bw=si * 8, throttle=np.full((8, 8), np.inf))
    conns_local, _ = run_agents(
        static_plan, lambda c: sim.measure_snapshot(c), steps=5)
    loc = run_query(sim, data, pred, conns=conns_local.astype(float),
                    compute_s=420.0)
    full = run_query(sim, data, pred, conns=plan.max_cons.astype(float),
                     cap=plan.throttle, compute_s=420.0)
    err_bw = pred + np.random.default_rng(0).choice(
        [-100.0, 100.0], size=pred.shape)
    err = run_query(sim, data, err_bw, conns=plan.max_cons.astype(float),
                    cap=plan.throttle, compute_s=420.0)
    rows = []
    for name, r in [("global_only", glob), ("local_only", loc),
                    ("full", full)]:
        rows.append((f"fig8.{name}.latency_gain_pct",
                     (1 - r.latency_s / vanilla.latency_s) * 100,
                     f"min_bw={r.min_bw:.0f} paper: 16/11/23%"))
    rows.append(("fig8.err100.latency_penalty_pct",
                 (err.latency_s / full.latency_s - 1) * 100,
                 "paper: ~18% worse with +-100Mbps errors"))
    return rows


def bench_fig9() -> List[Row]:
    """AIMD dynamics: target-BW tracking SD + 20% error injection."""
    sim = WanSimulator(seed=9)
    pred, plan = wanify_inputs(sim)
    agent = AimdAgent.from_plan(plan, 0)
    sds, sig = [], 0
    rng = np.random.default_rng(9)
    for epoch in range(20):
        sim.advance()
        mon = sim.measure_snapshot(plan.max_cons.astype(float))[0]
        agent.step(mon)
        sds.append(np.std(agent.target_bw[1:]))
        noisy = agent.target_bw * (1 + rng.uniform(-0.2, 0.2,
                                                   len(agent.target_bw)))
        sig += int((np.abs(noisy - mon)[1:] > 100).sum() >
                   (np.abs(agent.target_bw - mon)[1:] > 100).sum())
    return [("fig9.mean_target_sd", float(np.mean(sds)),
             f"epochs=20 sig_worse_with_20pct_err={sig}")]


def bench_fig10() -> List[Row]:
    """Skewed input data: w_s-aware vs skew-unaware (paper: 7-26%)."""
    sim = WanSimulator(seed=10)
    skew = np.array([3.0, 2.0, 2.0, 2.0, 1.0, 1.0, 1.0, 1.0])
    data = query_volumes(4.8, 8, seed=10, skew=skew)   # 600 MB wordcount
    pred, plan_ns = wanify_inputs(sim)
    _, plan_ws = wanify_inputs(sim, w_s=skew)
    base = run_query(sim, data, pred, compute_s=90.0)
    unif = run_query(sim, data, pred, conns=np.full((8, 8), 8.0),
                     compute_s=90.0)
    wns = run_query(sim, data, pred, conns=plan_ns.max_cons.astype(float),
                    cap=plan_ns.throttle, compute_s=90.0)
    ws = run_query(sim, data, pred, conns=plan_ws.max_cons.astype(float),
                   cap=plan_ws.throttle, compute_s=90.0)
    g = lambda a, b: (1 - max(a.net_s, 1e-9) / max(b.net_s, 1e-9)) * 100
    return [
        ("fig10.net_gain_vs_single_pct", g(ws, base), "paper: 26.5% (total)"),
        ("fig10.net_gain_vs_uniform_pct", g(ws, unif), "paper: 20.3% (total)"),
        ("fig10.net_gain_vs_noskew_pct", g(ws, wns), "paper: 7.1% (total)"),
    ]


def bench_fig11() -> List[Row]:
    """Prediction accuracy vs cluster size and heterogeneous VMs."""
    from repro.core.predictor import BwPredictor
    from repro.wan.dataset import train_default_forest
    from repro.wan.monitor import SnapshotMonitor
    rf, acc, r2 = train_default_forest(n_samples=150, n_trees=50)
    rows = [("fig11.train_acc_pct", acc * 100, "paper: 98.51%"),
            ("fig11.holdout_r2", r2, "")]
    for n in (4, 6, 8):
        sim = WanSimulator(regions=WanSimulator().regions[:n], seed=20 + n)
        si = sim.measure_static_independent()
        sim.advance(10)
        _, raw = SnapshotMonitor(sim).capture()
        pred = BwPredictor(rf).predict_matrix(
            n, raw["snapshot_bw"], raw["mem_util"], raw["cpu_load"],
            raw["retrans"], raw["dist"])
        truth = sim.measure_runtime()
        off = ~np.eye(n, dtype=bool)
        rows.append((f"fig11.n{n}.sig_errors_static",
                     float((np.abs(si - truth)[off] > 100).sum()), ""))
        rows.append((f"fig11.n{n}.sig_errors_predicted",
                     float((np.abs(pred - truth)[off] > 100).sum()),
                     "predicted < static expected"))
    return rows


def bench_fig4_ml() -> List[Row]:
    """BW-aware gradient quantization (SAGQ-family): training-time model
    time = compute + grad_bytes(bits)/min_BW per epoch."""
    sim = WanSimulator(seed=12)
    grads_gb = 0.44 * 8                    # ~55M-param model f32, in Gb
    epochs, comp = 10, 80.0
    pred, plan = wanify_inputs(sim)
    si = sim.measure_static_independent()
    off = OFF8

    def t_train(bw_matrix, bits, conns=None, cap=None):
        true = sim.measure_simultaneous(
            np.ones((8, 8)) if conns is None else conns, cap=cap)
        eff = float(true[off].min())
        per_epoch = comp + grads_gb * bits / 32.0 * 1000.0 / eff
        return epochs * per_epoch

    noq = t_train(si, 32)
    sagq = t_train(si, pick_bits(float(si[off].min())))
    predq = t_train(pred, pick_bits(float(pred[off].min())))
    wq = t_train(pred, pick_bits(float(pred[off].min())),
                 conns=plan.max_cons.astype(float), cap=plan.throttle)
    return [
        ("fig4.NoQ_s", noq, ""),
        ("fig4.SAGQ_s", sagq, f"gain={(1 - sagq / noq) * 100:.0f}% paper~22%"),
        ("fig4.PredQ_s", predq,
         f"gain_vs_SAGQ={(1 - predq / sagq) * 100:.0f}% paper~13-14%"),
        ("fig4.WQ_s", wq,
         f"gain_vs_SAGQ={(1 - wq / sagq) * 100:.0f}% paper~26%"),
    ]
