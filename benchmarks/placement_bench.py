"""Placement benchmark — the paper's §5 latency/cost comparison as a
tracked artifact: WANify-predicted-BW placement vs the static
single-connection ablation, per named scenario x named workload, with
latency/egress deltas (positive = WANify better).

Run:  PYTHONPATH=src python benchmarks/placement_bench.py
          [--out FILE] [--json [PATH]] [--smoke]

`--json` writes the machine-readable BENCH_placement.json trajectory
document (the e2e placement test reproduces the same comparison);
`--smoke` runs one scenario x one workload at truncated steps for CI.
"""
from __future__ import annotations

import sys
import time

try:
    from benchmarks.common import bench_parser, emit
except ImportError:            # run as a script: sys.path[0] is benchmarks/
    from common import bench_parser, emit
from repro.placement import compare_backends, get_workload
from repro.scenarios import get_scenario

SCENARIOS = ("skew_ramp", "link_flap", "cable_cut")
WORKLOADS = ("scan_agg", "two_stage_join", "iterative")
SMOKE_STEPS = 8


def bench_placement(seed: int = 0, smoke: bool = False):
    """One row per (scenario, workload): totals per backend + deltas."""
    scenarios = SCENARIOS[:1] if smoke else SCENARIOS
    workloads = WORKLOADS[:1] if smoke else WORKLOADS
    rows = []
    for scen_name in scenarios:
        for wl in workloads:
            spec = get_scenario(scen_name)
            if smoke:
                spec.steps = min(spec.steps, SMOKE_STEPS)
            query = get_workload(wl, spec.n_pods)
            t0 = time.time()
            r = compare_backends(spec, query=query, seed=seed)
            rows.append({
                "scenario": scen_name,
                "query": wl,
                "seed": seed,
                "steps": r["wanify"]["steps"],
                "makespan_wanify_s":
                    round(r["wanify"]["makespan_total_s"], 3),
                "makespan_static_s":
                    round(r["static"]["makespan_total_s"], 3),
                "latency_delta_pct": round(r["latency_delta_pct"], 2),
                "egress_wanify_usd":
                    round(r["wanify"]["egress_usd_total"], 4),
                "egress_static_usd":
                    round(r["static"]["egress_usd_total"], 4),
                "egress_delta_pct": round(r["egress_delta_pct"], 2),
                "replacements": r["wanify"]["replacements"],
                "wall_s": round(time.time() - t0, 3),
            })
            sys.stderr.write(
                f"[placement] {scen_name}/{wl}: "
                f"lat {rows[-1]['latency_delta_pct']:+.1f}% "
                f"egress {rows[-1]['egress_delta_pct']:+.1f}% "
                f"in {rows[-1]['wall_s']}s\n")
    return rows


def main() -> None:
    """CLI entry point; prints (or writes) one JSON document."""
    args = bench_parser(__doc__, "placement").parse_args()
    emit("placement", bench_placement(args.seed, smoke=args.smoke), args)


if __name__ == "__main__":
    main()
