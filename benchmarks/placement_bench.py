"""Placement benchmark — the paper's §5 latency/cost comparison plus
the placement-search engine's throughput, as one tracked artifact.

Two row kinds land in `BENCH_placement.json`:

  * ``kind="scenario"`` — WANify-predicted-BW placement vs the static
    single-connection ablation, the FULL scenario x workload grid
    (latency/egress deltas, positive = WANify better);
  * ``kind="search"`` — the search microbenchmark: one full
    `greedy_place` per backend (scalar one-eval-per-move reference vs
    batched numpy vs batched jax) at N in {4, 8, 16}, reporting
    ``evals_per_s`` — the perf contract is batched >= 10x scalar at
    N=8 (CI smoke-guards a generous 2x so the artifact can't rot).

Run:  PYTHONPATH=src python benchmarks/placement_bench.py
          [--out FILE] [--json [PATH]] [--smoke] [--search]

`--json` writes the machine-readable BENCH_placement.json trajectory
document; `--smoke` truncates steps/sizes for CI; `--search` runs only
the search microbenchmark rows.
"""
from __future__ import annotations

import sys
import time

import numpy as np

try:
    from benchmarks.common import bench_parser, emit
except ImportError:            # run as a script: sys.path[0] is benchmarks/
    from common import bench_parser, emit
from repro.placement import compare_backends, get_workload, greedy_place
from repro.scenarios import get_scenario

SCENARIOS = ("skew_ramp", "link_flap", "cable_cut")
WORKLOADS = ("scan_agg", "two_stage_join", "iterative")
SEARCH_BACKENDS = ("scalar", "numpy", "jax")
SEARCH_SIZES = (4, 8, 16)
SMOKE_STEPS = 6


def bench_placement(seed: int = 0, smoke: bool = False):
    """One row per (scenario, workload) over the full grid: totals per
    backend + deltas (smoke only truncates the per-scenario steps)."""
    rows = []
    for scen_name in SCENARIOS:
        for wl in WORKLOADS:
            spec = get_scenario(scen_name)
            if smoke:
                spec.steps = min(spec.steps, SMOKE_STEPS)
            query = get_workload(wl, spec.n_pods)
            t0 = time.time()
            r = compare_backends(spec, query=query, seed=seed)
            rows.append({
                "kind": "scenario",
                "scenario": scen_name,
                "query": wl,
                "seed": seed,
                "steps": r["wanify"]["steps"],
                "makespan_wanify_s":
                    round(r["wanify"]["makespan_total_s"], 3),
                "makespan_static_s":
                    round(r["static"]["makespan_total_s"], 3),
                "latency_delta_pct": round(r["latency_delta_pct"], 2),
                "egress_wanify_usd":
                    round(r["wanify"]["egress_usd_total"], 4),
                "egress_static_usd":
                    round(r["static"]["egress_usd_total"], 4),
                "egress_delta_pct": round(r["egress_delta_pct"], 2),
                "replacements": r["wanify"]["replacements"],
                "wall_s": round(time.time() - t0, 3),
            })
            sys.stderr.write(
                f"[placement] {scen_name}/{wl}: "
                f"lat {rows[-1]['latency_delta_pct']:+.1f}% "
                f"egress {rows[-1]['egress_delta_pct']:+.1f}% "
                f"in {rows[-1]['wall_s']}s\n")
    return rows


def bench_search(seed: int = 0, smoke: bool = False):
    """The search microbenchmark: a full `greedy_place` on the default
    workload per backend and DC count, timed after a warm-up run (the
    jax row amortizes its bucket compiles), reporting `evals_per_s`."""
    rows = []
    sizes = (8,) if smoke else SEARCH_SIZES
    repeats = 1 if smoke else 3
    rng = np.random.default_rng(seed)
    for n in sizes:
        query = get_workload("scan_agg", n)
        bw = rng.uniform(50.0, 2000.0, (n, n))
        np.fill_diagonal(bw, 100000.0)
        price = rng.uniform(0.02, 0.12, n)
        for backend in SEARCH_BACKENDS:
            decision = greedy_place(query, bw, egress_usd_per_gb=price,
                                    backend=backend)      # warm-up
            wall = np.inf
            for _ in range(repeats):
                t0 = time.perf_counter()
                decision = greedy_place(query, bw,
                                        egress_usd_per_gb=price,
                                        backend=backend)
                wall = min(wall, time.perf_counter() - t0)
            rows.append({
                "kind": "search",
                "query": "scan_agg",
                "n_dcs": n,
                "backend": backend,
                "seed": seed,
                "evals": decision.evals,
                "wall_s": round(wall, 5),
                "evals_per_s": round(decision.evals / wall, 1),
            })
            sys.stderr.write(
                f"[placement] search N={n} {backend}: "
                f"{rows[-1]['evals_per_s']:,.0f} evals/s "
                f"({decision.evals} evals in {wall:.4f}s)\n")
    return rows


def main() -> None:
    """CLI entry point; prints (or writes) one JSON document."""
    ap = bench_parser(__doc__, "placement")
    ap.add_argument("--search", action="store_true",
                    help="run only the search-microbenchmark rows")
    args = ap.parse_args()
    rows = [] if args.search else bench_placement(args.seed,
                                                  smoke=args.smoke)
    rows += bench_search(args.seed, smoke=args.smoke)
    emit("placement", rows, args)


if __name__ == "__main__":
    main()
