"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV. Sections:
  paper reproduction (Table 1/2/4, Fig 2/4/5/6/8/9/10/11)
  kernels + wansync micro-benches
  roofline summary (reads the dry-run JSONs when present)
"""
from __future__ import annotations

import json
import os
import sys
import time


def _roofline_rows():
    rows = []
    base = os.path.join(os.path.dirname(__file__), "results")
    for mesh in ("single", "multi"):
        p = os.path.join(base, f"dryrun_{mesh}_wanify.json")
        if not os.path.exists(p):
            continue
        with open(p) as f:
            cells = json.load(f)
        ok = [c for c in cells if c["status"] == "ok"]
        if not ok:
            continue
        worst = min(ok, key=lambda c: c["roofline"]["roofline_fraction"])
        rows.append((f"roofline.{mesh}.cells_ok", float(len(ok)),
                     f"of {len(cells)} "
                     f"({sum(c['status'] == 'skipped' for c in cells)} skipped)"))
        rows.append((f"roofline.{mesh}.worst_fraction",
                     worst["roofline"]["roofline_fraction"],
                     f"{worst['arch']}x{worst['shape']}"))
    return rows


def main() -> None:
    from benchmarks import kernels_bench, paper_tables
    benches = [
        paper_tables.bench_table1,
        paper_tables.bench_table2,
        paper_tables.bench_fig2,
        paper_tables.bench_table4,
        paper_tables.bench_fig5,
        paper_tables.bench_fig6,
        paper_tables.bench_fig8,
        paper_tables.bench_fig9,
        paper_tables.bench_fig10,
        paper_tables.bench_fig11,
        paper_tables.bench_fig4_ml,
        kernels_bench.bench_kernels,
        kernels_bench.bench_wansync_model,
        _roofline_rows,
    ]
    print("name,us_per_call,derived")
    for b in benches:
        t0 = time.time()
        try:
            rows = b()
        except Exception as e:  # keep the harness running
            print(f"{b.__name__},nan,ERROR {type(e).__name__}: {e}")
            continue
        for name, val, derived in rows:
            print(f"{name},{val:.4f},{derived}")
        sys.stderr.write(f"[bench] {b.__name__} done in "
                         f"{time.time() - t0:.1f}s\n")


if __name__ == "__main__":
    main()
