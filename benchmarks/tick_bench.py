"""Fused-tick benchmark — one jit program vs the sequential fleet loop.

Measures the PR-6 tentpole end to end:

  * `waterfill` rows — the progressive-fill rate solver alone: the
    numpy reference loop (one Python iteration per freeze event) vs
    the batched `repro.kernels.waterfill` while_loop kernel on the
    same random contended matrices;
  * `tick` rows — whole arbitration epochs at fleet scale: the
    sequential `FleetController.tick` loop vs `FusedFleet.run` (the
    same closed loop as ONE `lax.scan` launch) vs `FusedFleet.sweep`
    (B scenario variants x T steps vmapped into one launch);
  * `obs` rows — the sequential tick with the repro.obs span tracer
    off vs on (best-of-3), pinning the obs-on overhead. The CI
    bench-smoke guard asserts `overhead_frac < 0.05` on the committed
    BENCH_tick.json.

`steps_per_s` counts arbitration epochs per wall-clock second; the
sweep row counts every variant's epochs (B x T per launch). jit
compile time is excluded (one warm run before timing) — the fused
engine's pitch is steady-state scenario scanning, where one compile
amortizes over whole grids.

Run:  PYTHONPATH=src python benchmarks/tick_bench.py
          [--out FILE] [--json [PATH]] [--smoke]

`--json` writes the machine-readable BENCH_tick.json trajectory
document; `--smoke` shrinks to CI sizes (the CI gate asserts fused >=
2x sequential there; the committed full-size artifact shows the >= 5x
fleet-scale headline at J=16).
"""
from __future__ import annotations

import sys
import time

import numpy as np

try:
    from benchmarks.common import bench_parser, emit
except ImportError:            # run as a script: sys.path[0] is benchmarks/
    from common import bench_parser, emit
from repro.fleet import (BatchedRfPredictor, FleetController, JobSpec,
                         default_fleet_forest)
from repro.wan.simulator import WanSimulator

# the fused determinism contract: no observation/host noise
QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0,
             host_sigma=0.0)
PRIORITIES = (1.0, 2.0, 4.0)

N_JOBS, STEPS, SWEEP_B = 16, 24, 16
SMOKE_N_JOBS, SMOKE_STEPS, SMOKE_SWEEP_B = 6, 6, 4
FILL_BATCH, SMOKE_FILL_BATCH = 64, 8


def build_fleet(n_jobs: int, forest, seed: int = 0,
                obs: str = "off") -> FleetController:
    """`n_jobs` 4-DC jobs whose slices tile-and-overlap the 8-DC mesh
    (the fleet_bench pattern, under the fused noise contract)."""
    sim = WanSimulator(seed=seed, **QUIET)
    jobs = tuple(
        JobSpec(name=f"job{j}",
                dcs=tuple((j + k) % 8 for k in range(4)),
                priority=PRIORITIES[j % len(PRIORITIES)])
        for j in range(n_jobs))
    return FleetController(sim, BatchedRfPredictor(forest), m_total=8,
                           jobs=jobs, obs=obs)


def bench_waterfill(batch: int, seed: int = 0) -> list:
    """Rate-solver micro-bench: numpy loop vs one batched jax launch
    over the same `batch` random contended aggregate matrices."""
    from repro.kernels import waterfill as wfk
    sim = WanSimulator(seed=seed, **QUIET)
    rng = np.random.default_rng(seed)
    n = sim.N
    cs = rng.integers(0, 7, size=(batch, n, n)).astype(np.float64)
    for c in cs:
        np.fill_diagonal(c, 0.0)
    single, egress, ingress, w, path_cap = sim.fill_inputs()

    t0 = time.perf_counter()
    for c in cs:
        sim._fill_rates(c)
    t_np = time.perf_counter() - t0

    args = (cs, np.broadcast_to(single, cs.shape),
            np.broadcast_to(egress, (batch, n)),
            np.broadcast_to(ingress, (batch, n)), w,
            np.broadcast_to(path_cap, cs.shape))
    wfk.fill_rates(*args)                      # compile
    t0 = time.perf_counter()
    rate, iters, ok = wfk.fill_rates(*args)
    t_jx = time.perf_counter() - t0
    assert bool(np.all(ok))

    rows = [{"kind": "waterfill", "backend": "numpy", "batch": batch,
             "n_dcs": n, "fills_per_s": round(batch / t_np, 1)},
            {"kind": "waterfill", "backend": "jax", "batch": batch,
             "n_dcs": n, "fills_per_s": round(batch / t_jx, 1),
             "speedup_vs_numpy": round(t_np / t_jx, 2)}]
    for r in rows:
        sys.stderr.write(f"[tick] waterfill/{r['backend']}: "
                         f"{r['fills_per_s']} fills/s\n")
    return rows


def bench_ticks(n_jobs: int, steps: int, sweep_b: int,
                seed: int = 0) -> list:
    """Whole-epoch throughput: sequential loop vs fused scan vs
    vmapped B-scenario sweep, identical fleet configuration."""
    from repro.fleet.fused import make_schedule
    forest = default_fleet_forest()

    fleet = build_fleet(n_jobs, forest, seed=seed)
    fleet.tick()                               # warm caches
    t0 = time.perf_counter()
    for _ in range(steps):
        fleet.tick()
    t_seq = time.perf_counter() - t0
    seq_sps = steps / t_seq

    fleet = build_fleet(n_jobs, forest, seed=seed)
    fleet.run_fused(steps)                     # compile the scan
    t0 = time.perf_counter()
    fleet.run_fused(steps)
    t_fus = time.perf_counter() - t0
    fus_sps = steps / t_fus

    singles, bgs = [], []
    for b in range(sweep_b):
        sim = WanSimulator(seed=seed + b, **QUIET)
        s, g = make_schedule(sim, steps)
        singles.append(s)
        bgs.append(g)
    singles, bgs = np.stack(singles), np.stack(bgs)
    ff = build_fleet(n_jobs, forest, seed=seed).fused()
    ff.sweep(singles, bgs)                     # compile the vmapped scan
    t0 = time.perf_counter()
    ff.sweep(singles, bgs)
    t_swp = time.perf_counter() - t0
    swp_sps = sweep_b * steps / t_swp

    rows = [
        {"kind": "tick", "mode": "sequential", "n_jobs": n_jobs,
         "steps": steps, "steps_per_s": round(seq_sps, 2)},
        {"kind": "tick", "mode": "fused", "n_jobs": n_jobs,
         "steps": steps, "steps_per_s": round(fus_sps, 2),
         "speedup_vs_sequential": round(fus_sps / seq_sps, 2)},
        {"kind": "tick", "mode": "fused_sweep", "n_jobs": n_jobs,
         "steps": steps, "n_scenarios": sweep_b,
         "steps_per_s": round(swp_sps, 2),
         "speedup_vs_sequential": round(swp_sps / seq_sps, 2)},
    ]
    for r in rows:
        sys.stderr.write(f"[tick] {r['mode']}: {r['steps_per_s']} "
                         f"epochs/s\n")
    return rows


def bench_obs_overhead(n_jobs: int, steps: int, seed: int = 0) -> list:
    """Sequential tick with the span tracer off vs on, best-of-3 runs
    each (dampens single-core scheduler noise), same fleet config.
    Obs-on must stay passive AND cheap: the committed `overhead_frac`
    is gated < 5% by the CI bench-smoke job."""
    forest = default_fleet_forest()

    def timed(obs: str) -> float:
        best = float("inf")
        for _ in range(3):
            fleet = build_fleet(n_jobs, forest, seed=seed, obs=obs)
            fleet.tick()                       # warm the jit caches
            t0 = time.perf_counter()
            for _ in range(steps):
                fleet.tick()
            best = min(best, time.perf_counter() - t0)
        return best

    t_off = timed("off")
    t_on = timed("on")
    rows = [
        {"kind": "obs", "mode": "off", "n_jobs": n_jobs, "steps": steps,
         "steps_per_s": round(steps / t_off, 2)},
        {"kind": "obs", "mode": "on", "n_jobs": n_jobs, "steps": steps,
         "steps_per_s": round(steps / t_on, 2),
         "overhead_frac": round(max(t_on - t_off, 0.0) / t_off, 4)},
    ]
    sys.stderr.write(f"[tick] obs overhead: "
                     f"{rows[1]['overhead_frac']:.2%}\n")
    return rows


def main() -> None:
    """CLI entry point; prints (or writes) one JSON document."""
    ap = bench_parser(__doc__, "tick")
    args = ap.parse_args()
    if args.smoke:
        n_jobs, steps, sweep_b = SMOKE_N_JOBS, SMOKE_STEPS, SMOKE_SWEEP_B
        batch = SMOKE_FILL_BATCH
    else:
        n_jobs, steps, sweep_b = N_JOBS, STEPS, SWEEP_B
        batch = FILL_BATCH
    rows = bench_waterfill(batch, seed=args.seed)
    rows += bench_ticks(n_jobs, steps, sweep_b, seed=args.seed)
    rows += bench_obs_overhead(n_jobs, steps, seed=args.seed)
    emit("tick", rows, args)


if __name__ == "__main__":
    main()
