"""Fault-plane benchmark — the chaos harness as one tracked artifact.

Each row in `BENCH_faults.json` is one (chaos scenario, mode) run from
`repro.faults.harness`: the SAME scripted fault timeline executed with
the graceful degradation ladder (``mode="ladder"``, REPRO_FAULTS=on
semantics) and as the naive-crash ablation (``mode="naive"``, the off
gate with fault events scripted). A final ``kind="summary"`` row
carries the headline comparisons CI pins:

  * ``ladder_crashes == 0`` — the ladder survives the whole library
    with zero uncaught exceptions;
  * ``naive_crashes > 0`` — the ablation actually dies (a chaos suite
    nothing crashes under measures nothing);
  * mean MTTR (fault -> 90%-floor recovery, the obs responsiveness
    SLE) lower for the ladder than naive, and the ladder's worst
    degraded-mode min-BW floor above an absolute threshold while the
    naive ablation's is 0 (a crashed run makes no progress).

``--smoke`` runs a 3-scenario subset (one guaranteed naive crash, one
degraded-mode scenario, the fleet quarantine) so CI stays fast; the
committed full-size artifact is what the threshold guards gate.

Run:  PYTHONPATH=src python benchmarks/faults_bench.py
          [--seed N] [--out FILE] [--json [PATH]] [--smoke]
"""
from __future__ import annotations

import time

try:
    from benchmarks.common import bench_parser, emit
except ImportError:            # run as a script: sys.path[0] is benchmarks/
    from common import bench_parser, emit
from repro.faults.harness import chaos_report

SMOKE_SCENARIOS = ["solver_flake", "monitor_freeze", "fleet_blackout"]


def bench_faults(seed: int = 3, smoke: bool = False):
    """Two rows per chaos scenario (ladder vs naive) + a summary row."""
    names = SMOKE_SCENARIOS if smoke else None
    t0 = time.time()
    rep = chaos_report(names=names, seed=seed)
    elapsed = time.time() - t0
    rows = []
    for r in rep["runs"]:
        rows.append(dict(kind="chaos", **r))
    rows.append({
        "kind": "summary",
        "seed": seed,
        "smoke": bool(smoke),
        "elapsed_s": round(elapsed, 3),
        **rep["summary"],
    })
    return rows


def main() -> None:
    """CLI entry point (see module docstring for the flags)."""
    ap = bench_parser(__doc__.splitlines()[0], name="faults",
                      default_seed=3)
    args = ap.parse_args()
    rows = bench_faults(seed=args.seed, smoke=args.smoke)
    emit("faults", rows, args)


if __name__ == "__main__":
    main()
