"""Overlay benchmark — direct-only vs relay-routed on the staged
far-link cut (`cable_cut_reroute`), as one tracked artifact.

Each row in `BENCH_overlay.json` is one (seed, mode) run of the
scenario with a placement planner riding it:

  * ``mode="direct"`` — the historical overlay-off path;
  * ``mode="routed"`` — ``overlay=on``: the post-cut replans split the
    cut pair's connections onto one-hop detours through the healthy
    DCs (repro.overlay), charged on both hops in the ground-truth
    water-fill.

The tracked contract (smoke-guarded in CI): on the settled post-cut
window the routed run's min achievable BW is >= the direct run's, and
its total placement makespan is <= — relaying around a knee-capped cut
must never lose to pumping connections into it.

Run:  PYTHONPATH=src python benchmarks/overlay_bench.py
          [--seed N] [--out FILE] [--json [PATH]] [--smoke]
"""
from __future__ import annotations

import sys
import time

try:
    from benchmarks.common import bench_parser, emit
except ImportError:            # run as a script: sys.path[0] is benchmarks/
    from common import bench_parser, emit
from repro.placement.scenario import run_placement_scenario
from repro.scenarios import get_scenario

SCENARIO = "cable_cut_reroute"
# the cut lands at step 12; the first post-cut replan's routing is in
# force from step 14 (see tests/test_overlay.py)
SETTLED_STEP = 14
SMOKE_STEPS = 18               # smoke still covers cut + settled window


def bench_overlay(seed: int = 0, smoke: bool = False):
    """Two rows per seed — the same scenario weather priced and
    executed direct-only vs routed."""
    rows = []
    for mode, overlay in (("direct", "off"), ("routed", "on")):
        spec = get_scenario(SCENARIO)
        if smoke:
            spec.steps = min(spec.steps, SMOKE_STEPS)
        t0 = time.time()
        res = run_placement_scenario(spec, seed=seed, overlay=overlay)
        steps = res.trace.steps
        post = [s for s in steps if s.step >= SETTLED_STEP]
        rows.append({
            "kind": "scenario",
            "scenario": SCENARIO,
            "mode": mode,
            "seed": seed,
            "steps": len(steps),
            "makespan_total_s": round(sum(s.makespan_s for s in steps), 3),
            "postcut_makespan_s": round(sum(s.makespan_s for s in post), 3),
            "postcut_min_bw_mbps": round(min(s.achieved_min for s in post),
                                         3),
            "postcut_mean_min_bw_mbps":
                round(sum(s.achieved_min for s in post) / max(len(post), 1),
                      3),
            "replacements": sum(1 for s in steps if s.replaced),
            "wall_s": round(time.time() - t0, 3),
        })
        sys.stderr.write(
            f"[overlay] {SCENARIO}/{mode}: post-cut min BW "
            f"{rows[-1]['postcut_min_bw_mbps']} Mbps, makespan "
            f"{rows[-1]['makespan_total_s']}s in {rows[-1]['wall_s']}s\n")
    return rows


def main() -> None:
    """CLI entry point; prints (or writes) one JSON document."""
    args = bench_parser(__doc__, "overlay").parse_args()
    emit("overlay", bench_overlay(args.seed, smoke=args.smoke), args)


if __name__ == "__main__":
    main()
