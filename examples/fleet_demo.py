"""Fleet walkthrough — many jobs sharing one WAN, arbitrated per tick.

Three concurrent workloads (a serving fleet, a training run, a batch
ETL job) contend for the same 8-DC mesh. Each fleet tick splits the
per-host connection budget and every contended link's capacity by
priority-weighted fair share, batches all jobs' RF inference into ONE
Pallas kernel launch, and credits each job its share of a single
fleet-wide water-fill.

Shows: per-job budgets/caps/credited BW under steady contention, a
priority promotion re-splitting the shares, job churn re-arbitrating
survivors, and the serving job's `Engine.migration_schedule()` picking
up its fleet-arbitrated plan (the serve consumer is unchanged — it
just holds a controller whose envelope the fleet manages).

Run:  PYTHONPATH=src python examples/fleet_demo.py
"""
import numpy as np

from repro.fleet import (BatchedRfPredictor, FleetController, JobSpec,
                         default_fleet_forest)
from repro.wan.simulator import WanSimulator

QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0)


def show(record):
    print(f"  tick {record['tick']:2d} "
          f"(jobs={record['n_jobs']}, RF launches={record['kernel_calls']})")
    for row in record["jobs"]:
        cap = ("uncapped" if np.isinf(row["cap_min"])
               else f"cap_min={row['cap_min']:7.1f}")
        print(f"    {row['name']:9s} prio={row['priority']:3.1f} "
              f"budget M={row['budget']} {cap:>16s} "
              f"min BW={row['achieved_min']:7.1f} Mbps "
              f"conns={row['conns_total']}")


def main():
    forest = default_fleet_forest()
    sim = WanSimulator(seed=0, **QUIET)
    fleet = FleetController(
        sim, BatchedRfPredictor(forest), m_total=8,
        jobs=(JobSpec("serving", dcs=(0, 1, 2, 3), priority=4.0),
              JobSpec("training", dcs=(0, 1, 4, 5), priority=2.0),
              JobSpec("batch", dcs=(2, 3, 6, 7), priority=1.0)))

    print("== three jobs, priority 4:2:1, overlapping slices ==")
    for _ in range(3):
        rec = fleet.tick()
    show(rec)

    print("\n== the batch job is promoted to priority 6 ==")
    fleet.set_priority("batch", 6.0)
    rec = fleet.tick()
    show(rec)

    print("\n== training departs; survivors re-share its capacity ==")
    fleet.remove_job("training")
    rec = fleet.tick()
    show(rec)

    print("\n== a new analytics job arrives on a contended slice ==")
    fleet.add_job(JobSpec("analytics", dcs=(0, 1, 2, 3), priority=2.0))
    rec = fleet.tick()
    show(rec)

    # ---- the serving job IS a serve-engine control plane -------------
    # Engine only needs the job's WanifyController; chunking/wire bits
    # for kv_migrate come from the fleet-arbitrated plan.
    from repro.control import offset_schedule
    serving = fleet.jobs["serving"].controller
    print("\n== serving job's KV-migration schedule under arbitration ==")
    print(f"  plan conns = {serving.plan.conns}")
    print(f"  schedule   = {offset_schedule(serving.plan)}")
    print("  (hand this controller to serve.Engine(controller=...) and "
          "kv_migrate lowers it unchanged)")

    print(f"\n== invariant == RF kernel launches = {fleet.predictor.kernel_calls} "
          f"over {fleet.tick_count} ticks (one per tick, any job count)")


if __name__ == "__main__":
    main()
