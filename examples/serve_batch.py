"""Batched serving example: prefill + decode with KV caches on a small
Qwen3-family model, plus WANify-scheduled KV-cache migration between a
prefill pod and decode pods (disaggregated serving).

The migration plan comes from the shared control plane: a
`WanifyController` closes the snapshot -> prediction -> optimization ->
AIMD loop, and `Engine.replan()` adopts a fresh plan when the WAN
shifts — the next `kv_migrate` picks up the new chunking/wire bits.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs import get_config
from repro.configs.base import reduced
from repro.control import WanifyController
from repro.core.predictor import SnapshotPredictor
from repro.models import registry
from repro.serve.engine import Engine, Request, ServeConfig, kv_migrate
from repro.wan.simulator import WanSimulator


def main():
    cfg = reduced(get_config("qwen3-4b"))
    params = registry.init_params(cfg, jax.random.key(0))

    # serve-side control plane: 2 pods monitored on the simulated WAN
    # (SnapshotPredictor = no-RF ablation; swap in BwPredictor(rf) for
    # the paper's learned runtime-BW prediction)
    sim = WanSimulator(seed=0)
    ctl = WanifyController(sim=sim, predictor=SnapshotPredictor(),
                           n_pods=2)
    eng = Engine(cfg, params, ServeConfig(batch=4, s_max=128, tp=1),
                 controller=ctl)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        int(rng.integers(4, 24))
                                        ).astype(np.int32),
                    max_new=16)
            for i in range(8)]
    t0 = time.perf_counter()
    out = eng.serve(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in out.values())
    print(f"[serve] {len(reqs)} requests -> {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")
    for rid in sorted(out)[:3]:
        print(f"[serve] req {rid}: {out[rid][:8]} ...")

    # ---- disaggregated serving: migrate the prefill KV cache across
    # pods over the WANify-scheduled links (chunked + quantized wire) ---
    print("[serve] KV migration across 2 pods (WANify schedule) ...")
    mesh = compat.make_mesh((2, 4), ("pod", "data"))
    print(f"[serve] plan: conns={eng.plan.conns} "
          f"schedule={eng.migration_schedule()}")
    cache = jax.tree.map(jnp.asarray, eng.cache)

    def migrate(c):
        return kv_migrate(c, eng.plan, src_pod=0, compress=True)

    sm = compat.shard_map(migrate, mesh=mesh, in_specs=(P(),),
                          out_specs=P(), axis_names={"pod", "data"},
                          check_vma=False)
    with compat.use_mesh(mesh):
        moved = jax.jit(sm)(cache)
    ok = jax.tree.all(jax.tree.map(
        lambda a, b: bool(jnp.allclose(a.astype(jnp.float32),
                                       b.astype(jnp.float32),
                                       atol=0.1, rtol=0.1)), cache, moved))
    n_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))
    print(f"[serve] migrated {n_bytes / 2 ** 20:.1f} MiB of KV cache, "
          f"quantized wire, roundtrip-consistent: {ok}")

    # ---- the WAN shifts: replan and show the schedule adapting --------
    sim.advance(5)
    eng.replan()
    print(f"[serve] after replan: conns={eng.plan.conns} "
          f"schedule={eng.migration_schedule()}")


if __name__ == "__main__":
    main()
