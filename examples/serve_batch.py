"""Batched serving example: prefill + decode with KV caches on a small
Qwen3-family model, plus WANify-scheduled KV-cache migration between a
prefill pod and decode pods (disaggregated serving).

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import reduced
from repro.core.plan import WanPlan
from repro.models import registry
from repro.serve.engine import Engine, Request, ServeConfig, kv_migrate


def main():
    cfg = reduced(get_config("qwen3-4b"))
    params = registry.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, ServeConfig(batch=4, s_max=128, tp=1))

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab,
                                        int(rng.integers(4, 24))
                                        ).astype(np.int32),
                    max_new=16)
            for i in range(8)]
    t0 = time.perf_counter()
    out = eng.serve(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in out.values())
    print(f"[serve] {len(reqs)} requests -> {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")
    for rid in sorted(out)[:3]:
        print(f"[serve] req {rid}: {out[rid][:8]} ...")

    # ---- disaggregated serving: migrate the prefill KV cache across
    # pods over the WANify-scheduled links (chunked + int8 wire) --------
    print("[serve] KV migration across 2 pods (WANify schedule) ...")
    mesh = jax.make_mesh((2, 4), ("pod", "data"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    plan = WanPlan.uniform(2, conns=4, bits=8)
    cache = jax.tree.map(jnp.asarray, eng.cache)

    def migrate(c):
        return kv_migrate(c, plan, src_pod=0, compress=True)

    sm = jax.shard_map(migrate, mesh=mesh, in_specs=(P(),), out_specs=P(),
                       axis_names={"pod"}, check_vma=False)
    with jax.set_mesh(mesh):
        moved = jax.jit(sm)(cache)
    ok = jax.tree.all(jax.tree.map(
        lambda a, b: bool(jnp.allclose(a.astype(jnp.float32),
                                       b.astype(jnp.float32),
                                       atol=0.1, rtol=0.1)), cache, moved))
    n_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))
    print(f"[serve] migrated {n_bytes / 2 ** 20:.1f} MiB of KV cache, "
          f"int8 wire, roundtrip-consistent: {ok}")


if __name__ == "__main__":
    main()
