"""End-to-end training driver: ~100M-parameter llama-family model for a
few hundred steps on 2 simulated pods with the full WANify runtime
(RF prediction -> global optimization -> AIMD re-planning -> compressed
chunked cross-pod sync), checkpointing and straggler handling enabled.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 200]
(CPU: ~100M params is sized to stay within laptop memory; on TPU drop
--small-model and raise the mesh.)
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax

from repro import compat
from repro.configs import get_config
from repro.core.predictor import BwPredictor
from repro.data.pipeline import DataConfig
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import AdamWConfig
from repro.wan.dataset import train_default_forest
from repro.wan.simulator import WanSimulator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/wanify_e2e_ckpt")
    args = ap.parse_args()

    # ~100M params: 12L x d512 x heads 8 x ff 2048, 32k vocab
    cfg = get_config("llama3-8b").replace(
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=4, d_ff=2048,
        vocab=32000, head_dim=0)
    n_params = sum(
        int(jax.numpy.prod(jax.numpy.array(l.shape)))
        for l in jax.tree.leaves(
            jax.eval_shape(lambda k: __import__(
                "repro.models.registry", fromlist=["x"]).init_params(cfg, k),
                jax.random.key(0))))
    print(f"[e2e] model: {n_params / 1e6:.1f}M params")

    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    print("[e2e] training RF predictor ...")
    rf, acc, _ = train_default_forest(n_samples=150, n_trees=50)
    sim = WanSimulator(seed=0)
    tr = Trainer(
        cfg, mesh,
        DataConfig(batch=args.batch, seq=args.seq, vocab=cfg.vocab,
                   n_pods=2, skew=0.3),
        LoopConfig(steps=args.steps, ckpt_dir=args.ckpt, ckpt_every=50,
                   sync="wanify", compress=True, replan_every=25),
        opt=AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps),
        sim=sim, predictor=BwPredictor(rf))
    print(f"[e2e] initial plan: conns={tr.plan.conns} "
          f"bits={tr.plan.compress_bits}")
    t0 = time.time()
    tr.run(jax.random.key(0))
    dt = time.time() - t0
    if not tr.history:
        print("[e2e] no steps ran (--steps 0?)")
        return
    first = tr.history[0]["loss"]
    last = tr.history[-1]["loss"]
    toks = args.steps * args.batch * args.seq
    print(f"[e2e] {args.steps} steps in {dt:.0f}s "
          f"({toks / dt:.0f} tok/s) loss {first:.3f} -> {last:.3f}")
    print(f"[e2e] events: {tr.events}")
    print(f"[e2e] controller: {len(tr.controller.record)} replans, "
          f"{len(tr.controller.plan_cache)} compiled plans cached")
    assert last < first, "loss must decrease"


if __name__ == "__main__":
    main()
