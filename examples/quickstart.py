"""Quickstart: the WANify pipeline end-to-end in ~60 seconds on CPU.

1. simulate the paper's 8-DC AWS WAN,
2. train the Random-Forest runtime-BW predictor on Bandwidth-Analyzer
   data,
3. globally optimize heterogeneous parallel connections (Algorithm 1 +
   Eq. 2-3), throttle BW-rich links,
4. show the min-BW gain over single-connection / uniform-parallel
   baselines,
5. train a tiny LM for a few steps with the WANify-scheduled cross-pod
   gradient sync (2 simulated pods).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro import compat
from repro.configs import get_config
from repro.configs.base import reduced
from repro.core.global_opt import global_optimize
from repro.core.predictor import BwPredictor
from repro.data.pipeline import DataConfig
from repro.train.loop import LoopConfig, Trainer
from repro.train.optimizer import AdamWConfig
from repro.wan.dataset import train_default_forest
from repro.wan.monitor import SnapshotMonitor
from repro.wan.simulator import WanSimulator


def main():
    print("== 1. simulate the 8-DC WAN (paper Fig. 1 calibration) ==")
    sim = WanSimulator(seed=0)
    si = sim.measure_static_independent()
    ue, uw, ap = (sim.regions.index(r) for r in ("us-east", "us-west",
                                                 "ap-se"))
    print(f"static BW us-east<->us-west {si[ue, uw]:.0f} Mbps "
          f"(paper 1700), us-east<->ap-se {si[ue, ap]:.0f} Mbps (paper 121)")

    print("\n== 2. train the runtime-BW Random Forest ==")
    rf, acc, r2 = train_default_forest(n_samples=150, n_trees=50)
    print(f"train accuracy (within 10%): {acc * 100:.1f}%  "
          f"holdout R^2: {r2:.3f} (paper: 98.51%)")

    print("\n== 3. predict runtime BW from a 1-second snapshot ==")
    predictor = BwPredictor(rf)
    _, raw = SnapshotMonitor(sim).capture()
    pred = predictor.predict_matrix(8, raw["snapshot_bw"], raw["mem_util"],
                                    raw["cpu_load"], raw["retrans"],
                                    raw["dist"])
    plan = global_optimize(pred, M=8)
    print("connection matrix (max):")
    print(plan.max_cons)

    print("\n== 4. minimum-BW gain (the paper's headline) ==")
    off = ~np.eye(8, dtype=bool)
    m1 = sim.measure_simultaneous(np.ones((8, 8)))[off].min()
    m8 = sim.measure_simultaneous(np.full((8, 8), 8.0))[off].min()
    mw = sim.measure_simultaneous(plan.max_cons.astype(float),
                                  cap=plan.throttle)[off].min()
    print(f"min BW: single {m1:.0f} | uniform-8 {m8:.0f} | "
          f"WANify {mw:.0f} Mbps ({mw / m1:.2f}x vs single)")

    print("\n== 5. 2-pod training with WANify-scheduled gradient sync ==")
    cfg = reduced(get_config("llama3-8b"))
    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    tr = Trainer(cfg, mesh,
                 DataConfig(batch=8, seq=32, vocab=cfg.vocab, n_pods=2),
                 LoopConfig(steps=6, sync="wanify", compress=True),
                 opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=6),
                 sim=sim, predictor=predictor)
    print(f"plan conns={tr.plan.conns} wire bits={tr.plan.compress_bits}")
    tr.run(jax.random.key(0))
    print("losses:", [f"{h['loss']:.3f}" for h in tr.history])


if __name__ == "__main__":
    main()
