"""Placement walkthrough — what runtime-BW gauging buys the analytics
layer (paper §2 and §5): the same geo-distributed query placed from
static single-connection estimates vs WANify's predicted BW x
heterogeneous connections, with the latency/cost deltas, then a
re-placement ride-along under a scripted link flap.

Run:  PYTHONPATH=src python examples/wan_planning.py

(The paper's Fig. 2 BW narrative lives in benchmarks/paper_tables.py
`bench_fig2`; the closed loop under scripted dynamics is
examples/wan_scenarios.py.)
"""
import numpy as np

from repro.control import WanifyController
from repro.core.predictor import SnapshotPredictor
from repro.placement import (PlacementPlanner, compare_backends,
                             get_workload)
from repro.wan.simulator import WanSimulator

QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0)


def show(tag, cost):
    print(f"  {tag:28s} makespan={cost.makespan_s:7.1f} s "
          f"(net {cost.net_s:6.1f})  egress=${cost.egress_usd:6.3f}  "
          f"total=${cost.total_usd:6.3f}")


def main():
    print("== one query, two BW backends (4 DCs of the 8-DC mesh) ==")
    sim = WanSimulator(seed=3, **QUIET)
    ctl = WanifyController(sim, SnapshotPredictor(), n_pods=4)
    ctl.replan(reason="warm")          # capture at the in-force matrix
    query = get_workload("two_stage_join", 4)
    print(f"  query: {query.name}, inputs (Gb) = "
          f"{[round(v, 1) for v in query.input_gb]}")

    static = PlacementPlanner(ctl, query, backend="static")
    wanify = PlacementPlanner(ctl, query, backend="wanify")
    off = ~np.eye(4, dtype=bool)
    print(f"  static solo-BW estimate  min={static.priced_bw()[off].min():7.1f} Mbps"
          f"  (measured pair-at-a-time, everything idle)")
    print(f"  WANify achievable BW     min={wanify.priced_bw()[off].min():7.1f} Mbps"
          f"  (predicted x heterogeneous conns)")

    # execute both placements under the TRUE contended network
    full = np.ones((sim.N, sim.N))
    true_static = sim.waterfill(full)[:4, :4]
    full[:4, :4] = wanify.exec_conns()
    true_wanify = sim.waterfill(full)[:4, :4]
    st = static.evaluate(true_static)
    wa = wanify.evaluate(true_wanify)
    show("static placement @ 1 conn", st)
    show("WANify placement @ plan", wa)
    print(f"  -> latency delta {100 * (1 - wa.makespan_s / st.makespan_s):.1f}%"
          f", total-cost delta {100 * (1 - wa.total_usd / st.total_usd):.1f}%")

    print("\n== re-placement under a scripted link flap ==")
    r = compare_backends("link_flap", query=query, seed=0)
    w, s = r["wanify"], r["static"]
    print(f"  30 steps, us-east<->us-west collapses at 10, restores at 20")
    print(f"  WANify: re-placed {w['replacements']}x, "
          f"makespan total {w['makespan_total_s']:.0f} s")
    print(f"  static: placed once,  "
          f"makespan total {s['makespan_total_s']:.0f} s")
    print(f"  -> latency delta {r['latency_delta_pct']:.1f}%, "
          f"egress delta {r['egress_delta_pct']:.1f}%")

    print("\n== the paper's skew setting (skew_ramp) ==")
    r = compare_backends("skew_ramp", query=query, seed=0)
    print(f"  latency delta {r['latency_delta_pct']:.1f}%, "
          f"egress delta {r['egress_delta_pct']:.1f}% "
          f"(positive = WANify better on both)")
    print("  (benchmarks/placement_bench.py sweeps scenario x workload "
          "and writes BENCH_placement.json)")


if __name__ == "__main__":
    main()
