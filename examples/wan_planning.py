"""WAN planning walkthrough — reproduces the paper's Fig. 2 narrative on
the calibrated simulator: single connection vs uniform parallelism vs
heterogeneous connections (+ throttling), with the Fig. 2d network-time
table. For the closed loop under scripted dynamics (flaps, bursts,
rescales, deterministic replay) see examples/wan_scenarios.py.

Run:  PYTHONPATH=src python examples/wan_planning.py
"""
import numpy as np

from repro.control import WanifyController, offset_schedule
from repro.core.global_opt import global_optimize
from repro.core.local_opt import AimdAgent
from repro.core.predictor import SnapshotPredictor
from repro.core.relations import infer_dc_relations
from repro.wan.simulator import WanSimulator


def show(name, bw, off):
    print(f"  {name:22s} min={bw[off].min():7.1f}  max={bw[off].max():7.1f} "
          f" mean={bw[off].mean():7.1f} Mbps")


def main():
    print("== Fig. 2: 3 DCs (two near, one far) ==")
    sim = WanSimulator(regions=["us-east", "us-west", "ap-se"], seed=2)
    off = ~np.eye(3, dtype=bool)
    show("single connection", sim.measure_simultaneous(np.ones((3, 3))), off)
    show("uniform 8 conns", sim.measure_simultaneous(np.full((3, 3), 8.0)),
         off)
    het = np.array([[0, 2, 11], [2, 0, 13], [11, 13, 0]], float)
    show("heterogeneous (2c)", sim.measure_simultaneous(het), off)

    print("\n== Algorithm 1 on the paper's worked example ==")
    bw = np.array([[1000, 400, 120], [380, 1000, 130], [110, 120, 1000]],
                  float)
    rel = infer_dc_relations(bw, D=30)
    print("closeness indices:\n", rel)
    plan = global_optimize(bw, M=8, D=30)
    print("maxCons (Eq. 3):\n", plan.max_cons)

    print("\n== full 8-DC plan + AIMD epoch ==")
    sim8 = WanSimulator(seed=5)
    pred = sim8.measure_runtime()
    plan8 = global_optimize(pred, M=8)
    off8 = ~np.eye(8, dtype=bool)
    show("single connection", sim8.measure_simultaneous(np.ones((8, 8))),
         off8)
    show("WANify (Eq. 3)", sim8.measure_simultaneous(
        plan8.max_cons.astype(float)), off8)
    show("WANify + TC", sim8.measure_simultaneous(
        plan8.max_cons.astype(float), cap=plan8.throttle), off8)
    agent = AimdAgent.from_plan(plan8, 0)
    mon = sim8.measure_snapshot(plan8.max_cons.astype(float))[0]
    before = agent.cons.copy()
    agent.step(mon)
    print(f"AIMD (us-east agent): cons {before.tolist()} -> "
          f"{agent.cons.tolist()}")

    print("\n== one controller plan + its wire schedule ==")
    ctl = WanifyController(sim=WanSimulator(seed=7),
                           predictor=SnapshotPredictor(), n_pods=4)
    print(f"initial plan: conns={ctl.plan.conns}")
    print(f"wire schedule: {offset_schedule(ctl.plan)}")
    print("(driving this loop through scripted WAN dynamics lives in "
          "examples/wan_scenarios.py)")


if __name__ == "__main__":
    main()
