"""Scenario engine walkthrough — the closed loop under scripted WAN
dynamics (replaces the ad-hoc controller loops that used to live in
wan_planning.py).

Run:  PYTHONPATH=src python examples/wan_scenarios.py

Shows four of the paper's §5 settings end-to-end:
  * link_flap    — a visible flap and recovery; the plan oscillates
                   back and the compile cache hits instead of
                   re-lowering (§3.2's plan stability);
  * congestion   — a cross-traffic burst trips the straggler trigger
                   exactly once and AIMD backs off (§3.2.2);
  * elastic      — DC join/leave re-plans for new pod counts (§3.3.2);
  * diurnal      — BW cycles; replans track the swing ([38]).

Then demonstrates deterministic replay (same seed => byte-identical
trace) and a custom scripted timeline via the event DSL.
"""
from repro.scenarios import (LinkDegrade, ScenarioSpec, Straggler, at,
                             get_scenario, run_scenario)

QUIET = dict(fluct_sigma=0.0, snapshot_sigma=0.0, runtime_sigma=0.0)


def show(res):
    s = res.summary()
    print(f"  {s['scenario']:20s} steps={s['steps']:3d} "
          f"replans={s['replans']} "
          f"throughput={s['throughput_mbps']:7.1f} Mbps "
          f"plans={s['distinct_plans']} "
          f"cache {s['cache_builds']} builds / {s['cache_hits']} hits")


def main():
    print("== named scenarios (repro.scenarios.library) ==")
    flap_res = None
    for name in ("link_flap", "congestion", "elastic", "diurnal"):
        res = run_scenario(get_scenario(name), seed=0)
        show(res)
        if name == "link_flap":
            flap_res = res

    print("\n== the flap, step by step ==")
    t = flap_res.trace
    for k in (9, 10, 15, 20, 25):
        s = t.steps[k]
        marks = ", ".join(s.events) or "-"
        print(f"  step {s.step:2d}: plan={s.plan_sig}  "
              f"achieved_min={s.achieved_min:7.1f} Mbps  events: {marks}")
    print("  -> post-recovery signature equals the pre-flap one; the "
          "consumer kept its compiled step")

    print("\n== deterministic replay ==")
    a = run_scenario(get_scenario("runtime_fluctuation"), seed=7)
    b = run_scenario(get_scenario("runtime_fluctuation"), seed=7)
    same = a.trace.to_json() == b.trace.to_json()
    print(f"  two seed-7 runs byte-identical: {same}")

    print("\n== a custom timeline via the event DSL ==")
    spec = ScenarioSpec(
        name="custom", steps=25,
        description="silent cut at 8, slow host at 16",
        events=(at(8, LinkDegrade(("us-east", "us-west"), factor=0.1)),
                at(16, Straggler(slowdown=3.0, duration=2))),
        sim_kwargs=dict(QUIET),
        cfg_kwargs=dict(replan_every=5, straggler_factor=2.0,
                        straggler_cooldown=5))
    res = run_scenario(spec, seed=0)
    show(res)
    log = [(r["reason"], r["step"])
           for s in res.trace.steps for r in s.replans]
    print(f"  replan log: {log}")


if __name__ == "__main__":
    main()
