"""obsctl — summarize, diff, and gate observability exports.

    PYTHONPATH=src python tools/obsctl.py run steady -o run.json
    PYTHONPATH=src python tools/obsctl.py summarize run.json
    PYTHONPATH=src python tools/obsctl.py diff a.json b.json
    PYTHONPATH=src python tools/obsctl.py check run.json --min-accuracy 0.5

`run` drives one named scenario with `REPRO_OBS=on` and writes the
canonical run document; `summarize` renders ANY of the repo's JSON
observability documents (obs runs, BENCH_*.json, dryrun cell lists)
through the one report path in `repro.obs.export`; `check` validates
the schema and optional SLE floors, exiting non-zero on any problem
(the CI obs-smoke gate).
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional


def _cmd_run(args) -> int:
    from repro.obs import export_scenario, to_json, write_json, \
        write_spans_jsonl
    from repro.scenarios import ScenarioEngine, get_scenario
    eng = ScenarioEngine(get_scenario(args.scenario), seed=args.seed,
                         obs="on")
    doc = export_scenario(eng.run(), eng)
    if args.out:
        write_json(doc, args.out)
        sys.stderr.write(f"wrote {args.out}\n")
    else:
        sys.stdout.write(to_json(doc))
    if args.spans:
        write_spans_jsonl(eng.tracer, args.spans)
        sys.stderr.write(f"wrote {args.spans} "
                         f"({len(eng.tracer.spans)} spans)\n")
    return 0


def _cmd_summarize(args) -> int:
    from repro.obs import load, summarize
    for path in args.paths:
        print(summarize(load(path)))
    return 0


def _cmd_diff(args) -> int:
    from repro.obs import diff_runs, load
    d = diff_runs(load(args.a), load(args.b))
    if not d:
        print("no numeric differences")
        return 0
    w = max(len(k) for k in d)
    for k, row in d.items():
        rel = f"  ({row['rel']:+.1%})" if "rel" in row else ""
        print(f"{k:<{w}}  {row['a']} -> {row['b']}{rel}")
    return 1 if args.fail_on_diff else 0


def _cmd_check(args) -> int:
    from repro.obs import check_run, load
    problems = check_run(load(args.path),
                         min_accuracy=args.min_accuracy,
                         min_capacity=args.min_capacity,
                         min_fairness=args.min_fairness,
                         max_usd=args.max_usd)
    for p in problems:
        print(f"FAIL: {p}")
    if not problems:
        print(f"OK: {args.path} passes schema + SLE checks")
    return 1 if problems else 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="obsctl", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("run", help="run a scenario with obs on + export")
    p.add_argument("scenario")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("-o", "--out", default=None,
                   help="run-document path (default: stdout)")
    p.add_argument("--spans", default=None,
                   help="also write per-span JSONL here")
    p.set_defaults(fn=_cmd_run)

    p = sub.add_parser("summarize", help="render any obs/bench JSON")
    p.add_argument("paths", nargs="+")
    p.set_defaults(fn=_cmd_summarize)

    p = sub.add_parser("diff", help="numeric-leaf diff of two documents")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--fail-on-diff", action="store_true")
    p.set_defaults(fn=_cmd_diff)

    p = sub.add_parser("check", help="schema + SLE-floor gate")
    p.add_argument("path")
    p.add_argument("--min-accuracy", type=float, default=None)
    p.add_argument("--min-capacity", type=float, default=None)
    p.add_argument("--min-fairness", type=float, default=None)
    p.add_argument("--max-usd", type=float, default=None)
    p.set_defaults(fn=_cmd_check)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
